"""Perf-trend gate: diff a fresh bench JSON against the committed
baseline and fail on regression.

    PYTHONPATH=src python -m benchmarks.check_bench \
        --current BENCH_o2_serve.json \
        --baseline benchmarks/baselines/BENCH_o2_serve.json \
        --max-regression 0.15

The guarded number is picked by the artifact's ``benchmark`` field:

  o2_serve  — the o2-vs-frozen throughput *ratio*;
  slo_serve — the static-over-adaptive p95 queue-wait *ratio* (>1 means
              adaptive slot scheduling beats static pools under bursts);
  o2_annex  — the assessment-phase *speedup* of the widest annex slice
              over the 1-device serial annex (>1 means pooled
              assessments actually shard over the slice);
  swap_safety — the poisoned-canary drill's pre/post probe *ratio*
              (1.0 means the rollback restored the incumbent bitwise).
              This gate also enforces hard invariants before comparing:
              at least one rolled-back swap, zero pool-wide promotions
              of the poisoned candidate, and zero new step-program
              binds across the whole canary cycle — any violation fails
              the gate outright, regardless of tolerance.
  fleet     — the fleet-mode tenant sweep's req/s *ratio* (largest
              tenant count over smallest; ~1 means req/s holds as the
              fleet grows).  Hard invariants first: zero new step or
              stacked-fine-tune program binds across the sweep, every
              cold tenant at zero device bytes, and the K-wide stacked
              round strictly faster than K serial rounds.
  kernel    — the fused-tick *speedup* (unfused over fused ms per
              serving tick).  Hard invariant first: the fused program
              (scan + capture append in one dispatch) must not run
              slower than the unfused two-dispatch path beyond the
              paired-measurement noise floor — fusing the tail can
              only remove work, so a genuinely slower fused tick means
              the fusion re-materialized something.
  chaos     — the health-layer fault battery's degraded-over-healthy
              RPS *ratio* (~1: a demoted annex costs serving nothing).
              Hard invariants first, same policy as swap_safety: zero
              non-finite trees ever served, at least one rejected
              fine-tune round, one annex demotion AND recovery, one
              tenant quarantine AND release, one watchdog-dropped
              dispatch, one rolled-back canary, and a flush that came
              back inside its deadline.

All are dimensionless on purpose, so the committed baselines survive
runner-hardware drift that absolute req/s or milliseconds would not.
The gate fails when the current ratio falls more than
``--max-regression`` (relative) below the baseline's; a faster ratio
updates nothing (refresh the baseline deliberately by re-running the
bench with ``--json`` and committing the artifact).
"""
from __future__ import annotations

import argparse
import json
import sys


def o2_ratio(doc: dict) -> float:
    for row in doc["rows"]:
        if row["mode"] == "o2":
            return float(row["vs_frozen"])
    raise KeyError("no 'o2' row in bench JSON")


def slo_ratio(doc: dict) -> float:
    return float(doc["p95_wait_static_over_adaptive"])


def annex_speedup(doc: dict) -> float:
    return float(doc["assess_speedup"])


def swap_safety(doc: dict) -> float:
    """Validate the drill's hard invariants, then hand back the probe
    ratio for the usual regression comparison.  A poisoned model that
    promoted pool-wide — or a rollback that failed to fire, or a canary
    cycle that re-traced programs — is a correctness failure, not a
    perf regression; no tolerance applies."""
    sw = doc["swaps"]
    problems = []
    if sw["rolled_back"] < 1:
        problems.append("no swap was rolled back")
    if sw["promoted"] != 0:
        problems.append(f"{sw['promoted']} poisoned candidate(s) "
                        f"promoted pool-wide")
    if doc["new_binds"] != 0:
        problems.append(f"{doc['new_binds']} new step-program bind(s) "
                        f"during the canary cycle")
    if problems:
        raise ValueError("; ".join(problems))
    return float(doc["post_rollback_ns_ratio"])


def fleet(doc: dict) -> float:
    """Validate fleet mode's hard invariants, then hand back the
    req/s-vs-tenant-count ratio (largest sweep point over smallest) for
    the trend comparison.  A program cache that grows with the tenant
    count, a cold tenant holding device memory, or a stacked round
    slower than its serial equivalent is a design violation, not a perf
    regression; no tolerance applies."""
    problems = []
    for row in doc["rows"]:
        n = row["tenants"]
        if row["new_step_binds"] != 0:
            problems.append(f"{row['new_step_binds']} new step-program "
                            f"bind(s) at {n} tenants")
        if row["new_fleet_binds"] != 0:
            problems.append(f"{row['new_fleet_binds']} new stacked "
                            f"fine-tune bind(s) at {n} tenants")
        if row["cold_device_bytes_max"] != 0:
            problems.append(f"a cold tenant holds "
                            f"{row['cold_device_bytes_max']} device "
                            f"bytes at {n} tenants")
    if doc["stack"]["speedup"] <= 1.0:
        problems.append(f"stacked round not sublinear: K="
                        f"{doc['stack']['k']} stacked took "
                        f"{doc['stack']['stacked_ms']}ms vs "
                        f"{doc['stack']['serial_ms']}ms serial")
    if problems:
        raise ValueError("; ".join(problems))
    return float(doc["rps_ratio"])


# fused-vs-unfused wall time is a paired measurement on shared CI
# hardware: the two variants run the identical scan and differ by one
# dispatch, so a *real* fusion regression (re-materialized intermediate,
# extra copy) shows up at 10%+ while honest runs jitter within a few
# percent either way.  The invariant tolerates that jitter and nothing
# more.
_TICK_NOISE_FLOOR = 1.05


def kernel(doc: dict) -> float:
    """Validate the fused-tick hard invariant, then hand back the
    unfused/fused tick-time ratio for the trend comparison.  A fused
    program measurably slower than the scan-plus-standalone-capture
    path it replaces is a fusion bug, not a perf regression; no
    tolerance applies beyond the paired-measurement noise floor."""
    t = doc["tick"]
    fused, unfused = float(t["fused_ms"]), float(t["unfused_ms"])
    if fused > unfused * _TICK_NOISE_FLOOR:
        raise ValueError(
            f"fused tick slower than unfused beyond the "
            f"{100 * (_TICK_NOISE_FLOOR - 1):.0f}% noise floor: "
            f"{fused}ms fused vs {unfused}ms unfused (k={t['k']}, "
            f"slots={t['slots']})")
    return unfused / fused


def chaos(doc: dict) -> float:
    """Validate the fault battery's hard invariants, then hand back the
    degraded-over-healthy RPS ratio for the trend comparison.  A fault
    that was never seen, never contained, or never recovered from is a
    correctness failure, not a perf regression; no tolerance applies."""
    h = doc["health"]
    problems = []
    if doc["nonfinite_served"] != 0:
        problems.append(f"{doc['nonfinite_served']} non-finite param "
                        f"tree(s) reached serving")
    if h["rejected_params"] < 1:
        problems.append("no poisoned fine-tune round was rejected")
    if h["annex_demotions"] < 1:
        problems.append("the annex was never demoted")
    if h["annex_recoveries"] < 1:
        problems.append("the annex never recovered")
    if h["quarantines"] < 1:
        problems.append("no tenant was quarantined")
    if h["quarantine_releases"] < 1:
        problems.append("no quarantine was released")
    if h["dropped_dispatches"] < 1:
        problems.append("the watchdog never dropped a dispatch")
    if doc["swaps"]["rolled_back_canary"] < 1:
        problems.append("no forced canary loss was rolled back")
    if doc["flush_s"] > doc["config"]["flush_deadline_s"]:
        problems.append(f"flush took {doc['flush_s']:.1f}s, past its "
                        f"{doc['config']['flush_deadline_s']:.0f}s "
                        f"deadline")
    if problems:
        raise ValueError("; ".join(problems))
    return float(doc["degraded_over_healthy_rps"])


# benchmark name -> (description of the guarded ratio, extractor)
METRICS = {
    "o2_serve": ("o2-vs-frozen ratio", o2_ratio),
    "slo_serve": ("static/adaptive p95 queue-wait ratio", slo_ratio),
    "o2_annex": ("annex-slice assessment speedup", annex_speedup),
    "swap_safety": ("post-rollback probe ratio", swap_safety),
    "chaos": ("degraded/healthy serving RPS ratio", chaos),
    "fleet": ("req/s ratio across the tenant-count sweep", fleet),
    "kernel": ("fused-tick speedup (unfused/fused tick ms)", kernel),
}


def main():
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--current", required=True)
    ap.add_argument("--baseline", required=True)
    ap.add_argument("--max-regression", type=float, default=0.15,
                    help="largest tolerated relative drop of the "
                         "guarded ratio")
    args = ap.parse_args()

    with open(args.current) as f:
        current = json.load(f)
    with open(args.baseline) as f:
        baseline = json.load(f)

    name = current.get("benchmark")
    if name != baseline.get("benchmark"):
        print(f"check_bench: benchmark mismatch: current={name!r} "
              f"baseline={baseline.get('benchmark')!r}", file=sys.stderr)
        sys.exit(2)
    if name not in METRICS:
        print(f"check_bench: no gated metric for benchmark={name!r} "
              f"(have {sorted(METRICS)})", file=sys.stderr)
        sys.exit(2)
    label, extract = METRICS[name]

    try:
        cur, base = extract(current), extract(baseline)
    except ValueError as e:
        print(f"check_bench: {name} invariant violation: {e}",
              file=sys.stderr)
        sys.exit(1)
    floor = base * (1.0 - args.max_regression)
    verdict = "OK" if cur >= floor else "REGRESSION"
    print(f"check_bench: {label} current={cur:.3f} "
          f"baseline={base:.3f} floor={floor:.3f} -> {verdict}")
    if cur < floor:
        print(f"check_bench: {label} regressed >"
              f"{100 * args.max_regression:.0f}% vs the committed "
              f"baseline ({args.baseline}); if intentional, refresh the "
              f"baseline artifact in the same change", file=sys.stderr)
        sys.exit(1)


if __name__ == "__main__":
    main()
