"""Shared benchmark plumbing: instances, method registry, agent cache.

Scales: "smoke" (seconds, CI), "paper" (minutes, default for
`python -m benchmarks.run`), "full" (set REPRO_BENCH_SCALE=full).
RL agents are pretrained once per (index, scale) and cached on disk so the
per-figure benchmarks measure *tuning*, not training (the paper separates
these too -- Table 3).
"""
from __future__ import annotations

import dataclasses
import os
import time

import jax
import numpy as np

from repro.core.ddpg import DDPGConfig
from repro.core.litune import LITune, LITuneConfig
from repro.core.maml import MetaConfig
from repro.index import env as E
from repro.index.workloads import sample_keys, wr_workload
from repro.tuning.base import run_tuner
from repro.tuning.baselines import make_baseline
from repro.tuning.ddpg_vanilla import VanillaConfig, VanillaDDPGTuner

CACHE_DIR = os.environ.get("REPRO_BENCH_CACHE", "/tmp/repro_bench_cache")
SCALE = os.environ.get("REPRO_BENCH_SCALE", "paper")

WORKLOADS = {"balanced": 1.0, "read_heavy": 1.0 / 3.0, "write_heavy": 3.0}
DATASETS = ("osm", "books", "fb", "mix")


@dataclasses.dataclass(frozen=True)
class BenchScale:
    n_keys: int
    n_queries: int
    pretrain_outer: int
    vanilla_episodes: int
    budget_steps: int
    extensive_steps: int
    n_seeds: int


SCALES = {
    "smoke": BenchScale(2048, 2048, 2, 2, 5, 8, 1),
    "paper": BenchScale(4096, 4096, 8, 10, 10, 30, 2),
    "full": BenchScale(8192, 8192, 24, 30, 25, 50, 5),
}


def bench_scale() -> BenchScale:
    return SCALES[SCALE]


def make_instance(index_type: str, dataset: str, wr: float, seed: int = 0):
    sc = bench_scale()
    key = jax.random.PRNGKey(seed * 7919 + hash(dataset) % 1000)
    data = sample_keys(key, sc.n_keys, dataset)
    workload, _ = wr_workload(jax.random.fold_in(key, 1), data, wr,
                              total=sc.n_queries, dist=dataset)
    env_cfg = E.EnvConfig(index_type=index_type)
    return env_cfg, data, workload


# ------------------------------------------------------------------ agents
def litune_config(index_type: str, safe_rl=True, use_o2=True) -> LITuneConfig:
    return LITuneConfig(
        index_type=index_type, episode_len=bench_scale().budget_steps,
        lstm_hidden=64, mlp_hidden=128,
        ddpg=DDPGConfig(batch_size=32, seq_len=4, burn_in=1),
        meta=MetaConfig(meta_batch=2, inner_episodes=1, inner_updates=6),
        safe_rl=safe_rl, use_o2=use_o2)


def get_litune(index_type: str, seed: int = 0, safe_rl=True,
               tag: str = "") -> LITune:
    os.makedirs(CACHE_DIR, exist_ok=True)
    path = os.path.join(
        CACHE_DIR, f"litune_{index_type}_{SCALE}_s{seed}"
        f"{'_unsafe' if not safe_rl else ''}{tag}.pkl")
    if os.path.exists(path):
        return LITune.load(path)
    tuner = LITune(litune_config(index_type, safe_rl=safe_rl), seed=seed)
    tuner.pretrain(n_outer=bench_scale().pretrain_outer, seed=seed)
    tuner.save(path)
    return tuner


def get_vanilla(index_type: str, seed: int = 0) -> VanillaDDPGTuner:
    # no disk cache (pickling is cheap to skip; pretrain is short)
    cfg = VanillaConfig(index_type=index_type,
                        episode_len=bench_scale().budget_steps,
                        lstm_hidden=64, mlp_hidden=128,
                        ddpg=DDPGConfig(use_lstm=False, batch_size=32,
                                        seq_len=4, burn_in=1))
    t = VanillaDDPGTuner(cfg, seed=seed)
    t.pretrain(n_episodes=bench_scale().vanilla_episodes)
    return t


# ------------------------------------------------------------------ runs
def run_method(method: str, index_type: str, dataset: str, wr: float,
               budget: int, seed: int = 0) -> dict:
    """Unified: returns {best, default, runtimes(best-so-far), failures}."""
    env_cfg, data, workload = make_instance(index_type, dataset, wr, seed)
    if method in ("random", "grid", "heuristic", "smbo"):
        space = env_cfg.space
        res = run_tuner(make_baseline(method, space, seed), env_cfg, data,
                        workload, wr, budget_evals=budget)
        return {"method": method, "best": res.best_runtime_ns,
                "default": res.default_runtime_ns,
                "best_so_far": list(res.best_so_far),
                "failures": res.failures, "wall_s": res.wall_s}
    if method == "default":
        env_cfg2, data, workload = make_instance(index_type, dataset, wr,
                                                 seed)
        from repro.index.env import evaluate_params
        import jax.numpy as jnp
        mod = __import__(f"repro.index.{index_type}",
                         fromlist=["DEFAULTS"])
        draw = {k: jnp.float32(v) for k, v in mod.DEFAULTS.items()}
        rt, _, viol = evaluate_params(env_cfg2, draw, data, workload, wr)
        return {"method": "default", "best": float(rt), "default": float(rt),
                "best_so_far": [float(rt)] * budget, "failures": 0,
                "wall_s": 0.0}
    if method == "ddpg":
        t0 = time.time()
        agent = get_vanilla(index_type, seed)
        res = agent.tune(data, workload, wr, budget_steps=budget)
        bsf = list(np.minimum.accumulate(res["runtimes"]))
        bsf += [bsf[-1]] * (budget - len(bsf))
        return {"method": "ddpg", "best": res["best_runtime_ns"],
                "default": res["r0_ns"], "best_so_far": bsf,
                "failures": res["violations"], "wall_s": time.time() - t0}
    if method.startswith("litune"):
        safe = "nosafe" not in method
        t0 = time.time()
        tuner = get_litune(index_type, seed, safe_rl=safe)
        res = tuner.tune(data, workload, wr, budget_steps=budget)
        bsf = list(np.minimum.accumulate(res["runtimes"]))
        bsf += [bsf[-1]] * max(0, budget - len(bsf))
        return {"method": method, "best": res["best_runtime_ns"],
                "default": res["r0_ns"], "best_so_far": bsf,
                "failures": res["violations"], "wall_s": time.time() - t0}
    raise ValueError(method)


METHODS = ("default", "random", "grid", "heuristic", "smbo", "ddpg", "litune")


def csv_row(*fields) -> str:
    return ",".join(str(f) for f in fields)
