"""Kernel fast-path microbenchmark: probe, env step, fused tick.

    PYTHONPATH=src python -m benchmarks.kernel_bench
    PYTHONPATH=src python -m benchmarks.kernel_bench --json BENCH_kernel.json

Three layers of the compiled-Pallas seam (kernels/dispatch.py), reported
as CSV rows and an optional JSON artifact for the CI perf gate
(benchmarks/check_bench.py, metric ``kernel``):

  probe    — us/call of the predecessor probe at the bottom of every
             `run_reads`: the `searchsorted` reference vs the Pallas
             `index_probe.batched_lookup` in interpret mode (kernel
             *logic* timing — the interpreter is not a serving path) vs
             compiled (skip-marked unless an accelerator backend is up);
  env_step — ns/op of the full `alex.run_reads` read path under the
             same three kernel postures (`KernelConfig(mode=...)`);
  tick     — the headline: one K-rung serving tick, fused (scan +
             capture append in one resident program,
             `_step_program(capture=True)`) vs unfused (the historical
             scan program + standalone `_capture_write` dispatch),
             best-of-``--repeats`` ms per tick.  The gate's hard
             invariant is fused <= unfused: the fused program does
             strictly less dispatch work for the same math.

The JSON ratio the gate trends is ``unfused_ms / fused_ms``
(dimensionless, so the committed baseline survives runner drift).
"""
from __future__ import annotations

import argparse
import json
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.ddpg import DDPGConfig
from repro.core.etmdp import transition_view
from repro.core.litune import LITune, LITuneConfig
from repro.index import alex
from repro.index.workloads import sample_keys, wr_workload
from repro.kernels import dispatch
from repro.kernels.dispatch import KernelConfig
from repro.kernels.index_probe.ops import _auto_tile, batched_lookup
from repro.launch.serving import O2ServiceConfig, ServeConfig, TuningService
from repro.launch.serving.programs import (_capture_write, _pow2_ladder,
                                           _step_program)

POSTURES = ("ref", "interpret", "compiled")


def _on_accel() -> bool:
    return jax.default_backend() in ("gpu", "tpu")


def _skip_compiled(mode: str) -> str | None:
    """Reason string when `mode` cannot run on this backend, else None."""
    if mode == "compiled" and not _on_accel():
        return f"no accelerator backend (jax: {jax.default_backend()})"
    return None


def _time_us(fn, n_timing: int) -> float:
    fn()                                    # warm (bind outside timing)
    t0 = time.perf_counter()
    for _ in range(n_timing):
        fn()
    return (time.perf_counter() - t0) / n_timing * 1e6


# ------------------------------------------------------------------ probe
def bench_probe(n_keys: int, n_queries: int, n_timing: int) -> list[dict]:
    key = jax.random.PRNGKey(0)
    keys = jnp.sort(jax.random.uniform(key, (n_keys,)))
    queries = jax.random.uniform(jax.random.fold_in(key, 1), (n_queries,))
    tile = _auto_tile(n_keys)
    rows = []

    ss = jax.jit(lambda k, q: jnp.clip(
        jnp.searchsorted(k, q, side="right") - 1, 0, k.shape[0] - 1))
    us = _time_us(lambda: ss(keys, queries).block_until_ready(), n_timing)
    rows.append({"impl": "searchsorted_ref", "us_per_call": round(us, 1)})

    for mode in ("interpret", "compiled"):
        skip = _skip_compiled(mode)
        if skip:
            rows.append({"impl": f"pallas_{mode}", "skipped": skip})
            continue
        us = _time_us(
            lambda: batched_lookup(keys, queries, tile=tile,
                                   qcap=n_queries,
                                   mode=mode)[0].block_until_ready(),
            n_timing)
        rows.append({"impl": f"pallas_{mode}", "us_per_call": round(us, 1)})
    return rows


# --------------------------------------------------------------- env step
def bench_env_step(n_keys: int, n_reads: int, n_timing: int) -> list[dict]:
    key = jax.random.PRNGKey(1)
    keys = jnp.sort(jax.random.uniform(key, (n_keys,)))
    reads = jax.random.uniform(jax.random.fold_in(key, 1), (n_reads,))
    params = {k: jnp.float32(v) for k, v in alex.DEFAULTS.items()}
    idx = alex.build(keys, params)
    rows = []
    for mode in POSTURES:
        skip = _skip_compiled(mode)
        if skip:
            rows.append({"kernel": mode, "skipped": skip})
            continue
        kcfg = None if mode == "ref" else KernelConfig(mode=mode)
        fn = jax.jit(lambda r, _k=kcfg: alex.run_reads(idx, r, kernel=_k)[0])
        us = _time_us(lambda: fn(reads).block_until_ready(), n_timing)
        rows.append({"kernel": mode,
                     "ns_per_op": round(us * 1e3 / n_reads, 1)})
    return rows


# ------------------------------------------------------------- fused tick
def _make_requests(n: int, n_keys: int, seed: int = 1):
    dists = ["uniform", "books", "osm", "fb"]
    key = jax.random.PRNGKey(seed)
    out = []
    for i in range(n):
        k = jax.random.fold_in(key, i)
        data = sample_keys(k, n_keys, dists[i % len(dists)])
        wl, _ = wr_workload(jax.random.fold_in(k, 1), data, 1.0,
                            total=n_keys, dist="mix")
        out.append((data, wl, 1.0))
    return out


def bench_tick(slots: int, budget: int, n_keys: int, ticks: int,
               repeats: int) -> dict:
    """Fused vs unfused K-rung tick on real, program-cache-resident
    executables: serve a short O2 stream to bind the ladder and leave a
    live pool, then drive both step variants directly from its state.
    Each timed tick rebinds carry/capture exactly like the serving loop
    (donation-safe on accelerators) and blocks on the same narrow field
    the service fetches."""
    cfg = LITuneConfig(index_type="alex", episode_len=budget,
                       lstm_hidden=32, mlp_hidden=64,
                       ddpg=DDPGConfig(batch_size=16, seq_len=4, burn_in=1))
    svc = TuningService(LITune(cfg, seed=0), config=ServeConfig(
        slots=slots, horizon_cap=budget,
        o2=O2ServiceConfig(enabled=True)))
    for data, wl, wr in _make_requests(slots, n_keys):
        svc.submit(data, wl, wr, budget_steps=budget, noise_scale=0.02)
    svc.run()
    svc.flush_o2()
    pool = next(iter(svc.pools.values()))
    k = max(_pow2_ladder(budget))
    prog_u = _step_program(pool.slice, pool.net_cfg, pool.env_cfg,
                           pool.et_cfg, k)
    prog_f = _step_program(pool.slice, pool.net_cfg, pool.env_cfg,
                           pool.et_cfg, k, capture=True)
    noise = pool.noise_dev()
    off = jnp.zeros((slots,), jnp.int32)

    def fresh():
        # private buffers per timed run: the programs donate carry/cap
        # on accelerator backends, so state must rebind like the tick
        carry = jax.tree.map(jnp.array, pool.carry)
        cap = jax.tree.map(jnp.array, pool.ensure_cap())
        return carry, cap

    def run_fused():
        carry, cap = fresh()
        t0 = time.perf_counter()
        for _ in range(ticks):
            carry, out, cap = prog_f(pool.params, carry, noise, cap, off)
            np.asarray(out["reward"][-1])   # the serving loop's fetch
        return (time.perf_counter() - t0) / ticks * 1e3

    def run_unfused():
        carry, cap = fresh()
        t0 = time.perf_counter()
        for _ in range(ticks):
            carry, out = prog_u(pool.params, carry, noise)
            np.asarray(out["reward"][-1])
            cap = _capture_write(cap, transition_view(out), off)
            cap.block_until_ready()
        return (time.perf_counter() - t0) / ticks * 1e3

    run_fused(), run_unfused()              # warm both variants
    # interleave the variants so both mins sample the same machine
    # conditions (back-to-back blocks would let CPU-frequency / noisy-
    # neighbor drift decide the comparison)
    f_times, u_times = [], []
    for _ in range(repeats):
        f_times.append(run_fused())
        u_times.append(run_unfused())
    fused_ms, unfused_ms = min(f_times), min(u_times)
    return {"k": k, "slots": slots, "ticks": ticks,
            "fused_ms": round(fused_ms, 3),
            "unfused_ms": round(unfused_ms, 3),
            "speedup": round(unfused_ms / fused_ms, 3)}


def main():
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--n-keys", type=int, default=4096)
    ap.add_argument("--n-queries", type=int, default=512)
    ap.add_argument("--slots", type=int, default=4)
    ap.add_argument("--budget", type=int, default=16)
    ap.add_argument("--ticks", type=int, default=20,
                    help="ticks per timed tick-bench run")
    ap.add_argument("--timing", type=int, default=5,
                    help="calls per probe/env-step timing")
    ap.add_argument("--repeats", type=int, default=5,
                    help="timed runs per tick variant; min is reported")
    ap.add_argument("--json", default=None, metavar="PATH",
                    help="also write results as a JSON artifact (CI gate)")
    args = ap.parse_args()

    if dispatch.resolve(None) == "compiled" and not _on_accel():
        # REPRO_KERNEL_MODE=compiled forced without an accelerator: every
        # serving-path posture below would die inside pallas lowering.
        # Mirror the tests' skip-marker instead of crashing mid-bench.
        print("bench,layer,impl,value")
        print(f"kernel,all,compiled,SKIP(no accelerator backend, "
              f"jax: {jax.default_backend()})")
        return

    probe = bench_probe(args.n_keys, args.n_queries, args.timing)
    env_step = bench_env_step(args.n_keys, args.n_queries, args.timing)
    tick = bench_tick(args.slots, args.budget, min(args.n_keys, 1024),
                      args.ticks, args.repeats)

    print("bench,layer,impl,value")
    for r in probe:
        v = r.get("us_per_call", f"SKIP({r.get('skipped')})")
        print(f"kernel,probe,{r['impl']},{v}")
    for r in env_step:
        v = r.get("ns_per_op", f"SKIP({r.get('skipped')})")
        print(f"kernel,env_step,{r['kernel']},{v}")
    print(f"kernel,tick,fused_ms,{tick['fused_ms']}")
    print(f"kernel,tick,unfused_ms,{tick['unfused_ms']}")
    print(f"kernel,tick,speedup,{tick['speedup']}")

    if args.json:
        doc = {
            "benchmark": "kernel",
            "backend": jax.default_backend(),
            "mode_default": dispatch.resolve(None),
            "probe": probe,
            "env_step": env_step,
            "tick": tick,
            "config": {"n_keys": args.n_keys, "n_queries": args.n_queries,
                       "slots": args.slots, "budget": args.budget,
                       "ticks": args.ticks, "repeats": args.repeats},
        }
        with open(args.json, "w") as f:
            json.dump(doc, f, indent=1)
        print(f"wrote {args.json}")


if __name__ == "__main__":
    main()
