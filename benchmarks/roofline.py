"""Roofline benchmark: wraps launch/dryrun.py records into CSV rows.

NOTE: must run in a separate process from the other benchmarks when the
512-device flag is needed; benchmarks/run.py shells out for that reason.
This module also provides small-mesh (in-process, 1-device) micro-bench
rows: wall-clock us/call of the jitted smoke-scale step functions, which is
the only *measured* timing this CPU container can produce.
"""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp

from benchmarks.common import csv_row
from repro.configs import ARCH_NAMES, get_config, smoke
from repro.models import model_zoo


def micro_steps(n_timing: int = 5) -> list[str]:
    rows = [csv_row("micro_step", "arch", "fn", "us_per_call")]
    key = jax.random.PRNGKey(0)
    for name in ARCH_NAMES:
        cfg = smoke(get_config(name))
        bundle = model_zoo.build(cfg, remat=False)
        params = bundle.init(key)
        B, S = 2, 64
        tokens = jax.random.randint(key, (B, S), 0, cfg.vocab_size)
        labels = jax.random.randint(key, (B, S), 0, cfg.vocab_size)
        kwargs = {}
        if cfg.frontend == "vision_stub":
            kwargs["frontend_embeds"] = jnp.zeros(
                (B, cfg.n_frontend_tokens, cfg.d_model), jnp.bfloat16)
        if cfg.enc_dec:
            kwargs["enc_embeds"] = jnp.zeros((B, cfg.enc_seq, cfg.d_model),
                                             jnp.bfloat16)
        fwd = jax.jit(lambda p, t, kw: bundle.loss_fn(p, t, labels, **kw))
        fwd(params, tokens, kwargs).block_until_ready()
        t0 = time.perf_counter()
        for _ in range(n_timing):
            fwd(params, tokens, kwargs).block_until_ready()
        us = (time.perf_counter() - t0) / n_timing * 1e6
        rows.append(csv_row("micro_step", name, "loss", f"{us:.0f}"))
    return rows


def kernel_micro(n_timing: int = 3) -> list[str]:
    """us/call of the Pallas kernels in interpret mode vs their jnp refs
    (correctness-path timing only; TPU perf comes from the dry-run)."""
    import numpy as np
    from repro.kernels.flash_attention.kernel import flash_attention
    from repro.kernels.flash_attention.ref import attention_ref
    from repro.kernels.mamba_scan.kernel import selective_scan
    from repro.kernels.mamba_scan.ref import selective_scan_ref
    from repro.kernels.index_probe.ops import batched_lookup

    rows = [csv_row("kernel_micro", "kernel", "impl", "us_per_call")]
    key = jax.random.PRNGKey(0)
    q = jax.random.normal(key, (1, 2, 256, 64))
    jr = jax.jit(lambda q: attention_ref(q, q, q, causal=True))
    jr(q).block_until_ready()
    t0 = time.perf_counter()
    for _ in range(n_timing):
        jr(q).block_until_ready()
    rows.append(csv_row("kernel_micro", "flash_attention", "jnp_ref",
                        f"{(time.perf_counter()-t0)/n_timing*1e6:.0f}"))

    keys = jnp.sort(jax.random.uniform(key, (8 * 256,)))
    queries = jax.random.uniform(key, (128,))
    # explicit interpret mode: the row times the Pallas kernel *body*
    # (auto would resolve to the jnp ref on this CPU container)
    fn = jax.jit(lambda k, qq: batched_lookup(k, qq, tile=256, qcap=64,
                                              mode="interpret"))
    fn(keys, queries)[0].block_until_ready()
    t0 = time.perf_counter()
    for _ in range(n_timing):
        fn(keys, queries)[0].block_until_ready()
    rows.append(csv_row("kernel_micro", "index_probe", "pallas_interpret",
                        f"{(time.perf_counter()-t0)/n_timing*1e6:.0f}"))

    u = jax.random.normal(key, (1, 128, 64))
    dt = jax.nn.softplus(jax.random.normal(key, (1, 128, 64)))
    bm = jax.random.normal(key, (1, 128, 8))
    a = -jnp.exp(jax.random.normal(key, (64, 8)))
    jr2 = jax.jit(lambda *xs: selective_scan_ref(*xs))
    jr2(u, dt, bm, bm, a).block_until_ready()
    t0 = time.perf_counter()
    for _ in range(n_timing):
        jr2(u, dt, bm, bm, a).block_until_ready()
    rows.append(csv_row("kernel_micro", "mamba_scan", "jnp_ref",
                        f"{(time.perf_counter()-t0)/n_timing*1e6:.0f}"))
    return rows

def _kernel_record(name: str, shape: str, jitted, args, kwargs=None) -> dict:
    """Lower one hot-path kernel, run the HLO through the analytic
    roofline, and emit a record render_roofline.table() can consume
    (mesh="kernel" keeps these rows out of the 16x16 model tables)."""
    from repro.runtime import hlo_analysis as ha

    compiled = jitted.lower(*args, **(kwargs or {})).compile()
    analysis = ha.analyze(compiled.as_text())
    # no 6ND notion for data-movement kernels: the HLO flops *are* the
    # model flops, so useful_ratio pins at 1.0 and the interesting
    # numbers are bytes, arithmetic intensity, and the dominant term
    terms = ha.roofline(analysis, analysis.flops)
    arg_bytes = sum(x.nbytes for x in jax.tree.leaves((args, kwargs))
                    if hasattr(x, "nbytes"))
    ai = (analysis.flops / analysis.bytes_accessed
          if analysis.bytes_accessed else 0.0)
    return {
        "mesh": "kernel", "arch": name, "shape": shape, "status": "ok",
        "analytic_memory": {"total": arg_bytes},
        "arithmetic_intensity": round(ai, 4),
        "hlo_analysis": analysis.as_dict(),
        "roofline": {
            "compute_s": terms.compute_s,
            "memory_s": terms.memory_s,
            "collective_s": terms.collective_s,
            "collective_wire_s": terms.collective_wire_s,
            "dominant": terms.dominant,
            "model_flops_per_dev": analysis.flops,
            "hlo_flops_per_dev": analysis.flops,
            "useful_ratio": terms.useful_ratio,
            "roofline_fraction": terms.roofline_fraction,
            "step_time_s": terms.step_time_s,
        },
    }


def kernel_roofline(out: str | None = None) -> list[str]:
    """Arithmetic-intensity records for the serving hot path behind
    kernels/dispatch.py: the index_probe predecessor lookup, the
    fused-tick capture append, and the fused vs unfused K-rung serving
    tick programs.  Lowered on the host backend as a projection against
    the same TPU-v5e roofline constants the dry-run uses; write JSONL
    with ``out=`` and render with benchmarks/render_roofline.py."""
    import json

    from repro.core.litune import LITune, LITuneConfig
    from repro.index.workloads import sample_keys, wr_workload
    from repro.kernels.fused_tick.ops import fused_capture
    from repro.kernels.fused_tick.ref import FIELD_ORDER, fused_capture_ref
    from repro.kernels.index_probe.ops import _auto_tile, batched_lookup
    from repro.launch.serving import (O2ServiceConfig, ServeConfig,
                                      TuningService)
    from repro.launch.serving.programs import _pow2_ladder, _step_program

    records = []
    key = jax.random.PRNGKey(0)

    # -- index_probe: the predecessor lookup under every run_reads
    n, q = 4096, 512
    keys = jnp.sort(jax.random.uniform(key, (n,)))
    queries = jax.random.uniform(jax.random.fold_in(key, 1), (q,))
    tile = _auto_tile(n)
    for mode in ("ref", "interpret"):        # compiled needs a real accel
        fn = jax.jit(lambda k, qq, _m=mode: batched_lookup(
            k, qq, tile=tile, qcap=q, mode=_m))
        records.append(_kernel_record(
            "index_probe", f"n{n} q{q} t{tile} {mode}", fn, (keys, queries)))

    # -- fused_tick capture append (standalone dispatch = the unfused
    #    tail; the same body fuses into the step program below)
    k_steps, b, h = 1, 2, 8
    dims = {"obs": 6, "next_obs": 6, "h_a": 16, "c_a": 16, "h_q": 16,
            "c_q": 16}
    wide = sum(dims.values())
    new = {f: jax.random.normal(jax.random.fold_in(key, 2 + i),
                                (k_steps, b, dims[f]), jnp.float32)
           for i, f in enumerate(FIELD_ORDER)}
    cap = jnp.zeros((b, h, wide), jnp.float32)
    off = jnp.zeros((b,), jnp.int32)
    for mode in ("ref", "interpret"):
        records.append(_kernel_record(
            "fused_capture", f"B{b} H{h} w{wide} {mode}", fused_capture,
            (cap, new, off), {"mode": mode}))
    records.append(_kernel_record(
        "capture_write", f"B{b} H{h} w{wide} standalone",
        jax.jit(fused_capture_ref), (cap, new, off)))

    # -- the K-rung serving tick, fused vs unfused: bind the real ladder
    #    by serving a short O2 stream, then lower both resident variants
    budget, slots = 8, 2
    cfg = LITuneConfig(index_type="alex", episode_len=budget,
                       lstm_hidden=16, mlp_hidden=32)
    svc = TuningService(LITune(cfg, seed=0), config=ServeConfig(
        slots=slots, horizon_cap=budget, seed=0,
        o2=O2ServiceConfig(enabled=True)))
    for i in range(slots):
        kk = jax.random.fold_in(key, 100 + i)
        data = sample_keys(kk, 512, "mix")
        wl, _ = wr_workload(jax.random.fold_in(kk, 1), data, 1.0,
                            total=512, dist="mix")
        svc.submit(data, wl, 1.0, budget_steps=budget)
    svc.run()
    svc.flush_o2()
    pool = next(iter(svc.pools.values()))
    k = max(_pow2_ladder(budget))
    noise = pool.noise_dev()
    offs = jnp.zeros((slots,), jnp.int32)
    prog_u = _step_program(pool.slice, pool.net_cfg, pool.env_cfg,
                           pool.et_cfg, k)
    prog_f = _step_program(pool.slice, pool.net_cfg, pool.env_cfg,
                           pool.et_cfg, k, capture=True)
    records.append(_kernel_record(
        "serving_tick", f"K{k} slots{slots} unfused_scan", prog_u,
        (pool.params, pool.carry, noise)))
    records.append(_kernel_record(
        "serving_tick", f"K{k} slots{slots} fused", prog_f,
        (pool.params, pool.carry, noise, pool.ensure_cap(), offs)))

    if out:
        with open(out, "w") as f:
            for r in records:
                f.write(json.dumps(r) + "\n")

    rows = [csv_row("kernel_roofline", "kernel", "shape", "gflop",
                    "mbytes_hlo", "ai_flops_per_byte", "dominant",
                    "step_time_us")]
    for r in records:
        hlo = r["hlo_analysis"]
        rows.append(csv_row(
            "kernel_roofline", r["arch"], r["shape"].replace(" ", "_"),
            f"{hlo['flops'] / 1e9:.4f}",
            f"{hlo['bytes_accessed'] / 1e6:.3f}",
            f"{r['arithmetic_intensity']:.3f}",
            r["roofline"]["dominant"],
            f"{r['roofline']['step_time_s'] * 1e6:.2f}"))
    return rows


if __name__ == "__main__":
    import argparse

    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out", default=None, metavar="PATH",
                    help="also write kernel_roofline records as JSONL "
                         "(render: python -m benchmarks.render_roofline "
                         "PATH kernel)")
    cli = ap.parse_args()
    for row in kernel_roofline(out=cli.out):
        print(row)
