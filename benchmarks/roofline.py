"""Roofline benchmark: wraps launch/dryrun.py records into CSV rows.

NOTE: must run in a separate process from the other benchmarks when the
512-device flag is needed; benchmarks/run.py shells out for that reason.
This module also provides small-mesh (in-process, 1-device) micro-bench
rows: wall-clock us/call of the jitted smoke-scale step functions, which is
the only *measured* timing this CPU container can produce.
"""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp

from benchmarks.common import csv_row
from repro.configs import ARCH_NAMES, get_config, smoke
from repro.models import model_zoo


def micro_steps(n_timing: int = 5) -> list[str]:
    rows = [csv_row("micro_step", "arch", "fn", "us_per_call")]
    key = jax.random.PRNGKey(0)
    for name in ARCH_NAMES:
        cfg = smoke(get_config(name))
        bundle = model_zoo.build(cfg, remat=False)
        params = bundle.init(key)
        B, S = 2, 64
        tokens = jax.random.randint(key, (B, S), 0, cfg.vocab_size)
        labels = jax.random.randint(key, (B, S), 0, cfg.vocab_size)
        kwargs = {}
        if cfg.frontend == "vision_stub":
            kwargs["frontend_embeds"] = jnp.zeros(
                (B, cfg.n_frontend_tokens, cfg.d_model), jnp.bfloat16)
        if cfg.enc_dec:
            kwargs["enc_embeds"] = jnp.zeros((B, cfg.enc_seq, cfg.d_model),
                                             jnp.bfloat16)
        fwd = jax.jit(lambda p, t, kw: bundle.loss_fn(p, t, labels, **kw))
        fwd(params, tokens, kwargs).block_until_ready()
        t0 = time.perf_counter()
        for _ in range(n_timing):
            fwd(params, tokens, kwargs).block_until_ready()
        us = (time.perf_counter() - t0) / n_timing * 1e6
        rows.append(csv_row("micro_step", name, "loss", f"{us:.0f}"))
    return rows


def kernel_micro(n_timing: int = 3) -> list[str]:
    """us/call of the Pallas kernels in interpret mode vs their jnp refs
    (correctness-path timing only; TPU perf comes from the dry-run)."""
    import numpy as np
    from repro.kernels.flash_attention.kernel import flash_attention
    from repro.kernels.flash_attention.ref import attention_ref
    from repro.kernels.mamba_scan.kernel import selective_scan
    from repro.kernels.mamba_scan.ref import selective_scan_ref
    from repro.kernels.index_probe.ops import batched_lookup

    rows = [csv_row("kernel_micro", "kernel", "impl", "us_per_call")]
    key = jax.random.PRNGKey(0)
    q = jax.random.normal(key, (1, 2, 256, 64))
    jr = jax.jit(lambda q: attention_ref(q, q, q, causal=True))
    jr(q).block_until_ready()
    t0 = time.perf_counter()
    for _ in range(n_timing):
        jr(q).block_until_ready()
    rows.append(csv_row("kernel_micro", "flash_attention", "jnp_ref",
                        f"{(time.perf_counter()-t0)/n_timing*1e6:.0f}"))

    keys = jnp.sort(jax.random.uniform(key, (8 * 256,)))
    queries = jax.random.uniform(key, (128,))
    fn = jax.jit(lambda k, qq: batched_lookup(k, qq, tile=256, qcap=64))
    fn(keys, queries)[0].block_until_ready()
    t0 = time.perf_counter()
    for _ in range(n_timing):
        fn(keys, queries)[0].block_until_ready()
    rows.append(csv_row("kernel_micro", "index_probe", "pallas_interpret",
                        f"{(time.perf_counter()-t0)/n_timing*1e6:.0f}"))

    u = jax.random.normal(key, (1, 128, 64))
    dt = jax.nn.softplus(jax.random.normal(key, (1, 128, 64)))
    bm = jax.random.normal(key, (1, 128, 8))
    a = -jnp.exp(jax.random.normal(key, (64, 8)))
    jr2 = jax.jit(lambda *xs: selective_scan_ref(*xs))
    jr2(u, dt, bm, bm, a).block_until_ready()
    t0 = time.perf_counter()
    for _ in range(n_timing):
        jr2(u, dt, bm, bm, a).block_until_ready()
    rows.append(csv_row("kernel_micro", "mamba_scan", "jnp_ref",
                        f"{(time.perf_counter()-t0)/n_timing*1e6:.0f}"))
    return rows
