"""Render roofline JSONL records into the EXPERIMENTS.md markdown tables."""
from __future__ import annotations

import json
import sys


def load(path: str) -> list[dict]:
    out = []
    with open(path) as f:
        for line in f:
            out.append(json.loads(line))
    return out


def fmt_e(x):
    return f"{x:.2e}"


def table(records: list[dict], mesh: str) -> str:
    rows = ["| arch | shape | status | mem/dev GiB | compute s | memory s | "
            "collective s | dominant | useful (6ND/HLO) | roofline frac |",
            "|---|---|---|---|---|---|---|---|---|---|"]
    for r in records:
        if r["mesh"] != mesh:
            continue
        if r["status"] == "skipped":
            rows.append(f"| {r['arch']} | {r['shape']} | SKIP (full attention"
                        f" @500k) | - | - | - | - | - | - | - |")
            continue
        if r["status"] == "error":
            rows.append(f"| {r['arch']} | {r['shape']} | ERROR | - | - | - |"
                        f" - | - | - | - |")
            continue
        rf = r["roofline"]
        mem = r["analytic_memory"]["total"] / 2**30
        rows.append(
            f"| {r['arch']} | {r['shape']} | ok | {mem:.2f} | "
            f"{fmt_e(rf['compute_s'])} | {fmt_e(rf['memory_s'])} | "
            f"{fmt_e(rf['collective_s'])} | {rf['dominant']} | "
            f"{rf['useful_ratio']:.2f} | {rf['roofline_fraction']:.3f} |")
    return "\n".join(rows)


def summary_by_dominant(records: list[dict], mesh: str) -> str:
    from collections import Counter
    c = Counter(r["roofline"]["dominant"] for r in records
                if r["mesh"] == mesh and r["status"] == "ok")
    return ", ".join(f"{k}: {v}" for k, v in c.most_common())


if __name__ == "__main__":
    recs = load(sys.argv[1])
    mesh = sys.argv[2] if len(sys.argv) > 2 else "16x16"
    print(table(recs, mesh))
    print()
    print("dominant terms:", summary_by_dominant(recs, mesh))
