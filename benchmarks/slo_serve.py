"""Tail latency under bursty arrivals: static vs adaptive slot
scheduling.

    PYTHONPATH=src python -m benchmarks.slo_serve
    PYTHONPATH=src python -m benchmarks.slo_serve --bursts 4 \
        --burst-mean 10 --budget 8 --n-keys 512 --json BENCH_slo_serve.json

The other serving benches measure throughput on a pre-loaded queue; this
one measures what a *tenant* feels — queue-wait and serve-time
percentiles — under the arrival pattern that breaks static pools:
Poisson-sized bursts separated by idle gaps.  The same arrival trace is
replayed against two services:

  static   — fixed pool width (`--slots`), PR 1–3 behavior: a burst
             deeper than the pool waits out earlier waves slot by slot;
  adaptive — `AdaptiveSlotPolicy`: the scheduler grows the pool to the
             burst (ladder widths up to `--max-slots`, one cached gather
             per resize, zero program re-traces) and shrinks it back in
             the gaps.

``--policy edf`` appends a third row: `EDFSlotPolicy` admission —
tight-deadline requests enter free slots first and requests whose
budget provably cannot fit their deadline at the measured tick rate are
pre-dropped from the queue (arm ``--deadline-ms`` so deadlines exist;
the `pre_dropped` breach count shows the feasibility cut working).

Reported per mode: p50/p95/p99 queue-wait and serve-time from
`stats()["slo"]`, plus breach counts when `--deadline-ms` arms
per-request deadlines.  The headline number — static p95 queue-wait over
adaptive p95 queue-wait (>1 means adaptive wins) — is dimensionless so
the committed baseline survives runner-hardware drift; ``--json`` writes
it for the CI gate (benchmarks/check_bench.py).  Best of ``--repeats``
runs per mode (max ratio paired from per-mode minima) since CI hosts are
noisy.

``--scenario poisoned`` runs a different drill — the swap-safety smoke:

    PYTHONPATH=src python -m benchmarks.slo_serve --scenario poisoned \
        --json BENCH_swap_safety.json

A canary-armed O2 service serves a steady stream (building the tenant's
score baseline), then its offline learner is *poisoned* (params negated,
fine-tuning frozen) while the verdict seam is patched to report the
poisoned model winning every assessment — the exact failure mode the
staged swap pipeline exists to contain.  Drifted waves then fire the
divergence monitor; every forced win must die in the canary stage
(`canary_tolerance` is pinned so promotion is impossible: the drill
measures the containment machinery, not the scorer's judgment).  The
artifact reports `stats()["swaps"]`, a deterministic pre/post probe
ratio (1.0 — the incumbent was never touched), and the step-program
bind delta across the whole cycle (0 — canary lanes ride resident
executables).  check_bench.py gates all three as `swap_safety`.

``--scenario chaos`` runs the full fault battery through the health
layer (`launch/serving/health.py`):

    PYTHONPATH=src python -m benchmarks.slo_serve --scenario chaos \
        --json BENCH_chaos.json

One O2+canary service lives through six phases driven by deterministic
`FaultSite` injection: (1) healthy timed traffic (the RPS yardstick);
(2) NaN fine-tune rounds — every poisoned round must be rejected at the
publish gate until the tenant's circuit breaker quarantines it, then
clean traffic must release the breaker; (3) failed assessment
dispatches — retries exhaust, the annex demotes into degraded mode, a
timed phase shows serving continues on frozen params, then a half-open
probe recovers it; (4) a hung dispatch — the drain watchdog abandons
it and `flush_o2` returns a bounded partial-flush report; (5) forced
canary losses — rollbacks fire and strike the breaker; (6) after every
wave of every phase, a finiteness probe over all pool params and the
published snapshot (`nonfinite_served` must end at 0).  The headline
number is the degraded-over-healthy RPS ratio (~1: a demoted annex
costs serving nothing); check_bench.py gates it as `chaos` after
enforcing the hard invariants (each fault was seen, contained, and
recovered from — violations fail outright, regardless of tolerance).
"""
from __future__ import annotations

import argparse
import json
import os
import time

# expose every core as an XLA host device so pools shard; must happen
# before jax initializes (no-op if the operator already set it)
if "xla_force_host_platform_device_count" not in os.environ.get(
        "XLA_FLAGS", ""):
    os.environ["XLA_FLAGS"] = (
        os.environ.get("XLA_FLAGS", "")
        + f" --xla_force_host_platform_device_count={os.cpu_count()}")

import jax
import numpy as np

from repro.core.litune import LITune, LITuneConfig
from repro.index.workloads import sample_keys, wr_workload
from repro.launch.serving import (AdaptiveSlotPolicy, EDFSlotPolicy,
                                  ServeConfig, TuningService)


def make_arrivals(n_bursts: int, burst_mean: int, gap_s: float,
                  n_keys: int, seed: int):
    """One fixed trace of (arrival_time_s, data, workload, wr): bursts of
    Poisson(burst_mean) simultaneous requests, `gap_s` apart.  The trace
    is generated once and replayed against every mode, so the comparison
    is paired."""
    rng = np.random.default_rng(seed)
    key = jax.random.PRNGKey(seed)
    arrivals, t, i = [], 0.0, 0
    for b in range(n_bursts):
        size = max(1, int(rng.poisson(burst_mean)))
        for _ in range(size):
            k = jax.random.fold_in(key, i)
            data = sample_keys(k, n_keys, "mix")
            wl, _ = wr_workload(jax.random.fold_in(k, 1), data, 1.0,
                                total=n_keys, dist="mix")
            arrivals.append((t, data, wl, 1.0))
            i += 1
        t += gap_s
    return arrivals


def drive(service: TuningService, arrivals, budget: int,
          deadline_s: float | None) -> float:
    """Replay the arrival trace in real time: submit each request at its
    arrival instant, tick the service whenever there is work, sleep
    through idle gaps.  Returns the wall-clock span."""
    t0 = time.perf_counter()
    i = 0
    while True:
        now = time.perf_counter() - t0
        while i < len(arrivals) and arrivals[i][0] <= now:
            _, data, wl, wr = arrivals[i]
            service.submit(data, wl, wr, budget_steps=budget,
                           deadline_s=deadline_s)
            i += 1
        busy = service.queue or \
            any(p.n_active for p in service.pools.values())
        if busy:
            service.step()
        elif i < len(arrivals):
            time.sleep(max(0.0, min(arrivals[i][0] - now, 0.05)))
        else:
            break
    return time.perf_counter() - t0


def bench_mode(mk_tuner, arrivals, budget: int, slots: int,
               policy_fn, deadline_s, repeats: int):
    """Best-of-`repeats` run of one mode: keep the run with the lowest
    p95 queue-wait (CI hosts are noisy; the floor is the capability)."""
    best = None
    for _ in range(repeats):
        service = TuningService(mk_tuner(), config=ServeConfig(
            slots=slots, policy=policy_fn()))
        span = drive(service, arrivals, budget, deadline_s)
        st = service.stats()
        slo = st["slo"]
        row = {"span_s": span, "slo": slo, "stats": st}
        if best is None or slo["queue_wait_ms"]["p95"] < \
                best["slo"]["queue_wait_ms"]["p95"]:
            best = row
    return best


def run_poisoned(args):
    """The swap-safety drill: a poisoned offline model, a verdict seam
    forced to declare it the winner, and a canary stage that must contain
    it.  Every knob that decides an outcome is pinned so the run is
    deterministic given `--seed` — the committed baseline is exact."""
    import dataclasses

    from repro.core.o2 import O2Config
    from repro.index.workloads import StreamConfig, stream_windows
    from repro.launch.serving import O2ServiceConfig, SwapConfig
    from repro.launch.serving import o2_runtime as o2_mod

    budget = args.budget
    slots = max(args.slots, 4)           # >=4: a canary lane + controls
    # 2048-key windows on a 64-point quantile grid separate steady from
    # drifted KS cleanly (steady noise <= ~0.13, drift >= ~0.17); smaller
    # windows drown the drift signal in sampling noise
    n_keys = max(args.n_keys, 2048)
    cfg = LITuneConfig(
        index_type="alex", episode_len=budget,
        lstm_hidden=32, mlp_hidden=64,
        o2=O2Config(divergence_threshold=0.15, n_quantiles=64,
                    assess_every=1,
                    # the poison must persist: no fine-tune rounds may
                    # move the offline tree off the negated params
                    offline_updates_per_window=0))
    service = TuningService(LITune(cfg, seed=args.seed), config=ServeConfig(
        slots=slots, seed=args.seed,
        o2=O2ServiceConfig(enabled=True, o2=cfg.o2,
                           offline_updates_per_tick=0),
        swap=SwapConfig(canary=True, canary_fraction=0.25,
                        canary_min_episodes=1,
                        # strictly negative tolerance: promotion would
                        # need the canary mean <= 0 x control, impossible
                        # for positive scores — the drill pins the
                        # decision so only the containment machinery
                        # (not the scorer's judgment) is under test
                        canary_tolerance=-1.0,
                        canary_timeout_ticks=64)))
    key = jax.random.PRNGKey(args.seed + 1)
    steady = StreamConfig(n_windows=2 * slots, base_per_window=n_keys,
                          updates_per_window=n_keys, dist="mix",
                          drift_per_window=0.0, wr_start=1.0, wr_end=1.0)
    # constant wr on purpose: the workload split depends on it, and a
    # second (reads, inserts) shape would open a second pool mid-drill
    drifted = dataclasses.replace(steady, drift_per_window=1.0)

    def serve_wave(stream_cfg, fold):
        for _, data, wl, wr in stream_windows(jax.random.fold_in(key, fold),
                                              stream_cfg):
            service.submit(data, wl, wr, budget_steps=budget,
                           noise_scale=0.02)
        service.run()
        service.flush_o2()

    # the deterministic probe: a fixed steady window under a fixed key,
    # zero noise — bitwise repeatable whenever the incumbent params are
    # untouched (post-rollback it must reproduce the pre-poison result)
    probe_cfg = dataclasses.replace(steady, n_windows=1)
    _, pdata, pwl, pwr = next(iter(stream_windows(
        jax.random.fold_in(key, 99), probe_cfg)))
    probe_key = jax.random.PRNGKey(args.seed + 7)

    def probe():
        rid = service.submit(pdata, pwl, pwr, budget_steps=budget,
                             deterministic=True, key=probe_key)
        service.run()
        return float(service.results[rid]["best_runtime_ns"])

    # phase A: steady traffic, twice (program warmup: admission-wave
    # widths are staggering-dependent, one pass can miss one), then the
    # pre-poison probe and the bind-accounting snapshot
    print("# swap_safety: steady warmup ...")
    serve_wave(steady, fold=0)
    serve_wave(steady, fold=1)
    r_pre = probe()
    st0 = service.stats()
    binds0 = st0["program_misses"] + st0["programs_resident"]

    # phase B: poison the offline model (a catastrophically bad
    # fine-tune) and force every pooled assessment to declare it the
    # winner; drifted waves fire the divergence monitor until the canary
    # stage has rolled the candidate back
    print("# swap_safety: poisoning offline model, serving drift ...")
    tenant = service.tenants["alex"]
    tenant.offline["params"] = jax.tree.map(lambda x: -x,
                                            tenant.offline["params"])
    tenant.ready_params = jax.tree.map(lambda x: -x, tenant.ready_params)
    real_pooled_best = o2_mod._pooled_best
    o2_mod._pooled_best = lambda r0, runtimes: 0.0
    rounds = 0
    try:
        while service.stats()["swaps"]["rolled_back"] < 1 and rounds < 8:
            serve_wave(drifted, fold=10 + rounds)
            rounds += 1
    finally:
        o2_mod._pooled_best = real_pooled_best

    # phase C: the post-rollback probe — same window, same key; a lane
    # fraction carried the poison briefly, the incumbent never moved
    r_post = probe()
    st1 = service.stats()
    new_binds = st1["program_misses"] + st1["programs_resident"] - binds0
    sw = st1["swaps"]
    ratio = r_pre / max(r_post, 1e-9)

    print(f"# swap_safety  slots={slots} budget={budget} n_keys={n_keys} "
          f"rounds={rounds} seed={args.seed}")
    print("benchmark,candidates,canaried,rolled_back,promoted,deferred,"
          "probe_ratio,new_binds")
    print(f"swap_safety,{sw['candidates']},{sw['canaried']},"
          f"{sw['rolled_back']},{sw['promoted']},{sw['deferred']},"
          f"{ratio:.6f},{new_binds}")
    if args.json:
        with open(args.json, "w") as f:
            json.dump({"benchmark": "swap_safety",
                       "config": {"slots": slots, "budget": budget,
                                  "n_keys": n_keys, "seed": args.seed,
                                  "rounds": rounds,
                                  "devices": len(jax.devices())},
                       "swaps": sw,
                       "o2": {"windows": st1["o2"]["alex"]["windows"],
                              "diverged": st1["o2"]["alex"]["diverged"],
                              "assessments": st1["o2"]["assessments"]},
                       "r_pre_ns": r_pre, "r_post_ns": r_post,
                       "post_rollback_ns_ratio": ratio,
                       "new_binds": new_binds}, f, indent=2)
        print(f"# wrote {args.json}")


def run_chaos(args):
    """The fault battery: every failure mode health.py contains, in one
    continuous service lifetime, with hard invariants on the artifact.
    Faults are injected per-site (`guard.sites[...] = FaultSite(...)`)
    so each phase arms exactly the fault it is about and nothing else."""
    import time as _time

    from repro.core.o2 import O2Config
    from repro.launch.serving import (HealthConfig, O2ServiceConfig,
                                      SwapConfig)
    from repro.launch.serving import o2_runtime as o2_mod
    from repro.runtime.fault import FaultSite

    budget = args.budget
    slots = max(args.slots, 4)           # >=4: a canary lane + controls
    n_keys = args.n_keys
    # KS effectively off: divergence fires purely on W/R shift, which is
    # exact — every assessment trigger in the drill is deterministic.
    # The tiny DDPG shape matters: fine-tune rounds must actually
    # complete on budget-4 episodes or the learner-side fault sites
    # (NaN rounds, publish gates) never execute
    from repro.core.ddpg import DDPGConfig
    cfg = LITuneConfig(
        index_type="alex", episode_len=budget,
        lstm_hidden=16, mlp_hidden=32,
        ddpg=DDPGConfig(seq_len=3, burn_in=1, batch_size=8),
        o2=O2Config(divergence_threshold=10.0, wr_shift_threshold=0.5,
                    assess_every=1, offline_updates_per_window=2))
    health = HealthConfig(
        dispatch_timeout_s=2.0,          # hang phase: watchdog horizon
        dispatch_retries=1, retry_backoff_s=0.01, backoff_seed=args.seed,
        annex_failure_threshold=1,
        annex_cooloff_s=30.0,            # spans the degraded timed phase
        quarantine_threshold=2, quarantine_windows=2,
        flush_deadline_s=30.0)
    service = TuningService(LITune(cfg, seed=args.seed), config=ServeConfig(
        slots=slots, seed=args.seed,
        o2=O2ServiceConfig(enabled=True, o2=cfg.o2),
        swap=SwapConfig(canary=True, canary_fraction=0.25,
                        canary_min_episodes=1, canary_timeout_ticks=64),
        health=health))
    guard = service.o2rt.health
    key = jax.random.PRNGKey(args.seed + 1)
    fold = 0
    nonfinite_served = 0
    # alternating W/R so every wave past the first carries divergence
    # triggers (the reference anchors at wr=1)
    wave_wrs = [1.0, 3.0, 1.0, 3.0]
    timed_waves = 4

    def _finite(tree):
        return all(bool(np.all(np.isfinite(np.asarray(leaf))))
                   for leaf in jax.tree.leaves(jax.device_get(tree)))

    def serve_wave(flush=True):
        nonlocal fold, nonfinite_served
        for i, wr in enumerate(wave_wrs):
            k = jax.random.fold_in(key, 131 * fold + i)
            data = sample_keys(k, n_keys, "mix")
            wl, _ = wr_workload(jax.random.fold_in(k, 1), data, wr,
                                total=n_keys, dist="mix")
            service.submit(data, wl, wr, budget_steps=budget)
        fold += 1
        service.run()
        if flush:
            service.flush_o2()
        # the drill's core invariant, probed after EVERY wave: nothing
        # non-finite ever reaches a pool or the published snapshot
        for pool in service.pools.values():
            if not _finite(pool.params):
                nonfinite_served += 1
        if not _finite(service.tenants["alex"].ready_params):
            nonfinite_served += 1

    def hp():
        return service.stats()["health"]

    def timed_phase():
        t0 = _time.perf_counter()
        for _ in range(timed_waves):
            serve_wave()
        return timed_waves * len(wave_wrs) / (_time.perf_counter() - t0)

    # phase 1: warmup (program binds) + the healthy RPS yardstick
    print("# chaos: healthy traffic ...")
    serve_wave()
    serve_wave()
    rps_healthy = timed_phase()

    # phase 2: NaN fine-tune rounds until the publish gate has rejected
    # enough to quarantine the tenant; then clean traffic releases it
    print("# chaos: NaN fine-tune rounds -> quarantine ...")
    guard.sites["nan_round"] = FaultSite(fire_at=tuple(range(64)))
    rounds = 0
    while hp()["quarantines"] < 1 and rounds < 8:
        serve_wave()
        rounds += 1
    guard.sites["nan_round"] = FaultSite()      # disarm
    print("# chaos: clean traffic -> quarantine release ...")
    while hp()["quarantine_releases"] < 1 and rounds < 16:
        serve_wave()
        rounds += 1

    # phase 3: failed assessment dispatches exhaust their retries and
    # demote the annex; serving continues (timed) on frozen params;
    # after the cooloff a half-open probe recovers it.  The cooloff is
    # rewound rather than slept through — the drill injects time the
    # same way it injects faults
    print("# chaos: failed dispatches -> annex demotion ...")
    guard.sites["assess_fail"] = FaultSite(fire_at=(0, 1))
    while hp()["annex_demotions"] < 1 and rounds < 24:
        serve_wave()
        rounds += 1
    print("# chaos: degraded serving (timed) ...")
    rps_degraded = timed_phase()
    state_during_degraded = hp()["state"]
    guard._degraded_at -= health.annex_cooloff_s     # cooloff elapses
    print("# chaos: half-open probe -> recovery ...")
    while hp()["annex_recoveries"] < 1 and rounds < 32:
        serve_wave()
        rounds += 1

    # phase 4: one hung dispatch; the drain watchdog abandons it and
    # flush_o2 comes back bounded with a truthful report
    print("# chaos: hung dispatch -> bounded flush ...")
    guard.sites["assess_hang"] = FaultSite(fire_at=(0,))
    serve_wave(flush=False)
    t0 = _time.perf_counter()
    flush_report = service.flush_o2()
    flush_s = _time.perf_counter() - t0
    guard.sites["assess_hang"] = FaultSite()
    # the abandon was (correctly) an annex failure: the annex is demoted
    # again.  Elapse this cooloff too, so phase 5's assessments dispatch
    if guard._degraded_at is not None:
        guard._degraded_at -= health.annex_cooloff_s

    # phase 5: forced canary losses — the rollback arm of the breaker
    print("# chaos: forced canary losses -> rollbacks ...")
    guard.sites["canary_loss"] = FaultSite(fire_at=(0, 1))
    real_pooled_best = o2_mod._pooled_best
    o2_mod._pooled_best = lambda r0, runtimes: -1.0
    try:
        while service.stats()["swaps"]["rolled_back_canary"] < 1 \
                and rounds < 40:
            serve_wave()
            rounds += 1
    finally:
        o2_mod._pooled_best = real_pooled_best

    st = service.stats()
    h = st["health"]
    sw = st["swaps"]
    ratio = rps_degraded / max(rps_healthy, 1e-9)
    print(f"# chaos  slots={slots} budget={budget} n_keys={n_keys} "
          f"waves={fold} seed={args.seed} "
          f"state_during_degraded={state_during_degraded}")
    print("benchmark,nonfinite_served,rejected_params,quarantines,"
          "releases,demotions,recoveries,dropped,rolled_back_canary,"
          "degraded_over_healthy_rps,flush_s")
    print(f"chaos,{nonfinite_served},{h['rejected_params']},"
          f"{h['quarantines']},{h['quarantine_releases']},"
          f"{h['annex_demotions']},{h['annex_recoveries']},"
          f"{h['dropped_dispatches']},{sw['rolled_back_canary']},"
          f"{ratio:.3f},{flush_s:.3f}")
    if args.json:
        with open(args.json, "w") as f:
            json.dump({"benchmark": "chaos",
                       "config": {"slots": slots, "budget": budget,
                                  "n_keys": n_keys, "seed": args.seed,
                                  "waves": fold,
                                  "timed_waves": timed_waves,
                                  "devices": len(jax.devices()),
                                  "flush_deadline_s":
                                      health.flush_deadline_s},
                       "health": h,
                       "swaps": sw,
                       "nonfinite_served": nonfinite_served,
                       "state_during_degraded": state_during_degraded,
                       "rps_healthy": rps_healthy,
                       "rps_degraded": rps_degraded,
                       "degraded_over_healthy_rps": ratio,
                       "flush_s": flush_s,
                       "flush_report": flush_report}, f, indent=2)
        print(f"# wrote {args.json}")


def main():
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--bursts", type=int, default=4)
    ap.add_argument("--burst-mean", type=int, default=8,
                    help="Poisson mean burst size")
    ap.add_argument("--gap-s", type=float, default=0.5,
                    help="idle gap between bursts (seconds)")
    ap.add_argument("--budget", type=int, default=4)
    ap.add_argument("--n-keys", type=int, default=256)
    ap.add_argument("--slots", type=int, default=2,
                    help="static pool width (and the adaptive floor)")
    ap.add_argument("--max-slots", type=int, default=8,
                    help="adaptive pool ceiling (keep near the burst "
                         "size: wider pools pay idle-lane compute)")
    ap.add_argument("--deadline-ms", type=float, default=None,
                    help="arm per-request deadlines (breaches reported)")
    ap.add_argument("--policy", default=None, choices=["edf"],
                    help="append an EDF admission row (earliest deadline "
                         "first + feasibility pre-drops)")
    ap.add_argument("--repeats", type=int, default=2,
                    help="runs per mode; best p95 queue-wait is reported")
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--json", default=None, metavar="PATH",
                    help="also write results as a JSON artifact (CI gate)")
    ap.add_argument("--scenario", default="bursts",
                    choices=["bursts", "poisoned", "chaos"],
                    help="'bursts' races static vs adaptive scheduling; "
                         "'poisoned' runs the swap-safety drill (a forced"
                         "-win poisoned model must die in the canary "
                         "stage); 'chaos' runs the health-layer fault "
                         "battery (see module docstring)")
    args = ap.parse_args()

    if args.scenario == "poisoned":
        return run_poisoned(args)
    if args.scenario == "chaos":
        return run_chaos(args)

    cfg = LITuneConfig(index_type="alex", episode_len=args.budget,
                       lstm_hidden=32, mlp_hidden=64)
    mk = lambda: LITune(cfg, seed=args.seed)  # noqa: E731
    arrivals = make_arrivals(args.bursts, args.burst_mean, args.gap_s,
                             args.n_keys, args.seed + 1)
    deadline_s = (args.deadline_ms / 1e3
                  if args.deadline_ms is not None else None)
    static_policy = lambda: None  # noqa: E731  (service default: static)
    adaptive_policy = lambda: AdaptiveSlotPolicy(  # noqa: E731
        min_slots=args.slots, max_slots=args.max_slots, shrink_patience=2)
    edf_policy = lambda: EDFSlotPolicy()  # noqa: E731

    def run_static():
        return bench_mode(mk, arrivals, args.budget, args.slots,
                          static_policy, deadline_s, args.repeats)

    def run_adaptive():
        return bench_mode(mk, arrivals, args.budget, args.slots,
                          adaptive_policy, deadline_s, args.repeats)

    def run_edf():
        return bench_mode(mk, arrivals, args.budget, args.slots,
                          edf_policy, deadline_s, args.repeats)

    # warm both modes with the full trace so every pool width's programs
    # are resident before the timed runs (a real service binds them at
    # startup; the cache is process-wide).  Two warm drives per mode:
    # admission wave widths depend on timing, so a single pass can miss
    # a width whose first-compile would then land mid-measurement
    bench_mode(mk, arrivals, args.budget, args.slots, static_policy,
               deadline_s, 2)
    bench_mode(mk, arrivals, args.budget, args.slots, adaptive_policy,
               deadline_s, 2)

    modes = [("static", run_static), ("adaptive", run_adaptive)]
    if args.policy == "edf":
        modes.append(("edf", run_edf))
    rows = []
    for mode, run in modes:
        best = run()
        slo = best["slo"]
        st = best["stats"]
        rows.append({
            "mode": mode,
            "queue_wait_ms": slo["queue_wait_ms"],
            "serve_ms": slo["serve_ms"],
            "breaches": slo["breaches"],
            "span_s": best["span_s"],
            "requests": slo["tracked"],
            "resize_events": st["scheduler"]["resize_events"],
            "peak_slots": max(p["peak_slots"]
                              for p in st["per_pool"].values()),
        })

    p95_static = rows[0]["queue_wait_ms"]["p95"]
    p95_adaptive = rows[1]["queue_wait_ms"]["p95"]
    ratio = p95_static / max(p95_adaptive, 1e-9)

    print(f"# slo_serve  bursts={args.bursts} burst_mean={args.burst_mean} "
          f"gap_s={args.gap_s} budget={args.budget} n_keys={args.n_keys} "
          f"slots={args.slots} max_slots={args.max_slots} "
          f"deadline_ms={args.deadline_ms} repeats={args.repeats} "
          f"devices={len(jax.devices())}")
    print("benchmark,mode,slots,p50_wait_ms,p95_wait_ms,p99_wait_ms,"
          "p95_serve_ms,resizes,peak_slots")
    for r in rows:
        print(f"slo_serve,{r['mode']},{args.slots},"
              f"{r['queue_wait_ms']['p50']:.1f},"
              f"{r['queue_wait_ms']['p95']:.1f},"
              f"{r['queue_wait_ms']['p99']:.1f},"
              f"{r['serve_ms']['p95']:.1f},"
              f"{r['resize_events']},{r['peak_slots']}")
    print(f"slo_serve,p95_wait_static_over_adaptive,{args.slots},"
          f"{ratio:.2f},,,,,")
    if args.deadline_ms is not None:
        for r in rows:
            print(f"slo_serve,{r['mode']}_breaches,{args.slots},"
                  f"{r['breaches']},,,,,")

    if args.json:
        with open(args.json, "w") as f:
            json.dump({"benchmark": "slo_serve",
                       "config": {"bursts": args.bursts,
                                  "burst_mean": args.burst_mean,
                                  "gap_s": args.gap_s,
                                  "budget": args.budget,
                                  "n_keys": args.n_keys,
                                  "slots": args.slots,
                                  "max_slots": args.max_slots,
                                  "deadline_ms": args.deadline_ms,
                                  "repeats": args.repeats,
                                  "devices": len(jax.devices())},
                       "rows": rows,
                       "p95_wait_static_over_adaptive": ratio}, f,
                      indent=2)
        print(f"# wrote {args.json}")


if __name__ == "__main__":
    main()
