"""Tail latency under bursty arrivals: static vs adaptive slot
scheduling.

    PYTHONPATH=src python -m benchmarks.slo_serve
    PYTHONPATH=src python -m benchmarks.slo_serve --bursts 4 \
        --burst-mean 10 --budget 8 --n-keys 512 --json BENCH_slo_serve.json

The other serving benches measure throughput on a pre-loaded queue; this
one measures what a *tenant* feels — queue-wait and serve-time
percentiles — under the arrival pattern that breaks static pools:
Poisson-sized bursts separated by idle gaps.  The same arrival trace is
replayed against two services:

  static   — fixed pool width (`--slots`), PR 1–3 behavior: a burst
             deeper than the pool waits out earlier waves slot by slot;
  adaptive — `AdaptiveSlotPolicy`: the scheduler grows the pool to the
             burst (ladder widths up to `--max-slots`, one cached gather
             per resize, zero program re-traces) and shrinks it back in
             the gaps.

``--policy edf`` appends a third row: `EDFSlotPolicy` admission —
tight-deadline requests enter free slots first and requests whose
budget provably cannot fit their deadline at the measured tick rate are
pre-dropped from the queue (arm ``--deadline-ms`` so deadlines exist;
the `pre_dropped` breach count shows the feasibility cut working).

Reported per mode: p50/p95/p99 queue-wait and serve-time from
`stats()["slo"]`, plus breach counts when `--deadline-ms` arms
per-request deadlines.  The headline number — static p95 queue-wait over
adaptive p95 queue-wait (>1 means adaptive wins) — is dimensionless so
the committed baseline survives runner-hardware drift; ``--json`` writes
it for the CI gate (benchmarks/check_bench.py).  Best of ``--repeats``
runs per mode (max ratio paired from per-mode minima) since CI hosts are
noisy.

``--scenario poisoned`` runs a different drill — the swap-safety smoke:

    PYTHONPATH=src python -m benchmarks.slo_serve --scenario poisoned \
        --json BENCH_swap_safety.json

A canary-armed O2 service serves a steady stream (building the tenant's
score baseline), then its offline learner is *poisoned* (params negated,
fine-tuning frozen) while the verdict seam is patched to report the
poisoned model winning every assessment — the exact failure mode the
staged swap pipeline exists to contain.  Drifted waves then fire the
divergence monitor; every forced win must die in the canary stage
(`canary_tolerance` is pinned so promotion is impossible: the drill
measures the containment machinery, not the scorer's judgment).  The
artifact reports `stats()["swaps"]`, a deterministic pre/post probe
ratio (1.0 — the incumbent was never touched), and the step-program
bind delta across the whole cycle (0 — canary lanes ride resident
executables).  check_bench.py gates all three as `swap_safety`.
"""
from __future__ import annotations

import argparse
import json
import os
import time

# expose every core as an XLA host device so pools shard; must happen
# before jax initializes (no-op if the operator already set it)
if "xla_force_host_platform_device_count" not in os.environ.get(
        "XLA_FLAGS", ""):
    os.environ["XLA_FLAGS"] = (
        os.environ.get("XLA_FLAGS", "")
        + f" --xla_force_host_platform_device_count={os.cpu_count()}")

import jax
import numpy as np

from repro.core.litune import LITune, LITuneConfig
from repro.index.workloads import sample_keys, wr_workload
from repro.launch.serving import (AdaptiveSlotPolicy, EDFSlotPolicy,
                                  ServeConfig, TuningService)


def make_arrivals(n_bursts: int, burst_mean: int, gap_s: float,
                  n_keys: int, seed: int):
    """One fixed trace of (arrival_time_s, data, workload, wr): bursts of
    Poisson(burst_mean) simultaneous requests, `gap_s` apart.  The trace
    is generated once and replayed against every mode, so the comparison
    is paired."""
    rng = np.random.default_rng(seed)
    key = jax.random.PRNGKey(seed)
    arrivals, t, i = [], 0.0, 0
    for b in range(n_bursts):
        size = max(1, int(rng.poisson(burst_mean)))
        for _ in range(size):
            k = jax.random.fold_in(key, i)
            data = sample_keys(k, n_keys, "mix")
            wl, _ = wr_workload(jax.random.fold_in(k, 1), data, 1.0,
                                total=n_keys, dist="mix")
            arrivals.append((t, data, wl, 1.0))
            i += 1
        t += gap_s
    return arrivals


def drive(service: TuningService, arrivals, budget: int,
          deadline_s: float | None) -> float:
    """Replay the arrival trace in real time: submit each request at its
    arrival instant, tick the service whenever there is work, sleep
    through idle gaps.  Returns the wall-clock span."""
    t0 = time.perf_counter()
    i = 0
    while True:
        now = time.perf_counter() - t0
        while i < len(arrivals) and arrivals[i][0] <= now:
            _, data, wl, wr = arrivals[i]
            service.submit(data, wl, wr, budget_steps=budget,
                           deadline_s=deadline_s)
            i += 1
        busy = service.queue or \
            any(p.n_active for p in service.pools.values())
        if busy:
            service.step()
        elif i < len(arrivals):
            time.sleep(max(0.0, min(arrivals[i][0] - now, 0.05)))
        else:
            break
    return time.perf_counter() - t0


def bench_mode(mk_tuner, arrivals, budget: int, slots: int,
               policy_fn, deadline_s, repeats: int):
    """Best-of-`repeats` run of one mode: keep the run with the lowest
    p95 queue-wait (CI hosts are noisy; the floor is the capability)."""
    best = None
    for _ in range(repeats):
        service = TuningService(mk_tuner(), config=ServeConfig(
            slots=slots, policy=policy_fn()))
        span = drive(service, arrivals, budget, deadline_s)
        st = service.stats()
        slo = st["slo"]
        row = {"span_s": span, "slo": slo, "stats": st}
        if best is None or slo["queue_wait_ms"]["p95"] < \
                best["slo"]["queue_wait_ms"]["p95"]:
            best = row
    return best


def run_poisoned(args):
    """The swap-safety drill: a poisoned offline model, a verdict seam
    forced to declare it the winner, and a canary stage that must contain
    it.  Every knob that decides an outcome is pinned so the run is
    deterministic given `--seed` — the committed baseline is exact."""
    import dataclasses

    from repro.core.o2 import O2Config
    from repro.index.workloads import StreamConfig, stream_windows
    from repro.launch.serving import O2ServiceConfig, SwapConfig
    from repro.launch.serving import o2_runtime as o2_mod

    budget = args.budget
    slots = max(args.slots, 4)           # >=4: a canary lane + controls
    # 2048-key windows on a 64-point quantile grid separate steady from
    # drifted KS cleanly (steady noise <= ~0.13, drift >= ~0.17); smaller
    # windows drown the drift signal in sampling noise
    n_keys = max(args.n_keys, 2048)
    cfg = LITuneConfig(
        index_type="alex", episode_len=budget,
        lstm_hidden=32, mlp_hidden=64,
        o2=O2Config(divergence_threshold=0.15, n_quantiles=64,
                    assess_every=1,
                    # the poison must persist: no fine-tune rounds may
                    # move the offline tree off the negated params
                    offline_updates_per_window=0))
    service = TuningService(LITune(cfg, seed=args.seed), config=ServeConfig(
        slots=slots, seed=args.seed,
        o2=O2ServiceConfig(enabled=True, o2=cfg.o2,
                           offline_updates_per_tick=0),
        swap=SwapConfig(canary=True, canary_fraction=0.25,
                        canary_min_episodes=1,
                        # strictly negative tolerance: promotion would
                        # need the canary mean <= 0 x control, impossible
                        # for positive scores — the drill pins the
                        # decision so only the containment machinery
                        # (not the scorer's judgment) is under test
                        canary_tolerance=-1.0,
                        canary_timeout_ticks=64)))
    key = jax.random.PRNGKey(args.seed + 1)
    steady = StreamConfig(n_windows=2 * slots, base_per_window=n_keys,
                          updates_per_window=n_keys, dist="mix",
                          drift_per_window=0.0, wr_start=1.0, wr_end=1.0)
    # constant wr on purpose: the workload split depends on it, and a
    # second (reads, inserts) shape would open a second pool mid-drill
    drifted = dataclasses.replace(steady, drift_per_window=1.0)

    def serve_wave(stream_cfg, fold):
        for _, data, wl, wr in stream_windows(jax.random.fold_in(key, fold),
                                              stream_cfg):
            service.submit(data, wl, wr, budget_steps=budget,
                           noise_scale=0.02)
        service.run()
        service.flush_o2()

    # the deterministic probe: a fixed steady window under a fixed key,
    # zero noise — bitwise repeatable whenever the incumbent params are
    # untouched (post-rollback it must reproduce the pre-poison result)
    probe_cfg = dataclasses.replace(steady, n_windows=1)
    _, pdata, pwl, pwr = next(iter(stream_windows(
        jax.random.fold_in(key, 99), probe_cfg)))
    probe_key = jax.random.PRNGKey(args.seed + 7)

    def probe():
        rid = service.submit(pdata, pwl, pwr, budget_steps=budget,
                             deterministic=True, key=probe_key)
        service.run()
        return float(service.results[rid]["best_runtime_ns"])

    # phase A: steady traffic, twice (program warmup: admission-wave
    # widths are staggering-dependent, one pass can miss one), then the
    # pre-poison probe and the bind-accounting snapshot
    print("# swap_safety: steady warmup ...")
    serve_wave(steady, fold=0)
    serve_wave(steady, fold=1)
    r_pre = probe()
    st0 = service.stats()
    binds0 = st0["program_misses"] + st0["programs_resident"]

    # phase B: poison the offline model (a catastrophically bad
    # fine-tune) and force every pooled assessment to declare it the
    # winner; drifted waves fire the divergence monitor until the canary
    # stage has rolled the candidate back
    print("# swap_safety: poisoning offline model, serving drift ...")
    tenant = service.tenants["alex"]
    tenant.offline["params"] = jax.tree.map(lambda x: -x,
                                            tenant.offline["params"])
    tenant.ready_params = jax.tree.map(lambda x: -x, tenant.ready_params)
    real_pooled_best = o2_mod._pooled_best
    o2_mod._pooled_best = lambda r0, runtimes: 0.0
    rounds = 0
    try:
        while service.stats()["swaps"]["rolled_back"] < 1 and rounds < 8:
            serve_wave(drifted, fold=10 + rounds)
            rounds += 1
    finally:
        o2_mod._pooled_best = real_pooled_best

    # phase C: the post-rollback probe — same window, same key; a lane
    # fraction carried the poison briefly, the incumbent never moved
    r_post = probe()
    st1 = service.stats()
    new_binds = st1["program_misses"] + st1["programs_resident"] - binds0
    sw = st1["swaps"]
    ratio = r_pre / max(r_post, 1e-9)

    print(f"# swap_safety  slots={slots} budget={budget} n_keys={n_keys} "
          f"rounds={rounds} seed={args.seed}")
    print("benchmark,candidates,canaried,rolled_back,promoted,deferred,"
          "probe_ratio,new_binds")
    print(f"swap_safety,{sw['candidates']},{sw['canaried']},"
          f"{sw['rolled_back']},{sw['promoted']},{sw['deferred']},"
          f"{ratio:.6f},{new_binds}")
    if args.json:
        with open(args.json, "w") as f:
            json.dump({"benchmark": "swap_safety",
                       "config": {"slots": slots, "budget": budget,
                                  "n_keys": n_keys, "seed": args.seed,
                                  "rounds": rounds,
                                  "devices": len(jax.devices())},
                       "swaps": sw,
                       "o2": {"windows": st1["o2"]["alex"]["windows"],
                              "diverged": st1["o2"]["alex"]["diverged"],
                              "assessments": st1["o2"]["assessments"]},
                       "r_pre_ns": r_pre, "r_post_ns": r_post,
                       "post_rollback_ns_ratio": ratio,
                       "new_binds": new_binds}, f, indent=2)
        print(f"# wrote {args.json}")


def main():
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--bursts", type=int, default=4)
    ap.add_argument("--burst-mean", type=int, default=8,
                    help="Poisson mean burst size")
    ap.add_argument("--gap-s", type=float, default=0.5,
                    help="idle gap between bursts (seconds)")
    ap.add_argument("--budget", type=int, default=4)
    ap.add_argument("--n-keys", type=int, default=256)
    ap.add_argument("--slots", type=int, default=2,
                    help="static pool width (and the adaptive floor)")
    ap.add_argument("--max-slots", type=int, default=8,
                    help="adaptive pool ceiling (keep near the burst "
                         "size: wider pools pay idle-lane compute)")
    ap.add_argument("--deadline-ms", type=float, default=None,
                    help="arm per-request deadlines (breaches reported)")
    ap.add_argument("--policy", default=None, choices=["edf"],
                    help="append an EDF admission row (earliest deadline "
                         "first + feasibility pre-drops)")
    ap.add_argument("--repeats", type=int, default=2,
                    help="runs per mode; best p95 queue-wait is reported")
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--json", default=None, metavar="PATH",
                    help="also write results as a JSON artifact (CI gate)")
    ap.add_argument("--scenario", default="bursts",
                    choices=["bursts", "poisoned"],
                    help="'bursts' races static vs adaptive scheduling; "
                         "'poisoned' runs the swap-safety drill (a forced"
                         "-win poisoned model must die in the canary "
                         "stage; see module docstring)")
    args = ap.parse_args()

    if args.scenario == "poisoned":
        return run_poisoned(args)

    cfg = LITuneConfig(index_type="alex", episode_len=args.budget,
                       lstm_hidden=32, mlp_hidden=64)
    mk = lambda: LITune(cfg, seed=args.seed)  # noqa: E731
    arrivals = make_arrivals(args.bursts, args.burst_mean, args.gap_s,
                             args.n_keys, args.seed + 1)
    deadline_s = (args.deadline_ms / 1e3
                  if args.deadline_ms is not None else None)
    static_policy = lambda: None  # noqa: E731  (service default: static)
    adaptive_policy = lambda: AdaptiveSlotPolicy(  # noqa: E731
        min_slots=args.slots, max_slots=args.max_slots, shrink_patience=2)
    edf_policy = lambda: EDFSlotPolicy()  # noqa: E731

    def run_static():
        return bench_mode(mk, arrivals, args.budget, args.slots,
                          static_policy, deadline_s, args.repeats)

    def run_adaptive():
        return bench_mode(mk, arrivals, args.budget, args.slots,
                          adaptive_policy, deadline_s, args.repeats)

    def run_edf():
        return bench_mode(mk, arrivals, args.budget, args.slots,
                          edf_policy, deadline_s, args.repeats)

    # warm both modes with the full trace so every pool width's programs
    # are resident before the timed runs (a real service binds them at
    # startup; the cache is process-wide).  Two warm drives per mode:
    # admission wave widths depend on timing, so a single pass can miss
    # a width whose first-compile would then land mid-measurement
    bench_mode(mk, arrivals, args.budget, args.slots, static_policy,
               deadline_s, 2)
    bench_mode(mk, arrivals, args.budget, args.slots, adaptive_policy,
               deadline_s, 2)

    modes = [("static", run_static), ("adaptive", run_adaptive)]
    if args.policy == "edf":
        modes.append(("edf", run_edf))
    rows = []
    for mode, run in modes:
        best = run()
        slo = best["slo"]
        st = best["stats"]
        rows.append({
            "mode": mode,
            "queue_wait_ms": slo["queue_wait_ms"],
            "serve_ms": slo["serve_ms"],
            "breaches": slo["breaches"],
            "span_s": best["span_s"],
            "requests": slo["tracked"],
            "resize_events": st["scheduler"]["resize_events"],
            "peak_slots": max(p["peak_slots"]
                              for p in st["per_pool"].values()),
        })

    p95_static = rows[0]["queue_wait_ms"]["p95"]
    p95_adaptive = rows[1]["queue_wait_ms"]["p95"]
    ratio = p95_static / max(p95_adaptive, 1e-9)

    print(f"# slo_serve  bursts={args.bursts} burst_mean={args.burst_mean} "
          f"gap_s={args.gap_s} budget={args.budget} n_keys={args.n_keys} "
          f"slots={args.slots} max_slots={args.max_slots} "
          f"deadline_ms={args.deadline_ms} repeats={args.repeats} "
          f"devices={len(jax.devices())}")
    print("benchmark,mode,slots,p50_wait_ms,p95_wait_ms,p99_wait_ms,"
          "p95_serve_ms,resizes,peak_slots")
    for r in rows:
        print(f"slo_serve,{r['mode']},{args.slots},"
              f"{r['queue_wait_ms']['p50']:.1f},"
              f"{r['queue_wait_ms']['p95']:.1f},"
              f"{r['queue_wait_ms']['p99']:.1f},"
              f"{r['serve_ms']['p95']:.1f},"
              f"{r['resize_events']},{r['peak_slots']}")
    print(f"slo_serve,p95_wait_static_over_adaptive,{args.slots},"
          f"{ratio:.2f},,,,,")
    if args.deadline_ms is not None:
        for r in rows:
            print(f"slo_serve,{r['mode']}_breaches,{args.slots},"
                  f"{r['breaches']},,,,,")

    if args.json:
        with open(args.json, "w") as f:
            json.dump({"benchmark": "slo_serve",
                       "config": {"bursts": args.bursts,
                                  "burst_mean": args.burst_mean,
                                  "gap_s": args.gap_s,
                                  "budget": args.budget,
                                  "n_keys": args.n_keys,
                                  "slots": args.slots,
                                  "max_slots": args.max_slots,
                                  "deadline_ms": args.deadline_ms,
                                  "repeats": args.repeats,
                                  "devices": len(jax.devices())},
                       "rows": rows,
                       "p95_wait_static_over_adaptive": ratio}, f,
                      indent=2)
        print(f"# wrote {args.json}")


if __name__ == "__main__":
    main()
