"""Requests/sec of the batched tuning service vs serial `LITune.tune`.

    PYTHONPATH=src python -m benchmarks.tune_serve
    PYTHONPATH=src python -m benchmarks.tune_serve --requests 16 \
        --budget 8 --n-keys 2048 --slots 1,4,16

Serves the same wave of R tuning requests two ways and reports req/s:

  serial   — `LITune.tune` answers one request at a time (the paper's
             single-tenant shape: one jitted episode-step dispatch per
             step per request, host sync after every step);
  batched  — `launch.serving.TuningService` with B slots: one jitted
             B-slot step per service tick, one host transfer per tick.

Both paths run the identical traced per-episode program (the parity the
test suite asserts bitwise), so the ratio is pure serving-architecture
win: one K-step program per tick instead of per-step dispatch+sync, and
slots sharded across host devices (cores) — parallelism a single-tenant
tuner cannot use.  Prints CSV ``tune_serve,<mode>,<slots>,<req/s>,<speedup>``.
"""
from __future__ import annotations

import argparse
import json
import os
import time

# expose every core as an XLA host device so the service can shard slots;
# must happen before jax initializes (no-op if the operator already set it)
if "xla_force_host_platform_device_count" not in os.environ.get(
        "XLA_FLAGS", ""):
    os.environ["XLA_FLAGS"] = (
        os.environ.get("XLA_FLAGS", "")
        + f" --xla_force_host_platform_device_count={os.cpu_count()}")

import jax

from repro.core.litune import LITune, LITuneConfig
from repro.index.workloads import sample_keys, wr_workload
from repro.launch.serving import ServeConfig, TuningService


def make_requests(n: int, n_keys: int, seed: int = 1, mixed_wr: bool = False):
    """`mixed_wr` cycles write/read ratios -> 3 workload shapes -> the
    service fragments into 3 pools (the heterogeneous-stream demo); the
    default single ratio keeps one pool fully utilized (the throughput
    measurement)."""
    key = jax.random.PRNGKey(seed)
    out = []
    for i in range(n):
        k = jax.random.fold_in(key, i)
        wr = [0.33, 1.0, 3.0][i % 3] if mixed_wr else 1.0
        data = sample_keys(k, n_keys, "mix")
        wl, _ = wr_workload(jax.random.fold_in(k, 1), data, wr,
                            total=n_keys, dist="mix")
        out.append((data, wl, wr))
    return out


def bench_serial(tuner: LITune, requests, budget: int) -> float:
    t0 = time.perf_counter()
    for data, wl, wr in requests:
        tuner.tune(data, wl, wr, budget_steps=budget)
    return len(requests) / (time.perf_counter() - t0)


def bench_batched(tuner: LITune, requests, budget: int, slots: int) -> float:
    service = TuningService(tuner, config=ServeConfig(slots=slots))
    t0 = time.perf_counter()
    for data, wl, wr in requests:
        service.submit(data, wl, wr, budget_steps=budget)
    results = service.run()
    dt = time.perf_counter() - t0
    assert len(results) == len(requests)
    return len(requests) / dt


def main():
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--requests", type=int, default=32)
    ap.add_argument("--budget", type=int, default=8)
    ap.add_argument("--n-keys", type=int, default=512)
    ap.add_argument("--index", default="alex", choices=["alex", "carmi"])
    ap.add_argument("--slots", default="1,4,16")
    ap.add_argument("--mixed-wr", action="store_true",
                    help="cycle write/read ratios (heterogeneous pools)")
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--json", default=None, metavar="PATH",
                    help="also write results as a JSON artifact (CI trend)")
    args = ap.parse_args()
    slot_counts = [int(s) for s in args.slots.split(",")]

    cfg = LITuneConfig(index_type=args.index, episode_len=args.budget,
                       lstm_hidden=32, mlp_hidden=64)
    tuner = LITune(cfg, seed=args.seed)
    requests = make_requests(args.requests, args.n_keys, seed=args.seed + 1,
                             mixed_wr=args.mixed_wr)

    # warm both paths with the full wave so compile time is excluded (a
    # real service compiles its programs once at startup; the program
    # cache in launch/serving/programs.py is process-wide)
    bench_serial(tuner, requests, args.budget)
    for b in slot_counts:
        bench_batched(tuner, requests, args.budget, b)

    print(f"# tune_serve  requests={args.requests} budget={args.budget} "
          f"n_keys={args.n_keys} index={args.index} "
          f"mixed_wr={args.mixed_wr} devices={len(jax.devices())}")
    print("benchmark,mode,slots,req_per_s,speedup_vs_serial")
    serial_rps = bench_serial(tuner, requests, args.budget)
    rows = [{"mode": "serial", "slots": 1, "req_per_s": serial_rps,
             "speedup_vs_serial": 1.0}]
    print(f"tune_serve,serial,1,{serial_rps:.3f},1.00")
    for b in slot_counts:
        rps = bench_batched(tuner, requests, args.budget, b)
        rows.append({"mode": "batched", "slots": b, "req_per_s": rps,
                     "speedup_vs_serial": rps / serial_rps})
        print(f"tune_serve,batched,{b},{rps:.3f},{rps / serial_rps:.2f}")

    if args.json:
        with open(args.json, "w") as f:
            json.dump({"benchmark": "tune_serve",
                       "config": {"requests": args.requests,
                                  "budget": args.budget,
                                  "n_keys": args.n_keys,
                                  "index": args.index,
                                  "mixed_wr": args.mixed_wr,
                                  "devices": len(jax.devices())},
                       "rows": rows}, f, indent=2)
        print(f"# wrote {args.json}")


if __name__ == "__main__":
    main()
