"""Fleet mode at scale: hold req/s while the tenant count sweeps.

    PYTHONPATH=src python -m benchmarks.fleet_serve
    PYTHONPATH=src python -m benchmarks.fleet_serve \
        --tenants 64,256,1024,4096 --json BENCH_fleet.json

Builds one `TuningService` per sweep point with N fleet-mode tenants
(`FleetConfig(enabled=True)`) sharing one pretrained agent, drives the
same drifting request wave over a small *hot working set* of them, and
reports req/s per tenant count.  The point of fleet mode is that N is
almost free: tenants outside the working set stay **cold** (zero device
bytes — host-spilled replay pages, no learner copies), the working set
rides **stacked** fine-tune rounds (one jitted dispatch for all K hot
tenants), and the process-wide program caches never grow with N.

Reported per sweep point: req/s, hot/warm/cold tier counts, stacked
round occupancy, device bytes per tenant, and two hard invariants the
CI gate (benchmarks/check_bench.py, metric ``fleet``) enforces outright:

  * zero new `_step_program` binds across the whole tenant sweep (the
    serving cache must stay flat as N sweeps), and zero new stacked
    fine-tune programs after the first point's pow2 ladder warms;
  * every cold tenant at exactly zero device bytes.

A stacked-vs-serial microbench rides along: one K-wide stacked round
vs K width-1 rounds through the same machinery (same replay sampling,
same batch hops), timing the per-round fine-tune wall time's
sublinearity in the hot-tenant count.  The gated trend metric is the
req/s ratio of the largest tenant count over the smallest — the
"holding req/s while tenants sweep" claim as one dimensionless number.

CI smoke sweeps 64→512; the full sweep (64→4096) is the same command
with ``--tenants 64,256,1024,4096``.
"""
from __future__ import annotations

import argparse
import json
import os
import time

# expose every core plus one annex spare before jax initializes (no-op
# if the operator already set the flag) — same discipline as o2_serve
if "xla_force_host_platform_device_count" not in os.environ.get(
        "XLA_FLAGS", ""):
    os.environ["XLA_FLAGS"] = (
        os.environ.get("XLA_FLAGS", "")
        + " --xla_force_host_platform_device_count="
        + str(os.cpu_count() + 1))

import jax
import numpy as np

from repro.core.ddpg import DDPGConfig
from repro.core.litune import LITune, LITuneConfig
from repro.core.o2 import O2Config, _fleet_finetune_program, make_replay
from repro.index.workloads import sample_keys, wr_workload
from repro.launch.serving import (FleetConfig, FleetLearner,
                                  O2ServiceConfig, ServeConfig,
                                  TuningService)
from repro.launch.serving.programs import _step_program


def make_requests(n: int, n_keys: int, seed: int = 1):
    """The o2_serve drifting wave: the key distribution cycles so
    divergence fires and the O2/fleet path actually does its work."""
    dists = ["uniform", "books", "osm", "fb"]
    key = jax.random.PRNGKey(seed)
    out = []
    for i in range(n):
        k = jax.random.fold_in(key, i)
        data = sample_keys(k, n_keys, dists[i % len(dists)])
        wl, _ = wr_workload(jax.random.fold_in(k, 1), data, 1.0,
                            total=n_keys, dist="mix")
        out.append((data, wl, 1.0))
    return out


def build_service(cfg: LITuneConfig, tuner: LITune, n_tenants: int,
                  slots: int, fleet: FleetConfig,
                  replay_capacity: int) -> TuningService:
    """N fleet tenants sharing one pretrained agent (the homogeneous
    fleet: one config, one stacked program group)."""
    agents = {f"t{i}": tuner for i in range(n_tenants)}
    return TuningService(agents, config=ServeConfig(
        slots=slots,
        o2=O2ServiceConfig(enabled=True, o2=cfg.o2,
                           offline_updates_per_tick=2,
                           replay_capacity=replay_capacity,
                           fleet=fleet)))


def drive(service: TuningService, requests, budget: int, hot: int):
    """Submit the wave round-robin over the hot working set and serve
    it; timing covers submit+run only (the serving contract), flush
    settles the trailing learner outside the window."""
    t0 = time.perf_counter()
    for i, (data, wl, wr) in enumerate(requests):
        service.submit(data, wl, wr, budget_steps=budget,
                       index_type=f"t{i % hot}", noise_scale=0.02)
    results = service.run()
    dt = time.perf_counter() - t0
    service.flush_o2()
    assert len(results) == len(requests)
    return len(requests) / dt


def sweep_point(cfg, tuner, n_tenants, requests, budget, slots, hot,
                fleet, replay_capacity, repeats) -> dict:
    best = 0.0
    for _ in range(repeats):
        service = build_service(cfg, tuner, n_tenants, slots, fleet,
                                replay_capacity)
        rps = drive(service, requests, budget, hot)
        best = max(best, rps)
    st = service.stats()
    o2 = st["o2"]
    tenants = service.tenants
    cold_max = max((t.device_bytes() for t in tenants.values()
                    if t.tier == "cold"), default=0)
    return {
        "tenants": n_tenants,
        "req_per_s": best,
        "tenants_hot": o2["tenants_hot"],
        "tenants_warm": o2["tenants_warm"],
        "tenants_cold": o2["tenants_cold"],
        "occupancy": o2["fleet"]["occupancy"],
        "fleet_rounds": o2["fleet"]["rounds"],
        "fleet_lanes": o2["fleet"]["lanes"],
        "warm_starts": o2["warm_starts"],
        "device_bytes_per_tenant": o2["device_bytes"] // n_tenants,
        "cold_device_bytes_max": int(cold_max),
    }


def stack_microbench(cfg: LITuneConfig, fleet: FleetConfig, k: int,
                     n_updates: int, reps: int) -> dict:
    """One K-wide stacked round vs K width-1 rounds through the same
    `FleetLearner.round` machinery — per-round fine-tune wall time's
    sublinearity in the hot-tenant count, on this host."""
    import types

    from repro.core import ddpg as _ddpg

    net_cfg, ddpg_cfg, env_cfg = cfg.net_cfg(), cfg.ddpg, cfg.env_cfg()

    def tenant(i):
        replay = make_replay(net_cfg, ddpg_cfg, env_cfg, capacity=256,
                             seed=i, device=True)
        rng = np.random.default_rng(100 + i)
        T, hid = 24, net_cfg.lstm_hidden
        f32 = lambda *s: rng.standard_normal(s).astype(np.float32)  # noqa: E731
        for _ in range(4):
            replay.add_episode(
                obs=f32(T, replay.obs_dim), action=f32(T, replay.action_dim),
                reward=f32(T), next_obs=f32(T, replay.obs_dim),
                done=np.concatenate([np.zeros(T - 1, np.float32),
                                     [1.0]]).astype(np.float32),
                cost=(rng.random(T) < 0.3).astype(np.float32),
                actor_hidden=(f32(T, hid), f32(T, hid)),
                critic_hidden=(f32(T, hid), f32(T, hid)))
        return types.SimpleNamespace(
            net_cfg=net_cfg, ddpg_cfg=ddpg_cfg, replay=replay,
            offline=_ddpg.init_state(jax.random.PRNGKey(i), net_cfg,
                                     ddpg_cfg))

    learner = FleetLearner(FleetConfig(enabled=True, max_hot=k,
                                       stack_impl=fleet.stack_impl))

    def timed(tenants, width):
        # re-seed each rep's learner states so every round does the same
        # numeric work; block on the outputs (round returns async trees)
        for i, t in enumerate(tenants):
            t.offline = _ddpg.init_state(jax.random.PRNGKey(i), net_cfg,
                                         ddpg_cfg)
        t0 = time.perf_counter()
        if width == 1:
            for t in tenants:
                learner.round([(t, n_updates)])
        else:
            learner.round([(t, n_updates) for t in tenants])
        for t in tenants:
            jax.block_until_ready(t.offline["params"])
        return 1e3 * (time.perf_counter() - t0)

    ts = [tenant(i) for i in range(k)]
    timed(ts, 1)       # warm both program shapes outside the timing
    timed(ts, k)
    serial_ms = min(timed(ts, 1) for _ in range(reps))
    stacked_ms = min(timed(ts, k) for _ in range(reps))
    return {"k": k, "serial_ms": round(serial_ms, 3),
            "stacked_ms": round(stacked_ms, 3),
            "speedup": round(serial_ms / max(stacked_ms, 1e-9), 3)}


def main():
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--tenants", default="64,128,256,512",
                    metavar="N1,N2,...",
                    help="tenant counts to sweep (full: 64,256,1024,4096)")
    ap.add_argument("--requests", type=int, default=48)
    ap.add_argument("--budget", type=int, default=8)
    ap.add_argument("--n-keys", type=int, default=256)
    ap.add_argument("--slots", type=int, default=2)
    ap.add_argument("--hot", type=int, default=8,
                    help="hot working set: distinct tenants receiving "
                         "traffic (constant across the sweep)")
    ap.add_argument("--replay-capacity", type=int, default=128,
                    help="per-tenant ring rows (a fleet bounds its "
                         "per-tenant footprint here)")
    ap.add_argument("--repeats", type=int, default=2)
    ap.add_argument("--stack-k", type=int, default=8,
                    help="hot-tenant count for the stacked-vs-serial "
                         "fine-tune microbench")
    ap.add_argument("--updates", type=int, default=4,
                    help="fine-tune updates per round in the microbench")
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--json", default=None, metavar="PATH")
    args = ap.parse_args()

    counts = sorted(int(n) for n in args.tenants.split(",") if n)
    assert counts and args.hot <= min(counts)
    cfg = LITuneConfig(
        index_type="t0", episode_len=args.budget,
        lstm_hidden=32, mlp_hidden=64,
        ddpg=DDPGConfig(batch_size=16, seq_len=4, burn_in=1),
        o2=O2Config(divergence_threshold=0.10, assess_every=4,
                    offline_updates_per_window=2))
    fleet = FleetConfig(enabled=True, max_hot=max(args.hot, args.stack_k))
    tuner = LITune(cfg, seed=args.seed)
    requests = make_requests(args.requests, args.n_keys,
                             seed=args.seed + 1)

    # warm every program the sweep will touch (caches are process-wide),
    # then snapshot the cache sizes: the sweep must bind nothing new
    drive(build_service(cfg, tuner, counts[0], args.slots, fleet,
                        args.replay_capacity),
          requests, args.budget, args.hot)
    step_binds0 = _step_program.cache_info().currsize
    fleet_binds0 = _fleet_finetune_program.cache_info().currsize

    rows = []
    for n in counts:
        row = sweep_point(cfg, tuner, n, requests, args.budget,
                          args.slots, args.hot, fleet,
                          args.replay_capacity, args.repeats)
        row["new_step_binds"] = (_step_program.cache_info().currsize
                                 - step_binds0)
        row["new_fleet_binds"] = (
            _fleet_finetune_program.cache_info().currsize - fleet_binds0)
        rows.append(row)

    stack = stack_microbench(cfg, fleet, args.stack_k, args.updates,
                             args.repeats)
    rps_ratio = rows[-1]["req_per_s"] / rows[0]["req_per_s"]

    print(f"# fleet_serve  requests={args.requests} budget={args.budget} "
          f"n_keys={args.n_keys} slots={args.slots} hot={args.hot} "
          f"replay_capacity={args.replay_capacity} "
          f"repeats={args.repeats} devices={len(jax.devices())} "
          f"impl={FleetLearner(fleet).impl}")
    print("benchmark,tenants,req_per_s,hot,warm,cold,occupancy,"
          "dev_bytes_per_tenant,cold_dev_max,new_step_binds")
    for r in rows:
        print(f"fleet_serve,{r['tenants']},{r['req_per_s']:.3f},"
              f"{r['tenants_hot']},{r['tenants_warm']},"
              f"{r['tenants_cold']},{r['occupancy']:.2f},"
              f"{r['device_bytes_per_tenant']},"
              f"{r['cold_device_bytes_max']},{r['new_step_binds']}")
    print(f"fleet_serve,stack_k{stack['k']},serial={stack['serial_ms']}ms,"
          f"stacked={stack['stacked_ms']}ms,"
          f"speedup={stack['speedup']},,,,,")
    print(f"# rps_ratio (N={counts[-1]} over N={counts[0]}) = "
          f"{rps_ratio:.3f}")

    if args.json:
        with open(args.json, "w") as f:
            json.dump({"benchmark": "fleet",
                       "config": {"tenants": counts,
                                  "requests": args.requests,
                                  "budget": args.budget,
                                  "n_keys": args.n_keys,
                                  "slots": args.slots,
                                  "hot": args.hot,
                                  "replay_capacity": args.replay_capacity,
                                  "repeats": args.repeats,
                                  "stack_k": args.stack_k,
                                  "devices": len(jax.devices())},
                       "rows": rows,
                       "stack": stack,
                       "rps_ratio": rps_ratio}, f, indent=2)
        print(f"# wrote {args.json}")


if __name__ == "__main__":
    main()
