"""Benchmark entrypoint: one function per paper table/figure.

    PYTHONPATH=src python -m benchmarks.run                 # paper scale
    REPRO_BENCH_SCALE=smoke ... -m benchmarks.run           # CI scale
    ... -m benchmarks.run --only fig5_efficiency,table3_costs

Prints CSV (``benchmark,<cols...>``) to stdout.  The roofline table itself
comes from the separate 512-device process:
    PYTHONPATH=src python -m repro.launch.dryrun --out roofline.jsonl
"""
from __future__ import annotations

import argparse
import sys
import time
import traceback


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--only", default="",
                    help="comma-separated benchmark names")
    ap.add_argument("--skip", default="", help="comma-separated names")
    args = ap.parse_args()

    from benchmarks import paper_figs, roofline
    from benchmarks.common import SCALE

    benches = dict(paper_figs.ALL)
    benches["micro_steps"] = roofline.micro_steps
    benches["kernel_micro"] = roofline.kernel_micro
    benches["kernel_roofline"] = roofline.kernel_roofline

    only = [s for s in args.only.split(",") if s]
    skip = set(s for s in args.skip.split(",") if s)
    names = only or [n for n in benches if n not in skip]

    print(f"# repro benchmarks  scale={SCALE}", flush=True)
    failed = []
    for name in names:
        t0 = time.time()
        print(f"# --- {name} ---", flush=True)
        try:
            for row in benches[name]():
                print(row, flush=True)
            print(f"# {name} done in {time.time()-t0:.0f}s", flush=True)
        except Exception as e:  # keep going; report at the end
            failed.append(name)
            print(f"# {name} FAILED: {type(e).__name__}: {e}", flush=True)
            traceback.print_exc()
    if failed:
        print(f"# FAILED benchmarks: {failed}", flush=True)
        sys.exit(1)
    print("# all benchmarks complete", flush=True)


if __name__ == "__main__":
    main()
