"""Cost of continuous tuning (O2) inside the batched tuning service.

    PYTHONPATH=src python -m benchmarks.o2_serve
    PYTHONPATH=src python -m benchmarks.o2_serve --requests 12 --budget 16 \
        --n-keys 1024 --slots 4 --assess-every 2 --json BENCH_o2_serve.json

Serves the same drifting request wave through two service configurations
and reports req/s (best of ``--repeats`` runs per mode — the CPU hosts CI
runs on are noisy):

  frozen — `TuningService` as PR 1 shipped it: a frozen pretrained agent,
           no transition capture, no offline learner;
  o2     — `O2ServiceConfig(enabled=True)`: per-request divergence
           observation, device-resident transition capture into the
           annex replay ring, backpressured offline fine-tune rounds,
           and divergence-triggered pooled assessments / hot-swaps.

The gap between the two is the end-to-end price of continuous tuning.
Timing covers `run()` only — the serving contract; the trailing learner
and any still-executing assessment verdicts settle in `flush_o2()`
*outside* the timed window, exactly as a serving deployment experiences
them.  `--assess-every 1` is the worst case (every diverged window
assesses, costing up to one offline episode per served episode);
production rate-limits via the same knob.

Prints CSV ``o2_serve,<mode>,<slots>,<req/s>,<vs_frozen>`` plus swap
latency and per-phase host-time rows; ``--json`` writes the same numbers
as a JSON artifact for the CI perf gate (benchmarks/check_bench.py).
"""
from __future__ import annotations

import argparse
import json
import os
import sys
import time


def _argv_value(flag: str, default: str) -> str:
    """Peek one CLI value before argparse (and before jax initializes —
    the sweep's annex widths size the forced device count).  Accepts
    ``--flag value`` and ``--flag=value``; like argparse, the last
    occurrence wins.  main() cross-checks the peek against argparse and
    refuses forms the peek cannot see (abbreviated flags)."""
    value = default
    for i, arg in enumerate(sys.argv):
        if arg == flag and i + 1 < len(sys.argv):
            value = sys.argv[i + 1]
        elif arg.startswith(flag + "="):
            value = arg.split("=", 1)[1]
    return value


_ANNEX_WIDTHS = sorted(int(w) for w in
                       _argv_value("--annex-width", "").split(",") if w)

# expose every core as an XLA host device — plus the spare(s) the O2
# service adopts as its learner/assessment annex slice — before jax
# initializes (no-op if the operator already set the flag)
if "xla_force_host_platform_device_count" not in os.environ.get(
        "XLA_FLAGS", ""):
    os.environ["XLA_FLAGS"] = (
        os.environ.get("XLA_FLAGS", "")
        + " --xla_force_host_platform_device_count="
        + str(os.cpu_count() + max(_ANNEX_WIDTHS, default=1)))

import jax
import numpy as np

from repro.core.ddpg import DDPGConfig
from repro.core.litune import LITune, LITuneConfig
from repro.core.o2 import O2Config
from repro.index.workloads import sample_keys, wr_workload
from repro.launch.serving import (DeviceSlice, O2ServiceConfig,
                                  ServeConfig, ServingTopology,
                                  TuningService)
from repro.launch.serving.topology import _largest_divisor_leq


def make_requests(n: int, n_keys: int, seed: int = 1):
    """A drifting wave: the key distribution cycles so the divergence
    monitor actually fires (the O2 path's worst case — every window may
    trigger an assessment)."""
    dists = ["uniform", "books", "osm", "fb"]
    key = jax.random.PRNGKey(seed)
    out = []
    for i in range(n):
        k = jax.random.fold_in(key, i)
        data = sample_keys(k, n_keys, dists[i % len(dists)])
        wl, _ = wr_workload(jax.random.fold_in(k, 1), data, 1.0,
                            total=n_keys, dist="mix")
        out.append((data, wl, 1.0))
    return out


def bench_once(tuner: LITune, requests, budget: int, slots: int,
               o2: O2ServiceConfig | None, topology=None):
    service = TuningService(tuner, config=ServeConfig(
        slots=slots, o2=o2 if o2 is not None else O2ServiceConfig(),
        topology=topology))
    t0 = time.perf_counter()
    for data, wl, wr in requests:
        service.submit(data, wl, wr, budget_steps=budget, noise_scale=0.02)
    results = service.run()
    dt = time.perf_counter() - t0
    # settle the trailing learner + assessment verdicts outside the timed
    # window so the next run starts from a quiet machine
    service.flush_o2()
    assert len(results) == len(requests)
    return len(requests) / dt, service


def bench(mk_tuner, requests, budget, slots, o2, repeats: int):
    """Best-of-`repeats` req/s, with the stats of the *best* run — the
    JSON artifact's ratio and its phase breakdown describe one run."""
    best, service = 0.0, None
    for _ in range(repeats):
        rps, svc = bench_once(mk_tuner(), requests, budget, slots, o2)
        if rps > best:
            best, service = rps, svc
    return best, service


def annex_sweep(mk_tuner, requests, budget: int, slots: int,
                o2_cfg: O2ServiceConfig, widths: list[int],
                repeats: int) -> list[dict]:
    """Serve the same O2 stream once per annex slice width, keeping the
    serving slice fixed, and report the host-side assessment phase time
    (dispatch + blocking verdict fetches — the part of the O2 tax the
    annex slice actually absorbs).  Widths shard the pooled assessment
    waves across 1..w annex devices; per-lane math is identical, so the
    verdicts are bitwise equal and the only thing that moves is time.
    Min across `repeats` runs per width (noise floor)."""
    import jax
    ids = tuple(d.id for d in jax.devices())
    # the serving slice stays fixed across the sweep (the comparison is
    # annex-width-only): the largest divisor of `slots` that leaves the
    # widest requested annex room
    serve_n = _largest_divisor_leq(slots, len(ids) - max(widths))
    if serve_n + max(widths) > len(ids):
        raise SystemExit(
            f"annex sweep needs {serve_n}+{max(widths)} devices but the "
            f"host exposes {len(ids)} — unset any operator "
            f"xla_force_host_platform_device_count or lower the widths")
    serve = DeviceSlice(ids[:serve_n], name="serve")
    rows = []
    for w in widths:
        topo = ServingTopology(
            (serve,), DeviceSlice(ids[serve_n:serve_n + w], name="annex"),
            name=f"host+annex{w}")
        # one warm pass binds this width's programs outside the timing
        bench_once(mk_tuner(), requests, budget, slots, o2_cfg,
                   topology=topo)
        best_assess, best_rps = float("inf"), 0.0
        for _ in range(repeats):
            rps, svc = bench_once(mk_tuner(), requests, budget, slots,
                                  o2_cfg, topology=topo)
            st = svc.stats()["o2"]
            best_assess = min(best_assess, st["phase_ms"]["assess"])
            best_rps = max(best_rps, rps)
        rows.append({"annex_width": w, "assess_ms": round(best_assess, 3),
                     "req_per_s": best_rps,
                     "assessments": st["assessments"]})
    return rows


def main():
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--requests", type=int, default=12)
    ap.add_argument("--budget", type=int, default=16)
    ap.add_argument("--n-keys", type=int, default=1024)
    ap.add_argument("--slots", type=int, default=4)
    ap.add_argument("--updates-per-tick", type=int, default=2)
    ap.add_argument("--assess-every", type=int, default=2,
                    help="assess every Nth diverged window (1 = worst "
                         "case: one offline episode per served episode)")
    ap.add_argument("--repeats", type=int, default=3,
                    help="timed runs per mode; best is reported")
    ap.add_argument("--swap-reps", type=int, default=20,
                    help="direct hot-swap latency measurements")
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--annex-width", default=None, metavar="W1,W2,...",
                    help="sweep the O2 annex slice width instead of the "
                         "frozen-vs-o2 compare: serve the same stream "
                         "once per width and report the assessment "
                         "phase_ms scaling (JSON artifact: o2_annex)")
    ap.add_argument("--json", default=None, metavar="PATH",
                    help="also write results as a JSON artifact (CI gate)")
    args = ap.parse_args()

    cfg = LITuneConfig(
        index_type="alex", episode_len=args.budget,
        lstm_hidden=32, mlp_hidden=64,
        ddpg=DDPGConfig(batch_size=16, seq_len=4, burn_in=1),
        o2=O2Config(divergence_threshold=0.10,
                    assess_every=args.assess_every,
                    offline_updates_per_window=args.updates_per_tick))
    o2_cfg = O2ServiceConfig(
        enabled=True, o2=cfg.o2,
        offline_updates_per_tick=args.updates_per_tick)
    requests = make_requests(args.requests, args.n_keys, seed=args.seed + 1)
    mk = lambda: LITune(cfg, seed=args.seed)  # noqa: E731

    if args.annex_width:
        widths = sorted(int(w) for w in args.annex_width.split(",") if w)
        if widths != _ANNEX_WIDTHS:
            # the pre-jax peek sized the forced device count; if argparse
            # saw something else (abbreviated flag, exotic quoting), the
            # device layout would not match the sweep — refuse instead
            raise SystemExit(
                f"--annex-width must be passed as the exact flag: the "
                f"pre-jax device sizing saw {_ANNEX_WIDTHS or 'nothing'} "
                f"but argparse parsed {widths}")
        assert widths, "--annex-width needs at least one width"
        rows = annex_sweep(mk, requests, args.budget, args.slots, o2_cfg,
                           widths, args.repeats)
        base = rows[0]["assess_ms"]
        speedup = base / max(rows[-1]["assess_ms"], 1e-9)
        print(f"# o2_annex  requests={args.requests} budget={args.budget} "
              f"n_keys={args.n_keys} slots={args.slots} "
              f"assess_every={args.assess_every} repeats={args.repeats} "
              f"devices={len(jax.devices())} widths={widths}")
        print("benchmark,annex_width,slots,assess_ms,speedup_vs_w"
              + str(widths[0]))
        for r in rows:
            print(f"o2_annex,{r['annex_width']},{args.slots},"
                  f"{r['assess_ms']:.2f},"
                  f"{base / max(r['assess_ms'], 1e-9):.2f}")
        if args.json:
            with open(args.json, "w") as f:
                json.dump({"benchmark": "o2_annex",
                           "config": {"requests": args.requests,
                                      "budget": args.budget,
                                      "n_keys": args.n_keys,
                                      "slots": args.slots,
                                      "assess_every": args.assess_every,
                                      "repeats": args.repeats,
                                      "widths": widths,
                                      "devices": len(jax.devices())},
                           "rows": rows,
                           "assess_speedup": speedup}, f, indent=2)
            print(f"# wrote {args.json}")
        return

    # warm both paths so compile time is excluded (programs are cached
    # process-wide; a real service binds them once at startup)
    bench_once(mk(), requests, args.budget, args.slots, None)
    bench_once(mk(), requests, args.budget, args.slots, o2_cfg)

    frozen_rps, _ = bench(mk, requests, args.budget, args.slots, None,
                          args.repeats)
    o2_rps, service = bench(mk, requests, args.budget, args.slots, o2_cfg,
                            args.repeats)

    st = service.stats()["o2"]
    tstats = st["alex"]
    phase = st["phase_ms"]

    # hot-swap latency, measured directly: promote the offline model over
    # the service's (already live) pools `swap_reps` times
    from repro.launch.serving import TuneRequest
    data, wl, wr = requests[-1]
    last_req = TuneRequest(
        rid=-1, data_keys=np.asarray(data),
        workload={"reads": np.asarray(wl["reads"]),
                  "inserts": np.asarray(wl["inserts"])},
        wr_ratio=wr, budget_steps=args.budget)
    tenant = service.tenants["alex"]
    n0 = len(tenant.swap_times_s)
    for _ in range(args.swap_reps):
        service._hot_swap("alex", last_req)
    swap_ms = 1e3 * float(np.mean(tenant.swap_times_s[n0:]))
    print(f"# o2_serve  requests={args.requests} budget={args.budget} "
          f"n_keys={args.n_keys} slots={args.slots} "
          f"updates_per_tick={args.updates_per_tick} "
          f"assess_every={args.assess_every} repeats={args.repeats} "
          f"devices={len(jax.devices())} "
          f"windows={tstats['windows']} diverged={tstats['diverged']} "
          f"assessed={st['assessments']} swaps={tstats['swaps']} "
          f"offline_updates={tstats['offline_updates']} "
          f"finetune_skipped={tstats['finetune_skipped']}")
    print("benchmark,mode,slots,req_per_s,vs_frozen")
    print(f"o2_serve,frozen,{args.slots},{frozen_rps:.3f},1.00")
    print(f"o2_serve,o2,{args.slots},{o2_rps:.3f},"
          f"{o2_rps / frozen_rps:.2f}")
    print(f"o2_serve,swap,{args.slots},{swap_ms:.3f} ms,-")
    print(f"o2_serve,phase_ms,{args.slots},"
          f"capture={phase['capture']:.2f}|finetune={phase['finetune']:.2f}"
          f"|assess={phase['assess']:.2f},-")

    if args.json:
        with open(args.json, "w") as f:
            json.dump({"benchmark": "o2_serve",
                       "config": {"requests": args.requests,
                                  "budget": args.budget,
                                  "n_keys": args.n_keys,
                                  "slots": args.slots,
                                  "updates_per_tick": args.updates_per_tick,
                                  "assess_every": args.assess_every,
                                  "repeats": args.repeats,
                                  "devices": len(jax.devices())},
                       "rows": [
                           {"mode": "frozen", "req_per_s": frozen_rps,
                            "vs_frozen": 1.0},
                           {"mode": "o2", "req_per_s": o2_rps,
                            "vs_frozen": o2_rps / frozen_rps},
                       ],
                       "swap_latency_ms": swap_ms,
                       "phase_ms": phase,
                       "o2_stats": tstats}, f, indent=2)
        print(f"# wrote {args.json}")


if __name__ == "__main__":
    main()
