"""Benchmarks mirroring the paper's tables/figures (one function each).

Each returns a list of CSV lines; benchmarks/run.py drives them.  Mapping:
  fig1_surface        Fig 1(a)  2-parameter performance surface (ALEX)
  fig1_speedup        Fig 1(b)  optimal-vs-default speedup across datasets
  fig2_impact         Fig 2     per-parameter impact scores
  fig5_efficiency     Fig 5     best-found vs tuning-step budget
  fig6_7_extensive    Fig 6/7   extensive-tuning runtime + throughput
  fig8_radar          Fig 8     5-attribute method comparison (CARMI+MIX)
  fig9_10_stream      Fig 9/10  online tuning on data streams, O2 ablation
  fig11_safety        Fig 11    dangerous-zone exploration + failures
  fig12_stability     Fig 12    training stability +- Safe-RL
  table3_costs        Table 3   training/tuning cost vs sampling ratio
"""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import (DATASETS, WORKLOADS, bench_scale, csv_row,
                               get_litune, litune_config, make_instance,
                               run_method)
from repro.core.spaces import alex_space
from repro.index import env as E
from repro.index.env import evaluate_params
from repro.index.alex import DEFAULTS as ALEX_DEFAULTS


# ------------------------------------------------------------ Fig 1(a)
def fig1_surface() -> list[str]:
    """Sweep (kmax_ood_keys_log2 x density_init) on ALEX+MIX, runtime ns."""
    env_cfg, data, workload = make_instance("alex", "mix", 1.0)
    rows = [csv_row("fig1_surface", "kmax_log2", "density", "runtime_ns")]
    for kmax in (2, 6, 10, 14):
        for dens in (0.5, 0.65, 0.8, 0.95):
            p = {k: jnp.float32(v) for k, v in ALEX_DEFAULTS.items()}
            p["kmax_ood_keys_log2"] = jnp.float32(kmax)
            p["density_init"] = jnp.float32(dens)
            rt, _, _ = evaluate_params(env_cfg, p, data, workload, 1.0)
            rows.append(csv_row("fig1_surface", kmax, dens,
                                f"{float(rt):.1f}"))
    return rows


# ------------------------------------------------------------ Fig 1(b)
def fig1_speedup() -> list[str]:
    sc = bench_scale()
    rows = [csv_row("fig1_speedup", "index", "dataset", "speedup_x")]
    for index in ("alex", "carmi"):
        for ds in DATASETS:
            r = run_method("litune", index, ds, 1.0, sc.extensive_steps)
            rows.append(csv_row("fig1_speedup", index, ds,
                                f"{r['default'] / r['best']:.2f}"))
    return rows


# ------------------------------------------------------------ Fig 2
def fig2_impact() -> list[str]:
    """Impact score: improvement from tuning ONE parameter (others default)
    relative to full tuning.  Paper reports 10-25% with no dominant dim."""
    env_cfg, data, workload = make_instance("alex", "mix", 1.0)
    space = alex_space()
    default = {k: jnp.float32(v) for k, v in ALEX_DEFAULTS.items()}
    r_def, _, _ = evaluate_params(env_cfg, default, data, workload, 1.0)
    r_def = float(r_def)

    # full tuning reference: random search over all dims
    rng = np.random.default_rng(0)
    best_full = r_def
    for _ in range(60):
        raw = space.random_raw(rng)
        rt, _, v = evaluate_params(
            env_cfg, {k: jnp.float32(x) for k, x in raw.items()}, data,
            workload, 1.0)
        if float(v["c_m"]) + float(v["c_r"]) == 0:
            best_full = min(best_full, float(rt))
    full_gain = max(r_def - best_full, 1e-9)

    rows = [csv_row("fig2_impact", "parameter", "impact_pct")]
    for i, name in enumerate(space.names):
        best_one = r_def
        lo, hi = float(space.lows[i]), float(space.highs[i])
        for val in np.linspace(lo, hi, 8):
            p = dict(default)
            p[name] = jnp.float32(round(val) if space.kinds[i] in
                                  ("int", "choice", "bool") else val)
            rt, _, v = evaluate_params(env_cfg, p, data, workload, 1.0)
            if float(v["c_m"]) + float(v["c_r"]) == 0:
                best_one = min(best_one, float(rt))
        impact = 100.0 * (r_def - best_one) / full_gain
        rows.append(csv_row("fig2_impact", name, f"{impact:.1f}"))
    return rows


# ------------------------------------------------------------ Fig 5
def fig5_efficiency() -> list[str]:
    sc = bench_scale()
    budgets = sorted({2, 5, sc.budget_steps, sc.extensive_steps})
    rows = [csv_row("fig5_efficiency", "method", "budget_steps",
                    "runtime_ratio_vs_default")]
    for method in ("random", "heuristic", "smbo", "ddpg", "litune"):
        r = run_method(method, "alex", "mix", 1.0, max(budgets))
        bsf = r["best_so_far"]
        for b in budgets:
            val = bsf[min(b, len(bsf)) - 1] / r["default"]
            rows.append(csv_row("fig5_efficiency", method, b, f"{val:.4f}"))
    return rows


# ------------------------------------------------------------ Fig 6/7
def fig6_7_extensive() -> list[str]:
    sc = bench_scale()
    rows = [csv_row("fig6_7", "index", "dataset", "workload", "method",
                    "runtime_ns", "improvement_pct", "throughput_ops")]
    for index in ("alex", "carmi"):
        for ds in DATASETS:
            for wname, wr in WORKLOADS.items():
                for method in ("default", "smbo", "ddpg", "litune"):
                    r = run_method(method, index, ds, wr,
                                   sc.extensive_steps)
                    imp = 100.0 * (1 - r["best"] / r["default"])
                    thr = 1e9 / max(r["best"], 1e-9)
                    rows.append(csv_row(
                        "fig6_7", index, ds, wname, method,
                        f"{r['best']:.1f}", f"{imp:.1f}", f"{thr:.0f}"))
    return rows


# ------------------------------------------------------------ Fig 8
def fig8_radar() -> list[str]:
    """CARMI+MIX balanced: adaptability/quality/stability/efficiency/prep,
    normalized 0-9 (higher better)."""
    sc = bench_scale()
    methods = ("random", "grid", "heuristic", "smbo", "ddpg", "litune")
    stats = {}
    for m in methods:
        runs = [run_method(m, "carmi", ds, 1.0, sc.budget_steps, seed=s)
                for s in range(sc.n_seeds) for ds in ("mix", "osm")]
        best = np.array([r["best"] for r in runs])
        fails = np.array([r["failures"] for r in runs])
        wall = np.array([r["wall_s"] for r in runs])
        stats[m] = {
            "adaptability": -np.std(best / best.mean()),
            "quality": -best.mean(),
            "stability": -fails.mean(),
            "efficiency": -(best.mean() * np.maximum(wall.mean(), 1e-3)),
            "prep": {"random": 0, "grid": 0, "heuristic": -1, "smbo": -1,
                     "ddpg": -8, "litune": -5}[m],  # rel. prep cost (Table 3)
        }
    rows = [csv_row("fig8_radar", "method", "attribute", "score_0_9")]
    for attr in ("adaptability", "quality", "stability", "efficiency",
                 "prep"):
        vals = np.array([stats[m][attr] for m in methods], np.float64)
        lo, hi = vals.min(), vals.max()
        norm = 9.0 * (vals - lo) / max(hi - lo, 1e-12)
        for m, v in zip(methods, norm):
            rows.append(csv_row("fig8_radar", m, attr, f"{v:.1f}"))
    return rows


# ------------------------------------------------------------ Fig 9/10
def fig9_10_stream() -> list[str]:
    from repro.index.workloads import StreamConfig, stream_windows
    sc = bench_scale()
    n_windows = {"smoke": 4, "paper": 8, "full": 30}[
        __import__("benchmarks.common", fromlist=["SCALE"]).SCALE]
    rows = [csv_row("fig9_10", "index", "variant", "window",
                    "best_runtime_ns", "default_ns", "swapped")]
    for index, ds in (("alex", "osm"), ("carmi", "mix")):
        for variant, use_o2 in (("litune_o2", True), ("litune_no_o2", False)):
            tuner = get_litune(index, seed=0)
            tuner.cfg = litune_config(index, use_o2=use_o2)
            scfg = StreamConfig(n_windows=n_windows,
                                base_per_window=sc.n_keys // 2,
                                updates_per_window=sc.n_queries // 2,
                                dist=ds, drift_per_window=0.1)
            res = tuner.stream(stream_windows(jax.random.PRNGKey(5), scfg),
                               max_steps_per_window=5)
            for r in res:
                rows.append(csv_row(
                    "fig9_10", index, variant, r["window"],
                    f"{r['best_runtime_ns']:.1f}", f"{r['r0_ns']:.1f}",
                    r.get("swapped", False)))
    return rows


# ------------------------------------------------------------ Fig 11
def fig11_safety() -> list[str]:
    """Exploration safety: dangerous-zone visits + cumulative failures over
    tuning trials (ALEX + OSM + balanced, the paper's setting)."""
    sc = bench_scale()
    rows = [csv_row("fig11_safety", "method", "trials", "failures",
                    "danger_zone_visits")]
    env_cfg, data, workload = make_instance("alex", "osm", 1.0)
    space = alex_space()

    def danger(raw: dict) -> bool:
        return (raw["kmax_ood_keys_log2"] >= 12 and
                raw["ood_tolerance_factor"] >= 24)

    # baselines: count visits by replaying their proposals
    from repro.tuning.base import run_tuner
    from repro.tuning.baselines import make_baseline

    for method in ("random", "smbo"):
        visits, failures, trials = 0, 0, 0
        for seed in range(sc.n_seeds):
            tuner = make_baseline(method, space, seed)
            orig_propose = tuner.propose

            def propose():
                raw = orig_propose()
                nonlocal visits
                visits += int(danger(raw))
                return raw
            tuner.propose = propose
            res = run_tuner(tuner, env_cfg, data, workload, 1.0,
                            budget_evals=sc.extensive_steps)
            failures += res.failures
            trials += res.evals
        rows.append(csv_row("fig11_safety", method, trials, failures, visits))

    for variant, safe in (("litune", True), ("litune_nosafe", False)):
        visits, failures, trials = 0, 0, 0
        for seed in range(sc.n_seeds):
            tuner = get_litune("alex", seed=seed, safe_rl=safe)
            res = tuner.tune(data, workload, 1.0,
                             budget_steps=sc.extensive_steps)
            for a in res["actions"]:
                raw = {k: float(v) for k, v in
                       space.decode(jnp.asarray(a)).items()}
                visits += int(danger(raw))
            failures += int(res["violations"])
            trials += res["steps"]
        rows.append(csv_row("fig11_safety", variant, trials, failures,
                            visits))
    return rows


# ------------------------------------------------------------ Fig 12
def fig12_stability() -> list[str]:
    """Training-reward trajectories with vs without Safe-RL (fresh agents,
    same seeds).  Paper: no-safe shows late-training volatility."""
    from repro.core.litune import LITune
    rows = [csv_row("fig12_stability", "variant", "iter", "mean_return",
                    "violations")]
    outer = bench_scale().pretrain_outer
    for variant, safe in (("safe_rl", True), ("no_safe_rl", False)):
        tuner = LITune(litune_config("alex", safe_rl=safe), seed=123)
        hist = tuner.pretrain(n_outer=outer, seed=123)
        for rec in hist:
            rows.append(csv_row("fig12_stability", variant, rec["iter"],
                                f"{rec['mean_return']:.3f}",
                                f"{rec['violations']:.0f}"))
    return rows


# ------------------------------------------------------------ Table 3
def table3_costs() -> list[str]:
    """Sampling-ratio ablation: reservoir size vs tuning quality/time.
    LITune-X% = tuning on an X% reservoir of the (scaled) dataset."""
    sc = bench_scale()
    rows = [csv_row("table3", "variant", "reservoir_keys", "tune_wall_s",
                    "best_runtime_ns", "default_ns")]
    key = jax.random.PRNGKey(0)
    from repro.index.workloads import sample_keys, wr_workload
    full_n = sc.n_keys * 4
    data_full = sample_keys(key, full_n, "osm")
    tuner = get_litune("alex", seed=0)
    for frac, name in ((0.001, "litune_0.1pct"), (0.01, "litune_1pct"),
                       (0.1, "litune_10pct"), (1.0, "litune_full")):
        n = max(int(full_n * frac), 256)
        reservoir = data_full[jnp.linspace(0, full_n - 1, n).astype(int)]
        workload, _ = wr_workload(jax.random.fold_in(key, n), reservoir, 1.0,
                                  total=min(n, sc.n_queries), dist="osm")
        t0 = time.time()
        res = tuner.tune(reservoir, workload, 1.0,
                         budget_steps=sc.budget_steps)
        rows.append(csv_row("table3", name, n, f"{time.time() - t0:.1f}",
                            f"{res['best_runtime_ns']:.1f}",
                            f"{res['r0_ns']:.1f}"))
    return rows


ALL = {
    "fig1_surface": fig1_surface,
    "fig1_speedup": fig1_speedup,
    "fig2_impact": fig2_impact,
    "fig5_efficiency": fig5_efficiency,
    "fig6_7_extensive": fig6_7_extensive,
    "fig8_radar": fig8_radar,
    "fig9_10_stream": fig9_10_stream,
    "fig11_safety": fig11_safety,
    "fig12_stability": fig12_stability,
    "table3_costs": table3_costs,
}
