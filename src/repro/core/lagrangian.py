"""Lagrangian CMDP solver (paper §4.2 Eq. 1) — comparison baseline.

pi* = argmax_pi min_{lambda>=0} E[ sum r_t - lambda * sum c_t ] + lambda*C

Implemented inside the DDPG learner as a second (cost) critic plus dual
ascent on lambda (DDPGConfig.use_cost_critic=True).  The paper notes
(after [5]) that Lagrangian methods can violate constraints *during*
training, which motivates the ET-MDP + context-model design; the benchmark
fig12_stability contrasts the two.
"""
from __future__ import annotations

from repro.core.ddpg import DDPGConfig


def lagrangian_config(base: DDPGConfig | None = None,
                      cost_limit: float = 1.0,
                      lambda_lr: float = 1e-2) -> DDPGConfig:
    import dataclasses
    base = base or DDPGConfig()
    return dataclasses.replace(base, use_cost_critic=True,
                               cost_limit=cost_limit, lambda_lr=lambda_lr)
