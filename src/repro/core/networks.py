"""Actor / critic networks: LSTM context module + MLP heads (pure JAX).

The paper's backbone is "DDPG enhanced with LSTM" (§4.2): the LSTM maintains
context from past exploration so the policy can recognize (and avoid)
dangerous regions -- the context model of the ET-MDP solver.  Same ParamSpec
machinery as the LM substrate, so these networks shard/lower on the mesh with
the identical pipeline (the `litune` dry-run cells).
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp

from repro.models.module import (ParamSpec, fan_in_init, init_params,
                                 zeros_init)


@dataclasses.dataclass(frozen=True)
class NetConfig:
    obs_dim: int
    action_dim: int
    lstm_hidden: int = 128
    mlp_hidden: int = 256
    n_mlp_layers: int = 2


# ------------------------------------------------------------------ pieces
def _linear_specs(d_in, d_out):
    return {"w": ParamSpec((d_in, d_out), jnp.float32, ("generic", "generic"),
                           fan_in_init()),
            "b": ParamSpec((d_out,), jnp.float32, ("generic",), zeros_init())}


def _linear(p, x):
    return x @ p["w"] + p["b"]


def _lstm_specs(d_in, hidden):
    return {
        "wi": ParamSpec((d_in, 4 * hidden), jnp.float32,
                        ("generic", "generic"), fan_in_init()),
        "wh": ParamSpec((hidden, 4 * hidden), jnp.float32,
                        ("generic", "generic"), fan_in_init()),
        "b": ParamSpec((4 * hidden,), jnp.float32, ("generic",), zeros_init()),
    }


def lstm_step(p, hc, x):
    """x [..., d_in]; hc = (h, c) each [..., hidden]."""
    h, c = hc
    gates = x @ p["wi"] + h @ p["wh"] + p["b"]
    i, f, g, o = jnp.split(gates, 4, axis=-1)
    c = jax.nn.sigmoid(f + 1.0) * c + jax.nn.sigmoid(i) * jnp.tanh(g)
    h = jax.nn.sigmoid(o) * jnp.tanh(c)
    return (h, c)


def _mlp_specs(cfg: NetConfig, d_in, d_out):
    specs = {}
    d = d_in
    for i in range(cfg.n_mlp_layers):
        specs[f"l{i}"] = _linear_specs(d, cfg.mlp_hidden)
        d = cfg.mlp_hidden
    specs["out"] = _linear_specs(d, d_out)
    return specs


def _mlp(p, x, cfg: NetConfig):
    for i in range(cfg.n_mlp_layers):
        x = jax.nn.relu(_linear(p[f"l{i}"], x))
    return _linear(p["out"], x)


def zero_hidden(cfg: NetConfig, batch_shape=()):
    shape = tuple(batch_shape) + (cfg.lstm_hidden,)
    return (jnp.zeros(shape, jnp.float32), jnp.zeros(shape, jnp.float32))


# ------------------------------------------------------------------ actor
def actor_specs(cfg: NetConfig):
    return {"lstm": _lstm_specs(cfg.obs_dim, cfg.lstm_hidden),
            "mlp": _mlp_specs(cfg, cfg.lstm_hidden + cfg.obs_dim,
                              cfg.action_dim)}


def actor_apply(p, obs, hidden, cfg: NetConfig):
    """obs [..., obs_dim]; hidden (h,c). Returns (action [-1,1], hidden')."""
    hc = lstm_step(p["lstm"], hidden, obs)
    feat = jnp.concatenate([hc[0], obs], axis=-1)
    return jnp.tanh(_mlp(p["mlp"], feat, cfg)), hc


# ------------------------------------------------------------------ critic
def critic_specs(cfg: NetConfig):
    d_in = cfg.obs_dim + cfg.action_dim
    return {"lstm": _lstm_specs(d_in, cfg.lstm_hidden),
            "mlp": _mlp_specs(cfg, cfg.lstm_hidden + d_in, 1)}


def critic_apply(p, obs, action, hidden, cfg: NetConfig):
    x = jnp.concatenate([obs, action], axis=-1)
    hc = lstm_step(p["lstm"], hidden, x)
    feat = jnp.concatenate([hc[0], x], axis=-1)
    return _mlp(p["mlp"], feat, cfg)[..., 0], hc


def init_actor_critic(key, cfg: NetConfig, n_critics: int = 1):
    ka, kc = jax.random.split(key)
    params = {"actor": init_params(actor_specs(cfg), ka)}
    for i in range(n_critics):
        params[f"critic{i}"] = init_params(
            critic_specs(cfg), jax.random.fold_in(kc, i))
    return params
