"""LITune — end-to-end automatic tuner for learned indexes (top-level API).

Training Stage (paper Part A): `LITune.pretrain` runs the Meta-RL pipeline
over synthetic tuning instances.
Online Tuning Stage (Part B/C): `LITune.tune` answers a tuning request on a
concrete (data, workload) with the ET-MDP-safe agent; `LITune.stream` runs
continuous tuning over data-shift windows through the O2 system.
"""
from __future__ import annotations

import dataclasses
import pickle

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import ddpg
from repro.core.ddpg import DDPGConfig
from repro.core.etmdp import ETMDPConfig, rollout_episode
from repro.core.maml import MetaConfig, meta_train
from repro.core.networks import NetConfig
from repro.core.o2 import O2Config, O2System
from repro.index import env as E


def attach_best_params(summary: dict, env_cfg: E.EnvConfig) -> dict:
    """Decode the best-runtime step's action into raw index parameters —
    the summary shape shared by `LITune.tune` and the batched
    `launch.serving.TuningService` (host-side decode: no device
    dispatches per request)."""
    best_t = int(np.argmin(summary["runtimes"]))
    return env_cfg.space.decode_np(np.asarray(summary["actions"][best_t]))


@dataclasses.dataclass(frozen=True)
class LITuneConfig:
    index_type: str = "alex"
    episode_len: int = 25
    lstm_hidden: int = 128
    mlp_hidden: int = 256
    ddpg: DDPGConfig = DDPGConfig()
    etmdp: ETMDPConfig = ETMDPConfig()
    meta: MetaConfig = MetaConfig()
    o2: O2Config = O2Config()
    safe_rl: bool = True      # False -> LITune w/o Safe-RL (ablation)
    use_o2: bool = True       # False -> frozen pretrained model (ablation)

    def env_cfg(self) -> E.EnvConfig:
        return E.EnvConfig(index_type=self.index_type,
                           episode_len=self.episode_len)

    def net_cfg(self) -> NetConfig:
        return NetConfig(obs_dim=E.obs_dim(),
                         action_dim=self.env_cfg().space.dim,
                         lstm_hidden=self.lstm_hidden,
                         mlp_hidden=self.mlp_hidden)

    def et_cfg(self) -> ETMDPConfig:
        return self.etmdp if self.safe_rl else \
            dataclasses.replace(self.etmdp, enabled=False)


class LITune:
    def __init__(self, cfg: LITuneConfig = LITuneConfig(), seed: int = 0):
        self.cfg = cfg
        self.key = jax.random.PRNGKey(seed)
        self.key, k = jax.random.split(self.key)
        self.state = ddpg.init_state(k, cfg.net_cfg(), cfg.ddpg)
        self.history: list = []
        self._o2: O2System | None = None

    # ---------------- Training Stage ----------------
    def pretrain(self, n_outer: int = 20, seed: int = 0, callback=None):
        self.key, k = jax.random.split(self.key)
        self.state, hist = meta_train(
            k, self.cfg.net_cfg(), self.cfg.ddpg, self.cfg.env_cfg(),
            self.cfg.et_cfg(), self.cfg.meta, n_outer=n_outer, seed=seed,
            callback=callback)
        self.history.extend(hist)
        return hist

    # ---------------- Online Tuning Stage ----------------
    def tune(self, data_keys, workload, wr_ratio: float,
             budget_steps: int | None = None, deterministic: bool = False):
        """One tuning request: returns best params found + episode summary."""
        env_cfg = self.cfg.env_cfg()
        if budget_steps is not None:
            env_cfg = env_cfg.with_episode_len(budget_steps)
        self.key, k = jax.random.split(self.key)
        summary = rollout_episode(
            k, self.state, self.cfg.net_cfg(), env_cfg, self.cfg.et_cfg(),
            data_keys, workload, wr_ratio,
            noise_scale=0.0 if deterministic else 0.05,
            deterministic=deterministic)
        summary["best_params"] = attach_best_params(summary, env_cfg)
        return summary

    def tune_many(self, instances, slots: int = 4,
                  deterministic: bool = False, budget_steps: int | None = None):
        """Serve many tuning requests through the slot-batched
        `launch.serving.TuningService` (multi-tenant `tune`).

        `instances` is an iterable of `(data_keys, workload, wr_ratio)`
        tuples; returns summaries in submission order.
        """
        from repro.launch.serving import ServeConfig, TuningService
        # advance our PRNG so repeated tune_many calls explore differently,
        # matching tune()'s per-request key splitting
        self.key, k = jax.random.split(self.key)
        service = TuningService(self, config=ServeConfig(
            slots=slots,
            # any budget tune() accepts must fit the service horizon too
            horizon_cap=max(256, budget_steps or self.cfg.episode_len),
            seed=int(np.asarray(jax.random.key_data(k))[-1])))
        rids = [service.submit(data, workload, wr,
                               budget_steps=budget_steps,
                               deterministic=deterministic)
                for data, workload, wr in instances]
        results = service.run()
        return [results[rid] for rid in rids]

    def stream(self, windows, max_steps_per_window: int = 5,
               via_service: bool = False):
        """Continuous tuning over an iterable of
        (idx, data_keys, workload, wr_ratio) windows via the O2 system.

        With ``via_service=True`` the same stream is served through the
        batched `TuningService` with O2 enabled (one slot): same swap
        decisions as the serial loop, but on the engine that also serves
        concurrent tenants (see launch/serving/)."""
        if via_service:
            if not self.cfg.use_o2:
                raise ValueError(
                    "stream(via_service=True) serves the O2 system; the "
                    "use_o2=False ablation only runs the serial path")
            return self._stream_via_service(windows, max_steps_per_window)
        if self._o2 is None or not self.cfg.use_o2:
            self._o2 = O2System(self.state, self.cfg.net_cfg(), self.cfg.ddpg,
                                self.cfg.env_cfg(), self.cfg.et_cfg(),
                                self.cfg.o2)
        results = []
        for w, data, workload, wr in windows:
            self.key, k = jax.random.split(self.key)
            if self.cfg.use_o2:
                res = self._o2.tune_window(k, data, workload, wr,
                                           max_steps=max_steps_per_window)
            else:  # ablation: frozen pretrained model, no O2
                env_cfg = self.cfg.env_cfg().with_episode_len(
                    max_steps_per_window)
                res = rollout_episode(k, self.state, self.cfg.net_cfg(),
                                      env_cfg, self.cfg.et_cfg(), data,
                                      workload, wr, noise_scale=0.02)
            res["window"] = w
            results.append(res)
        if self.cfg.use_o2 and self._o2 is not None:
            self.state = self._o2.online  # keep the improved model
        return results

    def _stream_via_service(self, windows, max_steps: int):
        """O2 window stream through the batched serving engine."""
        from repro.launch.serving import (O2ServiceConfig, ServeConfig,
                                          TuningService)
        service = TuningService(self, config=ServeConfig(
            slots=1, horizon_cap=max(256, max_steps),
            o2=O2ServiceConfig(enabled=True, o2=self.cfg.o2,
                               strict_order=True)))
        rids, widx = [], []
        for w, data, workload, wr in windows:
            # same per-window key draws as the serial stream above
            self.key, k = jax.random.split(self.key)
            rids.append(service.submit(data, workload, wr,
                                       budget_steps=max_steps, key=k,
                                       noise_scale=0.02))
            widx.append(w)
        results = service.run()
        # settle any trailing O2 work (strict order drains verdicts
        # inline, but the offline learner's last round may still be
        # executing on the annex)
        service.flush_o2()
        out = []
        for w, rid in zip(widx, rids):
            res = results[rid]
            res["window"] = w
            out.append(res)
        # keep the improved (possibly hot-swapped) model
        self.state = service.tenants[self.cfg.index_type].online
        return out

    # ---------------- persistence ----------------
    def save(self, path: str):
        blob = {"cfg": self.cfg,
                "state": jax.tree.map(np.asarray, self.state)}
        with open(path, "wb") as f:
            pickle.dump(blob, f)

    @classmethod
    def load(cls, path: str) -> "LITune":
        with open(path, "rb") as f:
            blob = pickle.load(f)
        self = cls(blob["cfg"])
        self.state = jax.tree.map(jnp.asarray, blob["state"])
        return self
