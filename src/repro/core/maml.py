"""Adaptive (Meta-RL) training pipeline — paper §3.3.2.

Tuning instances (tasks) are (data distribution, W/R ratio, drift) triples.
Inner loop: instance-specific DDPG updates from the meta-initialization;
outer loop: first-order meta-update (FOMAML, with Reptile as an option) of
the initialization across instances.  Example 3.1's promise is exactly what
tests/test_meta.py checks: the meta-init adapts to a held-out instance in
fewer gradient steps than a scratch init.
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import ddpg
from repro.core.ddpg import DDPGConfig
from repro.core.etmdp import ETMDPConfig, rollout_episode
from repro.core.networks import NetConfig
from repro.core.replay import SequenceReplay
from repro.index import env as E
from repro.index.workloads import DATASETS, sample_keys, wr_workload


@dataclasses.dataclass(frozen=True)
class TaskSpec:
    dist: str = "mix"
    wr_ratio: float = 1.0
    drift: float = 0.0
    n_keys: int = 4096
    n_queries: int = 4096
    seed: int = 0


@dataclasses.dataclass(frozen=True)
class MetaConfig:
    meta_batch: int = 4            # tasks per outer iteration
    inner_episodes: int = 2        # rollouts per task before adapting
    inner_updates: int = 8         # gradient steps per task
    outer_lr: float = 0.5          # Reptile/FOMAML interpolation
    mode: str = "fomaml"           # fomaml | reptile
    replay_capacity: int = 4096


def sample_task(rng: np.random.Generator) -> TaskSpec:
    return TaskSpec(
        dist=str(rng.choice(list(DATASETS))),
        wr_ratio=float(np.exp(rng.uniform(np.log(0.1), np.log(10.0)))),
        drift=float(rng.uniform(0.0, 0.3)),
        seed=int(rng.integers(0, 2**31 - 1)),
    )


def make_task_env(task: TaskSpec):
    key = jax.random.PRNGKey(task.seed)
    k1, k2 = jax.random.split(key)
    data = sample_keys(k1, task.n_keys, task.dist, shift=task.drift)
    workload, _ = wr_workload(k2, data, task.wr_ratio, total=task.n_queries,
                              dist=task.dist, drift=task.drift)
    return data, workload


def inner_adapt(key, meta_state, task: TaskSpec, net_cfg: NetConfig,
                ddpg_cfg: DDPGConfig, env_cfg: E.EnvConfig,
                et_cfg: ETMDPConfig, meta_cfg: MetaConfig):
    """Instance-specific adaptation from the meta-init. Returns
    (adapted_state, stats)."""
    data, workload = make_task_env(task)
    replay = SequenceReplay(meta_cfg.replay_capacity, E.obs_dim(),
                            env_cfg.space.dim, net_cfg.lstm_hidden,
                            seq_len=ddpg_cfg.seq_len, seed=task.seed & 0xffff)
    state = jax.tree.map(lambda x: x, meta_state)  # copy
    stats = {"returns": [], "violations": 0.0, "best_runtime": []}
    for ep in range(meta_cfg.inner_episodes):
        key, k = jax.random.split(key)
        summary = rollout_episode(k, state, net_cfg, env_cfg, et_cfg,
                                  data, workload, task.wr_ratio,
                                  noise_scale=ddpg_cfg.noise_scale,
                                  replay=replay)
        stats["returns"].append(summary["episode_return"])
        stats["violations"] += summary["violations"]
        stats["best_runtime"].append(summary["best_runtime_ns"])
    for _ in range(meta_cfg.inner_updates):
        batch = replay.sample_sequences(ddpg_cfg.batch_size)
        if batch is None:
            break
        batch = jax.tree.map(jnp.asarray, batch)
        state, _ = ddpg.update(state, batch, net_cfg, ddpg_cfg)
    return state, stats


def outer_update(meta_state, adapted_states, meta_cfg: MetaConfig):
    """FOMAML/Reptile meta-update of the network parameters (and targets)."""
    def avg(paths):
        stacked = jax.tree.map(lambda *xs: jnp.stack(xs).mean(0), *paths)
        return stacked

    adapted_params = avg([s["params"] for s in adapted_states])
    adapted_targets = avg([s["targets"] for s in adapted_states])
    lr = meta_cfg.outer_lr
    interp = lambda old, new: jax.tree.map(
        lambda o, n: o + lr * (n - o), old, new)
    new_state = dict(meta_state)
    new_state["params"] = interp(meta_state["params"], adapted_params)
    new_state["targets"] = interp(meta_state["targets"], adapted_targets)
    return new_state


def meta_train(key, net_cfg: NetConfig, ddpg_cfg: DDPGConfig,
               env_cfg: E.EnvConfig, et_cfg: ETMDPConfig,
               meta_cfg: MetaConfig, n_outer: int = 20, seed: int = 0,
               log_every: int = 5, callback=None):
    """Full meta-training loop. Returns (meta_state, history)."""
    rng = np.random.default_rng(seed)
    meta_state = ddpg.init_state(key, net_cfg, ddpg_cfg)
    history = []
    for it in range(n_outer):
        adapted, all_stats = [], []
        for b in range(meta_cfg.meta_batch):
            key, k = jax.random.split(key)
            task = sample_task(rng)
            st, stats = inner_adapt(k, meta_state, task, net_cfg, ddpg_cfg,
                                    env_cfg, et_cfg, meta_cfg)
            adapted.append(st)
            all_stats.append(stats)
        meta_state = outer_update(meta_state, adapted, meta_cfg)
        rec = {
            "iter": it,
            "mean_return": float(np.mean(
                [np.mean(s["returns"]) for s in all_stats])),
            "violations": float(np.sum(
                [s["violations"] for s in all_stats])),
            "best_runtime": float(np.mean(
                [np.min(s["best_runtime"]) for s in all_stats])),
        }
        history.append(rec)
        if callback:
            callback(rec)
    return meta_state, history
