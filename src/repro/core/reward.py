"""The paper's tuning-oriented reward (§4.1), verbatim.

Delta_{t->0}   = (-R_t + R_0) / R_0
Delta_{t->t-1} = (-R_t + R_{t-1}) / R_{t-1}

r = ((1+D_t0)^2 - 1)^omega * (1+D_tt1)^kappa      if D_t0 > 0
  = -((1-D_t0)^2 - 1)^omega * (1-D_tt1)^kappa     if D_t0 <= 0

with omega odd (default 1) and kappa even (default 2).  R is end-to-end
runtime (lower is better), optionally a user mix of latency/throughput.
"""
from __future__ import annotations

import jax.numpy as jnp


def deltas(r_t, r_0, r_prev):
    d_t0 = (-r_t + r_0) / jnp.maximum(r_0, 1e-9)
    d_tt1 = (-r_t + r_prev) / jnp.maximum(r_prev, 1e-9)
    return d_t0, d_tt1


def reward(r_t, r_0, r_prev, omega: int = 1, kappa: int = 2):
    assert omega % 2 == 1 and kappa % 2 == 0, "omega odd, kappa even (paper)"
    d_t0, d_tt1 = deltas(r_t, r_0, r_prev)
    pos = ((1.0 + d_t0) ** 2 - 1.0) ** omega * (1.0 + d_tt1) ** kappa
    neg = -(((1.0 - d_t0) ** 2 - 1.0) ** omega) * (1.0 - d_tt1) ** kappa
    return jnp.where(d_t0 > 0, pos, neg)


def performance_metric(latency_ns, throughput_ops=None, w_latency: float = 1.0):
    """User-steerable R (paper: e.g. R = 0.8*latency + 0.2/throughput)."""
    r = w_latency * latency_ns
    if throughput_ops is not None:
        r = r + (1.0 - w_latency) / jnp.maximum(throughput_ops, 1e-9)
    return r
