"""ET-MDP: Early-Terminated MDP wrapper (paper §4.2, Def. 4.1/4.2).

The CMDP (S, A, H, r, c, C, T) is transformed into an unconstrained MDP with
an absorbing state s_e: when the running cost b_t = sum(c^m_tau + c^r_tau)
exceeds the budget C, the episode transitions to s_e with termination reward
r_e and stays there.  Solved by the DDPG+LSTM backbone (the LSTM is the
context model that generalizes safety across tasks).
"""
from __future__ import annotations

import dataclasses

import numpy as np
import jax
import jax.numpy as jnp

from repro.core import ddpg, networks as nets
from repro.index import env as E


@dataclasses.dataclass(frozen=True)
class ETMDPConfig:
    cost_budget: float = 1.0        # C: tolerated failures per episode
    termination_reward: float = -1.0  # r_e (small, per the paper)
    enabled: bool = True            # False -> plain (unsafe) episodes


def rollout_episode(key, agent_state, net_cfg, env_cfg: E.EnvConfig,
                    et_cfg: ETMDPConfig, data_keys, workload, wr_ratio,
                    noise_scale: float = 0.1, replay=None,
                    deterministic: bool = False):
    """Run one tuning episode under the ET-MDP.

    Returns a summary dict (episode return, best runtime, violations,
    terminated-early flag, params history).  Transitions are pushed into
    `replay` when provided.
    """
    env_state, obs = E.reset(env_cfg, data_keys, workload, wr_ratio)
    hidden_a = nets.zero_hidden(net_cfg)
    hidden_q = nets.zero_hidden(net_cfg)
    params = agent_state["params"]

    total_r, best_rt, violations = 0.0, float(env_state["r_best"]), 0.0
    terminated = False
    runtimes, actions = [], []
    b_t = 0.0
    for t in range(env_cfg.episode_len):
        key, k_act = jax.random.split(key)
        action, new_hidden_a = ddpg.act(params, obs, hidden_a, k_act, net_cfg,
                                        noise_scale=noise_scale,
                                        deterministic=deterministic)
        # critic hidden advances on (obs, action) for stored-state replay
        _, new_hidden_q = nets.critic_apply(params["critic0"], obs, action,
                                            hidden_q, net_cfg)
        env_state, next_obs, r, done, info = E.step(env_cfg, env_state, action)
        cost = float(info["cost"])
        b_t += cost
        violations += cost
        early = et_cfg.enabled and b_t > et_cfg.cost_budget
        r_val = float(r) if not early else et_cfg.termination_reward
        next_obs_eff = jnp.zeros_like(next_obs) if early else next_obs
        done_flag = bool(done) or early

        if replay is not None:
            replay.add(np.asarray(obs), np.asarray(action), r_val,
                       np.asarray(next_obs_eff), float(done_flag), cost,
                       (np.asarray(hidden_a[0]), np.asarray(hidden_a[1])),
                       (np.asarray(hidden_q[0]), np.asarray(hidden_q[1])))
        total_r += r_val
        best_rt = min(best_rt, float(info["runtime_ns"]))
        runtimes.append(float(info["runtime_ns"]))
        actions.append(np.asarray(action))
        obs, hidden_a, hidden_q = next_obs_eff, new_hidden_a, new_hidden_q
        if early:
            terminated = True
            break
        if done_flag:
            break
    return {
        "episode_return": total_r,
        "best_runtime_ns": best_rt,
        "r0_ns": float(env_state["r0"]),
        "violations": violations,
        "terminated_early": terminated,
        "runtimes": runtimes,
        "actions": actions,
        "steps": len(runtimes),
    }
