"""ET-MDP: Early-Terminated MDP wrapper (paper §4.2, Def. 4.1/4.2).

The CMDP (S, A, H, r, c, C, T) is transformed into an unconstrained MDP with
an absorbing state s_e: when the running cost b_t = sum(c^m_tau + c^r_tau)
exceeds the budget C, the episode transitions to s_e with termination reward
r_e and stays there.  Solved by the DDPG+LSTM backbone (the LSTM is the
context model that generalizes safety across tasks).

The per-step computation (policy act -> critic hidden advance -> env step ->
ET-MDP bookkeeping) lives in one pure core, `_episode_step_core`, shared by
both execution paths:

  * `episode_step`          — jitted, unbatched: drives the serial
                              `rollout_episode` (one request at a time);
  * `batched_episode_step`  — jitted `lax.map` over a carry with a leading
                              slot axis (one service tick);
  * `batched_episode_scan`  — `lax.scan` over K ticks of the map body:
                              drives the multi-tenant
                              `launch/tune_serve.TuningService`.

Because the batched paths map the *same* traced program per slot, a slot in
a B-wide service step produces bitwise-identical rewards/runtimes/actions
to a serial episode started from the same PRNG key
(tests/test_tune_service.py asserts this).
"""
from __future__ import annotations

import dataclasses
from functools import partial

import numpy as np
import jax
import jax.numpy as jnp

from repro.core import ddpg, networks as nets
from repro.index import env as E


@dataclasses.dataclass(frozen=True)
class ETMDPConfig:
    cost_budget: float = 1.0        # C: tolerated failures per episode
    termination_reward: float = -1.0  # r_e (small, per the paper)
    enabled: bool = True            # False -> plain (unsafe) episodes


# ------------------------------------------------------------------ carry
def init_episode_carry(key, env_state, obs, net_cfg, batch_shape=()):
    """The per-episode recurrent state threaded through `episode_step`."""
    return {
        "key": key,
        "env": env_state,
        "obs": obs,
        "h_a": nets.zero_hidden(net_cfg, batch_shape),
        "h_q": nets.zero_hidden(net_cfg, batch_shape),
        "b_t": jnp.zeros(batch_shape, jnp.float32),
    }


def _episode_step_core(params, carry, noise_scale, net_cfg,
                       env_cfg: E.EnvConfig, et_cfg: ETMDPConfig,
                       deterministic: bool):
    """One ET-MDP step for a single episode (unbatched carry).

    Returns (carry', outputs).  `outputs["reward"]` is the ET-MDP reward
    (termination reward substituted on early exit), `outputs["early"]` the
    budget-exceeded flag, `outputs["done"]` early-or-horizon.
    """
    key, k_act = jax.random.split(carry["key"])
    action, h_a2 = ddpg.act(params, carry["obs"], carry["h_a"], k_act,
                            net_cfg, noise_scale=noise_scale,
                            deterministic=deterministic)
    # critic hidden advances on (obs, action) for stored-state replay
    _, h_q2 = nets.critic_apply(params["critic0"], carry["obs"], action,
                                carry["h_q"], net_cfg)
    env2, next_obs, r, done, info = E.step_core(env_cfg, carry["env"], action)
    cost = info["cost"]
    b_t = carry["b_t"] + cost
    if et_cfg.enabled:
        early = b_t > et_cfg.cost_budget
    else:
        early = jnp.zeros_like(done)
    r_val = jnp.where(early, jnp.float32(et_cfg.termination_reward), r)
    next_obs_eff = jnp.where(early, jnp.zeros_like(next_obs), next_obs)
    done_flag = done | early
    new_carry = {"key": key, "env": env2, "obs": next_obs_eff,
                 "h_a": h_a2, "h_q": h_q2, "b_t": b_t}
    outputs = {"action": action, "reward": r_val, "raw_reward": r,
               "runtime_ns": info["runtime_ns"], "cost": cost,
               "early": early, "done": done_flag,
               "memory_bytes": info["memory_bytes"],
               # the transition view, for replay ingestion off the batched
               # paths: pre-step obs/hiddens + the post-step observation
               # (zeroed on early exit, the absorbing state s_e) — exactly
               # what the serial `rollout_episode` pushes into replay
               "obs": carry["obs"], "next_obs": next_obs_eff,
               "h_a": carry["h_a"], "h_q": carry["h_q"]}
    return new_carry, outputs


@partial(jax.jit, static_argnames=("net_cfg", "env_cfg", "et_cfg",
                                   "deterministic"))
def episode_step(params, carry, noise_scale, net_cfg, env_cfg: E.EnvConfig,
                 et_cfg: ETMDPConfig, deterministic: bool = False):
    """Jitted single-episode step (the serial tuning path)."""
    return _episode_step_core(params, carry, noise_scale, net_cfg, env_cfg,
                              et_cfg, deterministic)


def batched_episode_core(params, carry, noise_scale, net_cfg,
                         env_cfg: E.EnvConfig, et_cfg: ETMDPConfig,
                         deterministic: bool = False):
    """One step for B concurrent episodes (un-jitted core): `carry` has a
    leading slot axis on every leaf, `noise_scale` is [B] (per-request
    exploration).  The policy parameters are shared across slots.

    `lax.map` rather than `vmap` on purpose: the map body is the *same
    unbatched program* as the serial `episode_step`, so per-slot results
    are bitwise identical to the serial path at any slot count — a vmapped
    GEMM changes its reduction lowering with batch width and drifts by an
    ulp, which the carmi runtime model (continuous in the action) amplifies
    into observable divergence.  The batching win on the serving path is
    dispatch amortization plus slot-sharding over host devices
    (launch/tune_serve.py), both of which the map keeps.

    Note the program is independent of `env_cfg.episode_len` except for the
    env-internal horizon flag — the serving loop enforces per-request
    budgets host-side, so heterogeneous budgets share one executable.
    """
    return jax.lax.map(
        lambda cn: _episode_step_core(params, cn[0], cn[1], net_cfg,
                                      env_cfg, et_cfg, deterministic),
        (carry, noise_scale))


batched_episode_step = partial(jax.jit, static_argnames=(
    "net_cfg", "env_cfg", "et_cfg", "deterministic"))(batched_episode_core)


def batched_episode_scan(params, carry, noise_scale, n_steps: int, net_cfg,
                         env_cfg: E.EnvConfig, et_cfg: ETMDPConfig,
                         deterministic: bool = False):
    """`n_steps` ticks of `batched_episode_core` under one `lax.scan`
    (un-jitted; the tuning service wraps it in shard_map+jit).  Outputs
    are stacked [n_steps, B, ...].

    The scan body is the *whole* one-tick map program, so each step's
    per-slot math is the proven-bitwise body — scanning the unbatched core
    per slot instead (map-of-scan) refuses XLA the same lowering and
    drifts by an ulp.
    """
    def body(c, _):
        return batched_episode_core(params, c, noise_scale, net_cfg,
                                    env_cfg, et_cfg, deterministic)
    return jax.lax.scan(body, carry, None, length=n_steps)


def batched_episode_core_lanes(params_lanes, carry, noise_scale, net_cfg,
                               env_cfg: E.EnvConfig, et_cfg: ETMDPConfig,
                               deterministic: bool = False):
    """One step for B concurrent episodes with **per-lane** policy params:
    every leaf of `params_lanes` carries a leading slot axis, so each lane
    may serve a different parameter version — the mixed pool a canary swap
    creates (`launch/serving`).  The map body is the *same* unbatched
    program as `batched_episode_core`'s (params ride the mapped operand
    tuple instead of the closure), so a lane whose params equal the shared
    tree produces bitwise-identical outputs to the shared-params program —
    control lanes are untouched by the canary next door.
    """
    return jax.lax.map(
        lambda pcn: _episode_step_core(pcn[0], pcn[1], pcn[2], net_cfg,
                                       env_cfg, et_cfg, deterministic),
        (params_lanes, carry, noise_scale))


def batched_episode_scan_lanes(params_lanes, carry, noise_scale,
                               n_steps: int, net_cfg,
                               env_cfg: E.EnvConfig, et_cfg: ETMDPConfig,
                               deterministic: bool = False):
    """`n_steps` ticks of `batched_episode_core_lanes` under one
    `lax.scan` — the per-lane-params twin of `batched_episode_scan`, with
    the same whole-tick-map scan body (see that docstring for why the
    lowering order matters for bitwise parity)."""
    def body(c, _):
        return batched_episode_core_lanes(params_lanes, c, noise_scale,
                                          net_cfg, env_cfg, et_cfg,
                                          deterministic)
    return jax.lax.scan(body, carry, None, length=n_steps)


def transition_view(outputs: dict) -> dict:
    """The replay-facing slice of a step's outputs, keyed like the
    sequence-replay ring's wide fields (`core.replay.WIDE_FIELDS`):
    pre-step observation and LSTM hiddens plus the post-step observation
    (zeroed on early exit — the absorbing state s_e).  Works on single
    steps, `[K, B, ...]` tick stacks, anything the step core emitted —
    it's a pure re-keying, so the serving path's device-resident capture
    ingests exactly what the serial `rollout_episode` pushes into replay.
    """
    return {"obs": outputs["obs"], "next_obs": outputs["next_obs"],
            "h_a": outputs["h_a"][0], "c_a": outputs["h_a"][1],
            "h_q": outputs["h_q"][0], "c_q": outputs["h_q"][1]}


# jitted reset shared by the serial and batched paths (slot admission
# resets exactly one episode, so the unbatched program is reused there)
reset_episode = jax.jit(E.reset, static_argnames=("cfg",))


def rollout_episode(key, agent_state, net_cfg, env_cfg: E.EnvConfig,
                    et_cfg: ETMDPConfig, data_keys, workload, wr_ratio,
                    noise_scale: float = 0.1, replay=None,
                    deterministic: bool = False):
    """Run one tuning episode under the ET-MDP.

    Returns a summary dict (episode return, best runtime, violations,
    terminated-early flag, params history).  Transitions are pushed into
    `replay` when provided.
    """
    env_state, obs = reset_episode(env_cfg, data_keys, workload, wr_ratio)
    carry = init_episode_carry(key, env_state, obs, net_cfg)
    params = agent_state["params"]

    total_r, best_rt, violations = 0.0, float(env_state["r_best"]), 0.0
    terminated = False
    runtimes, actions = [], []
    for t in range(env_cfg.episode_len):
        carry, out = episode_step(params, carry, noise_scale, net_cfg,
                                  env_cfg, et_cfg,
                                  deterministic=deterministic)
        cost = float(out["cost"])
        violations += cost
        r_val = float(out["reward"])
        early = bool(out["early"])
        done_flag = bool(out["done"])

        if replay is not None:
            # the step emits its own transition view (pre-step obs/hiddens,
            # post-step next_obs) — the same fields the batched serving
            # path captures per slot
            replay.add(np.asarray(out["obs"]), np.asarray(out["action"]),
                       r_val, np.asarray(out["next_obs"]), float(done_flag),
                       cost,
                       (np.asarray(out["h_a"][0]), np.asarray(out["h_a"][1])),
                       (np.asarray(out["h_q"][0]), np.asarray(out["h_q"][1])))
        total_r += r_val
        best_rt = min(best_rt, float(out["runtime_ns"]))
        runtimes.append(float(out["runtime_ns"]))
        actions.append(np.asarray(out["action"]))
        if early:
            terminated = True
            break
        if done_flag:
            break
    return {
        "episode_return": total_r,
        "best_runtime_ns": best_rt,
        "r0_ns": float(carry["env"]["r0"]),
        "violations": violations,
        "terminated_early": terminated,
        "runtimes": runtimes,
        "actions": actions,
        "steps": len(runtimes),
    }
