"""Sequence replay buffer for the LSTM-context DDPG (R2D2-style stored
hidden states).  Numpy ring buffer on host; batches ship to device per
update.  Sequences never cross episode boundaries."""
from __future__ import annotations

import numpy as np


class SequenceReplay:
    def __init__(self, capacity: int, obs_dim: int, action_dim: int,
                 lstm_hidden: int, seq_len: int = 8, seed: int = 0):
        self.capacity = capacity
        self.seq_len = seq_len
        self.rng = np.random.default_rng(seed)
        self.size = 0
        self.ptr = 0
        f32 = np.float32
        self.obs = np.zeros((capacity, obs_dim), f32)
        self.action = np.zeros((capacity, action_dim), f32)
        self.reward = np.zeros((capacity,), f32)
        self.next_obs = np.zeros((capacity, obs_dim), f32)
        self.done = np.zeros((capacity,), f32)
        self.cost = np.zeros((capacity,), f32)
        self.h_a = np.zeros((capacity, lstm_hidden), f32)
        self.c_a = np.zeros((capacity, lstm_hidden), f32)
        self.h_q = np.zeros((capacity, lstm_hidden), f32)
        self.c_q = np.zeros((capacity, lstm_hidden), f32)
        self.step_left = np.zeros((capacity,), np.int32)  # steps to ep end

    def add(self, obs, action, reward, next_obs, done, cost,
            actor_hidden, critic_hidden):
        i = self.ptr
        self.obs[i] = obs
        self.action[i] = action
        self.reward[i] = reward
        self.next_obs[i] = next_obs
        self.done[i] = done
        self.cost[i] = cost
        self.h_a[i], self.c_a[i] = actor_hidden
        self.h_q[i], self.c_q[i] = critic_hidden
        self.step_left[i] = 0
        # back-fill steps-to-end for the finished episode
        if done:
            j = i
            count = 0
            while True:
                self.step_left[j] = count
                count += 1
                j = (j - 1) % self.capacity
                if count >= self.size + 1 or self.done[j] or count > 10_000:
                    break
        self.ptr = (self.ptr + 1) % self.capacity
        self.size = min(self.size + 1, self.capacity)

    def add_episode(self, obs, action, reward, next_obs, done, cost,
                    actor_hidden, critic_hidden):
        """Ingest one complete episode in a single batched ring write.

        All arguments are time-major ``[T, ...]`` arrays (hiddens are
        ``(h, c)`` pairs of ``[T, hidden]``), e.g. the stacked per-slot
        outputs of a `batched_episode_scan` tick.  Equivalent to T
        sequential `add` calls — same contents, pointer, size, and
        `step_left` back-fill — but with one slice assignment per field
        instead of T scalar writes, which is what lets the tuning service
        stream retired episodes into replay between ticks.
        """
        T = int(np.shape(reward)[0])
        if T == 0:
            return
        if T > self.capacity:
            raise ValueError(f"episode of {T} steps exceeds replay "
                             f"capacity {self.capacity}")
        ptr0, size0 = self.ptr, self.size
        idx = (ptr0 + np.arange(T)) % self.capacity
        self.obs[idx] = obs
        self.action[idx] = action
        self.reward[idx] = reward
        self.next_obs[idx] = next_obs
        self.done[idx] = done
        self.cost[idx] = cost
        self.h_a[idx], self.c_a[idx] = actor_hidden
        self.h_q[idx], self.c_q[idx] = critic_hidden
        self.step_left[idx] = 0
        for t in np.flatnonzero(np.asarray(done)):
            # the same back-fill walk `add` runs at its done step, with the
            # buffer size it would have seen at that point
            size_t = min(size0 + int(t), self.capacity)
            j, count = int(idx[t]), 0
            while True:
                self.step_left[j] = count
                count += 1
                j = (j - 1) % self.capacity
                if count >= size_t + 1 or self.done[j] or count > 10_000:
                    break
        self.ptr = (ptr0 + T) % self.capacity
        self.size = min(size0 + T, self.capacity)

    def _valid_starts(self):
        idx = np.arange(self.size)
        # a window [i, i+L) is valid if no done before its last element and
        # the whole window has been written
        ok = np.ones(self.size, bool)
        for off in range(self.seq_len - 1):
            j = (idx + off) % self.capacity
            ok &= (j < self.size)
            if off < self.seq_len - 1:
                ok &= (self.done[j] == 0) | (off == self.seq_len - 1)
        # exclude windows that wrap over the write pointer
        if self.size == self.capacity:
            dist = (self.ptr - idx) % self.capacity
            ok &= dist >= self.seq_len
        return idx[ok]

    def sample_sequences(self, batch: int):
        starts = self._valid_starts()
        if len(starts) == 0:
            return None
        sel = self.rng.choice(starts, size=batch, replace=True)
        L = self.seq_len
        gather = lambda arr: np.stack(
            [arr[(s + np.arange(L)) % self.capacity] for s in sel])
        return {
            "obs": gather(self.obs), "action": gather(self.action),
            "reward": gather(self.reward), "next_obs": gather(self.next_obs),
            "done": gather(self.done), "cost": gather(self.cost),
            "h_a": self.h_a[sel], "c_a": self.c_a[sel],
            "h_q": self.h_q[sel], "c_q": self.c_q[sel],
        }

    def sample_steps(self, batch: int):
        """Plain transition batch (for the vanilla DDPG baseline)."""
        if self.size == 0:
            return None
        sel = self.rng.integers(0, self.size, size=batch)
        return {
            "obs": self.obs[sel], "action": self.action[sel],
            "reward": self.reward[sel], "next_obs": self.next_obs[sel],
            "done": self.done[sel], "cost": self.cost[sel],
            "h_a": self.h_a[sel], "c_a": self.c_a[sel],
            "h_q": self.h_q[sel], "c_q": self.c_q[sel],
        }
