"""Sequence replay buffers for the LSTM-context DDPG (R2D2-style stored
hidden states).  Sequences never cross episode boundaries.

Two storage layouts share one ring/backfill/sampling discipline:

  * `SequenceReplay`       — numpy ring on host; batches ship to device
                             per update.  The serial O2 loop writes it one
                             transition at a time (`add`) or one episode
                             at a time (`add_episode`).
  * `DeviceSequenceReplay` — the serving-path variant: the wide per-step
                             fields (obs / next_obs / LSTM hiddens) live
                             in device ring buffers fed directly from the
                             tick program's outputs, so O2 transition
                             capture never round-trips them through the
                             host.  The narrow fields the serving loop
                             already fetches per tick (action / reward /
                             done / cost) stay host-side, which keeps the
                             `step_left` back-fill walk and valid-start
                             bookkeeping pure numpy.  Ring contents are
                             bitwise identical to a `SequenceReplay` fed
                             the same episodes (tests/test_o2_service.py),
                             and `sample_sequences` draws the same RNG
                             sequence, so offline fine-tuning consumes
                             identical batches on either layout.
"""
from __future__ import annotations

from functools import lru_cache

import numpy as np


class SequenceReplay:
    def __init__(self, capacity: int, obs_dim: int, action_dim: int,
                 lstm_hidden: int, seq_len: int = 8, seed: int = 0):
        self.capacity = capacity
        self.obs_dim = obs_dim
        self.action_dim = action_dim
        self.lstm_hidden = lstm_hidden
        self.seq_len = seq_len
        self.rng = np.random.default_rng(seed)
        self.size = 0
        self.ptr = 0
        self._alloc()

    def _alloc(self):
        capacity, f32 = self.capacity, np.float32
        self.obs = np.zeros((capacity, self.obs_dim), f32)
        self.action = np.zeros((capacity, self.action_dim), f32)
        self.reward = np.zeros((capacity,), f32)
        self.next_obs = np.zeros((capacity, self.obs_dim), f32)
        self.done = np.zeros((capacity,), f32)
        self.cost = np.zeros((capacity,), f32)
        self.h_a = np.zeros((capacity, self.lstm_hidden), f32)
        self.c_a = np.zeros((capacity, self.lstm_hidden), f32)
        self.h_q = np.zeros((capacity, self.lstm_hidden), f32)
        self.c_q = np.zeros((capacity, self.lstm_hidden), f32)
        self.step_left = np.zeros((capacity,), np.int32)  # steps to ep end

    def add(self, obs, action, reward, next_obs, done, cost,
            actor_hidden, critic_hidden):
        i = self.ptr
        self.obs[i] = obs
        self.action[i] = action
        self.reward[i] = reward
        self.next_obs[i] = next_obs
        self.done[i] = done
        self.cost[i] = cost
        self.h_a[i], self.c_a[i] = actor_hidden
        self.h_q[i], self.c_q[i] = critic_hidden
        self.step_left[i] = 0
        # back-fill steps-to-end for the finished episode
        if done:
            self._backfill(i, self.size)
        self.ptr = (self.ptr + 1) % self.capacity
        self.size = min(self.size + 1, self.capacity)

    # ------------------------------------------------- shared ring helpers
    def _ring_indices(self, T: int) -> np.ndarray:
        return (self.ptr + np.arange(T)) % self.capacity

    def _backfill(self, j: int, size_at_done: int):
        """The steps-to-end walk `add` runs at a done step: walk backward
        from `j` setting step_left until the previous episode's done (or
        the buffer edge as of `size_at_done`)."""
        count = 0
        while True:
            self.step_left[j] = count
            count += 1
            j = (j - 1) % self.capacity
            if count >= size_at_done + 1 or self.done[j] or count > 10_000:
                break

    def _write_narrow_and_advance(self, idx: np.ndarray, action, reward,
                                  done, cost):
        """Batched write of the host-side narrow fields + `step_left`
        back-fill + pointer/size advance — the episode-ingestion tail both
        storage layouts share (`idx` from `_ring_indices`, pre-advance)."""
        T = len(idx)
        ptr0, size0 = self.ptr, self.size
        self.action[idx] = action
        self.reward[idx] = reward
        self.done[idx] = done
        self.cost[idx] = cost
        self.step_left[idx] = 0
        for t in np.flatnonzero(np.asarray(done)):
            # the same back-fill walk `add` runs at its done step, with the
            # buffer size it would have seen at that point
            self._backfill(int(idx[t]), min(size0 + int(t), self.capacity))
        self.ptr = (ptr0 + T) % self.capacity
        self.size = min(size0 + T, self.capacity)

    def add_episode(self, obs, action, reward, next_obs, done, cost,
                    actor_hidden, critic_hidden):
        """Ingest one complete episode in a single batched ring write.

        All arguments are time-major ``[T, ...]`` arrays (hiddens are
        ``(h, c)`` pairs of ``[T, hidden]``), e.g. the stacked per-slot
        outputs of a `batched_episode_scan` tick.  Equivalent to T
        sequential `add` calls — same contents, pointer, size, and
        `step_left` back-fill — but with one slice assignment per field
        instead of T scalar writes, which is what lets the tuning service
        stream retired episodes into replay between ticks.
        """
        T = int(np.shape(reward)[0])
        if T == 0:
            return
        if T > self.capacity:
            raise ValueError(f"episode of {T} steps exceeds replay "
                             f"capacity {self.capacity}")
        idx = self._ring_indices(T)
        self.obs[idx] = obs
        self.next_obs[idx] = next_obs
        self.h_a[idx], self.c_a[idx] = actor_hidden
        self.h_q[idx], self.c_q[idx] = critic_hidden
        self._write_narrow_and_advance(idx, action, reward, done, cost)

    def _valid_starts(self):
        idx = np.arange(self.size)
        # a window [i, i+L) is valid if no done before its last element and
        # the whole window has been written
        ok = np.ones(self.size, bool)
        for off in range(self.seq_len - 1):
            j = (idx + off) % self.capacity
            ok &= (j < self.size)
            if off < self.seq_len - 1:
                ok &= (self.done[j] == 0) | (off == self.seq_len - 1)
        # exclude windows that wrap over the write pointer
        if self.size == self.capacity:
            dist = (self.ptr - idx) % self.capacity
            ok &= dist >= self.seq_len
        return idx[ok]

    def sample_sequences(self, batch: int):
        starts = self._valid_starts()
        if len(starts) == 0:
            return None
        sel = self.rng.choice(starts, size=batch, replace=True)
        return self._gather_sequences(sel)

    def _gather_sequences(self, sel: np.ndarray):
        L = self.seq_len
        gather = lambda arr: np.stack(
            [arr[(s + np.arange(L)) % self.capacity] for s in sel])
        return {
            "obs": gather(self.obs), "action": gather(self.action),
            "reward": gather(self.reward), "next_obs": gather(self.next_obs),
            "done": gather(self.done), "cost": gather(self.cost),
            "h_a": self.h_a[sel], "c_a": self.c_a[sel],
            "h_q": self.h_q[sel], "c_q": self.c_q[sel],
        }

    def sample_steps(self, batch: int):
        """Plain transition batch (for the vanilla DDPG baseline)."""
        if self.size == 0:
            return None
        sel = self.rng.integers(0, self.size, size=batch)
        return {
            "obs": self.obs[sel], "action": self.action[sel],
            "reward": self.reward[sel], "next_obs": self.next_obs[sel],
            "done": self.done[sel], "cost": self.cost[sel],
            "h_a": self.h_a[sel], "c_a": self.c_a[sel],
            "h_q": self.h_q[sel], "c_q": self.c_q[sel],
        }


# --------------------------------------------------------------- device ring
# The wide-field ring lives in a dict of jax arrays threaded functionally
# through three jitted data-movement programs (scatter/gather only — no
# float math, so there is nothing lowering-sensitive to drift bitwise).
# All indices are computed host-side and passed as array inputs, so each
# program compiles once per (padded length, ring shape) pair.

WIDE_FIELDS = ("obs", "next_obs", "h_a", "c_a", "h_q", "c_q")


def wide_dim(obs_dim: int, lstm_hidden: int) -> int:
    """Feature width of one packed wide-field row: the six per-step
    device-resident fields concatenated (`WIDE_FIELDS` order).  Packing
    them into one array keeps every capture/ring program at one wide
    operand instead of six, which matters when dispatch overhead is per
    argument."""
    return 2 * obs_dim + 4 * lstm_hidden


def donate_argnums(*argnums: int) -> tuple:
    """Buffer donation, gated off the CPU backend.  On accelerators,
    donating the ring pages / capture buffers / learner state lets XLA
    write outputs into the donated memory — the right call for the
    largest live trees.  On the CPU PJRT backend the donation hand-off
    is a synchronization point with the (shared) execution pool: the
    dispatch blocks until every in-flight reader of the donated buffer
    has executed, which under a busy offline learner re-serializes
    exactly the work this module keeps off the serving path (measured:
    a donated ring write stalls ~70 ms behind one fine-tune round, jax
    0.4.37).  The ring is paged instead, so CPU forgoes nothing: writes
    allocate one fresh page, not one fresh ring."""
    import jax
    return argnums if jax.default_backend() != "cpu" else ()


def _pow2_pad(n: int) -> int:
    k = 1
    while k < n:
        k *= 2
    return k


def _jax():
    import jax
    return jax


def _page_write(page, values, in_page_idx):
    """page[in_page_idx] = values (entries outside this page carry index
    == page_rows and drop).  The functional update allocates one fresh
    *page*, not one fresh ring — the reason the ring is paged."""
    return page.at[in_page_idx].set(values, mode="drop")


def _field_cols(obs_dim: int, lstm_hidden: int, field: str) -> slice:
    start = 0
    for f, d in zip(WIDE_FIELDS, (obs_dim, obs_dim, lstm_hidden,
                                  lstm_hidden, lstm_hidden, lstm_hidden)):
        if f == field:
            return slice(start, start + d)
        start += d
    raise KeyError(field)


def _replay_programs(obs_dim: int, lstm_hidden: int):
    """Process-wide jitted ring programs, keyed on the packed layout —
    every replay instance (and every service instance) shares the same
    callables, so a fresh service never recompiles the gather (a ~70 ms
    compile that would otherwise recur per instance)."""
    return _replay_programs_cached(obs_dim, lstm_hidden,
                                   donate_argnums(0))


@lru_cache(maxsize=None)
def _replay_programs_cached(obs_dim: int, lstm_hidden: int,
                            donate: tuple):
    jax = _jax()
    cols = {f: _field_cols(obs_dim, lstm_hidden, f) for f in WIDE_FIELDS}

    def gather(pages, win_idx, start_idx):
        """Packed sequence-window gather: obs/next_obs over the window
        indices, hiddens at the start index only (stored-state replay).
        The page concatenate materializes the ring view inside the
        program — execution-side work on the learner's timeline."""
        jnp = jax.numpy
        packed = pages[0] if len(pages) == 1 else jnp.concatenate(pages)
        win = packed[win_idx]
        start = packed[start_idx]
        return {
            "obs": win[..., cols["obs"]],
            "next_obs": win[..., cols["next_obs"]],
            "h_a": start[..., cols["h_a"]],
            "c_a": start[..., cols["c_a"]],
            "h_q": start[..., cols["h_q"]],
            "c_q": start[..., cols["c_q"]],
        }

    return {"write": jax.jit(_page_write, donate_argnums=donate),
            "gather": jax.jit(gather)}


class DeviceSequenceReplay(SequenceReplay):
    """`SequenceReplay` with the wide per-step fields resident on device,
    packed: one ``[rows, wide_dim]`` array holds obs | next_obs | h_a |
    c_a | h_q | c_q per ring row (`WIDE_FIELDS` order), split back into
    fields only where a consumer needs them.

    `add_episode` accepts host `[T, ...]` arrays (same signature as the
    base class); `add_episode_values` takes an already-packed device
    array straight off a pool's capture buffer — the serving path's
    ingestion, where the wide fields never visit the host.  Sampling
    draws indices host-side with the exact RNG sequence of the base
    class and gathers on device, so batches are bitwise identical to the
    host layout's.
    """

    def __init__(self, *args, device=None, spilled=False, **kwargs):
        self._device = device       # ring placement (None -> default)
        # fleet tiering: a spilled ring keeps its pages as host numpy
        # arrays (pinned buffers on accelerator backends) instead of
        # device arrays — zero device bytes, same contents.  Construct
        # spilled for cold-start tenants so admission never allocates
        # device pages the tenant may never earn; `repage()` promotes
        self._spilled = bool(spilled)
        super().__init__(*args, **kwargs)

    def _alloc(self):
        jnp = _jax().numpy
        capacity, f32 = self.capacity, np.float32
        self.action = np.zeros((capacity, self.action_dim), f32)
        self.reward = np.zeros((capacity,), f32)
        self.done = np.zeros((capacity,), f32)
        self.cost = np.zeros((capacity,), f32)
        self.step_left = np.zeros((capacity,), np.int32)
        # the ring is a list of fixed-size packed pages: an episode write
        # touches the 1-2 pages it lands on, so the functional update
        # allocates O(page), never O(capacity)
        self.wide = wide_dim(self.obs_dim, self.lstm_hidden)
        self.page_rows = 256 if capacity % 256 == 0 else capacity
        n_pages = capacity // self.page_rows
        if self._spilled:
            self._pages = [np.zeros((self.page_rows, self.wide), f32)
                           for _ in range(n_pages)]
        else:
            self._pages = [
                self._place(jnp.zeros((self.page_rows, self.wide), f32))
                for _ in range(n_pages)]

    # --------------------------------------------------- spill / re-page
    @property
    def spilled(self) -> bool:
        return self._spilled

    @property
    def device_bytes(self) -> int:
        """Approximate device residency of the ring (the wide pages; the
        narrow fields are host-side in both states)."""
        if self._spilled:
            return 0
        return sum(int(np.prod(p.shape)) * 4 for p in self._pages)

    @property
    def host_bytes(self) -> int:
        """Approximate host residency: the narrow fields always, plus the
        spilled wide pages while the ring is off-device."""
        narrow = (self.action.nbytes + self.reward.nbytes
                  + self.done.nbytes + self.cost.nbytes
                  + self.step_left.nbytes)
        pages = (sum(p.nbytes for p in self._pages) if self._spilled
                 else 0)
        return narrow + pages

    def spill(self):
        """Move the ring's wide pages to host buffers and drop the device
        references (warm/cold tiers).  Float32 crosses the transfer
        exactly, so a later `repage()` restores the ring bitwise; writes
        and samples keep working against the host pages meanwhile."""
        if self._spilled:
            return
        jax = _jax()
        # np.array, not asarray: device_get may hand back a read-only
        # view of the runtime's buffer, and spilled pages must accept
        # host-side episode writes
        self._pages = [np.array(jax.device_get(p), np.float32)
                       for p in self._pages]
        self._spilled = True

    def repage(self):
        """Commit the spilled pages back onto the ring's device (hot
        promotion).  The ring is bitwise-identical to one that never left
        the device — tests/test_fleet.py pins it, including episodes that
        span pages and rings that wrapped while spilled."""
        if not self._spilled:
            return
        jnp = _jax().numpy
        self._pages = [self._place(jnp.asarray(p)) for p in self._pages]
        self._spilled = False

    def _place(self, tree):
        """Commit values to the ring's device so every ring program stays
        single-device."""
        if self._device is None:
            return tree
        return _jax().device_put(tree, self._device)

    def _ring_view(self, field):
        if self._spilled:
            packed = (self._pages[0] if len(self._pages) == 1
                      else np.concatenate(self._pages))
        else:
            jnp = _jax().numpy
            packed = (self._pages[0] if len(self._pages) == 1
                      else jnp.concatenate(self._pages))
        return packed[:, _field_cols(self.obs_dim, self.lstm_hidden,
                                     field)]

    # device ring views under the base-class attribute names, so parity
    # tests (and any reader) address both layouts identically
    obs = property(lambda self: self._ring_view("obs"))
    next_obs = property(lambda self: self._ring_view("next_obs"))
    h_a = property(lambda self: self._ring_view("h_a"))
    c_a = property(lambda self: self._ring_view("c_a"))
    h_q = property(lambda self: self._ring_view("h_q"))
    c_q = property(lambda self: self._ring_view("c_q"))

    def add(self, *args, **kwargs):
        raise NotImplementedError(
            "DeviceSequenceReplay ingests whole episodes (add_episode / "
            "add_episode_values); per-step add is the host layout's path")

    def _padded_ring_idx(self, T: int) -> np.ndarray:
        """Ring scatter indices padded to a power of two so the write
        program compiles once per padded length: pad rows scatter to index
        `capacity`, which `.at[..., mode='drop']` discards."""
        t = np.arange(_pow2_pad(T))
        return np.where(t < T, (self.ptr + t) % self.capacity,
                        self.capacity).astype(np.int32)

    def add_episode(self, obs, action, reward, next_obs, done, cost,
                    actor_hidden, critic_hidden):
        T = int(np.shape(reward)[0])
        if T == 0:
            return
        src = np.minimum(np.arange(_pow2_pad(T)), T - 1)
        packed = np.concatenate(
            [np.asarray(obs, np.float32), np.asarray(next_obs, np.float32),
             np.asarray(actor_hidden[0], np.float32),
             np.asarray(actor_hidden[1], np.float32),
             np.asarray(critic_hidden[0], np.float32),
             np.asarray(critic_hidden[1], np.float32)], axis=-1)[src]
        self.add_episode_values(_jax().numpy.asarray(packed), T,
                                action, reward, done, cost)

    def add_episode_values(self, values, T: int, action, reward, done,
                           cost):
        """Ingest one episode whose wide fields arrive as one packed
        ``[pow2_pad(T), wide_dim]`` device array (rows past T-1 are
        don't-care pads — their ring indices drop); the narrow fields
        arrive as host ``[T]`` arrays the serving loop already collected.
        The serving path feeds this straight from a pool's capture
        buffer, so the wide fields never visit the host."""
        if T == 0:
            return
        if T > self.capacity:
            raise ValueError(f"episode of {T} steps exceeds replay "
                             f"capacity {self.capacity}")
        rows = self.page_rows
        if self._spilled:
            # host-side write into the spilled pages: same rows, same
            # float32 values as the device scatter (pad rows past T-1
            # never land there either — their ring indices drop)
            vals = np.asarray(_jax().device_get(values),
                              np.float32)[:T]
            flat = self._ring_indices(T)
            for p in np.unique(flat // rows):
                m = (flat // rows) == p
                self._pages[int(p)][flat[m] % rows] = vals[m]
        else:
            flat = self._padded_ring_idx(T)
            values = self._place(values)
            live = flat[flat < self.capacity]
            write = _replay_programs(self.obs_dim,
                                     self.lstm_hidden)["write"]
            for p in np.unique(live // rows):
                in_page = np.where((flat < self.capacity)
                                   & (flat // rows == p),
                                   flat % rows, rows).astype(np.int32)
                self._pages[int(p)] = write(self._pages[int(p)], values,
                                            in_page)
        self._write_narrow_and_advance(self._ring_indices(T), action,
                                       reward, done, cost)

    def _gather_sequences(self, sel: np.ndarray):
        L = self.seq_len
        win = (sel[..., None] + np.arange(L)) % self.capacity
        if self._spilled:
            # numpy gather over the host pages — same indices, same
            # float32 values, so a spilled ring samples bitwise-identical
            # batches (the consumer jnp.asarray's them either way)
            packed = (self._pages[0] if len(self._pages) == 1
                      else np.concatenate(self._pages))
            cols = {f: _field_cols(self.obs_dim, self.lstm_hidden, f)
                    for f in WIDE_FIELDS}
            w, s = packed[win], packed[sel]
            wide = {"obs": w[..., cols["obs"]],
                    "next_obs": w[..., cols["next_obs"]],
                    "h_a": s[..., cols["h_a"]],
                    "c_a": s[..., cols["c_a"]],
                    "h_q": s[..., cols["h_q"]],
                    "c_q": s[..., cols["c_q"]]}
        else:
            wide = _replay_programs(self.obs_dim,
                                    self.lstm_hidden)["gather"](
                tuple(self._pages), win.astype(np.int32),
                sel.astype(np.int32))
        # narrow fields gather host-side and commit to the ring's device,
        # so the learner's update program never mixes device queues
        gather = lambda arr: self._place(arr[win])
        return {
            "obs": wide["obs"], "action": gather(self.action),
            "reward": gather(self.reward), "next_obs": wide["next_obs"],
            "done": gather(self.done), "cost": gather(self.cost),
            "h_a": wide["h_a"], "c_a": wide["c_a"],
            "h_q": wide["h_q"], "c_q": wide["c_q"],
        }

    def sample_sequence_batches(self, n_batches: int, batch: int):
        """`n_batches` sequential `sample_sequences` draws gathered in one
        device program, stacked on a leading axis — the scanned offline
        fine-tune's input.  Same RNG sequence as the sequential calls, so
        batches are bitwise identical; None if sampling isn't possible."""
        starts = self._valid_starts()
        if len(starts) == 0:
            return None
        sel = np.stack([self.rng.choice(starts, size=batch, replace=True)
                        for _ in range(n_batches)])
        return self._gather_sequences(sel)

    def sample_steps(self, batch: int):
        raise NotImplementedError(
            "step sampling is the vanilla-DDPG baseline's host path")
