"""Mixed parameter spaces: [-1,1]^d agent actions <-> raw index parameters.

Handles the paper's Table-2 heterogeneity: continuous ranges, booleans,
integers (linear in log2 space where declared as *_log2), discrete choices,
and CARMI's hybrid continuous/discrete lambda.  Everything is jit-friendly
(params stay float32 scalars inside jitted env code; the index simulators
consume them with soft thresholds for booleans/choices).
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np


@dataclasses.dataclass(frozen=True)
class ParamSpace:
    names: tuple
    kinds: tuple           # cont | bool | int | choice | hybrid
    lows: np.ndarray
    highs: np.ndarray

    @property
    def dim(self) -> int:
        return len(self.names)

    # ---------- action <-> raw ----------
    def decode(self, action: jax.Array) -> dict:
        """action in [-1,1]^d -> dict of raw float params."""
        a01 = (jnp.clip(action, -1.0, 1.0) + 1.0) * 0.5
        out = {}
        for i, (name, kind) in enumerate(zip(self.names, self.kinds)):
            lo, hi = float(self.lows[i]), float(self.highs[i])
            x = a01[i] * (hi - lo) + lo
            if kind == "bool":
                x = (a01[i] > 0.5).astype(jnp.float32)
            elif kind in ("int", "choice"):
                x = jnp.round(x)
            out[name] = x.astype(jnp.float32)
        return out

    def decode_np(self, action) -> dict:
        """Host-side mirror of `decode` (same float32 arithmetic, numpy):
        per-request summaries on the serving hot path decode without any
        device dispatches."""
        a = np.asarray(action, np.float32)
        a01 = (np.clip(a, np.float32(-1.0), np.float32(1.0))
               + np.float32(1.0)) * np.float32(0.5)
        out = {}
        for i, (name, kind) in enumerate(zip(self.names, self.kinds)):
            lo, hi = float(self.lows[i]), float(self.highs[i])
            x = a01[i] * np.float32(hi - lo) + np.float32(lo)
            if kind == "bool":
                x = np.float32(a01[i] > 0.5)
            elif kind in ("int", "choice"):
                x = np.round(x)
            out[name] = float(np.float32(x))
        return out

    def encode(self, raw: dict) -> np.ndarray:
        """dict of raw params -> action in [-1,1]^d (for warm starts)."""
        a = np.zeros(self.dim, np.float32)
        for i, name in enumerate(self.names):
            lo, hi = float(self.lows[i]), float(self.highs[i])
            x = float(raw[name])
            a[i] = 2.0 * (x - lo) / max(hi - lo, 1e-9) - 1.0
        return np.clip(a, -1.0, 1.0)

    def random_raw(self, rng: np.random.Generator) -> dict:
        out = {}
        for i, (name, kind) in enumerate(zip(self.names, self.kinds)):
            lo, hi = float(self.lows[i]), float(self.highs[i])
            if kind == "bool":
                out[name] = float(rng.integers(0, 2))
            elif kind in ("int", "choice"):
                out[name] = float(rng.integers(int(lo), int(hi) + 1))
            else:
                out[name] = float(rng.uniform(lo, hi))
        return out

    def grid_axes(self, points_per_dim: int = 3):
        """Per-dimension grids (for grid search)."""
        axes = []
        for i, kind in enumerate(self.kinds):
            lo, hi = float(self.lows[i]), float(self.highs[i])
            if kind == "bool":
                axes.append([0.0, 1.0])
            elif kind in ("int", "choice"):
                n = min(points_per_dim, int(hi - lo) + 1)
                axes.append(list(np.round(np.linspace(lo, hi, n))))
            else:
                axes.append(list(np.linspace(lo, hi, points_per_dim)))
        return axes


def from_table(table) -> ParamSpace:
    names = tuple(t[0] for t in table)
    kinds = tuple(t[1] for t in table)
    lows = np.array([t[2][0] for t in table], np.float64)
    highs = np.array([t[2][1] for t in table], np.float64)
    return ParamSpace(names, kinds, lows, highs)


def alex_space() -> ParamSpace:
    from repro.index.alex import PARAM_SPACE
    return from_table(PARAM_SPACE)


def carmi_space() -> ParamSpace:
    from repro.index.carmi import PARAM_SPACE
    return from_table(PARAM_SPACE)
