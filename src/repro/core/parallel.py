"""Mesh-parallel meta-training — the paper's §6 future work, implemented.

LITune's offline stage is embarrassingly parallel over tuning instances:
every environment step is a pure jittable function (index/env.py), so a
meta-batch of B instances vmaps into one program and shards over the mesh
data axes.  One `parallel_rollout` step on a 2×16×16 pod advances 512+
environments at once; the DDPG update itself is replicated (tiny nets) with
batch-sharded sequences.

This module also supplies the *paper-technique dry-run cells*
(`launch/dryrun.py --arch litune_alex --shape meta_train`): the same
lower+compile+roofline treatment the LM cells get, proving the tuner's
training loop is pod-scale runnable.
"""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp

from repro.core import ddpg, networks as nets
from repro.core.ddpg import DDPGConfig
from repro.core.networks import NetConfig
from repro.index import env as E


def batched_reset(cfg: E.EnvConfig, data_keys, workloads, wr_ratios):
    """Vectorized reset over B instances (leading axis on all args)."""
    def one(data, reads, inserts, wr):
        return E.reset(cfg, data, {"reads": reads, "inserts": inserts}, wr)
    return jax.vmap(one)(data_keys, workloads["reads"], workloads["inserts"],
                         wr_ratios)


def mapped_reset(cfg: E.EnvConfig, data_keys, workloads, wr_ratios):
    """`lax.map` variant of `batched_reset`: per-slot results are bitwise
    identical to the unbatched `E.reset` (see core/etmdp.py on map-vs-vmap);
    the tuning service admits request waves through this."""
    def one(x):
        data, reads, inserts, wr = x
        return E.reset(cfg, data, {"reads": reads, "inserts": inserts}, wr)
    return jax.lax.map(one, (data_keys, workloads["reads"],
                             workloads["inserts"], wr_ratios))


@partial(jax.jit, static_argnames=("env_cfg", "net_cfg", "ddpg_cfg",
                                   "n_steps"))
def parallel_rollout(agent_params, env_states, obs, key,
                     env_cfg: E.EnvConfig, net_cfg: NetConfig,
                     ddpg_cfg: DDPGConfig, n_steps: int = 8):
    """Roll B environments n_steps forward under the (shared) policy.

    Returns (env_states', trajectories) where trajectories hold
    [n_steps, B, ...] transitions ready for sequence replay / updates.
    The B axis shards over the mesh data axes under pjit.
    """
    b = obs.shape[0]
    hidden_a = nets.zero_hidden(net_cfg, (b,))
    hidden_q = nets.zero_hidden(net_cfg, (b,))
    step_fn = E.step_core  # un-jitted core; vmapped below

    def body(carry, k):
        env_states, obs, h_a, h_q = carry
        act, h_a2 = nets.actor_apply(agent_params["actor"], obs, h_a,
                                     net_cfg)
        noise = ddpg_cfg.noise_scale * jax.random.normal(k, act.shape)
        act = jnp.clip(act + noise, -1.0, 1.0)
        _, h_q2 = nets.critic_apply(agent_params["critic0"], obs, act, h_q,
                                    net_cfg)
        env_states2, obs2, rew, done, info = jax.vmap(
            lambda s, a: step_fn(env_cfg, s, a))(env_states, act)
        tr = {"obs": obs, "action": act, "reward": rew, "next_obs": obs2,
              "done": done.astype(jnp.float32), "cost": info["cost"],
              "h_a": h_a[0], "c_a": h_a[1], "h_q": h_q[0], "c_q": h_q[1],
              "runtime_ns": info["runtime_ns"]}
        return (env_states2, obs2, h_a2, h_q2), tr

    keys = jax.random.split(key, n_steps)
    (env_states, obs, _, _), traj = jax.lax.scan(
        body, (env_states, obs, hidden_a, hidden_q), keys)
    return env_states, obs, traj


def traj_to_sequences(traj, seq_len: int):
    """[T, B, ...] trajectories -> sequence batch for ddpg.update."""
    t = traj["reward"].shape[0]
    n = (t // seq_len) * seq_len
    # fold (time-chunks, B) into the batch dim
    out = {}
    for k in ("obs", "action", "reward", "next_obs", "done", "cost"):
        x = traj[k][:n]
        x = x.reshape(n // seq_len, seq_len, x.shape[1], *x.shape[2:])
        x = jnp.moveaxis(x, 2, 1)  # [chunks, B, L, ...]
        out[k] = x.reshape(-1, seq_len, *x.shape[3:])
    for k in ("h_a", "c_a", "h_q", "c_q"):
        x = traj[k][:n].reshape(n // seq_len, seq_len, traj[k].shape[1], -1)
        out[k] = x[:, 0].reshape(-1, x.shape[-1])
    return out


def meta_train_parallel(key, net_cfg: NetConfig, ddpg_cfg: DDPGConfig,
                        env_cfg: E.EnvConfig, meta_batch: int = 8,
                        n_outer: int = 4, rollout_steps: int = 8,
                        updates_per_outer: int = 4, seed: int = 0):
    """Data-parallel variant of core/maml.meta_train: all instances advance
    in one vmapped program per outer iteration (single- or multi-host)."""
    import numpy as np
    from repro.core.maml import sample_task
    from repro.index.workloads import WorkloadConfig, make_workload, sample_keys
    rng = np.random.default_rng(seed)
    state = ddpg.init_state(key, net_cfg, ddpg_cfg)
    history = []
    for it in range(n_outer):
        tasks = [sample_task(rng) for _ in range(meta_batch)]
        # batched envs need uniform array shapes: fixed 50/50 read/insert
        # split; task diversity comes from distribution + drift (the
        # sequential maml path keeps the full W/R variation)
        envs = []
        for t in tasks:
            kk = jax.random.PRNGKey(t.seed)
            d = sample_keys(kk, t.n_keys, t.dist, shift=t.drift)
            w = make_workload(jax.random.fold_in(kk, 1), d,
                              WorkloadConfig(n_reads=t.n_queries // 2,
                                             n_inserts=t.n_queries // 2,
                                             insert_drift=t.drift), t.dist)
            envs.append((d, w))
        data = jnp.stack([d for d, _ in envs])
        workloads = {
            "reads": jnp.stack([w["reads"] for _, w in envs]),
            "inserts": jnp.stack([w["inserts"] for _, w in envs]),
        }
        wr = jnp.ones((meta_batch,), jnp.float32)
        env_states, obs = batched_reset(env_cfg, data, workloads, wr)
        key, k = jax.random.split(key)
        env_states, obs, traj = parallel_rollout(
            state["params"], env_states, obs, k, env_cfg, net_cfg, ddpg_cfg,
            n_steps=rollout_steps)
        batch = traj_to_sequences(traj, ddpg_cfg.seq_len)
        for _ in range(updates_per_outer):
            state, metrics = ddpg.update(state, batch, net_cfg, ddpg_cfg)
        history.append({
            "iter": it,
            "mean_runtime": float(jnp.mean(traj["runtime_ns"])),
            "best_runtime": float(jnp.min(traj["runtime_ns"])),
            "violations": float(jnp.sum(traj["cost"])),
            "critic_loss": float(metrics["critic_loss"]),
        })
    return state, history


# ------------------------------------------------------------------
# Dry-run support: the paper-technique cell.
def litune_cell_inputs(env_cfg: E.EnvConfig, net_cfg: NetConfig,
                       meta_batch: int, n_keys: int = 4096,
                       n_queries: int = 4096):
    """Abstract (ShapeDtypeStruct, logical-axes) inputs for lowering
    `parallel_rollout` on a production mesh: B tuning instances shard over
    the data axes, agent parameters replicate."""
    f32 = jnp.float32
    sds = {
        "data_keys": jax.ShapeDtypeStruct((meta_batch, n_keys), f32),
        "reads": jax.ShapeDtypeStruct((meta_batch, n_queries // 2), f32),
        "inserts": jax.ShapeDtypeStruct((meta_batch, n_queries // 2), f32),
        "wr": jax.ShapeDtypeStruct((meta_batch,), f32),
        "key": jax.ShapeDtypeStruct((2,), jnp.uint32),
    }
    axes = {
        "data_keys": ("batch", None), "reads": ("batch", None),
        "inserts": ("batch", None), "wr": ("batch",),
        "key": (None,),
    }
    return sds, axes
