"""DDPG with LSTM context (the LITune backbone) + target networks.

Sequence updates R2D2-style: hidden states are stored at write time, the
first `burn_in` steps of each sampled sequence only warm the LSTM, and
target-network hiddens reuse the online chain (standard stored-state
approximation).  The same learner also runs context-free (use_lstm=False
zeroes the hidden contribution) for the vanilla-DDPG baseline.
"""
from __future__ import annotations

import dataclasses
from functools import partial

import jax
import jax.numpy as jnp

from repro.core import networks as nets
from repro.core.networks import NetConfig
from repro.optim.adamw import AdamWConfig, adamw_update, init_opt_state


@dataclasses.dataclass(frozen=True)
class DDPGConfig:
    gamma: float = 0.95
    tau: float = 0.01
    actor_lr: float = 3e-4
    critic_lr: float = 1e-3
    noise_scale: float = 0.15
    seq_len: int = 8
    burn_in: int = 2
    batch_size: int = 64
    use_lstm: bool = True
    # Lagrangian safety head (core/lagrangian.py drives these)
    use_cost_critic: bool = False
    lambda_lr: float = 1e-2
    cost_limit: float = 1.0


def init_state(key, net_cfg: NetConfig, ddpg_cfg: DDPGConfig):
    n_critics = 2 if ddpg_cfg.use_cost_critic else 1
    params = nets.init_actor_critic(key, net_cfg, n_critics=n_critics)
    return {
        "params": params,
        "targets": jax.tree.map(lambda x: x, params),
        "opt_actor": init_opt_state(params["actor"]),
        "opt_critic": init_opt_state(params["critic0"]),
        "opt_cost": (init_opt_state(params["critic1"])
                     if ddpg_cfg.use_cost_critic else None),
        "lmbda": jnp.float32(0.0),
        "updates": jnp.int32(0),
    }


@partial(jax.jit, static_argnames=("net_cfg", "deterministic"))
def act(params, obs, hidden, key, net_cfg: NetConfig,
        noise_scale: float = 0.0, deterministic: bool = False):
    a, hc = nets.actor_apply(params["actor"], obs, hidden, net_cfg)
    if not deterministic:
        a = jnp.clip(a + noise_scale * jax.random.normal(key, a.shape),
                     -1.0, 1.0)
    return a, hc


def _unroll_critic(p, obs_seq, act_seq, h0, net_cfg):
    """obs/act [B,L,...]; returns q [B,L] and hidden sequence."""
    def step(hc, xs):
        o, a = xs
        q, hc2 = nets.critic_apply(p, o, a, hc, net_cfg)
        return hc2, (q, hc2[0], hc2[1])
    xs = (obs_seq.swapaxes(0, 1), act_seq.swapaxes(0, 1))
    _, (q, hs, cs) = jax.lax.scan(step, h0, xs)
    return q.swapaxes(0, 1), (hs.swapaxes(0, 1), cs.swapaxes(0, 1))


def _unroll_actor(p, obs_seq, h0, net_cfg):
    def step(hc, o):
        a, hc2 = nets.actor_apply(p, o, hc, net_cfg)
        return hc2, a
    _, a = jax.lax.scan(step, h0, obs_seq.swapaxes(0, 1))
    return a.swapaxes(0, 1)


@partial(jax.jit, static_argnames=("net_cfg", "cfg"))
def update(state, batch, net_cfg: NetConfig, cfg: DDPGConfig):
    """One DDPG update on a batch of sequences. Returns (state, metrics)."""
    p, tp = state["params"], state["targets"]
    L, b = cfg.seq_len, cfg.burn_in
    zeros = lambda key: (batch[key] * 0.0) if not cfg.use_lstm else batch[key]
    h_q0 = (zeros("h_q"), zeros("c_q"))
    h_a0 = (zeros("h_a"), zeros("c_a"))
    mask = jnp.arange(L) >= b  # burn-in excluded from losses

    # ---- critic ----
    def critic_loss(cp):
        q, (hs, cs) = _unroll_critic(cp, batch["obs"], batch["action"],
                                     h_q0, net_cfg)
        # target actions from target actor over next_obs
        a_next = _unroll_actor(tp["actor"], batch["next_obs"], h_a0, net_cfg)
        q_next, _ = _unroll_critic(tp["critic0"], batch["next_obs"], a_next,
                                   (hs[:, 0] * 0 + h_q0[0], h_q0[1]), net_cfg)
        y = batch["reward"] + cfg.gamma * (1.0 - batch["done"]) \
            * jax.lax.stop_gradient(q_next)
        err = (q - jax.lax.stop_gradient(y)) * mask
        return jnp.sum(err ** 2) / jnp.maximum(jnp.sum(mask), 1.0) / q.shape[0]

    c_loss, c_grads = jax.value_and_grad(critic_loss)(p["critic0"])
    new_c, opt_c, _ = adamw_update(
        p["critic0"], c_grads, state["opt_critic"],
        AdamWConfig(lr=cfg.critic_lr, weight_decay=0.0))

    # ---- optional cost critic (Lagrangian CMDP baseline) ----
    new_cost, opt_cost, cost_loss = p.get("critic1"), state["opt_cost"], 0.0
    if cfg.use_cost_critic:
        def cost_loss_fn(cp):
            qc, _ = _unroll_critic(cp, batch["obs"], batch["action"], h_q0,
                                   net_cfg)
            a_next = _unroll_actor(tp["actor"], batch["next_obs"], h_a0,
                                   net_cfg)
            qc_next, _ = _unroll_critic(tp["critic1"], batch["next_obs"],
                                        a_next, h_q0, net_cfg)
            y = batch["cost"] + cfg.gamma * (1.0 - batch["done"]) \
                * jax.lax.stop_gradient(qc_next)
            err = (qc - jax.lax.stop_gradient(y)) * mask
            return jnp.sum(err ** 2) / jnp.maximum(jnp.sum(mask), 1.0) \
                / qc.shape[0]
        cost_loss, cost_grads = jax.value_and_grad(cost_loss_fn)(p["critic1"])
        new_cost, opt_cost, _ = adamw_update(
            p["critic1"], cost_grads, state["opt_cost"],
            AdamWConfig(lr=cfg.critic_lr, weight_decay=0.0))

    # ---- actor ----
    def actor_loss(ap):
        a = _unroll_actor(ap, batch["obs"], h_a0, net_cfg)
        q, _ = _unroll_critic(new_c, batch["obs"], a, h_q0, net_cfg)
        loss = -(q * mask).sum() / jnp.maximum(mask.sum(), 1.0) / q.shape[0]
        if cfg.use_cost_critic:
            qc, _ = _unroll_critic(new_cost, batch["obs"], a, h_q0, net_cfg)
            loss = loss + state["lmbda"] * (qc * mask).sum() \
                / jnp.maximum(mask.sum(), 1.0) / qc.shape[0]
        return loss

    a_loss, a_grads = jax.value_and_grad(actor_loss)(p["actor"])
    new_a, opt_a, _ = adamw_update(
        p["actor"], a_grads, state["opt_actor"],
        AdamWConfig(lr=cfg.actor_lr, weight_decay=0.0))

    # ---- lagrange multiplier (dual ascent) ----
    ep_cost = jnp.mean(jnp.sum(batch["cost"], axis=1))
    lmbda = jnp.maximum(
        state["lmbda"] + cfg.lambda_lr * (ep_cost - cfg.cost_limit), 0.0) \
        if cfg.use_cost_critic else state["lmbda"]

    new_params = dict(p)
    new_params["actor"] = new_a
    new_params["critic0"] = new_c
    if cfg.use_cost_critic:
        new_params["critic1"] = new_cost
    soft = lambda t, n: jax.tree.map(
        lambda a_, b_: (1 - cfg.tau) * a_ + cfg.tau * b_, t, n)
    new_state = dict(state)
    new_state.update({
        "params": new_params,
        "targets": soft(tp, new_params),
        "opt_actor": opt_a, "opt_critic": opt_c, "opt_cost": opt_cost,
        "lmbda": lmbda,
        "updates": state["updates"] + 1,
    })
    return new_state, {"critic_loss": c_loss, "actor_loss": a_loss,
                       "cost_critic_loss": cost_loss, "lambda": lmbda}
