"""O2 system — integrated Online tuning + Offline training (paper §3.4.2).

Two models:
  * ONLINE: serves recommendations immediately (frozen between swaps);
  * OFFLINE: continually fine-tunes on fresh transitions collected online.

A divergence monitor (KS statistic over key-distribution quantiles + W/R
drift) decides when data has shifted; at assessment points, if divergence
exceeds the threshold and the offline model beats the online one on the
recent window, the online model is swapped (Example 3.2's
stable-vs-dynamic-phase behaviour)."""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import ddpg
from repro.core.ddpg import DDPGConfig
from repro.core.etmdp import ETMDPConfig, rollout_episode
from repro.core.networks import NetConfig
from repro.core.replay import SequenceReplay
from repro.index import env as E


@dataclasses.dataclass(frozen=True)
class O2Config:
    divergence_threshold: float = 0.15   # KS distance on key quantiles
    wr_shift_threshold: float = 0.5      # relative W/R change
    assess_every: int = 1                # windows between assessments
    offline_updates_per_window: int = 16
    eval_episodes: int = 1
    n_quantiles: int = 32


def _quantiles(keys: np.ndarray, n: int) -> np.ndarray:
    return np.quantile(np.asarray(keys), np.linspace(0.0, 1.0, n))


def ks_distance(q_ref: np.ndarray, q_new: np.ndarray) -> float:
    """KS statistic between two distributions given matched quantile grids."""
    grid = np.union1d(q_ref, q_new)
    cdf = lambda q: np.searchsorted(q, grid, side="right") / len(q)
    return float(np.max(np.abs(cdf(q_ref) - cdf(q_new))))


class O2System:
    def __init__(self, pretrained_state, net_cfg: NetConfig,
                 ddpg_cfg: DDPGConfig, env_cfg: E.EnvConfig,
                 et_cfg: ETMDPConfig, o2_cfg: O2Config = O2Config(),
                 seed: int = 0):
        copy = lambda s: jax.tree.map(lambda x: x, s)
        self.online = copy(pretrained_state)
        self.offline = copy(pretrained_state)
        self.net_cfg, self.ddpg_cfg = net_cfg, ddpg_cfg
        self.env_cfg, self.et_cfg, self.cfg = env_cfg, et_cfg, o2_cfg
        self.replay = SequenceReplay(8192, E.obs_dim(), env_cfg.space.dim,
                                     net_cfg.lstm_hidden,
                                     seq_len=ddpg_cfg.seq_len, seed=seed)
        self.ref_quantiles: np.ndarray | None = None
        self.ref_wr: float | None = None
        self.windows_seen = 0
        self.swaps = 0
        self.divergences: list[float] = []

    # ---------- divergence detection ----------
    def observe_window(self, data_keys, wr_ratio: float) -> dict:
        q = _quantiles(np.asarray(data_keys), self.cfg.n_quantiles)
        if self.ref_quantiles is None:
            self.ref_quantiles, self.ref_wr = q, wr_ratio
            return {"diverged": False, "ks": 0.0, "wr_shift": 0.0}
        ks = ks_distance(self.ref_quantiles, q)
        wr_shift = abs(wr_ratio - self.ref_wr) / max(abs(self.ref_wr), 1e-9)
        self.divergences.append(ks)
        diverged = (ks > self.cfg.divergence_threshold
                    or wr_shift > self.cfg.wr_shift_threshold)
        return {"diverged": diverged, "ks": ks, "wr_shift": wr_shift}

    # ---------- the O2 loop on one window ----------
    def tune_window(self, key, data_keys, workload, wr_ratio: float,
                    max_steps: int | None = None) -> dict:
        """Online-tune the current window; offline model keeps learning;
        swap if diverged and offline wins."""
        div = self.observe_window(data_keys, wr_ratio)
        self.windows_seen += 1
        env_cfg = self.env_cfg
        if max_steps is not None:
            env_cfg = dataclasses.replace(env_cfg, episode_len=max_steps)

        key, k_on = jax.random.split(key)
        online_summary = rollout_episode(
            k_on, self.online, self.net_cfg, env_cfg, self.et_cfg,
            data_keys, workload, wr_ratio, noise_scale=0.02,
            replay=self.replay, deterministic=False)

        # offline model: continual fine-tuning on accumulated transitions
        for _ in range(self.cfg.offline_updates_per_window):
            batch = self.replay.sample_sequences(self.ddpg_cfg.batch_size)
            if batch is None:
                break
            batch = jax.tree.map(jnp.asarray, batch)
            self.offline, _ = ddpg.update(self.offline, batch, self.net_cfg,
                                          self.ddpg_cfg)

        swapped = False
        if div["diverged"] and \
                self.windows_seen % self.cfg.assess_every == 0:
            key, k_off = jax.random.split(key)
            off_summary = rollout_episode(
                k_off, self.offline, self.net_cfg, env_cfg, self.et_cfg,
                data_keys, workload, wr_ratio, noise_scale=0.0,
                deterministic=True)
            if off_summary["best_runtime_ns"] < online_summary["best_runtime_ns"]:
                self.online = jax.tree.map(lambda x: x, self.offline)
                self.swaps += 1
                swapped = True
                q = _quantiles(np.asarray(data_keys), self.cfg.n_quantiles)
                self.ref_quantiles, self.ref_wr = q, wr_ratio

        return {**online_summary, "divergence": div, "swapped": swapped}
