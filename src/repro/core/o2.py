"""O2 system — integrated Online tuning + Offline training (paper §3.4.2).

Two models:
  * ONLINE: serves recommendations immediately (frozen between swaps);
  * OFFLINE: continually fine-tunes on fresh transitions collected online.

A divergence monitor (KS statistic over key-distribution quantiles + W/R
drift) decides when data has shifted; at assessment points, if divergence
exceeds the threshold and the offline model beats the online one on the
recent window, the online model is swapped (Example 3.2's
stable-vs-dynamic-phase behaviour).

The loop is factored into three reusable pieces shared by the serial path
(`O2System.tune_window`, driven by `LITune.stream`) and the serving path
(`launch/tune_serve.TuningService` with `O2ServiceConfig`):

  * `DivergenceMonitor` — per-tenant KS + W/R drift bookkeeping;
  * `offline_finetune`  — N DDPG updates of the offline learner on the
                          shared replay;
  * `assess_offline`    — the deterministic offline evaluation episode
                          whose best-runtime decides a hot-swap.
"""
from __future__ import annotations

import dataclasses
from functools import lru_cache

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import ddpg
from repro.core.ddpg import DDPGConfig
from repro.core.etmdp import ETMDPConfig, rollout_episode
from repro.core.networks import NetConfig
from repro.core.replay import (DeviceSequenceReplay, SequenceReplay,
                               donate_argnums)
from repro.index import env as E


@dataclasses.dataclass(frozen=True)
class O2Config:
    divergence_threshold: float = 0.15   # KS distance on key quantiles
    wr_shift_threshold: float = 0.5      # relative W/R change
    assess_every: int = 1                # windows between assessments
    offline_updates_per_window: int = 16
    eval_episodes: int = 1
    n_quantiles: int = 32


def _quantiles(keys: np.ndarray, n: int) -> np.ndarray:
    return np.quantile(np.asarray(keys), np.linspace(0.0, 1.0, n))


def ks_distance(q_ref: np.ndarray, q_new: np.ndarray) -> float:
    """KS statistic between two distributions given matched quantile grids."""
    grid = np.union1d(q_ref, q_new)

    def cdf(q):
        return np.searchsorted(q, grid, side="right") / len(q)

    return float(np.max(np.abs(cdf(q_ref) - cdf(q_new))))


class DivergenceMonitor:
    """KS-on-quantiles + W/R drift detector over a window stream.

    Bookkeeping invariants (one entry per observed window, always):
      * ``len(divergences) == windows_seen`` — the reference window records
        a 0.0 divergence instead of being silently dropped;
      * ``anchors`` lists the window indices (0-based) whose data anchors
        the current and all past reference quantiles, so re-anchors on
        model swaps stay visible in the history.

    Non-finite window summaries (NaN/Inf keys or W/R ratios — a corrupt
    trace, a poisoned feed) are *skipped and counted*
    (``skipped_nonfinite``), never ingested: a single NaN quantile
    adopted as the reference would poison every later KS distance into
    NaN (NaN comparisons are False, so detection would silently go dark
    forever).  A skipped window still appends a 0.0 divergence entry to
    keep the one-entry-per-window invariant.
    """

    def __init__(self, cfg: O2Config):
        self.cfg = cfg
        self.ref_quantiles: np.ndarray | None = None
        self.ref_wr: float | None = None
        self.windows_seen = 0
        self.divergences: list[float] = []
        self.anchors: list[int] = []
        self.diverged_count = 0        # windows whose verdict fired (KS or W/R)
        self.skipped_nonfinite = 0     # windows refused (NaN/Inf summary)
        self.history_trimmed = 0       # entries dropped by `trim_history`

    @staticmethod
    def _finite_summary(q: np.ndarray, wr_ratio: float) -> bool:
        return bool(np.isfinite(wr_ratio)) and bool(np.all(np.isfinite(q)))

    def observe(self, data_keys, wr_ratio: float) -> dict:
        """Record one window; returns the divergence verdict for it."""
        q = _quantiles(np.asarray(data_keys), self.cfg.n_quantiles)
        self.windows_seen += 1
        if not self._finite_summary(q, wr_ratio):
            self.skipped_nonfinite += 1
            self.divergences.append(0.0)
            return {"diverged": False, "ks": 0.0, "wr_shift": 0.0,
                    "skipped_nonfinite": True}
        if self.ref_quantiles is None:
            self.ref_quantiles, self.ref_wr = q, wr_ratio
            self.divergences.append(0.0)
            self.anchors.append(self.windows_seen - 1)
            return {"diverged": False, "ks": 0.0, "wr_shift": 0.0}
        ks = ks_distance(self.ref_quantiles, q)
        wr_shift = abs(wr_ratio - self.ref_wr) / max(abs(self.ref_wr), 1e-9)
        self.divergences.append(ks)
        diverged = (ks > self.cfg.divergence_threshold
                    or wr_shift > self.cfg.wr_shift_threshold)
        self.diverged_count += bool(diverged)
        return {"diverged": diverged, "ks": ks, "wr_shift": wr_shift}

    def trim_history(self, keep: int) -> int:
        """Bound the per-window history lists (cold-tier eviction in the
        serving fleet).  Counters (`windows_seen`, `diverged_count`) and
        the live reference distribution are untouched — only the
        unbounded `divergences`/`anchors` tails shrink, so detection
        behaves identically afterward.  Relaxes the
        ``len(divergences) == windows_seen`` bookkeeping invariant for
        this monitor (the trimmed prefix is accounted by
        `history_trimmed`).  Returns how many entries were dropped."""
        dropped = max(0, len(self.divergences) - keep)
        if dropped:
            self.divergences = self.divergences[-keep:]
            self.anchors = [a for a in self.anchors
                            if a >= self.windows_seen - keep][-keep:]
            self.history_trimmed += dropped
        return dropped

    def re_anchor(self, data_keys, wr_ratio: float,
                  window: int | None = None):
        """Reset the reference distribution (after a model swap) and record
        which window re-anchored it.  `window` is the 0-based index of the
        window whose data is being anchored; it defaults to the latest
        observed one (the serial loop's case), but a concurrent server
        passes the retired window explicitly — another window may have
        been observed since.  A non-finite anchor is refused (skipped and
        counted) — the previous reference stays live rather than letting
        a corrupt window blind the monitor."""
        q = _quantiles(np.asarray(data_keys), self.cfg.n_quantiles)
        if not self._finite_summary(q, wr_ratio):
            self.skipped_nonfinite += 1
            return
        self.ref_quantiles = q
        self.ref_wr = wr_ratio
        self.anchors.append(self.windows_seen - 1 if window is None
                            else window)


def make_replay(net_cfg: NetConfig, ddpg_cfg: DDPGConfig,
                env_cfg: E.EnvConfig, capacity: int = 8192,
                seed: int = 0, device: bool = False,
                place_on=None, spilled: bool = False) -> SequenceReplay:
    """The replay shape both O2 paths share — constructing it identically
    is what makes serial/serving fine-tuning bitwise comparable.  With
    ``device=True`` the wide fields live in device ring buffers
    (`DeviceSequenceReplay`) — same contents, same sampling RNG —
    optionally pinned to `place_on` (the serving path's O2 annex device,
    so ring traffic never queues on the serving mesh).  ``spilled=True``
    constructs the device ring with its pages on the host (the fleet
    cold tier's zero-device-bytes start; `repage()` promotes)."""
    if device:
        return DeviceSequenceReplay(
            capacity, E.obs_dim(), env_cfg.space.dim, net_cfg.lstm_hidden,
            seq_len=ddpg_cfg.seq_len, seed=seed, device=place_on,
            spilled=spilled)
    return SequenceReplay(capacity, E.obs_dim(), env_cfg.space.dim,
                          net_cfg.lstm_hidden, seq_len=ddpg_cfg.seq_len,
                          seed=seed)


@jax.jit
def _copy_tree(tree):
    # jnp.copy under jit (without donation) materializes distinct output
    # buffers for every leaf — one program dispatch for the whole tree
    return jax.tree.map(jnp.copy, tree)


def copy_state(state):
    """A real (buffer-copying) clone of a DDPG state tree, as one async
    program dispatch.

    `offline_finetune` donates its input state to the scanned update
    program, so any tree that must outlive the learner — the pretrained
    state handed in by the caller, the online model promoted at a swap —
    has to own its buffers rather than alias the learner's."""
    return _copy_tree(state)


@lru_cache(maxsize=None)
def _finetune_program(net_cfg: NetConfig, ddpg_cfg: DDPGConfig,
                      n_updates: int):
    """`n_updates` chained DDPG updates under one `lax.scan`, jitted with
    the state donated (off-CPU — see `replay.donate_argnums`): one
    dispatch per fine-tune round instead of one per update, so the
    serving path can fire the whole round asynchronously after a tick
    and never block on it."""
    def run(state, batches):
        def body(s, b):
            s2, _ = ddpg.update(s, b, net_cfg, ddpg_cfg)
            return s2, None
        return jax.lax.scan(body, state, batches, length=n_updates)[0]

    return jax.jit(run, donate_argnums=donate_argnums(0))


def sample_update_batches(replay: SequenceReplay, n_updates: int,
                          batch_size: int):
    """Draw `n_updates` sequence batches stacked on a leading axis — the
    same RNG draw sequence as `n_updates` sequential `sample_sequences`
    calls (the ring does not change between draws of one round, so the
    all-up-front sampling is observationally identical).  None when the
    replay cannot sample yet."""
    if hasattr(replay, "sample_sequence_batches"):
        return replay.sample_sequence_batches(n_updates, batch_size)
    batches = []
    for _ in range(n_updates):
        b = replay.sample_sequences(batch_size)
        if b is None:
            return None
        batches.append(b)
    return jax.tree.map(lambda *xs: np.stack(xs), *batches)


def offline_finetune(state, replay: SequenceReplay, net_cfg: NetConfig,
                     ddpg_cfg: DDPGConfig, n_updates: int, place_on=None):
    """Continually fine-tune the offline learner: `n_updates` DDPG steps
    on the accumulated transitions, dispatched as a single scanned
    program.  Returns (state, updates_done); the returned state is an
    async value — consume it as a program input, or block only when a
    decision actually needs it.  `place_on` hops the sampled batches to
    the learner's device first (the serving path's annex), so the update
    program never mixes device queues."""
    if n_updates <= 0:
        return state, 0
    batches = sample_update_batches(replay, n_updates, ddpg_cfg.batch_size)
    if batches is None:
        return state, 0
    batches = jax.tree.map(jnp.asarray, batches)
    if place_on is not None:
        batches = jax.device_put(batches, place_on)
    state = _finetune_program(net_cfg, ddpg_cfg, n_updates)(state, batches)
    return state, n_updates


# ------------------------------------------------------------ fleet mode
# The tenant axis as a batched device axis: K tenants' learner states
# stacked on a leading axis and fine-tuned by ONE jitted program per
# annex round, instead of K serial `offline_finetune` dispatches.  The
# per-tenant programs are identical — only buffers differ — so the
# stacked program compiles once per (configs, round size, pow2 stack
# width) and the rest of the process-wide program cache stays flat as
# the hot set sweeps (asserted in tests/test_fleet.py).


def fleet_stack_impl(impl: str = "auto") -> str:
    """Resolve the tenant-axis batching implementation.  ``vmap``
    batches the per-tenant math into K-wide kernels — the accelerator
    win — but batched CPU dot kernels accumulate in a different order
    than the serial program, so it is NOT bitwise-equal to K serial
    rounds there (measured: ~190 mismatched leaves at K=3 on the CPU
    PJRT backend).  ``map`` lowers the tenant axis as a `lax.scan` of
    the identical per-tenant computation — bitwise-equal to serial by
    construction (the same discipline as the pool lanes' `lax.map`),
    still one dispatch and one batch hop per round.  ``auto`` picks
    `vmap` off-CPU and `map` on CPU, mirroring `replay.donate_argnums`'s
    backend gate, so every serial-parity guarantee holds where CI runs
    while accelerators get the batched kernels."""
    if impl == "auto":
        import jax as _j
        return "map" if _j.default_backend() == "cpu" else "vmap"
    if impl not in ("vmap", "map"):
        raise ValueError(f"fleet stack impl {impl!r} not in "
                         f"('auto', 'vmap', 'map')")
    return impl


@lru_cache(maxsize=None)
def _fleet_finetune_program(net_cfg: NetConfig, ddpg_cfg: DDPGConfig,
                            n_updates: int, k_pad: int, impl: str):
    """The stacked round: `[K, ...]` learner states x `[K, n_updates,
    ...]` batch stacks -> `[K, ...]` advanced states, as one jitted
    program.  Keyed on the pow2-padded stack width so a warmed ladder
    (1..max_hot) never binds a new entry as the hot-set size changes —
    the cache-flatness the fleet tests assert.  The stacked input is
    donated off-CPU like the serial program's state (the caller stacks
    fresh buffers per round, so per-tenant trees are never aliased)."""
    assert impl in ("vmap", "map"), impl

    def run_one(state, batches):
        def body(s, b):
            s2, _ = ddpg.update(s, b, net_cfg, ddpg_cfg)
            return s2, None
        return jax.lax.scan(body, state, batches, length=n_updates)[0]

    if impl == "vmap":
        run = jax.vmap(run_one)
    else:
        def run(states, batches):
            return jax.lax.map(lambda sb: run_one(*sb), (states, batches))
    return jax.jit(run, donate_argnums=donate_argnums(0))


def fleet_finetune(states: list, batches_list: list, net_cfg: NetConfig,
                   ddpg_cfg: DDPGConfig, n_updates: int, place_on=None,
                   impl: str = "auto", stack_fn=None) -> list:
    """Advance K tenants' offline learners one round each with a single
    stacked program dispatch.  `states[i]` and `batches_list[i]` must
    pair up (the caller draws each tenant's batches from its OWN replay
    RNG, in serial tenant order — that is what makes the stacked round
    bitwise-equal to K serial `offline_finetune` calls under the `map`
    impl).  The stack pads to a power of two with lane 0 repeated; pad
    lanes burn flops, never RNG draws, and their outputs are dropped.
    `stack_fn(*trees)` overrides the eager per-leaf stack (the serving
    layer passes its cached jitted pack program — pure data movement
    either way, so parity is unaffected).  Returns the K advanced
    states (leading-axis slices of one program output)."""
    k = len(states)
    if k == 0:
        return []
    impl = fleet_stack_impl(impl)
    k_pad = 1
    while k_pad < k:
        k_pad *= 2
    pad = k_pad - k
    pad_s = list(states) + [states[0]] * pad
    pad_b = [jax.tree.map(jnp.asarray, b)
             for b in list(batches_list) + [batches_list[0]] * pad]
    if stack_fn is None:
        stacked_s = jax.tree.map(lambda *xs: jnp.stack(xs), *pad_s)
        stacked_b = jax.tree.map(lambda *xs: jnp.stack(xs), *pad_b)
    else:
        stacked_s = stack_fn(*pad_s)
        stacked_b = stack_fn(*pad_b)
    if place_on is not None:
        stacked_s = jax.device_put(stacked_s, place_on)
        stacked_b = jax.device_put(stacked_b, place_on)
    out = _fleet_finetune_program(net_cfg, ddpg_cfg, n_updates, k_pad,
                                  impl)(stacked_s, stacked_b)
    return [jax.tree.map(lambda x: x[i], out) for i in range(k)]


def assess_offline(key, offline_state, net_cfg: NetConfig,
                   env_cfg: E.EnvConfig, et_cfg: ETMDPConfig, data_keys,
                   workload, wr_ratio) -> dict:
    """The assessment episode: run the offline model deterministically on
    the window; the caller compares best runtimes to decide the swap."""
    return rollout_episode(key, offline_state, net_cfg, env_cfg, et_cfg,
                           data_keys, workload, wr_ratio, noise_scale=0.0,
                           deterministic=True)


class O2System:
    def __init__(self, pretrained_state, net_cfg: NetConfig,
                 ddpg_cfg: DDPGConfig, env_cfg: E.EnvConfig,
                 et_cfg: ETMDPConfig, o2_cfg: O2Config = O2Config(),
                 seed: int = 0):
        # real copies: offline_finetune donates its input buffers to the
        # scanned update program, so online / the caller's pretrained
        # state must not alias the learner's tree
        self.online = copy_state(pretrained_state)
        self.offline = copy_state(pretrained_state)
        self.net_cfg, self.ddpg_cfg = net_cfg, ddpg_cfg
        self.env_cfg, self.et_cfg, self.cfg = env_cfg, et_cfg, o2_cfg
        self.replay = make_replay(net_cfg, ddpg_cfg, env_cfg, seed=seed)
        self.monitor = DivergenceMonitor(o2_cfg)
        self.swaps = 0

    # monitor state, surfaced for callers/tests that predate the refactor
    @property
    def windows_seen(self) -> int:
        return self.monitor.windows_seen

    @property
    def divergences(self) -> list[float]:
        return self.monitor.divergences

    @property
    def ref_quantiles(self):
        return self.monitor.ref_quantiles

    @property
    def ref_wr(self):
        return self.monitor.ref_wr

    # ---------- divergence detection ----------
    def observe_window(self, data_keys, wr_ratio: float) -> dict:
        return self.monitor.observe(data_keys, wr_ratio)

    # ---------- the O2 loop on one window ----------
    def tune_window(self, key, data_keys, workload, wr_ratio: float,
                    max_steps: int | None = None) -> dict:
        """Online-tune the current window; offline model keeps learning;
        swap if diverged and offline wins."""
        div = self.observe_window(data_keys, wr_ratio)
        env_cfg = self.env_cfg
        if max_steps is not None:
            env_cfg = env_cfg.with_episode_len(max_steps)

        key, k_on = jax.random.split(key)
        online_summary = rollout_episode(
            k_on, self.online, self.net_cfg, env_cfg, self.et_cfg,
            data_keys, workload, wr_ratio, noise_scale=0.02,
            replay=self.replay, deterministic=False)

        # offline model: continual fine-tuning on accumulated transitions
        self.offline, _ = offline_finetune(
            self.offline, self.replay, self.net_cfg, self.ddpg_cfg,
            self.cfg.offline_updates_per_window)

        swapped = False
        if div["diverged"] and \
                self.monitor.windows_seen % self.cfg.assess_every == 0:
            key, k_off = jax.random.split(key)
            off_summary = assess_offline(
                k_off, self.offline, self.net_cfg, env_cfg, self.et_cfg,
                data_keys, workload, wr_ratio)
            if off_summary["best_runtime_ns"] < online_summary["best_runtime_ns"]:
                self.online = copy_state(self.offline)
                self.swaps += 1
                swapped = True
                self.monitor.re_anchor(data_keys, wr_ratio)

        return {**online_summary, "divergence": div, "swapped": swapped}
