"""Whisper-style encoder-decoder backbone.

The conv/audio frontend is a STUB per the assignment: `input_specs()`
provides precomputed frame embeddings [B, enc_seq, D].  Positions are
sinusoidal (no shape-dependent parameters).  Norm = LayerNorm, MLP = GELU,
no RoPE — all selected via the arch config.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.configs import ArchConfig, LayerKind
from repro.models import attention as attn
from repro.models.layers import (apply_mlp, apply_norm, embed_specs,
                                 embed_tokens, mlp_specs, norm_specs, unembed)
from repro.models.module import stack_specs, trip_scope
from repro.runtime.mesh_utils import constrain

_KIND = LayerKind()  # plain full attention


def _sinusoid(positions: jax.Array, d: int) -> jax.Array:
    half = d // 2
    freqs = jnp.exp(-jnp.arange(half, dtype=jnp.float32)
                    * (jnp.log(10000.0) / max(half - 1, 1)))
    ang = positions.astype(jnp.float32)[:, None] * freqs
    return jnp.concatenate([jnp.sin(ang), jnp.cos(ang)], axis=-1)


def _enc_layer_specs(cfg: ArchConfig) -> dict:
    return {"attn": attn.attn_specs(cfg),
            "mlp": {"norm": norm_specs(cfg), **mlp_specs(cfg)}}


def _dec_layer_specs(cfg: ArchConfig) -> dict:
    return {"self": attn.attn_specs(cfg),
            "cross": attn.attn_specs(cfg, cross=True),
            "mlp": {"norm": norm_specs(cfg), **mlp_specs(cfg)}}


def encdec_specs(cfg: ArchConfig) -> dict:
    return {
        "embed": embed_specs(cfg),
        "enc": {"block": stack_specs(_enc_layer_specs(cfg), cfg.n_enc_layers),
                "final_norm": norm_specs(cfg)},
        "dec": {"block": stack_specs(_dec_layer_specs(cfg), cfg.n_layers)},
        "final_norm": norm_specs(cfg),
    }


# ------------------------------------------------------------------ encoder
def encode(params: dict, enc_embeds: jax.Array, cfg: ArchConfig,
           remat: bool = True) -> jax.Array:
    b, s, d = enc_embeds.shape
    x = enc_embeds + _sinusoid(jnp.arange(s), d)[None].astype(enc_embeds.dtype)
    x = constrain(x, ("batch", None, None))
    positions = jnp.arange(s)

    def body(x, lp):
        x = x + attn.apply_attention(lp["attn"], x, cfg, _KIND, positions,
                                     causal=False)
        h = apply_norm(lp["mlp"]["norm"], x, cfg)
        return x + apply_mlp(lp["mlp"], h, cfg), None

    body_fn = jax.remat(body) if remat else body
    with trip_scope(cfg.n_enc_layers, "enc_layers"):
        x, _ = jax.lax.scan(body_fn, x, params["enc"]["block"])
    return apply_norm(params["enc"]["final_norm"], x, cfg)


def _stacked_cross_kv(params: dict, enc_out: jax.Array, cfg: ArchConfig):
    def body(_, lp):
        k, v = attn.cross_kv(lp["cross"], enc_out, cfg)
        return None, {"k": k, "v": v}
    with trip_scope(cfg.n_layers, "cross_kv"):
        _, kv = jax.lax.scan(body, None, params["dec"]["block"])
    return kv  # leaves stacked [L, B, Se, K, Dh]


# ------------------------------------------------------------------ decoder
def _dec_layer_train(lp, x, cfg, positions, enc_out):
    x = x + attn.apply_attention(lp["self"], x, cfg, _KIND, positions,
                                 causal=True)
    kv = attn.cross_kv(lp["cross"], enc_out, cfg)
    x = x + attn.apply_cross_attention(lp["cross"], x, cfg, kv)
    h = apply_norm(lp["mlp"]["norm"], x, cfg)
    return x + apply_mlp(lp["mlp"], h, cfg)


def encdec_apply(params: dict, tokens: jax.Array, enc_embeds: jax.Array,
                 cfg: ArchConfig, remat: bool = True):
    """Training forward. Returns (logits [B,S,V] f32, aux=0)."""
    enc_out = encode(params, enc_embeds, cfg, remat=remat)
    b, s = tokens.shape
    x = embed_tokens(params["embed"], tokens)
    x = x + _sinusoid(jnp.arange(s), cfg.d_model)[None].astype(x.dtype)
    positions = jnp.arange(s)

    def body(x, lp):
        return _dec_layer_train(lp, x, cfg, positions, enc_out), None

    body_fn = jax.remat(body) if remat else body
    with trip_scope(cfg.n_layers, "dec_layers"):
        x, _ = jax.lax.scan(body_fn, x, params["dec"]["block"])
    x = apply_norm(params["final_norm"], x, cfg)
    return unembed(params["embed"], x, cfg), jnp.float32(0.0)


def encdec_loss(params, tokens, labels, cfg, enc_embeds, remat: bool = True):
    from repro.models.layers import softmax_cross_entropy
    logits, aux = encdec_apply(params, tokens, enc_embeds, cfg, remat=remat)
    return softmax_cross_entropy(logits, labels) + aux


# ------------------------------------------------------------------ serving
def encdec_prefill(params: dict, tokens: jax.Array, enc_embeds: jax.Array,
                   cfg: ArchConfig, max_len: int = 0):
    """Prefill decoder self-cache + precompute cross kv.

    Returns (last logits [B,V], cache={"self": {...}, "cross": {...}, }).
    """
    enc_out = encode(params, enc_embeds, cfg, remat=False)
    cross = _stacked_cross_kv(params, enc_out, cfg)
    b, s = tokens.shape
    x = embed_tokens(params["embed"], tokens)
    x = x + _sinusoid(jnp.arange(s), cfg.d_model)[None].astype(x.dtype)
    positions = jnp.arange(s)

    def body(x, xs):
        lp, ckv = xs
        y, cache = attn.prefill_attention(lp["self"], x, cfg, _KIND, positions,
                                          max_len=max_len)
        x = x + y
        x = x + attn.apply_cross_attention(lp["cross"], x, cfg,
                                           (ckv["k"], ckv["v"]))
        h = apply_norm(lp["mlp"]["norm"], x, cfg)
        return x + apply_mlp(lp["mlp"], h, cfg), cache

    with trip_scope(cfg.n_layers, "dec_layers"):
        x, self_cache = jax.lax.scan(body, x, (params["dec"]["block"], cross))
    x = apply_norm(params["final_norm"], x[:, -1:], cfg)
    logits = unembed(params["embed"], x, cfg)[:, 0]
    return logits, {"self": self_cache, "cross": cross}


def encdec_decode_step(params: dict, token: jax.Array, cache: dict,
                       pos: jax.Array, cfg: ArchConfig):
    x = embed_tokens(params["embed"], token[:, None])
    x = x + _sinusoid(pos[None], cfg.d_model)[None].astype(x.dtype)

    def body(x, xs):
        lp, self_c, ckv = xs
        y, new_c = attn.decode_attention(lp["self"], x, cfg, _KIND, self_c, pos)
        x = x + y
        x = x + attn.apply_cross_attention(lp["cross"], x, cfg,
                                           (ckv["k"], ckv["v"]))
        h = apply_norm(lp["mlp"]["norm"], x, cfg)
        return x + apply_mlp(lp["mlp"], h, cfg), new_c

    with trip_scope(cfg.n_layers, "dec_layers"):
        x, new_self = jax.lax.scan(
            body, x, (params["dec"]["block"], cache["self"], cache["cross"]))
    x = apply_norm(params["final_norm"], x, cfg)
    logits = unembed(params["embed"], x, cfg)[:, 0]
    return logits, {"self": new_self, "cross": cache["cross"]}


def encdec_cache_specs(cfg: ArchConfig, batch: int, seq: int,
                       dtype=jnp.bfloat16):
    """ShapeDtypeStruct + logical-axes trees for the whisper decode cache."""
    k, dh = cfg.n_kv_heads, cfg.resolved_head_dim
    L = cfg.n_layers
    self_shape = (L, batch, k, seq, dh)
    cross_shape = (L, batch, cfg.enc_seq, k, dh)
    self_axes = ("layers", "cache_batch", "kv_heads", "cache_seq", "head_dim")
    cross_axes = ("layers", "cache_batch", None, "kv_heads", "head_dim")
    sds = {"self": {"k": jax.ShapeDtypeStruct(self_shape, dtype),
                    "v": jax.ShapeDtypeStruct(self_shape, dtype)},
           "cross": {"k": jax.ShapeDtypeStruct(cross_shape, dtype),
                     "v": jax.ShapeDtypeStruct(cross_shape, dtype)}}
    axes = {"self": {"k": self_axes, "v": self_axes},
            "cross": {"k": cross_axes, "v": cross_axes}}
    return sds, axes
