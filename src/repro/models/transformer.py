"""Decoder-only LM assembled from pattern-periodic layer blocks.

Layers are grouped by the smallest period P of the layer-kind pattern
(P=1 dense/MoE/SSM, P=6 gemma3 5:1 local:global, P=8 jamba 1:7 attn:mamba).
Weights for each in-block position are stacked over the n_blocks axis and the
whole block is scanned (jax.lax.scan) -> compact HLO at any depth; leftover
layers (34 = 5*6 + 4) run unrolled as the "tail".

Decode threads a cache pytree with the same block/tail structure through the
scan (cache slices as xs, updated slices as ys).
"""
from __future__ import annotations

from typing import Any

import jax
import jax.numpy as jnp

from repro.configs import ArchConfig, LayerKind
from repro.models import attention as attn
from repro.models import mamba as ssm
from repro.models.layers import (apply_mlp, apply_norm, embed_specs,
                                 embed_tokens, mlp_specs, norm_specs, unembed)
from repro.models.module import stack_specs, trip_scope
from repro.models.moe import apply_moe, moe_specs
from repro.runtime import mesh_utils
from repro.runtime.mesh_utils import constrain


def _moe(p, x, cfg):
    """MoE implementation dispatch: explicit-all_to_all shard_map EP when
    requested and a model-parallel mesh is ambient; GSPMD otherwise."""
    if cfg.moe_impl == "shard_map":
        mesh = mesh_utils._current_mesh()
        if mesh is not None and mesh_utils.axis_size(
                mesh, mesh_utils.MODEL_AXIS) > 1:
            dp = mesh_utils.axis_size(mesh, mesh_utils.DATA_AXES)
            if x.shape[0] % dp == 0:  # batch must split over the data axes
                from repro.models.moe_shard_map import apply_moe_shard_map
                return apply_moe_shard_map(p, x, cfg, mesh)
    return apply_moe(p, x, cfg)


# ------------------------------------------------------------------ specs
def layer_specs(cfg: ArchConfig, kind: LayerKind) -> dict:
    d: dict[str, Any] = {}
    if kind.mixer == "mamba":
        d["mixer"] = ssm.mamba_specs(cfg)
    else:
        d["mixer"] = attn.attn_specs(cfg)
    if kind.mlp == "dense":
        d["mlp"] = {"norm": norm_specs(cfg), **mlp_specs(cfg)}
    elif kind.mlp == "moe":
        d["mlp"] = moe_specs(cfg)
    return d


def stack_structure(cfg: ArchConfig) -> tuple[int, int, int]:
    """(period, n_blocks, n_tail)."""
    period = cfg.block_period()
    n_blocks = cfg.n_layers // period
    return period, n_blocks, cfg.n_layers - n_blocks * period


def lm_specs(cfg: ArchConfig) -> dict:
    period, n_blocks, n_tail = stack_structure(cfg)
    kinds = cfg.layer_kinds()
    block = {f"sub{j}": stack_specs(layer_specs(cfg, kinds[j]), n_blocks)
             for j in range(period)}
    tail = {f"tail{t}": layer_specs(cfg, kinds[n_blocks * period + t])
            for t in range(n_tail)}
    return {
        "embed": embed_specs(cfg),
        "block": block,
        "tail": tail,
        "final_norm": norm_specs(cfg),
    }


# ------------------------------------------------------------------ cache
def layer_cache_spec(cfg: ArchConfig, kind: LayerKind, batch: int, seq: int):
    if kind.mixer == "mamba":
        return ssm.init_ssm_cache(cfg, batch)
    return attn.init_kv_cache(cfg, kind, batch, seq)


def cache_specs(cfg: ArchConfig, batch: int, seq: int,
                dtype=jnp.bfloat16) -> tuple[dict, dict]:
    """Returns (ShapeDtypeStruct pytree, logical-axes pytree) for the cache."""
    period, n_blocks, n_tail = stack_structure(cfg)
    kinds = cfg.layer_kinds()

    def leaf(shape, axes, stacked):
        if stacked:
            shape, axes = (n_blocks,) + shape, ("layers",) + axes
        return jax.ShapeDtypeStruct(shape, dtype), axes

    def cache_for(kind, stacked):
        spec = layer_cache_spec(cfg, kind, batch, seq)
        sds, axes = {}, {}
        for name, entry in spec.items():
            shape, ax = entry[0], entry[1]
            dt = entry[2] if len(entry) > 2 else (
                jnp.float32 if (kind.mixer == "mamba" and name == "ssm")
                else dtype)
            s, a = leaf(shape, ax, stacked)
            sds[name] = jax.ShapeDtypeStruct(s.shape, dt)
            axes[name] = a
        return sds, axes

    sds_tree: dict = {"block": {}, "tail": {}}
    axes_tree: dict = {"block": {}, "tail": {}}
    for j in range(period):
        sds_tree["block"][f"sub{j}"], axes_tree["block"][f"sub{j}"] = \
            cache_for(kinds[j], stacked=True)
    for t in range(n_tail):
        kind = kinds[n_blocks * period + t]
        sds_tree["tail"][f"tail{t}"], axes_tree["tail"][f"tail{t}"] = \
            cache_for(kind, stacked=False)
    return sds_tree, axes_tree


def zero_cache(cfg: ArchConfig, batch: int, seq: int, dtype=jnp.bfloat16):
    sds, _ = cache_specs(cfg, batch, seq, dtype)
    return jax.tree.map(lambda s: jnp.zeros(s.shape, s.dtype), sds)


# ------------------------------------------------------------------ layer fns
def apply_layer_train(p: dict, kind: LayerKind, x: jax.Array, cfg: ArchConfig,
                      positions: jax.Array):
    aux = jnp.float32(0.0)
    if kind.mixer == "mamba":
        x = x + ssm.apply_mamba(p["mixer"], x, cfg)
    else:
        x = x + attn.apply_attention(p["mixer"], x, cfg, kind, positions)
    x = constrain(x, ("batch", "seq", None))
    if kind.mlp == "dense":
        h = apply_norm(p["mlp"]["norm"], x, cfg)
        x = x + apply_mlp(p["mlp"], h, cfg)
    elif kind.mlp == "moe":
        y, aux = _moe(p["mlp"], x, cfg)
        x = x + y
    x = constrain(x, ("batch", "seq", None))
    return x, aux


def apply_layer_prefill(p: dict, kind: LayerKind, x: jax.Array,
                        cfg: ArchConfig, positions: jax.Array,
                        max_len: int = 0):
    """Like train, but also emits the layer's decode cache."""
    if kind.mixer == "mamba":
        y, cache = ssm.prefill_mamba(p["mixer"], x, cfg)
        x = x + y
    else:
        y, cache = attn.prefill_attention(p["mixer"], x, cfg, kind, positions,
                                          max_len=max_len)
        x = x + y
    if kind.mlp == "dense":
        h = apply_norm(p["mlp"]["norm"], x, cfg)
        x = x + apply_mlp(p["mlp"], h, cfg)
    elif kind.mlp == "moe":
        y, _ = _moe(p["mlp"], x, cfg)
        x = x + y
    return x, cache


def apply_layer_decode(p: dict, kind: LayerKind, x: jax.Array,
                       cfg: ArchConfig, cache: dict, pos: jax.Array):
    if kind.mixer == "mamba":
        y, new_cache = ssm.decode_mamba(p["mixer"], x, cfg, cache)
    else:
        y, new_cache = attn.decode_attention(p["mixer"], x, cfg, kind, cache, pos)
    x = x + y
    if kind.mlp == "dense":
        h = apply_norm(p["mlp"]["norm"], x, cfg)
        x = x + apply_mlp(p["mlp"], h, cfg)
    elif kind.mlp == "moe":
        y, _ = _moe(p["mlp"], x, cfg)
        x = x + y
    return x, new_cache


# ------------------------------------------------------------------ stacks
def apply_stack_train(params: dict, x: jax.Array, cfg: ArchConfig,
                      positions: jax.Array, remat: bool = True):
    period, n_blocks, n_tail = stack_structure(cfg)
    kinds = cfg.layer_kinds()

    def block_body(carry, block_params):
        x, aux = carry
        for j in range(period):
            x, a = apply_layer_train(block_params[f"sub{j}"], kinds[j], x,
                                     cfg, positions)
            aux = aux + a
        return (x, aux), None

    body = jax.remat(block_body) if remat else block_body
    if n_blocks == 1:
        (x, aux), _ = body((x, jnp.float32(0.0)),
                           jax.tree.map(lambda t: t[0], params["block"]))
    else:
        with trip_scope(n_blocks, "layers"):
            (x, aux), _ = jax.lax.scan(body, (x, jnp.float32(0.0)),
                                       params["block"])
    for t in range(n_tail):
        x, a = apply_layer_train(params["tail"][f"tail{t}"],
                                 kinds[n_blocks * period + t], x, cfg, positions)
        aux = aux + a
    return x, aux


def apply_stack_prefill(params: dict, x: jax.Array, cfg: ArchConfig,
                        positions: jax.Array, max_len: int = 0):
    period, n_blocks, n_tail = stack_structure(cfg)
    kinds = cfg.layer_kinds()

    def block_body(x, block_params):
        caches = {}
        for j in range(period):
            x, caches[f"sub{j}"] = apply_layer_prefill(
                block_params[f"sub{j}"], kinds[j], x, cfg, positions,
                max_len=max_len)
        return x, caches

    if n_blocks == 1:
        x, caches = block_body(x, jax.tree.map(lambda t: t[0], params["block"]))
        cache_block = jax.tree.map(lambda t: t[None], caches)
    else:
        with trip_scope(n_blocks, "layers"):
            x, cache_block = jax.lax.scan(jax.remat(block_body), x,
                                          params["block"])
    cache_tail = {}
    for t in range(n_tail):
        x, cache_tail[f"tail{t}"] = apply_layer_prefill(
            params["tail"][f"tail{t}"], kinds[n_blocks * period + t], x, cfg,
            positions, max_len=max_len)
    return x, {"block": cache_block, "tail": cache_tail}


def apply_stack_decode(params: dict, x: jax.Array, cfg: ArchConfig,
                       cache: dict, pos: jax.Array):
    period, n_blocks, n_tail = stack_structure(cfg)
    kinds = cfg.layer_kinds()

    def block_body(x, xs):
        block_params, block_cache = xs
        new_cache = {}
        for j in range(period):
            x, new_cache[f"sub{j}"] = apply_layer_decode(
                block_params[f"sub{j}"], kinds[j], x, cfg,
                block_cache[f"sub{j}"], pos)
        return x, new_cache

    if n_blocks == 1:
        x, caches = block_body(x, jax.tree.map(lambda t: t[0],
                                               (params["block"], cache["block"])))
        new_block = jax.tree.map(lambda t: t[None], caches)
    else:
        with trip_scope(n_blocks, "layers"):
            x, new_block = jax.lax.scan(block_body, x,
                                        (params["block"], cache["block"]))
    new_tail = {}
    for t in range(n_tail):
        x, new_tail[f"tail{t}"] = apply_layer_decode(
            params["tail"][f"tail{t}"], kinds[n_blocks * period + t], x, cfg,
            cache["tail"][f"tail{t}"], pos)
    return x, {"block": new_block, "tail": new_tail}


# ------------------------------------------------------------------ LM API
def lm_apply(params: dict, tokens: jax.Array, cfg: ArchConfig,
             frontend_embeds: jax.Array | None = None, remat: bool = True):
    """Full forward for training. Returns (logits f32 [B,S,V], aux_loss)."""
    b, s = tokens.shape
    x = embed_tokens(params["embed"], tokens)
    if frontend_embeds is not None:  # vlm/audio stub: overwrite leading slots
        x = jax.lax.dynamic_update_slice_in_dim(
            x, frontend_embeds.astype(x.dtype), 0, axis=1)
    positions = jnp.arange(s)
    x, aux = apply_stack_train(params, x, cfg, positions, remat=remat)
    x = apply_norm(params["final_norm"], x, cfg)
    return unembed(params["embed"], x, cfg), aux


def lm_prefill(params: dict, tokens: jax.Array, cfg: ArchConfig,
               frontend_embeds: jax.Array | None = None, max_len: int = 0):
    """Prefill: returns (last-position logits [B,V], cache)."""
    b, s = tokens.shape
    x = embed_tokens(params["embed"], tokens)
    if frontend_embeds is not None:
        x = jax.lax.dynamic_update_slice_in_dim(
            x, frontend_embeds.astype(x.dtype), 0, axis=1)
    positions = jnp.arange(s)
    x, cache = apply_stack_prefill(params, x, cfg, positions, max_len=max_len)
    x_last = x[:, -1:]
    x_last = apply_norm(params["final_norm"], x_last, cfg)
    logits = unembed(params["embed"], x_last, cfg)[:, 0]
    return logits, cache


def lm_decode_step(params: dict, token: jax.Array, cache: dict,
                   pos: jax.Array, cfg: ArchConfig):
    """One decode step: token [B] int32, pos scalar -> (logits [B,V], cache)."""
    x = embed_tokens(params["embed"], token[:, None])
    x, new_cache = apply_stack_decode(params, x, cfg, cache, pos)
    x = apply_norm(params["final_norm"], x, cfg)
    logits = unembed(params["embed"], x, cfg)[:, 0]
    return logits, new_cache


def lm_loss(params: dict, tokens: jax.Array, labels: jax.Array,
            cfg: ArchConfig, frontend_embeds=None, remat: bool = True):
    from repro.models.layers import softmax_cross_entropy
    logits, aux = lm_apply(params, tokens, cfg, frontend_embeds, remat=remat)
    return softmax_cross_entropy(logits, labels) + aux
