"""Mamba-1 selective-SSM block (falcon-mamba / jamba mixer).

Training/prefill uses a chunked sequential scan: outer `lax.scan` over
sequence chunks (rematerialized) with an inner exact recurrence, so the
saved residuals are only the chunk-boundary states [B, Di, N] instead of
[B, S, Di, N].  Decode is a single O(1) state update.

The depthwise causal conv (kernel 4) is expressed as a sum of shifted
arrays (no conv op -> simpler HLO for the roofline parser).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.configs import ArchConfig
from repro.models.layers import PARAM_DTYPE, apply_norm, norm_specs
from repro.models.module import ParamSpec, const_init, ones_init, trip_scope, zeros_init
from repro.runtime.mesh_utils import constrain


def mamba_specs(cfg: ArchConfig) -> dict:
    d, di, n = cfg.d_model, cfg.d_inner, cfg.ssm_state
    r, kc = cfg.resolved_dt_rank, cfg.ssm_conv

    def a_log_init(key, shape, dtype):
        # S4D-real init: A = -(1..N) per channel; honors stacked shapes
        a = jnp.broadcast_to(jnp.arange(1, shape[-1] + 1, dtype=jnp.float32),
                             shape)
        return jnp.log(a).astype(dtype)

    return {
        "norm": norm_specs(cfg),
        "in_proj": ParamSpec((d, 2 * di), PARAM_DTYPE, ("embed", "d_inner")),
        "conv_w": ParamSpec((kc, di), jnp.float32, ("conv", "d_inner"),
                            const_init(1.0 / kc)),
        "conv_b": ParamSpec((di,), jnp.float32, ("d_inner",), zeros_init()),
        "x_proj": ParamSpec((di, r + 2 * n), PARAM_DTYPE, ("d_inner", "generic")),
        "dt_proj": ParamSpec((r, di), PARAM_DTYPE, ("dt_rank", "d_inner")),
        "dt_bias": ParamSpec((di,), jnp.float32, ("d_inner",), const_init(-4.6)),
        "a_log": ParamSpec((di, n), jnp.float32, ("d_inner", "state"), a_log_init),
        "d_skip": ParamSpec((di,), jnp.float32, ("d_inner",), ones_init()),
        "out_proj": ParamSpec((di, d), PARAM_DTYPE, ("d_inner", "embed")),
    }


def _causal_conv(xs: jax.Array, w: jax.Array, b: jax.Array,
                 state: jax.Array | None = None):
    """xs [B,S,Di]; w [K,Di]; optional state [B,K-1,Di] of trailing inputs.

    Returns (conv_out [B,S,Di] f32, new_state [B,K-1,Di]).
    """
    kc = w.shape[0]
    if state is None:
        state = jnp.zeros((xs.shape[0], kc - 1, xs.shape[2]), xs.dtype)
    ext = jnp.concatenate([state.astype(xs.dtype), xs], axis=1)  # [B,S+K-1,Di]
    out = jnp.zeros(xs.shape, jnp.float32)
    for i in range(kc):  # kernel taps as shifted adds (K=4)
        out = out + ext[:, i:i + xs.shape[1]].astype(jnp.float32) * w[i]
    new_state = ext[:, ext.shape[1] - (kc - 1):]
    return out + b, new_state


def _ssm_coeffs(p: dict, cfg: ArchConfig, u: jax.Array):
    """u [B,S,Di] (post-conv, post-silu) -> per-step (dA, dBu, C)."""
    r, n = cfg.resolved_dt_rank, cfg.ssm_state
    xdb = jnp.einsum("bsd,de->bse", u.astype(PARAM_DTYPE), p["x_proj"])
    dt = jax.nn.softplus(
        jnp.einsum("bsr,rd->bsd", xdb[..., :r], p["dt_proj"]).astype(jnp.float32)
        + p["dt_bias"])                                     # [B,S,Di]
    b_mat = xdb[..., r:r + n].astype(jnp.float32)           # [B,S,N]
    c_mat = xdb[..., r + n:].astype(jnp.float32)            # [B,S,N]
    a = -jnp.exp(p["a_log"])                                # [Di,N]
    return dt, b_mat, c_mat, a


def _scan_chunk(h0, u_c, dt_c, b_c, c_c, a):
    """Exact recurrence over one chunk; inputs [B,c,...]; h0 [B,Di,N]."""
    def step(h, inp):
        u_t, dt_t, b_t, c_t = inp  # [B,Di],[B,Di],[B,N],[B,N]
        da = jnp.exp(dt_t[..., None] * a)                  # [B,Di,N]
        dbu = (dt_t * u_t)[..., None] * b_t[:, None, :]    # [B,Di,N]
        h = h * da + dbu
        y = jnp.einsum("bdn,bn->bd", h, c_t)               # [B,Di]
        return h, y

    xs = (u_c.swapaxes(0, 1), dt_c.swapaxes(0, 1),
          b_c.swapaxes(0, 1), c_c.swapaxes(0, 1))
    with trip_scope(u_c.shape[1], "ssm_inner"):
        h, ys = jax.lax.scan(step, h0, xs)
    return h, ys.swapaxes(0, 1)  # [B,c,Di]


def _mamba_fwd(p: dict, x: jax.Array, cfg: ArchConfig, chunk: int = 256):
    """Full-sequence mamba block. Returns (out, (final_h, conv_state))."""
    b, s, _ = x.shape
    di = cfg.d_inner
    hx = apply_norm(p["norm"], x, cfg)
    xz = jnp.einsum("bsd,de->bse", hx, p["in_proj"])
    xs_in, z = xz[..., :di], xz[..., di:]
    xs_in = constrain(xs_in, ("batch", None, "d_inner"))
    conv, conv_state = _causal_conv(xs_in, p["conv_w"], p["conv_b"])
    u = jax.nn.silu(conv)                                   # [B,S,Di] f32
    dt, b_mat, c_mat, a = _ssm_coeffs(p, cfg, u)

    c = min(chunk, s)
    while s % c:
        c //= 2
    nchunk = s // c

    def outer(h, sl):
        u_c, dt_c, b_c, c_c = sl
        h, y = _scan_chunk(h, u_c, dt_c, b_c, c_c, a)
        return h, y

    h0 = jnp.zeros((b, di, cfg.ssm_state), jnp.float32)
    if nchunk == 1:
        h_fin, y = outer(h0, (u, dt, b_mat, c_mat))
    else:
        resh = lambda t: t.reshape(b, nchunk, c, *t.shape[2:]).swapaxes(0, 1)
        with trip_scope(nchunk, "ssm_chunks"):
            h_fin, y = jax.lax.scan(
                jax.remat(outer), h0, (resh(u), resh(dt), resh(b_mat), resh(c_mat)))
        y = y.swapaxes(0, 1).reshape(b, s, di)
    y = y + u * p["d_skip"]
    y = y * jax.nn.silu(z.astype(jnp.float32))
    y = constrain(y.astype(x.dtype), ("batch", None, "d_inner"))
    return jnp.einsum("bse,ed->bsd", y, p["out_proj"]), (h_fin, conv_state)


def apply_mamba(p: dict, x: jax.Array, cfg: ArchConfig,
                chunk: int = 256) -> jax.Array:
    return _mamba_fwd(p, x, cfg, chunk)[0]


def prefill_mamba(p: dict, x: jax.Array, cfg: ArchConfig, chunk: int = 256):
    out, (h_fin, conv_state) = _mamba_fwd(p, x, cfg, chunk)
    cache = {"conv": conv_state.astype(jnp.bfloat16), "ssm": h_fin}
    return out, cache


# ------------------------------------------------------------------
def init_ssm_cache(cfg: ArchConfig, batch: int):
    di, n, kc = cfg.d_inner, cfg.ssm_state, cfg.ssm_conv
    return {
        "conv": ((batch, kc - 1, di), ("cache_batch", "conv", "d_inner")),
        "ssm": ((batch, di, n), ("cache_batch", "d_inner", "state")),
    }


def decode_mamba(p: dict, x: jax.Array, cfg: ArchConfig, cache: dict):
    """One-step mamba update. x [B,1,D]; cache {conv:[B,K-1,Di], ssm:[B,Di,N]}."""
    di = cfg.d_inner
    hx = apply_norm(p["norm"], x, cfg)
    xz = jnp.einsum("bsd,de->bse", hx, p["in_proj"])
    xs_in, z = xz[..., :di], xz[..., di:]
    conv, conv_state = _causal_conv(xs_in, p["conv_w"], p["conv_b"],
                                    state=cache["conv"])
    u = jax.nn.silu(conv)                                   # [B,1,Di]
    dt, b_mat, c_mat, a = _ssm_coeffs(p, cfg, u)
    da = jnp.exp(dt[:, 0, :, None] * a)                     # [B,Di,N]
    dbu = (dt[:, 0] * u[:, 0])[..., None] * b_mat[:, 0][:, None, :]
    h = cache["ssm"] * da + dbu
    y = jnp.einsum("bdn,bn->bd", h, c_mat[:, 0])[:, None]   # [B,1,Di]
    y = y + u * p["d_skip"]
    y = y * jax.nn.silu(z.astype(jnp.float32))
    out = jnp.einsum("bse,ed->bsd", y.astype(x.dtype), p["out_proj"])
    return out, {"conv": conv_state.astype(cache["conv"].dtype), "ssm": h}
