"""Minimal functional module system (no flax dependency).

A model definition is a pytree of `ParamSpec`s.  From it we derive:
  * real parameters           (init_params)     -- for smoke tests / training
  * abstract parameters       (abstract_params) -- ShapeDtypeStructs, dry-run
  * logical-axis annotations  (axes_tree)       -- for sharding rules

Apply functions are plain jax-traceable functions over the params pytree.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Callable

import jax
import jax.numpy as jnp

Initializer = Callable[[jax.Array, tuple, jnp.dtype], jax.Array]


def normal_init(stddev: float) -> Initializer:
    def init(key, shape, dtype):
        return (jax.random.normal(key, shape, jnp.float32) * stddev).astype(dtype)
    return init


def fan_in_init(axis: int = -2) -> Initializer:
    """Lecun-normal-style init with fan-in = prod of contracted dims."""
    def init(key, shape, dtype):
        # By convention the *last* axis is the output feature axis; everything
        # else is fan-in.  Works for [D,F], [D,H,Dh] (out = H*Dh), [E,D,F].
        fan_in = max(1, int(jnp.prod(jnp.array(shape[:-1]))) if len(shape) == 1
                     else math.prod(shape[:-1]))
        std = 1.0 / math.sqrt(fan_in)
        return (jax.random.normal(key, shape, jnp.float32) * std).astype(dtype)
    return init


def zeros_init() -> Initializer:
    return lambda key, shape, dtype: jnp.zeros(shape, dtype)


def ones_init() -> Initializer:
    return lambda key, shape, dtype: jnp.ones(shape, dtype)


def const_init(value: float) -> Initializer:
    return lambda key, shape, dtype: jnp.full(shape, value, dtype)


@dataclasses.dataclass(frozen=True)
class ParamSpec:
    shape: tuple
    dtype: jnp.dtype
    axes: tuple  # logical axis names, len == len(shape)
    init: Initializer = dataclasses.field(default=fan_in_init(), repr=False)

    def __post_init__(self):
        assert len(self.shape) == len(self.axes), (self.shape, self.axes)

    def abstract(self) -> jax.ShapeDtypeStruct:
        return jax.ShapeDtypeStruct(self.shape, self.dtype)


def is_spec(x) -> bool:
    return isinstance(x, ParamSpec)


def init_params(specs, key: jax.Array):
    leaves, treedef = jax.tree.flatten(specs, is_leaf=is_spec)
    keys = jax.random.split(key, len(leaves))
    vals = [s.init(k, s.shape, s.dtype) for s, k in zip(leaves, keys)]
    return jax.tree.unflatten(treedef, vals)


def abstract_params(specs):
    return jax.tree.map(lambda s: s.abstract(), specs, is_leaf=is_spec)


def axes_tree(specs):
    return jax.tree.map(lambda s: s.axes, specs, is_leaf=is_spec)


def stack_specs(spec_tree, n: int, axis_name: str = "layers"):
    """Prepend a stacked leading dim (for scan-over-layers weight stacking)."""
    def stack(s: ParamSpec) -> ParamSpec:
        return ParamSpec((n,) + s.shape, s.dtype, (axis_name,) + s.axes, s.init)
    return jax.tree.map(stack, spec_tree, is_leaf=is_spec)


def param_count(specs) -> int:
    leaves = jax.tree.leaves(specs, is_leaf=is_spec)
    return sum(math.prod(s.shape) for s in leaves)


def param_bytes(specs) -> int:
    leaves = jax.tree.leaves(specs, is_leaf=is_spec)
    return sum(math.prod(s.shape) * jnp.dtype(s.dtype).itemsize for s in leaves)


def trip_scope(n: int, tag: str = "scan"):
    """named_scope whose name encodes a loop trip count.

    runtime/hlo_analysis.py recovers while-loop trip counts from these scope
    names in HLO op metadata ("<tag>_trip<n>"), which lets the roofline
    analysis scale scan bodies correctly even though XLA's cost_analysis
    counts a while body only once.
    """
    return jax.named_scope(f"{tag}_trip{n}")
