"""Shared layers: norms, RoPE, MLPs, embedding/unembedding.

All functions are pure; parameters are dicts of arrays built from the
ParamSpec trees in this module's ``*_specs`` helpers.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.configs import ArchConfig
from repro.models.module import ParamSpec, fan_in_init, normal_init, ones_init, zeros_init
from repro.runtime.mesh_utils import constrain

PARAM_DTYPE = jnp.bfloat16


# ----------------------------------------------------------- norms
def norm_specs(cfg: ArchConfig) -> dict:
    d = cfg.d_model
    if cfg.norm_type == "layernorm":
        return {"scale": ParamSpec((d,), jnp.float32, ("embed",), ones_init()),
                "bias": ParamSpec((d,), jnp.float32, ("embed",), zeros_init())}
    return {"scale": ParamSpec((d,), jnp.float32, ("embed",), ones_init())}


def apply_norm(p: dict, x: jax.Array, cfg: ArchConfig) -> jax.Array:
    x32 = x.astype(jnp.float32)
    if cfg.norm_type == "layernorm":
        mu = jnp.mean(x32, axis=-1, keepdims=True)
        var = jnp.mean(jnp.square(x32 - mu), axis=-1, keepdims=True)
        y = (x32 - mu) * jax.lax.rsqrt(var + cfg.norm_eps)
        y = y * p["scale"] + p["bias"]
    else:
        ms = jnp.mean(jnp.square(x32), axis=-1, keepdims=True)
        y = x32 * jax.lax.rsqrt(ms + cfg.norm_eps) * p["scale"]
    return y.astype(x.dtype)


# ----------------------------------------------------------- rope
def rope_angles(positions: jax.Array, head_dim: int, theta: float) -> tuple:
    """positions [...]-shaped int array -> (sin, cos) of [..., head_dim//2]."""
    half = head_dim // 2
    freqs = theta ** (-jnp.arange(0, half, dtype=jnp.float32) / half)
    ang = positions.astype(jnp.float32)[..., None] * freqs  # [..., half]
    return jnp.sin(ang), jnp.cos(ang)


def apply_rope(x: jax.Array, sin: jax.Array, cos: jax.Array) -> jax.Array:
    """x [..., S, H, Dh]; sin/cos [..., S, Dh//2] broadcast over heads."""
    half = x.shape[-1] // 2
    x1, x2 = x[..., :half], x[..., half:]
    s = sin[..., None, :]  # add head axis
    c = cos[..., None, :]
    x32_1, x32_2 = x1.astype(jnp.float32), x2.astype(jnp.float32)
    return jnp.concatenate(
        [x32_1 * c - x32_2 * s, x32_2 * c + x32_1 * s], axis=-1).astype(x.dtype)


# ----------------------------------------------------------- MLP
def mlp_specs(cfg: ArchConfig) -> dict:
    d, f = cfg.d_model, cfg.d_ff
    if cfg.mlp_variant == "gelu":
        return {
            "wi": ParamSpec((d, f), PARAM_DTYPE, ("embed", "mlp")),
            "bi": ParamSpec((f,), jnp.float32, ("mlp",), zeros_init()),
            "wo": ParamSpec((f, d), PARAM_DTYPE, ("mlp", "embed")),
            "bo": ParamSpec((d,), jnp.float32, ("embed",), zeros_init()),
        }
    return {
        "wg": ParamSpec((d, f), PARAM_DTYPE, ("embed", "mlp")),
        "wu": ParamSpec((d, f), PARAM_DTYPE, ("embed", "mlp")),
        "wd": ParamSpec((f, d), PARAM_DTYPE, ("mlp", "embed")),
    }


def apply_mlp(p: dict, x: jax.Array, cfg: ArchConfig) -> jax.Array:
    if cfg.mlp_variant == "gelu":
        h = jnp.einsum("...d,df->...f", x, p["wi"]) + p["bi"].astype(x.dtype)
        h = jax.nn.gelu(h.astype(jnp.float32)).astype(x.dtype)
        return jnp.einsum("...f,fd->...d", h, p["wo"]) + p["bo"].astype(x.dtype)
    g = jnp.einsum("...d,df->...f", x, p["wg"])
    u = jnp.einsum("...d,df->...f", x, p["wu"])
    h = jax.nn.silu(g.astype(jnp.float32)).astype(x.dtype) * u
    h = constrain(h, (None,) * (h.ndim - 1) + ("mlp",))
    return jnp.einsum("...f,fd->...d", h, p["wd"])


# ----------------------------------------------------------- embedding
def embed_specs(cfg: ArchConfig) -> dict:
    specs = {"embedding": ParamSpec((cfg.vocab_size, cfg.d_model), PARAM_DTYPE,
                                    ("vocab", "embed"), normal_init(0.02))}
    if not cfg.tie_embeddings:
        specs["lm_head"] = ParamSpec((cfg.d_model, cfg.vocab_size), PARAM_DTYPE,
                                     ("embed", "vocab"), fan_in_init())
    return specs


def embed_tokens(p: dict, tokens: jax.Array) -> jax.Array:
    x = p["embedding"][tokens]
    return constrain(x, ("batch",) + (None,) * (x.ndim - 1))


def unembed(p: dict, x: jax.Array, cfg: ArchConfig) -> jax.Array:
    if cfg.tie_embeddings:
        logits = jnp.einsum("...d,vd->...v", x, p["embedding"],
                            preferred_element_type=jnp.float32)
    else:
        logits = jnp.einsum("...d,dv->...v", x, p["lm_head"],
                            preferred_element_type=jnp.float32)
    return logits


def softmax_cross_entropy(logits: jax.Array, labels: jax.Array) -> jax.Array:
    """logits [..., V] f32, labels [...] int -> mean nll."""
    logp = jax.nn.log_softmax(logits, axis=-1)
    nll = -jnp.take_along_axis(logp, labels[..., None], axis=-1)[..., 0]
    return jnp.mean(nll)
