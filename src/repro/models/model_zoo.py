"""Unified model bundle: one object per architecture exposing specs, apply
functions, abstract input specs per assigned shape, and analytic FLOPs.

This is the single entry point used by smoke tests, the trainer, the server
and the multi-pod dry-run.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Any, Callable

import jax
import jax.numpy as jnp

from repro.configs import ArchConfig, ShapeConfig
from repro.models import encdec, transformer
from repro.models.module import (abstract_params, axes_tree, init_params,
                                 is_spec, param_count)


@dataclasses.dataclass(frozen=True)
class ModelBundle:
    cfg: ArchConfig
    specs: dict
    loss_fn: Callable        # (params, **inputs) -> scalar
    apply_fn: Callable       # (params, **inputs) -> (logits, aux)
    prefill_fn: Callable     # (params, **inputs) -> (logits, cache)
    decode_fn: Callable      # (params, token, cache, pos) -> (logits, cache)

    # ---------------- parameters ----------------
    def init(self, key):
        return init_params(self.specs, key)

    def abstract(self):
        return abstract_params(self.specs)

    def axes(self):
        return axes_tree(self.specs)

    def n_params(self) -> int:
        return param_count(self.specs)

    def n_active_params(self) -> int:
        """Active-per-token params (MoE counts only k/E of expert weights)."""
        cfg = self.cfg
        total = self.n_params()
        if not cfg.n_experts:
            return total
        moe_leaf = sum(
            math.prod(leaf.shape)
            for leaf in jax.tree.leaves(self.specs, is_leaf=is_spec)
            if "expert" in leaf.axes)
        frac = cfg.experts_per_token / max(cfg.n_experts, 1)
        return int(total - moe_leaf + moe_leaf * frac)

    def n_embed_params(self) -> int:
        cfg = self.cfg
        n = cfg.vocab_size * cfg.d_model
        return n if cfg.tie_embeddings else 2 * n

    # ---------------- analytic model flops ----------------
    def model_flops(self, shape: ShapeConfig) -> float:
        """MODEL_FLOPS per §Roofline: 6·N·D (train) / 2·N·B (per decode step),
        N = active non-embedding params + the LM-head matmul, attention
        quadratic excluded (the HLO/MODEL ratio then surfaces it)."""
        cfg = self.cfg
        n_act = self.n_active_params() - self.n_embed_params()
        n_head = cfg.d_model * cfg.vocab_size  # lm head matmul
        tokens = shape.global_batch * shape.seq_len
        if shape.kind == "train":
            return 6.0 * (n_act + n_head) * tokens
        if shape.kind == "prefill":
            return 2.0 * (n_act) * tokens + 2.0 * n_head * shape.global_batch
        return 2.0 * (n_act + n_head) * shape.global_batch  # per decode step

    # ---------------- abstract inputs ----------------
    def input_specs(self, shape: ShapeConfig) -> tuple[dict, dict]:
        """Returns (ShapeDtypeStruct tree, logical-axes tree) of step inputs."""
        cfg = self.cfg
        b, s = shape.global_batch, shape.seq_len
        i32 = jnp.int32
        sds: dict[str, Any] = {}
        axes: dict[str, Any] = {}
        tok_axes = ("batch", None)

        def add(name, shp, ax, dtype=i32):
            sds[name] = jax.ShapeDtypeStruct(shp, dtype)
            axes[name] = ax

        if shape.kind in ("train", "prefill"):
            add("tokens", (b, s), tok_axes)
            if shape.kind == "train":
                add("labels", (b, s), tok_axes)
            if cfg.frontend == "vision_stub":
                add("frontend_embeds", (b, cfg.n_frontend_tokens, cfg.d_model),
                    ("batch", None, None), jnp.bfloat16)
            if cfg.enc_dec:
                add("enc_embeds", (b, cfg.enc_seq, cfg.d_model),
                    ("batch", None, None), jnp.bfloat16)
        else:  # decode
            add("token", (b,), ("batch",))
            add("pos", (), ())
            if cfg.enc_dec:
                c_sds, c_axes = encdec.encdec_cache_specs(cfg, b, s)
            else:
                c_sds, c_axes = transformer.cache_specs(cfg, b, s)
            sds["cache"] = c_sds
            axes["cache"] = c_axes
        return sds, axes

    def zero_inputs(self, shape: ShapeConfig) -> dict:
        sds, _ = self.input_specs(shape)
        return jax.tree.map(lambda t: jnp.zeros(t.shape, t.dtype), sds)


# ---------------------------------------------------------------- builders
def build(cfg: ArchConfig, remat: bool = True) -> ModelBundle:
    if cfg.enc_dec:
        specs = encdec.encdec_specs(cfg)

        def loss_fn(params, tokens, labels, enc_embeds):
            return encdec.encdec_loss(params, tokens, labels, cfg, enc_embeds,
                                      remat=remat)

        def apply_fn(params, tokens, enc_embeds):
            return encdec.encdec_apply(params, tokens, enc_embeds, cfg,
                                       remat=remat)

        def prefill_fn(params, tokens, enc_embeds, max_len=0):
            return encdec.encdec_prefill(params, tokens, enc_embeds, cfg,
                                         max_len=max_len)

        def decode_fn(params, token, cache, pos):
            return encdec.encdec_decode_step(params, token, cache, pos, cfg)
    else:
        specs = transformer.lm_specs(cfg)
        fe = cfg.frontend == "vision_stub"

        def loss_fn(params, tokens, labels, frontend_embeds=None):
            return transformer.lm_loss(params, tokens, labels, cfg,
                                       frontend_embeds if fe else None,
                                       remat=remat)

        def apply_fn(params, tokens, frontend_embeds=None):
            return transformer.lm_apply(params, tokens, cfg,
                                        frontend_embeds if fe else None,
                                        remat=remat)

        def prefill_fn(params, tokens, frontend_embeds=None, max_len=0):
            return transformer.lm_prefill(params, tokens, cfg,
                                          frontend_embeds if fe else None,
                                          max_len=max_len)

        def decode_fn(params, token, cache, pos):
            return transformer.lm_decode_step(params, token, cache, pos, cfg)

    return ModelBundle(cfg=cfg, specs=specs, loss_fn=loss_fn,
                       apply_fn=apply_fn, prefill_fn=prefill_fn,
                       decode_fn=decode_fn)


def decode_rules(cfg: ArchConfig, tp: int) -> dict:
    """Sharding-rule overrides for the decode path (see DESIGN.md §5)."""
    if cfg.n_kv_heads and cfg.n_kv_heads % max(tp, 1) == 0:
        return {}  # kv heads shard normally
    return {"cache_seq": "model", "kv_heads": None}
