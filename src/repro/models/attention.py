"""GQA attention: train/prefill (flash-style chunked softmax, compact HLO)
and decode (grouped-query against a sequence-sharded KV cache).

Sharding strategy (see DESIGN.md §5):
  * train/prefill: q projected column-parallel over `model` (flat head dim);
    kv projections replicated when n_kv_heads % tp != 0 (true for every
    assigned arch at tp=16), kv repeated to H heads *after* projection so the
    repeat is a local slice per shard.  wo is row-parallel -> one all-reduce.
  * decode: KV cache [B, S, K, Dh] sharded over `cache_seq` on `model`
    (flash-decode pattern; GSPMD inserts the partial-softmax all-reduces).
    Queries stay grouped [B, 1, K, R, Dh] with no head sharding.
"""
from __future__ import annotations

import math

import jax
import jax.numpy as jnp

from repro.configs import ArchConfig, LayerKind
from repro.models.layers import PARAM_DTYPE, apply_norm, apply_rope, norm_specs, rope_angles
from repro.models.module import ParamSpec, trip_scope
from repro.runtime.mesh_utils import constrain

NEG_INF = -0.7 * float(jnp.finfo(jnp.float32).max)


def attn_specs(cfg: ArchConfig, cross: bool = False) -> dict:
    d, h, k, dh = cfg.d_model, cfg.n_heads, cfg.n_kv_heads, cfg.resolved_head_dim
    specs = {
        "norm": norm_specs(cfg),
        "wq": ParamSpec((d, h, dh), PARAM_DTYPE, ("embed", "q_heads", "head_dim")),
        "wk": ParamSpec((d, k, dh), PARAM_DTYPE, ("embed", "kv_heads", "head_dim")),
        "wv": ParamSpec((d, k, dh), PARAM_DTYPE, ("embed", "kv_heads", "head_dim")),
        "wo": ParamSpec((h, dh, d), PARAM_DTYPE, ("q_heads", "head_dim", "embed")),
    }
    if cross:
        specs["norm_kv"] = norm_specs(cfg)
    return specs


# ------------------------------------------------------------------
# Flash-style attention over full sequences (train / prefill).
# Streaming softmax over kv chunks inside a scan over q chunks keeps the
# HLO compact and the live set ~[B, Hloc, q_chunk, kv_chunk].
# ------------------------------------------------------------------
def _chunk_sizes(sq: int, sk: int) -> tuple[int, int]:
    q_chunk = min(sq, 2048)
    kv_chunk = min(sk, 2048)
    while sq % q_chunk:
        q_chunk //= 2
    while sk % kv_chunk:
        kv_chunk //= 2
    return max(q_chunk, 1), max(kv_chunk, 1)


def flash_attention_jnp(q, k, v, *, causal: bool, window: int = 0,
                        q_offset: int = 0, kv_len=None, scale=None):
    """q [B,Sq,H,Dh], k/v [B,Sk,H,Dh] (kv already repeated to H heads).

    window > 0 limits attention to the last `window` keys (sliding window).
    kv_len (optional scalar) masks out cache positions >= kv_len.
    """
    b, sq, h, dh = q.shape
    sk = k.shape[1]
    scale = scale or 1.0 / math.sqrt(dh)
    # the whole streaming-softmax region is VMEM-resident in the Pallas
    # flash kernel (kernels/flash_attention); the scope tag lets the
    # roofline analyzer report kernel-projected memory traffic.
    flash_scope = jax.named_scope("flash_fusible")
    flash_scope.__enter__()
    qc, kc = _chunk_sizes(sq, sk)
    nq, nk = sq // qc, sk // kc

    # keep batch/head sharding pinned through the reshapes and the scans --
    # without these, GSPMD may replicate the batch axis inside the while
    # bodies when FSDP also uses the data axis for weights (measured: 16x
    # attention flops/bytes on qwen3 train).
    blk_axes = ("batch", None, None, "q_heads", None)
    q = constrain(q.reshape(b, nq, qc, h, dh), blk_axes)
    k = constrain(k.reshape(b, nk, kc, h, dh), blk_axes)
    v = constrain(v.reshape(b, nk, kc, h, dh), blk_axes)

    q_pos_base = jnp.arange(qc)
    k_pos_base = jnp.arange(kc)

    def q_body(_, qi):
        (q_blk, q_idx) = qi  # [b, qc, h, dh], scalar block index
        q_pos = q_pos_base + q_idx * qc + q_offset

        def kv_body(carry, ki):
            m, lsum, acc = carry
            (k_blk, v_blk, k_idx) = ki
            k_blk = constrain(k_blk, ("batch", None, "q_heads", None))
            v_blk = constrain(v_blk, ("batch", None, "q_heads", None))
            k_pos = k_pos_base + k_idx * kc
            s = jnp.einsum("bqhd,bkhd->bhqk", q_blk, k_blk,
                           preferred_element_type=jnp.float32) * scale
            s = constrain(s, ("batch", "q_heads", None, None))
            mask = jnp.ones((qc, kc), jnp.bool_)
            if causal:
                mask &= q_pos[:, None] >= k_pos[None, :]
            if window:
                mask &= (q_pos[:, None] - k_pos[None, :]) < window
            if kv_len is not None:
                mask &= (k_pos < kv_len)[None, :]
            s = jnp.where(mask[None, None], s, NEG_INF)
            m_new = jnp.maximum(m, jnp.max(s, axis=-1))
            p = jnp.exp(s - m_new[..., None])
            alpha = jnp.exp(m - m_new)
            lsum_new = lsum * alpha + jnp.sum(p, axis=-1)
            pv = jnp.einsum("bhqk,bkhd->bhqd", p.astype(v_blk.dtype), v_blk,
                            preferred_element_type=jnp.float32)
            acc_new = constrain(acc * alpha[..., None] + pv,
                                ("batch", "q_heads", None, None))
            return (m_new, lsum_new, acc_new), None

        m0 = jnp.full((b, h, qc), NEG_INF, jnp.float32)
        l0 = jnp.zeros((b, h, qc), jnp.float32)
        a0 = jnp.zeros((b, h, qc, dh), jnp.float32)
        if nk == 1:
            (m, lsum, acc), _ = kv_body((m0, l0, a0),
                                     (k[:, 0], v[:, 0], jnp.int32(0)))
        else:
            with trip_scope(nk, "attn_kv"):
                (m, lsum, acc), _ = jax.lax.scan(
                    kv_body, (m0, l0, a0),
                    (k.swapaxes(0, 1), v.swapaxes(0, 1), jnp.arange(nk)))
        out = acc / jnp.maximum(lsum[..., None], 1e-30)
        return None, out.swapaxes(1, 2)  # [b, qc, h, dh]

    if nq == 1:
        _, out = q_body(None, (q[:, 0], jnp.int32(0)))
        out = out[:, None]
    else:
        with trip_scope(nq, "attn_q"):
            _, out = jax.lax.scan(q_body, None,
                                  (q.swapaxes(0, 1), jnp.arange(nq)))
        out = out.swapaxes(0, 1)  # [b, nq, qc, h, dh]
    flash_scope.__exit__(None, None, None)
    return out.reshape(b, sq, h, dh).astype(v.dtype)


def _flash_remat(q, k, v, *, causal, window):
    """Flash attention with recompute-in-backward (jax.checkpoint): only
    q/k/v and the output are saved; the O(S^2) probabilities are
    rematerialized during the backward pass, exactly like a fused flash
    backward kernel.  Removes the dominant activation-memory term
    (~2GB/layer f32 probs at 4k) from every train cell."""
    fn = jax.checkpoint(
        lambda q_, k_, v_: flash_attention_jnp(q_, k_, v_, causal=causal,
                                               window=window))
    return fn(q, k, v)


def apply_attention(p: dict, x: jax.Array, cfg: ArchConfig, kind: LayerKind,
                    positions: jax.Array, causal: bool = True) -> jax.Array:
    """Full-sequence self-attention (train / prefill path)."""
    h_heads, k_heads = cfg.n_heads, cfg.n_kv_heads
    hx = apply_norm(p["norm"], x, cfg)
    q = jnp.einsum("bsd,dhk->bshk", hx, p["wq"])
    k = jnp.einsum("bsd,dhk->bshk", hx, p["wk"])
    v = jnp.einsum("bsd,dhk->bshk", hx, p["wv"])
    if cfg.use_rope:
        sin, cos = rope_angles(positions, cfg.resolved_head_dim, kind.rope_theta)
        q = apply_rope(q, sin, cos)
        k = apply_rope(k, sin, cos)
    rep = h_heads // k_heads
    k = jnp.repeat(k, rep, axis=2)
    v = jnp.repeat(v, rep, axis=2)
    q = constrain(q, ("batch", None, "q_heads", None))
    k = constrain(k, ("batch", None, "q_heads", None))
    v = constrain(v, ("batch", None, "q_heads", None))
    out = _flash_remat(q, k, v, causal=causal, window=kind.window)
    out = constrain(out, ("batch", None, "q_heads", None))
    return jnp.einsum("bshk,hkd->bsd", out, p["wo"])


def prefill_attention(p: dict, x: jax.Array, cfg: ArchConfig, kind: LayerKind,
                      positions: jax.Array, max_len: int = 0):
    """Full-sequence attention that also emits the decode cache.

    The cache stores *rotated* keys (decode rotates at insert time too).
    Sliding-window layers keep only the last `window` positions, laid out in
    ring order (slot = absolute_pos % window) to match `decode_attention`.
    """
    h_heads, k_heads = cfg.n_heads, cfg.n_kv_heads
    s = x.shape[1]
    hx = apply_norm(p["norm"], x, cfg)
    q = jnp.einsum("bsd,dhk->bshk", hx, p["wq"])
    k = jnp.einsum("bsd,dhk->bshk", hx, p["wk"])
    v = jnp.einsum("bsd,dhk->bshk", hx, p["wv"])
    if cfg.use_rope:
        sin, cos = rope_angles(positions, cfg.resolved_head_dim, kind.rope_theta)
        q = apply_rope(q, sin, cos)
        k = apply_rope(k, sin, cos)

    max_len = max(max_len, s)
    kt = k.transpose(0, 2, 1, 3)                 # [B, K, S, Dh]
    vt = v.transpose(0, 2, 1, 3)
    int8_cache = cfg.kv_cache_dtype == "int8"
    if int8_cache:
        kt, k_sc = _quant_kv(kt)
        vt, v_sc = _quant_kv(vt)
    if kind.window and min(kind.window, max_len) <= s:
        w = min(kind.window, max_len)
        slots = (s - w + jnp.arange(w)) % w      # ring layout
        store = lambda t: jnp.zeros(
            t.shape[:2] + (w,) + t.shape[3:], t.dtype
            ).at[:, :, slots].set(t[:, :, s - w:])
    else:
        size = min(kind.window, max_len) if kind.window else max_len
        store = lambda t: jnp.pad(
            t, [(0, 0), (0, 0), (0, size - t.shape[2]), (0, 0)])
    cache = {"k": store(kt), "v": store(vt)}
    if int8_cache:
        cache["k_scale"] = store(k_sc)
        cache["v_scale"] = store(v_sc)

    rep = h_heads // k_heads
    k = jnp.repeat(k, rep, axis=2)
    v = jnp.repeat(v, rep, axis=2)
    q = constrain(q, ("batch", None, "q_heads", None))
    k = constrain(k, ("batch", None, "q_heads", None))
    v = constrain(v, ("batch", None, "q_heads", None))
    out = flash_attention_jnp(q, k, v, causal=True, window=kind.window)
    return jnp.einsum("bshk,hkd->bsd", out, p["wo"]), cache


def apply_cross_attention(p: dict, x: jax.Array, cfg: ArchConfig,
                          enc_kv: tuple[jax.Array, jax.Array]) -> jax.Array:
    """Decoder cross-attention over precomputed encoder k/v [B,Se,H,Dh]."""
    hx = apply_norm(p["norm"], x, cfg)
    q = jnp.einsum("bsd,dhk->bshk", hx, p["wq"])
    k, v = enc_kv
    rep = cfg.n_heads // cfg.n_kv_heads
    if rep > 1:
        k = jnp.repeat(k, rep, axis=2)
        v = jnp.repeat(v, rep, axis=2)
    out = flash_attention_jnp(q, k, v, causal=False)
    return jnp.einsum("bshk,hkd->bsd", out, p["wo"])


def cross_kv(p: dict, enc_out: jax.Array, cfg: ArchConfig):
    """Precompute cross-attention k/v from encoder output (kept per layer)."""
    h = apply_norm(p["norm_kv"], enc_out, cfg)
    k = jnp.einsum("bsd,dhk->bshk", h, p["wk"])
    v = jnp.einsum("bsd,dhk->bshk", h, p["wv"])
    return k, v


# ------------------------------------------------------------------
# Decode path: one new token against a KV cache.
# ------------------------------------------------------------------
def init_kv_cache(cfg: ArchConfig, kind: LayerKind, batch: int, seq: int):
    """Abstract/zero cache shapes for one attention layer.

    Spec values are (shape, axes) or (shape, axes, dtype).  With
    kv_cache_dtype="int8" the cache stores symmetric per-(batch, head,
    position) quantized keys/values plus bf16 scales: 2x less HBM read per
    decode step on the memory-bound serving cells (§Perf cell C)."""
    eff = min(seq, kind.window) if kind.window else seq
    # [B, K, S, Dh]: per-head-contiguous layout; the decode dot contracts Dh
    # (scores) and S (values) with no transposes on either backend.
    shape = (batch, cfg.n_kv_heads, eff, cfg.resolved_head_dim)
    axes = ("cache_batch", "kv_heads", "cache_seq", "head_dim")
    if cfg.kv_cache_dtype == "int8":
        import jax.numpy as _jnp
        s_shape = shape[:-1] + (1,)
        s_axes = axes[:-1] + (None,)
        return {"k": (shape, axes, _jnp.int8),
                "v": (shape, axes, _jnp.int8),
                "k_scale": (s_shape, s_axes, _jnp.bfloat16),
                "v_scale": (s_shape, s_axes, _jnp.bfloat16)}
    return {"k": (shape, axes), "v": (shape, axes)}


def _quant_kv(x: jax.Array):
    """x [..., Dh] -> (int8 values, bf16 scale [..., 1])."""
    scale = jnp.max(jnp.abs(x.astype(jnp.float32)), axis=-1,
                    keepdims=True) / 127.0
    scale = jnp.maximum(scale, 1e-8)
    q = jnp.clip(jnp.round(x.astype(jnp.float32) / scale), -127, 127
                 ).astype(jnp.int8)
    return q, scale.astype(jnp.bfloat16)


def decode_attention(p: dict, x: jax.Array, cfg: ArchConfig, kind: LayerKind,
                     cache: dict, pos: jax.Array) -> tuple[jax.Array, dict]:
    """x [B,1,D]; cache {k,v: [B,S,K,Dh]}; pos scalar int32 (tokens so far).

    Sliding-window layers use the cache as a ring buffer of size `window`.
    """
    b = x.shape[0]
    k_heads, dh = cfg.n_kv_heads, cfg.resolved_head_dim
    rep = cfg.n_heads // k_heads
    hx = apply_norm(p["norm"], x, cfg)
    q = jnp.einsum("bsd,dhk->bshk", hx, p["wq"])
    k_new = jnp.einsum("bsd,dhk->bshk", hx, p["wk"])
    v_new = jnp.einsum("bsd,dhk->bshk", hx, p["wv"])
    if cfg.use_rope:
        sin, cos = rope_angles(pos[None], dh, kind.rope_theta)
        q = apply_rope(q, sin[None], cos[None])
        k_new = apply_rope(k_new, sin[None], cos[None])

    s_cache = cache["k"].shape[2]
    if kind.window:  # ring buffer
        slot = pos % s_cache
        valid = jnp.arange(s_cache) < jnp.minimum(pos + 1, s_cache)
    else:
        slot = pos
        valid = jnp.arange(s_cache) <= pos
    int8_cache = cache["k"].dtype == jnp.int8
    kt_new = k_new.transpose(0, 2, 1, 3)
    vt_new = v_new.transpose(0, 2, 1, 3)
    new_cache = {}
    if int8_cache:
        kt_new, ks_new = _quant_kv(kt_new)
        vt_new, vs_new = _quant_kv(vt_new)
        new_cache["k_scale"] = jax.lax.dynamic_update_slice_in_dim(
            cache["k_scale"], ks_new, slot, axis=2)
        new_cache["v_scale"] = jax.lax.dynamic_update_slice_in_dim(
            cache["v_scale"], vs_new, slot, axis=2)
    k_c = jax.lax.dynamic_update_slice_in_dim(
        cache["k"], kt_new.astype(cache["k"].dtype), slot, axis=2)
    v_c = jax.lax.dynamic_update_slice_in_dim(
        cache["v"], vt_new.astype(cache["v"].dtype), slot, axis=2)

    # grouped-query attention over the cache, no head repeat materialized.
    # int8 path: the per-position scale factors out of the Dh contraction
    # (scores) and folds into the probabilities (values), so the dequantized
    # cache is never materialized.
    qg = q.reshape(b, k_heads, rep, dh)
    s = jnp.einsum("bkrd,bksd->bkrs", qg, k_c.astype(qg.dtype),
                   preferred_element_type=jnp.float32) / math.sqrt(dh)
    if int8_cache:
        s = s * new_cache["k_scale"][..., 0].astype(jnp.float32)[:, :, None, :]
    s = jnp.where(valid[None, None, None, :], s, NEG_INF)
    w = jax.nn.softmax(s, axis=-1)
    if int8_cache:
        w = w * new_cache["v_scale"][..., 0].astype(jnp.float32)[:, :, None, :]
    o = jnp.einsum("bkrs,bksd->bkrd", w.astype(jnp.bfloat16),
                   v_c.astype(jnp.bfloat16))
    o = o.reshape(b, 1, cfg.n_heads, dh)
    out = jnp.einsum("bshk,hkd->bsd", o, p["wo"])
    new_cache["k"] = k_c
    new_cache["v"] = v_c
    return out, new_cache
