"""Top-k MoE with sort-based, capacity-bounded dispatch.

Tokens are routed *per group* (group = one sequence) so the argsort never
crosses the data-parallel axis; the only cross-axis communication is the
token buffer resharding from (batch->data) to (expert->model), which GSPMD
lowers to all-to-all-like collectives.  No [T, E, C] one-hot is ever
materialized (buffer is [G, E, C, D] with C = S*k*cf/E).
"""
from __future__ import annotations

import math

import jax
import jax.numpy as jnp

from repro.configs import ArchConfig
from repro.models.layers import PARAM_DTYPE, norm_specs
from repro.models.module import ParamSpec, normal_init
from repro.runtime.mesh_utils import constrain


def moe_specs(cfg: ArchConfig) -> dict:
    d, e, f = cfg.d_model, cfg.n_experts, cfg.moe_d_ff
    return {
        "norm": norm_specs(cfg),
        "router": ParamSpec((d, e), jnp.float32, ("embed", None),
                            normal_init(0.02)),
        "wg": ParamSpec((e, d, f), PARAM_DTYPE, ("expert", "embed", "expert_mlp")),
        "wu": ParamSpec((e, d, f), PARAM_DTYPE, ("expert", "embed", "expert_mlp")),
        "wd": ParamSpec((e, f, d), PARAM_DTYPE, ("expert", "expert_mlp", "embed")),
    }


def expert_capacity(cfg: ArchConfig, tokens_per_group: int) -> int:
    k, e, cf = cfg.experts_per_token, cfg.n_experts, cfg.capacity_factor
    cap = math.ceil(tokens_per_group * k * cf / e)
    return max(4, -(-cap // 4) * 4)  # round up to a multiple of 4


def apply_moe(p: dict, x_normed: jax.Array, cfg: ArchConfig):
    """x_normed [B,S,D] (already normed by caller's block logic is NOT assumed
    -- this takes the *raw* residual stream and applies its own norm).

    Returns (out [B,S,D], aux_loss scalar f32).
    """
    from repro.models.layers import apply_norm  # local import, no cycle
    b, s, d = x_normed.shape
    e, k = cfg.n_experts, cfg.experts_per_token
    x = apply_norm(p["norm"], x_normed, cfg)
    cap = expert_capacity(cfg, s)

    logits = jnp.einsum("bsd,de->bse", x.astype(jnp.float32), p["router"])
    probs = jax.nn.softmax(logits, axis=-1)                  # [B,S,E]
    gate, eidx = jax.lax.top_k(probs, k)                     # [B,S,k]
    gate = gate / jnp.maximum(gate.sum(-1, keepdims=True), 1e-9)

    # Load-balance auxiliary loss (Switch-style).
    me = jnp.mean(probs, axis=(0, 1))                        # [E]
    ce = jnp.mean(
        (jax.nn.one_hot(eidx, e).sum(axis=2)), axis=(0, 1)) / k
    aux = cfg.router_aux_coef * e * jnp.sum(me * ce)

    # ---- per-group (per-sequence) sort-based dispatch ----
    # (index tensors are pinned batch-sharded: gathers/scatters align their
    # output sharding with the index operands, so unsharded indices would
    # replicate the whole combine path over the data axis)
    row = ("batch", None)
    flat_e = constrain(eidx.reshape(b, s * k), row)          # [B, N]
    order = constrain(jnp.argsort(flat_e, axis=-1), row)     # [B, N]
    se = constrain(jnp.take_along_axis(flat_e, order, axis=-1), row)
    tok = constrain(order // k, row)                         # source token
    # position within each expert's run
    starts = jax.vmap(lambda r_: jnp.searchsorted(r_, jnp.arange(e)))(se)
    pos = jnp.arange(s * k)[None, :] - jnp.take_along_axis(starts, se, axis=-1)
    pos = constrain(pos, row)
    keep = pos < cap

    gates_sorted = jnp.take_along_axis(gate.reshape(b, s * k), order, axis=-1)
    safe_pos = jnp.where(keep, pos, cap - 1)
    bidx = jnp.broadcast_to(jnp.arange(b)[:, None], se.shape)

    # Dispatch is formulated as a small *index* scatter followed by a *data*
    # gather: scattering the [B,N,D] hidden states into an expert-sharded
    # buffer makes GSPMD all-gather the activations over the model axis
    # (measured: 1.6e3 s collective term on qwen3 train); scattering only
    # int32 token ids [B,E,C] is 1000x smaller, and the data gather from the
    # (model-replicated) activations is then local per expert shard.
    slot_tok = jnp.full((b, e, cap), s, jnp.int32)           # s = "empty"
    slot_tok = slot_tok.at[bidx, se, safe_pos].min(
        jnp.where(keep, tok, s).astype(jnp.int32), mode="drop")
    slot_tok = constrain(slot_tok, ("batch", "expert", None))
    x_pad = jnp.concatenate([x, jnp.zeros((b, 1, d), x.dtype)], axis=1)
    buf = x_pad[jnp.arange(b)[:, None, None], slot_tok]      # [B,E,C,D]
    buf = constrain(buf, ("batch", "expert", None, None))

    g = jnp.einsum("becd,edf->becf", buf, p["wg"])
    g = constrain(g, ("batch", "expert", None, None))
    u = jnp.einsum("becd,edf->becf", buf, p["wu"])
    u = constrain(u, ("batch", "expert", None, None))
    hmid = jax.nn.silu(g.astype(jnp.float32)).astype(buf.dtype) * u
    yexp = jnp.einsum("becf,efd->becd", hmid, p["wd"])
    yexp = constrain(yexp, ("batch", "expert", None, None))

    # combine: slot n of group b reads yexp[b, se[n], pos[n]] -- a gather
    # along the expert-sharded dim, which GSPMD lowers to masked local
    # gather + psum over `model`; keep the result batch-sharded.
    slot_y = yexp[bidx, se, safe_pos]                        # [B, N, D]
    slot_y = constrain(slot_y, ("batch", None, None))
    slot_y = slot_y * (gates_sorted * keep)[..., None].astype(slot_y.dtype)
    out = jnp.zeros((b, s, d), slot_y.dtype)
    out = out.at[bidx, tok].add(slot_y)
    out = constrain(out, ("batch", None, None))
    return out, aux
