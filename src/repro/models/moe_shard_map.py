"""Expert-parallel MoE with explicit all_to_all (shard_map, fully manual).

Why: under pure GSPMD the capacity-dispatch gathers/scatters between the
token space (batch-sharded) and the expert space (model-sharded) lower to
batch-replicated all-reduces of [B, S*k, D] f32 -- measured 1.15e3 s
collective term on qwen3-235B train_4k (EXPERIMENTS.md §Perf cell A).
Hand-placing the communication makes it two all_to_alls of exactly the
routed slots per direction; shard_map transposes all_to_all to all_to_all,
so the backward is equally lean.

Layout inside the manual region (per device):
  x_loc [b_loc, S, D]; expert weights [e_loc, D, F] (e_loc = E / tp).
  1. local top-k routing over the full router table;
  2. slots sorted by target shard -> send buffer [tp, cap_send, D];
  3. all_to_all over `model` -> recv [tp, cap_send, D] (+ int32 metadata);
  4. second-level local grouping by local expert -> [e_loc, cap_loc, D];
  5. local expert matmuls; inverse gather; all_to_all back; weighted
     combine into [b_loc, S, D].
Capacity factors apply at both levels (token drops mirror the GSPMD path).
"""
from __future__ import annotations

import math
import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.configs import ArchConfig
from repro.runtime import mesh_utils


def _capacity(n: int, mult: float) -> int:
    cap = math.ceil(n * mult)
    return max(8, -(-cap // 8) * 8)


def _local_moe(x, router, wg, wu, wd, cfg: ArchConfig, tp: int,
               model_axis: str, data_axes: tuple = ("data",)):
    """Runs on ONE device inside shard_map. x [t_loc, D] (flattened)."""
    t_loc, d = x.shape
    e, k = cfg.n_experts, cfg.experts_per_token
    e_loc = e // tp

    logits = (x.astype(jnp.float32) @ router)
    probs = jax.nn.softmax(logits, axis=-1)
    gate, eidx = jax.lax.top_k(probs, k)                 # [t_loc, k]
    gate = gate / jnp.maximum(gate.sum(-1, keepdims=True), 1e-9)
    me = jnp.mean(probs, axis=0)
    ce = jnp.mean(jax.nn.one_hot(eidx, e).sum(1), axis=0) / k
    aux = cfg.router_aux_coef * e * jnp.sum(me * ce)
    aux = jax.lax.pmean(aux, (model_axis,) + tuple(data_axes))

    # ---- level 1: group slots by target expert shard ----
    n = t_loc * k
    flat_e = eidx.reshape(n)
    shard_of = flat_e // e_loc
    order = jnp.argsort(shard_of)
    se = shard_of[order]
    cap_s = _capacity(n // tp, cfg.capacity_factor)
    starts = jnp.searchsorted(se, jnp.arange(tp))
    pos = jnp.arange(n) - starts[se]
    keep = pos < cap_s
    safe_pos = jnp.where(keep, pos, cap_s - 1)

    tok = order // k
    send_x = jnp.zeros((tp, cap_s, d), x.dtype)
    send_x = send_x.at[se, safe_pos].add(
        jnp.where(keep[:, None], x[tok], 0))
    send_eid = jnp.full((tp, cap_s), -1, jnp.int32)
    send_eid = send_eid.at[se, safe_pos].max(
        jnp.where(keep, flat_e[order] % e_loc, -1).astype(jnp.int32))

    # ---- all_to_all: slots travel to their expert shard ----
    recv_x = jax.lax.all_to_all(send_x, model_axis, split_axis=0,
                                concat_axis=0, tiled=False)
    recv_eid = jax.lax.all_to_all(send_eid, model_axis, split_axis=0,
                                  concat_axis=0, tiled=False)
    # recv_* [tp, cap_s, ...]: slot (src_shard, c) from each source shard

    # ---- level 2: group received slots by local expert ----
    m = tp * cap_s
    r_eid = recv_eid.reshape(m)                      # -1 = empty slot
    r_x = recv_x.reshape(m, d)
    order2 = jnp.argsort(jnp.where(r_eid < 0, e_loc, r_eid))
    ge = jnp.where(r_eid < 0, e_loc, r_eid)[order2]
    cap_l = _capacity(m // max(e_loc, 1), cfg.capacity_factor)
    starts2 = jnp.searchsorted(ge, jnp.arange(e_loc))
    pos2 = jnp.arange(m) - starts2[jnp.minimum(ge, e_loc - 1)]
    keep2 = (pos2 < cap_l) & (ge < e_loc)
    safe_pos2 = jnp.where(keep2, pos2, cap_l - 1)

    buf = jnp.zeros((e_loc, cap_l, d), x.dtype)
    buf = buf.at[jnp.minimum(ge, e_loc - 1), safe_pos2].add(
        jnp.where(keep2[:, None], r_x[order2], 0))

    # ---- local expert matmuls ----
    g = jnp.einsum("ecd,edf->ecf", buf, wg)
    u = jnp.einsum("ecd,edf->ecf", buf, wu)
    h = jax.nn.silu(g.astype(jnp.float32)).astype(buf.dtype) * u
    y = jnp.einsum("ecf,efd->ecd", h, wd)            # [e_loc, cap_l, D]

    # ---- inverse: back to recv slots, all_to_all home, combine ----
    y_slots = jnp.zeros((m, d), y.dtype)
    vals = jnp.where(keep2[:, None],
                     y[jnp.minimum(ge, e_loc - 1), safe_pos2], 0)
    y_slots = y_slots.at[order2].add(vals)
    y_back = jax.lax.all_to_all(y_slots.reshape(tp, cap_s, d), model_axis,
                                split_axis=0, concat_axis=0, tiled=False)
    # y_back [tp, cap_s, d] in the original send layout
    slot_y = jnp.where(keep[:, None], y_back[se, safe_pos], 0)
    gates_sorted = gate.reshape(n)[order]
    out = jnp.zeros((t_loc, d), y.dtype)
    out = out.at[tok].add(slot_y * gates_sorted[:, None].astype(slot_y.dtype))
    return out, aux


def apply_moe_shard_map(p: dict, x_normed: jax.Array, cfg: ArchConfig,
                        mesh) -> tuple[jax.Array, jax.Array]:
    """Drop-in replacement for apply_moe when a mesh with a `model` axis is
    ambient.  x_normed [B, S, D] batch-sharded over the data axes."""
    from repro.models.layers import apply_norm
    b, s, d = x_normed.shape
    x = apply_norm(p["norm"], x_normed, cfg)
    tp = mesh_utils.axis_size(mesh, mesh_utils.MODEL_AXIS)
    data_axes = tuple(a for a in mesh_utils.DATA_AXES if a in mesh.shape)

    def body(router, wg, wu, wd, x_loc):
        b_loc = x_loc.shape[0]
        flat = x_loc.reshape(b_loc * x_loc.shape[1], d)
        # x is replicated over the model axis: each model-axis peer routes a
        # DISTINCT 1/tp slice of the tokens (otherwise all tp peers duplicate
        # the routing work and a2a traffic -- measured 16x compute).  Decode
        # steps (t_loc < tp) keep the replicated path: the duplicate routing
        # of a handful of tokens is cheaper than padding to tp slices.
        t_loc = flat.shape[0]
        if t_loc % tp == 0 and t_loc >= tp:
            me = jax.lax.axis_index(mesh_utils.MODEL_AXIS)
            t_me = t_loc // tp
            flat_me = jax.lax.dynamic_slice_in_dim(flat, me * t_me, t_me,
                                                   axis=0)
            out_me, aux = _local_moe(flat_me, router, wg, wu, wd, cfg, tp,
                                     mesh_utils.MODEL_AXIS, data_axes)
            out = jax.lax.all_gather(out_me, mesh_utils.MODEL_AXIS, axis=0,
                                     tiled=True)
        else:
            out, aux = _local_moe(flat, router, wg, wu, wd, cfg, tp,
                                  mesh_utils.MODEL_AXIS, data_axes)
            out = jax.lax.pmean(out, mesh_utils.MODEL_AXIS)  # identical copies
        return out.reshape(x_loc.shape), aux

    batch_spec = P(data_axes if len(data_axes) > 1 else data_axes[0])
    expert_spec = P(mesh_utils.MODEL_AXIS)
    fn = mesh_utils.shard_map_compat(
        body, mesh,
        in_specs=(P(), expert_spec, expert_spec, expert_spec, batch_spec),
        out_specs=(batch_spec, P()),
        axis_names={mesh_utils.MODEL_AXIS, *data_axes})
    return fn(p["router"], p["wg"], p["wu"], p["wd"], x)
