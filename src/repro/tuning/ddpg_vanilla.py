"""Vanilla DDPG tuner (paper §5.3 "DDPG"): a direct RL pipeline from the
DBMS-tuning literature (CDBTune/RusKey style) embedded in our framework —
no LSTM context, no ET-MDP safety, no Meta-RL, no O2.  Pretrained and
fine-tuned with the same data as LITune (paper's protocol), it demonstrates
why the tailor-made design matters (Fig 6/7: lags 10-15%; Fig 12:
unstable training)."""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import ddpg
from repro.core.ddpg import DDPGConfig
from repro.core.etmdp import ETMDPConfig, rollout_episode
from repro.core.maml import make_task_env, sample_task
from repro.core.networks import NetConfig
from repro.core.replay import SequenceReplay
from repro.index import env as E


@dataclasses.dataclass(frozen=True)
class VanillaConfig:
    index_type: str = "alex"
    episode_len: int = 25
    lstm_hidden: int = 128   # buffer layout only; hiddens are zeroed
    mlp_hidden: int = 256
    ddpg: DDPGConfig = DDPGConfig(use_lstm=False)
    updates_per_episode: int = 8


class VanillaDDPGTuner:
    name = "ddpg"

    def __init__(self, cfg: VanillaConfig = VanillaConfig(), seed: int = 0):
        self.cfg = cfg
        self.env_cfg = E.EnvConfig(index_type=cfg.index_type,
                                   episode_len=cfg.episode_len)
        self.net_cfg = NetConfig(obs_dim=E.obs_dim(),
                                 action_dim=self.env_cfg.space.dim,
                                 lstm_hidden=cfg.lstm_hidden,
                                 mlp_hidden=cfg.mlp_hidden)
        self.et_cfg = ETMDPConfig(enabled=False)  # no safety (by design)
        self.key = jax.random.PRNGKey(seed)
        self.key, k = jax.random.split(self.key)
        self.state = ddpg.init_state(k, self.net_cfg, cfg.ddpg)
        self.replay = SequenceReplay(16384, E.obs_dim(),
                                     self.env_cfg.space.dim,
                                     cfg.lstm_hidden,
                                     seq_len=cfg.ddpg.seq_len, seed=seed)
        self.rng = np.random.default_rng(seed)
        self.train_violations = 0.0
        self.train_returns: list[float] = []

    def pretrain(self, n_episodes: int = 20, callback=None):
        for ep in range(n_episodes):
            task = sample_task(self.rng)
            data, workload = make_task_env(task)
            self.key, k = jax.random.split(self.key)
            summary = rollout_episode(
                k, self.state, self.net_cfg, self.env_cfg, self.et_cfg,
                data, workload, task.wr_ratio,
                noise_scale=self.cfg.ddpg.noise_scale, replay=self.replay)
            self.train_violations += summary["violations"]
            self.train_returns.append(summary["episode_return"])
            for _ in range(self.cfg.updates_per_episode):
                batch = self.replay.sample_sequences(self.cfg.ddpg.batch_size)
                if batch is None:
                    break
                batch = jax.tree.map(jnp.asarray, batch)
                self.state, _ = ddpg.update(self.state, batch, self.net_cfg,
                                            self.cfg.ddpg)
            if callback:
                callback({"episode": ep,
                          "return": summary["episode_return"],
                          "violations": summary["violations"]})
        return self.train_returns

    def tune(self, data_keys, workload, wr_ratio, budget_steps: int = 25):
        env_cfg = dataclasses.replace(self.env_cfg, episode_len=budget_steps)
        self.key, k = jax.random.split(self.key)
        summary = rollout_episode(k, self.state, self.net_cfg, env_cfg,
                                  self.et_cfg, data_keys, workload, wr_ratio,
                                  noise_scale=0.05, replay=self.replay)
        return summary
