"""Baseline tuner interface + budgeted evaluation loop with failure
accounting (paper §5.3: Default / Random / Grid / Heuristic / SMBO / DDPG).

Every tuner proposes raw-parameter dicts; the runner evaluates them on the
(data, workload) instance through the same `evaluate_params` primitive the
RL env uses, so comparisons are apples-to-apples.  Violations (memory /
runtime budget) are counted as *failures* -- exactly what Fig 1(d) and
Fig 11(f) report.
"""
from __future__ import annotations

import dataclasses
import time

import jax.numpy as jnp
import numpy as np

from repro.core.spaces import ParamSpace
from repro.index import env as E


@dataclasses.dataclass
class TuneResult:
    method: str
    best_runtime_ns: float
    default_runtime_ns: float
    best_params: dict
    runtimes: list            # runtime per evaluated candidate, in order
    failures: int             # budget violations encountered
    evals: int
    wall_s: float

    @property
    def best_so_far(self) -> np.ndarray:
        return np.minimum.accumulate(np.asarray(self.runtimes))

    @property
    def speedup(self) -> float:
        return self.default_runtime_ns / max(self.best_runtime_ns, 1e-9)


class Tuner:
    """Propose/observe interface."""
    name = "base"

    def __init__(self, space: ParamSpace, seed: int = 0):
        self.space = space
        self.rng = np.random.default_rng(seed)

    def propose(self) -> dict:
        raise NotImplementedError

    def observe(self, params: dict, runtime_ns: float, failed: bool):
        pass


def run_tuner(tuner: Tuner, env_cfg: E.EnvConfig, data_keys, workload,
              wr_ratio, budget_evals: int = 25,
              budget_seconds: float | None = None) -> TuneResult:
    from repro.index.env import evaluate_params
    mod_defaults = __import__(
        f"repro.index.{env_cfg.index_type}", fromlist=["DEFAULTS"]).DEFAULTS
    default_raw = {k: jnp.float32(v) for k, v in mod_defaults.items()}
    r_def, _, _ = evaluate_params(env_cfg, default_raw, data_keys, workload,
                                  wr_ratio)
    r_def = float(r_def)

    t0 = time.time()
    runtimes, failures = [], 0
    best_rt, best_params = r_def, dict(mod_defaults)
    for i in range(budget_evals):
        if budget_seconds is not None and time.time() - t0 > budget_seconds:
            break
        params = tuner.propose()
        params_j = {k: jnp.float32(v) for k, v in params.items()}
        rt, _, viol = evaluate_params(env_cfg, params_j, data_keys, workload,
                                      wr_ratio)
        rt = float(rt)
        failed = float(viol["c_m"]) + float(viol["c_r"]) > 0
        failures += int(failed)
        # a failed configuration cannot be deployed; treat as default-speed
        eff_rt = r_def * 2.0 if failed else rt
        runtimes.append(eff_rt)
        tuner.observe(params, eff_rt, failed)
        if not failed and rt < best_rt:
            best_rt, best_params = rt, params
    return TuneResult(
        method=tuner.name, best_runtime_ns=best_rt,
        default_runtime_ns=r_def, best_params=best_params,
        runtimes=runtimes, failures=failures, evals=len(runtimes),
        wall_s=time.time() - t0)
