"""The paper's baseline tuning methods (§5.3), self-contained:

  * RandomSearch  — uniform over the raw space
  * GridSearch    — fixed lattice fixed at the outset (no expert defaults)
  * HeuristicSearch — simulated annealing (OpenTuner's SA kernel analogue)
  * SMBO          — Tree-structured Parzen Estimator from scratch
                    (Bergstra et al.; the paper uses TPE via Hyperopt)
"""
from __future__ import annotations

import itertools
import math

import numpy as np

from repro.core.spaces import ParamSpace
from repro.tuning.base import Tuner


class RandomSearch(Tuner):
    name = "random"

    def propose(self) -> dict:
        return self.space.random_raw(self.rng)


class GridSearch(Tuner):
    name = "grid"

    def __init__(self, space: ParamSpace, seed: int = 0,
                 points_per_dim: int = 3):
        super().__init__(space, seed)
        axes = space.grid_axes(points_per_dim)
        self._iter = itertools.product(*axes)

    def propose(self) -> dict:
        try:
            point = next(self._iter)
        except StopIteration:
            point = [float(self.rng.choice(ax))
                     for ax in self.space.grid_axes(3)]
        return dict(zip(self.space.names, [float(x) for x in point]))


class HeuristicSearch(Tuner):
    """Simulated annealing over the normalized [-1,1]^d space."""
    name = "heuristic"

    def __init__(self, space: ParamSpace, seed: int = 0,
                 t0: float = 1.0, cooling: float = 0.9, step: float = 0.3):
        super().__init__(space, seed)
        self.temp = t0
        self.cooling = cooling
        self.step = step
        self.cur = self.rng.uniform(-1, 1, space.dim).astype(np.float32)
        self.cur_val: float | None = None
        self._pending = None

    def _to_raw(self, a: np.ndarray) -> dict:
        import jax.numpy as jnp
        return {k: float(v) for k, v in
                self.space.decode(jnp.asarray(a)).items()}

    def propose(self) -> dict:
        cand = np.clip(self.cur + self.rng.normal(0, self.step,
                                                  self.space.dim), -1, 1)
        self._pending = cand
        return self._to_raw(cand)

    def observe(self, params: dict, runtime_ns: float, failed: bool):
        if self.cur_val is None:
            self.cur, self.cur_val = self._pending, runtime_ns
            return
        delta = runtime_ns - self.cur_val
        accept = delta < 0 or self.rng.uniform() < math.exp(
            -delta / max(self.cur_val * self.temp, 1e-9))
        if accept and not failed:
            self.cur, self.cur_val = self._pending, runtime_ns
        self.temp *= self.cooling


class SMBO(Tuner):
    """Tree-structured Parzen Estimator (from scratch, no hyperopt).

    Splits observed configs into good (best gamma-quantile) and bad sets,
    models each dimension with a KDE, and proposes the candidate maximizing
    l(x)/g(x) among n_ei samples drawn from the good model.
    """
    name = "smbo"

    def __init__(self, space: ParamSpace, seed: int = 0, gamma: float = 0.25,
                 n_ei: int = 24, n_startup: int = 5, bw: float = 0.15):
        super().__init__(space, seed)
        self.gamma, self.n_ei, self.n_startup, self.bw = gamma, n_ei, n_startup, bw
        self.X: list[np.ndarray] = []   # normalized [0,1]^d
        self.y: list[float] = []

    def _norm(self, raw: dict) -> np.ndarray:
        x = np.array([raw[n] for n in self.space.names], np.float64)
        return (x - self.space.lows) / np.maximum(
            self.space.highs - self.space.lows, 1e-9)

    def _denorm(self, x01: np.ndarray) -> dict:
        x = x01 * (self.space.highs - self.space.lows) + self.space.lows
        out = {}
        for i, (n, kind) in enumerate(zip(self.space.names,
                                          self.space.kinds)):
            v = float(x[i])
            if kind == "bool":
                v = float(x01[i] > 0.5)
            elif kind in ("int", "choice"):
                v = float(round(v))
            out[n] = v
        return out

    def _kde_logpdf(self, pts: np.ndarray, x: np.ndarray) -> np.ndarray:
        # product of per-dim gaussian KDEs; pts [n,d], x [m,d] -> [m]
        if len(pts) == 0:
            return np.zeros(len(x))
        d2 = ((x[:, None, :] - pts[None, :, :]) / self.bw) ** 2
        log_k = -0.5 * d2.sum(-1)
        m = log_k.max(axis=1, keepdims=True)
        return (m[:, 0] + np.log(np.exp(log_k - m).sum(1) + 1e-300))

    def propose(self) -> dict:
        if len(self.y) < self.n_startup:
            return self.space.random_raw(self.rng)
        order = np.argsort(self.y)
        n_good = max(1, int(self.gamma * len(self.y)))
        good = np.stack([self.X[i] for i in order[:n_good]])
        bad = np.stack([self.X[i] for i in order[n_good:]]) \
            if len(self.y) > n_good else np.zeros((0, self.space.dim))
        # sample candidates from the good KDE
        centers = good[self.rng.integers(0, len(good), self.n_ei)]
        cands = np.clip(centers + self.rng.normal(0, self.bw,
                                                  centers.shape), 0, 1)
        score = self._kde_logpdf(good, cands) - self._kde_logpdf(bad, cands)
        return self._denorm(cands[int(np.argmax(score))])

    def observe(self, params: dict, runtime_ns: float, failed: bool):
        self.X.append(self._norm(params))
        self.y.append(runtime_ns * (4.0 if failed else 1.0))


def make_baseline(name: str, space: ParamSpace, seed: int = 0) -> Tuner:
    return {
        "random": RandomSearch, "grid": GridSearch,
        "heuristic": HeuristicSearch, "smbo": SMBO,
    }[name](space, seed)
