"""Straggler mitigation via speculative batch re-execution.

Because every batch is a pure function of (seed, step, shard)
(data/pipeline.py), a slow host's work can be re-issued on any spare host
without coordination or data movement: the backup recomputes `batch_at(cfg,
step)` for the straggler's shard and runs the same deterministic step.  The
first finisher wins; results are identical, so no reconciliation is needed.

This module provides the host-side policy: an EWMA step-time tracker that
flags stragglers, and a simulator used by tests (no real multi-host here).
"""
from __future__ import annotations

import dataclasses

import numpy as np


@dataclasses.dataclass
class StragglerDetector:
    n_shards: int
    ewma_alpha: float = 0.2
    threshold: float = 1.8   # x median EWMA

    def __post_init__(self):
        self.ewma = np.zeros(self.n_shards)

    def observe(self, shard: int, step_time_s: float):
        prev = self.ewma[shard]
        self.ewma[shard] = (step_time_s if prev == 0 else
                            (1 - self.ewma_alpha) * prev
                            + self.ewma_alpha * step_time_s)

    def stragglers(self) -> list[int]:
        active = self.ewma[self.ewma > 0]
        if len(active) < max(2, self.n_shards // 2):
            return []
        med = float(np.median(active))
        return [i for i in range(self.n_shards)
                if self.ewma[i] > self.threshold * med]


def simulate_speculative_execution(step_times: np.ndarray,
                                   detector: StragglerDetector,
                                   backup_speed: float = 1.0):
    """step_times [steps, shards] -> (completion time per step with/without
    speculation). A flagged straggler's shard is also run on a backup; the
    step completes at min(straggler, backup) while others are unaffected."""
    base, spec = [], []
    for t in range(step_times.shape[0]):
        times = step_times[t].copy()
        for s in range(detector.n_shards):
            detector.observe(s, times[s])
        base.append(times.max())
        flagged = detector.stragglers()
        for s in flagged:
            med = float(np.median(times))
            times[s] = min(times[s], med / backup_speed)
        spec.append(times.max())
    return np.array(base), np.array(spec)
