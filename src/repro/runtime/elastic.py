"""Elastic scaling: reshard a checkpoint onto a different mesh.

Checkpoints store full logical arrays (per-host shards in a real pod, with
the manifest describing the global shapes), so re-scaling is: load -> build
the new mesh's shardings from the same logical-axis annotations ->
device_put.  The data pipeline re-partitions itself from (n_shards,
shard_id) (data/pipeline.py), so a 16x16 run can resume as 8x8 or 2x16x16
with bitwise-identical model state and a consistent stream position.
"""
from __future__ import annotations

import jax

from repro.checkpoint import ckpt
from repro.runtime import mesh_utils


def reshard_tree(tree, axes_tree, new_mesh, rules=None):
    """device_put every leaf with the sharding its logical axes imply on
    `new_mesh` (divisibility fallbacks handled by logical_to_spec)."""
    def leaf(x, axes):
        sh = mesh_utils.logical_to_sharding(axes, x.shape, new_mesh, rules)
        return jax.device_put(x, sh)
    return jax.tree.map(
        leaf, tree, axes_tree,
        is_leaf=lambda x: isinstance(x, tuple) and all(
            isinstance(e, (str, type(None))) for e in x))


def restore_on_mesh(directory: str, step: int, like_tree, axes_tree,
                    new_mesh, rules=None):
    """Load checkpoint `step` and place it on `new_mesh`."""
    shardings = jax.tree.map(
        lambda sds, axes: mesh_utils.logical_to_sharding(
            axes, sds.shape, new_mesh, rules),
        like_tree, axes_tree,
        is_leaf=lambda x: isinstance(x, tuple) and all(
            isinstance(e, (str, type(None))) for e in x))
    return ckpt.restore(directory, step, like_tree, shardings)
