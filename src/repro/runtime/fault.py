"""Fault tolerance: failure injection + restart-from-checkpoint driver.

The contract for 1000+ node runs (DESIGN.md §7): any host can die at any
step; on restart the driver resumes from the latest *committed* checkpoint
(atomic rename in checkpoint/ckpt.py guarantees no torn state), and the
deterministic data pipeline replays the exact batch sequence from the
restored cursor.  tests/test_fault_tolerance.py kills a training run at a
random step and asserts the restarted run converges to the bit-identical
parameter trajectory of an uninterrupted run.
"""
from __future__ import annotations

import dataclasses
from typing import Callable

import numpy as np


class InjectedFailure(RuntimeError):
    """Simulated node failure."""


class FaultSite:
    """Deterministic per-site fault counter: the Nth event at a named
    site fires iff N is in `fire_at` (0-based ordinals).

    The training driver's `FailureInjector` below schedules faults by
    *step number*; long-lived services have no single step counter, so
    the serving health layer (`launch/serving/health.py`) instead counts
    events per site — fine-tune rounds, assessment dispatches, canary
    trials — and consults one `FaultSite` each.  Same idiom, one counter
    per seam instead of one per run."""

    def __init__(self, fire_at=()):
        self.fire_at = frozenset(int(x) for x in fire_at)
        self.count = 0

    def check(self) -> bool:
        """Count one event; True when this ordinal is scheduled to
        fail."""
        fired = self.count in self.fire_at
        self.count += 1
        return fired


@dataclasses.dataclass
class FailureInjector:
    """Deterministically raises at configured steps (or by probability)."""
    fail_at_steps: tuple = ()
    fail_prob: float = 0.0
    seed: int = 0
    max_failures: int = 1

    def __post_init__(self):
        self._rng = np.random.default_rng(self.seed)
        self._count = 0

    def check(self, step: int):
        if self._count >= self.max_failures:
            return
        if step in self.fail_at_steps or (
                self.fail_prob > 0 and self._rng.random() < self.fail_prob):
            self._count += 1
            raise InjectedFailure(f"simulated node failure at step {step}")


def run_with_restarts(make_driver: Callable[[], "object"],
                      total_steps: int, max_restarts: int = 5):
    """Supervisor loop: (re)create the driver and run until `total_steps`.

    `make_driver()` must return an object with `.step` (resumed position)
    and `.run_until(step)` that raises on failure.  Mirrors how a cluster
    scheduler restarts a crashed job from its checkpoint directory.
    """
    restarts = 0
    while True:
        driver = make_driver()
        try:
            driver.run_until(total_steps)
            return driver, restarts
        except InjectedFailure:
            restarts += 1
            if restarts > max_restarts:
                raise
