"""Logical-axis sharding rules -> concrete NamedShardings.

Every parameter/activation in the model stack is annotated with a tuple of
*logical* axis names ("vocab", "embed", "q_heads", ...).  A rule table maps
logical axes to mesh axes; `logical_to_spec` applies the table with
divisibility fallbacks so a single model definition lowers on any mesh
(1-device CPU smoke tests, 16x16 single pod, 2x16x16 multi-pod).
"""
from __future__ import annotations

import dataclasses
from typing import Mapping, Sequence

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

# Mesh axis groups: "data-like" axes absorb the batch; "model" is tensor
# parallel.  Multi-pod meshes prepend a "pod" axis that joins the data group.
DATA_AXES = ("pod", "data")
MODEL_AXIS = "model"


def shard_map_compat(body, mesh, in_specs, out_specs, axis_names=None):
    """Version-tolerant `shard_map` (single shim for every call site):
    jax >= 0.6 exposes `jax.shard_map` with `check_vma`/`axis_names`;
    older versions only have the experimental surface with `check_rep`.
    `axis_names` (the *manual* axes) maps to the experimental surface's
    complementary `auto=` set so partial-manual programs keep their
    GSPMD-managed axes instead of silently going fully manual."""
    if hasattr(jax, "shard_map"):
        kw = {"check_vma": False}
        if axis_names is not None:
            kw["axis_names"] = axis_names
        return jax.shard_map(body, mesh=mesh, in_specs=in_specs,
                             out_specs=out_specs, **kw)
    from jax.experimental.shard_map import shard_map
    kw = {"check_rep": False}
    if axis_names is not None:
        auto = frozenset(mesh.axis_names) - set(axis_names)
        if auto:
            kw["auto"] = auto
    return shard_map(body, mesh=mesh, in_specs=in_specs,
                     out_specs=out_specs, **kw)

# Default logical-axis -> mesh-axis rules (single source of truth).
# None means replicate.  Tuples mean "shard over the product of these axes".
DEFAULT_RULES: dict[str, object] = {
    "batch": DATA_AXES,          # global batch over pod x data
    "seq": None,                 # baseline: sequence replicated in train
    "act_embed": None,
    "vocab": MODEL_AXIS,
    "embed": None,
    "q_heads": MODEL_AXIS,
    "kv_heads": MODEL_AXIS,      # falls back to replicated if not divisible
    "head_dim": None,
    "mlp": MODEL_AXIS,
    "expert": MODEL_AXIS,
    "expert_mlp": None,
    "expert_cap": None,
    "layers": None,              # stacked-layer leading dim, never sharded
    "cache_batch": DATA_AXES,
    "cache_seq": None,           # adaptive: "model" when kv_heads can't shard
    "d_inner": MODEL_AXIS,       # mamba inner channels
    "conv": None,
    "state": None,
    "dt_rank": None,
    "enc_seq": None,
    "generic": None,
}


def axis_size(mesh: Mesh, axis) -> int:
    """Product of mesh axis sizes for a (possibly tuple / missing) axis."""
    if axis is None:
        return 1
    if isinstance(axis, str):
        return mesh.shape[axis] if axis in mesh.shape else 1
    size = 1
    for a in axis:
        if a in mesh.shape:
            size *= mesh.shape[a]
    return size


def _present(mesh: Mesh, axis):
    """Filter a rule target down to the axes present in this mesh."""
    if axis is None:
        return None
    if isinstance(axis, str):
        return axis if axis in mesh.shape else None
    kept = tuple(a for a in axis if a in mesh.shape)
    if not kept:
        return None
    return kept if len(kept) > 1 else kept[0]


def logical_to_spec(
    logical_axes: Sequence[str | None],
    shape: Sequence[int],
    mesh: Mesh,
    rules: Mapping[str, object] | None = None,
) -> P:
    """Map logical axes to a PartitionSpec, dropping non-divisible shardings.

    A dropped sharding is safe (replication), just less parallel; the dry-run
    report surfaces them so they become roofline findings, not crashes.
    """
    rules = dict(DEFAULT_RULES, **(rules or {}))
    assert len(logical_axes) == len(shape), (logical_axes, shape)
    used: set[str] = set()
    out = []
    for name, dim in zip(logical_axes, shape):
        target = _present(mesh, rules.get(name)) if name else None
        if target is None:
            out.append(None)
            continue
        t_axes = (target,) if isinstance(target, str) else tuple(target)
        if any(a in used for a in t_axes):
            out.append(None)  # a mesh axis can appear only once per spec
            continue
        if dim % axis_size(mesh, target) != 0:
            out.append(None)  # divisibility fallback -> replicate
            continue
        used.update(t_axes)
        out.append(target)
    return P(*out)


def logical_to_sharding(logical_axes, shape, mesh, rules=None) -> NamedSharding:
    return NamedSharding(mesh, logical_to_spec(logical_axes, shape, mesh, rules))


def tree_shardings(abstract_params, logical_tree, mesh, rules=None):
    """Shardings for a pytree of ShapeDtypeStructs given a parallel tree of
    logical-axis tuples."""
    return jax.tree.map(
        lambda sds, axes: logical_to_sharding(axes, sds.shape, mesh, rules),
        abstract_params,
        logical_tree,
        is_leaf=lambda x: isinstance(x, tuple) and all(
            isinstance(e, (str, type(None))) for e in x),
    )


def constrain(x, logical_axes, mesh=None, rules=None):
    """with_sharding_constraint by logical axes (no-op outside a mesh)."""
    mesh = mesh or _current_mesh()
    if mesh is None or mesh.empty:
        return x
    spec = logical_to_spec(logical_axes, x.shape, mesh, rules)
    return jax.lax.with_sharding_constraint(x, NamedSharding(mesh, spec))


def _current_mesh():
    try:
        from jax._src import mesh as mesh_lib
        mesh = mesh_lib.thread_resources.env.physical_mesh
        return None if mesh.empty else mesh
    except Exception:
        try:
            from jax.interpreters import pxla
            mesh = pxla.thread_resources.env.physical_mesh
            return None if mesh.empty else mesh
        except Exception:
            return None


@dataclasses.dataclass(frozen=True)
class MeshPlan:
    """A mesh + rule overrides, carried through lowering."""
    mesh: Mesh
    rules: dict = dataclasses.field(default_factory=dict)

    def spec(self, logical_axes, shape) -> P:
        return logical_to_spec(logical_axes, shape, self.mesh, self.rules)

    def sharding(self, logical_axes, shape) -> NamedSharding:
        return NamedSharding(self.mesh, self.spec(logical_axes, shape))

    @property
    def dp(self) -> int:
        return axis_size(self.mesh, DATA_AXES)

    @property
    def tp(self) -> int:
        return axis_size(self.mesh, MODEL_AXIS)
