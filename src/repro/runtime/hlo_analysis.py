"""Roofline-grade analysis of compiled (post-SPMD) HLO text.

Why this exists: XLA's ``compiled.cost_analysis()`` counts a ``while`` body
exactly once, so any scan-over-layers model (the only way to keep 95-layer
HLO compact) under-reports flops/bytes by ~the layer count.  This module
re-derives per-device totals from ``compiled.as_text()``:

  * computations + per-op result shapes are parsed line-by-line;
  * a call graph (fusion `calls=`, while `body=/condition=`, `to_apply=`,
    conditional branches) assigns each computation a multiplier;
  * while trip counts come from ``trip_scope`` markers ("<tag>_trip<N>") that
    the model code embeds in op metadata, with a fallback to the constant in
    the loop condition;
  * flops: 2 * prod(result dims) * prod(contracting dims) for every dot;
  * bytes: operand + result sizes of every op at fusion boundaries
    (reads + writes ~= HBM traffic);
  * collective bytes: operand sizes of all-gather / all-reduce /
    reduce-scatter / all-to-all / collective-permute, with ring-adjusted
    wire bytes reported alongside the raw spec-mandated sum.

Validated against cost_analysis() on unrolled models in
tests/test_hlo_analysis.py.
"""
from __future__ import annotations

import dataclasses
import re
from collections import defaultdict


def xla_cost_analysis(compiled) -> dict:
    """Version-tolerant ``compiled.cost_analysis()``: newer jax returns one
    properties dict, older versions a one-element list of dicts (and some
    builds return None for empty programs).  Always returns a dict."""
    ca = compiled.cost_analysis()
    if isinstance(ca, (list, tuple)):
        ca = ca[0] if ca else {}
    return ca or {}

_DTYPE_BYTES = {
    "pred": 1, "s4": 0.5, "u4": 0.5, "s8": 1, "u8": 1, "s16": 2, "u16": 2,
    "s32": 4, "u32": 4, "s64": 8, "u64": 8, "f16": 2, "bf16": 2, "f32": 4,
    "f64": 8, "c64": 8, "c128": 16, "f8e4m3fn": 1, "f8e5m2": 1, "f8e4m3": 1,
    "f8e3m4": 1, "f8e8m0fnu": 1, "token": 0, "opaque": 0,
}

COLLECTIVE_KINDS = (
    "all-reduce", "all-gather", "reduce-scatter", "all-to-all",
    "collective-permute",
)

_OP_RE = re.compile(
    r"^\s*(?:ROOT\s+)?%([\w.\-]+)\s+=\s+(.+?)\s+([a-z][a-z0-9\-]*)\((.*)$")
_COMP_RE = re.compile(r"^(?:ENTRY\s+)?%?([\w.\-]+)\s+\(.*\)\s+->\s+.+\s+\{")
_SHAPE_RE = re.compile(r"([a-z][a-z0-9]*)\[([0-9,]*)\]")
_TRIP_RE = re.compile(r"_trip(\d+)")
_GROUPS_RE = re.compile(r"replica_groups=\[(\d+),(\d+)\]")
_GROUPS_EXPLICIT_RE = re.compile(r"replica_groups=\{\{([^}]*)\}")
_CALL_ATTR_RE = re.compile(
    r"(calls|body|condition|to_apply|branch_computations|true_computation|"
    r"false_computation)=(\{[^}]*\}|%[\w.\-]+)")
_CONTRACT_RE = re.compile(r"lhs_contracting_dims=\{([0-9,]*)\}")
_OPNAME_META_RE = re.compile(r'op_name="([^"]*)"')

_FREE_OPS = {"parameter", "constant", "tuple", "get-tuple-element", "bitcast",
             "after-all", "partition-id", "replica-id", "iota",
             # control flow: the body/branch computations account their own
             # traffic; the op itself moves nothing (carries are aliased)
             "while", "conditional", "call"}

_SLICE_KINDS = {"dynamic-slice", "slice", "gather"}

# XLA:CPU lowers bf16 dots as f32 dots with materialized converts of the
# operands; the TPU MXU consumes bf16 natively with f32 accumulate, so
# convert-only traffic is a host-backend artifact and is not charged.
_TRIVIAL_KINDS = {"parameter", "constant", "bitcast", "reshape", "convert",
                  "tuple", "get-tuple-element", "broadcast"}


@dataclasses.dataclass
class Op:
    name: str
    kind: str
    shapes: list          # list of (dtype, dims) for result (tuple flattened)
    operands: list        # operand value names
    line: str

    def result_bytes(self) -> float:
        return sum(_DTYPE_BYTES.get(dt, 4) * _prod(dims)
                   for dt, dims in self.shapes)


def _prod(dims):
    out = 1
    for d in dims:
        out *= d
    return out


def _parse_shapes(type_str: str):
    return [(m.group(1), tuple(int(x) for x in m.group(2).split(",") if x))
            for m in _SHAPE_RE.finditer(type_str)]


@dataclasses.dataclass
class Computation:
    name: str
    ops: dict            # name -> Op
    order: list          # op names in order


def parse_hlo(text: str) -> dict[str, Computation]:
    comps: dict[str, Computation] = {}
    cur: Computation | None = None
    for line in text.splitlines():
        if cur is None:
            m = _COMP_RE.match(line.strip())
            if m and "->" in line:
                cur = Computation(m.group(1), {}, [])
            continue
        if line.startswith("}") or line.strip() == "}":
            comps[cur.name] = cur
            cur = None
            continue
        m = _OP_RE.match(line)
        if not m:
            continue
        name, type_str, kind, rest = m.groups()
        operands = re.findall(r"%([\w.\-]+)", rest.split(")")[0])
        op = Op(name=name, kind=kind, shapes=_parse_shapes(type_str),
                operands=operands, line=line.rstrip())
        cur.ops[name] = op
        cur.order.append(name)
    return comps


# ------------------------------------------------------------------
def _call_edges(op: Op):
    """Yields (attr, computation_name) for computations referenced by op."""
    for m in _CALL_ATTR_RE.finditer(op.line):
        attr, val = m.groups()
        if val.startswith("{"):
            for name in re.findall(r"%([\w.\-]+)", val):
                yield attr, name
        else:
            yield attr, val[1:]


def _while_trip(op: Op, comps: dict[str, Computation],
                warnings: list) -> int:
    meta = _OPNAME_META_RE.search(op.line)
    if meta:
        tags = _TRIP_RE.findall(meta.group(1))
        if tags:
            return int(tags[-1])
    # fallback: constant bound in the loop condition
    cond_name = next((c for a, c in _call_edges(op) if a == "condition"), None)
    if cond_name and cond_name in comps:
        cond = comps[cond_name]
        consts = {o.name: o for o in cond.ops.values() if o.kind == "constant"}
        for o in cond.ops.values():
            if o.kind == "compare":
                for operand in o.operands:
                    if operand in consts:
                        mm = re.search(r"constant\((\d+)\)",
                                       consts[operand].line)
                        if mm:
                            return int(mm.group(1))
    warnings.append(f"while {op.name}: trip count unknown, assuming 1")
    return 1


def _dot_flops(op: Op, comp: Computation) -> float:
    out_elems = _prod(op.shapes[0][1])
    m = _CONTRACT_RE.search(op.line)
    contract = 1
    if m and op.operands:
        lhs = comp.ops.get(op.operands[0])
        if lhs is not None:
            dims = lhs.shapes[0][1]
            for idx in (int(x) for x in m.group(1).split(",") if x):
                if idx < len(dims):
                    contract *= dims[idx]
    return 2.0 * out_elems * contract


def _param_index(op: Op) -> int | None:
    m = re.search(r"parameter\((\d+)\)", op.line)
    return int(m.group(1)) if m else None


def _op_bytes(op: Op, comp: Computation, comps: dict) -> float:
    """HBM traffic estimate for one boundary op (reads + writes).

    Slicing ops (and fusions that only slice an operand) are charged the
    *slice* size, and in-place dynamic-update-slice roots are charged the
    update size — matching XLA's buffer aliasing inside while loops.  Without
    this, scan-over-layers models are overcharged ~the full weight stack per
    layer (measured 400x inflation on an 8B train step).
    """
    if op.kind == "convert":
        return 0.0
    if op.kind == "dynamic-slice" or op.kind == "slice" or op.kind == "gather":
        return 2.0 * op.result_bytes()
    if op.kind == "dynamic-update-slice":
        upd = comp.ops.get(op.operands[1]) if len(op.operands) > 1 else None
        upd_bytes = upd.result_bytes() if upd else op.result_bytes()
        return 2.0 * upd_bytes
    if op.kind == "scatter":
        upd = comp.ops.get(op.operands[-1]) if op.operands else None
        return 2.0 * (upd.result_bytes() if upd else op.result_bytes())
    if op.kind == "fusion":
        callee = next((c for a, c in _call_edges(op) if a == "calls"), None)
        if callee in comps:
            return _fusion_bytes(op, comp, comps[callee])
    operand_bytes = sum(
        comp.ops[o].result_bytes() for o in op.operands
        if o in comp.ops and comp.ops[o].kind != "constant")
    return op.result_bytes() + operand_bytes


def _fusion_bytes(op: Op, comp: Computation, fc: Computation) -> float:
    if all(o.kind in _TRIVIAL_KINDS for o in fc.ops.values()):
        return 0.0  # pure dtype/layout-metadata fusion (host-backend artifact)
    params: dict[int, Op] = {}
    consumers: dict[str, list[Op]] = defaultdict(list)
    dus_ops: list[Op] = []
    for o in fc.ops.values():
        if o.kind == "parameter":
            idx = _param_index(o)
            if idx is not None:
                params[idx] = o
        for opr in o.operands:
            consumers[opr].append(o)
        if o.kind == "dynamic-update-slice":
            dus_ops.append(o)

    # In-place updates: charge 2x the update slice, alias the base buffer
    # (XLA aliases dus buffers in while bodies), and remove the full buffer
    # from the fusion's written-result accounting.
    result_bytes = op.result_bytes()
    aliased_param_names: set[str] = set()
    for dus in dus_ops:
        upd = fc.ops.get(dus.operands[1]) if len(dus.operands) > 1 else None
        if upd is None:
            continue
        base = fc.ops.get(dus.operands[0]) if dus.operands else None
        hops = 0
        while base is not None and base.kind in ("bitcast", "copy", "convert") \
                and base.operands and hops < 8:
            base = fc.ops.get(base.operands[0])
            hops += 1
        if base is not None and base.kind == "parameter":
            aliased_param_names.add(base.name)
            result_bytes -= dus.result_bytes()          # not fully written
            result_bytes += 2.0 * upd.result_bytes()    # rmw of the slice
    result_bytes = max(result_bytes, 0.0)

    total = result_bytes
    for i, opr_name in enumerate(op.operands):
        if opr_name not in comp.ops:
            continue
        full = comp.ops[opr_name].result_bytes()
        p = params.get(i)
        if p is None:
            total += full
            continue
        if p.name in aliased_param_names:
            continue  # in-place buffer, no read of the full extent
        uses = consumers.get(p.name, [])
        if uses and all(u.kind in _SLICE_KINDS for u in uses):
            total += sum(u.result_bytes() for u in uses)
        else:
            total += full
    return total


def _pre_convert_bytes(op: Op, comp: Computation, comps: dict) -> float:
    """Effective payload of a collective operand: when the operand is a
    bare convert (or a convert-only fusion), charge the *source* bytes --
    XLA:CPU upcasts bf16 to f32 around reductions, which the TPU backend
    does not materialize on the wire."""
    cur = op
    for _ in range(4):
        if cur.kind == "convert" and cur.operands:
            nxt = comp.ops.get(cur.operands[0])
        elif cur.kind == "fusion":
            callee = next((c for a, c in _call_edges(cur) if a == "calls"),
                          None)
            fc = comps.get(callee)
            if fc is None or not all(o.kind in _TRIVIAL_KINDS
                                     for o in fc.ops.values()):
                break
            nxt = comp.ops.get(cur.operands[0]) if cur.operands else None
        else:
            break
        if nxt is None:
            break
        if nxt.result_bytes() < cur.result_bytes():
            cur = nxt
        else:
            break
    return cur.result_bytes()


def _group_size(op: Op, default: int) -> int:
    m = _GROUPS_RE.search(op.line)
    if m:
        return int(m.group(2))
    m = _GROUPS_EXPLICIT_RE.search(op.line)
    if m:
        return len([x for x in m.group(1).split(",") if x.strip() != ""])
    return default


@dataclasses.dataclass
class HLOAnalysis:
    flops: float = 0.0
    bytes_accessed: float = 0.0
    collective_bytes: float = 0.0        # raw operand sizes (spec formula)
    collective_wire_bytes: float = 0.0   # ring-adjusted on-wire estimate
    per_collective: dict = dataclasses.field(default_factory=dict)
    while_trips: dict = dataclasses.field(default_factory=dict)
    warnings: list = dataclasses.field(default_factory=list)

    def as_dict(self):
        return {
            "flops": self.flops,
            "bytes_accessed": self.bytes_accessed,
            "collective_bytes": self.collective_bytes,
            "collective_wire_bytes": self.collective_wire_bytes,
            "per_collective": dict(self.per_collective),
            "warnings": list(self.warnings),
        }


def analyze(text: str, n_devices: int = 1,
            fused_scopes: tuple = ()) -> HLOAnalysis:
    """Analyze post-SPMD HLO text; all numbers are PER-DEVICE.

    fused_scopes: named_scope tags whose interior byte traffic is discounted
    (flops and collectives still counted) -- used to project the measured
    jnp lowering onto the implemented Pallas kernels, whose working set is
    VMEM-resident (e.g. "flash_fusible")."""
    comps = parse_hlo(text)
    res = HLOAnalysis()

    # entry computation = the one never referenced by others
    referenced = set()
    for comp in comps.values():
        for op in comp.ops.values():
            for _, callee in _call_edges(op):
                referenced.add(callee)
    entries = [c for c in comps if c not in referenced]
    if not entries:
        res.warnings.append("no entry computation found")
        return res

    # propagate multipliers through the call graph
    mult: dict[str, float] = defaultdict(float)
    fused_only: dict[str, bool] = defaultdict(lambda: True)
    for e in entries:
        mult[e] = 1.0
        fused_only[e] = False
    # iterate to fixpoint (call graphs are DAGs; bounded passes)
    for _ in range(len(comps) + 2):
        changed = False
        for cname, comp in comps.items():
            if mult[cname] == 0:
                continue
            for op in comp.ops.values():
                trip = None
                for attr, callee in _call_edges(op):
                    if callee not in comps:
                        continue
                    factor = 1.0
                    if attr in ("body", "condition"):
                        if trip is None:
                            trip = _while_trip(op, comps, res.warnings)
                            res.while_trips[op.name] = trip
                        factor = float(trip)
                    new = mult[cname] * factor
                    is_fusion_edge = (attr == "calls" and op.kind == "fusion")
                    if new > mult[callee] + 1e-9:
                        mult[callee] = new
                        changed = True
                    if not is_fusion_edge and fused_only[callee]:
                        fused_only[callee] = False
                        changed = True
        if not changed:
            break

    # accumulate per-op costs
    for cname, comp in comps.items():
        m = mult[cname]
        if m == 0:
            continue
        boundary = not fused_only[cname]
        for op in comp.ops.values():
            if op.kind == "dot":
                res.flops += m * _dot_flops(op, comp)
            elif op.kind == "convolution":
                # rough: 2 * out_elems * (in_ch * prod(kernel)) unknown from
                # text -> use 2*out_elems and warn (models avoid conv ops)
                res.flops += m * 2.0 * _prod(op.shapes[0][1])
                res.warnings.append(f"convolution {op.name}: approximate flops")
            kind = op.kind.replace("-start", "")
            if kind in COLLECTIVE_KINDS:
                operand_bytes = sum(
                    _pre_convert_bytes(comp.ops[o], comp, comps)
                    for o in op.operands if o in comp.ops)
                if operand_bytes == 0:
                    operand_bytes = op.result_bytes()
                n = _group_size(op, n_devices)
                if kind == "all-reduce":
                    wire = 2.0 * (n - 1) / max(n, 1) * operand_bytes
                elif kind == "collective-permute":
                    wire = operand_bytes
                elif kind == "all-gather":
                    # operand is the shard; on-wire each device sends its
                    # shard to n-1 peers in a ring: (n-1) * shard
                    wire = (n - 1) * operand_bytes
                else:  # reduce-scatter, all-to-all: operand is full buffer
                    wire = (n - 1) / max(n, 1) * operand_bytes
                res.collective_bytes += m * operand_bytes
                res.collective_wire_bytes += m * wire
                agg = res.per_collective.setdefault(
                    kind, {"count": 0.0, "bytes": 0.0})
                agg["count"] += m
                agg["bytes"] += m * operand_bytes
            if boundary and op.kind not in _FREE_OPS:
                if fused_scopes:
                    meta = _OPNAME_META_RE.search(op.line)
                    if meta and any(t in meta.group(1) for t in fused_scopes):
                        continue
                res.bytes_accessed += m * _op_bytes(op, comp, comps)
    return res


# ------------------------------------------------------------------
# TPU v5e roofline constants (per chip)
PEAK_FLOPS_BF16 = 197e12       # FLOP/s
HBM_BW = 819e9                 # B/s
ICI_BW = 50e9                  # B/s per link


@dataclasses.dataclass
class RooflineTerms:
    compute_s: float
    memory_s: float
    collective_s: float
    collective_wire_s: float
    model_flops: float = 0.0
    hlo_flops: float = 0.0

    @property
    def dominant(self) -> str:
        terms = {"compute": self.compute_s, "memory": self.memory_s,
                 "collective": self.collective_s}
        return max(terms, key=terms.get)

    @property
    def step_time_s(self) -> float:
        """Optimistic (full-overlap) step time = max of the three terms."""
        return max(self.compute_s, self.memory_s, self.collective_s)

    @property
    def useful_ratio(self) -> float:
        return self.model_flops / self.hlo_flops if self.hlo_flops else 0.0

    @property
    def roofline_fraction(self) -> float:
        """MODEL_FLOPS-per-chip / peak over the bottleneck-implied step time."""
        if self.step_time_s == 0:
            return 0.0
        return (self.model_flops / PEAK_FLOPS_BF16) / self.step_time_s


def roofline(analysis: HLOAnalysis, model_flops_per_device: float = 0.0
             ) -> RooflineTerms:
    return RooflineTerms(
        compute_s=analysis.flops / PEAK_FLOPS_BF16,
        memory_s=analysis.bytes_accessed / HBM_BW,
        collective_s=analysis.collective_bytes / ICI_BW,
        collective_wire_s=analysis.collective_wire_bytes / ICI_BW,
        model_flops=model_flops_per_device,
        hlo_flops=analysis.flops,
    )
