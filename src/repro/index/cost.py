"""Calibrated per-operation cost constants (ns).

The simulator counts *work* (model evals, probe steps, shifts, slot copies,
retrained keys, buffer comparisons, cache lines) and converts to nanoseconds
with these constants, which are calibrated to the order of magnitude of
published ALEX/CARMI microbenchmarks (in-cache probe ~3ns, model eval ~5ns,
DRAM cache line ~60-100ns, retrain ~10ns/key amortized).

A deterministic cost surface is what makes thousands of parallel tuning
environments per chip possible (DESIGN.md §2); the absolute scale only
shifts runtimes, not the tuning landscape.
"""

MODEL_EVAL_NS = 5.0          # linear model evaluation
PROBE_STEP_NS = 3.0          # one exponential/binary search step (in cache)
SHIFT_NS = 2.0               # move one element in a gapped array
SLOT_INIT_NS = 0.5           # allocate/copy one slot during expansion
RETRAIN_PER_KEY_NS = 10.0    # refit models over one key
FIT_PER_KEY_NS = 4.0         # initial build fit per key
BUFFER_CMP_NS = 1.0          # out-of-domain buffer linear-scan comparison
QUERY_BASE_NS = 20.0         # fixed per-query overhead (dispatch etc.)
CACHE_LINE_NS = 60.0         # DRAM cache-line fetch (CARMI)
CACHE_LINE_PREFETCHED_NS = 8.0
KEYS_PER_LINE = 8            # 64B line / 8B key

# Failure thresholds for the ET-MDP cost functions (env-level).
MEM_BUDGET_BYTES = 64e6      # per-reservoir memory budget
RUNTIME_BUDGET_NS = 1e8      # per-step runtime budget ("endless runtime")
