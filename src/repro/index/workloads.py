"""SOSD-style datasets and query workloads.

The paper evaluates on SOSD (books / OSM / Facebook / MIX) and trains on
synthetic distributions (uniform, beta/normal, ...) with W/R ratios between
1:10 and 10:1.  This module generates statistically matching synthetic key
sets (we have no network access), plus the tumbling-window data-shift streams
of §5.2.4(b).

All keys are float64 in [0, 1): learned-index mechanics only depend on the
empirical CDF, so any monotone rescaling of the published datasets is
equivalent for tuning dynamics.
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp

DATASETS = ("uniform", "books", "osm", "fb", "mix")


def _normalize(keys: jax.Array) -> jax.Array:
    lo, hi = jnp.min(keys), jnp.max(keys)
    return (keys - lo) / jnp.maximum(hi - lo, 1e-12)


def sample_keys(key: jax.Array, n: int, dist: str = "mix",
                shift: float = 0.0) -> jax.Array:
    """n sorted unique-ish keys in [0,1). `shift` in [0,1] drifts the
    distribution (for data-shifting streams)."""
    k1, k2, k3, k4 = jax.random.split(key, 4)
    if dist == "uniform":
        x = jax.random.uniform(k1, (n,))
    elif dist == "books":  # lognormal-ish popularity
        x = jnp.exp(jax.random.normal(k1, (n,)) * (1.0 + shift))
    elif dist == "osm":    # multi-modal clusters (geographic)
        n_clusters = 8
        centers = jax.random.uniform(k1, (n_clusters,))
        widths = jax.random.uniform(k2, (n_clusters,), minval=0.001,
                                    maxval=0.05 + 0.1 * shift)
        assign = jax.random.randint(k3, (n,), 0, n_clusters)
        x = centers[assign] + jax.random.normal(k4, (n,)) * widths[assign]
    elif dist == "fb":     # heavy-tailed ids
        u = jax.random.uniform(k1, (n,), minval=1e-6)
        x = u ** (-1.0 / (1.5 + shift))  # pareto tail
    elif dist == "mix":
        parts = [sample_keys(kk, n, d, shift)
                 for kk, d in zip(jax.random.split(k1, 4),
                                  ("uniform", "books", "osm", "fb"))]
        assign = jax.random.randint(k2, (n,), 0, 4)
        x = jnp.stack(parts, 0)[assign, jnp.arange(n)]
    else:
        raise ValueError(f"unknown dataset {dist}")
    # dedupe-ish: add tiny deterministic jitter, normalize, sort
    x = _normalize(x) + jnp.arange(n) * 1e-12
    return jnp.sort(_normalize(x))


@dataclasses.dataclass(frozen=True)
class WorkloadConfig:
    n_reads: int = 2048
    n_inserts: int = 2048
    read_hit_frac: float = 0.9      # fraction of reads that hit existing keys
    insert_in_domain_frac: float = 0.9  # rest are out-of-domain (beyond max)
    insert_drift: float = 0.0       # distribution drift of inserted keys

    @property
    def wr_ratio(self) -> float:
        return self.n_inserts / max(self.n_reads, 1)


def make_workload(key: jax.Array, data_keys: jax.Array, cfg: WorkloadConfig,
                  dist: str = "mix"):
    """Returns dict of query arrays: reads [n_reads], inserts [n_inserts]."""
    k1, k2, k3, k4, k5 = jax.random.split(key, 5)
    n = data_keys.shape[0]
    # reads: mostly existing keys, some misses
    idx = jax.random.randint(k1, (cfg.n_reads,), 0, n)
    hits = data_keys[idx]
    misses = jax.random.uniform(k2, (cfg.n_reads,))
    is_hit = jax.random.uniform(k3, (cfg.n_reads,)) < cfg.read_hit_frac
    reads = jnp.where(is_hit, hits, misses)
    # inserts: in-domain from (possibly drifted) distribution; rest beyond max
    fresh = sample_keys(k4, cfg.n_inserts, dist, shift=cfg.insert_drift)
    dmax = jnp.max(data_keys)
    out_of_domain = dmax + jax.random.uniform(
        k5, (cfg.n_inserts,)) * 0.2 + 1e-6
    in_dom = jax.random.uniform(k5, (cfg.n_inserts,)) \
        < cfg.insert_in_domain_frac
    inserts = jnp.where(in_dom, fresh * dmax, out_of_domain)
    return {"reads": reads, "inserts": inserts}


def wr_workload(key, data_keys, wr_ratio: float, total: int = 4096,
                dist: str = "mix", drift: float = 0.0):
    """Workload from a write/read ratio (paper: Balanced=1, RH=1/3, WH=3)."""
    n_ins = int(total * wr_ratio / (1.0 + wr_ratio))
    cfg = WorkloadConfig(n_reads=total - n_ins, n_inserts=n_ins,
                         insert_drift=drift)
    return make_workload(key, data_keys, cfg, dist), cfg


@dataclasses.dataclass(frozen=True)
class StreamConfig:
    """Tumbling-window data-shift stream (paper §5.2.4(b))."""
    n_windows: int = 30
    base_per_window: int = 4096
    updates_per_window: int = 8192
    dist: str = "mix"
    drift_per_window: float = 0.03
    wr_start: float = 1.0
    wr_end: float = 3.0


def stream_windows(key: jax.Array, cfg: StreamConfig):
    """Yields (window_idx, data_keys, workload, wr_ratio) lazily."""
    for w in range(cfg.n_windows):
        kw = jax.random.fold_in(key, w)
        k1, k2 = jax.random.split(kw)
        shift = cfg.drift_per_window * w
        data = sample_keys(k1, cfg.base_per_window, cfg.dist, shift=shift)
        frac = w / max(cfg.n_windows - 1, 1)
        wr = cfg.wr_start + (cfg.wr_end - cfg.wr_start) * frac
        workload, _ = wr_workload(k2, data, wr, total=cfg.updates_per_window,
                                  dist=cfg.dist, drift=shift)
        yield w, data, workload, wr
