"""ALEX-family gapped-array learned index, as a pure-JAX functional simulator.

Faithful mechanics (see DESIGN.md §4): two-level structure (root model over
leaves, per-leaf linear models over a gapped array), exact per-query search
distances on real fitted models, density-triggered expansions, policy-driven
splits, and the out-of-domain insert buffer whose thresholds
(kMaxOutOfDomainKeys x kOutOfDomainToleranceFactor) create the paper's
"dangerous zone" (Fig 11).  All operations are batched and jit/vmap-able;
costs are work counters multiplied by calibrated ns constants (index/cost.py).

14 tunable parameters matching Table 2 (5 continuous, 3 boolean, 4 integer,
2 discrete-choice) -- see PARAM_SPACE below.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.index import cost as C
from repro.index import linear_model as lm
from repro.kernels.index_probe.ops import predecessor_positions

MAX_LEAVES = 512  # static capacity; max_fanout param stays below this

# name, kind, (low, high) in *raw* space
PARAM_SPACE = [
    ("density_init", "cont", (0.5, 0.95)),
    ("density_upper", "cont", (0.6, 0.99)),
    ("expected_insert_frac", "cont", (0.0, 1.0)),
    ("split_balance", "cont", (0.3, 0.7)),
    ("cost_w_traverse", "cont", (0.0, 1.0)),
    ("approx_model_computation", "bool", (0, 1)),
    ("approx_cost_computation", "bool", (0, 1)),
    ("allow_splitting_upwards", "bool", (0, 1)),
    ("max_node_size_log2", "int", (8, 16)),
    ("kmax_ood_keys_log2", "int", (2, 14)),
    ("ood_tolerance_factor", "int", (1, 50)),
    ("max_fanout_log2", "int", (4, 9)),
    ("fanout_selection_method", "choice", (0, 1)),   # equi-depth | equi-width
    ("splitting_policy_method", "choice", (0, 2)),   # halve | density | side
]

# Expert defaults (mirrors ALEX's published defaults, scaled to simulator).
DEFAULTS = {
    "density_init": 0.7, "density_upper": 0.8, "expected_insert_frac": 1.0,
    "split_balance": 0.5, "cost_w_traverse": 0.5,
    "approx_model_computation": 0, "approx_cost_computation": 0,
    "allow_splitting_upwards": 0, "max_node_size_log2": 14,
    "kmax_ood_keys_log2": 4, "ood_tolerance_factor": 2,
    "max_fanout_log2": 7, "fanout_selection_method": 0,
    "splitting_policy_method": 0,
}


def build(keys: jax.Array, p: dict):
    """Construct the index on sorted keys [n]. Returns an index state dict."""
    n = keys.shape[0]
    nf = jnp.asarray(n, jnp.float32)
    max_fanout = 2.0 ** p["max_fanout_log2"]
    node_keys = 2.0 ** p["max_node_size_log2"] * p["density_init"]
    n_leaves = jnp.clip(jnp.ceil(nf / jnp.maximum(node_keys, 16.0)),
                        1.0, jnp.minimum(max_fanout, MAX_LEAVES))

    ranks = jnp.arange(n, dtype=jnp.float32)
    kmin, kmax = keys[0], keys[-1]
    width = jnp.maximum(kmax - kmin, 1e-12)
    seg_depth = jnp.minimum((ranks * n_leaves / nf), n_leaves - 1.0)
    seg_width = jnp.clip((keys - kmin) / width * n_leaves, 0.0, n_leaves - 1.0)
    equi_width = p["fanout_selection_method"] > 0.5
    seg = jnp.where(equi_width, seg_width, seg_depth).astype(jnp.int32)

    exact = lm.fit_segments_exact(keys, seg, MAX_LEAVES)
    approx = lm.fit_segments_approx(keys, seg, MAX_LEAVES)
    use_approx = p["approx_model_computation"] > 0.5
    slope = jnp.where(use_approx, approx[0], exact[0])
    intercept = jnp.where(use_approx, approx[1], exact[1])
    cnt = exact[2]
    err = lm.segment_errors(keys, seg, MAX_LEAVES, slope, intercept)

    # root model: linear fit of key -> leaf id (exact for equi-width)
    root_slope_w = n_leaves / width
    root_icpt_w = -root_slope_w * kmin
    rs, ri, _ = lm.fit_segments_exact(keys, jnp.zeros_like(seg), 1)
    root_slope_d = rs[0] * n_leaves / nf        # rank-model -> leaf id
    root_icpt_d = ri[0] * n_leaves / nf
    root_slope = jnp.where(equi_width, root_slope_w, root_slope_d)
    root_icpt = jnp.where(equi_width, root_icpt_w, root_icpt_d)

    # gapped slots: density + headroom for expected inserts
    slots = cnt / jnp.maximum(p["density_init"], 0.05) \
        * (1.0 + 0.5 * p["expected_insert_frac"])
    slots = jnp.where(cnt > 0, jnp.maximum(slots, cnt + 1.0), 0.0)

    build_cost = (n * C.RETRAIN_PER_KEY_NS
                  + jnp.sum(slots) * C.SLOT_INIT_NS
                  + jnp.where(use_approx, 0.3, 1.0) * n * C.FIT_PER_KEY_NS)

    return {
        "keys": keys, "seg_of_key": seg,
        "n_leaves": n_leaves, "slope": slope, "intercept": intercept,
        "cnt": cnt, "slots": slots, "err": err,
        "root_slope": root_slope, "root_icpt": root_icpt,
        "kmin": kmin, "kmax": kmax,
        "ood_buffer": jnp.float32(0.0),
        "counters": {
            "n_expands": jnp.float32(0.0), "n_splits": jnp.float32(0.0),
            "n_retrains": jnp.float32(0.0), "build_cost_ns": build_cost,
            "mega_leaf": jnp.float32(0.0),
        },
    }


def _locate(idx: dict, q: jax.Array, pos: jax.Array | None = None):
    """Root traversal for a batch of queries. Returns (leaf, root_cost).

    `pos` accepts precomputed predecessor positions (the read path probes
    once through `predecessor_positions` and shares the result with the
    local-search stage); None recomputes the searchsorted reference."""
    pred = idx["root_slope"] * q + idx["root_icpt"]
    pred = jnp.clip(pred, 0.0, idx["n_leaves"] - 1.0)
    # true leaf = leaf of the predecessor key (exact, computed on real data)
    if pos is None:
        pos = jnp.clip(jnp.searchsorted(idx["keys"], q, side="right") - 1,
                       0, idx["keys"].shape[0] - 1)
    true_leaf = idx["seg_of_key"][pos]
    root_err = jnp.abs(pred - true_leaf.astype(jnp.float32))
    cost = C.MODEL_EVAL_NS + C.PROBE_STEP_NS * jnp.log2(1.0 + root_err)
    return true_leaf, cost, root_err


def run_reads(idx: dict, reads: jax.Array, kernel=None):
    """Batched SEARCH. Returns (total_ns, metrics dict).

    `kernel` (a `kernels.dispatch.KernelConfig`) gates the predecessor
    probe: Pallas modes route it through the `index_probe` kernel, the
    default resolves to the bitwise `searchsorted` reference on CPU.  The
    probe runs once and feeds both the root-traversal and local-search
    stages (historically two identical searchsorteds)."""
    pos = predecessor_positions(idx["keys"], reads, kernel=kernel)
    leaf, root_cost, root_err = _locate(idx, reads, pos=pos)
    cnt = jnp.maximum(idx["cnt"], 1.0)
    starts = jnp.cumsum(idx["cnt"]) - idx["cnt"]
    local_rank = pos.astype(jnp.float32) - starts[leaf]
    pred_local = idx["slope"][leaf] * reads + idx["intercept"][leaf]
    pred_local = jnp.clip(pred_local, 0.0, cnt[leaf])
    # gapped-array positions scale ranks by 1/density
    density = jnp.clip(idx["cnt"] / jnp.maximum(idx["slots"], 1.0), 0.01, 1.0)
    search_dist = jnp.abs(pred_local - local_rank) / density[leaf]
    probe = C.MODEL_EVAL_NS + C.PROBE_STEP_NS * (
        1.0 + 2.0 * jnp.log2(1.0 + search_dist))
    buffer_scan = idx["ood_buffer"] * C.BUFFER_CMP_NS  # linear ood scan
    per_q = C.QUERY_BASE_NS + root_cost + probe + buffer_scan
    total = jnp.sum(per_q)
    return total, {
        "avg_search_dist": jnp.mean(search_dist),
        "p99_search_dist": jnp.percentile(search_dist, 99),
        "avg_root_err": jnp.mean(root_err),
        "read_ns_avg": jnp.mean(per_q),
    }


def run_inserts(idx: dict, inserts: jax.Array, p: dict):
    """Batched INSERT with density-aware displacement, expansions, splits and
    the out-of-domain buffer/retrain mechanics.  Returns (idx', ns, metrics).
    """
    in_domain = inserts <= idx["kmax"]
    n_ood = jnp.sum(~in_domain).astype(jnp.float32)
    q_in = jnp.where(in_domain, inserts, idx["kmin"])  # mask ood from leaves

    leaf, root_cost, _ = _locate(idx, q_in)
    w_in = in_domain.astype(jnp.float32)
    add = jnp.zeros(MAX_LEAVES).at[leaf].add(w_in)

    cnt0, slots0 = idx["cnt"], jnp.maximum(idx["slots"], 1.0)
    occ0 = jnp.clip(cnt0 / slots0, 0.0, 0.999)
    occ1 = jnp.clip((cnt0 + add) / slots0, 0.0, 0.999)
    occ_mid = 0.5 * (occ0 + occ1)
    # expected gapped-array displacement ~ rho/(1-rho)
    disp = occ_mid / (1.0 - occ_mid)
    per_leaf_ins_ns = add * (C.MODEL_EVAL_NS + C.SHIFT_NS * disp
                             + C.PROBE_STEP_NS * 2.0)
    cnt1 = cnt0 + add

    # --- expansions / splits ---
    over = (cnt1 / slots0 > p["density_upper"]) & (cnt0 > 0)
    node_cap = 2.0 ** p["max_node_size_log2"]
    want_expand = over & (slots0 / p["density_init"] <= node_cap)
    # approximate cost model mis-predicts expand-vs-split decisions
    flip = (p["approx_cost_computation"] > 0.5) & \
        (jnp.abs(jnp.sin(cnt1 * 12.9898)) < 0.15 + 0.2 * p["cost_w_traverse"])
    want_split = (over & ~want_expand) | (want_expand & flip)
    want_expand = over & ~want_split

    new_slots = jnp.where(want_expand, cnt1 / p["density_init"], slots0)
    expand_ns = jnp.where(want_expand,
                          new_slots * C.SLOT_INIT_NS
                          + cnt1 * C.RETRAIN_PER_KEY_NS, 0.0)

    can_split = (idx["n_leaves"] < 2.0 ** p["max_fanout_log2"]) | \
        (p["allow_splitting_upwards"] > 0.5)
    do_split = want_split & can_split
    bal = jnp.clip(p["split_balance"], 0.05, 0.95)
    imb = 1.0 + jnp.abs(bal - 0.5) * 2.0   # unbalanced splits refill faster
    split_ns = jnp.where(do_split,
                         cnt1 * (C.RETRAIN_PER_KEY_NS + C.SHIFT_NS) * imb, 0.0)
    # split halves occupancy (approximately, policy-dependent)
    policy = p["splitting_policy_method"]
    post_density = jnp.where(policy < 0.5, 0.5,
                             jnp.where(policy < 1.5, p["density_init"], 0.65))
    # cascade pathology: if splits leave nodes at/above the expansion
    # threshold they immediately re-split -- the "infinite loop" failure mode
    # of the real codebase (Fig 4b / Fig 11 dangerous zone).
    cascade = jnp.where(post_density >= p["density_upper"] - 0.02,
                        50.0, 1.0)
    split_ns = split_ns * cascade
    new_slots = jnp.where(do_split, cnt1 / jnp.maximum(post_density, 0.05),
                          new_slots)
    mega = want_split & ~can_split   # couldn't split: mega-leaf degradation
    new_slots = jnp.where(mega, cnt1 / 0.99, new_slots)

    n_new_leaves = jnp.minimum(idx["n_leaves"] + jnp.sum(do_split),
                               float(MAX_LEAVES))

    # --- out-of-domain buffer ---
    kmax_ood = 2.0 ** p["kmax_ood_keys_log2"]
    limit = kmax_ood * p["ood_tolerance_factor"]
    buf1 = idx["ood_buffer"] + n_ood
    retrain = buf1 > limit
    n_keys = idx["keys"].shape[0]
    retrain_ns = jnp.where(retrain,
                           (n_keys + buf1) * C.RETRAIN_PER_KEY_NS
                           + jnp.sum(new_slots) * C.SLOT_INIT_NS, 0.0)
    buf2 = jnp.where(retrain, 0.0, buf1)
    ood_ns = n_ood * (C.QUERY_BASE_NS + C.BUFFER_CMP_NS * buf1 * 0.5)

    total_ns = (jnp.sum(per_leaf_ins_ns) + jnp.sum(expand_ns)
                + jnp.sum(split_ns) + retrain_ns + ood_ns
                + jnp.sum(w_in) * C.QUERY_BASE_NS
                + jnp.sum(root_cost * w_in))

    counters = dict(idx["counters"])
    counters["n_expands"] = counters["n_expands"] + jnp.sum(want_expand)
    counters["n_splits"] = counters["n_splits"] + jnp.sum(do_split)
    counters["n_retrains"] = counters["n_retrains"] + retrain.astype(jnp.float32)
    counters["mega_leaf"] = counters["mega_leaf"] + jnp.sum(mega)

    idx2 = dict(idx)
    idx2["cnt"] = cnt1
    idx2["slots"] = jnp.where(cnt0 > 0, new_slots, slots0)
    idx2["ood_buffer"] = buf2
    idx2["n_leaves"] = n_new_leaves
    idx2["counters"] = counters
    metrics = {
        "insert_ns_avg": total_ns / jnp.maximum(inserts.shape[0], 1),
        "avg_displacement": jnp.mean(disp * (add > 0)),
        "ood_frac": n_ood / jnp.maximum(inserts.shape[0], 1),
        "buffer_fill": buf2,
        "retrained": retrain.astype(jnp.float32),
    }
    return idx2, total_ns, metrics


def memory_bytes(idx: dict, p: dict | None = None) -> jax.Array:
    """Resident bytes: slots + models + the PRE-ALLOCATED out-of-domain
    buffer capacity (kMaxOutOfDomainKeys x tolerance per boundary region).

    With equi-width fanout + upward splitting the boundary-region count
    multiplies -- reproducing the paper's Fig-11 dangerous zone where
    aggressive (kmax_ood, tolerance) settings crash the system."""
    base = (jnp.sum(idx["slots"]) * 16.0 + MAX_LEAVES * 32.0
            + idx["ood_buffer"] * 16.0)
    if p is None:
        return base
    regions = 32.0 * jnp.where(
        (p["fanout_selection_method"] > 0.5)
        & (p["allow_splitting_upwards"] > 0.5), 4.0, 1.0) * jnp.where(
        p["splitting_policy_method"] > 0.5, 2.0, 1.0)
    buffer_capacity = (2.0 ** p["kmax_ood_keys_log2"]
                       * p["ood_tolerance_factor"] * 16.0 * regions)
    return base + buffer_capacity
