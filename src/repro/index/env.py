"""The learned-index tuning environment (the paper's RL environment).

One step = (decode action -> index params) -> rebuild index on the reservoir
sample -> execute the query workload -> metrics/state/reward.  This mirrors
LITune's working process (§3.5): the index is the environment, parameters are
actions, structural+operational metrics are states, reward follows §4.1.

The env is a pure function of its state dict -> jit / vmap / scan friendly,
which is what lets meta-training shard thousands of environments across the
mesh `data` axis (DESIGN.md §2).

Constraint costs (ET-MDP, §4.2): c_m = 1 on memory-budget violation,
c_r = 1 on runtime-budget violation; the ET-MDP wrapper (core/etmdp.py)
terminates when the cumulative cost exceeds C.
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp

from repro.core import reward as rw
from repro.core.spaces import ParamSpace, alex_space, carmi_space
from repro.index import alex, carmi
from repro.index import cost as C
from repro.index.features import STATE_DIM, state_vector, workload_stats
from repro.kernels.dispatch import KernelConfig


@dataclasses.dataclass(frozen=True)
class EnvConfig:
    index_type: str = "alex"          # alex | carmi
    episode_len: int = 25
    mem_budget: float = C.MEM_BUDGET_BYTES
    runtime_budget: float = C.RUNTIME_BUDGET_NS
    omega: int = 1
    kappa: int = 2
    # kernel execution posture (kernels/dispatch.py): gates routing the
    # read probes through the Pallas index_probe kernel and the serving
    # tick's fused capture.  Frozen and hashable — it rides the jit
    # static args and serving program-cache keys, so two postures never
    # share an executable.  The default resolves to the bitwise jnp
    # reference on CPU and the compiled kernels on GPU/TPU
    kernel: KernelConfig = KernelConfig()

    @property
    def space(self) -> ParamSpace:
        return alex_space() if self.index_type == "alex" else carmi_space()

    def with_episode_len(self, n: int) -> "EnvConfig":
        """Same environment, different horizon — the tuning/O2/serving
        paths re-horizon per request without touching any other knob."""
        return dataclasses.replace(self, episode_len=n)


def _backend(index_type: str):
    mod = alex if index_type == "alex" else carmi
    return mod


def evaluate_params(cfg: EnvConfig, params_raw: dict, data_keys, workload,
                    wr_ratio):
    """Build + run one workload under `params_raw`.

    Returns (runtime_ns, state_pieces, violations) -- the core experiment
    primitive shared by the RL env and every baseline tuner.
    """
    mod = _backend(cfg.index_type)
    if cfg.index_type == "alex":
        idx = mod.build(data_keys, params_raw)
        read_ns, read_m = mod.run_reads(idx, workload["reads"],
                                        kernel=cfg.kernel)
        idx, ins_ns, ins_m = mod.run_inserts(idx, workload["inserts"],
                                             params_raw)
    else:
        idx = mod.build(data_keys, params_raw)
        read_ns, read_m = mod.run_reads(idx, workload["reads"], params_raw,
                                        kernel=cfg.kernel)
        idx, ins_ns, ins_m = mod.run_inserts(idx, workload["inserts"],
                                             params_raw)
    n_ops = workload["reads"].shape[0] + workload["inserts"].shape[0]
    runtime = (read_ns + ins_ns) / n_ops  # avg ns per operation (paper metric)
    mem = mod.memory_bytes(idx, params_raw) if cfg.index_type == "alex" \
        else mod.memory_bytes(idx)
    c_m = (mem > cfg.mem_budget).astype(jnp.float32)
    c_r = ((read_ns + ins_ns) > cfg.runtime_budget).astype(jnp.float32)
    return runtime, (idx, read_m, ins_m), {"c_m": c_m, "c_r": c_r,
                                           "memory_bytes": mem}


def reset(cfg: EnvConfig, data_keys, workload, wr_ratio,
          default_raw: dict | None = None):
    """Initial env state: evaluate the DEFAULT parameters to set R_0."""
    mod = _backend(cfg.index_type)
    default_raw = default_raw or {
        k: jnp.float32(v) for k, v in mod.DEFAULTS.items()}
    r0, (idx, read_m, ins_m), viol = evaluate_params(
        cfg, default_raw, data_keys, workload, wr_ratio)
    ws = workload_stats(data_keys, wr_ratio)
    obs = state_vector(idx, read_m, ins_m, r0, r0, r0, ws)
    env_state = {
        "data_keys": data_keys,
        "reads": workload["reads"],
        "inserts": workload["inserts"],
        "wr_ratio": jnp.asarray(wr_ratio, jnp.float32),
        "r0": r0, "r_prev": r0, "r_best": r0,
        "t": jnp.int32(0),
        "cum_cost": jnp.float32(0.0),
    }
    return env_state, obs


def step_core(cfg: EnvConfig, env_state: dict, action: jax.Array):
    """One tuning step (un-jitted pure core). action in [-1,1]^dim.

    This is the composable form: `step` below is its jitted entry point,
    `core/etmdp.py` inlines it into the fused episode step,
    `core/parallel.py` vmaps it over the meta-batch, and the serving path
    (`launch/tune_serve.py`) `lax.map`s it over a slot axis.
    """
    space = cfg.space
    params_raw = space.decode(action)
    workload = {"reads": env_state["reads"], "inserts": env_state["inserts"]}
    runtime, (idx, read_m, ins_m), viol = evaluate_params(
        cfg, params_raw, env_state["data_keys"], workload,
        env_state["wr_ratio"])
    r = rw.reward(runtime, env_state["r0"], env_state["r_prev"],
                  cfg.omega, cfg.kappa)
    ws = workload_stats(env_state["data_keys"], env_state["wr_ratio"])
    obs = state_vector(idx, read_m, ins_m, runtime, env_state["r_prev"],
                       env_state["r0"], ws)
    cost = viol["c_m"] + viol["c_r"]
    new_state = dict(env_state)
    new_state["r_prev"] = runtime
    new_state["r_best"] = jnp.minimum(env_state["r_best"], runtime)
    new_state["t"] = env_state["t"] + 1
    new_state["cum_cost"] = env_state["cum_cost"] + cost
    done = new_state["t"] >= cfg.episode_len
    info = {"runtime_ns": runtime, "cost": cost, **viol}
    return new_state, obs, r, done, info


step = jax.jit(step_core, static_argnames=("cfg",))


def obs_dim() -> int:
    return STATE_DIM
