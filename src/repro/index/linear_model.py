"""Per-segment linear models (key -> position) with error bounds.

ALEX/CARMI leaves predict positions with linear models; the probe cost is
O(log |error|) via exponential+binary search inside the error bound.  Both
exact least-squares fits and the cheap 2-point "approximate" fits that ALEX's
`approx_model_computation` flag selects are provided, fully vectorized over
segments (static shapes, masked).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


def fit_segments_exact(keys: jax.Array, seg_id: jax.Array, n_segs: int):
    """Least-squares fit per segment of (key -> local rank).

    keys [n] sorted; seg_id [n] in [0, n_segs); returns (slope, intercept,
    count) each [n_segs].  Positions are local ranks within the segment.
    """
    n = keys.shape[0]
    ones = jnp.ones_like(keys)
    cnt = jnp.zeros(n_segs).at[seg_id].add(ones)
    # local rank = global rank - segment start rank
    starts = jnp.cumsum(cnt) - cnt                      # [n_segs]
    pos = jnp.arange(n, dtype=keys.dtype) - starts[seg_id]

    sx = jnp.zeros(n_segs).at[seg_id].add(keys)
    sy = jnp.zeros(n_segs).at[seg_id].add(pos)
    sxx = jnp.zeros(n_segs).at[seg_id].add(keys * keys)
    sxy = jnp.zeros(n_segs).at[seg_id].add(keys * pos)
    c = jnp.maximum(cnt, 1.0)
    var = sxx - sx * sx / c
    cov = sxy - sx * sy / c
    slope = jnp.where(var > 1e-18, cov / jnp.maximum(var, 1e-18), 0.0)
    intercept = (sy - slope * sx) / c
    return slope, intercept, cnt


def fit_segments_approx(keys: jax.Array, seg_id: jax.Array, n_segs: int):
    """2-point (min/max) fit per segment — ALEX's approximate model path."""
    big = jnp.inf
    kmin = jnp.full((n_segs,), big).at[seg_id].min(keys)
    kmax = jnp.full((n_segs,), -big).at[seg_id].max(keys)
    cnt = jnp.zeros(n_segs).at[seg_id].add(jnp.ones_like(keys))
    rng = jnp.maximum(kmax - kmin, 1e-18)
    slope = jnp.where(cnt > 1, (cnt - 1) / rng, 0.0)
    intercept = -slope * jnp.where(jnp.isfinite(kmin), kmin, 0.0)
    return slope, intercept, cnt


def predict(slope, intercept, seg_of_q, q):
    """Predicted local rank for queries q given their segment."""
    return slope[seg_of_q] * q + intercept[seg_of_q]


def segment_errors(keys, seg_id, n_segs, slope, intercept):
    """Max |prediction - actual local rank| per segment (the probe bound)."""
    n = keys.shape[0]
    cnt = jnp.zeros(n_segs).at[seg_id].add(jnp.ones_like(keys))
    starts = jnp.cumsum(cnt) - cnt
    pos = jnp.arange(n, dtype=keys.dtype) - starts[seg_id]
    pred = slope[seg_id] * keys + intercept[seg_id]
    err = jnp.abs(pred - pos)
    return jnp.zeros(n_segs).at[seg_id].max(err)
