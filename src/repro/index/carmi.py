"""CARMI-family cache-aware RMI simulator (pure JAX).

CARMI (Zhang & Gao, 2021) is an RMI variant whose construction optimizes a
hybrid space-time cost with cache-awareness: nodes are sized in cache lines,
and a lambda parameter trades memory (gaps, wider fanout) against lookup
time.  13 tunable parameters (10 continuous, 2 integer, 1 hybrid
continuous/discrete) per Table 2 of the paper.

Costs are cache-line touches x line latency + in-line comparisons, which is
what distinguishes CARMI's landscape from ALEX's probe-dominated one; the
paper reports much larger tuning headroom on CARMI (>90% runtime reduction,
Fig 6) which this cost structure reproduces: bad (fanout, leaf-size,
prefetch) choices multiply DRAM line fetches.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.index import cost as C
from repro.index import linear_model as lm
from repro.kernels.index_probe.ops import predecessor_positions

MAX_LEAVES = 1024

PARAM_SPACE = [
    ("alpha_visit", "cont", (0.1, 4.0)),      # traversal cost weight
    ("alpha_scan", "cont", (0.1, 4.0)),       # in-leaf scan cost weight
    ("prefetch_aggr", "cont", (0.0, 1.0)),    # prefetch aggressiveness
    ("leaf_density", "cont", (0.5, 0.95)),
    ("split_ratio", "cont", (0.3, 0.7)),
    ("w_read", "cont", (0.0, 2.0)),           # read-optimized construction
    ("w_write", "cont", (0.0, 2.0)),          # write-optimized construction
    ("ood_tolerance", "cont", (0.0, 1.0)),
    ("rebuild_threshold", "cont", (0.05, 1.0)),
    ("root_lr_scale", "cont", (0.25, 4.0)),   # root model granularity
    ("leaf_lines_log2", "int", (1, 7)),       # cache lines per leaf
    ("root_fanout_log2", "int", (4, 10)),
    ("lambda_spacetime", "hybrid", (0.0, 1.0)),  # <0.05 snaps to time-only
]

DEFAULTS = {
    "alpha_visit": 1.0, "alpha_scan": 1.0, "prefetch_aggr": 0.0,
    "leaf_density": 0.75, "split_ratio": 0.5, "w_read": 1.0, "w_write": 1.0,
    "ood_tolerance": 0.2, "rebuild_threshold": 0.5, "root_lr_scale": 1.0,
    "leaf_lines_log2": 3, "root_fanout_log2": 8, "lambda_spacetime": 0.5,
}


def build(keys: jax.Array, p: dict):
    n = keys.shape[0]
    nf = jnp.asarray(n, jnp.float32)
    lam = p["lambda_spacetime"]
    time_only = lam < 0.05  # discrete snap: pure-time construction mode
    density = jnp.where(time_only, 0.5,
                        jnp.clip(p["leaf_density"] + 0.2 * lam, 0.5, 0.98))

    keys_per_leaf = (2.0 ** p["leaf_lines_log2"]) * C.KEYS_PER_LINE * density
    fanout = jnp.clip(2.0 ** p["root_fanout_log2"] * p["root_lr_scale"],
                      2.0, MAX_LEAVES)
    n_leaves = jnp.clip(jnp.ceil(nf / jnp.maximum(keys_per_leaf, 4.0)),
                        1.0, fanout)
    ranks = jnp.arange(n, dtype=jnp.float32)
    seg = jnp.minimum(ranks * n_leaves / nf, n_leaves - 1).astype(jnp.int32)

    slope, intercept, cnt = lm.fit_segments_exact(keys, seg, MAX_LEAVES)
    err = lm.segment_errors(keys, seg, MAX_LEAVES, slope, intercept)

    rs, ri, _ = lm.fit_segments_exact(keys, jnp.zeros_like(seg), 1)
    root_slope = rs[0] * n_leaves / nf
    root_icpt = ri[0] * n_leaves / nf

    slots = jnp.where(cnt > 0, cnt / jnp.maximum(density, 0.05), 0.0)
    build_cost = nf * C.FIT_PER_KEY_NS * (1.0 + lam) \
        + jnp.sum(slots) * C.SLOT_INIT_NS
    return {
        "keys": keys, "seg_of_key": seg, "n_leaves": n_leaves,
        "slope": slope, "intercept": intercept, "cnt": cnt, "slots": slots,
        "err": err, "root_slope": root_slope, "root_icpt": root_icpt,
        "kmin": keys[0], "kmax": keys[-1],
        "ood_buffer": jnp.float32(0.0),
        "counters": {"n_splits": jnp.float32(0.0),
                     "n_retrains": jnp.float32(0.0),
                     "build_cost_ns": build_cost,
                     "n_expands": jnp.float32(0.0),
                     "mega_leaf": jnp.float32(0.0)},
    }


def _lines_touched(idx, q, p, kernel=None):
    """Cache lines touched per lookup + the search distance metric.
    `kernel` gates the predecessor probe (see `alex.run_reads`)."""
    pred_leaf = jnp.clip(idx["root_slope"] * q + idx["root_icpt"],
                         0.0, idx["n_leaves"] - 1.0)
    pos = predecessor_positions(idx["keys"], q, kernel=kernel)
    leaf = idx["seg_of_key"][pos]
    root_err = jnp.abs(pred_leaf - leaf.astype(jnp.float32))
    root_lines = 1.0 + jnp.log2(1.0 + root_err)   # inner-node line hops

    starts = jnp.cumsum(idx["cnt"]) - idx["cnt"]
    local = pos.astype(jnp.float32) - starts[leaf]
    pred_local = jnp.clip(idx["slope"][leaf] * q + idx["intercept"][leaf],
                          0.0, jnp.maximum(idx["cnt"][leaf], 1.0))
    dist = jnp.abs(pred_local - local)
    leaf_lines = 1.0 + dist / C.KEYS_PER_LINE
    # prefetch hides leaf line latency when prediction error is small
    hit = jnp.exp(-dist / (C.KEYS_PER_LINE * 2.0))
    eff_line_ns = (p["prefetch_aggr"] * hit * C.CACHE_LINE_PREFETCHED_NS
                   + (1.0 - p["prefetch_aggr"] * hit) * C.CACHE_LINE_NS)
    # aggressive prefetch on misses wastes bandwidth
    waste = p["prefetch_aggr"] * (1.0 - hit) * C.CACHE_LINE_NS * 0.5
    ns = (p["alpha_visit"] * root_lines * C.CACHE_LINE_NS
          + p["alpha_scan"] * leaf_lines * eff_line_ns + waste
          + dist * C.PROBE_STEP_NS * 0.25)
    return ns, dist, root_err, leaf


def run_reads(idx, reads, p, kernel=None):
    ns, dist, root_err, _ = _lines_touched(idx, reads, p, kernel=kernel)
    per_q = C.QUERY_BASE_NS + ns / jnp.maximum(p["w_read"], 0.1) \
        + idx["ood_buffer"] * C.BUFFER_CMP_NS * 0.25
    total = jnp.sum(per_q)
    return total, {
        "avg_search_dist": jnp.mean(dist),
        "p99_search_dist": jnp.percentile(dist, 99),
        "avg_root_err": jnp.mean(root_err),
        "read_ns_avg": jnp.mean(per_q),
    }


def run_inserts(idx, inserts, p):
    in_domain = inserts <= idx["kmax"]
    n_ood = jnp.sum(~in_domain).astype(jnp.float32)
    q_in = jnp.where(in_domain, inserts, idx["kmin"])
    ns, dist, _, leaf = _lines_touched(idx, q_in, p)
    w_in = in_domain.astype(jnp.float32)
    add = jnp.zeros(MAX_LEAVES).at[leaf].add(w_in)
    cnt1 = idx["cnt"] + add
    slots = jnp.maximum(idx["slots"], 1.0)
    occ = jnp.clip(cnt1 / slots, 0.0, 0.999)
    shift_lines = (occ / (1.0 - occ)) / C.KEYS_PER_LINE

    full = (occ > 0.95) & (idx["cnt"] > 0)
    split_ns = jnp.where(
        full, cnt1 * C.RETRAIN_PER_KEY_NS
        * (1.0 + jnp.abs(p["split_ratio"] - 0.5)), 0.0)
    new_slots = jnp.where(full, cnt1 / jnp.maximum(p["leaf_density"], 0.05),
                          slots)

    buf1 = idx["ood_buffer"] + n_ood
    limit = 64.0 * (1.0 + 63.0 * p["ood_tolerance"])
    retrain = buf1 > limit * p["rebuild_threshold"] * 4.0
    retrain_ns = jnp.where(
        retrain, (idx["keys"].shape[0] + buf1) * C.RETRAIN_PER_KEY_NS, 0.0)
    buf2 = jnp.where(retrain, 0.0, buf1)

    per_ins = (C.QUERY_BASE_NS + ns + shift_lines[leaf] * C.CACHE_LINE_NS) \
        / jnp.maximum(p["w_write"], 0.1)
    total = jnp.sum(per_ins * w_in) + jnp.sum(split_ns) + retrain_ns \
        + n_ood * (C.QUERY_BASE_NS + C.BUFFER_CMP_NS * buf1 * 0.25)

    counters = dict(idx["counters"])
    counters["n_splits"] = counters["n_splits"] + jnp.sum(full)
    counters["n_retrains"] = counters["n_retrains"] + retrain.astype(jnp.float32)
    idx2 = dict(idx)
    idx2["cnt"] = cnt1
    idx2["slots"] = jnp.where(idx["cnt"] > 0, new_slots, slots)
    idx2["ood_buffer"] = buf2
    idx2["counters"] = counters
    metrics = {
        "insert_ns_avg": total / jnp.maximum(inserts.shape[0], 1),
        "avg_displacement": jnp.mean(shift_lines),
        "ood_frac": n_ood / jnp.maximum(inserts.shape[0], 1),
        "buffer_fill": buf2,
        "retrained": retrain.astype(jnp.float32),
    }
    return idx2, total, metrics


def memory_bytes(idx) -> jax.Array:
    lam_gap = jnp.sum(idx["slots"]) - jnp.sum(idx["cnt"])
    return (jnp.sum(idx["slots"]) * 16.0 + idx["n_leaves"] * 64.0
            + idx["ood_buffer"] * 16.0 + lam_gap * 2.0)
