"""Universal state features (paper §4.1): structural + operational metrics
shared across index types, so one agent architecture tunes both ALEX and
CARMI.  26-dim float32 vector, roughly normalized to O(1)."""
from __future__ import annotations

import jax
import jax.numpy as jnp

STATE_DIM = 26


def _log1p(x):
    return jnp.log1p(jnp.maximum(x, 0.0))


def state_vector(idx: dict, read_m: dict, ins_m: dict, runtime_ns,
                 r_prev_ns, r0_ns, workload_stats: dict) -> jax.Array:
    """Assemble the state. All inputs are scalars/metrics from one step."""
    cnt = idx["cnt"]
    slots = jnp.maximum(idx["slots"], 1.0)
    active = cnt > 0
    occ = jnp.where(active, cnt / slots, 0.0)
    n_active = jnp.sum(active).astype(jnp.float32)
    c = idx["counters"]

    feats = jnp.stack([
        # ---- structural ----
        _log1p(n_active) / 7.0,
        _log1p(jnp.sum(slots)) / 16.0,                   # memory footprint
        jnp.sum(occ) / jnp.maximum(n_active, 1.0),       # avg occupancy
        jnp.max(occ),                                    # max occupancy
        _log1p(jnp.max(cnt)) / 14.0,                     # biggest node
        _log1p(jnp.max(idx["err"])) / 10.0,              # worst model error
        _log1p(jnp.sum(idx["err"] * active)
               / jnp.maximum(n_active, 1.0)) / 8.0,      # avg model error
        _log1p(idx["ood_buffer"]) / 12.0,
        _log1p(c["n_expands"]) / 8.0,
        _log1p(c["n_splits"]) / 8.0,
        _log1p(c["n_retrains"]) / 5.0,
        _log1p(c["mega_leaf"]) / 8.0,
        # ---- operational ----
        _log1p(read_m["avg_search_dist"]) / 8.0,
        _log1p(read_m["p99_search_dist"]) / 10.0,
        _log1p(read_m["avg_root_err"]) / 6.0,
        _log1p(read_m["read_ns_avg"]) / 10.0,
        _log1p(ins_m["insert_ns_avg"]) / 10.0,
        _log1p(ins_m["avg_displacement"]) / 6.0,
        ins_m["ood_frac"],
        ins_m["retrained"],
        # ---- runtime trajectory ----
        _log1p(runtime_ns * 1e-6) / 10.0,
        _log1p(r_prev_ns * 1e-6) / 10.0,
        _log1p(r0_ns * 1e-6) / 10.0,
        # ---- workload ----
        workload_stats["wr_ratio"] / 4.0,
        workload_stats["key_mean"],
        workload_stats["key_std"],
    ]).astype(jnp.float32)
    return feats


def workload_stats(data_keys: jax.Array, wr_ratio) -> dict:
    return {
        "wr_ratio": jnp.asarray(wr_ratio, jnp.float32),
        "key_mean": jnp.mean(data_keys).astype(jnp.float32),
        "key_std": jnp.std(data_keys).astype(jnp.float32),
    }
