"""Checkpoint manager: rotation, cadence, restart-from-latest.

The fault-tolerance contract (tested in tests/test_fault_tolerance.py):
a training driver constructed with the same directory resumes from the
latest committed checkpoint -- including the data-pipeline cursor -- after
any crash, and interrupted writes (.tmp dirs) are never visible."""
from __future__ import annotations

import dataclasses
import os
import shutil

from repro.checkpoint import ckpt


@dataclasses.dataclass
class CheckpointManager:
    directory: str
    save_every: int = 100
    keep_last: int = 3

    def maybe_save(self, step: int, tree, extra: dict | None = None) -> bool:
        if step % self.save_every != 0:
            return False
        ckpt.save(self.directory, step, tree, extra)
        self._rotate()
        return True

    def save(self, step: int, tree, extra: dict | None = None) -> str:
        path = ckpt.save(self.directory, step, tree, extra)
        self._rotate()
        return path

    def _rotate(self):
        steps = ckpt.available_steps(self.directory)
        for old in steps[:-self.keep_last]:
            shutil.rmtree(os.path.join(self.directory, f"step_{old}"))
        # clear any orphaned tmp dirs from crashed writers
        for name in os.listdir(self.directory):
            if name.endswith(".tmp"):
                shutil.rmtree(os.path.join(self.directory, name),
                              ignore_errors=True)

    def restore_latest(self, like_tree, shardings=None):
        """Returns (tree, manifest) or (None, None) when no checkpoint."""
        step = ckpt.latest(self.directory)
        if step is None:
            return None, None
        return ckpt.restore(self.directory, step, like_tree, shardings)
