"""Sharded checkpoint save/restore with manifest + atomic commit.

Layout per checkpoint:
    <dir>/step_<N>.tmp/          (written first)
        manifest.json            leaf paths, shapes, dtypes, logical axes,
                                 step, mesh shape, pipeline state
        arrays.npz               one entry per pytree leaf (addressable data)
    <dir>/step_<N>/              (atomic rename on completion)

On a real multi-host pod each host writes only its addressable shards; in
this single-process container the full arrays are written.  Restore is
mesh-shape-agnostic: arrays are re-sharded at load time by the caller's
shardings (runtime/elastic.py builds on this for elastic re-scaling).
"""
from __future__ import annotations

import json
import os
import shutil

import jax
import numpy as np


def _flatten_with_paths(tree):
    import jax.tree_util as jtu
    flat, _ = jtu.tree_flatten_with_path(tree)
    out = []
    for path, leaf in flat:
        key = "/".join(str(getattr(p, "key", getattr(p, "idx", p)))
                       for p in path)
        out.append((key, leaf))
    return out


def save(directory: str, step: int, tree, extra: dict | None = None) -> str:
    """Write checkpoint atomically. Returns the final path."""
    os.makedirs(directory, exist_ok=True)
    tmp = os.path.join(directory, f"step_{step}.tmp")
    final = os.path.join(directory, f"step_{step}")
    if os.path.exists(tmp):
        shutil.rmtree(tmp)
    os.makedirs(tmp)

    arrays, manifest_leaves = {}, {}
    for key, leaf in _flatten_with_paths(tree):
        arr = np.asarray(jax.device_get(leaf))
        arrays[key] = arr
        manifest_leaves[key] = {"shape": list(arr.shape),
                                "dtype": str(arr.dtype)}
    np.savez(os.path.join(tmp, "arrays.npz"), **arrays)
    manifest = {"step": step, "leaves": manifest_leaves,
                "extra": extra or {}}
    with open(os.path.join(tmp, "manifest.json"), "w") as f:
        json.dump(manifest, f)
    if os.path.exists(final):
        shutil.rmtree(final)
    os.rename(tmp, final)  # atomic commit
    return final


def available_steps(directory: str) -> list[int]:
    if not os.path.isdir(directory):
        return []
    steps = []
    for name in os.listdir(directory):
        if name.startswith("step_") and not name.endswith(".tmp"):
            try:
                steps.append(int(name.split("_")[1]))
            except ValueError:
                pass
    return sorted(steps)


def restore(directory: str, step: int, like_tree, shardings=None):
    """Load a checkpoint into the structure of `like_tree`.

    `shardings` (same pytree structure) re-shards leaves on load -- this is
    what makes restore mesh-shape-agnostic."""
    path = os.path.join(directory, f"step_{step}")
    with open(os.path.join(path, "manifest.json")) as f:
        manifest = json.load(f)
    data = np.load(os.path.join(path, "arrays.npz"))

    keys = [k for k, _ in _flatten_with_paths(like_tree)]
    leaves = []
    for key in keys:
        arr = data[key]
        want = manifest["leaves"][key]["dtype"]
        if str(arr.dtype) != want:
            # npz stores ml_dtypes (bfloat16, fp8) as raw void; re-view
            import ml_dtypes  # ships with jax
            arr = arr.view(np.dtype(getattr(ml_dtypes, want, want)))
        leaves.append(arr)
    import jax.tree_util as jtu
    treedef = jtu.tree_structure(like_tree)
    tree = jtu.tree_unflatten(treedef, leaves)
    if shardings is not None:
        tree = jax.tree.map(
            lambda a, s: jax.device_put(a, s), tree, shardings)
    else:
        tree = jax.tree.map(jax.numpy.asarray, tree)
    return tree, manifest


def latest(directory: str):
    steps = available_steps(directory)
    return steps[-1] if steps else None
