"""AdamW from scratch (no optax): bf16 params, f32 moments, global-norm
clipping, decoupled weight decay, schedule support."""
from __future__ import annotations

import dataclasses
from typing import Callable

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class AdamWConfig:
    lr: float | Callable = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    grad_clip_norm: float = 1.0


def init_opt_state(params):
    zeros = lambda p: jnp.zeros(p.shape, jnp.float32)
    return {
        "m": jax.tree.map(zeros, params),
        "v": jax.tree.map(zeros, params),
        "step": jnp.zeros((), jnp.int32),
    }


def opt_state_specs(param_specs):
    """ParamSpec tree for the optimizer state (for sharded init/dry-run)."""
    from repro.models.module import ParamSpec, is_spec, zeros_init
    f32 = lambda s: ParamSpec(s.shape, jnp.float32, s.axes, zeros_init())
    return {
        "m": jax.tree.map(f32, param_specs, is_leaf=is_spec),
        "v": jax.tree.map(f32, param_specs, is_leaf=is_spec),
        "step": ParamSpec((), jnp.int32, (), zeros_init()),
    }


def global_norm(tree) -> jax.Array:
    leaves = jax.tree.leaves(tree)
    return jnp.sqrt(sum(jnp.sum(jnp.square(x.astype(jnp.float32)))
                        for x in leaves))


def adamw_update(params, grads, state, cfg: AdamWConfig):
    """Returns (new_params, new_state, metrics)."""
    step = state["step"] + 1
    lr = cfg.lr(step) if callable(cfg.lr) else cfg.lr

    gnorm = global_norm(grads)
    scale = jnp.minimum(1.0, cfg.grad_clip_norm / jnp.maximum(gnorm, 1e-9))

    def upd(p, g, m, v):
        g = g.astype(jnp.float32) * scale
        m = cfg.b1 * m + (1 - cfg.b1) * g
        v = cfg.b2 * v + (1 - cfg.b2) * jnp.square(g)
        mhat = m / (1 - cfg.b1 ** step.astype(jnp.float32))
        vhat = v / (1 - cfg.b2 ** step.astype(jnp.float32))
        delta = mhat / (jnp.sqrt(vhat) + cfg.eps)
        if p.ndim >= 2:  # decoupled decay on matrices only
            delta = delta + cfg.weight_decay * p.astype(jnp.float32)
        new_p = (p.astype(jnp.float32) - lr * delta).astype(p.dtype)
        return new_p, m, v

    flat_p, treedef = jax.tree.flatten(params)
    flat_g = jax.tree.leaves(grads)
    flat_m = jax.tree.leaves(state["m"])
    flat_v = jax.tree.leaves(state["v"])
    out = [upd(p, g, m, v) for p, g, m, v in zip(flat_p, flat_g, flat_m, flat_v)]
    new_params = jax.tree.unflatten(treedef, [o[0] for o in out])
    new_state = {
        "m": jax.tree.unflatten(treedef, [o[1] for o in out]),
        "v": jax.tree.unflatten(treedef, [o[2] for o in out]),
        "step": step,
    }
    return new_params, new_state, {"grad_norm": gnorm, "lr": jnp.float32(lr)}
