"""int8 error-feedback gradient compression for the cross-pod all-reduce.

At 1000+ node scale the *cross-pod* data-parallel all-reduce rides the
slowest links, so that is where compression pays: gradients are computed
pod-locally (shard_map over the `pod` axis, `data`/`model` left to GSPMD
via auto axes), quantized to int8 with per-leaf max-abs scaling and an
error-feedback residual (Karimireddy et al., 2019 -- unbiased over time),
then summed with an explicit int16 psum (lossless for <=258 pods since
max |sum| = 127*n_pods): 2x wire bytes vs f32 master-grad reduction on the
collective roofline term, with int8 storage at rest.

Used by launch/train.py --grad-compress; tested in
tests/test_grad_compress.py (including the EF-accumulator property:
compressed-SGD trajectories track uncompressed ones).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.runtime.mesh_utils import shard_map_compat


def quantize(x: jax.Array):
    """f32/bf16 -> (int8, scale). Symmetric per-tensor max-abs scaling."""
    x32 = x.astype(jnp.float32)
    scale = jnp.maximum(jnp.max(jnp.abs(x32)), 1e-12) / 127.0
    q = jnp.clip(jnp.round(x32 / scale), -127, 127).astype(jnp.int8)
    return q, scale


def dequantize(q: jax.Array, scale: jax.Array) -> jax.Array:
    return q.astype(jnp.float32) * scale


def compress_residual(grad: jax.Array, error: jax.Array):
    """Error-feedback step: compensate, quantize, return residual."""
    comp = grad.astype(jnp.float32) + error
    q, scale = quantize(comp)
    new_error = comp - dequantize(q, scale)
    return q, scale, new_error


def init_error_state(grads):
    return jax.tree.map(lambda g: jnp.zeros(g.shape, jnp.float32), grads)


def compressed_psum_leaf(g_shard, e_shard, axis_name: str, n: int):
    """INSIDE shard_map: int8 EF compression + psum over `axis_name`.

    A shared (pmax) scale makes the int8 sum lossless across shards."""
    q, scale, new_e = compress_residual(g_shard, e_shard)
    smax = jax.lax.pmax(scale, axis_name)
    qg = jnp.clip(jnp.round(dequantize(q, scale) / jnp.maximum(smax, 1e-12)),
                  -127, 127).astype(jnp.int8)
    # int16 accumulate: |sum| <= 127 * n_pods < 2^15 for n <= 258
    acc = jax.lax.psum(qg.astype(jnp.int16), axis_name)
    mean = acc.astype(jnp.float32) * smax / n
    return mean, new_e


def make_pod_grad_fn(loss_fn, mesh, params_tree, batch_tree,
                     axis_name: str = "pod"):
    """Returns grad_fn(params, err_state, batch) -> (loss, grads, err').

    Gradients are computed pod-locally under a partial-manual shard_map
    (`axis_names={pod}`; `data`/`model` stay under GSPMD) and combined with
    the compressed int8 all-reduce.  Falls back to plain value_and_grad on
    meshes without a `pod` axis.
    """
    if axis_name not in mesh.shape:
        def plain(params, err_state, batch):
            loss, grads = jax.value_and_grad(loss_fn)(params, **batch)
            grads = jax.tree.map(lambda g: g.astype(jnp.float32), grads)
            return loss, grads, err_state
        return plain

    n_pods = mesh.shape[axis_name]
    # params / error state are replicated across pods -> P(); batch leaves
    # are sharded on dim 0 over the pod axis.
    p_specs = jax.tree.map(lambda _: P(), params_tree)
    e_specs = jax.tree.map(lambda _: P(), params_tree)
    b_specs = jax.tree.map(
        lambda leaf: P(*((axis_name,) + (None,) * (leaf.ndim - 1))),
        batch_tree)

    def body(params, err_state, batch):
        loss, grads = jax.value_and_grad(loss_fn)(params, **batch)
        flat_g, treedef = jax.tree.flatten(grads)
        flat_e = jax.tree.leaves(err_state)
        out = [compressed_psum_leaf(g, e, axis_name, n_pods)
               for g, e in zip(flat_g, flat_e)]
        grads = jax.tree.unflatten(treedef, [o[0] for o in out])
        new_err = jax.tree.unflatten(treedef, [o[1] for o in out])
        loss = jax.lax.pmean(loss, axis_name)
        return loss, grads, new_err

    return shard_map_compat(body, mesh,
                            in_specs=(p_specs, e_specs, b_specs),
                            out_specs=(P(), p_specs, e_specs),
                            axis_names={axis_name})
