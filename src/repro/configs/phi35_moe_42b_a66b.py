"""Phi-3.5-MoE 42B-A6.6B: 16 experts top-2.
[hf:microsoft/Phi-3.5-MoE-instruct; hf]"""
from repro.configs import ArchConfig

CONFIG = ArchConfig(
    name="phi35_moe_42b_a66b", family="moe",
    n_layers=32, d_model=4096, n_heads=32, n_kv_heads=8,
    d_ff=6400, vocab_size=32064, head_dim=128,
    n_experts=16, experts_per_token=2, moe_d_ff=6400, moe_period=1,
    rope_theta=10000.0, tie_embeddings=False,
    source="hf:microsoft/Phi-3.5-MoE-instruct",
)
