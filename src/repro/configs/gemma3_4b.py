"""Gemma-3 4B: 5:1 local:global attention, sliding window 1024,
256k vocab, 128k context. [hf:google/gemma-3-1b-pt; unverified]"""
from repro.configs import ArchConfig

CONFIG = ArchConfig(
    name="gemma3_4b", family="dense",
    n_layers=34, d_model=2560, n_heads=8, n_kv_heads=4,
    d_ff=10240, vocab_size=262144, head_dim=256,
    sliding_window=1024, global_period=6, local_rope_theta=10000.0,
    rope_theta=1000000.0, tie_embeddings=True,
    subquadratic=True,  # 5/6 of layers cache only the 1024-window
    source="hf:google/gemma-3-1b-pt",
)
