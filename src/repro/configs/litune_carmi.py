"""The paper's own tuning target: CARMI-family learned index (Table 2)."""
from repro.core.litune import LITuneConfig

CONFIG = LITuneConfig(index_type="carmi")
PARAM_DIMS = 13  # 10 continuous, 2 integer, 1 hybrid continuous/discrete
