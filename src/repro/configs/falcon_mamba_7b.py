"""Falcon-Mamba-7B: attention-free mamba1, ssm_state=16.
[arXiv:2410.05355; unverified]"""
from repro.configs import ArchConfig

CONFIG = ArchConfig(
    name="falcon_mamba_7b", family="ssm",
    n_layers=64, d_model=4096, n_heads=0, n_kv_heads=0,
    d_ff=0, vocab_size=65024,
    attn_period=-1, ssm_state=16, ssm_conv=4, ssm_expand=2,
    use_rope=False, tie_embeddings=False,
    subquadratic=True,
    source="arXiv:2410.05355",
)
