"""Whisper-small: 12L enc + 12L dec, conv frontend stubbed.
[arXiv:2212.04356; unverified]"""
from repro.configs import ArchConfig

CONFIG = ArchConfig(
    name="whisper_small", family="audio",
    n_layers=12, d_model=768, n_heads=12, n_kv_heads=12,
    d_ff=3072, vocab_size=51865, head_dim=64,
    enc_dec=True, n_enc_layers=12, enc_seq=1500,
    frontend="audio_stub",
    norm_type="layernorm", mlp_variant="gelu", use_rope=False,
    tie_embeddings=True,
    source="arXiv:2212.04356",
)
