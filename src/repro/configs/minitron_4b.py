"""Minitron-4B (pruned Nemotron). [arXiv:2407.14679; hf]"""
from repro.configs import ArchConfig

CONFIG = ArchConfig(
    name="minitron_4b", family="dense",
    n_layers=32, d_model=3072, n_heads=24, n_kv_heads=8,
    d_ff=9216, vocab_size=256000, head_dim=128,
    rope_theta=10000.0, tie_embeddings=False,
    source="arXiv:2407.14679",
)
