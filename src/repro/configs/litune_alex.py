"""The paper's own tuning target: ALEX-family learned index (Table 2).

Not an LM architecture: this config selects the learned-index environment
for the LITune launchers (`repro.launch.tune --index alex`).
"""
from repro.core.litune import LITuneConfig

CONFIG = LITuneConfig(index_type="alex")
PARAM_DIMS = 14  # 5 continuous, 3 boolean, 4 integer, 2 discrete-choice
