"""Architecture & shape registry.

Each assigned architecture lives in its own module exporting ``CONFIG``.
``get_config(name)`` returns the exact published configuration;
``smoke(cfg)`` returns a reduced same-family config for CPU smoke tests.
"""
from __future__ import annotations

import dataclasses
import importlib
import math


@dataclasses.dataclass(frozen=True)
class LayerKind:
    mixer: str = "attn"        # attn | attn_local | mamba
    mlp: str = "dense"         # dense | moe | none
    window: int = 0            # sliding window size (attn_local)
    rope_theta: float = 500000.0


@dataclasses.dataclass(frozen=True)
class ArchConfig:
    name: str
    family: str                # dense | moe | ssm | hybrid | audio | vlm
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab_size: int
    head_dim: int = 0          # 0 -> d_model // n_heads
    # --- MoE ---
    n_experts: int = 0
    experts_per_token: int = 0
    moe_d_ff: int = 0
    moe_period: int = 0        # 0 = no MoE; 1 = every layer; 2 = alternate
    moe_offset: int = 0
    capacity_factor: float = 1.25
    router_aux_coef: float = 0.01
    moe_impl: str = "gspmd"   # gspmd | shard_map (explicit all_to_all EP)
    kv_cache_dtype: str = "bf16"  # bf16 | int8 (quantized serving cache)
    # --- attention pattern ---
    sliding_window: int = 0
    global_period: int = 0     # gemma3: 6 -> layer i is global iff i%6==5
    local_rope_theta: float = 10000.0
    # --- ssm / hybrid ---
    attn_period: int = 0       # 0: all attn; -1: none (pure SSM); jamba: 8
    attn_offset: int = 0
    ssm_state: int = 16
    ssm_conv: int = 4
    ssm_expand: int = 2
    dt_rank: int = 0           # 0 -> ceil(d_model / 16)
    # --- encoder-decoder (whisper) ---
    enc_dec: bool = False
    n_enc_layers: int = 0
    enc_seq: int = 1500
    # --- modality frontend stub ---
    frontend: str = "none"     # none | vision_stub | audio_stub
    n_frontend_tokens: int = 0
    # --- numerics / misc ---
    use_rope: bool = True
    rope_theta: float = 500000.0
    norm_eps: float = 1e-5
    norm_type: str = "rmsnorm"  # rmsnorm | layernorm
    mlp_variant: str = "swiglu"  # swiglu | gelu
    tie_embeddings: bool = False
    subquadratic: bool = False  # eligible for long_500k
    source: str = ""

    # ------------------------------------------------------------------
    @property
    def resolved_head_dim(self) -> int:
        return self.head_dim or self.d_model // self.n_heads

    @property
    def resolved_dt_rank(self) -> int:
        return self.dt_rank or math.ceil(self.d_model / 16)

    @property
    def d_inner(self) -> int:
        return self.ssm_expand * self.d_model

    def layer_kind(self, i: int) -> LayerKind:
        if self.attn_period == -1:
            mixer = "mamba"
        elif self.attn_period > 0:
            mixer = "attn" if i % self.attn_period == self.attn_offset else "mamba"
        elif self.global_period > 0:
            mixer = ("attn" if i % self.global_period == self.global_period - 1
                     else "attn_local")
        else:
            mixer = "attn"
        if self.d_ff == 0 and mixer == "mamba":
            mlp = "none"
        elif self.moe_period > 0 and i % self.moe_period == self.moe_offset:
            mlp = "moe"
        else:
            mlp = "dense"
        window = self.sliding_window if mixer == "attn_local" else 0
        theta = self.local_rope_theta if mixer == "attn_local" else self.rope_theta
        return LayerKind(mixer=mixer, mlp=mlp, window=window, rope_theta=theta)

    def layer_kinds(self) -> list[LayerKind]:
        return [self.layer_kind(i) for i in range(self.n_layers)]

    def block_period(self) -> int:
        """Smallest period p with kinds[i] == kinds[i % p] (scan grouping)."""
        kinds = self.layer_kinds()
        for p in range(1, self.n_layers + 1):
            if all(kinds[i] == kinds[i % p] for i in range(self.n_layers)):
                return p
        return self.n_layers

    def uses_cache(self) -> bool:
        return True  # all assigned archs have a decoder


# ----------------------------------------------------------------------
@dataclasses.dataclass(frozen=True)
class ShapeConfig:
    name: str
    seq_len: int
    global_batch: int
    kind: str  # train | prefill | decode


SHAPES: dict[str, ShapeConfig] = {
    "train_4k": ShapeConfig("train_4k", 4096, 256, "train"),
    "prefill_32k": ShapeConfig("prefill_32k", 32768, 32, "prefill"),
    "decode_32k": ShapeConfig("decode_32k", 32768, 128, "decode"),
    "long_500k": ShapeConfig("long_500k", 524288, 1, "decode"),
}

ARCH_NAMES = [
    "internvl2_76b", "gemma3_4b", "deepseek_67b", "llama3_8b", "minitron_4b",
    "qwen3_moe_235b_a22b", "phi35_moe_42b_a66b", "falcon_mamba_7b",
    "whisper_small", "jamba_v01_52b",
]

# The paper's own tuning targets (learned-index environments).
TUNE_CONFIG_NAMES = ["litune_alex", "litune_carmi"]


def get_config(name: str) -> ArchConfig:
    name = name.replace("-", "_").replace(".", "")
    mod = importlib.import_module(f"repro.configs.{name}")
    return mod.CONFIG


def cell_is_runnable(cfg: ArchConfig, shape: ShapeConfig) -> tuple[bool, str]:
    """Whether an (arch x shape) cell runs, and why not when skipped."""
    if shape.name == "long_500k" and not cfg.subquadratic:
        return False, ("pure full-attention arch: 500k dense KV cache per "
                       "layer is not sub-quadratic; skipped per assignment")
    return True, ""


def smoke(cfg: ArchConfig) -> ArchConfig:
    """Reduced same-family config: small dims, few layers/experts."""
    period = cfg.block_period()
    n_layers = max(2 * period, period)  # >= 2 blocks when pattern allows
    if cfg.n_layers < n_layers:
        n_layers = cfg.n_layers
    heads = min(cfg.n_heads, 4)
    kv = min(cfg.n_kv_heads, max(1, heads // 2)) if cfg.n_kv_heads else 0
    return dataclasses.replace(
        cfg,
        name=cfg.name + "_smoke",
        n_layers=n_layers,
        d_model=64,
        n_heads=heads,
        n_kv_heads=kv,
        head_dim=16,
        d_ff=0 if cfg.d_ff == 0 else 128,
        vocab_size=256,
        n_experts=min(cfg.n_experts, 4),
        experts_per_token=min(cfg.experts_per_token, 2),
        moe_d_ff=0 if cfg.moe_d_ff == 0 else 64,
        sliding_window=min(cfg.sliding_window, 8) if cfg.sliding_window else 0,
        ssm_state=min(cfg.ssm_state, 8),
        dt_rank=8 if cfg.attn_period != 0 else 0,
        n_enc_layers=min(cfg.n_enc_layers, 2),
        enc_seq=16 if cfg.enc_dec else cfg.enc_seq,
        n_frontend_tokens=min(cfg.n_frontend_tokens, 4),
    )
