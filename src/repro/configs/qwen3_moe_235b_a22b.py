"""Qwen3-MoE 235B-A22B: 128 experts top-8, GQA kv=4.
[hf:Qwen/Qwen3-30B-A3B; hf]"""
from repro.configs import ArchConfig

CONFIG = ArchConfig(
    name="qwen3_moe_235b_a22b", family="moe",
    n_layers=94, d_model=4096, n_heads=64, n_kv_heads=4,
    d_ff=1536, vocab_size=151936, head_dim=128,
    n_experts=128, experts_per_token=8, moe_d_ff=1536, moe_period=1,
    rope_theta=1000000.0, tie_embeddings=False,
    source="hf:Qwen/Qwen3-30B-A3B",
)
