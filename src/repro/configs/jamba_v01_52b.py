"""Jamba-v0.1 52B: Mamba+attention 1:7 interleave, MoE 16e top-2
on alternate layers. [arXiv:2403.19887; hf]"""
from repro.configs import ArchConfig

CONFIG = ArchConfig(
    name="jamba_v01_52b", family="hybrid",
    n_layers=32, d_model=4096, n_heads=32, n_kv_heads=8,
    d_ff=14336, vocab_size=65536, head_dim=128,
    n_experts=16, experts_per_token=2, moe_d_ff=14336,
    moe_period=2, moe_offset=1,
    attn_period=8, attn_offset=4,  # 1 attn : 7 mamba per period-8 block
    ssm_state=16, ssm_conv=4, ssm_expand=2,
    use_rope=False,  # jamba uses no positional encoding
    tie_embeddings=False, subquadratic=True,
    source="arXiv:2403.19887",
)
