"""InternVL2-76B backbone (InternViT frontend stubbed).
[arXiv:2404.16821; unverified]"""
from repro.configs import ArchConfig

CONFIG = ArchConfig(
    name="internvl2_76b", family="vlm",
    n_layers=80, d_model=8192, n_heads=64, n_kv_heads=8,
    d_ff=28672, vocab_size=128256, head_dim=128,
    frontend="vision_stub", n_frontend_tokens=256,
    rope_theta=1000000.0, tie_embeddings=False,
    source="arXiv:2404.16821",
)
