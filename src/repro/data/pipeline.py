"""Deterministic, shard-aware, checkpointable data pipeline.

Design for 1000+ nodes (DESIGN.md §7):
  * batches are a pure function of (seed, step, shard) -- no host state to
    lose, so restart-after-failure resumes mid-epoch exactly;
  * straggler mitigation: because batch(step, shard) is recomputable
    anywhere, a backup host can re-issue any shard's batch without
    coordination (speculative re-execution);
  * elastic scaling: shards are derived from (n_shards, shard_id) at call
    time, so changing the data-parallel degree re-partitions the stream
    deterministically.

Synthetic token streams stand in for a tokenized corpus (no network in this
container); the interface matches what a file-backed loader would expose.
"""
from __future__ import annotations

import dataclasses
import jax.numpy as jnp
import numpy as np


@dataclasses.dataclass(frozen=True)
class PipelineConfig:
    vocab_size: int
    seq_len: int
    global_batch: int
    seed: int = 0
    n_shards: int = 1
    shard_id: int = 0
    # synthetic stream params (markov-ish so loss is learnable)
    n_patterns: int = 512
    pattern_len: int = 16

    @property
    def shard_batch(self) -> int:
        assert self.global_batch % self.n_shards == 0
        return self.global_batch // self.n_shards


def _fold(*ints) -> np.random.Generator:
    mask = (1 << 64) - 1
    seed = 0x9E3779B97F4A7C15
    for x in ints:
        seed = ((seed ^ (int(x) & mask)) * 0xBF58476D1CE4E5B9) & mask
    return np.random.default_rng(seed % (1 << 63))


def batch_at(cfg: PipelineConfig, step: int) -> dict:
    """The (tokens, labels) batch for `step` on this shard. Pure function."""
    rng = _fold(cfg.seed, step, cfg.shard_id, cfg.n_shards)
    b, s = cfg.shard_batch, cfg.seq_len
    # learnable structure: repeated patterns with noise
    pat_rng = _fold(cfg.seed, 0xABCDEF)
    patterns = pat_rng.integers(
        0, cfg.vocab_size, (cfg.n_patterns, cfg.pattern_len))
    n_pat = (s + 1 + cfg.pattern_len - 1) // cfg.pattern_len
    idx = rng.integers(0, cfg.n_patterns, (b, n_pat))
    stream = patterns[idx].reshape(b, -1)[:, :s + 1]
    noise_mask = rng.random((b, s + 1)) < 0.05
    noise = rng.integers(0, cfg.vocab_size, (b, s + 1))
    stream = np.where(noise_mask, noise, stream)
    return {
        "tokens": jnp.asarray(stream[:, :-1], jnp.int32),
        "labels": jnp.asarray(stream[:, 1:], jnp.int32),
    }


class DataPipeline:
    """Stateful wrapper (current step) with O(1) checkpoint state."""

    def __init__(self, cfg: PipelineConfig, start_step: int = 0):
        self.cfg = cfg
        self.step = start_step

    def __next__(self) -> dict:
        batch = batch_at(self.cfg, self.step)
        self.step += 1
        return batch

    def __iter__(self):
        return self

    def state_dict(self) -> dict:
        return {"step": self.step, "seed": self.cfg.seed,
                "n_shards": self.cfg.n_shards, "shard_id": self.cfg.shard_id}

    @classmethod
    def from_state(cls, cfg: PipelineConfig, state: dict) -> "DataPipeline":
        assert state["seed"] == cfg.seed, "seed mismatch on restore"
        return cls(cfg, start_step=state["step"])
