"""Pallas TPU kernel: batched learned-index probe (the paper's hot loop).

Hardware adaptation (DESIGN.md §2): instead of ALEX's pointer-chasing
exponential search (a scalar-CPU pattern), keys live in sorted VMEM tiles
and each grid step answers a *vector* of queries against one tile with a
branchless bisection: log2(tile) masked-compare steps on the VPU.  The
model-routing stage (query -> tile) happens outside as a capacity-grouped
dispatch, mirroring the MoE token dispatch.

Grid: (n_tiles,).  BlockSpec tiles: keys [tile_size] and the per-tile query
group [qcap] are VMEM-resident; tile_size/qcap are chosen so both fit VMEM
lanes (multiples of 128).
"""
from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _probe_kernel(keys_ref, q_ref, valid_ref, out_ref, *, tile: int):
    keys = keys_ref[0]                         # [tile] f32, sorted
    q = q_ref[0]                               # [qcap] f32
    valid = valid_ref[0]                       # [qcap] int32 (0/1)

    # branchless bisection: after log2(tile) steps, lo = #(keys <= q)
    lo = jnp.zeros(q.shape, jnp.int32)
    width = tile
    steps = int(math.log2(tile))
    for _ in range(steps):                     # unrolled: static trip count
        width //= 2
        mid = lo + width
        # keys[mid-1] <= q ? advance : stay   (mid in [1, tile])
        km = keys[jnp.clip(mid - 1, 0, tile - 1)]
        lo = jnp.where(km <= q, mid, lo)
    # one final correction step for width 1
    km = keys[jnp.clip(lo, 0, tile - 1)]
    lo = jnp.where(km <= q, lo + 1, lo)
    lo = jnp.minimum(lo, tile)
    out_ref[0, :] = jnp.where(valid > 0, lo, -1).astype(jnp.int32)


def probe_pallas(key_tiles: jax.Array, queries: jax.Array,
                 valid: jax.Array, interpret: bool = True) -> jax.Array:
    """key_tiles [n_tiles, tile]; queries/valid [n_tiles, qcap]."""
    n_tiles, tile = key_tiles.shape
    qcap = queries.shape[1]
    assert tile & (tile - 1) == 0, "tile must be a power of two"
    kern = functools.partial(_probe_kernel, tile=tile)
    return pl.pallas_call(
        kern,
        grid=(n_tiles,),
        in_specs=[
            pl.BlockSpec((1, tile), lambda i: (i, 0)),
            pl.BlockSpec((1, qcap), lambda i: (i, 0)),
            pl.BlockSpec((1, qcap), lambda i: (i, 0)),
        ],
        out_specs=pl.BlockSpec((1, qcap), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((n_tiles, qcap), jnp.int32),
        interpret=interpret,
    )(key_tiles, queries, valid.astype(jnp.int32))
