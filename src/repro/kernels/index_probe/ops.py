"""Jitted public API for the learned-index probe kernel, including the
model-routing dispatch (query -> tile grouping) that precedes the kernel.

`batched_lookup` is the end-to-end op: (sorted keys, queries) -> global
predecessor ranks, using a linear root model + capacity-grouped tile
dispatch + the Pallas in-VMEM bisection kernel.  Execution mode
(compiled / interpret / jnp ref) routes through `kernels/dispatch.py` —
``mode=None`` defers to the process-wide resolution, so CPU callers get
the bitwise jnp reference and accelerator callers the compiled kernel
without any per-callsite flags.

`predecessor_positions` is the env-facing wrapper the index simulators'
``run_reads`` hot paths call: predecessor *positions* (clipped rank-1)
with a drop-free capacity so Pallas modes are exact — numerically equal
to ``searchsorted(side="right") - 1`` on every input
(tests/test_kernels.py asserts the parity property-based).
"""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp

from repro.kernels import dispatch
from repro.kernels.index_probe.kernel import probe_pallas
from repro.kernels.index_probe.ref import probe_ref


@partial(jax.jit, static_argnames=("tile", "qcap", "mode"))
def batched_lookup(keys: jax.Array, queries: jax.Array, tile: int = 512,
                   qcap: int = 0, mode: str | None = None):
    """keys [n] sorted (n % tile == 0); queries [m].

    Returns (ranks [m] int32, dropped [m] bool).  `dropped` marks queries
    beyond a tile's query capacity (retried by the caller -- same contract
    as MoE capacity dispatch).  `mode` is a `kernels.dispatch` mode
    (None/"auto" -> the process default; resolution is process-cached, so
    the static jit key stays stable).
    """
    mode = dispatch.resolve(mode)
    n = keys.shape[0]
    m = queries.shape[0]
    assert n % tile == 0
    n_tiles = n // tile
    qcap = qcap or max(2 * m // n_tiles, 8)
    key_tiles = keys.reshape(n_tiles, tile)

    # root routing: tile of the predecessor via boundary keys
    boundaries = key_tiles[:, 0]
    tile_of = jnp.clip(
        jnp.searchsorted(boundaries, queries, side="right") - 1, 0,
        n_tiles - 1).astype(jnp.int32)

    # capacity-grouped dispatch (sort-free: scatter with per-tile cursor)
    order = jnp.argsort(tile_of)
    t_sorted = tile_of[order]
    starts = jnp.searchsorted(t_sorted, jnp.arange(n_tiles))
    pos_in_tile = jnp.arange(m) - starts[t_sorted]
    keep = pos_in_tile < qcap
    # overflow queries scatter to the out-of-bounds column `qcap` so
    # mode="drop" discards the write; clamping them to qcap-1 would clobber
    # the legitimate occupant of the last slot with a silently-wrong rank
    safe_pos = jnp.where(keep, pos_in_tile, qcap)

    q_grouped = jnp.full((n_tiles, qcap), -jnp.inf, queries.dtype)
    v_grouped = jnp.zeros((n_tiles, qcap), jnp.int32)
    q_grouped = q_grouped.at[t_sorted, safe_pos].set(
        queries[order], mode="drop")
    v_grouped = v_grouped.at[t_sorted, safe_pos].max(
        keep.astype(jnp.int32), mode="drop")

    if mode == "ref":
        pos = probe_ref(key_tiles.astype(jnp.float32),
                        q_grouped.astype(jnp.float32), v_grouped > 0)
    else:
        pos = probe_pallas(key_tiles.astype(jnp.float32),
                           q_grouped.astype(jnp.float32), v_grouped,
                           interpret=dispatch.interpret_flag(mode))

    # gather back to query order: global rank = tile_start + local rank
    # (dropped entries read a clamped slot; `keep` masks them to -1 below)
    local = pos[t_sorted, jnp.minimum(safe_pos, qcap - 1)]
    global_rank = t_sorted * tile + local
    ranks = jnp.zeros((m,), jnp.int32).at[order].set(
        jnp.where(keep, global_rank, -1))
    dropped = jnp.zeros((m,), bool).at[order].set(~keep)
    return ranks, dropped


def _auto_tile(n: int, cap: int = 512) -> int | None:
    """Largest power-of-two divisor of n, capped at `cap` — the key-tile
    size the kernel grids over.  None when n has no usable pow2 divisor
    (odd/tiny arrays fall back to the jnp reference)."""
    t = n & -n                                  # largest pow2 divisor
    t = min(t, cap)
    return t if t >= 8 else None


def predecessor_positions(keys: jax.Array, queries: jax.Array,
                          kernel=None) -> jax.Array:
    """Predecessor positions clip(#(keys <= q) - 1, 0, n-1) — the probe
    at the bottom of every `run_reads` hot path.

    `kernel` is a `dispatch.KernelConfig` (None -> defaults).  Pallas
    modes route through `batched_lookup` with a drop-free capacity
    (qcap=m: no query can overflow its tile group, so ranks are exact —
    no retry path in the env) and are numerically equal to the
    searchsorted reference; "ref" mode *is* the searchsorted reference.
    """
    n = keys.shape[0]
    kcfg = kernel if kernel is not None else dispatch.KernelConfig()
    mode = kcfg.resolved() if kcfg.probe_reads else "ref"
    tile = kcfg.probe_tile or _auto_tile(n)
    if mode == "ref" or tile is None or n % tile != 0:
        rank = jnp.searchsorted(keys, queries, side="right")
    else:
        rank, _ = batched_lookup(keys, queries, tile=tile,
                                 qcap=queries.shape[0], mode=mode)
    return jnp.clip(rank - 1, 0, n - 1)
