"""Jitted public API for the learned-index probe kernel, including the
model-routing dispatch (query -> tile grouping) that precedes the kernel.

`batched_lookup` is the end-to-end op: (sorted keys, queries) -> global
predecessor ranks, using a linear root model + capacity-grouped tile
dispatch + the Pallas in-VMEM bisection kernel.
"""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp

from repro.kernels.index_probe.kernel import probe_pallas
from repro.kernels.index_probe.ref import probe_ref


@partial(jax.jit, static_argnames=("tile", "qcap", "use_pallas", "interpret"))
def batched_lookup(keys: jax.Array, queries: jax.Array, tile: int = 512,
                   qcap: int = 0, use_pallas: bool = True,
                   interpret: bool = True):
    """keys [n] sorted (n % tile == 0); queries [m].

    Returns (ranks [m] int32, dropped [m] bool).  `dropped` marks queries
    beyond a tile's query capacity (retried by the caller -- same contract
    as MoE capacity dispatch).
    """
    n = keys.shape[0]
    m = queries.shape[0]
    assert n % tile == 0
    n_tiles = n // tile
    qcap = qcap or max(2 * m // n_tiles, 8)
    key_tiles = keys.reshape(n_tiles, tile)

    # root routing: tile of the predecessor via boundary keys
    boundaries = key_tiles[:, 0]
    tile_of = jnp.clip(
        jnp.searchsorted(boundaries, queries, side="right") - 1, 0,
        n_tiles - 1).astype(jnp.int32)

    # capacity-grouped dispatch (sort-free: scatter with per-tile cursor)
    order = jnp.argsort(tile_of)
    t_sorted = tile_of[order]
    starts = jnp.searchsorted(t_sorted, jnp.arange(n_tiles))
    pos_in_tile = jnp.arange(m) - starts[t_sorted]
    keep = pos_in_tile < qcap
    # overflow queries scatter to the out-of-bounds column `qcap` so
    # mode="drop" discards the write; clamping them to qcap-1 would clobber
    # the legitimate occupant of the last slot with a silently-wrong rank
    safe_pos = jnp.where(keep, pos_in_tile, qcap)

    q_grouped = jnp.full((n_tiles, qcap), -jnp.inf, queries.dtype)
    v_grouped = jnp.zeros((n_tiles, qcap), jnp.int32)
    q_grouped = q_grouped.at[t_sorted, safe_pos].set(
        queries[order], mode="drop")
    v_grouped = v_grouped.at[t_sorted, safe_pos].max(
        keep.astype(jnp.int32), mode="drop")

    if use_pallas:
        pos = probe_pallas(key_tiles.astype(jnp.float32),
                           q_grouped.astype(jnp.float32), v_grouped,
                           interpret=interpret)
    else:
        pos = probe_ref(key_tiles.astype(jnp.float32),
                        q_grouped.astype(jnp.float32), v_grouped > 0)

    # gather back to query order: global rank = tile_start + local rank
    # (dropped entries read a clamped slot; `keep` masks them to -1 below)
    local = pos[t_sorted, jnp.minimum(safe_pos, qcap - 1)]
    global_rank = t_sorted * tile + local
    ranks = jnp.zeros((m,), jnp.int32).at[order].set(
        jnp.where(keep, global_rank, -1))
    dropped = jnp.zeros((m,), bool).at[order].set(~keep)
    return ranks, dropped
