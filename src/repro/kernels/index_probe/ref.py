"""Pure-jnp oracle for the batched learned-index probe.

Semantics: keys are sorted and partitioned into tiles of `tile` keys.
Queries arrive pre-grouped per tile (capacity-padded, like MoE dispatch):
`queries [n_tiles, qcap]` with `valid [n_tiles, qcap]`.  For each valid
query the result is its predecessor rank *within the tile* (the final
binary-search step of a learned-index lookup after the model has routed the
query to a tile), i.e. the count of keys in the tile that are <= q.
Invalid slots return -1.
"""
from __future__ import annotations

import jax.numpy as jnp


def probe_ref(key_tiles: jnp.ndarray, queries: jnp.ndarray,
              valid: jnp.ndarray) -> jnp.ndarray:
    """key_tiles [n_tiles, tile] sorted per tile; queries [n_tiles, qcap];
    valid [n_tiles, qcap] bool -> positions [n_tiles, qcap] int32."""
    le = key_tiles[:, None, :] <= queries[:, :, None]   # [T, Q, tile]
    pos = jnp.sum(le, axis=-1).astype(jnp.int32)        # predecessor count
    return jnp.where(valid, pos, -1)
