"""Pallas kernel: fused K-ladder tick capture append.

The K-step serving tick (`core/etmdp.batched_episode_scan`) ends in a
memory-bound tail: re-key the scan's stacked outputs into the
transition view, pack six wide fields into one feature axis, and append
`[K, wide]` rows into each slot's `[H, wide]` capture block at a
per-slot dynamic offset.  Dispatched separately (the historical
`_capture_write` program) that tail materializes the whole `[K, B,
wide]` intermediate across a program boundary every tick; fused into
the step program (`launch/serving/programs._step_program(capture=True)`)
this kernel consumes the scan's outputs in place.

Grid: (B,) — one program instance per slot lane, mirroring the
`index_probe` one-tile-per-step idiom.  Blocks: each wide field arrives
as its `[K, 1, d_f]` lane slice, the capture block as the lane's
`[1, H, wide]` rows, the offset as a `[1]` scalar block.  The body is
pure data movement (concat + one dynamic row-slice update), so the
kernel is bitwise against the jnp reference (`ref.fused_capture_ref`)
in every mode — the serving path's capture parity does not depend on
which backend ran it.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.kernels.fused_tick.ref import FIELD_ORDER


def _capture_kernel(obs_ref, nobs_ref, ha_ref, ca_ref, hq_ref, cq_ref,
                    off_ref, cap_ref, out_ref):
    fields = (obs_ref, nobs_ref, ha_ref, ca_ref, hq_ref, cq_ref)
    packed = jnp.concatenate([f[:, 0, :] for f in fields],
                             axis=-1)                       # [K, wide]
    off = off_ref[0]
    out_ref[0] = jax.lax.dynamic_update_slice(
        cap_ref[0], packed, (off, 0))


def fused_capture_pallas(cap, new, offsets, interpret: bool = True):
    """cap [B, H, wide]; new: dict of [K, B, d_f] wide fields (the tick's
    transition view); offsets [B] int32 -> updated cap."""
    B, H, wide = cap.shape
    K = new[FIELD_ORDER[0]].shape[0]
    field_specs = [
        pl.BlockSpec((K, 1, new[f].shape[2]), lambda i: (0, i, 0))
        for f in FIELD_ORDER]
    return pl.pallas_call(
        _capture_kernel,
        grid=(B,),
        in_specs=field_specs + [
            pl.BlockSpec((1,), lambda i: (i,)),
            pl.BlockSpec((1, H, wide), lambda i: (i, 0, 0)),
        ],
        out_specs=pl.BlockSpec((1, H, wide), lambda i: (i, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((B, H, wide), cap.dtype),
        interpret=interpret,
    )(*(new[f] for f in FIELD_ORDER), offsets.astype(jnp.int32), cap)
