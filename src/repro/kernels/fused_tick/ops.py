"""Mode-gated entry points for the fused-tick capture append.

`fused_capture_core` is the un-jitted composable form the serving step
program inlines (`launch/serving/programs._step_program(capture=True)`
traces it inside its shard_map core, so the whole tick — K-step scan +
capture append — is one dispatched program).  `fused_capture` is the
standalone jitted op for tests and benchmarks.  Mode routes through
`kernels/dispatch.py`: "ref" is the jnp oracle (what CPU serving runs —
bitwise the historical two-program path), Pallas modes run the kernel
(bitwise too: the body is pure data movement).
"""
from __future__ import annotations

from functools import partial

import jax

from repro.kernels import dispatch
from repro.kernels.fused_tick.kernel import fused_capture_pallas
from repro.kernels.fused_tick.ref import fused_capture_ref


def fused_capture_core(cap, new, offsets, mode: str):
    """Un-jitted core: `mode` must already be resolved (static under the
    caller's trace)."""
    if mode == "ref":
        return fused_capture_ref(cap, new, offsets)
    return fused_capture_pallas(cap, new, offsets,
                                interpret=dispatch.interpret_flag(mode))


@partial(jax.jit, static_argnames=("mode",))
def fused_capture(cap, new, offsets, *, mode: str | None = None):
    """cap [B, H, wide]; new: dict of [K, B, d_f] transition-view fields;
    offsets [B] -> updated cap (rows [off, off+K) per slot)."""
    return fused_capture_core(cap, new, offsets, dispatch.resolve(mode))
