"""Pure-jnp oracle for the fused-tick capture append.

The serving tick's tail is pure data movement: pack the six wide
transition fields (`core.replay.WIDE_FIELDS` order) of a `[K, B, ...]`
tick stack into one `[B, K, wide]` operand and append it into each
slot's `[H, wide]` capture rows at that slot's episode offset.  This is
the historical `launch/serving/programs._capture_write_core` body,
hoisted here so the Pallas kernel and the serving program share one
reference (the kernel is bitwise against it: both are copies).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

# the packing order of the capture feature axis — must match
# core.replay.WIDE_FIELDS (replay.py slices the columns back out)
FIELD_ORDER = ("obs", "next_obs", "h_a", "c_a", "h_q", "c_q")


def fused_capture_ref(cap, new, offsets):
    """cap [B, H, wide]; new: dict of [K, B, d_f] wide fields; offsets
    [B] int32 -> cap with rows [off, off+K) of each slot replaced."""
    packed = jnp.concatenate([new[f] for f in FIELD_ORDER],
                             axis=-1)           # [K, B, wide]
    packed = jnp.moveaxis(packed, 0, 1)         # [B, K, wide]

    def one(b, n_, off):
        return jax.lax.dynamic_update_slice(b, n_, (off, 0))

    return jax.vmap(one)(cap, packed, offsets)
