"""Process-wide Pallas execution-mode dispatch: the one resolution seam.

Every in-tree kernel op (`index_probe.batched_lookup`,
`flash_attention.mha`, `mamba_scan.scan`, `fused_tick.fused_capture`)
takes a single static ``mode`` argument instead of per-callsite
``use_pallas``/``interpret`` flag stacks, and ``mode=None`` defers to
this module's per-process resolution:

  * ``compiled``  — lower the Pallas kernel for real (GPU/TPU only);
  * ``interpret`` — run the Pallas kernel body through the interpreter
                    (any backend; the CPU correctness path for the
                    kernel *logic*, far too slow to serve from);
  * ``ref``       — the pure-jnp reference implementation (bitwise
                    oracle; what CPU serving actually runs);
  * ``auto``      — ``compiled`` when the default jax backend is an
                    accelerator, ``ref`` otherwise.

Resolution order for ``auto``/``None``: the ``REPRO_KERNEL_MODE``
environment variable (when set to a concrete mode) wins, then the
backend rule above.  The result is cached for the life of the process —
kernel mode is a deployment property, not a per-call one — so every
jitted program in the process agrees on it and the serving program
cache never splits on kernel flags.  `KernelConfig` is the frozen,
hashable carrier that threads an explicit override through
`EnvConfig`/`ServeConfig` (it participates in jit static args and the
serving program-cache keys, so two services with different kernel
postures never share an executable by accident).

Importing this module never initializes jax's backend: the backend
probe happens lazily inside `resolve()`, at program-build time, after
the operator's XLA_FLAGS are set (same contract as
`launch/serving/programs.py`).
"""
from __future__ import annotations

import dataclasses
import os
from functools import lru_cache

MODES = ("auto", "compiled", "interpret", "ref")
_ACCELERATOR_BACKENDS = ("gpu", "tpu", "cuda", "rocm")
_ENV_VAR = "REPRO_KERNEL_MODE"


def _validate(mode: str) -> str:
    if mode not in MODES:
        raise ValueError(f"kernel mode {mode!r} not in {MODES}")
    return mode


@lru_cache(maxsize=None)
def _auto_mode() -> str:
    """The process's resolved default mode (cached: kernel mode is a
    deployment property — one answer per process keeps every jitted
    program and cache key coherent)."""
    env = os.environ.get(_ENV_VAR, "").strip().lower()
    if env and env != "auto":
        return _validate(env)
    import jax  # lazy: never initialize the backend at import time
    backend = jax.default_backend()
    return "compiled" if backend in _ACCELERATOR_BACKENDS else "ref"


def resolve(mode: str | None = None) -> str:
    """Resolve a requested mode to a concrete one (never ``auto``)."""
    if mode is None or mode == "auto":
        return _auto_mode()
    return _validate(mode)


def interpret_flag(mode: str) -> bool:
    """The `pl.pallas_call(interpret=...)` flag for a resolved Pallas
    mode (callers branch to the jnp ref before consulting this)."""
    assert mode in ("compiled", "interpret"), mode
    return mode == "interpret"


@dataclasses.dataclass(frozen=True)
class KernelConfig:
    """Frozen kernel posture threaded through `EnvConfig`/`ServeConfig`.

    ``mode`` picks the Pallas execution mode for every routed kernel
    (``auto`` defers to `resolve()`); ``probe_reads`` gates routing the
    learned-index read probes (`index/alex.py` / `carmi.py`
    ``run_reads``) through `index_probe.batched_lookup` when the
    resolved mode is a Pallas one; ``fused_tick`` gates fusing the
    K-ladder tick's transition-capture tail into the serving step
    program (`launch/serving/programs._step_program(capture=True)`);
    ``probe_tile`` overrides the probe kernel's key-tile size (0 = the
    largest power-of-two divisor of n, capped at 512).
    """

    mode: str = "auto"
    probe_reads: bool = True
    fused_tick: bool = True
    probe_tile: int = 0

    def __post_init__(self):
        _validate(self.mode)
        if self.probe_tile < 0 or (
                self.probe_tile and self.probe_tile & (self.probe_tile - 1)):
            raise ValueError(f"probe_tile={self.probe_tile} must be 0 "
                             f"(auto) or a power of two")

    def resolved(self) -> str:
        """This config's concrete mode for the current process."""
        return resolve(self.mode)
