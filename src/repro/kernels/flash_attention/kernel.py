"""Pallas TPU flash-attention forward kernel.

Grid (B*H, n_q_blocks, n_kv_blocks) with the kv axis minor-most: TPU grids
execute sequentially over the last axis, so the online-softmax running state
(m, l, acc) lives in VMEM scratch and is carried across kv blocks; the
output tile is written once on the final kv block.  Block sizes default to
(128, 128) -- MXU-aligned -- and the whole working set
(q_blk + k_blk + v_blk + acc ~ 4*128*head_dim*4B) stays far under the ~16MB
VMEM budget for every assigned head_dim (64..256).

This kernel is what removes the [B,H,S,S] f32 probability traffic that
dominates the memory roofline term of the jnp baseline (EXPERIMENTS.md
§Perf).
"""
from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -0.7 * float(jnp.finfo(jnp.float32).max)


def _flash_kernel(q_ref, k_ref, v_ref, o_ref, m_ref, l_ref, acc_ref, *,
                  scale: float, causal: bool, window: int,
                  block_q: int, block_k: int, n_kv: int):
    qi = pl.program_id(1)
    ki = pl.program_id(2)

    @pl.when(ki == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    q = q_ref[0]                                  # [bq, d]
    k = k_ref[0]                                  # [bk, d]
    v = v_ref[0]
    s = jax.lax.dot_general(
        q, k, (((1,), (1,)), ((), ())),
        preferred_element_type=jnp.float32) * scale   # [bq, bk]

    q_pos = qi * block_q + jax.lax.broadcasted_iota(jnp.int32, s.shape, 0)
    k_pos = ki * block_k + jax.lax.broadcasted_iota(jnp.int32, s.shape, 1)
    mask = jnp.ones(s.shape, jnp.bool_)
    if causal:
        mask &= q_pos >= k_pos
    if window:
        mask &= (q_pos - k_pos) < window
    s = jnp.where(mask, s, NEG_INF)

    m_prev, l_prev, acc_prev = m_ref[...], l_ref[...], acc_ref[...]
    m_new = jnp.maximum(m_prev, jnp.max(s, axis=1))
    alpha = jnp.exp(m_prev - m_new)
    p = jnp.exp(s - m_new[:, None])
    l_new = l_prev * alpha + jnp.sum(p, axis=1)
    pv = jax.lax.dot_general(
        p.astype(v.dtype), v, (((1,), (0,)), ((), ())),
        preferred_element_type=jnp.float32)
    acc_new = acc_prev * alpha[:, None] + pv

    m_ref[...] = m_new
    l_ref[...] = l_new
    acc_ref[...] = acc_new

    @pl.when(ki == n_kv - 1)
    def _emit():
        out = acc_new / jnp.maximum(l_new[:, None], 1e-30)
        o_ref[0] = out.astype(o_ref.dtype)


def flash_attention(q, k, v, *, causal: bool = True, window: int = 0,
                    block_q: int = 128, block_k: int = 128,
                    scale: float | None = None, interpret: bool = True):
    """q/k/v [B, H, S, D] (same S for q and kv) -> [B, H, S, D]."""
    b, h, s, d = q.shape
    sk = k.shape[2]
    scale = scale or 1.0 / math.sqrt(d)
    block_q = min(block_q, s)
    block_k = min(block_k, sk)
    assert s % block_q == 0 and sk % block_k == 0
    n_q, n_kv = s // block_q, sk // block_k

    qf = q.reshape(b * h, s, d)
    kf = k.reshape(b * h, sk, d)
    vf = v.reshape(b * h, sk, d)
    kern = functools.partial(
        _flash_kernel, scale=scale, causal=causal, window=window,
        block_q=block_q, block_k=block_k, n_kv=n_kv)
    out = pl.pallas_call(
        kern,
        grid=(b * h, n_q, n_kv),
        in_specs=[
            pl.BlockSpec((1, block_q, d), lambda bh, qi, ki: (bh, qi, 0)),
            pl.BlockSpec((1, block_k, d), lambda bh, qi, ki: (bh, ki, 0)),
            pl.BlockSpec((1, block_k, d), lambda bh, qi, ki: (bh, ki, 0)),
        ],
        out_specs=pl.BlockSpec((1, block_q, d), lambda bh, qi, ki: (bh, qi, 0)),
        out_shape=jax.ShapeDtypeStruct((b * h, s, d), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((block_q,), jnp.float32),
            pltpu.VMEM((block_q,), jnp.float32),
            pltpu.VMEM((block_q, d), jnp.float32),
        ],
        interpret=interpret,
    )(qf, kf, vf)
    return out.reshape(b, h, s, d)
