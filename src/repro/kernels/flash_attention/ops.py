"""Jitted wrapper for the flash-attention kernel with layout adapters for
the model stack ([B,S,H,D] <-> [B,H,S,D]) and GQA head repetition.
Execution mode (compiled / interpret / jnp ref) routes through
`kernels/dispatch.py`; ``mode=None`` defers to the process default."""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp

from repro.kernels import dispatch
from repro.kernels.flash_attention.kernel import flash_attention
from repro.kernels.flash_attention.ref import attention_ref


@partial(jax.jit, static_argnames=("causal", "window", "mode",
                                   "block_q", "block_k"))
def mha(q, k, v, *, causal: bool = True, window: int = 0,
        mode: str | None = None, block_q: int = 128, block_k: int = 128):
    """q [B,S,H,D], k/v [B,S,K,D] (K divides H) -> [B,S,H,D]."""
    mode = dispatch.resolve(mode)
    h, kheads = q.shape[2], k.shape[2]
    if kheads != h:
        rep = h // kheads
        k = jnp.repeat(k, rep, axis=2)
        v = jnp.repeat(v, rep, axis=2)
    qt = q.transpose(0, 2, 1, 3)
    kt = k.transpose(0, 2, 1, 3)
    vt = v.transpose(0, 2, 1, 3)
    if mode == "ref":
        out = attention_ref(qt, kt, vt, causal=causal, window=window)
    else:
        out = flash_attention(qt, kt, vt, causal=causal, window=window,
                              block_q=block_q, block_k=block_k,
                              interpret=dispatch.interpret_flag(mode))
    return out.transpose(0, 2, 1, 3)
