"""Pure-jnp oracle for flash attention: full-materialization softmax
attention with causal / sliding-window masking, f32 accumulation."""
from __future__ import annotations

import math

import jax
import jax.numpy as jnp

NEG_INF = -0.7 * float(jnp.finfo(jnp.float32).max)


def attention_ref(q, k, v, *, causal: bool = True, window: int = 0,
                  scale: float | None = None):
    """q/k/v [B, H, S, D] -> out [B, H, Sq, D] (kv length may differ)."""
    d = q.shape[-1]
    scale = scale or 1.0 / math.sqrt(d)
    s = jnp.einsum("bhqd,bhkd->bhqk", q, k,
                   preferred_element_type=jnp.float32) * scale
    sq, sk = q.shape[2], k.shape[2]
    q_pos = jnp.arange(sq)[:, None]
    k_pos = jnp.arange(sk)[None, :]
    mask = jnp.ones((sq, sk), bool)
    if causal:
        mask &= q_pos >= k_pos
    if window:
        mask &= (q_pos - k_pos) < window
    s = jnp.where(mask[None, None], s, NEG_INF)
    p = jax.nn.softmax(s, axis=-1)
    return jnp.einsum("bhqk,bhkd->bhqd", p.astype(v.dtype), v,
                      preferred_element_type=jnp.float32).astype(q.dtype)
