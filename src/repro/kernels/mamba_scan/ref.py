"""Pure-jnp oracle for the selective-scan (mamba1) recurrence:

    h_t = h_{t-1} * exp(dt_t * A) + (dt_t * u_t) B_t
    y_t = <h_t, C_t>
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


def selective_scan_ref(u, dt, b_mat, c_mat, a):
    """u,dt [B,S,Di]; b_mat,c_mat [B,S,N]; a [Di,N] -> y [B,S,Di] f32."""
    def step(h, xs):
        u_t, dt_t, b_t, c_t = xs
        da = jnp.exp(dt_t[..., None] * a)                 # [B,Di,N]
        h = h * da + (dt_t * u_t)[..., None] * b_t[:, None, :]
        y = jnp.einsum("bdn,bn->bd", h, c_t)
        return h, y

    bsz, s, di = u.shape
    h0 = jnp.zeros((bsz, di, a.shape[1]), jnp.float32)
    xs = (u.swapaxes(0, 1).astype(jnp.float32),
          dt.swapaxes(0, 1).astype(jnp.float32),
          b_mat.swapaxes(0, 1).astype(jnp.float32),
          c_mat.swapaxes(0, 1).astype(jnp.float32))
    _, ys = jax.lax.scan(step, h0, xs)
    return ys.swapaxes(0, 1)
