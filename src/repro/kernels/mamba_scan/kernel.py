"""Pallas TPU kernel: chunked selective scan (mamba1 recurrence).

Grid (B, n_di_blocks, n_chunks), chunk axis minor-most: the SSM state
h [di_blk, N] persists in VMEM scratch across sequence chunks (TPU grids
run the last axis sequentially), so the recurrence streams the sequence
through VMEM in chunk_size steps while HBM traffic stays at
O(S * (Di + N)) -- the inputs/outputs themselves -- instead of the
O(S * Di * N) dA/dBu tensors a naive jnp implementation materializes.

Block sizing: di_blk=256, N=16 -> state tile 16KB; a chunk of 256 steps
keeps u/dt blocks at 256x256x4B = 256KB, comfortably inside VMEM.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _scan_kernel(u_ref, dt_ref, b_ref, c_ref, a_ref, y_ref, h_ref, *,
                 chunk: int):
    ci = pl.program_id(2)

    @pl.when(ci == 0)
    def _init():
        h_ref[...] = jnp.zeros_like(h_ref)

    u = u_ref[0].astype(jnp.float32)      # [chunk, di_blk]
    dt = dt_ref[0].astype(jnp.float32)    # [chunk, di_blk]
    bm = b_ref[0].astype(jnp.float32)     # [chunk, N]
    cm = c_ref[0].astype(jnp.float32)     # [chunk, N]
    a = a_ref[...].astype(jnp.float32)    # [di_blk, N]

    def step(t, carry):
        h, ys = carry
        dt_t = jax.lax.dynamic_index_in_dim(dt, t, keepdims=False)  # [di_blk]
        u_t = jax.lax.dynamic_index_in_dim(u, t, keepdims=False)
        b_t = jax.lax.dynamic_index_in_dim(bm, t, keepdims=False)   # [N]
        c_t = jax.lax.dynamic_index_in_dim(cm, t, keepdims=False)
        da = jnp.exp(dt_t[:, None] * a)                   # [di_blk, N]
        h = h * da + (dt_t * u_t)[:, None] * b_t[None, :]
        y_t = jnp.sum(h * c_t[None, :], axis=1)           # [di_blk]
        ys = jax.lax.dynamic_update_index_in_dim(ys, y_t, t, axis=0)
        return h, ys

    h0 = h_ref[...]
    ys0 = jnp.zeros(u.shape, jnp.float32)
    h_fin, ys = jax.lax.fori_loop(0, chunk, step, (h0, ys0))
    h_ref[...] = h_fin
    y_ref[0] = ys.astype(y_ref.dtype)


def selective_scan(u, dt, b_mat, c_mat, a, *, chunk: int = 256,
                   di_block: int = 256, interpret: bool = True):
    """u,dt [B,S,Di]; b_mat,c_mat [B,S,N]; a [Di,N] -> y [B,S,Di] f32."""
    bsz, s, di = u.shape
    n = a.shape[1]
    chunk = min(chunk, s)
    while s % chunk:
        chunk //= 2
    di_block = min(di_block, di)
    while di % di_block:
        di_block //= 2
    n_chunks, n_di = s // chunk, di // di_block

    kern = functools.partial(_scan_kernel, chunk=chunk)
    return pl.pallas_call(
        kern,
        grid=(bsz, n_di, n_chunks),
        in_specs=[
            pl.BlockSpec((1, chunk, di_block), lambda b, d, c: (b, c, d)),
            pl.BlockSpec((1, chunk, di_block), lambda b, d, c: (b, c, d)),
            pl.BlockSpec((1, chunk, n), lambda b, d, c: (b, c, 0)),
            pl.BlockSpec((1, chunk, n), lambda b, d, c: (b, c, 0)),
            pl.BlockSpec((di_block, n), lambda b, d, c: (d, 0)),
        ],
        out_specs=pl.BlockSpec((1, chunk, di_block), lambda b, d, c: (b, c, d)),
        out_shape=jax.ShapeDtypeStruct((bsz, s, di), jnp.float32),
        scratch_shapes=[pltpu.VMEM((di_block, n), jnp.float32)],
        interpret=interpret,
    )(u, dt, b_mat, c_mat, a)
