"""Jitted wrapper for the selective-scan kernel."""
from __future__ import annotations

from functools import partial

import jax

from repro.kernels.mamba_scan.kernel import selective_scan
from repro.kernels.mamba_scan.ref import selective_scan_ref


@partial(jax.jit, static_argnames=("use_pallas", "interpret", "chunk",
                                   "di_block"))
def scan(u, dt, b_mat, c_mat, a, *, use_pallas: bool = True,
         interpret: bool = True, chunk: int = 256, di_block: int = 256):
    if use_pallas:
        return selective_scan(u, dt, b_mat, c_mat, a, chunk=chunk,
                              di_block=di_block, interpret=interpret)
    return selective_scan_ref(u, dt, b_mat, c_mat, a)
