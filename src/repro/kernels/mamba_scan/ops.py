"""Jitted wrapper for the selective-scan kernel.  Execution mode
(compiled / interpret / jnp ref) routes through `kernels/dispatch.py`;
``mode=None`` defers to the process default."""
from __future__ import annotations

from functools import partial

import jax

from repro.kernels import dispatch
from repro.kernels.mamba_scan.kernel import selective_scan
from repro.kernels.mamba_scan.ref import selective_scan_ref


@partial(jax.jit, static_argnames=("mode", "chunk", "di_block"))
def scan(u, dt, b_mat, c_mat, a, *, mode: str | None = None,
         chunk: int = 256, di_block: int = 256):
    mode = dispatch.resolve(mode)
    if mode == "ref":
        return selective_scan_ref(u, dt, b_mat, c_mat, a)
    return selective_scan(u, dt, b_mat, c_mat, a, chunk=chunk,
                          di_block=di_block,
                          interpret=dispatch.interpret_flag(mode))
