"""Batched serving driver: continuous-batch prefill + decode loop.

CPU demo:
    PYTHONPATH=src python -m repro.launch.serve --arch gemma3_4b \
        --scale tiny --batch 4 --prompt-len 64 --gen 32
The same step functions lower on the production meshes (launch/dryrun.py
decode cells); this driver adds the request plumbing: a request queue,
slot-based continuous batching, and per-request completion.
"""
from __future__ import annotations

import argparse
import dataclasses
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config
from repro.launch.train import scale_config
from repro.models import model_zoo


@dataclasses.dataclass
class Request:
    rid: int
    prompt: np.ndarray           # [prompt_len] int32
    max_new_tokens: int
    generated: list = dataclasses.field(default_factory=list)
    done: bool = False


class BatchedServer:
    """Slot-based continuous batching: a fixed decode batch of B slots; new
    requests are prefilled into free slots while others keep decoding."""

    def __init__(self, cfg, batch_slots: int, max_len: int, seed: int = 0):
        self.cfg = cfg
        self.bundle = model_zoo.build(cfg, remat=False)
        self.params = self.bundle.init(jax.random.PRNGKey(seed))
        self.slots = batch_slots
        self.max_len = max_len
        self._decode = jax.jit(self.bundle.decode_fn)
        self.cache = None
        self.pos = 0
        self.active: list[Request | None] = [None] * batch_slots

    def _prefill_batch(self, requests: list[Request], **frontend):
        toks = jnp.stack([jnp.asarray(r.prompt, jnp.int32)
                          for r in requests])
        logits, cache = self.bundle.prefill_fn(
            self.params, toks, max_len=self.max_len, **frontend)
        return logits, cache

    def run(self, requests: list[Request], **frontend) -> dict:
        """Serve a wave of identical-length prompts (slot-parallel).

        Returns per-request outputs + throughput stats."""
        assert len(requests) <= self.slots
        t0 = time.time()
        logits, cache = self._prefill_batch(requests, **frontend)
        prefill_s = time.time() - t0
        pos = requests[0].prompt.shape[0]
        next_tok = jnp.argmax(logits, -1).astype(jnp.int32)
        for r, t in zip(requests, np.asarray(next_tok)):
            r.generated.append(int(t))

        t0 = time.time()
        steps = max(r.max_new_tokens for r in requests) - 1
        for i in range(steps):
            logits, cache = self._decode(self.params, next_tok, cache,
                                         jnp.int32(pos))
            pos += 1
            next_tok = jnp.argmax(logits, -1).astype(jnp.int32)
            for r, t in zip(requests, np.asarray(next_tok)):
                if len(r.generated) < r.max_new_tokens:
                    r.generated.append(int(t))
        decode_s = time.time() - t0
        n_tokens = sum(len(r.generated) for r in requests)
        return {
            "prefill_s": prefill_s,
            "decode_s": decode_s,
            "decode_tok_per_s": n_tokens / max(decode_s, 1e-9),
            "outputs": {r.rid: r.generated for r in requests},
        }


def main():
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--arch", default="gemma3_4b")
    ap.add_argument("--scale", default="tiny",
                    choices=["tiny", "10m", "100m", "full"])
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=64)
    ap.add_argument("--gen", type=int, default=32)
    args = ap.parse_args()

    cfg = scale_config(get_config(args.arch), args.scale)
    rng = np.random.default_rng(0)
    server = BatchedServer(cfg, args.batch,
                           max_len=args.prompt_len + args.gen)
    reqs = [Request(i, rng.integers(0, cfg.vocab_size, args.prompt_len),
                    args.gen) for i in range(args.batch)]
    frontend = {}
    if cfg.enc_dec:
        frontend["enc_embeds"] = jnp.zeros(
            (args.batch, cfg.enc_seq, cfg.d_model), jnp.bfloat16)
    stats = server.run(reqs, **frontend)
    print(f"arch={cfg.name} slots={args.batch} prompt={args.prompt_len} "
          f"gen={args.gen}")
    print(f"prefill {stats['prefill_s']:.2f}s  decode {stats['decode_s']:.2f}s"
          f"  {stats['decode_tok_per_s']:.1f} tok/s")
    first = next(iter(stats["outputs"].values()))
    print("sample output tokens:", first[:16])


if __name__ == "__main__":
    main()
