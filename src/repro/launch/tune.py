"""LITune end-to-end tuning driver (the paper's own end-to-end scenario).

    PYTHONPATH=src python -m repro.launch.tune --index alex --dataset osm \
        --wr 1.0 --pretrain-iters 10 --budget 25

Pretrains the Meta-RL agent (or loads a saved one), answers a tuning
request on the chosen (dataset, workload), and reports runtime vs default
plus the recommended parameters.  `--stream` runs the data-shift scenario
through the O2 system instead.
"""
from __future__ import annotations

import argparse
import json
import os
import time

import jax

from repro.core.litune import LITune, LITuneConfig
from repro.index.workloads import StreamConfig, sample_keys, stream_windows, wr_workload


def main():
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--index", default="alex", choices=["alex", "carmi"])
    ap.add_argument("--dataset", default="mix",
                    choices=["uniform", "books", "osm", "fb", "mix"])
    ap.add_argument("--wr", type=float, default=1.0,
                    help="write/read ratio (B=1, RH=1/3, WH=3)")
    ap.add_argument("--n-keys", type=int, default=8192)
    ap.add_argument("--budget", type=int, default=25, help="tuning steps")
    ap.add_argument("--pretrain-iters", type=int, default=10)
    ap.add_argument("--model", default="",
                    help="load/save pretrained agent at this path")
    ap.add_argument("--stream", action="store_true",
                    help="data-shift stream through the O2 system")
    ap.add_argument("--windows", type=int, default=10)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()

    cfg = LITuneConfig(index_type=args.index, episode_len=args.budget)
    if args.model and os.path.exists(args.model):
        tuner = LITune.load(args.model)
        print(f"loaded pretrained agent from {args.model}")
    else:
        tuner = LITune(cfg, seed=args.seed)
        if args.pretrain_iters:
            print(f"meta-pretraining {args.pretrain_iters} outer iters ...")
            t0 = time.time()
            tuner.pretrain(n_outer=args.pretrain_iters, seed=args.seed,
                           callback=lambda r: print(
                               f"  iter {r['iter']:3d} return "
                               f"{r['mean_return']:8.3f} violations "
                               f"{r['violations']:.0f}"))
            print(f"pretraining took {time.time() - t0:.0f}s")
        if args.model:
            tuner.save(args.model)
            print(f"saved agent to {args.model}")

    key = jax.random.PRNGKey(args.seed + 1)
    if args.stream:
        scfg = StreamConfig(n_windows=args.windows,
                            base_per_window=args.n_keys,
                            updates_per_window=args.n_keys,
                            dist=args.dataset, wr_start=args.wr,
                            wr_end=args.wr * 3)
        results = tuner.stream(stream_windows(key, scfg),
                               max_steps_per_window=5)
        for r in results:
            print(f"window {r['window']:2d}: best "
                  f"{r['best_runtime_ns']:9.1f} ns/op  default "
                  f"{r['r0_ns']:9.1f}  swap={r.get('swapped', False)}")
        return

    data = sample_keys(key, args.n_keys, args.dataset)
    workload, _ = wr_workload(jax.random.fold_in(key, 1), data, args.wr,
                              total=args.n_keys, dist=args.dataset)
    t0 = time.time()
    res = tuner.tune(data, workload, args.wr, budget_steps=args.budget)
    print(f"\ntuning request: index={args.index} data={args.dataset} "
          f"wr={args.wr} budget={args.budget} steps")
    print(f"default runtime : {res['r0_ns']:10.1f} ns/op")
    print(f"best runtime    : {res['best_runtime_ns']:10.1f} ns/op "
          f"({res['r0_ns'] / res['best_runtime_ns']:.2f}x speedup)")
    print(f"violations      : {res['violations']:.0f}   "
          f"tuning wall time: {time.time() - t0:.1f}s")
    print("recommended parameters:")
    print(json.dumps({k: round(v, 4) for k, v in
                      res["best_params"].items()}, indent=2))


if __name__ == "__main__":
    main()
