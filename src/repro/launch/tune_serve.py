"""Batched tuning-as-a-service: slot-based continuous batching for the
online tuning stage (multi-tenant `LITune.tune`).

`launch/serve.py` serves LM decode with fixed slots and per-request
completion; this driver applies the same shape to tuning requests.  Many
concurrent requests — heterogeneous `(data_keys, workload, wr_ratio,
budget_steps)` across both `alex` and `carmi` spaces — fill fixed slots in
per-space pools; one jitted multi-step program advances all active
episodes of a pool at once; a request that exhausts its budget (or
ET-MDP-terminates) frees its slot mid-flight for the next queued request.

CPU demo:
    PYTHONPATH=src python -m repro.launch.tune_serve --requests 8 --slots 4
Multi-core (slots shard over forced host devices):
    XLA_FLAGS=--xla_force_host_platform_device_count=2 \
        PYTHONPATH=src python -m repro.launch.tune_serve

Key properties:
  * **parity** — every slot computes the *same traced per-step program*
    as the serial `rollout_episode` (`lax.map` over slots, `lax.scan`
    over steps of the whole map body), so per-request rewards/runtimes
    are bitwise identical to a one-at-a-time `LITune.tune` with the same
    PRNG key (tests/test_tune_service.py).
  * **no recompiles on mixed streams** — compiled executables are cached
    by `(index_type, array shapes, batch shape, scan length)`; an alex
    request arriving after a carmi wave reuses the alex program.
  * **host-side budgets** — `budget_steps` is enforced by the serving
    loop, not baked into the program: each tick scans
    K = largest power of two ≤ the smallest remaining budget among active
    slots, so heterogeneous budgets share a small ladder of executables.
  * **slot sharding** — when the host platform exposes multiple devices
    (cores) and they divide the slot count, slots shard across them via
    `shard_map`; sharding never changes per-slot math, so parity holds.
  * **continuous tuning (O2)** — with `O2ServiceConfig(enabled=True)` the
    service stops serving a frozen agent: retired episodes stream their
    transitions into a per-tenant replay, an offline DDPG learner
    fine-tunes between ticks, and a divergence monitor (KS on key
    quantiles + W/R drift, observed at admission) triggers assessments
    that hot-swap pool params when the offline model wins.  The swap is a
    pure buffer update — params are program *inputs*, so the K-ladder
    compiled-program cache never re-traces.  A single-tenant strict-order
    stream makes the same swap decisions as
    `core.o2.O2System.tune_window` at any budget
    (tests/test_o2_service.py).
"""
from __future__ import annotations

import argparse
import dataclasses
import time
from collections import deque
from functools import lru_cache

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.core import networks as nets
from repro.runtime.mesh_utils import shard_map_compat
from repro.core.etmdp import batched_episode_scan
from repro.core.litune import attach_best_params
from repro.core.o2 import (DivergenceMonitor, O2Config, assess_offline,
                           make_replay, offline_finetune)
from repro.core.parallel import mapped_reset
from repro.index import env as E


@dataclasses.dataclass
class TuneRequest:
    """One tuning-as-a-service request (the unit of multi-tenancy)."""
    rid: int
    data_keys: jax.Array
    workload: dict                 # {"reads": [r], "inserts": [i]}
    wr_ratio: float
    budget_steps: int
    index_type: str = "alex"       # alex | carmi
    key: jax.Array | None = None   # episode PRNG key (parity handle)
    noise_scale: float = 0.05
    o2_key: jax.Array | None = None  # window-key remainder (assessment PRNG)


@dataclasses.dataclass(frozen=True)
class O2ServiceConfig:
    """Continuous tuning inside the service (the O2 loop, per tenant)."""
    enabled: bool = False
    o2: O2Config = O2Config()
    # offline fine-tune steps run after each tick that retires at least
    # one of the tenant's episodes (ticks with no fresh transitions skip
    # the learner: re-sampling an unchanged replay would add latency to
    # every tick of a long episode and desync the per-window update count
    # from the serial O2 loop).  None -> the O2Config's per-window count,
    # which makes a strict-order single-tenant stream decision-identical
    # to `O2System.tune_window` at any budget
    offline_updates_per_tick: int | None = None
    # one window in flight at a time, in submission order: trades the
    # service's cross-pool concurrency for the serial O2 loop's exact
    # observe->tune->assess interleaving (the parity mode LITune.stream
    # uses when routed through the service)
    strict_order: bool = False
    replay_seed: int = 0


class _TenantO2:
    """Per-tenant continuous-tuning state: the divergence monitor, the
    replay the offline learner samples, and the offline DDPG state that
    hot-swaps into the tenant's pools on divergence + win."""

    def __init__(self, tuner, svc_cfg: O2ServiceConfig):
        self.cfg = svc_cfg.o2
        self.net_cfg = tuner.cfg.net_cfg()
        self.ddpg_cfg = tuner.cfg.ddpg
        self.et_cfg = tuner.cfg.et_cfg()
        self.env_cfg = tuner.cfg.env_cfg()
        self.monitor = DivergenceMonitor(self.cfg)
        self.replay = make_replay(self.net_cfg, self.ddpg_cfg, self.env_cfg,
                                  seed=svc_cfg.replay_seed)
        self.online = jax.tree.map(lambda x: x, tuner.state)
        self.offline = jax.tree.map(lambda x: x, tuner.state)
        self.offline_updates = 0
        self.swaps = 0
        self.swap_times_s: list[float] = []


def summarize_episode(env_cfg: E.EnvConfig, r0: float, rewards, runtimes,
                      actions, costs, terminated: bool) -> dict:
    """Assemble the per-request summary in the exact `LITune.tune` shape
    (shared decode via `attach_best_params`)."""
    summary = {
        "episode_return": float(np.sum(rewards)),
        "best_runtime_ns": min(r0, float(np.min(runtimes))),
        "r0_ns": r0,
        "violations": float(np.sum(costs)),
        "terminated_early": terminated,
        "runtimes": [float(r) for r in runtimes],
        "actions": [np.asarray(a) for a in actions],
        "steps": len(runtimes),
    }
    summary["best_params"] = attach_best_params(summary, env_cfg)
    return summary


def _pow2_ladder(n: int) -> list[int]:
    out, k = [], 1
    while k <= n:
        out.append(k)
        k *= 2
    return out


# --------------------------------------------------------------- programs
# Process-wide program cache: builders are keyed on (device ids, frozen
# configs, shapes) so every TuningService instance — and every pool within
# one — shares the same jitted callables and their compiled executables.
# A per-service dict on top of this would recompile per instance, which is
# exactly the recompile-on-mixed-streams failure this engine exists to
# avoid.

def _mesh_for(device_ids: tuple) -> Mesh:
    by_id = {d.id: d for d in jax.devices()}
    return Mesh(np.array([by_id[i] for i in device_ids]), ("slots",))


@lru_cache(maxsize=None)
def _step_program(device_ids: tuple, net_cfg, env_cfg, et_cfg, k: int):
    """K-step slot program: scan over K ticks of the bitwise-stable
    one-tick map body, slots sharded over the mesh."""
    mesh = _mesh_for(device_ids)

    def core(p, c, n):
        return batched_episode_scan(p, c, n, k, net_cfg, env_cfg, et_cfg,
                                    False)

    return jax.jit(shard_map_compat(
        core, mesh, in_specs=(P(), P("slots"), P("slots")),
        out_specs=(P("slots"), P(None, "slots"))))


@lru_cache(maxsize=None)
def _reset_program(device_ids: tuple, env_cfg):
    """Batched admission: reset a wave of episodes in one (sharded when
    the wave divides the mesh) program."""
    mesh = _mesh_for(device_ids)

    def core(d, r, i, wr):
        return mapped_reset(env_cfg, d, {"reads": r, "inserts": i}, wr)

    return jax.jit(shard_map_compat(
        core, mesh,
        in_specs=(P("slots"), P("slots"), P("slots"), P("slots")),
        out_specs=P("slots")))


@lru_cache(maxsize=None)
def _admit_scatter_program(device_ids: tuple, net_cfg, slots: int):
    """Scatter freshly-reset episodes into their slots (padded entries
    target slot index B and are dropped)."""
    sharded = NamedSharding(_mesh_for(device_ids), P("slots"))

    def scatter(carry, idx, keys, env_states, obs):
        def upd(buf, x):
            return buf.at[idx].set(x, mode="drop")
        zero_h = nets.zero_hidden(net_cfg, (idx.shape[0],))
        return {
            "key": upd(carry["key"], keys),
            "env": jax.tree.map(upd, carry["env"], env_states),
            "obs": upd(carry["obs"], obs),
            "h_a": tuple(upd(c, z) for c, z in zip(carry["h_a"], zero_h)),
            "h_q": tuple(upd(c, z) for c, z in zip(carry["h_q"], zero_h)),
            "b_t": upd(carry["b_t"],
                       jnp.zeros((idx.shape[0],), jnp.float32)),
        }

    return jax.jit(scatter, out_shardings=sharded)


@lru_cache(maxsize=None)
def _build_carry_program(device_ids: tuple, net_cfg, slots: int):
    """Initial-wave fast path: construct the whole B-slot carry from a
    full batch of resets (no scatter)."""
    sharded = NamedSharding(_mesh_for(device_ids), P("slots"))

    def build(keys, env_states, obs):
        return {
            "key": keys,
            "env": env_states,
            "obs": obs,
            "h_a": nets.zero_hidden(net_cfg, (slots,)),
            "h_q": nets.zero_hidden(net_cfg, (slots,)),
            "b_t": jnp.zeros((slots,), jnp.float32),
        }

    return jax.jit(build, out_shardings=sharded)


class _SlotPool:
    """Fixed B-slot episode pool for one (index space, array-shape) group.

    Device state: a slot-batched episode carry (sharded over the mesh) and
    a [B] per-slot noise vector.  Host state: which request occupies which
    slot, steps taken, and the per-step records streamed back each tick.
    """

    def __init__(self, env_cfg: E.EnvConfig, net_cfg, et_cfg, params,
                 slots: int, mesh: Mesh, capture: bool = False):
        self.env_cfg = env_cfg
        self.net_cfg = net_cfg
        self.et_cfg = et_cfg
        self.slots = slots
        self.mesh = mesh
        self.capture = capture          # record per-step transitions (O2)
        self.replicated = NamedSharding(mesh, P())
        self.sharded = NamedSharding(mesh, P("slots"))
        self.params = jax.device_put(params, self.replicated)
        self.carry = None                       # batched pytree, lazy init
        self.noise = np.zeros((slots,), np.float32)
        self._noise_dev = None                  # placed copy, lazy
        self.requests: list[TuneRequest | None] = [None] * slots
        self.steps_taken = np.zeros((slots,), np.int64)
        self.records: list[dict | None] = [None] * slots
        self.r0: list[float] = [0.0] * slots

    @property
    def n_active(self) -> int:
        return sum(r is not None for r in self.requests)

    def free_slots(self):
        return [i for i, r in enumerate(self.requests) if r is None]

    def remaining(self):
        return [r.budget_steps - int(self.steps_taken[i])
                for i, r in enumerate(self.requests) if r is not None]

    def noise_dev(self):
        if self._noise_dev is None:
            self._noise_dev = jax.device_put(jnp.asarray(self.noise),
                                             self.sharded)
        return self._noise_dev

    def mark_admitted(self, slot: int, req: TuneRequest, r0: float):
        self.noise[slot] = req.noise_scale
        self._noise_dev = None
        self.requests[slot] = req
        self.steps_taken[slot] = 0
        self.r0[slot] = r0
        rec = {"rewards": [], "runtimes": [], "actions": [], "costs": []}
        if self.capture:
            rec.update({"obs": [], "next_obs": [], "done": [],
                        "h_a": [], "c_a": [], "h_q": [], "c_q": []})
        self.records[slot] = rec

    def collect(self, slot: int, out_host: dict, step: int,
                early: bool = False) -> bool:
        """Record one step for `slot`; returns whether the episode is done
        (early exit or budget exhausted)."""
        rec = self.records[slot]
        rec["rewards"].append(float(out_host["reward"][step, slot]))
        rec["runtimes"].append(float(out_host["runtime_ns"][step, slot]))
        rec["actions"].append(np.asarray(out_host["action"][step, slot]))
        rec["costs"].append(float(out_host["cost"][step, slot]))
        self.steps_taken[slot] += 1
        done = early or \
            self.steps_taken[slot] >= self.requests[slot].budget_steps
        if self.capture:
            # the transition view: pre-step obs/hiddens + post-step obs.
            # `done` is computed host-side against the request budget — the
            # program's own horizon flag tracks the pool's horizon_cap, not
            # the per-request episode length the serial path would record.
            rec["obs"].append(np.asarray(out_host["obs"][step, slot]))
            rec["next_obs"].append(
                np.asarray(out_host["next_obs"][step, slot]))
            rec["done"].append(1.0 if done else 0.0)
            rec["h_a"].append(np.asarray(out_host["h_a"][0][step, slot]))
            rec["c_a"].append(np.asarray(out_host["h_a"][1][step, slot]))
            rec["h_q"].append(np.asarray(out_host["h_q"][0][step, slot]))
            rec["c_q"].append(np.asarray(out_host["h_q"][1][step, slot]))
        return done

    def retire(self, slot: int,
               terminated: bool) -> tuple[TuneRequest, dict, dict | None]:
        req, rec = self.requests[slot], self.records[slot]
        summary = summarize_episode(
            self.env_cfg, self.r0[slot], rec["rewards"], rec["runtimes"],
            rec["actions"], rec["costs"], terminated)
        transitions = None
        if self.capture:
            transitions = {
                "obs": np.stack(rec["obs"]),
                "action": np.stack(rec["actions"]),
                "reward": np.asarray(rec["rewards"], np.float32),
                "next_obs": np.stack(rec["next_obs"]),
                "done": np.asarray(rec["done"], np.float32),
                "cost": np.asarray(rec["costs"], np.float32),
                "actor_hidden": (np.stack(rec["h_a"]), np.stack(rec["c_a"])),
                "critic_hidden": (np.stack(rec["h_q"]),
                                  np.stack(rec["c_q"])),
            }
        self.requests[slot] = None
        self.records[slot] = None
        return req, summary, transitions


class TuningService:
    """Multi-tenant tuning engine over pretrained LITune agents.

    `agents` maps index_type -> a `core.litune.LITune` (or anything with
    `.cfg` and `.state`); a single LITune is accepted and keyed by its own
    `cfg.index_type`.  Submit requests, then `run()` — per-request
    summaries come back keyed by request id.
    """

    def __init__(self, agents, slots: int = 4, horizon_cap: int = 256,
                 seed: int = 0, o2: O2ServiceConfig | None = None):
        if not isinstance(agents, dict):
            agents = {agents.cfg.index_type: agents}
        self.agents = agents
        self.slots = slots
        self.horizon_cap = horizon_cap
        self.o2 = o2 if o2 is not None else O2ServiceConfig()
        self.tenants: dict[str, _TenantO2] = {}
        if self.o2.enabled:
            for it, tuner in agents.items():
                self.tenants[it] = _TenantO2(tuner, self.o2)
        self._o2_pending: dict[int, dict] = {}  # rid -> admission verdict
        self.key = jax.random.PRNGKey(seed)
        devices = jax.devices()
        # largest device subset whose count divides the slots (gcd), so
        # e.g. slots=4 on a 16-device host still shards over 4 devices
        devices = devices[:np.gcd(slots, len(devices))]
        self.mesh = Mesh(np.array(devices), ("slots",))
        self.queue: deque[TuneRequest] = deque()
        self.results: dict[int, dict] = {}
        self.pools: dict[tuple, _SlotPool] = {}
        self._programs: dict[tuple, object] = {}   # compiled-program cache
        self.program_misses = 0
        self.program_hits = 0
        self.service_steps = 0
        self.episode_steps = 0
        self._next_rid = 0

    # ------------------------------------------------------------ intake
    def submit(self, data_keys, workload, wr_ratio: float,
               budget_steps: int | None = None, index_type: str | None = None,
               noise_scale: float | None = None,
               deterministic: bool = False, key=None) -> int:
        """Enqueue one tuning request; returns its request id."""
        if index_type is None:
            index_type = next(iter(self.agents))
        if index_type not in self.agents:
            raise KeyError(f"no agent for index_type={index_type!r} "
                           f"(have {sorted(self.agents)})")
        tuner = self.agents[index_type]
        if budget_steps is None:
            budget_steps = tuner.cfg.episode_len
        if budget_steps > self.horizon_cap:
            raise ValueError(f"budget_steps={budget_steps} exceeds "
                             f"horizon_cap={self.horizon_cap}")
        if budget_steps < 1:
            raise ValueError(f"budget_steps={budget_steps} must be >= 1")
        # `deterministic` is served as noise_scale=0.0 through the shared
        # stochastic program (a per-request static branch would split the
        # pool's executable): for the tanh-bounded actor, a + 0*noise
        # clipped to [-1,1] equals the deterministic branch's raw output,
        # so recommendations match LITune.tune(deterministic=True)
        if noise_scale is None:
            noise_scale = 0.0 if deterministic else 0.05
        if key is None:
            self.key, key = jax.random.split(self.key)
        o2_key = None
        if self.o2.enabled:
            # mirror O2System.tune_window's PRNG discipline: the submitted
            # key is the *window* key — the episode runs on k_on, and the
            # assessment (if the window diverges) draws k_off from the
            # remainder, so decisions line up with the serial O2 loop
            o2_key, key = jax.random.split(key)
        rid = self._next_rid
        self._next_rid += 1
        # numpy (uncommitted) on purpose: admission programs place these
        # per the pool's mesh; committed jax arrays would pin device 0
        self.queue.append(TuneRequest(
            rid=rid, data_keys=np.asarray(data_keys),
            workload={"reads": np.asarray(workload["reads"]),
                      "inserts": np.asarray(workload["inserts"])},
            wr_ratio=float(wr_ratio), budget_steps=int(budget_steps),
            index_type=index_type, key=key,
            noise_scale=float(noise_scale), o2_key=o2_key))
        return rid

    # ------------------------------------------------------------ pools
    def _pool_key(self, req: TuneRequest) -> tuple:
        return (req.index_type, int(req.data_keys.shape[0]),
                int(req.workload["reads"].shape[0]),
                int(req.workload["inserts"].shape[0]))

    def _pool_for(self, req: TuneRequest) -> _SlotPool:
        pk = self._pool_key(req)
        if pk not in self.pools:
            tuner = self.agents[req.index_type]
            env_cfg = tuner.cfg.env_cfg().with_episode_len(self.horizon_cap)
            # under O2, pools serve the tenant's (possibly already swapped)
            # online model rather than the agent's frozen pretrained state
            params = (self.tenants[req.index_type].online["params"]
                      if self.o2.enabled else tuner.state["params"])
            self.pools[pk] = _SlotPool(env_cfg, tuner.cfg.net_cfg(),
                                       tuner.cfg.et_cfg(), params,
                                       self.slots, self.mesh,
                                       capture=self.o2.enabled)
        return self.pools[pk]

    # --------------------------------------------------------- programs
    @property
    def _device_ids(self) -> tuple:
        return tuple(d.id for d in self.mesh.devices.flat)

    def _pool_step_program(self, pk: tuple, pool: _SlotPool, k: int):
        """K-step slot program, cached process-wide on
        (devices, frozen configs, K) so mixed alex/carmi request streams —
        and successive service instances — alternate between resident
        executables, never re-tracing."""
        prog_key = ("step", pk, self.slots, k)
        if prog_key not in self._programs:
            self.program_misses += 1
            self._programs[prog_key] = _step_program(
                self._device_ids, pool.net_cfg, pool.env_cfg, pool.et_cfg,
                k)
        else:
            self.program_hits += 1
        return self._programs[prog_key]

    def _pool_reset_program(self, pool: _SlotPool, width: int):
        ids = self._device_ids
        if width % len(ids) != 0:
            ids = ids[:1]               # narrow wave: single-device mesh
        return _reset_program(ids, pool.env_cfg)

    # ------------------------------------------------------------ serving
    def _admit(self, pk: tuple, pool: _SlotPool, admits: list[TuneRequest]):
        """Admit up to `len(free slots)` requests into `pool` with one
        batched reset (padded to a power-of-two width)."""
        free = pool.free_slots()
        assert len(admits) <= len(free)
        m = len(admits)
        widths = sorted(set(_pow2_ladder(self.slots) + [self.slots]))
        width = next(w for w in widths if w >= m)
        pad = width - m
        reqs = admits + [admits[0]] * pad
        data = np.stack([r.data_keys for r in reqs])
        reads = np.stack([r.workload["reads"] for r in reqs])
        ins = np.stack([r.workload["inserts"] for r in reqs])
        wr = np.asarray([r.wr_ratio for r in reqs], np.float32)
        keys = np.stack([np.asarray(r.key) for r in reqs])
        env_states, obs = self._pool_reset_program(pool, width)(
            data, reads, ins, wr)
        ndev = len(self._device_ids)
        if ndev > 1 and width % ndev != 0:
            # narrow reset ran on a single-device mesh; rehome to host so
            # the scatter (placed on the pool mesh) accepts it
            env_states, obs = jax.device_get((env_states, obs))

        if m == self.slots and pool.carry is None:
            pool.carry = _build_carry_program(
                self._device_ids, pool.net_cfg, self.slots)(
                keys, env_states, obs)
            slots_used = list(range(self.slots))
        else:
            if pool.carry is None:
                # first admission with a partial wave: seed every slot with
                # episode 0 so idle slots hold valid (ignored) state
                es0, obs0 = jax.device_get(
                    (jax.tree.map(lambda x: x[:1], env_states), obs[:1]))
                full = jax.tree.map(
                    lambda x: np.broadcast_to(x, (self.slots,)
                                              + x.shape[1:]),
                    (es0, obs0))
                pool.carry = _build_carry_program(
                    self._device_ids, pool.net_cfg, self.slots)(
                    np.broadcast_to(keys[:1], (self.slots,)
                                    + keys.shape[1:]), full[0], full[1])
            slots_used = free[:m]
            idx = np.asarray(slots_used + [self.slots] * pad, np.int32)
            pool.carry = _admit_scatter_program(
                self._device_ids, pool.net_cfg, self.slots)(
                pool.carry, idx, keys, env_states, obs)
        r0s = np.asarray(jax.device_get(env_states["r_best"]))
        for j, (slot, req) in enumerate(zip(slots_used, admits)):
            pool.mark_admitted(slot, req, float(r0s[j]))
            if self.o2.enabled:
                # each admitted request is one window of the tenant's
                # stream: observe divergence now (against the reference
                # distribution), assess after the episode retires
                tenant = self.tenants[req.index_type]
                div = tenant.monitor.observe(req.data_keys, req.wr_ratio)
                self._o2_pending[req.rid] = {
                    "div": div, "window": tenant.monitor.windows_seen,
                    "o2_key": req.o2_key}

    def _admit_from_queue(self):
        """Fill free slots with queued requests (FIFO per pool group),
        one batched reset per pool per tick.  In strict-order O2 mode a
        single window is admitted at a time, in submission order."""
        if self.o2.enabled and self.o2.strict_order:
            if not self.queue or \
                    any(p.n_active for p in self.pools.values()):
                return
            req = self.queue.popleft()
            self._admit(self._pool_key(req), self._pool_for(req), [req])
            return
        per_pool: dict[tuple, list[TuneRequest]] = {}
        still_queued = deque()
        free_left: dict[tuple, int] = {}
        while self.queue:
            req = self.queue.popleft()
            pool = self._pool_for(req)
            pk = self._pool_key(req)
            if pk not in free_left:
                free_left[pk] = len(pool.free_slots())
            if free_left[pk] > 0:
                per_pool.setdefault(pk, []).append(req)
                free_left[pk] -= 1
            else:
                still_queued.append(req)
        self.queue = still_queued
        for pk, admits in per_pool.items():
            self._admit(pk, self.pools[pk], admits)

    def step(self) -> int:
        """One service tick: admit queued requests, advance every active
        pool by a K-step jitted program, retire finished episodes, then —
        under O2 — fine-tune the offline learners and assess retired
        windows.  Returns the number of episode-steps of useful work."""
        self._admit_from_queue()
        work = 0
        retired: list[tuple[TuneRequest, dict]] = []
        for pk, pool in self.pools.items():
            if pool.n_active == 0 or pool.carry is None:
                continue
            min_rem = min(pool.remaining())
            k = max(w for w in _pow2_ladder(self.horizon_cap)
                    if w <= max(min_rem, 1))
            program = self._pool_step_program(pk, pool, k)
            pool.carry, out = program(pool.params, pool.carry,
                                      pool.noise_dev())
            # only the fields the serving loop reads cross to the host
            fields = ["reward", "runtime_ns", "action", "cost", "early"]
            if self.o2.enabled:
                fields += ["obs", "next_obs", "h_a", "h_q"]
            out_host = jax.device_get({f: out[f] for f in fields})
            for slot, req in enumerate(pool.requests):
                if req is None:
                    continue
                for j in range(k):
                    early = bool(out_host["early"][j, slot])
                    done = pool.collect(slot, out_host, j, early)
                    work += 1
                    if done:
                        rreq, summary, trans = pool.retire(slot, early)
                        self.results[rreq.rid] = summary
                        if self.o2.enabled:
                            # stream the completed episode into the
                            # tenant's replay (batched ring write)
                            self.tenants[rreq.index_type].replay \
                                .add_episode(**trans)
                            retired.append((rreq, summary))
                        break
        if self.o2.enabled:
            self._o2_tick(retired)
        self.service_steps += 1
        self.episode_steps += work
        return work

    # --------------------------------------------------------------- O2
    def _o2_tick(self, retired: list):
        """The between-ticks half of the O2 loop: each tenant that
        retired an episode this tick fine-tunes its offline learner on
        the freshly accumulated transitions, then every retired window is
        assessed (if its admission flagged divergence) and may hot-swap
        its tenant's pools."""
        for index_type in {req.index_type for req, _ in retired}:
            tenant = self.tenants[index_type]
            n = (self.o2.offline_updates_per_tick
                 if self.o2.offline_updates_per_tick is not None
                 else tenant.cfg.offline_updates_per_window)
            tenant.offline, done = offline_finetune(
                tenant.offline, tenant.replay, tenant.net_cfg,
                tenant.ddpg_cfg, n)
            tenant.offline_updates += done
        for req, summary in retired:
            tenant = self.tenants[req.index_type]
            pend = self._o2_pending.pop(req.rid)
            swapped = False
            if pend["div"]["diverged"] and \
                    pend["window"] % tenant.cfg.assess_every == 0:
                k_off = jax.random.split(pend["o2_key"])[1]
                off = assess_offline(
                    k_off, tenant.offline, tenant.net_cfg,
                    tenant.env_cfg.with_episode_len(req.budget_steps),
                    tenant.et_cfg, req.data_keys, req.workload,
                    req.wr_ratio)
                if off["best_runtime_ns"] < summary["best_runtime_ns"]:
                    self._hot_swap(req.index_type, req,
                                   window=pend["window"] - 1)
                    swapped = True
            # annotate the request's result with its window verdict, in
            # the exact shape O2System.tune_window returns
            summary["divergence"] = pend["div"]
            summary["swapped"] = swapped

    def _hot_swap(self, index_type: str, req: TuneRequest,
                  window: int | None = None):
        """Promote the offline model to online: a pure buffer update on
        every pool of the tenant.  Params are program *inputs*, not traced
        constants, so the K-ladder compiled-program cache is untouched —
        no re-trace, no re-compile (asserted in tests/test_o2_service.py).
        `window` is the retired window whose data re-anchors the monitor
        (under concurrent serving it may not be the latest one observed).
        """
        t0 = time.perf_counter()
        tenant = self.tenants[index_type]
        tenant.online = jax.tree.map(lambda x: x, tenant.offline)
        for pk, pool in self.pools.items():
            if pk[0] == index_type:
                pool.params = jax.device_put(tenant.online["params"],
                                             pool.replicated)
        tenant.monitor.re_anchor(req.data_keys, req.wr_ratio,
                                 window=window)
        tenant.swaps += 1
        tenant.swap_times_s.append(time.perf_counter() - t0)

    def run(self, max_service_steps: int | None = None) -> dict[int, dict]:
        """Serve until the queue and every slot drain; returns
        {rid: summary} for everything completed so far."""
        n = 0
        while self.queue or any(p.n_active for p in self.pools.values()):
            if max_service_steps is not None and n >= max_service_steps:
                break
            self.step()
            n += 1
        return self.results

    def stats(self) -> dict:
        st = {
            "service_steps": self.service_steps,
            "episode_steps": self.episode_steps,
            "completed": len(self.results),
            "queued": len(self.queue),
            "pools": len(self.pools),
            "devices": len(self.mesh.devices),
            # per-service binds: first/repeat use of a program key here
            "program_misses": self.program_misses,
            "program_hits": self.program_hits,
            # actual process-wide compiled step programs (shared cache)
            "programs_resident": _step_program.cache_info().currsize,
        }
        if self.o2.enabled:
            st["o2"] = {
                it: {"windows": t.monitor.windows_seen,
                     "diverged": t.monitor.diverged_count,
                     "swaps": t.swaps,
                     "offline_updates": t.offline_updates,
                     "replay_size": t.replay.size,
                     "mean_swap_ms": (1e3 * float(np.mean(t.swap_times_s))
                                      if t.swap_times_s else 0.0)}
                for it, t in self.tenants.items()}
        return st


# ---------------------------------------------------------------- driver
def main():
    from repro.core.litune import LITune, LITuneConfig
    from repro.index.workloads import sample_keys, wr_workload

    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--slots", type=int, default=4)
    ap.add_argument("--n-keys", type=int, default=2048)
    ap.add_argument("--budget", type=int, default=10)
    ap.add_argument("--index", default="alex", choices=["alex", "carmi"])
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()

    cfg = LITuneConfig(index_type=args.index, episode_len=args.budget,
                       lstm_hidden=32, mlp_hidden=64)
    tuner = LITune(cfg, seed=args.seed)
    service = TuningService(tuner, slots=args.slots, seed=args.seed)

    key = jax.random.PRNGKey(args.seed + 1)
    for i in range(args.requests):
        k = jax.random.fold_in(key, i)
        wr = [0.33, 1.0, 3.0][i % 3]
        data = sample_keys(k, args.n_keys, "mix")
        wl, _ = wr_workload(jax.random.fold_in(k, 1), data, wr,
                            total=args.n_keys, dist="mix")
        service.submit(data, wl, wr, budget_steps=args.budget)

    t0 = time.time()
    results = service.run()
    dt = time.time() - t0
    for rid in sorted(results):
        r = results[rid]
        print(f"req {rid}: default {r['r0_ns']:9.1f} ns/op  best "
              f"{r['best_runtime_ns']:9.1f}  steps {r['steps']:3d}  "
              f"violations {r['violations']:.0f}")
    st = service.stats()
    print(f"\n{len(results)} requests in {dt:.2f}s "
          f"({len(results) / max(dt, 1e-9):.2f} req/s)  "
          f"ticks={st['service_steps']}  devices={st['devices']}  "
          f"step programs bound={st['program_misses']} "
          f"reused={st['program_hits']} "
          f"resident={st['programs_resident']}")


if __name__ == "__main__":
    main()
