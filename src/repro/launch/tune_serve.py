"""Compatibility shim: the tuning service now lives in the layered
`repro.launch.serving` package (scheduler / pools / O2 runtime / SLO
layers behind a thin `service.TuningService`).

Everything this module used to define is re-exported here — the same
objects, not copies — so `from repro.launch.tune_serve import
TuningService` and `python -m repro.launch.tune_serve` keep working
(tests/test_serving_layers.py pins the identity).  New code should
import from `repro.launch.serving` directly.

Note the one thing a re-export cannot preserve: monkeypatching *this*
module's attributes (e.g. `tune_serve._pooled_best`) only rebinds the
shim's name — the serving layers resolve their internals from their own
module globals.  Patch the owning module instead
(`repro.launch.serving.o2_runtime._pooled_best`,
`repro.launch.serving.programs._step_program`, ...), as the test suite
now does.
"""
from repro.launch.serving import (  # noqa: F401
    AdaptiveSlotPolicy,
    DeviceSlice,
    EDFSlotPolicy,
    O2Runtime,
    O2ServiceConfig,
    Scheduler,
    ServingTopology,
    SLOConfig,
    SLOTracker,
    SlotPolicy,
    StaticSlotPolicy,
    summarize_episode,
    TuneRequest,
    TuningService,
    _SlotPool,
)
from repro.launch.serving.o2_runtime import (  # noqa: F401
    _PendingAssess,
    _TenantO2,
    _pooled_best,
)
from repro.launch.serving.programs import (  # noqa: F401
    _admit_key_chain,
    _admit_scatter_program,
    _batched_admit_keys,
    _build_carry_program,
    _capture_write,
    _extract_episode_program,
    _mesh_for,
    _pow2_ladder,
    _reset_program,
    _resize_program,
    _step_program,
)
from repro.launch.serving.service import main  # noqa: F401

if __name__ == "__main__":
    main()
