"""Batched tuning-as-a-service: slot-based continuous batching for the
online tuning stage (multi-tenant `LITune.tune`).

`launch/serve.py` serves LM decode with fixed slots and per-request
completion; this driver applies the same shape to tuning requests.  Many
concurrent requests — heterogeneous `(data_keys, workload, wr_ratio,
budget_steps)` across both `alex` and `carmi` spaces — fill fixed slots in
per-space pools; one jitted multi-step program advances all active
episodes of a pool at once; a request that exhausts its budget (or
ET-MDP-terminates) frees its slot mid-flight for the next queued request.

CPU demo:
    PYTHONPATH=src python -m repro.launch.tune_serve --requests 8 --slots 4
Multi-core (slots shard over forced host devices):
    XLA_FLAGS=--xla_force_host_platform_device_count=2 \
        PYTHONPATH=src python -m repro.launch.tune_serve

Key properties:
  * **parity** — every slot computes the *same traced per-step program*
    as the serial `rollout_episode` (`lax.map` over slots, `lax.scan`
    over steps of the whole map body), so per-request rewards/runtimes
    are bitwise identical to a one-at-a-time `LITune.tune` with the same
    PRNG key (tests/test_tune_service.py).
  * **no recompiles on mixed streams** — compiled executables are cached
    by `(index_type, array shapes, batch shape, scan length)`; an alex
    request arriving after a carmi wave reuses the alex program.
  * **host-side budgets** — `budget_steps` is enforced by the serving
    loop, not baked into the program: each tick scans
    K = largest power of two ≤ the smallest remaining budget among active
    slots, so heterogeneous budgets share a small ladder of executables.
  * **slot sharding** — when the host platform exposes multiple devices
    (cores) and they divide the slot count, slots shard across them via
    `shard_map`; sharding never changes per-slot math, so parity holds.
  * **continuous tuning (O2)** — with `O2ServiceConfig(enabled=True)` the
    service stops serving a frozen agent: retired episodes stream their
    transitions into a per-tenant replay, an offline DDPG learner
    fine-tunes between ticks, and a divergence monitor (KS on key
    quantiles + W/R drift, observed at admission) triggers assessments
    that hot-swap pool params when the offline model wins.  The swap is a
    pure buffer update — params are program *inputs*, so the K-ladder
    compiled-program cache never re-traces.  A single-tenant strict-order
    stream makes the same swap decisions as
    `core.o2.O2System.tune_window` at any budget
    (tests/test_o2_service.py).
  * **near-zero O2 serving tax** — the three O2 phases stay off the
    serving loop's critical path: (1) transition capture is
    device-resident — each tick appends its transition view into per-slot
    capture buffers and retirement moves the episode into a
    `DeviceSequenceReplay` ring without the wide fields ever crossing to
    the host, so an O2 tick fetches exactly the five narrow fields the
    frozen service fetches; (2) offline fine-tuning is one scanned,
    state-donating program dispatched asynchronously after a retiring
    tick, with backpressure — a round is skipped (and counted) while the
    previous round is still executing, so the learner trails the server
    instead of stalling it; (3) divergence-triggered assessments run as
    pooled episodes through the *same* cached K-ladder step programs
    (zero-noise inputs, full slot width), and their verdicts are drained
    when ready — a tick later under load — rather than awaited.
    `strict_order` mode keeps the fully synchronous serial-equivalent
    interleaving for parity.
"""
from __future__ import annotations

import argparse
import dataclasses
import time
from collections import deque
from functools import lru_cache

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.core import networks as nets
from repro.runtime.mesh_utils import shard_map_compat
from repro.core.etmdp import batched_episode_scan, transition_view
from repro.core.litune import attach_best_params
from repro.core.o2 import (DivergenceMonitor, O2Config, copy_state,
                           make_replay, offline_finetune)
from repro.core.parallel import mapped_reset
from repro.core.replay import _pow2_pad, donate_argnums, wide_dim
from repro.index import env as E

# Buffer donation (the slot carry, capture buffers, learner state — the
# largest live trees, all rebound every tick) is gated off the CPU
# backend via `repro.core.replay.donate_argnums`: the CPU PJRT donation
# hand-off synchronizes with pending readers (~6-70 ms per dispatch,
# measured on jax 0.4.37) for no memory win.  The helper probes the
# backend lazily at program-build time, so importing this module never
# initializes jax before the operator's XLA_FLAGS are set.
# tests/test_o2_service.py asserts the donating programs stay
# re-trace-free either way.


@dataclasses.dataclass
class TuneRequest:
    """One tuning-as-a-service request (the unit of multi-tenancy)."""
    rid: int
    data_keys: jax.Array
    workload: dict                 # {"reads": [r], "inserts": [i]}
    wr_ratio: float
    budget_steps: int
    index_type: str = "alex"       # alex | carmi
    key: jax.Array | None = None   # episode/window PRNG key (parity handle)
    noise_scale: float = 0.05


@dataclasses.dataclass(frozen=True)
class O2ServiceConfig:
    """Continuous tuning inside the service (the O2 loop, per tenant)."""
    enabled: bool = False
    o2: O2Config = O2Config()
    # offline fine-tune steps dispatched after each tick that retires at
    # least one of the tenant's episodes (ticks with no fresh transitions
    # skip the learner: re-sampling an unchanged replay would add latency
    # to every tick of a long episode and desync the per-window update
    # count from the serial O2 loop).  None -> the O2Config's per-window
    # count, which makes a strict-order single-tenant stream
    # decision-identical to `O2System.tune_window` at any budget.  In
    # concurrent (non-strict) mode the count is a per-tick *cap*: a round
    # is skipped — and counted in `stats()["o2"][...]["finetune_skipped"]`
    # — while the previous round is still executing, so the learner
    # trails the server instead of serializing with it
    offline_updates_per_tick: int | None = None
    # one window in flight at a time, in submission order: trades the
    # service's cross-pool concurrency for the serial O2 loop's exact
    # observe->tune->assess interleaving (the parity mode LITune.stream
    # uses when routed through the service).  Strict mode also awaits
    # every assessment verdict inside its window's tick; concurrent mode
    # drains verdicts when their device work completes (at the latest in
    # `flush_o2`), so a hot-swap may land one or more ticks after the
    # window that earned it
    strict_order: bool = False
    replay_seed: int = 0


class _TenantO2:
    """Per-tenant continuous-tuning state: the divergence monitor, the
    device-resident replay ring the offline learner samples, and the
    offline DDPG state that hot-swaps into the tenant's pools on
    divergence + win.  The learner state and its update program live on
    the service's O2 annex device when the host provides one, so their
    execution never queues in front of the serving mesh's fetches; the
    ring stays on the serving side (its writers and sampling readers run
    in the post-fetch window when that queue is empty), with sampled
    batches hopped to the annex per round."""

    def __init__(self, tuner, svc_cfg: O2ServiceConfig, annex=None,
                 ring_device=None):
        self.cfg = svc_cfg.o2
        self.net_cfg = tuner.cfg.net_cfg()
        self.ddpg_cfg = tuner.cfg.ddpg
        self.et_cfg = tuner.cfg.et_cfg()
        self.env_cfg = tuner.cfg.env_cfg()
        self.annex = annex
        self.monitor = DivergenceMonitor(self.cfg)
        # the ring lives on the serving side (its writers and sampling
        # readers run there, right after the tick fetch when the queue is
        # empty); only the learner state and its update program live on
        # the annex, with sampled batches hopped across per round
        self.replay = make_replay(self.net_cfg, self.ddpg_cfg, self.env_cfg,
                                  seed=svc_cfg.replay_seed, device=True,
                                  place_on=ring_device)
        # real copies (not aliases): the scanned fine-tune program donates
        # its input state, so the tuner's pretrained tree and the online
        # model must own their buffers
        self.online = copy_state(tuner.state)
        self.offline = self._place(copy_state(tuner.state))
        # the assessment-facing snapshot: params of the latest *completed*
        # fine-tune round (concurrent mode never blocks on a pending one)
        self.ready_params = self._place(copy_state(tuner.state["params"]))
        self.offline_updates = 0
        self.finetune_skipped = 0
        self._inflight = None       # marker array of the pending round
        self._round_dirty = False   # a round completed but isn't published
        self.swaps = 0
        self.swap_times_s: list[float] = []

    def _place(self, tree):
        return tree if self.annex is None else jax.device_put(tree,
                                                              self.annex)

    def learner_free(self) -> bool:
        return self._inflight is None or bool(self._inflight.is_ready())

    def publish_ready(self):
        """Expose the latest completed round's params to assessments —
        bounded staleness, never a block on a pending round (the copy
        also shields them from the next round's donation off-CPU)."""
        if self._round_dirty and self.learner_free():
            self.ready_params = copy_state(self.offline["params"])
            self._round_dirty = False

    def finetune(self, n_updates: int, strict: bool):
        """Dispatch one offline fine-tune round.  Strict mode always runs
        it (serial-equivalent update counts); concurrent mode applies
        backpressure — if the previous round hasn't finished executing,
        the round is skipped and counted rather than queued behind."""
        if n_updates <= 0:
            return
        if not strict and not self.learner_free():
            self.finetune_skipped += n_updates
            return
        self.offline, done = offline_finetune(
            self.offline, self.replay, self.net_cfg, self.ddpg_cfg,
            n_updates, place_on=self.annex)
        self.offline_updates += done
        if done:
            self._inflight = self.offline["updates"]
            self._round_dirty = True


def summarize_episode(env_cfg: E.EnvConfig, r0: float, rewards, runtimes,
                      actions, costs, terminated: bool) -> dict:
    """Assemble the per-request summary in the exact `LITune.tune` shape
    (shared decode via `attach_best_params`)."""
    summary = {
        "episode_return": float(np.sum(rewards)),
        "best_runtime_ns": min(r0, float(np.min(runtimes))),
        "r0_ns": r0,
        "violations": float(np.sum(costs)),
        "terminated_early": terminated,
        "runtimes": [float(r) for r in runtimes],
        "actions": [np.asarray(a) for a in actions],
        "steps": len(runtimes),
    }
    summary["best_params"] = attach_best_params(summary, env_cfg)
    return summary


def _pow2_ladder(n: int) -> list[int]:
    out, k = [], 1
    while k <= n:
        out.append(k)
        k *= 2
    return out


def _admit_key_chain(window_key):
    """O2System.tune_window's PRNG discipline for one window key: the
    episode runs on the second split (k_on) and a diverged window's
    assessment on the second split of the remainder (k_off)."""
    remainder, k_on = jax.random.split(window_key)
    k_off = jax.random.split(remainder)[1]
    return k_on, k_off


# one dispatch derives a whole admission wave's episode + assessment keys
# (vmap over the integer threefry core is bitwise the per-key splits)
_batched_admit_keys = jax.jit(jax.vmap(_admit_key_chain))


def _pooled_best(r0: float, runtimes: np.ndarray) -> float:
    """Best runtime of one pooled assessment episode — min over the
    request's step prefix and the default-config runtime, exactly the
    ``best_runtime_ns`` `core.o2.assess_offline` reports for the same key
    (the hot-swap comparison's left-hand side, and the seam tests patch
    to force a verdict)."""
    return min(r0, float(np.min(runtimes)))


@dataclasses.dataclass
class _PendingAssess:
    """One dispatched pooled assessment awaiting its verdict: up to
    2*slots diverged windows of a single tenant, rolled out as one batch
    through the resident step programs.  Holds only device references —
    nothing crosses to the host until `ready()` (or a blocking drain).
    `params` is the exact tree the episodes ran under: a winning verdict
    promotes *those* params, not whatever the learner has advanced to by
    drain time."""
    index_type: str
    items: list          # [(req, summary, pend)] per occupied slot column
    r0: object           # [B] device: r_best at reset
    outs: list           # [(k, runtime_ns [k, B], early [k, B]) ...]
    params: object       # the judged param tree

    def ready(self) -> bool:
        return bool(self.outs[-1][1].is_ready())


# --------------------------------------------------------------- programs
# Process-wide program cache: builders are keyed on (device ids, frozen
# configs, shapes) so every TuningService instance — and every pool within
# one — shares the same jitted callables and their compiled executables.
# A per-service dict on top of this would recompile per instance, which is
# exactly the recompile-on-mixed-streams failure this engine exists to
# avoid.

def _mesh_for(device_ids: tuple) -> Mesh:
    by_id = {d.id: d for d in jax.devices()}
    return Mesh(np.array([by_id[i] for i in device_ids]), ("slots",))


@lru_cache(maxsize=None)
def _step_program(device_ids: tuple, net_cfg, env_cfg, et_cfg, k: int):
    """K-step slot program: scan over K ticks of the bitwise-stable
    one-tick map body, slots sharded over the mesh.  The carry is donated
    — every caller rebinds it to the program's output, and the donation
    lets XLA write the new carry into the old one's buffers instead of
    allocating a fresh slot-state tree per tick."""
    mesh = _mesh_for(device_ids)

    def core(p, c, n):
        return batched_episode_scan(p, c, n, k, net_cfg, env_cfg, et_cfg,
                                    False)

    return jax.jit(shard_map_compat(
        core, mesh, in_specs=(P(), P("slots"), P("slots")),
        out_specs=(P("slots"), P(None, "slots"))),
        donate_argnums=donate_argnums(1))


@lru_cache(maxsize=None)
def _reset_program(device_ids: tuple, env_cfg):
    """Batched admission: reset a wave of episodes in one (sharded when
    the wave divides the mesh) program."""
    mesh = _mesh_for(device_ids)

    def core(d, r, i, wr):
        return mapped_reset(env_cfg, d, {"reads": r, "inserts": i}, wr)

    return jax.jit(shard_map_compat(
        core, mesh,
        in_specs=(P("slots"), P("slots"), P("slots"), P("slots")),
        out_specs=P("slots")))


@lru_cache(maxsize=None)
def _admit_scatter_program(device_ids: tuple, net_cfg, slots: int):
    """Scatter freshly-reset episodes into their slots (padded entries
    target slot index B and are dropped)."""
    sharded = NamedSharding(_mesh_for(device_ids), P("slots"))

    def scatter(carry, idx, keys, env_states, obs):
        def upd(buf, x):
            return buf.at[idx].set(x, mode="drop")
        zero_h = nets.zero_hidden(net_cfg, (idx.shape[0],))
        return {
            "key": upd(carry["key"], keys),
            "env": jax.tree.map(upd, carry["env"], env_states),
            "obs": upd(carry["obs"], obs),
            "h_a": tuple(upd(c, z) for c, z in zip(carry["h_a"], zero_h)),
            "h_q": tuple(upd(c, z) for c, z in zip(carry["h_q"], zero_h)),
            "b_t": upd(carry["b_t"],
                       jnp.zeros((idx.shape[0],), jnp.float32)),
        }

    # the carry is rebound to the output on every admission — donate it
    return jax.jit(scatter, out_shardings=sharded,
                   donate_argnums=donate_argnums(0))


@lru_cache(maxsize=None)
def _build_carry_program(device_ids: tuple, net_cfg, slots: int):
    """Initial-wave fast path: construct the whole B-slot carry from a
    full batch of resets (no scatter)."""
    sharded = NamedSharding(_mesh_for(device_ids), P("slots"))

    def build(keys, env_states, obs):
        return {
            "key": keys,
            "env": env_states,
            "obs": obs,
            "h_a": nets.zero_hidden(net_cfg, (slots,)),
            "h_q": nets.zero_hidden(net_cfg, (slots,)),
            "b_t": jnp.zeros((slots,), jnp.float32),
        }

    return jax.jit(build, out_shardings=sharded)


def _extract_episode_core(cap, slot, src_idx):
    """One retired slot's capture rows, compacted to the episode's padded
    length: the small packed `[Tp, wide]` array the ring ingests (pure
    gather — indices are inputs)."""
    return cap[slot][src_idx]


@lru_cache(maxsize=None)
def _extract_episode_program(device_ids: tuple):
    """Replicated-output extract: every serving device holds the episode
    rows, so the ring's single-device `_place` resolves to a local copy
    instead of a cross-device reshard the next gather would wait on."""
    sharding = NamedSharding(_mesh_for(device_ids), P())
    return jax.jit(_extract_episode_core, out_shardings=sharding)


def _capture_write_core(cap, new, offsets):
    """Append one tick's transition view into the `[B, H, wide]` packed
    capture buffer at each slot's episode offset.  The six wide fields
    pack into one feature axis inside the program (`WIDE_FIELDS` order),
    so the whole capture path moves one operand per program.  Pure data
    movement (offsets are array inputs): compiles once per (K, shape)
    pair and never re-traces on admissions or swaps."""
    packed = jnp.concatenate(
        [new[f] for f in ("obs", "next_obs", "h_a", "c_a", "h_q", "c_q")],
        axis=-1)                                # [K, B, wide]
    packed = jnp.moveaxis(packed, 0, 1)         # [B, K, wide]

    def one(b, n_, off):
        return jax.lax.dynamic_update_slice(b, n_, (off, 0))

    return jax.vmap(one)(cap, packed, offsets)


_capture_write = jax.jit(_capture_write_core, donate_argnums=donate_argnums(0))


class _SlotPool:
    """Fixed B-slot episode pool for one (index space, array-shape) group.

    Device state: a slot-batched episode carry (sharded over the mesh), a
    [B] per-slot noise vector, and — under O2 — per-slot `[B, H, ...]`
    transition capture buffers appended in place by each tick's program
    outputs.  Host state: which request occupies which slot, steps taken,
    and the per-step narrow records streamed back each tick.
    """

    def __init__(self, env_cfg: E.EnvConfig, net_cfg, et_cfg, params,
                 slots: int, mesh: Mesh, capture: bool = False):
        self.env_cfg = env_cfg
        self.net_cfg = net_cfg
        self.et_cfg = et_cfg
        self.slots = slots
        self.mesh = mesh
        self.capture = capture          # device-resident transitions (O2)
        self.replicated = NamedSharding(mesh, P())
        self.sharded = NamedSharding(mesh, P("slots"))
        self.params = jax.device_put(params, self.replicated)
        self.carry = None                       # batched pytree, lazy init
        self.cap = None                         # capture buffers, lazy
        self.noise = np.zeros((slots,), np.float32)
        self._noise_dev = None                  # placed copy, lazy
        self.requests: list[TuneRequest | None] = [None] * slots
        self.steps_taken = np.zeros((slots,), np.int64)
        self.records: list[dict | None] = [None] * slots
        self.r0: list[float] = [0.0] * slots

    @property
    def n_active(self) -> int:
        return sum(r is not None for r in self.requests)

    def free_slots(self):
        return [i for i, r in enumerate(self.requests) if r is None]

    def remaining(self):
        return [r.budget_steps - int(self.steps_taken[i])
                for i, r in enumerate(self.requests) if r is not None]

    def noise_dev(self):
        if self._noise_dev is None:
            self._noise_dev = jax.device_put(jnp.asarray(self.noise),
                                             self.sharded)
        return self._noise_dev

    def capture_tick(self, out: dict):
        """Append this tick's `[K, B, ...]` transition view into the
        capture buffers (on the serving mesh, next to their producer and
        their extract readers) at each slot's pre-tick episode offset.
        Called after the tick's narrow-field fetch — the serving queue is
        drained then, so the donated in-place append costs its own
        microseconds, not a wait — and before `collect` advances
        `steps_taken`."""
        if self.cap is None:
            self.cap = jax.device_put(
                jnp.zeros((self.slots, self.env_cfg.episode_len,
                           wide_dim(self.net_cfg.obs_dim,
                                    self.net_cfg.lstm_hidden)),
                          jnp.float32), self.sharded)
        self.cap = _capture_write(self.cap, transition_view(out),
                                  self.steps_taken.astype(np.int32))

    def mark_admitted(self, slot: int, req: TuneRequest, r0: float):
        self.noise[slot] = req.noise_scale
        self._noise_dev = None
        self.requests[slot] = req
        self.steps_taken[slot] = 0
        self.r0[slot] = r0
        self.records[slot] = {"rewards": [], "runtimes": [], "actions": [],
                              "costs": []}

    def collect(self, slot: int, out_host: dict, step: int,
                early: bool = False) -> bool:
        """Record one step for `slot`; returns whether the episode is done
        (early exit or budget exhausted).  `done` is computed host-side
        against the request budget — the program's own horizon flag tracks
        the pool's horizon_cap, not the per-request episode length."""
        rec = self.records[slot]
        rec["rewards"].append(float(out_host["reward"][step, slot]))
        rec["runtimes"].append(float(out_host["runtime_ns"][step, slot]))
        rec["actions"].append(np.asarray(out_host["action"][step, slot]))
        rec["costs"].append(float(out_host["cost"][step, slot]))
        self.steps_taken[slot] += 1
        return early or \
            self.steps_taken[slot] >= self.requests[slot].budget_steps

    def retire(self, slot: int,
               terminated: bool) -> tuple[TuneRequest, dict, dict | None]:
        """Free the slot; returns the request, its summary, and — under
        capture — the episode's narrow fields (`[T]` host arrays) for ring
        ingestion alongside the slot's device capture rows.  The wide
        fields never left the device: they ride `self.cap`."""
        req, rec = self.requests[slot], self.records[slot]
        summary = summarize_episode(
            self.env_cfg, self.r0[slot], rec["rewards"], rec["runtimes"],
            rec["actions"], rec["costs"], terminated)
        narrow = None
        if self.capture:
            T = len(rec["rewards"])
            done = np.zeros((T,), np.float32)
            done[-1] = 1.0      # retire only happens at the done step
            narrow = {
                "action": np.stack(rec["actions"]).astype(np.float32),
                "reward": np.asarray(rec["rewards"], np.float32),
                "done": done,
                "cost": np.asarray(rec["costs"], np.float32),
            }
        self.requests[slot] = None
        self.records[slot] = None
        return req, summary, narrow


class TuningService:
    """Multi-tenant tuning engine over pretrained LITune agents.

    `agents` maps index_type -> a `core.litune.LITune` (or anything with
    `.cfg` and `.state`); a single LITune is accepted and keyed by its own
    `cfg.index_type`.  Submit requests, then `run()` — per-request
    summaries come back keyed by request id.
    """

    def __init__(self, agents, slots: int = 4, horizon_cap: int = 256,
                 seed: int = 0, o2: O2ServiceConfig | None = None):
        if not isinstance(agents, dict):
            agents = {agents.cfg.index_type: agents}
        self.agents = agents
        self.slots = slots
        self.horizon_cap = horizon_cap
        self.o2 = o2 if o2 is not None else O2ServiceConfig()
        self.key = jax.random.PRNGKey(seed)
        devices = jax.devices()
        # largest device subset whose count divides the slots, so e.g.
        # slots=4 on a 16-device host shards over 4 devices, and slots=2
        # on a 3-device host still shards over 2 (the old gcd rule
        # collapsed that to 1)
        nserve = max(d for d in range(1, len(devices) + 1)
                     if slots % d == 0)
        self.mesh = Mesh(np.array(devices[:nserve]), ("slots",))
        # O2 annex: the first device beyond the serving mesh, when the
        # host offers one — the stand-in for the learner executor a
        # production deployment provisions beside the serving pod.  The
        # learner state, replay ring, and assessment episodes all run
        # there, so their device work never queues in front of the
        # serving mesh's fetches.  With no spare device they share
        # device 0 (correct, just without the overlap).
        self.annex = None
        if self.o2.enabled:
            self.annex = (devices[nserve] if len(devices) > nserve
                          else devices[0])
        self.tenants: dict[str, _TenantO2] = {}
        if self.o2.enabled:
            for it, tuner in agents.items():
                self.tenants[it] = _TenantO2(
                    tuner, self.o2, annex=self.annex,
                    ring_device=self.mesh.devices.flat[0])
        self._o2_pending: dict[int, dict] = {}  # rid -> admission verdict
        self._assess_backlog: list[tuple] = []  # (pk, req, summary, pend)
        self._assess_inflight: deque[_PendingAssess] = deque()
        self._assess_noise: dict[int, jax.Array] = {}  # width -> zeros
        self.o2_pending_missing = 0     # retired without admission verdict
        self.assessments = 0            # pooled assessment episodes judged
        self._phase_ms = {"capture": 0.0, "finetune": 0.0, "assess": 0.0}
        self.queue: deque[TuneRequest] = deque()
        self.results: dict[int, dict] = {}
        self.pools: dict[tuple, _SlotPool] = {}
        self._programs: dict[tuple, object] = {}   # compiled-program cache
        self.program_misses = 0
        self.program_hits = 0
        self.service_steps = 0
        self.episode_steps = 0
        self._next_rid = 0

    # ------------------------------------------------------------ intake
    def submit(self, data_keys, workload, wr_ratio: float,
               budget_steps: int | None = None, index_type: str | None = None,
               noise_scale: float | None = None,
               deterministic: bool = False, key=None) -> int:
        """Enqueue one tuning request; returns its request id."""
        if index_type is None:
            index_type = next(iter(self.agents))
        if index_type not in self.agents:
            raise KeyError(f"no agent for index_type={index_type!r} "
                           f"(have {sorted(self.agents)})")
        tuner = self.agents[index_type]
        if budget_steps is None:
            budget_steps = tuner.cfg.episode_len
        if budget_steps > self.horizon_cap:
            raise ValueError(f"budget_steps={budget_steps} exceeds "
                             f"horizon_cap={self.horizon_cap}")
        if budget_steps < 1:
            raise ValueError(f"budget_steps={budget_steps} must be >= 1")
        # `deterministic` is served as noise_scale=0.0 through the shared
        # stochastic program (a per-request static branch would split the
        # pool's executable): for the tanh-bounded actor, a + 0*noise
        # clipped to [-1,1] equals the deterministic branch's raw output,
        # so recommendations match LITune.tune(deterministic=True)
        if noise_scale is None:
            noise_scale = 0.0 if deterministic else 0.05
        if key is None:
            self.key, key = jax.random.split(self.key)
        # under O2 the submitted key is the *window* key: admission
        # batch-splits it into the episode key (k_on) and the assessment
        # remainder, mirroring O2System.tune_window's PRNG discipline so
        # decisions line up with the serial O2 loop
        rid = self._next_rid
        self._next_rid += 1
        # numpy (uncommitted) on purpose: admission programs place these
        # per the pool's mesh; committed jax arrays would pin device 0
        self.queue.append(TuneRequest(
            rid=rid, data_keys=np.asarray(data_keys),
            workload={"reads": np.asarray(workload["reads"]),
                      "inserts": np.asarray(workload["inserts"])},
            wr_ratio=float(wr_ratio), budget_steps=int(budget_steps),
            index_type=index_type, key=key,
            noise_scale=float(noise_scale)))
        return rid

    # ------------------------------------------------------------ pools
    def _pool_key(self, req: TuneRequest) -> tuple:
        return (req.index_type, int(req.data_keys.shape[0]),
                int(req.workload["reads"].shape[0]),
                int(req.workload["inserts"].shape[0]))

    def _pool_for(self, req: TuneRequest) -> _SlotPool:
        pk = self._pool_key(req)
        if pk not in self.pools:
            tuner = self.agents[req.index_type]
            env_cfg = tuner.cfg.env_cfg().with_episode_len(self.horizon_cap)
            # under O2, pools serve the tenant's (possibly already swapped)
            # online model rather than the agent's frozen pretrained state
            params = (self.tenants[req.index_type].online["params"]
                      if self.o2.enabled else tuner.state["params"])
            self.pools[pk] = _SlotPool(env_cfg, tuner.cfg.net_cfg(),
                                       tuner.cfg.et_cfg(), params,
                                       self.slots, self.mesh,
                                       capture=self.o2.enabled)
        return self.pools[pk]

    # --------------------------------------------------------- programs
    @property
    def _device_ids(self) -> tuple:
        return tuple(d.id for d in self.mesh.devices.flat)

    @property
    def _annex_ids(self) -> tuple:
        """Single-device mesh ids for annex-side programs (assessments);
        identical to the serving ids on one-device hosts, so the program
        cache is shared there."""
        return ((self.annex.id,) if self.annex is not None
                else self._device_ids[:1])

    def _pool_step_program(self, pk: tuple, pool: _SlotPool, k: int):
        """K-step slot program, cached process-wide on
        (devices, frozen configs, K) so mixed alex/carmi request streams —
        and successive service instances — alternate between resident
        executables, never re-tracing."""
        prog_key = ("step", pk, self.slots, k)
        if prog_key not in self._programs:
            self.program_misses += 1
            self._programs[prog_key] = _step_program(
                self._device_ids, pool.net_cfg, pool.env_cfg, pool.et_cfg,
                k)
        else:
            self.program_hits += 1
        return self._programs[prog_key]

    def _pool_reset_program(self, pool: _SlotPool, width: int):
        ids = self._device_ids
        if width % len(ids) != 0:
            ids = ids[:1]               # narrow wave: single-device mesh
        return _reset_program(ids, pool.env_cfg)

    # ------------------------------------------------------------ serving
    def _admit(self, pk: tuple, pool: _SlotPool, admits: list[TuneRequest]):
        """Admit up to `len(free slots)` requests into `pool` with one
        batched reset (padded to a power-of-two width)."""
        free = pool.free_slots()
        assert len(admits) <= len(free)
        m = len(admits)
        widths = sorted(set(_pow2_ladder(self.slots) + [self.slots]))
        width = next(w for w in widths if w >= m)
        pad = width - m
        reqs = admits + [admits[0]] * pad
        data = np.stack([r.data_keys for r in reqs])
        reads = np.stack([r.workload["reads"] for r in reqs])
        ins = np.stack([r.workload["inserts"] for r in reqs])
        wr = np.asarray([r.wr_ratio for r in reqs], np.float32)
        keys = np.stack([np.asarray(r.key) for r in reqs])
        assess_keys = None
        if self.o2.enabled:
            # one batched split per wave: window key -> (episode key,
            # assessment key), the same bits as the serial loop's
            # per-window jax.random.split chain
            k_on, k_off = _batched_admit_keys(keys)
            keys = np.asarray(k_on)
            assess_keys = np.asarray(k_off)
        env_states, obs = self._pool_reset_program(pool, width)(
            data, reads, ins, wr)
        ndev = len(self._device_ids)
        if ndev > 1 and width % ndev != 0:
            # narrow reset ran on a single-device mesh; rehome to host so
            # the scatter (placed on the pool mesh) accepts it
            env_states, obs = jax.device_get((env_states, obs))

        if m == self.slots and pool.carry is None:
            pool.carry = _build_carry_program(
                self._device_ids, pool.net_cfg, self.slots)(
                keys, env_states, obs)
            slots_used = list(range(self.slots))
        else:
            if pool.carry is None:
                # first admission with a partial wave: seed every slot with
                # episode 0 so idle slots hold valid (ignored) state
                es0, obs0 = jax.device_get(
                    (jax.tree.map(lambda x: x[:1], env_states), obs[:1]))
                full = jax.tree.map(
                    lambda x: np.broadcast_to(x, (self.slots,)
                                              + x.shape[1:]),
                    (es0, obs0))
                pool.carry = _build_carry_program(
                    self._device_ids, pool.net_cfg, self.slots)(
                    np.broadcast_to(keys[:1], (self.slots,)
                                    + keys.shape[1:]), full[0], full[1])
            slots_used = free[:m]
            idx = np.asarray(slots_used + [self.slots] * pad, np.int32)
            pool.carry = _admit_scatter_program(
                self._device_ids, pool.net_cfg, self.slots)(
                pool.carry, idx, keys, env_states, obs)
        r0s = np.asarray(jax.device_get(env_states["r_best"]))
        for j, (slot, req) in enumerate(zip(slots_used, admits)):
            pool.mark_admitted(slot, req, float(r0s[j]))
            if self.o2.enabled:
                # each admitted request is one window of the tenant's
                # stream: observe divergence now (against the reference
                # distribution), assess after the episode retires
                tenant = self.tenants[req.index_type]
                div = tenant.monitor.observe(req.data_keys, req.wr_ratio)
                self._o2_pending[req.rid] = {
                    "div": div, "window": tenant.monitor.windows_seen,
                    "assess_key": assess_keys[j]}

    def _admit_from_queue(self):
        """Fill free slots with queued requests (FIFO per pool group),
        one batched reset per pool per tick.  In strict-order O2 mode a
        single window is admitted at a time, in submission order."""
        if self.o2.enabled and self.o2.strict_order:
            if not self.queue or \
                    any(p.n_active for p in self.pools.values()):
                return
            req = self.queue.popleft()
            self._admit(self._pool_key(req), self._pool_for(req), [req])
            return
        per_pool: dict[tuple, list[TuneRequest]] = {}
        still_queued = deque()
        free_left: dict[tuple, int] = {}
        while self.queue:
            req = self.queue.popleft()
            pool = self._pool_for(req)
            pk = self._pool_key(req)
            if pk not in free_left:
                free_left[pk] = len(pool.free_slots())
            if free_left[pk] > 0:
                per_pool.setdefault(pk, []).append(req)
                free_left[pk] -= 1
            else:
                still_queued.append(req)
        self.queue = still_queued
        for pk, admits in per_pool.items():
            self._admit(pk, self.pools[pk], admits)

    def step(self) -> int:
        """One service tick: drain any ready assessment verdicts, admit
        queued requests, advance every active pool by a K-step jitted
        program, retire finished episodes (streaming their transitions
        into the tenant's device replay ring), then — under O2 — dispatch
        the offline learners and the retired windows' assessments.
        Returns the number of episode-steps of useful work."""
        if self.o2.enabled:
            self._drain_assessments()
        self._admit_from_queue()
        work = 0
        retired: list[tuple[TuneRequest, dict]] = []
        for pk, pool in self.pools.items():
            if pool.n_active == 0 or pool.carry is None:
                continue
            min_rem = min(pool.remaining())
            k = max(w for w in _pow2_ladder(self.horizon_cap)
                    if w <= max(min_rem, 1))
            program = self._pool_step_program(pk, pool, k)
            pool.carry, out = program(pool.params, pool.carry,
                                      pool.noise_dev())
            # only the narrow fields the serving loop reads cross to the
            # host — the same five the frozen service transfers
            fields = ["reward", "runtime_ns", "action", "cost", "early"]
            out_host = jax.device_get({f: out[f] for f in fields})
            if pool.capture:
                # wide fields stay on device: append them to the annex
                # capture buffers (the view is materialized now, so the
                # hop is a pure copy) before collect() advances offsets
                t0 = time.perf_counter()
                pool.capture_tick(out)
                self._phase_ms["capture"] += \
                    1e3 * (time.perf_counter() - t0)
            for slot, req in enumerate(pool.requests):
                if req is None:
                    continue
                for j in range(k):
                    early = bool(out_host["early"][j, slot])
                    done = pool.collect(slot, out_host, j, early)
                    work += 1
                    if done:
                        rreq, summary, narrow = pool.retire(slot, early)
                        self.results[rreq.rid] = summary
                        if self.o2.enabled and narrow is not None:
                            # extract the episode's capture rows (small
                            # gather on the serving mesh) into the ring —
                            # the wide fields never visit the host
                            t0 = time.perf_counter()
                            T = len(narrow["reward"])
                            src = np.minimum(
                                np.arange(_pow2_pad(T)), T - 1) \
                                .astype(np.int32)
                            values = _extract_episode_program(
                                self._device_ids)(
                                pool.cap, np.int32(slot), src)
                            self.tenants[rreq.index_type].replay \
                                .add_episode_values(values, T, **narrow)
                            self._phase_ms["capture"] += \
                                1e3 * (time.perf_counter() - t0)
                            retired.append((rreq, summary))
                        break
        if self.o2.enabled:
            self._o2_tick(retired)
        self.service_steps += 1
        self.episode_steps += work
        return work

    # --------------------------------------------------------------- O2
    def _o2_tick(self, retired: list):
        """The between-ticks half of the O2 loop.  Strict mode keeps the
        serial interleaving: fine-tune, assess against the fresh offline
        tail, await the verdict.  Concurrent mode inverts it for the
        annex's FIFO: assessments dispatch first (against the last
        *completed* round's published params, so they never chain behind
        a pending one), the fine-tune round queues after them, and
        verdicts land on a later tick's drain."""
        strict = self.o2.strict_order
        if strict:
            t0 = time.perf_counter()
            self._finetune_retired(retired, strict)
            self._phase_ms["finetune"] += 1e3 * (time.perf_counter() - t0)
        t0 = time.perf_counter()
        for req, summary in retired:
            tenant = self.tenants[req.index_type]
            pend = self._o2_pending.pop(req.rid, None)
            if pend is None:
                # admitted before O2 tracked this tenant (or replayed
                # after a config swap): skip the window verdict instead
                # of raising mid-tick, and count it
                self.o2_pending_missing += 1
                continue
            # annotate the request's result with its window verdict, in
            # the exact shape O2System.tune_window returns; `swapped`
            # flips in the drain if the assessment wins
            summary["divergence"] = pend["div"]
            summary["swapped"] = False
            if pend["div"]["diverged"] and \
                    pend["window"] % tenant.cfg.assess_every == 0:
                self._assess_backlog.append(
                    (self._pool_key(req), req, summary, pend))
        self._pump_assessments()
        self._phase_ms["assess"] += 1e3 * (time.perf_counter() - t0)
        if strict:
            # serial-equivalent interleaving: the verdict (and any swap)
            # lands before the next window is admitted
            self._drain_assessments(block=True)
        else:
            t0 = time.perf_counter()
            self._finetune_retired(retired, strict)
            self._phase_ms["finetune"] += 1e3 * (time.perf_counter() - t0)

    def _pump_assessments(self):
        """Move backlog windows into pooled assessment dispatches, widest
        chunks first, with at most two chunks in flight — the annex's
        admission control.  A saturated annex (many diverged windows,
        long budgets) grows the backlog instead of the device queue, and
        `flush_o2` settles whatever is left."""
        max_width = 2 * self.slots
        while self._assess_backlog and len(self._assess_inflight) < 2:
            pk = self._assess_backlog[0][0]
            chunk = [item for item in self._assess_backlog
                     if item[0] == pk][:max_width]
            for item in chunk:
                self._assess_backlog.remove(item)
            pool, tenant = self.pools[pk], self.tenants[pk[0]]
            if not self.o2.strict_order:
                tenant.publish_ready()
            self._assess_inflight.append(self._dispatch_assess(
                pk, pool, tenant, [item[1:] for item in chunk]))

    def _finetune_retired(self, retired: list, strict: bool):
        for index_type in {req.index_type for req, _ in retired}:
            n = (self.o2.offline_updates_per_tick
                 if self.o2.offline_updates_per_tick is not None
                 else self.tenants[index_type].cfg
                 .offline_updates_per_window)
            self.tenants[index_type].finetune(n, strict)

    def _assess_noise_dev(self, width: int):
        if width not in self._assess_noise:
            zeros = jnp.zeros((width,), jnp.float32)
            self._assess_noise[width] = (
                zeros if self.annex is None
                else jax.device_put(zeros, self.annex))
        return self._assess_noise[width]

    def _dispatch_assess(self, pk: tuple, pool: _SlotPool,
                         tenant: _TenantO2, chunk: list) -> "_PendingAssess":
        """Launch one pooled assessment on the O2 annex: up to B diverged
        windows of one tenant reset and roll out as a single batch
        through the K-ladder step-program cache (zero-noise inputs — the
        deterministic branch for the tanh-bounded actor), in place of
        len(chunk) serial `rollout_episode` calls.  Strict mode assesses
        the offline tail (serial semantics); concurrent mode the
        published ready params.  Nothing is fetched here; the verdict
        scalars cross to the host in `_drain_assessments` once the
        device work completes."""
        ids = self._annex_ids
        m = len(chunk)
        width = _pow2_pad(m)
        reqs = [item[0] for item in chunk]
        rpad = reqs + [reqs[0]] * (width - m)
        data = np.stack([r.data_keys for r in rpad])
        reads = np.stack([r.workload["reads"] for r in rpad])
        ins = np.stack([r.workload["inserts"] for r in rpad])
        wr = np.asarray([r.wr_ratio for r in rpad], np.float32)
        # the assessment keys were derived in the admission wave's
        # batched split (same bits as the serial loop's chain)
        k_offs = np.stack([item[2]["assess_key"] for item in chunk])
        keys = np.concatenate(
            [k_offs, np.broadcast_to(k_offs[:1], (width - m, 2))])
        env_states, obs = _reset_program(ids, pool.env_cfg)(
            data, reads, ins, wr)
        carry = _build_carry_program(ids, pool.net_cfg, width)(
            keys, env_states, obs)
        params = (tenant.offline["params"] if self.o2.strict_order
                  else tenant.ready_params)
        outs = []
        remaining = max(r.budget_steps for r in reqs)
        while remaining > 0:
            k = max(w for w in _pow2_ladder(self.horizon_cap)
                    if w <= remaining)
            program = _step_program(ids, pool.net_cfg, pool.env_cfg,
                                    pool.et_cfg, k)
            carry, out = program(params, carry,
                                 self._assess_noise_dev(width))
            outs.append((k, out["runtime_ns"], out["early"]))
            remaining -= k
        return _PendingAssess(pk[0], list(chunk), env_states["r_best"],
                              outs, params)

    def _drain_assessments(self, block: bool = False):
        """Judge every in-flight pooled assessment whose device work has
        completed (all of them when `block`), in dispatch order: fetch
        the per-slot runtime scalars, compare each window's offline best
        against its online summary, and hot-swap winners."""
        while self._assess_inflight:
            entry = self._assess_inflight[0]
            if not block and not entry.ready():
                break
            self._assess_inflight.popleft()
            t0 = time.perf_counter()
            r0s = np.asarray(jax.device_get(entry.r0))
            rts = np.concatenate(
                [np.asarray(jax.device_get(r)) for _, r, _ in entry.outs])
            earls = np.concatenate(
                [np.asarray(jax.device_get(e)) for _, _, e in entry.outs])
            for j, (req, summary, pend) in enumerate(entry.items):
                T = req.budget_steps
                hit = np.flatnonzero(earls[:T, j])
                stop = int(hit[0]) + 1 if hit.size else T
                best = _pooled_best(float(r0s[j]), rts[:stop, j])
                self.assessments += 1
                if best < summary["best_runtime_ns"]:
                    self._hot_swap(entry.index_type, req,
                                   window=pend["window"] - 1,
                                   params=entry.params)
                    summary["swapped"] = True
            self._phase_ms["assess"] += 1e3 * (time.perf_counter() - t0)

    def _hot_swap(self, index_type: str, req: TuneRequest,
                  window: int | None = None, params=None):
        """Promote the offline model to online: a pure buffer update on
        every pool of the tenant.  Params are program *inputs*, not traced
        constants, so the K-ladder compiled-program cache is untouched —
        no re-trace, no re-compile (asserted in tests/test_o2_service.py).
        `params` is the judged tree an assessment verdict promotes (the
        concurrent learner may have advanced past it by drain time);
        None — the strict/serial case and direct callers — promotes the
        offline tail.  `window` is the retired window whose data
        re-anchors the monitor (under concurrent serving it may not be
        the latest one observed)."""
        t0 = time.perf_counter()
        tenant = self.tenants[index_type]
        # real copies: the next fine-tune round donates the offline
        # tree's buffers, and the promoted online model must outlive that
        tenant.online = copy_state(tenant.offline)
        if params is not None:
            tenant.online["params"] = copy_state(params)
        for pk, pool in self.pools.items():
            if pk[0] == index_type:
                pool.params = jax.device_put(tenant.online["params"],
                                             pool.replicated)
        tenant.monitor.re_anchor(req.data_keys, req.wr_ratio,
                                 window=window)
        tenant.swaps += 1
        tenant.swap_times_s.append(time.perf_counter() - t0)

    def flush_o2(self):
        """Settle all in-flight O2 work: the assessment backlog drains
        through the annex, every verdict lands (hot-swaps applied), and
        the trailing offline learner catches up.  Blocks; callers that
        only need serving results never have to."""
        if not self.o2.enabled:
            return
        while self._assess_backlog or self._assess_inflight:
            self._pump_assessments()
            self._drain_assessments(block=True)
        for tenant in self.tenants.values():
            jax.block_until_ready(tenant.offline["params"])

    def run(self, max_service_steps: int | None = None) -> dict[int, dict]:
        """Serve until the queue and every slot drain; returns
        {rid: summary} for everything completed so far.  In concurrent O2
        mode, assessment verdicts that are still executing keep trailing:
        their `swapped` annotations land on `flush_o2` (serving
        throughput never waits for the annex).  Strict mode settled every
        verdict inside its window's tick already."""
        n = 0
        while self.queue or any(p.n_active for p in self.pools.values()):
            if max_service_steps is not None and n >= max_service_steps:
                break
            self.step()
            n += 1
        if self.o2.enabled:
            self._drain_assessments()
        return self.results

    def stats(self) -> dict:
        st = {
            "service_steps": self.service_steps,
            "episode_steps": self.episode_steps,
            "completed": len(self.results),
            "queued": len(self.queue),
            "pools": len(self.pools),
            "devices": len(self.mesh.devices),
            # per-service binds: first/repeat use of a program key here
            "program_misses": self.program_misses,
            "program_hits": self.program_hits,
            # actual process-wide compiled step programs (shared cache)
            "programs_resident": _step_program.cache_info().currsize,
        }
        if self.o2.enabled:
            st["o2"] = {
                it: {"windows": t.monitor.windows_seen,
                     "diverged": t.monitor.diverged_count,
                     "swaps": t.swaps,
                     "offline_updates": t.offline_updates,
                     "finetune_skipped": t.finetune_skipped,
                     "replay_size": t.replay.size,
                     "mean_swap_ms": (1e3 * float(np.mean(t.swap_times_s))
                                      if t.swap_times_s else 0.0)}
                for it, t in self.tenants.items()}
            # host-side time spent driving each O2 phase (dispatch +
            # verdict fetches — device execution overlaps serving)
            st["o2"]["phase_ms"] = {k: round(v, 3)
                                    for k, v in self._phase_ms.items()}
            st["o2"]["assessments"] = self.assessments
            st["o2"]["inflight_assessments"] = len(self._assess_inflight)
            st["o2"]["pending_missing"] = self.o2_pending_missing
        return st


# ---------------------------------------------------------------- driver
def main():
    from repro.core.litune import LITune, LITuneConfig
    from repro.index.workloads import sample_keys, wr_workload

    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--slots", type=int, default=4)
    ap.add_argument("--n-keys", type=int, default=2048)
    ap.add_argument("--budget", type=int, default=10)
    ap.add_argument("--index", default="alex", choices=["alex", "carmi"])
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()

    cfg = LITuneConfig(index_type=args.index, episode_len=args.budget,
                       lstm_hidden=32, mlp_hidden=64)
    tuner = LITune(cfg, seed=args.seed)
    service = TuningService(tuner, slots=args.slots, seed=args.seed)

    key = jax.random.PRNGKey(args.seed + 1)
    for i in range(args.requests):
        k = jax.random.fold_in(key, i)
        wr = [0.33, 1.0, 3.0][i % 3]
        data = sample_keys(k, args.n_keys, "mix")
        wl, _ = wr_workload(jax.random.fold_in(k, 1), data, wr,
                            total=args.n_keys, dist="mix")
        service.submit(data, wl, wr, budget_steps=args.budget)

    t0 = time.time()
    results = service.run()
    dt = time.time() - t0
    for rid in sorted(results):
        r = results[rid]
        print(f"req {rid}: default {r['r0_ns']:9.1f} ns/op  best "
              f"{r['best_runtime_ns']:9.1f}  steps {r['steps']:3d}  "
              f"violations {r['violations']:.0f}")
    st = service.stats()
    print(f"\n{len(results)} requests in {dt:.2f}s "
          f"({len(results) / max(dt, 1e-9):.2f} req/s)  "
          f"ticks={st['service_steps']}  devices={st['devices']}  "
          f"step programs bound={st['program_misses']} "
          f"reused={st['program_hits']} "
          f"resident={st['programs_resident']}")


if __name__ == "__main__":
    main()
