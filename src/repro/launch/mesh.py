"""Production mesh construction.

Defined as functions (never module-level constants) so importing this module
never touches jax device state.  The dry-run sets
XLA_FLAGS=--xla_force_host_platform_device_count=512 *before* importing jax;
smoke tests and benchmarks see the real (single) device.
"""
from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    """16x16 single pod (256 chips) or 2x16x16 multi-pod (512 chips)."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_mesh(shape: tuple[int, ...], axes: tuple[str, ...]):
    """Arbitrary mesh for elastic-scaling tests (e.g. (8, 8))."""
    return jax.make_mesh(shape, axes)


def make_host_mesh():
    """Single-device mesh for CPU smoke tests."""
    return jax.make_mesh((1, 1), ("data", "model"))
