"""End-to-end training driver: pjit train step + deterministic pipeline +
checkpoint manager + fault tolerance + optional int8 cross-pod gradient
compression and microbatch accumulation.

CPU demo (the (b) deliverable, ~100M params for a few hundred steps):
    PYTHONPATH=src python -m repro.launch.train --arch llama3_8b \
        --scale 100m --steps 200 --batch 8 --seq 512
Production meshes reuse exactly this driver with --mesh 16x16 / 2x16x16
under the dry-run device flag.
"""
from __future__ import annotations

import argparse
import dataclasses
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.checkpoint.manager import CheckpointManager
from repro.configs import get_config
from repro.data.pipeline import DataPipeline, PipelineConfig
from repro.models import model_zoo
from repro.models.module import abstract_params
from repro.optim.adamw import AdamWConfig, adamw_update, init_opt_state
from repro.optim import grad_compress as gc
from repro.optim.schedules import warmup_cosine
from repro.runtime.fault import FailureInjector


def scale_config(cfg, scale: str):
    """Reduced-size variants of an arch for CPU-scale end-to-end runs."""
    if scale == "full":
        return cfg
    sizes = {
        "100m": dict(n_layers=6, d_model=512, n_heads=8, n_kv_heads=4,
                     head_dim=64, d_ff=2048, vocab_size=32768),
        "10m": dict(n_layers=4, d_model=256, n_heads=4, n_kv_heads=2,
                    head_dim=64, d_ff=1024, vocab_size=8192),
        "tiny": dict(n_layers=2, d_model=64, n_heads=2, n_kv_heads=1,
                     head_dim=32, d_ff=128, vocab_size=512),
    }[scale]
    if cfg.n_experts:
        sizes.update(n_experts=min(cfg.n_experts, 8),
                     moe_d_ff=sizes["d_ff"] // 2)
    if cfg.d_ff == 0:
        sizes.update(d_ff=0)  # pure SSM
    if cfg.enc_dec:
        sizes.update(n_enc_layers=sizes["n_layers"], enc_seq=64)
    if cfg.n_frontend_tokens:
        sizes.update(n_frontend_tokens=16)
    return dataclasses.replace(cfg, **sizes, name=f"{cfg.name}_{scale}")


@dataclasses.dataclass
class TrainerConfig:
    arch: str = "llama3_8b"
    scale: str = "100m"
    steps: int = 200
    global_batch: int = 8
    seq_len: int = 512
    lr: float = 3e-4
    warmup: int = 20
    ckpt_dir: str = "/tmp/repro_ckpt"
    save_every: int = 50
    microbatch: int = 0          # 0 = no accumulation
    grad_compress: bool = False
    seed: int = 0
    log_every: int = 10


class Trainer:
    def __init__(self, tc: TrainerConfig, mesh=None,
                 injector: FailureInjector | None = None):
        self.tc = tc
        self.mesh = mesh
        self.injector = injector
        cfg = scale_config(get_config(tc.arch), tc.scale)
        self.cfg = cfg
        self.bundle = model_zoo.build(cfg, remat=True)
        self.opt_cfg = AdamWConfig(
            lr=warmup_cosine(tc.lr, tc.warmup, tc.steps))
        self.manager = CheckpointManager(tc.ckpt_dir,
                                         save_every=tc.save_every)
        self.pipe_cfg = PipelineConfig(
            vocab_size=cfg.vocab_size, seq_len=tc.seq_len,
            global_batch=tc.global_batch, seed=tc.seed)
        self.losses: list[float] = []
        self._build_state()
        self._build_step()

    # ------------------------------------------------------------------
    def _build_state(self):
        key = jax.random.PRNGKey(self.tc.seed)
        restored, manifest = self.manager.restore_latest(
            self._abstract_state())
        if restored is not None:
            self.state = restored
            self.step = int(manifest["extra"]["next_step"])
            self.pipe = DataPipeline.from_state(
                self.pipe_cfg, manifest["extra"]["pipeline"])
        else:
            params = self.bundle.init(key)
            state = {"params": params, "opt": init_opt_state(params)}
            if self.tc.grad_compress:
                state["err"] = gc.init_error_state(params)
            self.state = state
            self.step = 0
            self.pipe = DataPipeline(self.pipe_cfg)

    def _abstract_state(self):
        params = abstract_params(self.bundle.specs)
        state = {"params": params,
                 "opt": {"m": params, "v": params,
                         "step": jax.ShapeDtypeStruct((), jnp.int32)}}
        state["opt"] = jax.tree.map(
            lambda s: jax.ShapeDtypeStruct(s.shape, jnp.float32), state["opt"])
        state["opt"]["step"] = jax.ShapeDtypeStruct((), jnp.int32)
        if self.tc.grad_compress:
            state["err"] = jax.tree.map(
                lambda s: jax.ShapeDtypeStruct(s.shape, jnp.float32), params)
        return state

    # ------------------------------------------------------------------
    def _build_step(self):
        bundle, tc = self.bundle, self.tc
        mesh = self.mesh
        compress = tc.grad_compress and mesh is not None \
            and "pod" in getattr(mesh, "shape", {})

        def loss_fn(params, **batch):
            return bundle.loss_fn(params, **batch)

        if compress:
            sample = {"tokens": jnp.zeros((2, 2), jnp.int32),
                      "labels": jnp.zeros((2, 2), jnp.int32)}
            grad_fn = gc.make_pod_grad_fn(
                loss_fn, mesh,
                abstract_params(self.bundle.specs), sample)

        def train_step(state, batch):
            if compress:
                loss, grads, err = grad_fn(state["params"], state["err"],
                                           batch)
            elif tc.microbatch and tc.microbatch < tc.global_batch:
                nmb = tc.global_batch // tc.microbatch
                resh = lambda t: t.reshape(nmb, tc.microbatch, *t.shape[1:])
                mb = jax.tree.map(resh, batch)

                def acc_body(carry, mbatch):
                    lv, g = jax.value_and_grad(loss_fn)(state["params"],
                                                        **mbatch)
                    return (carry[0] + lv / nmb,
                            jax.tree.map(lambda a, b: a + b / nmb,
                                         carry[1], g)), None

                zero_g = jax.tree.map(
                    lambda p: jnp.zeros(p.shape, jnp.float32),
                    state["params"])
                from repro.models.module import trip_scope
                with trip_scope(nmb, "microbatch"):
                    (loss, grads), _ = jax.lax.scan(
                        acc_body, (jnp.float32(0.0), zero_g), mb)
                err = None
            else:
                loss, grads = jax.value_and_grad(loss_fn)(state["params"],
                                                          **batch)
                err = None
            params, opt, metrics = adamw_update(
                state["params"], grads, state["opt"], self.opt_cfg)
            new_state = {"params": params, "opt": opt}
            if compress:
                new_state["err"] = err
            elif "err" in state:
                new_state["err"] = state["err"]
            return new_state, loss, metrics

        self.train_step = jax.jit(train_step, donate_argnums=(0,))

    # ------------------------------------------------------------------
    def run_until(self, target_step: int):
        while self.step < target_step:
            if self.injector is not None:
                self.injector.check(self.step)
            batch = next(self.pipe)
            t0 = time.time()
            self.state, loss, metrics = self.train_step(self.state, batch)
            loss = float(loss)
            self.losses.append(loss)
            self.step += 1
            if self.step % self.tc.log_every == 0:
                print(f"step {self.step:5d} loss {loss:8.4f} "
                      f"gnorm {float(metrics['grad_norm']):7.3f} "
                      f"lr {float(metrics['lr']):.2e} "
                      f"{time.time() - t0:5.2f}s/step", flush=True)
            self.manager.maybe_save(
                self.step, self.state,
                extra={"next_step": self.step,
                       "pipeline": self.pipe.state_dict()})
        return self


def main():
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--arch", default="llama3_8b")
    ap.add_argument("--scale", default="100m",
                    choices=["tiny", "10m", "100m", "full"])
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=512)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--ckpt-dir", default="/tmp/repro_ckpt")
    ap.add_argument("--save-every", type=int, default=50)
    ap.add_argument("--microbatch", type=int, default=0)
    ap.add_argument("--grad-compress", action="store_true")
    args = ap.parse_args()
    tc = TrainerConfig(arch=args.arch, scale=args.scale, steps=args.steps,
                       global_batch=args.batch, seq_len=args.seq, lr=args.lr,
                       ckpt_dir=args.ckpt_dir, save_every=args.save_every,
                       microbatch=args.microbatch,
                       grad_compress=args.grad_compress)
    trainer = Trainer(tc)
    t0 = time.time()
    trainer.run_until(tc.steps)
    first = np.mean(trainer.losses[:10])
    last = np.mean(trainer.losses[-10:])
    print(f"done: {tc.steps} steps in {time.time()-t0:.0f}s; "
          f"loss {first:.3f} -> {last:.3f}")


if __name__ == "__main__":
    main()
