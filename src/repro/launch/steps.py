"""Jittable step functions (train / prefill / decode) + their sharding trees.

Shared between the real trainer/server and the multi-pod dry-run so the
artifact that gets rooflined is the artifact that runs.
"""
from __future__ import annotations

import dataclasses
from typing import Any

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.configs import ArchConfig, ShapeConfig
from repro.models import model_zoo
from repro.models.model_zoo import ModelBundle
from repro.optim.adamw import AdamWConfig, adamw_update, opt_state_specs
from repro.models.module import abstract_params, axes_tree
from repro.runtime import mesh_utils


@dataclasses.dataclass
class CellPlan:
    """Everything needed to lower one (arch x shape) cell on a mesh."""
    bundle: ModelBundle
    shape: ShapeConfig
    step_fn: Any                 # jittable callable
    in_sds: tuple                # ShapeDtypeStructs (with shardings attached)
    in_shardings: tuple
    out_shardings: Any
    donate_argnums: tuple
    rules: dict
    mesh: Any = None
    microbatches: int = 1


def _shardings_for(tree_sds, tree_axes, mesh, rules):
    return jax.tree.map(
        lambda sds, axes: mesh_utils.logical_to_sharding(
            axes, sds.shape, mesh, rules),
        tree_sds, tree_axes,
        is_leaf=lambda x: isinstance(x, tuple) and all(
            isinstance(e, (str, type(None))) for e in x))


def _attach(tree_sds, tree_shardings):
    return jax.tree.map(
        lambda s, sh: jax.ShapeDtypeStruct(s.shape, s.dtype, sharding=sh),
        tree_sds, tree_shardings)


def make_train_step(bundle: ModelBundle, opt_cfg: AdamWConfig,
                    microbatches: int = 1):
    """Train step with optional gradient accumulation: the batch splits
    into `microbatches` chunks scanned sequentially, dividing activation /
    remat-residual memory by the same factor (the standard fit lever for
    residual-stack-dominated cells -- §Perf)."""
    def train_step(params, opt_state, batch):
        if microbatches > 1:
            from repro.models.module import trip_scope
            resh = lambda t: t.reshape(
                (microbatches, t.shape[0] // microbatches) + t.shape[1:])
            mb = jax.tree.map(resh, batch)

            def acc(carry, mbatch):
                loss_a, grads_a = carry
                lv, g = jax.value_and_grad(bundle.loss_fn)(params,
                                                            **mbatch)
                return (loss_a + lv / microbatches,
                        jax.tree.map(lambda a, b: a + b / microbatches,
                                     grads_a, g)), None

            zero = jax.tree.map(
                lambda p: jnp.zeros(p.shape, jnp.float32), params)
            with trip_scope(microbatches, "microbatch"):
                (loss, grads), _ = jax.lax.scan(
                    acc, (jnp.float32(0.0), zero), mb)
        else:
            loss, grads = jax.value_and_grad(bundle.loss_fn)(params, **batch)
        params, opt_state, metrics = adamw_update(params, grads, opt_state,
                                                  opt_cfg)
        return params, opt_state, loss, metrics
    return train_step


def plan_cell(cfg: ArchConfig, shape: ShapeConfig, mesh,
              opt_cfg: AdamWConfig | None = None,
              rules_override: dict | None = None,
              remat: bool = True, fsdp: bool | None = None,
              microbatches: int = 1) -> CellPlan:
    """Build the jittable step + fully-sharded abstract inputs for a cell.

    fsdp=None (auto): train cells shard parameters/optimizer state over the
    data axes as well (ZeRO-3 style; GSPMD inserts per-layer all-gathers
    inside the scan) -- the production default at 100B+ scale, and the only
    way e.g. qwen3-235B fits 16GB/chip (EXPERIMENTS.md §Dry-run)."""
    bundle = model_zoo.build(cfg, remat=remat)
    tp = mesh_utils.axis_size(mesh, mesh_utils.MODEL_AXIS)
    rules = dict(rules_override or {})
    if fsdp is None:
        fsdp = shape.kind == "train"
    elif fsdp == "auto_size":
        # FSDP only when TP-only params+optimizer (~18 B/param: bf16 p +
        # f32 m,v + f32 grads) would not fit; for small models FSDP's
        # data-axis weight sharding makes GSPMD batch-replicate the mlp
        # wgrad dots (measured +46% memory term on gemma3 -- §Perf)
        fsdp = (shape.kind == "train"
                and bundle.n_params() * 18 / max(tp, 1) > 8 * 2**30)
    if fsdp and shape.kind == "train":
        rules.setdefault("embed", mesh_utils.DATA_AXES)
    if shape.kind == "decode":
        rules = {**model_zoo.decode_rules(cfg, tp), **rules}

    p_sds = abstract_params(bundle.specs)
    p_axes = axes_tree(bundle.specs)
    p_shard = _shardings_for(p_sds, p_axes, mesh, rules)
    in_sds_tree, in_axes = bundle.input_specs(shape)
    in_shard = _shardings_for(in_sds_tree, in_axes, mesh, rules)
    repl = NamedSharding(mesh, P())

    if shape.kind == "train":
        opt_cfg = opt_cfg or AdamWConfig()
        o_specs = opt_state_specs(bundle.specs)
        o_sds = abstract_params(o_specs)
        o_axes = axes_tree(o_specs)
        o_shard = _shardings_for(o_sds, o_axes, mesh, rules)
        step = make_train_step(bundle, opt_cfg, microbatches=microbatches)
        in_sds = (_attach(p_sds, p_shard), _attach(o_sds, o_shard),
                  _attach(in_sds_tree, in_shard))
        return CellPlan(
            bundle=bundle, shape=shape, step_fn=step, in_sds=in_sds,
            in_shardings=(p_shard, o_shard, in_shard),
            out_shardings=(p_shard, o_shard, repl,
                           {"grad_norm": repl, "lr": repl}),
            donate_argnums=(0, 1), rules=rules, mesh=mesh,
            microbatches=microbatches)

    if shape.kind == "prefill":
        def step(params, batch):
            return bundle.prefill_fn(params, **batch)
        # cache output shardings: derive from cache axes under decode rules
        d_rules = {**model_zoo.decode_rules(cfg, tp), **(rules_override or {})}
        from repro.models import encdec, transformer
        if cfg.enc_dec:
            c_sds, c_axes = encdec.encdec_cache_specs(
                cfg, shape.global_batch, shape.seq_len)
        else:
            c_sds, c_axes = transformer.cache_specs(
                cfg, shape.global_batch, shape.seq_len)
        c_shard = _shardings_for(c_sds, c_axes, mesh, d_rules)
        logits_shard = NamedSharding(mesh, mesh_utils.logical_to_spec(
            ("batch", None), (shape.global_batch, cfg.vocab_size), mesh, rules))
        in_sds = (_attach(p_sds, p_shard), _attach(in_sds_tree, in_shard))
        return CellPlan(
            bundle=bundle, shape=shape, step_fn=step, in_sds=in_sds,
            in_shardings=(p_shard, in_shard),
            out_shardings=(logits_shard, c_shard),
            donate_argnums=(), rules=rules, mesh=mesh)

    # decode
    def step(params, batch):
        return bundle.decode_fn(params, batch["token"], batch["cache"],
                                batch["pos"])
    logits_shard = NamedSharding(mesh, mesh_utils.logical_to_spec(
        ("batch", None), (shape.global_batch, cfg.vocab_size), mesh, rules))
    cache_shard = in_shard["cache"]
    in_sds = (_attach(p_sds, p_shard), _attach(in_sds_tree, in_shard))
    return CellPlan(
        bundle=bundle, shape=shape, step_fn=step, in_sds=in_sds,
        in_shardings=(p_shard, in_shard),
        out_shardings=(logits_shard, cache_shard),
        donate_argnums=(1,), rules=rules, mesh=mesh)


def analytic_memory(plan: CellPlan) -> dict:
    """Sharding-exact per-device bytes for params/opt/cache/inputs plus an
    activation estimate.  This is the TPU-relevant memory model; CPU-backend
    memory_analysis() over-reports (no donation aliasing on host)."""
    def tree_bytes(sds_tree, shard_tree):
        total = 0
        for sds, sh in zip(jax.tree.leaves(sds_tree),
                           jax.tree.leaves(
                               shard_tree,
                               is_leaf=lambda x: hasattr(x, "spec"))):
            n = 1
            for ax in sh.spec:
                if ax is None:
                    continue
                for a in (ax if isinstance(ax, tuple) else (ax,)):
                    n *= plan.mesh.shape.get(a, 1)
            total += sds.size * sds.dtype.itemsize // max(n, 1)
        return total

    cfg, shape = plan.bundle.cfg, plan.shape
    out = {}
    if shape.kind == "train":
        p_sds, o_sds, b_sds = plan.in_sds
        out["params"] = tree_bytes(p_sds, plan.in_shardings[0])
        out["opt_state"] = tree_bytes(o_sds, plan.in_shardings[1])
        out["grads"] = out["params"] * 2  # f32 grads of bf16 params
        dp = mesh_utils.axis_size(plan.mesh, mesh_utils.DATA_AXES)
        b_loc = shape.global_batch // dp // max(plan.microbatches, 1)
        # remat residual stack: per-block carry + one block's live set
        out["residuals"] = (cfg.n_layers // max(cfg.block_period(), 1)
                           * b_loc * shape.seq_len * cfg.d_model * 2)
        tp = mesh_utils.axis_size(plan.mesh, mesh_utils.MODEL_AXIS)
        h_loc = max(cfg.n_heads // tp, 1) if cfg.n_heads else 1
        qc = min(shape.seq_len, 2048)
        out["attn_transient"] = 3 * b_loc * h_loc * qc * \
            min(shape.seq_len, 2048) * 4
    else:
        p_sds, b_sds = plan.in_sds
        out["params"] = tree_bytes(p_sds, plan.in_shardings[0])
        if shape.kind == "decode":
            out["cache"] = tree_bytes(b_sds["cache"],
                                      plan.in_shardings[1]["cache"])
    out["inputs"] = tree_bytes(
        plan.in_sds[-1], plan.in_shardings[-1])
    out["total"] = sum(v for k, v in out.items())
    return out


def lower_cell(plan: CellPlan):
    jitted = jax.jit(plan.step_fn,
                     in_shardings=plan.in_shardings,
                     out_shardings=plan.out_shardings,
                     donate_argnums=plan.donate_argnums)
    # the ambient mesh makes with_sharding_constraint (mesh_utils.constrain)
    # active during tracing -- without it every internal sharding annotation
    # silently no-ops and GSPMD propagation is unconstrained.
    with plan.mesh:
        return jitted.lower(*plan.in_sds)
