import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

# --- everything below happens only after the device count is pinned ---
import argparse      # noqa: E402
import json          # noqa: E402
import time          # noqa: E402
import traceback     # noqa: E402

import jax           # noqa: E402

from repro.configs import ARCH_NAMES, SHAPES, cell_is_runnable, get_config  # noqa: E402
from repro.launch.mesh import make_production_mesh                          # noqa: E402
from repro.launch.steps import analytic_memory, lower_cell, plan_cell                        # noqa: E402
from repro.runtime import hlo_analysis as ha                                # noqa: E402
from repro.runtime.mesh_utils import DATA_AXES as mesh_utils_DATA_AXES       # noqa: E402

"""Multi-pod dry-run: .lower().compile() for every (arch x shape x mesh).

For each cell this proves (a) the sharding config is coherent (no
divisibility / resharding errors), (b) the program fits (memory_analysis),
and (c) extracts the roofline terms (flops / bytes / collective bytes) via
runtime/hlo_analysis.py.  See EXPERIMENTS.md §Dry-run and §Roofline.
"""


def run_cell(arch: str, shape_name: str, multi_pod: bool,
             rules_override: dict | None = None,
             collect_hlo: bool = False, opt: bool = False) -> dict:
    import dataclasses
    cfg = get_config(arch)
    shape = SHAPES[shape_name]
    record: dict = {
        "arch": arch, "shape": shape_name,
        "mesh": "2x16x16" if multi_pod else "16x16",
        "profile": "optimized" if opt else "baseline",
    }
    fused_scopes = ()
    if opt:
        # beyond-paper profile (EXPERIMENTS.md §Perf): explicit-a2a MoE,
        # sequence-parallel activations in train, kernel-projected flash
        if cfg.n_experts:
            cfg = dataclasses.replace(cfg, moe_impl="shard_map")
        if shape.kind == "decode" and not cfg.enc_dec:
            cfg = dataclasses.replace(cfg, kv_cache_dtype="int8")
            # shard weights over data too (ZeRO-inference): resident params
            # /dp at the cost of per-layer all-gathers (decode reads every
            # weight once per token anyway)
            rules_override = {**(rules_override or {}),
                              "embed": mesh_utils_DATA_AXES}
        # NOTE: a {"seq": "model"} Megatron-SP rule was tried and REFUTED:
        # GSPMD re-replicates the batch axis on the seq gather-back
        # (17GB/layer all-gathers); see EXPERIMENTS.md §Perf cell B.
        fused_scopes = ("flash_fusible",)
    ok, why = cell_is_runnable(cfg, shape)
    if not ok:
        record["status"] = "skipped"
        record["reason"] = why
        return record

    mesh = make_production_mesh(multi_pod=multi_pod)
    n_dev = mesh.size
    t0 = time.time()
    try:
        microbatches = 1
        if opt and shape.kind == "train" and not cfg.n_experts:
            # MoE-EP cells are excluded: splitting the batch shrinks the
            # all_to_all payloads into their padding floors (measured 4.3x
            # compute / 25x collective regression on qwen3 at mb=8); their
            # fit lever is the multi-pod mesh. See §Perf.
            # pick the smallest power-of-two microbatch count that brings the
            # analytic per-device residency under the 16 GiB HBM budget
            probe = plan_cell(cfg, shape, mesh, rules_override=rules_override,
                              fsdp="auto_size")
            need = analytic_memory(probe)
            fixed = need["params"] + need["opt_state"] + need["grads"] \
                + need["inputs"]
            act = need["total"] - fixed
            budget = 15 * 2**30
            while microbatches < 32 and \
                    fixed + act / microbatches > budget:
                microbatches *= 2
        record["microbatches"] = microbatches
        plan = plan_cell(cfg, shape, mesh, rules_override=rules_override,
                         fsdp="auto_size" if opt else None,
                         microbatches=microbatches)
        lowered = lower_cell(plan)
        record["lower_s"] = round(time.time() - t0, 2)
        t0 = time.time()
        compiled = lowered.compile()
        record["compile_s"] = round(time.time() - t0, 2)

        mem = compiled.memory_analysis()
        record["memory"] = {
            "argument_bytes": int(mem.argument_size_in_bytes),
            "output_bytes": int(mem.output_size_in_bytes),
            "temp_bytes": int(mem.temp_size_in_bytes),
            "alias_bytes": int(mem.alias_size_in_bytes),
        }
        record["analytic_memory"] = analytic_memory(plan)
        ca = ha.xla_cost_analysis(compiled)
        record["xla_cost_analysis"] = {
            "flops": float(ca.get("flops", 0.0)),
            "bytes_accessed": float(ca.get("bytes accessed", 0.0)),
        }

        analysis = ha.analyze(compiled.as_text(), n_devices=n_dev,
                              fused_scopes=fused_scopes)
        model_flops_dev = plan.bundle.model_flops(shape) / n_dev
        terms = ha.roofline(analysis, model_flops_dev)
        record["hlo_analysis"] = analysis.as_dict()
        record["roofline"] = {
            "compute_s": terms.compute_s,
            "memory_s": terms.memory_s,
            "collective_s": terms.collective_s,
            "collective_wire_s": terms.collective_wire_s,
            "dominant": terms.dominant,
            "model_flops_per_dev": model_flops_dev,
            "hlo_flops_per_dev": analysis.flops,
            "useful_ratio": terms.useful_ratio,
            "roofline_fraction": terms.roofline_fraction,
            "step_time_s": terms.step_time_s,
        }
        record["n_params"] = plan.bundle.n_params()
        record["n_active_params"] = plan.bundle.n_active_params()
        record["status"] = "ok"
        if collect_hlo:
            record["hlo_text"] = compiled.as_text()
    except Exception as e:  # a failing cell is a bug; record and surface it
        record["status"] = "error"
        record["error"] = f"{type(e).__name__}: {e}"
        record["traceback"] = traceback.format_exc()[-2000:]
    return record


def run_litune_cell(index_type: str, multi_pod: bool,
                    meta_batch: int = 512) -> dict:
    """The paper-technique dry-run cell: lower + compile LITune's
    mesh-parallel meta-training rollout (core/parallel.py) with the tuning
    instances sharded over the data axes of the production mesh."""
    import jax.numpy as jnp
    from jax.sharding import NamedSharding
    from repro.core.ddpg import DDPGConfig
    from repro.core.networks import NetConfig
    from repro.core import parallel as par
    from repro.core import ddpg as ddpg_mod
    from repro.index import env as E
    from repro.runtime import mesh_utils

    record = {"arch": f"litune_{index_type}", "shape": "meta_train",
              "mesh": "2x16x16" if multi_pod else "16x16",
              "profile": "paper-technique"}
    mesh = make_production_mesh(multi_pod=multi_pod)
    try:
        env_cfg = E.EnvConfig(index_type=index_type, episode_len=8)
        net_cfg = NetConfig(obs_dim=E.obs_dim(),
                            action_dim=env_cfg.space.dim)
        ddpg_cfg = DDPGConfig()
        sds, axes = par.litune_cell_inputs(env_cfg, net_cfg, meta_batch)
        shard = {k: NamedSharding(mesh, mesh_utils.logical_to_spec(
            axes[k], sds[k].shape, mesh)) for k in sds}
        agent = ddpg_mod.init_state(jax.numpy.array([0, 0], dtype="uint32")
                                    if False else jax.random.PRNGKey(0),
                                    net_cfg, ddpg_cfg)
        params_sds = jax.tree.map(
            lambda x: jax.ShapeDtypeStruct(x.shape, x.dtype),
            agent["params"])

        def rollout(params, data_keys, reads, inserts, wr, key):
            env_states, obs = par.batched_reset(
                env_cfg, data_keys, {"reads": reads, "inserts": inserts}, wr)
            env_states, obs, traj = par.parallel_rollout.__wrapped__(
                params, env_states, obs, key, env_cfg, net_cfg, ddpg_cfg, 8)
            return traj["reward"].sum(), traj["cost"].sum()

        t0 = time.time()
        with mesh:
            lowered = jax.jit(rollout).lower(
                params_sds,
                jax.ShapeDtypeStruct(sds["data_keys"].shape, jnp.float32,
                                     sharding=shard["data_keys"]),
                jax.ShapeDtypeStruct(sds["reads"].shape, jnp.float32,
                                     sharding=shard["reads"]),
                jax.ShapeDtypeStruct(sds["inserts"].shape, jnp.float32,
                                     sharding=shard["inserts"]),
                jax.ShapeDtypeStruct(sds["wr"].shape, jnp.float32,
                                     sharding=shard["wr"]),
                jax.ShapeDtypeStruct((2,), jnp.uint32))
            compiled = lowered.compile()
        record["compile_s"] = round(time.time() - t0, 2)
        analysis = ha.analyze(compiled.as_text(), n_devices=mesh.size)
        record["hlo_analysis"] = analysis.as_dict()
        mem = compiled.memory_analysis()
        record["memory"] = {"temp_bytes": int(mem.temp_size_in_bytes),
                            "argument_bytes": int(mem.argument_size_in_bytes)}
        record["status"] = "ok"
        record["meta_batch"] = meta_batch
    except Exception as e:
        record["status"] = "error"
        record["error"] = f"{type(e).__name__}: {e}"
        record["traceback"] = traceback.format_exc()[-2000:]
    return record


def fmt_row(r: dict) -> str:
    if r["status"] == "skipped":
        return (f"{r['arch']:22s} {r['shape']:12s} {r['mesh']:8s} SKIP "
                f"({r['reason'][:60]}...)")
    if r["status"] == "error":
        return (f"{r['arch']:22s} {r['shape']:12s} {r['mesh']:8s} ERROR "
                f"{r['error'][:90]}")
    rf = r["roofline"]
    mem_gb = r["analytic_memory"]["total"] / 2**30
    return (f"{r['arch']:22s} {r['shape']:12s} {r['mesh']:8s} ok "
            f"compile={r['compile_s']:7.1f}s mem/dev={mem_gb:6.2f}GiB "
            f"compute={rf['compute_s']:.3e}s memory={rf['memory_s']:.3e}s "
            f"coll={rf['collective_s']:.3e}s dom={rf['dominant']:10s} "
            f"useful={rf['useful_ratio']:.2f} roofline={rf['roofline_fraction']:.2f}")


def main():
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--arch", default=None, help="one arch (default: all)")
    ap.add_argument("--shape", default=None, help="one shape (default: all)")
    ap.add_argument("--multi-pod", action="store_true",
                    help="2x16x16 multi-pod mesh (default: 16x16 single pod)")
    ap.add_argument("--both-meshes", action="store_true")
    ap.add_argument("--out", default=None, help="append JSONL records here")
    ap.add_argument("--opt", action="store_true",
                    help="beyond-paper optimized profile (see §Perf)")
    ap.add_argument("--litune", action="store_true",
                    help="also lower the paper-technique meta-training cells")
    args = ap.parse_args()

    archs = [args.arch] if args.arch else ARCH_NAMES
    shapes = [args.shape] if args.shape else list(SHAPES)
    meshes = [False, True] if args.both_meshes else [args.multi_pod]

    records = []
    for multi_pod in meshes:
        for arch in archs:
            for shape in shapes:
                r = run_cell(arch, shape, multi_pod, opt=args.opt)
                records.append(r)
                print(fmt_row(r), flush=True)
                if args.out:
                    with open(args.out, "a") as f:
                        f.write(json.dumps(
                            {k: v for k, v in r.items() if k != "hlo_text"})
                            + "\n")
    if args.litune:
        for multi_pod in meshes:
            for index_type in ("alex", "carmi"):
                r = run_litune_cell(index_type, multi_pod)
                records.append(r)
                if r["status"] == "ok":
                    coll = r["hlo_analysis"]["collective_bytes"]
                    print(f"litune_{index_type:6s} meta_train "
                          f"{r['mesh']:8s} ok compile={r['compile_s']:.1f}s "
                          f"coll_bytes={coll:.2e}", flush=True)
                else:
                    print(f"litune_{index_type} meta_train {r['mesh']} "
                          f"ERROR {r['error'][:80]}", flush=True)
                if args.out:
                    with open(args.out, "a") as f:
                        f.write(json.dumps(r) + "\n")
    n_err = sum(r["status"] == "error" for r in records)
    print(f"\n{len(records)} cells: "
          f"{sum(r['status'] == 'ok' for r in records)} ok, "
          f"{sum(r['status'] == 'skipped' for r in records)} skipped, "
          f"{n_err} errors")
    return 1 if n_err else 0


if __name__ == "__main__":
    raise SystemExit(main())
