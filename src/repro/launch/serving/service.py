"""`TuningService`: the thin composition root of the serving stack.

Layering (each layer a sibling module with an explicit seam):

    topology.py    device placement: pool slices, annex slice, ring home
        |  which devices serve, learn, and assess
    scheduler.py   admission queue, deadlines, slot-scheduling policy
        |  which requests enter which pool, at what pool width
    pools.py       slot-batched episode execution (device carries)
        |  episodes advance K steps/tick, retire into summaries
    o2_runtime.py  continuous tuning: capture -> learner -> assessments
        |  hot-swaps pool params when the offline model wins
    slo.py         per-request latency tracking + breach accounting
    programs.py    process-wide compiled-program cache under everything

The service itself only orchestrates: one `step()` drains ready O2
verdicts, applies queued-deadline drops, resizes pools per the policy,
admits a wave, advances every active pool by one K-step program, retires
finished episodes, enforces running deadlines, and hands the retired set
to the O2 runtime.  All PR 2/3 parity guarantees (strict-order decisions,
bitwise replay/params, zero program-cache re-traces — now also across
pool resizes) are carried by the layers, not re-implemented here.
"""
from __future__ import annotations

import argparse
import dataclasses
import time
import warnings
from collections import deque

import jax
import numpy as np

from repro.launch.serving import programs
from repro.launch.serving.config import (LEGACY_KWARGS, ServeConfig,
                                         config_from_legacy)
from repro.launch.serving.o2_runtime import O2Runtime, O2ServiceConfig
from repro.launch.serving.pools import _SlotPool
from repro.launch.serving.programs import (_mixed_params_program,
                                           _pow2_ladder, _reset_program,
                                           _step_program)
from repro.launch.serving.scheduler import (Scheduler, SlotPolicy,
                                            StaticSlotPolicy, TuneRequest)
from repro.launch.serving.slo import SLOConfig, SLOTracker
from repro.launch.serving.stats import (PoolStats, SchedulerStats,
                                        ServiceStats)
from repro.launch.serving.topology import ServingTopology


class TuningService:
    """Multi-tenant tuning engine over pretrained LITune agents.

    `agents` maps index_type -> a `core.litune.LITune` (or anything with
    `.cfg` and `.state`); a single LITune is accepted and keyed by its own
    `cfg.index_type`.  Submit requests, then `run()` — per-request
    summaries come back keyed by request id.

    The serving posture — slot counts, O2, scheduling policy, SLOs,
    topology, and the hot-swap trust policy — is one frozen
    `ServeConfig` passed as `config=` (`serving/config.py`).  The
    pre-consolidation per-knob kwargs (`slots`, `horizon_cap`, `seed`,
    `o2`, `policy`, `slo`, `clock`, `topology`) still work through a
    thin adapter that builds the equivalent `ServeConfig` and emits a
    `DeprecationWarning`; mixing `config=` with legacy kwargs raises.
    `policy`/`clock`/`topology` keep None-means-default semantics
    (static policy, `time.perf_counter`, flat host layout over
    `jax.devices()`); pass
    `topology=ServingTopology.from_mesh(make_production_mesh(), slots)`
    and one service instance spans a pod — placement is a config field,
    not a rewrite.
    """

    def __init__(self, agents, slots: int | None = None,
                 horizon_cap: int | None = None, seed: int | None = None,
                 o2: O2ServiceConfig | None = None,
                 policy: SlotPolicy | None = None,
                 slo: SLOConfig | None = None, clock=None,
                 topology: ServingTopology | None = None, swap=None, *,
                 config: ServeConfig | None = None):
        legacy = {"slots": slots, "horizon_cap": horizon_cap,
                  "seed": seed, "o2": o2, "policy": policy, "slo": slo,
                  "clock": clock, "topology": topology, "swap": swap}
        passed = {k: v for k, v in legacy.items() if v is not None}
        if config is not None:
            if passed:
                raise TypeError(
                    f"pass the serving posture either as "
                    f"config=ServeConfig(...) or through the legacy "
                    f"kwargs, not both (got config= plus "
                    f"{sorted(passed)})")
        else:
            if passed:
                warnings.warn(
                    f"TuningService's per-knob kwargs "
                    f"({', '.join(LEGACY_KWARGS)}) are deprecated; "
                    f"pass config=ServeConfig(...) instead",
                    DeprecationWarning, stacklevel=2)
            config = config_from_legacy(**passed)
        if not isinstance(agents, dict):
            agents = {agents.cfg.index_type: agents}
        self.agents = agents
        self.config = config
        self.slots = config.slots
        self.horizon_cap = config.horizon_cap
        self.o2 = config.o2
        self.policy = (config.policy if config.policy is not None
                       else StaticSlotPolicy())
        self.slo_cfg = config.slo
        self.swap_cfg = config.swap
        self.clock = (config.clock if config.clock is not None
                      else time.perf_counter)
        self.key = jax.random.PRNGKey(config.seed)
        # every placement decision — serving slices, annex slice, ring
        # home — is the topology layer's (topology.py); the service only
        # consumes slices
        self.topology = (config.topology if config.topology is not None
                         else ServingTopology.host(config.slots))
        self.topology.validate_slots(config.slots)
        self.pools: dict[tuple, _SlotPool] = {}
        self.o2rt: O2Runtime | None = None
        if self.o2.enabled:
            if self.topology.annex_shared:
                # single-device hosts (and annex_rows=0 carvings) run the
                # learner and assessments on a serving device: correct,
                # but the O2 work stops overlapping serving — say so once
                # instead of silently co-locating
                warnings.warn(
                    f"O2 annex shares device(s) "
                    f"{self.topology.annex.device_ids} with the serving "
                    f"slice: learner and assessment work will queue "
                    f"behind serving fetches (stats()['o2'] reports "
                    f"annex_shared)", RuntimeWarning, stacklevel=2)
            self.o2rt = O2Runtime(
                agents, self.o2, self.pools, self.topology,
                horizon_cap=self.horizon_cap,
                max_assess_width=2 * self.slots,
                swap_cfg=self.swap_cfg, clock=self.clock,
                health_cfg=config.health, kernel=config.kernel)
        self.scheduler = Scheduler(self.policy,
                                   strict_order=(self.o2.enabled
                                                 and self.o2.strict_order))
        self.slo = SLOTracker(self.clock)
        self.results: dict[int, dict] = {}
        self._programs: dict[tuple, object] = {}   # compiled-program cache
        self.program_misses = 0
        self.program_hits = 0
        self.service_steps = 0
        self.episode_steps = 0
        self._next_rid = 0

    # ------------------------------------------------- layer delegation
    @property
    def queue(self) -> deque:
        return self.scheduler.queue

    @queue.setter
    def queue(self, value):
        self.scheduler.queue = deque(value)

    @property
    def tenants(self):
        return self.o2rt.tenants if self.o2rt is not None else {}

    @property
    def _o2_pending(self):
        return self.o2rt.pending if self.o2rt is not None else {}

    @property
    def o2_pending_missing(self) -> int:
        return self.o2rt.pending_missing if self.o2rt is not None else 0

    @property
    def assessments(self) -> int:
        return self.o2rt.assessments if self.o2rt is not None else 0

    def _in_trial(self, index_type: str) -> bool:
        """Whether the tenant has a live swap trial (canary stage or
        post-promotion watch window)."""
        return self.o2rt is not None and index_type in self.o2rt.trials

    def _hot_swap(self, index_type: str, req: TuneRequest,
                  window: int | None = None, params=None):
        self.o2rt.hot_swap(index_type, req, window=window, params=params)

    def flush_o2(self, deadline_s: float | None = None) -> dict | None:
        """Settle all in-flight O2 work (see `O2Runtime.flush`); callers
        that only need serving results never have to.  Returns the flush
        report ({deadline_hit, abandoned_backlog, abandoned_inflight,
        elapsed_s}; None with O2 off).  `deadline_s` defaults to
        `HealthConfig.flush_deadline_s` (None -> settle fully — but a
        demoted annex or hung dispatch is abandoned rather than hung
        on, so the call is bounded either way)."""
        if self.o2rt is None:
            return None
        if deadline_s is None:
            deadline_s = self.config.health.flush_deadline_s
        return self.o2rt.flush(deadline_s=deadline_s)

    # ------------------------------------------------------------ intake
    def submit(self, data_keys, workload, wr_ratio: float,
               budget_steps: int | None = None, index_type: str | None = None,
               noise_scale: float | None = None,
               deterministic: bool = False, key=None,
               deadline_s: float | None = None,
               on_breach: str | None = None) -> int:
        """Enqueue one tuning request; returns its request id.

        `deadline_s` (service-clock seconds from now; default the
        service's `SLOConfig.default_deadline_s`) bounds the request's
        total latency; `on_breach` ("truncate" | "drop") picks what a
        mid-flight breach returns."""
        if index_type is None:
            index_type = next(iter(self.agents))
        if index_type not in self.agents:
            raise KeyError(f"no agent for index_type={index_type!r} "
                           f"(have {sorted(self.agents)})")
        tuner = self.agents[index_type]
        if budget_steps is None:
            budget_steps = tuner.cfg.episode_len
        if budget_steps > self.horizon_cap:
            raise ValueError(f"budget_steps={budget_steps} exceeds "
                             f"horizon_cap={self.horizon_cap}")
        if budget_steps < 1:
            raise ValueError(f"budget_steps={budget_steps} must be >= 1")
        # `deterministic` is served as noise_scale=0.0 through the shared
        # stochastic program (a per-request static branch would split the
        # pool's executable): for the tanh-bounded actor, a + 0*noise
        # clipped to [-1,1] equals the deterministic branch's raw output,
        # so recommendations match LITune.tune(deterministic=True)
        if noise_scale is None:
            noise_scale = 0.0 if deterministic else 0.05
        if deadline_s is None:
            deadline_s = self.slo_cfg.default_deadline_s
        if on_breach is None:
            on_breach = self.slo_cfg.on_breach
        if on_breach not in ("truncate", "drop"):
            raise ValueError(f"on_breach={on_breach!r} must be "
                             f"'truncate' or 'drop'")
        # the PRNG split comes after every validation path: a rejected
        # submission must not perturb later requests' auto-drawn keys
        if key is None:
            self.key, key = jax.random.split(self.key)
        # under O2 the submitted key is the *window* key: admission
        # batch-splits it into the episode key (k_on) and the assessment
        # remainder, mirroring O2System.tune_window's PRNG discipline so
        # decisions line up with the serial O2 loop
        rid = self._next_rid
        self._next_rid += 1
        # numpy (uncommitted) on purpose: admission programs place these
        # per the pool's mesh; committed jax arrays would pin device 0
        self.scheduler.submit(TuneRequest(
            rid=rid, data_keys=np.asarray(data_keys),
            workload={"reads": np.asarray(workload["reads"]),
                      "inserts": np.asarray(workload["inserts"])},
            wr_ratio=float(wr_ratio), budget_steps=int(budget_steps),
            index_type=index_type, key=key,
            noise_scale=float(noise_scale), deadline_s=deadline_s,
            on_breach=on_breach, submitted_at=self.clock()))
        return rid

    # ------------------------------------------------------------ pools
    def _pool_key(self, req: TuneRequest) -> tuple:
        return (req.index_type, int(req.data_keys.shape[0]),
                int(req.workload["reads"].shape[0]),
                int(req.workload["inserts"].shape[0]))

    def _pool_for(self, req: TuneRequest) -> _SlotPool:
        pk = self._pool_key(req)
        if pk not in self.pools:
            tuner = self.agents[req.index_type]
            env_cfg = tuner.cfg.env_cfg().with_episode_len(self.horizon_cap)
            # the service's kernel posture rides the pool's env config:
            # frozen dataclasses hash by value, so the default posture
            # keys the same resident programs as the serial path
            env_cfg = dataclasses.replace(env_cfg, kernel=self.config.kernel)
            # under O2, pools serve the tenant's (possibly already swapped)
            # online model rather than the agent's frozen pretrained state
            # (`online_params` — a cold fleet tenant serves its seed tree
            # without materializing a per-tenant copy)
            params = (self.tenants[req.index_type].online_params()
                      if self.o2.enabled else tuner.state["params"])
            # pools pin to the topology's carved slices round-robin by
            # creation order (one flat slice on hosts; one row per pool
            # on a carved production mesh)
            slice_ = self.topology.pool_slice(len(self.pools))
            self.pools[pk] = _SlotPool(env_cfg, tuner.cfg.net_cfg(),
                                       tuner.cfg.et_cfg(), params,
                                       self.slots, slice_,
                                       capture=self.o2.enabled)
            if self.o2.enabled and self.swap_cfg.canary:
                # pre-bind the canary-side programs with the pool: the
                # per-lane K ladder (same lru cache as the shared-params
                # ladder, so `programs_resident` is flat across a whole
                # canary->promote/rollback cycle) and the params mix.
                # Binding is an lru insert; XLA still traces lazily
                pool = self.pools[pk]
                for k in _pow2_ladder(self.horizon_cap):
                    self._pool_step_program(pk, pool, k, per_lane=True)
                _mixed_params_program(slice_, self.slots)
        return self.pools[pk]

    def _size_ladder(self, pool: _SlotPool) -> list[int]:
        """Pool widths the policy may choose from: the initial width plus
        slice-width multiples doubling up to the policy cap — every entry
        shards over the pool's topology slice, and the doubling keeps the
        set of traced carry shapes (and therefore resident executables)
        small."""
        nd = pool.slice.width
        cap = max(getattr(self.policy, "max_slots", self.slots),
                  self.slots)
        sizes = {self.slots}
        s = nd
        while s <= cap:
            sizes.add(s)
            s *= 2
        return sorted(s for s in sizes if s % nd == 0)

    # --------------------------------------------------------- programs
    def _fused(self, pool: _SlotPool) -> bool:
        """Whether this pool's serving tick runs the fused-tick step
        variant (scan + capture append in one program)."""
        return pool.capture and self.config.kernel.fused_tick

    @staticmethod
    def _step_key(pk: tuple, pool: _SlotPool, k: int,
                  per_lane: bool, capture: bool) -> tuple:
        return ("step-lanes" if per_lane else "step", pk, pool.slots, k,
                capture)

    def _pool_step_program(self, pk: tuple, pool: _SlotPool, k: int,
                           per_lane: bool = False):
        """K-step slot program, cached process-wide on
        (slice, frozen configs, width, K) so mixed alex/carmi request
        streams — and successive service instances, and pools returning
        to a previously-served width — alternate between resident
        executables, never re-tracing.  `per_lane` selects the canary
        variant (params carry a leading slot axis); the fused-capture
        variant is derived here from the pool + kernel posture so every
        caller (pre-binds and ticks) agrees on the key.  All variants
        share `_step_program`'s lru cache."""
        capture = self._fused(pool)
        prog_key = self._step_key(pk, pool, k, per_lane, capture)
        if prog_key not in self._programs:
            self.program_misses += 1
            self._programs[prog_key] = _step_program(
                pool.slice, pool.net_cfg, pool.env_cfg, pool.et_cfg, k,
                per_lane=per_lane, capture=capture)
        else:
            self.program_hits += 1
        return self._programs[prog_key]

    def _pool_reset_program(self, pool: _SlotPool, width: int):
        # a wave that does not divide the pool's slice lowers onto the
        # widest sub-slice it does divide (1-device at worst)
        return _reset_program(pool.slice.narrow(width), pool.env_cfg)

    # ------------------------------------------------------------ serving
    def _admit(self, pk: tuple, pool: _SlotPool, admits: list[TuneRequest]):
        """Admit up to `len(free slots)` requests into `pool` with one
        batched reset (padded to a power-of-two width)."""
        free = pool.free_slots()
        assert len(admits) <= len(free)
        m = len(admits)
        widths = sorted(set(_pow2_ladder(pool.slots) + [pool.slots]))
        width = next(w for w in widths if w >= m)
        pad = width - m
        reqs = admits + [admits[0]] * pad
        data = np.stack([r.data_keys for r in reqs])
        reads = np.stack([r.workload["reads"] for r in reqs])
        ins = np.stack([r.workload["inserts"] for r in reqs])
        wr = np.asarray([r.wr_ratio for r in reqs], np.float32)
        keys = np.stack([np.asarray(r.key) for r in reqs])
        assess_keys = None
        if self.o2rt is not None:
            keys, assess_keys = self.o2rt.admit_keys(keys)
        env_states, obs = self._pool_reset_program(pool, width)(
            data, reads, ins, wr)
        if width % pool.slice.width != 0:
            # narrow reset ran on a sub-slice mesh; rehome to host so the
            # scatter (placed on the pool's slice) accepts it
            env_states, obs = jax.device_get((env_states, obs))

        if m == pool.slots and pool.carry is None:
            pool.carry = programs._build_carry_program(
                pool.slice, pool.net_cfg, pool.slots)(
                keys, env_states, obs)
            slots_used = list(range(pool.slots))
        else:
            if pool.carry is None:
                # first admission with a partial wave: seed every slot with
                # episode 0 so idle slots hold valid (ignored) state
                es0, obs0 = jax.device_get(
                    (jax.tree.map(lambda x: x[:1], env_states), obs[:1]))
                full = jax.tree.map(
                    lambda x: np.broadcast_to(x, (pool.slots,)
                                              + x.shape[1:]),
                    (es0, obs0))
                pool.carry = programs._build_carry_program(
                    pool.slice, pool.net_cfg, pool.slots)(
                    np.broadcast_to(keys[:1], (pool.slots,)
                                    + keys.shape[1:]), full[0], full[1])
            slots_used = free[:m]
            idx = np.asarray(slots_used + [pool.slots] * pad, np.int32)
            pool.carry = programs._admit_scatter_program(
                pool.slice, pool.net_cfg, pool.slots)(
                pool.carry, idx, keys, env_states, obs)
        r0s = np.asarray(jax.device_get(env_states["r_best"]))
        now = self.clock()
        for j, (slot, req) in enumerate(zip(slots_used, admits)):
            pool.mark_admitted(slot, req, float(r0s[j]))
            self.slo.on_admit(req, now)
            if self.o2rt is not None:
                self.o2rt.observe_admission(req, assess_keys[j])

    def _admit_from_queue(self):
        """Fill free slots with queued requests (FIFO per pool group),
        one batched reset per pool per tick; the scheduler picks the
        admissions (and, in strict-order O2 mode, admits one window at a
        time in submission order)."""
        per_pool = self.scheduler.select(
            self.pools, self._pool_for, self._pool_key,
            any_active=any(p.n_active for p in self.pools.values()),
            now=self.clock())
        for pk, admits in per_pool.items():
            self._admit(pk, self.pools[pk], admits)

    def _drop_breached_queued(self):
        """Queued requests past their deadline never occupy a slot: they
        retire straight into a dropped result; under an `EDFSlotPolicy`,
        requests whose budget provably cannot fit their deadline at the
        measured tick rate are pre-dropped the same way (flagged
        `pre_dropped`), freeing their queue time for feasible work."""
        now = self.clock()
        for req in self.scheduler.drop_breached(now):
            self.results[req.rid] = {
                "dropped": True, "slo_breached": True, "steps": 0,
                "terminated_early": False}
            self.slo.on_drop_queued(req, now)
            if self._in_trial(req.index_type):
                self.slo.note_trial_breach()
        for req in self.scheduler.pre_drop_hopeless(now):
            self.results[req.rid] = {
                "dropped": True, "slo_breached": True, "pre_dropped": True,
                "steps": 0, "terminated_early": False}
            self.slo.on_drop_queued(req, now, pre=True)
            if self._in_trial(req.index_type):
                self.slo.note_trial_breach()

    def _apply_slot_policy(self):
        """Consult the slot policy for every pool (pools for queued
        requests are created first so a burst can grow its pool before
        the first admission) and apply planned resizes."""
        if isinstance(self.policy, StaticSlotPolicy) or \
                self.scheduler.strict_order:
            return
        for req in self.scheduler.queue:
            self._pool_for(req)
        queued = self.scheduler.queued_by_pool(self._pool_key)
        for pk, pool in self.pools.items():
            if pool.canary_lanes is not None:
                # a resize would re-map lanes mid-trial and shuffle the
                # canary/control arms; the pool resumes policy sizing
                # the tick after the trial promotes or rolls back
                continue
            new = self.scheduler.plan_resize(pk, pool, queued.get(pk, 0),
                                             self._size_ladder(pool))
            if new is not None:
                pool.resize(new)

    def _enforce_running_deadlines(self, retired: list):
        """Running requests past their deadline retire before the next
        tick advances them further: truncated (best-so-far summary,
        flagged) or dropped, per request — either way the slot frees for
        this tick's admissions.  Slots are independent lanes of the same
        mapped program, so the early retirement never perturbs the
        surviving slots' decisions."""
        now = self.clock()
        for pk, pool in self.pools.items():
            for slot, req in enumerate(pool.requests):
                if req is None or req.deadline_s is None:
                    continue
                if now - req.submitted_at <= req.deadline_s:
                    continue
                if pool.steps_taken[slot] == 0:
                    continue        # admitted this tick; gets one tick
                rreq, summary, narrow = pool.retire(slot, False)
                if self._in_trial(rreq.index_type):
                    # attribution for the swaps block: this breach landed
                    # while the tenant's canary/watch trial was live
                    self.slo.note_trial_breach()
                if rreq.on_breach == "drop":
                    self.results[rreq.rid] = {
                        "dropped": True, "slo_breached": True,
                        "steps": summary["steps"],
                        "terminated_early": False}
                    self.slo.on_breach_running(rreq, now, dropped=True)
                    if self.o2rt is not None:
                        # the window never produced a servable result:
                        # discard its admission verdict silently
                        self.o2rt.pending.pop(rreq.rid, None)
                else:
                    summary["slo_breached"] = True
                    summary["truncated"] = True
                    self.results[rreq.rid] = summary
                    self.slo.on_breach_running(rreq, now, dropped=False)
                    if self.o2rt is not None and narrow is not None:
                        self.o2rt.ingest_retired(pool, slot, rreq, narrow)
                        retired.append((rreq, summary))

    def step(self) -> int:
        """One service tick: drain any ready assessment verdicts, enforce
        deadlines (queued breaches drop, running breaches truncate or
        drop — freeing their slots for this tick), apply the slot policy,
        admit queued requests, advance every active pool by a K-step
        jitted program, retire finished episodes (streaming their
        transitions into the tenant's device replay ring), then — under
        O2 — dispatch the offline learners and the retired windows'
        assessments.  Returns the number of episode-steps of useful
        work."""
        if self.o2rt is not None:
            self.o2rt.drain()
        work = 0
        retired: list[tuple[TuneRequest, dict]] = []
        self._drop_breached_queued()
        self._enforce_running_deadlines(retired)
        self._apply_slot_policy()
        self._admit_from_queue()
        for pk, pool in self.pools.items():
            if pool.n_active == 0 or pool.carry is None:
                continue
            min_rem = min(pool.remaining())
            k = max(w for w in _pow2_ladder(self.horizon_cap)
                    if w <= max(min_rem, 1))
            t_tick = self.clock()
            # a live canary routes the tick through the per-lane program
            # variant with the pool's mixed params tree — same resident
            # program cache, zero re-traces (pre-bound at pool creation)
            canary = pool.lane_params is not None
            fused = self._fused(pool)
            # a first-use bind traces/compiles inside the timed window;
            # that sample would poison the EDF feasibility estimate, so
            # only warm ticks feed it
            warm = self._step_key(pk, pool, k, canary,
                                  fused) in self._programs
            program = self._pool_step_program(pk, pool, k,
                                              per_lane=canary)
            if fused:
                # fused tick: the capture append rides the step dispatch
                # (offsets are the pre-tick step counts; collect()
                # advances them after), so no second program runs below
                pool.carry, out, pool.cap = program(
                    pool.lane_params if canary else pool.params,
                    pool.carry, pool.noise_dev(), pool.ensure_cap(),
                    pool.steps_taken.astype(np.int32))
            else:
                pool.carry, out = program(
                    pool.lane_params if canary else pool.params,
                    pool.carry, pool.noise_dev())
            # only the narrow fields the serving loop reads cross to the
            # host — the same five the frozen service transfers
            fields = ["reward", "runtime_ns", "action", "cost", "early"]
            out_host = jax.device_get({f: out[f] for f in fields})
            # the narrow-field fetch bounds the tick: feed the EDF
            # feasibility estimate (seconds per episode-step)
            if warm:
                self.scheduler.note_tick(
                    k, self.clock() - t_tick,
                    in_trial=self._in_trial(pk[0]))
            if pool.capture and not fused:
                # unfused fallback (KernelConfig(fused_tick=False)): wide
                # fields stay on device, appended by the standalone
                # capture program before collect() advances offsets
                t0 = time.perf_counter()
                pool.capture_tick(out)
                self.o2rt.phase_ms["capture"] += \
                    1e3 * (time.perf_counter() - t0)
            for slot, req in enumerate(pool.requests):
                if req is None:
                    continue
                for j in range(k):
                    early = bool(out_host["early"][j, slot])
                    done = pool.collect(slot, out_host, j, early)
                    work += 1
                    if done:
                        rreq, summary, narrow = pool.retire(slot, early)
                        self.results[rreq.rid] = summary
                        self.slo.on_retire(rreq.rid, self.clock())
                        if self.o2rt is not None and narrow is not None:
                            self.o2rt.ingest_retired(pool, slot, rreq,
                                                     narrow)
                            retired.append((rreq, summary))
                        break
        if self.o2rt is not None:
            self.o2rt.tick(retired, self._pool_key)
        self.service_steps += 1
        self.episode_steps += work
        return work

    def run(self, max_service_steps: int | None = None) -> dict[int, dict]:
        """Serve until the queue and every slot drain; returns
        {rid: summary} for everything completed so far.  In concurrent O2
        mode, assessment verdicts that are still executing keep trailing:
        their `swapped` annotations land on `flush_o2` (serving
        throughput never waits for the annex).  Strict mode settled every
        verdict inside its window's tick already."""
        n = 0
        while self.queue or any(p.n_active for p in self.pools.values()):
            if max_service_steps is not None and n >= max_service_steps:
                break
            self.step()
            n += 1
        if self.o2rt is not None:
            self.o2rt.drain()
        return self.results

    def stats_block(self) -> ServiceStats:
        """The typed stats document (`serving/stats.py` is the schema);
        `stats()` renders it to the pinned dict shape."""
        swaps = None
        if self.o2rt is not None:
            swaps = self.o2rt.swap_stats()
            swaps.breaches_during_trial = self.slo.trial_breaches
        return ServiceStats(
            service_steps=self.service_steps,
            episode_steps=self.episode_steps,
            completed=len(self.results),
            queued=len(self.queue),
            pools=len(self.pools),
            devices=self.topology.serving.width,
            topology=self.topology.describe(),
            # per-service binds: first/repeat use of a program key here
            program_misses=self.program_misses,
            program_hits=self.program_hits,
            # actual process-wide compiled step programs (shared cache)
            programs_resident=_step_program.cache_info().currsize,
            # per-pool breakdown: the adaptive scheduler's observability
            per_pool={
                "/".join(str(x) for x in pk): PoolStats(
                    slots=pool.slots, active=pool.n_active,
                    peak_slots=pool.peak_slots,
                    resizes=dict(pool.resizes))
                for pk, pool in self.pools.items()},
            scheduler=SchedulerStats(
                policy=self.policy.name,
                resize_events=self.scheduler.resize_events),
            slo=self.slo.stats_block(),
            o2=(self.o2rt.stats_block()
                if self.o2rt is not None else None),
            swaps=swaps,
            health=(self.o2rt.health_stats()
                    if self.o2rt is not None else None))

    def stats(self) -> dict:
        return self.stats_block().as_dict()


# ---------------------------------------------------------------- driver
def main():
    from repro.core.litune import LITune, LITuneConfig
    from repro.index.workloads import sample_keys, wr_workload

    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--slots", type=int, default=4)
    ap.add_argument("--n-keys", type=int, default=2048)
    ap.add_argument("--budget", type=int, default=10)
    ap.add_argument("--index", default="alex", choices=["alex", "carmi"])
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()

    cfg = LITuneConfig(index_type=args.index, episode_len=args.budget,
                       lstm_hidden=32, mlp_hidden=64)
    tuner = LITune(cfg, seed=args.seed)
    service = TuningService(tuner, config=ServeConfig(slots=args.slots,
                                                      seed=args.seed))

    key = jax.random.PRNGKey(args.seed + 1)
    for i in range(args.requests):
        k = jax.random.fold_in(key, i)
        wr = [0.33, 1.0, 3.0][i % 3]
        data = sample_keys(k, args.n_keys, "mix")
        wl, _ = wr_workload(jax.random.fold_in(k, 1), data, wr,
                            total=args.n_keys, dist="mix")
        service.submit(data, wl, wr, budget_steps=args.budget)

    t0 = time.time()
    results = service.run()
    dt = time.time() - t0
    for rid in sorted(results):
        r = results[rid]
        print(f"req {rid}: default {r['r0_ns']:9.1f} ns/op  best "
              f"{r['best_runtime_ns']:9.1f}  steps {r['steps']:3d}  "
              f"violations {r['violations']:.0f}")
    st = service.stats()
    slo = st["slo"]
    print(f"\n{len(results)} requests in {dt:.2f}s "
          f"({len(results) / max(dt, 1e-9):.2f} req/s)  "
          f"ticks={st['service_steps']}  devices={st['devices']}  "
          f"step programs bound={st['program_misses']} "
          f"reused={st['program_hits']} "
          f"resident={st['programs_resident']}")
    print(f"SLO: queue-wait p95={slo['queue_wait_ms']['p95']:.1f}ms "
          f"serve p95={slo['serve_ms']['p95']:.1f}ms  "
          f"breaches={slo['breaches']}")


if __name__ == "__main__":
    main()
