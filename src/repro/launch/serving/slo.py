"""Request-level SLO layer: per-request deadlines and latency tracking.

The service retires strictly by budget/ET-MDP; this layer adds the
serving contract on top — how long a request may wait and run before the
service gives up on it, and the percentile evidence that the contract is
being met.

Two knobs, both per-request (with service-level defaults via
`SLOConfig`):

  * ``deadline_s`` — wall-clock budget measured from submission.  A
    queued request past its deadline is dropped before it ever occupies
    a slot; a running request past its deadline is retired at the end of
    the breaching tick.
  * ``on_breach`` — what a *running* breach does: ``"truncate"`` returns
    the best-so-far summary (flagged ``slo_breached``/``truncated``) —
    the tuned parameters found within the deadline are still useful;
    ``"drop"`` abandons the episode (the result records only the drop).

Every request is timed regardless of deadlines: queue-wait (submit →
admit) and serve-time (admit → retire) feed the p50/p95/p99 percentiles
`TuningService.stats()["slo"]` reports, which is also what
`benchmarks/slo_serve.py` compares across scheduling policies.

The clock is injectable (`TuningService(clock=...)`) so deadline
behavior is deterministic under test.
"""
from __future__ import annotations

import dataclasses
from collections import deque

import numpy as np


@dataclasses.dataclass(frozen=True)
class SLOConfig:
    """Service-level SLO defaults; per-request submit() kwargs override."""
    default_deadline_s: float | None = None
    on_breach: str = "truncate"         # truncate | drop


_PCTS = (50, 95, 99)


def _percentiles_ms(samples_s) -> dict:
    if not samples_s:
        return {f"p{p}": 0.0 for p in _PCTS}
    arr = 1e3 * np.asarray(samples_s)
    return {f"p{p}": round(float(np.percentile(arr, p)), 3) for p in _PCTS}


class SLOTracker:
    """Per-request latency bookkeeping: queue-wait and serve-time
    samples, breach counters, and the percentile summary for stats().

    Samples live in a bounded window (`window` most recent requests) so
    a long-lived service neither grows without bound nor reports
    percentiles frozen by day-one traffic; `tracked` counts every
    request ever timed."""

    def __init__(self, clock, window: int = 4096):
        self.clock = clock
        self.queue_wait_s: deque[float] = deque(maxlen=window)
        self.serve_s: deque[float] = deque(maxlen=window)
        self.tracked = 0
        self.truncated = 0
        self.dropped_queued = 0
        self.dropped_running = 0
        self.pre_dropped = 0        # EDF feasibility cuts (never admitted)
        # breaches that landed while the breaching request's tenant had a
        # live swap trial (canary or post-promotion watch) — attribution
        # the swap pipeline reports under stats()["swaps"], NOT here: the
        # "slo" block's shape is pinned by pre-pipeline assertions
        self.trial_breaches = 0
        self._admitted_at: dict[int, float] = {}

    # ------------------------------------------------------- lifecycle
    def on_admit(self, req, now: float):
        self.tracked += 1
        self.queue_wait_s.append(now - req.submitted_at)
        self._admitted_at[req.rid] = now

    def on_retire(self, rid: int, now: float):
        t_admit = self._admitted_at.pop(rid, None)
        if t_admit is not None:
            self.serve_s.append(now - t_admit)

    # --------------------------------------------------------- breaches
    def on_drop_queued(self, req, now: float, pre: bool = False):
        """A queued request leaves without a slot: its deadline lapsed
        while waiting, or (`pre`) the EDF policy judged its budget
        infeasible at the measured tick rate and cut it early."""
        self.tracked += 1
        self.dropped_queued += 1
        if pre:
            self.pre_dropped += 1
        # the wait it accrued before the drop still counts against the SLO
        self.queue_wait_s.append(now - req.submitted_at)

    def note_trial_breach(self):
        """Attribute one breach to a live swap trial (called by the
        service alongside the regular breach hook when the request's
        tenant was mid-canary or mid-watch)."""
        self.trial_breaches += 1

    def on_breach_running(self, req, now: float, dropped: bool):
        if dropped:
            self.dropped_running += 1
            self._admitted_at.pop(req.rid, None)
        else:
            self.truncated += 1
            self.on_retire(req.rid, now)

    # ------------------------------------------------------------ stats
    def stats_block(self):
        """The typed `stats()["slo"]` block (`serving/stats.py` is the
        schema).  Percentiles cover the bounded recent window; `tracked`
        and the breach counters are cumulative.  `trial_breaches` is
        deliberately absent — it renders under `stats()["swaps"]`."""
        from repro.launch.serving.stats import BreachStats, SLOStats
        return SLOStats(
            queue_wait_ms=_percentiles_ms(self.queue_wait_s),
            serve_ms=_percentiles_ms(self.serve_s),
            breaches=BreachStats(
                dropped_queued=self.dropped_queued,
                dropped_running=self.dropped_running,
                pre_dropped=self.pre_dropped,
                truncated=self.truncated),
            tracked=self.tracked)

    def stats(self) -> dict:
        return self.stats_block().as_dict()
