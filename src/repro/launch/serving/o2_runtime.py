"""O2 runtime layer of the serving stack: continuous tuning off the
serving critical path — and the trust machinery that decides when its
verdicts are allowed to touch production pools.

Owns everything the frozen serving path does not need: per-tenant
divergence monitors, the device-resident replay rings retired episodes
stream into, the offline DDPG learners (dispatched onto the O2 annex
slice with backpressure), and the pooled divergence-triggered
assessments whose verdicts hot-swap pool params.  Placement comes from
the service's `ServingTopology`: the annex is a multi-device *slice*,
not a single device — pooled assessments `shard_map` across its width
(each pow2-padded wave lowers onto the widest annex sub-slice it
divides) instead of running `lax.map`-serial, bitwise-equal either way
because per-lane math is mapped; the learner state lives on the slice's
lead device, and can scale its round size to the slice width
(`scale_rounds_to_annex`).  The service hands this
layer two things per tick — the episodes that retired, and a chance to
drain finished verdicts — and the layer never blocks the serving loop:
strict-order mode opts back into the serial loop's synchronous
interleaving for parity, everything else trails the server and settles
in `flush()` at the latest.
"""
from __future__ import annotations

import dataclasses
import time
from collections import deque

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.o2 import (DivergenceMonitor, O2Config,
                           _fleet_finetune_program, copy_state,
                           make_replay, offline_finetune)
from repro.core.replay import _pow2_pad

from repro.launch.serving.fleet import (FleetConfig, FleetLearner,
                                        embed_window, nearest_tenant)
from repro.launch.serving.health import HealthConfig, HealthGuard
from repro.launch.serving.programs import (_batched_admit_keys,
                                           _build_carry_program,
                                           _extract_episode_program,
                                           _pow2_ladder, _reset_program,
                                           _step_program)
from repro.launch.serving.stats import (HealthStats, O2Stats, SwapStats,
                                        TenantO2Stats, TenantSwapStats)
from repro.launch.serving.topology import ServingTopology


@dataclasses.dataclass(frozen=True)
class O2ServiceConfig:
    """Continuous tuning inside the service (the O2 loop, per tenant)."""
    enabled: bool = False
    o2: O2Config = O2Config()
    # offline fine-tune steps dispatched after each tick that retires at
    # least one of the tenant's episodes (ticks with no fresh transitions
    # skip the learner: re-sampling an unchanged replay would add latency
    # to every tick of a long episode and desync the per-window update
    # count from the serial O2 loop).  None -> the O2Config's per-window
    # count, which makes a strict-order single-tenant stream
    # decision-identical to `O2System.tune_window` at any budget.  In
    # concurrent (non-strict) mode the count is a per-tick *cap*: a round
    # is skipped — and counted in `stats()["o2"][...]["finetune_skipped"]`
    # — while the previous round is still executing, so the learner
    # trails the server instead of serializing with it
    offline_updates_per_tick: int | None = None
    # one window in flight at a time, in submission order: trades the
    # service's cross-pool concurrency for the serial O2 loop's exact
    # observe->tune->assess interleaving (the parity mode LITune.stream
    # uses when routed through the service).  Strict mode also awaits
    # every assessment verdict inside its window's tick; concurrent mode
    # drains verdicts when their device work completes (at the latest in
    # `flush_o2`), so a hot-swap may land one or more ticks after the
    # window that earned it
    strict_order: bool = False
    replay_seed: int = 0
    # scale each fine-tune round by the annex slice width: a w-wide annex
    # runs w times the configured updates per round (the slice bought the
    # assessment headroom; the learner may spend it too).  Off by default
    # — scaling changes the update count and therefore the offline params,
    # so every serial-parity guarantee keeps its exact round sizes
    scale_rounds_to_annex: bool = False
    # per-tenant replay ring capacity (rows).  The historical 8192
    # default sizes a single-digit tenant count; a thousand-tenant fleet
    # bounds its per-tenant host/device footprint here
    replay_capacity: int = 8192
    # fleet mode: stacked multi-tenant fine-tune rounds + hot/warm/cold
    # tenant tiering (serving/fleet.py).  Default off — the per-tenant
    # eager path, bitwise-unchanged
    fleet: FleetConfig = FleetConfig()


class _TenantO2:
    """Per-tenant continuous-tuning state: the divergence monitor, the
    device-resident replay ring the offline learner samples, and the
    offline DDPG state that hot-swaps into the tenant's pools on
    divergence + win.  The learner state and its update program live on
    the service's O2 annex device when the host provides one, so their
    execution never queues in front of the serving mesh's fetches; the
    ring stays on the serving side (its writers and sampling readers run
    in the post-fetch window when that queue is empty), with sampled
    batches hopped to the annex per round."""

    def __init__(self, tuner, svc_cfg: O2ServiceConfig, annex=None,
                 ring_device=None, baseline_window: int = 32,
                 guard: HealthGuard | None = None,
                 index_type: str | None = None, kernel=None):
        self.cfg = svc_cfg.o2
        self.guard = guard
        self.index_type = (index_type if index_type is not None
                           else tuner.cfg.index_type)
        self.net_cfg = tuner.cfg.net_cfg()
        self.ddpg_cfg = tuner.cfg.ddpg
        self.et_cfg = tuner.cfg.et_cfg()
        self.env_cfg = tuner.cfg.env_cfg()
        if kernel is not None:
            # the service's kernel posture (ServeConfig.kernel): keeps the
            # tenant's env config — and therefore every assessment program
            # key — aligned with the pools it serves
            self.env_cfg = dataclasses.replace(self.env_cfg, kernel=kernel)
        self.annex = annex
        self.monitor = DivergenceMonitor(self.cfg)
        lazy = svc_cfg.fleet.enabled
        # the ring lives on the serving side (its writers and sampling
        # readers run there, right after the tick fetch when the queue is
        # empty); only the learner state and its update program live on
        # the annex, with sampled batches hopped across per round.  Fleet
        # tenants construct with the ring spilled (host pages, zero
        # device bytes) — `promote_hot` re-pages on first activity
        self.replay = make_replay(self.net_cfg, self.ddpg_cfg, self.env_cfg,
                                  capacity=svc_cfg.replay_capacity,
                                  seed=svc_cfg.replay_seed, device=True,
                                  place_on=ring_device, spilled=lazy)
        # the pretrained tree the learner seeds from.  Read-only — every
        # materialization copies; the fleet warm start may rebind it to a
        # donor tenant's tuned copy before the first promotion
        self._seed_state = tuner.state
        # fleet tiering state (inert off-fleet: every tenant stays "hot")
        self.tier = "cold" if lazy else "hot"
        self.idle_ticks = 0
        self.embedding = None       # workload embedding (warm start)
        self.warm_started = False
        self.repages = 0
        self.spills = 0
        self._host_online = None    # cold tier's evicted online tree
        self._host_offline = None   # cold tier's evicted learner tree
        if lazy:
            # zero device memory until the tenant earns it: no learner
            # copies, no ready snapshot — `promote_hot` materializes
            self.online = None
            self.offline = None
            self.ready_params = None
        else:
            # real copies (not aliases): the scanned fine-tune program
            # donates its input state, so the tuner's pretrained tree and
            # the online model must own their buffers
            self.online = copy_state(tuner.state)
            self.offline = self._place(copy_state(tuner.state))
            # the assessment-facing snapshot: params of the latest
            # *completed* fine-tune round (concurrent mode never blocks
            # on a pending one)
            self.ready_params = self._place(
                copy_state(tuner.state["params"]))
        self.offline_updates = 0
        self.finetune_skipped = 0
        self._inflight = None       # marker array of the pending round
        self._round_dirty = False   # a round completed but isn't published
        self.swaps = 0
        self.swap_times_s: list[float] = []
        # the swap pipeline's verdict state machine counters, plus the
        # rolling pre-swap score baseline (the control arm for pools with
        # no spare lane, and the post-promotion regression reference)
        self.swap = TenantSwapStats()
        self.baseline: deque[float] = deque(maxlen=baseline_window)
        # the health layer's last-known-good learner state: every
        # publish/strict round that passes the param gate refreshes it,
        # and a rejected round restores from it — so one NaN gradient
        # never wedges the tenant's learner permanently (None until a
        # lazy fleet tenant materializes)
        self._last_good = (None if lazy
                           else self._place(copy_state(tuner.state)))
        self.rejected_params = 0
        # circuit-breaker state: consecutive bad events (rejected
        # params, rollbacks); at the guard's threshold the tenant's O2
        # loop is quarantined until `quarantined_until` (a window count
        # on this tenant's own monitor — traffic-paced, not wall-paced)
        self.bad_streak = 0
        self.quarantined_until: int | None = None

    @property
    def quarantined(self) -> bool:
        return self.quarantined_until is not None

    # ---------------------------------------------- fleet tier machinery
    def online_state(self):
        """The tenant's online learner tree, materialized on demand: a
        cold fleet tenant holds none until it earns one (from its cold
        eviction if it tuned before, else a copy of the seed tree)."""
        if self.online is None:
            if self._host_online is not None:
                self.online = copy_state(self._host_online)
                self._host_online = None
            else:
                self.online = copy_state(self._seed_state)
        return self.online

    def online_params(self):
        """Params a new pool of this tenant binds.  A cold never-tuned
        tenant serves the seed tree directly — the pool makes its own
        device copy, so no per-tenant online tree is materialized for a
        tenant that may never diverge."""
        if self.online is not None:
            return self.online["params"]
        if self._host_online is not None:
            return self.online_state()["params"]
        return self._seed_state["params"]

    def promote_hot(self):
        """Cold/warm -> hot: re-page the replay ring onto its device and
        materialize the learner trees (from the cold-evicted host copy
        when the tenant tuned before, else the seed).  Bitwise: the ring
        round-trips float32 exactly, and a never-tuned tenant's learner
        starts from the same seed copy the eager path made."""
        if self.tier == "hot":
            self.idle_ticks = 0
            return
        if self.replay.spilled:
            self.replay.repage()
            self.repages += 1
        if self.offline is None:
            src = (self._host_offline if self._host_offline is not None
                   else self._seed_state)
            self.offline = self._place(copy_state(src))
            self._host_offline = None
            self.ready_params = copy_state(self.offline["params"])
            self._last_good = self._place(copy_state(self.offline))
        if self.online is None:
            self.online_state()
        self.tier = "hot"
        self.idle_ticks = 0

    def demote_warm(self) -> bool:
        """Hot -> warm: the replay pages spill to host; the learner trees
        stay resident (the tenant re-enters the stacked round without a
        re-page the moment traffic returns)."""
        if self.tier != "hot":
            return False
        if not self.replay.spilled:
            self.replay.spill()
            self.spills += 1
        self.tier = "warm"
        return True

    def demote_cold(self, keep_history: int):
        """Warm -> cold: zero device bytes.  The (possibly tuned) learner
        trees evict to host copies, the ready/last-good snapshots drop
        (re-derived at the next promotion), and the divergence monitor's
        unbounded history trims to its last `keep_history` entries — the
        fix for per-tenant state growing forever once a tenant is seen."""
        if self.tier == "cold":
            return
        if not self.replay.spilled:
            self.replay.spill()
            self.spills += 1

        def to_host(tree):
            return jax.tree.map(
                lambda x: np.asarray(jax.device_get(x)), tree)

        if self.offline is not None:
            self._host_offline = to_host(self.offline)
            self.offline = None
        if self.online is not None:
            self._host_online = to_host(self.online)
            self.online = None
        self.ready_params = None
        self._last_good = None
        self._inflight = None
        self._round_dirty = False
        self.monitor.trim_history(keep_history)
        self.tier = "cold"

    @staticmethod
    def _tree_bytes(tree) -> int:
        if tree is None:
            return 0
        return sum(int(np.prod(np.shape(x)))
                   * np.dtype(getattr(x, "dtype", np.float32)).itemsize
                   for x in jax.tree.leaves(tree))

    def device_bytes(self) -> int:
        """Approximate device residency of this tenant's O2 state (ring
        pages + learner trees).  Zero for a cold tenant — pinned in
        tests/test_fleet.py and gated in the fleet bench."""
        return (self.replay.device_bytes
                + self._tree_bytes(self.online)
                + self._tree_bytes(self.offline)
                + self._tree_bytes(self.ready_params)
                + self._tree_bytes(self._last_good))

    def host_bytes(self) -> int:
        """Approximate host residency (spilled pages, narrow ring
        fields, cold-evicted learner trees)."""
        return (self.replay.host_bytes
                + self._tree_bytes(self._host_online)
                + self._tree_bytes(self._host_offline))

    def reject_round(self):
        """Drop an unhealthy fine-tune result: count it, restore the
        learner from the last-good snapshot (a real copy — the next
        round donates its input), and clear the round-pending state."""
        self.rejected_params += 1
        if self.guard is not None:
            self.guard.rejected_params += 1
        self.offline = self._place(copy_state(self._last_good))
        self._inflight = None
        self._round_dirty = False

    def gate_round(self) -> bool:
        """Health-gate the latest completed fine-tune round.  Healthy
        rounds refresh the last-good snapshot; unhealthy ones are
        rejected.  The breaker streak deliberately does NOT reset here —
        only a swap that survives its watch window or a quarantine
        release clears it, so repeated canary rollbacks trip the
        breaker even when every fine-tune round between them is
        healthy.  Read-only on the healthy path beyond the snapshot
        copy."""
        if self.guard is None or not self.guard.enabled:
            return True
        if self.guard.params_healthy(self.offline["params"]):
            self._last_good = self._place(copy_state(self.offline))
            return True
        self.reject_round()
        return False

    def _place(self, tree):
        return tree if self.annex is None else jax.device_put(tree,
                                                              self.annex)

    def learner_free(self) -> bool:
        return self._inflight is None or bool(self._inflight.is_ready())

    def publish_ready(self):
        """Expose the latest completed round's params to assessments —
        bounded staleness, never a block on a pending round (the copy
        also shields them from the next round's donation off-CPU).
        The completed round passes the health gate first: a rejected
        round never publishes, and `ready_params` keeps the last-good
        version (callers watch `rejected_params` for breaker strikes)."""
        if self._round_dirty and self.learner_free():
            if not self.gate_round():
                return
            self.ready_params = copy_state(self.offline["params"])
            self._round_dirty = False

    def finetune(self, n_updates: int, strict: bool):
        """Dispatch one offline fine-tune round.  Strict mode always runs
        it (serial-equivalent update counts); concurrent mode applies
        backpressure — if the previous round hasn't finished executing,
        the round is skipped and counted rather than queued behind."""
        if n_updates <= 0:
            return
        if not strict and not self.learner_free():
            self.finetune_skipped += n_updates
            return
        self.offline, done = offline_finetune(
            self.offline, self.replay, self.net_cfg, self.ddpg_cfg,
            n_updates, place_on=self.annex)
        self.offline_updates += done
        if done:
            self._inflight = self.offline["updates"]
            self._round_dirty = True
            if self.guard is not None and self.guard.fire("nan_round"):
                # injected learner divergence: poison the round's params
                # before any gate sees them (the chaos drill's NaN site)
                self.offline["params"] = jax.tree.map(
                    lambda x: jnp.full_like(x, jnp.nan),
                    self.offline["params"])


def _pooled_best(r0: float, runtimes: np.ndarray) -> float:
    """Best runtime of one pooled assessment episode — min over the
    request's step prefix and the default-config runtime, exactly the
    ``best_runtime_ns`` `core.o2.assess_offline` reports for the same key
    (the hot-swap comparison's left-hand side, and the seam tests patch
    to force a verdict)."""
    return min(r0, float(np.min(runtimes)))


def _bootstrap_ci(deltas, level: float, resamples: int,
                  rng: np.random.Generator) -> tuple[float, float]:
    """Percentile-bootstrap confidence interval on the mean of `deltas`
    (the per-window offline-vs-online runtime improvements a pooled
    assessment produced).  Deterministic given the generator state — the
    runtime seeds it from `SwapConfig.ci_seed`, so a replayed request
    stream reproduces every gate decision."""
    deltas = np.asarray(deltas, np.float64)
    if deltas.size == 1:
        return float(deltas[0]), float(deltas[0])
    idx = rng.integers(0, deltas.size, size=(resamples, deltas.size))
    means = deltas[idx].mean(axis=1)
    alpha = (1.0 - level) / 2.0
    return (float(np.quantile(means, alpha)),
            float(np.quantile(means, 1.0 - alpha)))


def _lane_score(summary: dict) -> float:
    """One retired episode's score for canary-arm comparison: tuned best
    runtime normalized by the default-config runtime (lower is better).
    The normalization makes lanes serving different windows comparable —
    raw runtimes mix the workload's difficulty into the arm means.
    Module-level on purpose: the seam tests patch to force a verdict."""
    return summary["best_runtime_ns"] / max(float(summary["r0_ns"]), 1e-9)


@dataclasses.dataclass
class _SwapTrial:
    """One tenant's in-flight swap trial: the canary stage (candidate
    params live on a lane fraction of every pool) and, after promotion,
    the post-swap watch window.  Holds everything a bitwise rollback
    needs: the judged candidate tree, the pre-swap online state, and the
    divergence monitor's pre-promotion reference snapshot."""
    index_type: str
    req: object                  # the request whose verdict started it
    window: int                  # 0-based window index for re-anchoring
    summary: dict                # its summary (swap flags land here)
    candidate: object            # the judged param tree (owned copy)
    prev_online: object          # pre-swap online state (owned copy)
    baseline_mean: float | None  # tenant rolling baseline at trial start
    state: str = "canary"        # "canary" -> "promoted"
    canary_scores: list = dataclasses.field(default_factory=list)
    control_scores: list = dataclasses.field(default_factory=list)
    post_scores: list = dataclasses.field(default_factory=list)
    ticks: int = 0               # service ticks spent in the canary stage
    watch_windows: int = 0       # windows observed since promotion
    monitor_ref: tuple | None = None  # (ref_quantiles, ref_wr) pre-swap
    prev_anchor: int | None = None    # anchor window index pre-swap
    forced_loss: bool = False    # fault injection: lose this canary


@dataclasses.dataclass
class _PendingAssess:
    """One dispatched pooled assessment awaiting its verdict: up to
    2*slots diverged windows of a single tenant, rolled out as one batch
    through the resident step programs.  Holds only device references —
    nothing crosses to the host until `ready()` (or a blocking drain).
    `params` is the exact tree the episodes ran under: a winning verdict
    promotes *those* params, not whatever the learner has advanced to by
    drain time."""
    index_type: str
    items: list          # [(req, summary, pend)] per occupied slot column
    r0: object           # [B] device: r_best at reset
    outs: list           # [(k, runtime_ns [k, B], early [k, B]) ...]
    params: object       # the judged param tree
    dispatched_at: float | None = None  # wall time of dispatch (watchdog)
    forced_hang: bool = False  # fault injection: never report ready

    def ready(self) -> bool:
        if self.forced_hang:
            return False
        return bool(self.outs[-1][1].is_ready())


class O2Runtime:
    """The between-ticks half of the O2 loop, composed into the service.

    Shares the service's pools dict (hot-swaps update every pool of a
    tenant in place) and its device/annex ids; owns the tenants, the
    admission-verdict map, the assessment backlog/in-flight queues, and
    the per-phase host-time accounting.
    """

    def __init__(self, agents: dict, svc_cfg: O2ServiceConfig, pools: dict,
                 topology: ServingTopology, horizon_cap: int,
                 max_assess_width: int, swap_cfg=None, clock=None,
                 health_cfg: HealthConfig | None = None, kernel=None):
        self.cfg = svc_cfg
        if swap_cfg is None:
            # lazy: config.py imports O2ServiceConfig from this module
            from repro.launch.serving.config import SwapConfig
            swap_cfg = SwapConfig()
        self.swap_cfg = swap_cfg
        self.health = HealthGuard(health_cfg if health_cfg is not None
                                  else HealthConfig())
        # the service's injectable clock (swap timing rides it, so tests
        # and benchmarks measure swaps on the same timebase as SLOs)
        self.clock = clock if clock is not None else time.perf_counter
        self.pools = pools              # shared with the service
        self.topology = topology
        # the learner state and its scanned update program live on the
        # annex slice's lead device; assessments spread over the slice
        self.annex = topology.annex.device(0)
        self.horizon_cap = horizon_cap
        self.max_assess_width = max_assess_width
        self.fleet_cfg = svc_cfg.fleet
        self.fleet = (FleetLearner(svc_cfg.fleet, annex=self.annex)
                      if svc_cfg.fleet.enabled else None)
        self.warm_starts = 0
        self.tenants: dict[str, _TenantO2] = {
            it: _TenantO2(tuner, svc_cfg, annex=self.annex,
                          ring_device=topology.ring.device(),
                          baseline_window=swap_cfg.baseline_window,
                          guard=self.health, index_type=it, kernel=kernel)
            for it, tuner in agents.items()}
        # tier bookkeeping: tenants holding any device memory (hot/warm)
        # — the only ones the per-tick aging walk visits, so a mostly-
        # cold thousand-tenant fleet pays O(active), not O(tenants)
        self._noncold: set[str] = {it for it, t in self.tenants.items()
                                   if t.tier != "cold"}
        self._touched: set[str] = set()     # tenants active this tick
        # at most one swap trial per tenant (verdict wins landing while
        # one is live are deferred, not queued): index_type -> _SwapTrial
        self.trials: dict[str, _SwapTrial] = {}
        self._ci_rng = np.random.default_rng(swap_cfg.ci_seed)
        self.pending: dict[int, dict] = {}      # rid -> admission verdict
        self.backlog: list[tuple] = []          # (pk, req, summary, pend)
        self.inflight: deque[_PendingAssess] = deque()
        # bind the assessment-side program wrappers for every annex
        # sub-slice x K up front: which (wave width, K) pairs actually
        # occur is drain-timing-dependent in concurrent mode, and the
        # process-wide program accounting must not move after warmup
        # (tests assert zero new binds across waves).  Binding is a
        # cheap lru insert — XLA still traces lazily per shape, exactly
        # as the single-device annex behaved.  Deduped by config: a
        # thousand-tenant fleet sharing one agent config walks the
        # ladder once, not once per tenant
        _seen_cfgs: set = set()
        for tenant in self.tenants.values():
            if (tenant.net_cfg, tenant.env_cfg, tenant.et_cfg) \
                    in _seen_cfgs:
                continue
            _seen_cfgs.add((tenant.net_cfg, tenant.env_cfg,
                            tenant.et_cfg))
            env_cfg = tenant.env_cfg.with_episode_len(horizon_cap)
            # pad the top: a chunk of max_assess_width windows pads to
            # the next power of two, and that width must be warm too
            widths = _pow2_ladder(_pow2_pad(max_assess_width))
            for sl in {topology.assess_slice(w) for w in widths}:
                _reset_program(sl, env_cfg)
                for w in widths:
                    if w % sl.width == 0 and topology.assess_slice(w) == sl:
                        _build_carry_program(sl, tenant.net_cfg, w)
                for k in _pow2_ladder(horizon_cap):
                    _step_program(sl, tenant.net_cfg, env_cfg,
                                  tenant.et_cfg, k)
        if self.fleet is not None:
            # pre-bind the stacked fine-tune ladder (pow2 stack widths up
            # to the hot-tier cap), deduped by (configs, round size): the
            # hot-set size sweeping 1..max_hot after warmup binds zero
            # new programs — the fleet bench's hard invariant
            _seen_fleet: set = set()
            for tenant in self.tenants.values():
                n = self._round_updates(tenant)
                ck = (tenant.net_cfg, tenant.ddpg_cfg, n)
                if n <= 0 or ck in _seen_fleet:
                    continue
                _seen_fleet.add(ck)
                for k_pad in _pow2_ladder(_pow2_pad(svc_cfg.fleet.max_hot)):
                    _fleet_finetune_program(tenant.net_cfg,
                                            tenant.ddpg_cfg, n, k_pad,
                                            self.fleet.impl)
        self._assess_noise: dict[tuple, jax.Array] = {}  # (slice,w) -> 0s
        # (index_type, slice) -> (source tree, replicated copy): the
        # broadcast onto the assess slice is paid once per params
        # version, not once per wave (identity-compared — publish_ready
        # and each fine-tune round rebind the source tree)
        self._assess_params: dict[tuple, tuple] = {}
        self.pending_missing = 0        # retired without admission verdict
        self.assessments = 0            # pooled assessment episodes judged
        self.phase_ms = {"capture": 0.0, "finetune": 0.0, "assess": 0.0}

    # --------------------------------------------------------- admission
    def admit_keys(self, keys: np.ndarray):
        """One batched split per admission wave: window key -> (episode
        key, assessment key), the same bits as the serial loop's
        per-window jax.random.split chain."""
        k_on, k_off = _batched_admit_keys(keys)
        return np.asarray(k_on), np.asarray(k_off)

    def observe_admission(self, req, assess_key):
        """Each admitted request is one window of the tenant's stream:
        observe divergence now (against the reference distribution),
        assess after the episode retires."""
        tenant = self.tenants[req.index_type]
        if self.fleet is not None and tenant.embedding is None:
            self._admit_fleet(tenant, req)
        div = tenant.monitor.observe(req.data_keys, req.wr_ratio)
        if self.fleet is not None:
            self._touched.add(req.index_type)
            if div["diverged"] and tenant.tier != "hot":
                # first divergence observation re-pages a cold tenant:
                # the O2 loop is about to need its ring and learner
                self._promote_hot(tenant)
        if tenant.quarantined and \
                tenant.monitor.windows_seen >= tenant.quarantined_until:
            # cooloff elapsed (measured in this tenant's own observed
            # windows): release the breaker with a clean streak
            tenant.quarantined_until = None
            tenant.bad_streak = 0
            self.health.quarantine_releases += 1
        self.pending[req.rid] = {
            "div": div, "window": tenant.monitor.windows_seen,
            "assess_key": assess_key}
        trial = self.trials.get(req.index_type)
        if trial is not None and trial.state == "promoted":
            # the post-promotion watch: the monitor was re-anchored on
            # the promoted window's data, so a re-fire this soon means
            # the swap anchored on an unrepresentative window — revert
            trial.watch_windows += 1
            if div["diverged"]:
                self._rollback_promoted(req.index_type, trial, "monitor")
            elif trial.watch_windows >= self.swap_cfg.rollback_windows:
                self._close_trial(req.index_type)

    # ------------------------------------------------------------- fleet
    def _admit_fleet(self, tenant: _TenantO2, req):
        """First observed window of a fleet tenant: embed the workload
        (key-quantile profile + write mix) and, when enabled, seed the
        learner from the L2-nearest existing tenant's tuned params
        instead of the pretrained default (BALANCE-style transfer —
        cold-start tuning becomes transfer from the fleet's accumulated
        knowledge).  Falls back to the default when no other tenant has
        been observed yet.  Counted in `stats()["o2"]["warm_starts"]`."""
        tenant.embedding = embed_window(req.data_keys, req.wr_ratio)
        if not self.fleet_cfg.warm_start or tenant.warm_started:
            return
        if tenant.monitor.windows_seen > 0 or tenant.online is not None \
                or tenant._host_online is not None:
            return      # an already-tuned tenant keeps its own learner
        donors = {it: t for it, t in self.tenants.items()
                  if t is not tenant and t.embedding is not None}
        # prefer donors whose learner is resident (hot/warm) — a cold
        # donor's tree works too, just from its host copy or seed
        warm = {it: t for it, t in donors.items() if t.tier != "cold"}
        pool_ = warm if warm else donors
        pick = nearest_tenant(tenant.embedding,
                              {it: t.embedding for it, t in pool_.items()})
        if pick is None:
            return
        donor = self.tenants[pick]
        src = (donor.online if donor.online is not None else
               donor._host_online if donor._host_online is not None else
               donor._seed_state)
        tenant._seed_state = copy_state(src)
        tenant.warm_started = True
        self.warm_starts += 1
        # admission resolves the pool before this observation lands, so
        # any existing pool of the tenant rebinds to the donor-seeded
        # params — a pure buffer update, zero re-traces
        for pk, pool in self.pools.items():
            if pk[0] == tenant.index_type:
                pool.params = jax.device_put(
                    tenant._seed_state["params"], pool.replicated)

    def _promote_hot(self, tenant: _TenantO2):
        """Promote a tenant into the hot tier, spilling the idlest hot
        tenant to warm when the tier is at `max_hot` capacity."""
        was_cold_or_warm = tenant.tier != "hot"
        tenant.promote_hot()
        self._noncold.add(tenant.index_type)
        if self.fleet is None:
            return
        if was_cold_or_warm:
            self.fleet.promotions += 1
        hot = [self.tenants[it] for it in self._noncold
               if self.tenants[it].tier == "hot"]
        if len(hot) > self.fleet_cfg.max_hot:
            idlest = max((t for t in hot if t is not tenant),
                         key=lambda t: t.idle_ticks)
            if idlest.demote_warm():
                self.fleet.demotions += 1

    def _age_tiers(self):
        """One O2 tick of tier aging, run at the end of `tick`: every
        hot/warm tenant that saw no activity (admission or retirement)
        this tick ages toward warm (`warm_after_ticks`: replay pages
        spill to host) and then cold (`cold_after_ticks`: learner trees
        evict, idle pools drop).  Cold tenants are not walked at all."""
        fc = self.fleet_cfg
        for it in list(self._noncold):
            tenant = self.tenants[it]
            if it in self._touched:
                tenant.idle_ticks = 0
                continue
            tenant.idle_ticks += 1
            if tenant.tier == "hot" \
                    and tenant.idle_ticks >= fc.warm_after_ticks:
                if tenant.demote_warm():
                    self.fleet.demotions += 1
            if tenant.tier == "warm" \
                    and tenant.idle_ticks >= fc.cold_after_ticks:
                self._evict_cold(it, tenant)
        self._touched.clear()

    def _evict_cold(self, it: str, tenant: _TenantO2):
        """Cold eviction: zero device bytes for the tenant, and its idle
        pools (no active episodes) are torn down — `_pool_for` re-creates
        them on demand, re-entering the same resident programs."""
        tenant.demote_cold(self.fleet_cfg.monitor_history)
        self.fleet.evictions += 1
        self._noncold.discard(it)
        for pk in [pk for pk, p in self.pools.items()
                   if pk[0] == it and p.n_active == 0]:
            del self.pools[pk]

    def _round_updates(self, tenant: _TenantO2) -> int:
        """One fine-tune round's update count for a tenant (the serial
        path's exact resolution order)."""
        n = (self.cfg.offline_updates_per_tick
             if self.cfg.offline_updates_per_tick is not None
             else tenant.cfg.offline_updates_per_window)
        if self.cfg.scale_rounds_to_annex:
            n *= self.topology.annex.width
        return n

    # ----------------------------------------------------------- capture
    def ingest_retired(self, pool, slot: int, req, narrow: dict):
        """Extract the retired episode's capture rows (small gather on
        the serving mesh) into the tenant's ring — the wide fields never
        visit the host."""
        t0 = time.perf_counter()
        tenant = self.tenants[req.index_type]
        if self.fleet is not None:
            self._touched.add(req.index_type)
            if tenant.tier != "hot":
                # a retiring episode is ring traffic: re-page before the
                # write so the capture rows land on device pages
                self._promote_hot(tenant)
        T = len(narrow["reward"])
        src = np.minimum(np.arange(_pow2_pad(T)), T - 1).astype(np.int32)
        values = _extract_episode_program(pool.slice)(
            pool.cap, np.int32(slot), src)
        tenant.replay.add_episode_values(values, T, **narrow)
        self.phase_ms["capture"] += 1e3 * (time.perf_counter() - t0)

    # -------------------------------------------------------------- tick
    def tick(self, retired: list, pool_key):
        """The between-ticks half of the O2 loop.  Strict mode keeps the
        serial interleaving: fine-tune, assess against the fresh offline
        tail, await the verdict.  Concurrent mode inverts it for the
        annex's FIFO: assessments dispatch first (against the last
        *completed* round's published params, so they never chain behind
        a pending one), the fine-tune round queues after them, and
        verdicts land on a later tick's drain."""
        strict = self.cfg.strict_order
        # a demoted annex inside its cooloff pauses all O2 work for the
        # tick — the serving path keeps running frozen on current params
        paused = self.health.o2_paused()
        if paused:
            self.health.degraded_ticks += 1
        if strict and not paused:
            t0 = time.perf_counter()
            self._finetune_retired(retired, strict)
            self.phase_ms["finetune"] += 1e3 * (time.perf_counter() - t0)
        t0 = time.perf_counter()
        for req, summary in retired:
            tenant = self.tenants[req.index_type]
            pend = self.pending.pop(req.rid, None)
            if pend is None:
                # admitted before O2 tracked this tenant (or replayed
                # after a config swap): skip the window verdict instead
                # of raising mid-tick, and count it
                self.pending_missing += 1
                continue
            # annotate the request's result with its window verdict, in
            # the exact shape O2System.tune_window returns; `swapped`
            # flips in the drain if the assessment wins
            summary["divergence"] = pend["div"]
            summary["swapped"] = False
            if pend["div"]["diverged"] and \
                    pend["window"] % tenant.cfg.assess_every == 0 \
                    and not paused and not tenant.quarantined:
                self.backlog.append((pool_key(req), req, summary, pend))
        if self.swap_cfg.staged:
            self._observe_retired(retired)
            self._advance_trials()
        if not paused:
            self._pump_assessments()
        self.phase_ms["assess"] += 1e3 * (time.perf_counter() - t0)
        if strict:
            # serial-equivalent interleaving: the verdict (and any swap)
            # lands before the next window is admitted
            self.drain(block=True)
        elif not paused:
            t0 = time.perf_counter()
            self._finetune_retired(retired, strict)
            self.phase_ms["finetune"] += 1e3 * (time.perf_counter() - t0)
        if self.fleet is not None:
            self._age_tiers()

    def _pump_assessments(self):
        """Move backlog windows into pooled assessment dispatches, widest
        chunks first, with at most two chunks in flight — the annex's
        admission control.  A saturated annex (many diverged windows,
        long budgets) grows the backlog instead of the device queue, and
        `flush` settles whatever is left."""
        while self.backlog and len(self.inflight) < 2:
            if self.health.o2_paused():
                # demoted annex mid-pump: keep the rest of the backlog
                # for after recovery instead of burning it on dispatches
                # that cannot succeed
                break
            pk = self.backlog[0][0]
            chunk = [item for item in self.backlog
                     if item[0] == pk][:self.max_assess_width]
            for item in chunk:
                self.backlog.remove(item)
            pool, tenant = self.pools[pk], self.tenants[pk[0]]
            if tenant.quarantined:
                # stale backlog of a breaker-open tenant: drop it
                continue
            if not self.cfg.strict_order:
                before = tenant.rejected_params
                tenant.publish_ready()
                if tenant.rejected_params != before:
                    self._note_bad(tenant)
                    if tenant.quarantined:
                        continue
            entry = self._guarded_dispatch(
                pk, pool, tenant, [item[1:] for item in chunk])
            if entry is not None:
                self.inflight.append(entry)

    def _guarded_dispatch(self, pk: tuple, pool, tenant: _TenantO2,
                          chunk: list):
        """One pooled-assessment dispatch under the annex watchdog:
        bounded retries with seeded backoff; exhaustion drops the chunk
        and strikes the annex breaker.  Returns None when dropped."""
        g = self.health
        if not g.enabled:
            entry = self._dispatch_assess(pk, pool, tenant, chunk)
            entry.dispatched_at = time.monotonic()
            return entry
        if g.o2_paused():
            # demoted mid-tick: the cooloff applies to the rest of the
            # tick's dispatches too, not just the next tick's
            g.dropped_dispatches += 1
            return None
        for attempt in range(g.cfg.dispatch_retries + 1):
            try:
                g.raise_if_planned("assess_fail")
                entry = self._dispatch_assess(pk, pool, tenant, chunk)
            except RuntimeError:
                # InjectedFailure and device/runtime faults alike
                if attempt < g.cfg.dispatch_retries:
                    g.note_retry()
                    g.sleep_backoff(attempt)
                    continue
                g.note_annex_failure()
                g.dropped_dispatches += 1
                return None
            g.note_annex_ok()
            entry.dispatched_at = time.monotonic()
            if g.fire("assess_hang"):
                entry.forced_hang = True
            return entry

    def _finetune_retired(self, retired: list, strict: bool):
        # deterministic first-retirement tenant order (a set of strings
        # iterates in hash order, which varies with PYTHONHASHSEED): the
        # fleet stack's lane order — and therefore which replay RNG draws
        # pair with which lane — must be reproducible run to run
        order = list(dict.fromkeys(req.index_type for req, _ in retired))
        tenants = [self.tenants[it] for it in order
                   if not self.tenants[it].quarantined]
        if not tenants:
            return
        # getattr: tests drive this method on lightweight runtime
        # stand-ins that don't construct the fleet learner
        if getattr(self, "fleet", None) is not None:
            self._fleet_finetune(tenants, strict)
            return
        for tenant in tenants:
            self._guarded_finetune(tenant, self._round_updates(tenant),
                                   strict)

    def _fleet_finetune(self, tenants: list, strict: bool):
        """One stacked fine-tune round over every tenant that retired
        episodes this tick.  Quarantined tenants were already filtered
        out — the stack is re-formed from scratch each round, so a
        mid-round eviction cannot perturb the surviving lanes' bits
        (each lane's state and batches are its own; parity pinned in
        tests/test_fleet.py).  Per-tenant semantics match the serial
        path lane by lane: backpressure skips, update counters, the
        NaN-round fault site, and strict-mode gating."""
        g = self.health
        ready = []
        for tenant in tenants:
            n = self._round_updates(tenant)
            if n <= 0:
                continue
            if tenant.tier != "hot":
                # retirement promoted it in ingest; belt and braces for
                # direct callers
                self._promote_hot(tenant)
            if not strict and not tenant.learner_free():
                tenant.finetune_skipped += n
                continue
            ready.append((tenant, n))
        if not ready:
            return
        # one watchdog-guarded dispatch for the whole stack (the same
        # retry/backoff contract as the serial per-tenant rounds)
        if not g.enabled:
            ran = self.fleet.round(ready)
        else:
            ran = None
            for attempt in range(g.cfg.dispatch_retries + 1):
                try:
                    g.raise_if_planned("finetune_fail")
                    ran = self.fleet.round(ready)
                except RuntimeError:
                    if attempt < g.cfg.dispatch_retries:
                        g.note_retry()
                        g.sleep_backoff(attempt)
                        continue
                    g.note_annex_failure()
                    return
                g.note_annex_ok()
                break
        for tenant, n in ran:
            tenant.offline_updates += n
            tenant._inflight = tenant.offline["updates"]
            tenant._round_dirty = True
            if g.fire("nan_round"):
                tenant.offline["params"] = jax.tree.map(
                    lambda x: jnp.full_like(x, jnp.nan),
                    tenant.offline["params"])
            if strict and tenant._round_dirty:
                if tenant.gate_round():
                    tenant._round_dirty = False   # strict never publishes
                else:
                    self._note_bad(tenant)

    def _guarded_finetune(self, tenant: _TenantO2, n: int, strict: bool):
        """One learner round under the watchdog (same retry/backoff
        contract as assessments).  Strict mode additionally gates the
        completed round's params right here, preserving the serial
        interleaving — concurrent mode gates at publish time instead, so
        a pending round is never synced on."""
        g = self.health
        if not g.enabled:
            tenant.finetune(n, strict)
            return
        if g.o2_paused():        # demoted mid-tick: no learner work either
            return
        for attempt in range(g.cfg.dispatch_retries + 1):
            try:
                g.raise_if_planned("finetune_fail")
                tenant.finetune(n, strict)
            except RuntimeError:
                if attempt < g.cfg.dispatch_retries:
                    g.note_retry()
                    g.sleep_backoff(attempt)
                    continue
                g.note_annex_failure()
                return
            g.note_annex_ok()
            if strict and tenant._round_dirty:
                if tenant.gate_round():
                    tenant._round_dirty = False   # strict never publishes
                else:
                    self._note_bad(tenant)
            return

    def _note_bad(self, tenant: _TenantO2):
        """One tenant-level health strike (rejected params, rollback).
        At the configured threshold the tenant's breaker opens: its O2
        loop quarantines until `quarantine_windows` more of its windows
        are observed, and any live canary is rolled back (the incumbent
        params were never touched, so serving stays frozen-good)."""
        tenant.bad_streak += 1
        g = self.health
        if not g.enabled or tenant.quarantined:
            return
        if tenant.bad_streak >= g.cfg.quarantine_threshold:
            tenant.quarantined_until = (tenant.monitor.windows_seen
                                        + g.cfg.quarantine_windows)
            g.quarantines += 1
            if self.fleet is not None and g.cfg.quarantine_spills:
                # a quarantined tenant leaves the stacked round (the
                # stack is re-formed each round, so the others' bits are
                # untouched) and cannot fine-tune during the cooloff —
                # spill its ring pages rather than hold device memory
                if tenant.demote_warm():
                    self.fleet.demotions += 1
            trial = self.trials.get(tenant.index_type)
            if trial is not None and trial.state == "canary":
                self._rollback_canary(tenant.index_type, trial,
                                      note=False)

    def _assess_noise_dev(self, slice_, width: int):
        key = (slice_, width)
        if key not in self._assess_noise:
            self._assess_noise[key] = jax.device_put(
                jnp.zeros((width,), jnp.float32), slice_.sharded())
        return self._assess_noise[key]

    def _dispatch_assess(self, pk: tuple, pool,
                         tenant: _TenantO2, chunk: list) -> _PendingAssess:
        """Launch one pooled assessment on the O2 annex slice: up to B
        diverged windows of one tenant reset and roll out as a single
        batch through the K-ladder step-program cache (zero-noise inputs
        — the deterministic branch for the tanh-bounded actor), in place
        of len(chunk) serial `rollout_episode` calls.  The pow2-padded
        wave shards over the widest annex sub-slice it divides — lanes
        split across annex devices instead of `lax.map`-serial on one,
        bitwise-equal because the per-lane program is identical (the
        1-device slice *is* the serial path).  Strict mode assesses the
        offline tail (serial semantics); concurrent mode the published
        ready params.  Nothing is fetched here; the verdict scalars cross
        to the host in `drain` once the device work completes."""
        m = len(chunk)
        width = _pow2_pad(m)
        sl = self.topology.assess_slice(width)
        reqs = [item[0] for item in chunk]
        rpad = reqs + [reqs[0]] * (width - m)
        data = np.stack([r.data_keys for r in rpad])
        reads = np.stack([r.workload["reads"] for r in rpad])
        ins = np.stack([r.workload["inserts"] for r in rpad])
        wr = np.asarray([r.wr_ratio for r in rpad], np.float32)
        # the assessment keys were derived in the admission wave's
        # batched split (same bits as the serial loop's chain)
        k_offs = np.stack([item[2]["assess_key"] for item in chunk])
        keys = np.concatenate(
            [k_offs, np.broadcast_to(k_offs[:1], (width - m, 2))])
        env_states, obs = _reset_program(sl, pool.env_cfg)(
            data, reads, ins, wr)
        carry = _build_carry_program(sl, pool.net_cfg, width)(
            keys, env_states, obs)
        # replicate the judged params over the assess slice (a local view
        # on a 1-wide slice; a broadcast onto a wider one) so the sharded
        # step program never mixes committed device sets; cached until
        # the source tree is rebound (a completed round / publish)
        src = (tenant.offline["params"] if self.cfg.strict_order
               else tenant.ready_params)
        ck = (pk[0], sl)
        if ck not in self._assess_params or \
                self._assess_params[ck][0] is not src:
            self._assess_params[ck] = (src, jax.device_put(
                src, sl.replicated()))
        params = self._assess_params[ck][1]
        outs = []
        remaining = max(r.budget_steps for r in reqs)
        while remaining > 0:
            k = max(w for w in _pow2_ladder(self.horizon_cap)
                    if w <= remaining)
            program = _step_program(sl, pool.net_cfg, pool.env_cfg,
                                    pool.et_cfg, k)
            carry, out = program(params, carry,
                                 self._assess_noise_dev(sl, width))
            outs.append((k, out["runtime_ns"], out["early"]))
            remaining -= k
        return _PendingAssess(pk[0], list(chunk), env_states["r_best"],
                              outs, params)

    def drain(self, block: bool = False, deadline_s: float | None = None):
        """Judge every in-flight pooled assessment whose device work has
        completed (all of them when `block`), in dispatch order: fetch
        the per-slot runtime scalars, compare each window's offline best
        against its online summary, and hot-swap winners.

        The annex watchdog rides along: a dispatched entry not ready
        after `HealthConfig.dispatch_timeout_s` of wall time is
        abandoned (counted, annex breaker struck) instead of blocking
        forever, and a blocking drain stops at `deadline_s` (from
        `flush`'s partial-flush budget) with the remainder in flight."""
        t_start = time.monotonic()
        while self.inflight:
            entry = self.inflight[0]
            if not entry.ready():
                if self.health.watchdog_expired(entry.dispatched_at):
                    # hung dispatch: abandon the verdict (its windows
                    # simply keep their online summaries) and strike
                    self.inflight.popleft()
                    self.health.dropped_dispatches += 1
                    self.health.note_annex_failure()
                    continue
                if not block:
                    break
                if deadline_s is not None and \
                        (time.monotonic() - t_start) >= deadline_s:
                    break
                time.sleep(5e-4)
                continue
            self.inflight.popleft()
            t0 = time.perf_counter()
            r0s = np.asarray(jax.device_get(entry.r0))
            rts = np.concatenate(
                [np.asarray(jax.device_get(r)) for _, r, _ in entry.outs])
            earls = np.concatenate(
                [np.asarray(jax.device_get(e)) for _, _, e in entry.outs])
            deltas: dict[int, float] = {}   # slot column -> delta (ns)
            wins: dict[int, float] = {}     # winning columns only
            stops: dict[int, int] = {}
            tenant = self.tenants[entry.index_type]
            candidate_ok = None   # lazy health verdict on entry.params
            for j, (req, summary, pend) in enumerate(entry.items):
                T = req.budget_steps
                hit = np.flatnonzero(earls[:T, j])
                stop = int(hit[0]) + 1 if hit.size else T
                stops[j] = stop
                best = _pooled_best(float(r0s[j]), rts[:stop, j])
                self.assessments += 1
                delta = summary["best_runtime_ns"] - best
                deltas[j] = delta
                if best < summary["best_runtime_ns"]:
                    wins[j] = delta
                    if not self.swap_cfg.staged:
                        # the immediate path — bitwise the pre-pipeline
                        # behavior: every per-window win swaps, in order
                        if tenant.quarantined:
                            continue    # breaker open: serve frozen
                        if candidate_ok is None:
                            # swap candidacy gate, once per entry: a
                            # non-finite/exploded tree never reaches a
                            # pool, win or not
                            candidate_ok = self.health.params_healthy(
                                entry.params)
                            if not candidate_ok:
                                self.health.rejected_params += 1
                                tenant.rejected_params += 1
                                self._note_bad(tenant)
                        if not candidate_ok:
                            continue
                        tenant.swap.candidates += 1
                        tenant.swap.immediate += 1
                        tenant.swap.promoted += 1
                        self.hot_swap(entry.index_type, req,
                                      window=pend["window"] - 1,
                                      params=entry.params)
                        summary["swapped"] = True
            if wins and self.swap_cfg.staged and not tenant.quarantined:
                self._judge_staged(entry, deltas, wins, stops, rts)
            self.phase_ms["assess"] += 1e3 * (time.perf_counter() - t0)

    # ------------------------------------------- the swap state machine
    # verdict win -> [CI gate] -> candidate -> [canary trial] -> promoted
    # -> [watch window], with auto-rollback out of both bracketed stages.
    # All host-side bookkeeping: the only device work is the same pure
    # buffer updates the immediate path already performed.

    def _judge_staged(self, entry: _PendingAssess, deltas: dict,
                      wins: dict, stops: dict, rts: np.ndarray):
        """Entry-level verdict for the staged pipeline: one pooled
        assessment produces one candidate at most (the window with the
        largest improvement), gated on the bootstrap CI when armed."""
        tenant = self.tenants[entry.index_type]
        if not self.health.params_healthy(entry.params):
            # swap candidacy gate: an unhealthy tree is rejected before
            # the CI gate can even look at it — it never becomes a
            # candidate, never touches a canary lane
            self.health.rejected_params += 1
            tenant.rejected_params += 1
            self._note_bad(tenant)
            return
        if self.swap_cfg.ci_gate:
            if len(entry.items) > 1:
                samples = list(deltas.values())
            else:
                # a single-window dispatch has one per-window delta — fall
                # back to per-step deltas (online best vs each offline
                # assessment step) so the bootstrap still sees spread
                j = next(iter(deltas))
                summary = entry.items[j][1]
                samples = (summary["best_runtime_ns"]
                           - rts[:stops[j], j]).tolist()
            lo, _ = _bootstrap_ci(samples, self.swap_cfg.ci_level,
                                  self.swap_cfg.ci_resamples, self._ci_rng)
            if lo <= 0.0:
                # the interval does not exclude zero: a win this noisy is
                # not evidence the offline model is better
                tenant.swap.ci_rejected += 1
                return
        self._on_win(entry, max(wins, key=wins.get))

    def _on_win(self, entry: _PendingAssess, j: int):
        """One gated candidate: promote immediately (canary stage off),
        defer (a trial is already live), or start the canary trial."""
        req, summary, pend = entry.items[j]
        tenant = self.tenants[entry.index_type]
        tenant.swap.candidates += 1
        window = pend["window"] - 1
        if not self.swap_cfg.canary:
            # CI-gate-only posture: promote pool-wide now, but still arm
            # the post-promotion watch so the monitor can revert it
            trial = _SwapTrial(entry.index_type, req, window, summary,
                               copy_state(entry.params),
                               copy_state(tenant.online),
                               self._baseline_mean(tenant))
            tenant.swap.immediate += 1
            self.trials[entry.index_type] = trial
            self._promote_trial(entry.index_type, trial)
            return
        if entry.index_type in self.trials:
            tenant.swap.deferred += 1
            summary["swap_deferred"] = True
            return
        self._start_trial(entry, j)

    @staticmethod
    def _baseline_mean(tenant: _TenantO2) -> float | None:
        return (float(np.mean(tenant.baseline))
                if tenant.baseline else None)

    def _canary_lanes(self, slots: int) -> list[int]:
        """The trailing `canary_fraction` of a pool's lanes (at least
        one; at most slots-1 so a multi-lane pool keeps a control arm)."""
        n = max(1, int(round(self.swap_cfg.canary_fraction * slots)))
        if slots > 1:
            n = min(n, slots - 1)
        return list(range(slots - n, slots))

    def _start_trial(self, entry: _PendingAssess, j: int):
        """Land the candidate on a lane fraction of every pool of the
        tenant — a pure buffer update per pool (`set_canary` builds the
        mixed per-lane tree for the resident `per_lane` step program)."""
        req, summary, pend = entry.items[j]
        tenant = self.tenants[entry.index_type]
        pools = [p for pk, p in self.pools.items()
                 if pk[0] == entry.index_type]
        if not pools:
            # nothing to canary on (the tenant's pools were torn down
            # between dispatch and drain); treat as deferred
            tenant.swap.deferred += 1
            return
        candidate = copy_state(entry.params)
        trial = _SwapTrial(entry.index_type, req, pend["window"] - 1,
                           summary, candidate, copy_state(tenant.online),
                           self._baseline_mean(tenant))
        for pool in pools:
            pool.set_canary(self._canary_lanes(pool.slots), candidate)
        if self.health.fire("canary_loss"):
            trial.forced_loss = True
        self.trials[entry.index_type] = trial
        tenant.swap.canaried += 1
        tenant.swap.active_state = "canary"
        summary["canaried"] = True

    def _observe_retired(self, retired: list):
        """Feed retired-episode scores into the tenant baselines and any
        live trial's arms (the pool lane-tagged each summary at retire
        while its canary was live)."""
        for req, summary in retired:
            tenant = self.tenants[req.index_type]
            trial = self.trials.get(req.index_type)
            score = _lane_score(summary)
            if trial is None:
                tenant.baseline.append(score)
            elif trial.state == "canary":
                if "canary" in summary:
                    (trial.canary_scores if summary["canary"]
                     else trial.control_scores).append(score)
            else:
                trial.post_scores.append(score)

    def _advance_trials(self):
        """Decide every live trial that has enough evidence: canary arms
        compare once the canary side has `canary_min_episodes` retired
        summaries (against concurrent control lanes, falling back to the
        tenant's pre-swap baseline); promoted trials regression-check
        against that baseline.  Idle canaries time out into rollback."""
        cfg = self.swap_cfg
        for it, trial in list(self.trials.items()):
            if trial.state == "canary":
                trial.ticks += 1
                if trial.forced_loss:
                    # injected canary loss (the chaos drill's repeated-
                    # rollback site): decide against it immediately
                    self._rollback_canary(it, trial)
                    continue
                if len(trial.canary_scores) >= cfg.canary_min_episodes:
                    control = (float(np.mean(trial.control_scores))
                               if len(trial.control_scores)
                               >= cfg.canary_min_episodes
                               else trial.baseline_mean)
                    if control is not None:
                        canary = float(np.mean(trial.canary_scores))
                        if canary <= control * (1.0 + cfg.canary_tolerance):
                            self._promote_trial(it, trial)
                        else:
                            self._rollback_canary(it, trial)
                        continue
                if trial.ticks > cfg.canary_timeout_ticks:
                    self._rollback_canary(it, trial)
            elif trial.state == "promoted":
                if trial.baseline_mean is not None and \
                        len(trial.post_scores) >= cfg.canary_min_episodes:
                    post = float(np.mean(trial.post_scores))
                    if post > trial.baseline_mean * \
                            (1.0 + cfg.rollback_tolerance):
                        self._rollback_promoted(it, trial, "regression")

    def _promote_trial(self, index_type: str, trial: _SwapTrial):
        """Pool-wide promotion of a trial's candidate: clear the canary
        mix, snapshot the rollback state (pre-swap online tree + monitor
        reference), then run the standard hot swap.  The cleared pools
        re-enter the shared-params step program — still resident, still
        zero re-traces."""
        tenant = self.tenants[index_type]
        for pk, pool in self.pools.items():
            if pk[0] == index_type and pool.canary_lanes is not None:
                pool.clear_canary()
        # refresh the rollback snapshot at the promotion boundary (the
        # online tree cannot have moved during the trial — wins defer —
        # but the monitor reference may have: windows kept arriving)
        trial.prev_online = copy_state(tenant.online)
        mon = tenant.monitor
        trial.monitor_ref = (None if mon.ref_quantiles is None
                             else mon.ref_quantiles.copy(), mon.ref_wr)
        trial.prev_anchor = mon.anchors[-1] if mon.anchors else None
        self.hot_swap(index_type, trial.req, window=trial.window,
                      params=trial.candidate)
        trial.summary["swapped"] = True
        trial.state = "promoted"
        trial.ticks = 0
        trial.watch_windows = 0
        trial.post_scores = []
        tenant.swap.promoted += 1
        tenant.swap.active_state = "promoted"

    def _rollback_canary(self, index_type: str, trial: _SwapTrial,
                         note: bool = True):
        """Abort a canary: drop the per-lane mix on every pool — the
        incumbent `pool.params` was never touched, so this *is* the
        bitwise revert — and retire the trial.  A rollback is a breaker
        strike (`note`), except when the breaker itself triggered it."""
        for pk, pool in self.pools.items():
            if pk[0] == index_type and pool.canary_lanes is not None:
                pool.clear_canary()
        tenant = self.tenants[index_type]
        tenant.swap.rolled_back_canary += 1
        tenant.swap.active_state = None
        trial.summary["swap_rolled_back"] = "canary"
        del self.trials[index_type]
        if note:
            self._note_bad(tenant)

    def _rollback_promoted(self, index_type: str, trial: _SwapTrial,
                           reason: str, note: bool = True):
        """Revert a promoted swap bitwise: restore the pre-swap online
        tree on every pool and the divergence monitor's pre-promotion
        reference distribution (re-appending the pre-swap anchor keeps
        the monitor's anchors-history invariant — the revert stays
        visible)."""
        tenant = self.tenants[index_type]
        tenant.online = trial.prev_online
        for pk, pool in self.pools.items():
            if pk[0] == index_type:
                pool.params = jax.device_put(tenant.online["params"],
                                             pool.replicated)
        if trial.monitor_ref is not None:
            mon = tenant.monitor
            mon.ref_quantiles, mon.ref_wr = trial.monitor_ref
            if trial.prev_anchor is not None:
                mon.anchors.append(trial.prev_anchor)
        tenant.swap.rolled_back_promoted += 1
        tenant.swap.active_state = None
        trial.summary["swap_rolled_back"] = reason
        del self.trials[index_type]
        if note:
            self._note_bad(tenant)

    def _close_trial(self, index_type: str):
        """A promoted trial survived its watch window: drop the rollback
        snapshots and free the tenant for the next candidate (a
        surviving swap also clears the tenant's breaker streak)."""
        self.trials.pop(index_type, None)
        tenant = self.tenants[index_type]
        tenant.swap.active_state = None
        tenant.bad_streak = 0

    def swap_stats(self) -> SwapStats:
        """The `stats()["swaps"]` block's data (the service adds SLO
        breach attribution before rendering)."""
        return SwapStats(per_tenant={it: t.swap
                                     for it, t in self.tenants.items()})

    def health_stats(self) -> HealthStats:
        """The `stats()["health"]` block's data: the guard's aggregate
        counters plus the currently quarantined tenant list."""
        g = self.health
        return HealthStats(
            state="degraded" if g.degraded else "healthy",
            rejected_params=g.rejected_params,
            retries=g.retries,
            annex_demotions=g.annex_demotions,
            annex_recoveries=g.annex_recoveries,
            dropped_dispatches=g.dropped_dispatches,
            quarantines=g.quarantines,
            quarantine_releases=g.quarantine_releases,
            degraded_ticks=g.degraded_ticks,
            quarantined=sorted(it for it, t in self.tenants.items()
                               if t.quarantined))

    def hot_swap(self, index_type: str, req,
                 window: int | None = None, params=None):
        """Promote the offline model to online: a pure buffer update on
        every pool of the tenant.  Params are program *inputs*, not traced
        constants, so the K-ladder compiled-program cache is untouched —
        no re-trace, no re-compile (asserted in tests/test_o2_service.py).
        `params` is the judged tree an assessment verdict promotes (the
        concurrent learner may have advanced past it by drain time);
        None — the strict/serial case and direct callers — promotes the
        offline tail.  `window` is the retired window whose data
        re-anchors the monitor (under concurrent serving it may not be
        the latest one observed).

        Swap timing rides the service's injectable clock (not a bare
        `time.perf_counter`), so `mean_swap_ms` shares the timebase of
        every other latency the service reports — and tests can pin it
        with a fake clock."""
        t0 = self.clock()
        tenant = self.tenants[index_type]
        # real copies: the next fine-tune round donates the offline
        # tree's buffers, and the promoted online model must outlive that
        tenant.online = copy_state(tenant.offline)
        if params is not None:
            tenant.online["params"] = copy_state(params)
        for pk, pool in self.pools.items():
            if pk[0] == index_type:
                pool.params = jax.device_put(tenant.online["params"],
                                             pool.replicated)
        tenant.monitor.re_anchor(req.data_keys, req.wr_ratio,
                                 window=window)
        tenant.swaps += 1
        tenant.swap_times_s.append(self.clock() - t0)

    def flush(self, deadline_s: float | None = None) -> dict:
        """Settle all in-flight O2 work: the assessment backlog drains
        through the annex, every verdict lands (hot-swaps applied), and
        the trailing offline learner catches up.  Blocks; callers that
        only need serving results never have to.

        Returns a flush report instead of hanging: with `deadline_s`
        set, whatever has not settled by then is abandoned and counted;
        without one, a demoted annex (which can never settle its
        backlog) still abandons rather than spinning forever, and hung
        dispatches are abandoned by the drain watchdog — the historical
        block-until-settled contract only ever applies to work that can
        actually finish."""
        t0 = time.monotonic()
        report = {"deadline_hit": False, "abandoned_backlog": 0,
                  "abandoned_inflight": 0, "elapsed_s": 0.0}
        while self.backlog or self.inflight:
            out_of_time = deadline_s is not None and \
                (time.monotonic() - t0) >= deadline_s
            # a paused annex with nothing in flight cannot make progress
            # until its cooloff elapses — and a failed half-open probe
            # restarts that clock, so waiting it out is unbounded
            stalled = self.health.o2_paused() and not self.inflight
            if out_of_time or stalled:
                report["deadline_hit"] = out_of_time
                report["abandoned_backlog"] = len(self.backlog)
                report["abandoned_inflight"] = len(self.inflight)
                self.health.dropped_dispatches += len(self.inflight)
                self.backlog.clear()
                self.inflight.clear()
                break
            self._pump_assessments()
            remaining = (None if deadline_s is None
                         else max(deadline_s - (time.monotonic() - t0),
                                  0.0))
            self.drain(block=True, deadline_s=remaining)
        if not report["deadline_hit"]:
            for tenant in self.tenants.values():
                if tenant.offline is not None:
                    jax.block_until_ready(tenant.offline["params"])
        report["elapsed_s"] = time.monotonic() - t0
        return report

    # ------------------------------------------------------------- stats
    def stats_block(self) -> O2Stats:
        tenants = {
            it: TenantO2Stats(
                windows=t.monitor.windows_seen,
                diverged=t.monitor.diverged_count,
                swaps=t.swaps,
                offline_updates=t.offline_updates,
                finetune_skipped=t.finetune_skipped,
                replay_size=t.replay.size,
                mean_swap_ms=(1e3 * float(np.mean(t.swap_times_s))
                              if t.swap_times_s else 0.0),
                tier=t.tier)
            for it, t in self.tenants.items()}
        tiers = {"hot": 0, "warm": 0, "cold": 0}
        for t in self.tenants.values():
            tiers[t.tier] += 1
        return O2Stats(
            tenants=tenants,
            # host-side time spent driving each O2 phase (dispatch +
            # verdict fetches — device execution overlaps serving)
            phase_ms={k: round(v, 3) for k, v in self.phase_ms.items()},
            assessments=self.assessments,
            inflight_assessments=len(self.inflight),
            pending_missing=self.pending_missing,
            # annex placement (the topology layer's verdict): a shared
            # annex queues learner/assessment work behind serving fetches
            annex_width=self.topology.annex.width,
            annex_shared=self.topology.annex_shared,
            warm_starts=self.warm_starts,
            tenants_hot=tiers["hot"],
            tenants_warm=tiers["warm"],
            tenants_cold=tiers["cold"],
            device_bytes=sum(t.device_bytes()
                             for t in self.tenants.values()),
            host_bytes=sum(t.host_bytes()
                           for t in self.tenants.values()),
            fleet=(self.fleet.stats() if self.fleet is not None
                   else FleetLearner.empty_stats()))

    def stats(self) -> dict:
        return self.stats_block().as_dict()
