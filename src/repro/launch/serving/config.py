"""Consolidated serving configuration: one frozen object per service.

`TuningService` grew one constructor kwarg per PR (`slots`,
`horizon_cap`, `seed`, `o2`, `policy`, `slo`, `clock`, `topology` — and
this PR adds the swap-pipeline knobs).  `ServeConfig` folds all of them
into a single frozen dataclass so a service is constructed as

    TuningService(agents, config=ServeConfig(slots=8, o2=..., swap=...))

and a deployment's serving posture is one value that can be logged,
diffed, and passed around.  The legacy kwarg form still works through a
thin adapter in `TuningService.__init__` (it builds the equivalent
`ServeConfig` and emits a `DeprecationWarning`); mixing `config=` with
legacy kwargs is an error.

`SwapConfig` is the trust policy for hot-swaps (the paper's O2 promotion
step, hardened for fleet scale where one noisy assessment verdict would
otherwise be a mass regression):

  * **CI gate** (`ci_gate`) — a pooled assessment dispatch already
    carries up to `2*slots` windows; instead of promoting on any single
    window's `_pooled_best` win, bootstrap the per-window
    offline-vs-online deltas into a confidence interval and promote only
    when the interval excludes zero (UTune's uncertainty-aware tuning,
    PAPERS.md).
  * **Canary stage** (`canary`) — a winning swap first lands on
    `canary_fraction` of each pool's lanes.  Params are per-lane program
    *inputs* (`programs._step_program(per_lane=True)`), so the mixed
    pool is a pure buffer update — zero re-traces.  Canary lanes'
    retired summaries are scored against the concurrent control lanes
    (or the tenant's rolling pre-swap baseline when the pool has no
    control lane) before pool-wide promotion.
  * **Auto-rollback** — the pre-swap tree is kept per tenant; a
    promotion reverts bitwise when the post-swap `DivergenceMonitor`
    re-fires within `rollback_windows` observed windows, or when
    post-promotion summaries regress past `rollback_tolerance`.

Both gates default **off**: the default `SwapConfig()` reproduces the
immediate-swap path bitwise, so every serial-parity guarantee is
untouched (tests/test_o2_service.py runs unmodified).
"""
from __future__ import annotations

import dataclasses
from typing import Callable

from repro.kernels.dispatch import KernelConfig
from repro.launch.serving.fleet import FleetConfig
from repro.launch.serving.health import HealthConfig
from repro.launch.serving.o2_runtime import O2ServiceConfig

__all__ = ["FleetConfig", "KernelConfig", "ServeConfig", "SwapConfig",
           "config_from_legacy", "LEGACY_KWARGS"]
from repro.launch.serving.scheduler import SlotPolicy
from repro.launch.serving.slo import SLOConfig
from repro.launch.serving.topology import ServingTopology


@dataclasses.dataclass(frozen=True)
class SwapConfig:
    """Trust policy for promoting offline params into serving pools."""

    # ---- verdict gate: bootstrap CI over the pooled assessment windows
    # promote only when the bootstrap CI on the offline-vs-online delta
    # excludes zero (False -> today's per-window `_pooled_best` check)
    ci_gate: bool = False
    ci_level: float = 0.95          # two-sided CI coverage
    ci_resamples: int = 200         # bootstrap draws per verdict
    ci_seed: int = 0                # seeds the (deterministic) resampler

    # ---- canary stage: a winning swap serves a lane fraction first
    canary: bool = False
    canary_fraction: float = 0.25   # of each pool's lanes (>=1 lane)
    # retired canary-lane summaries required before the arm comparison
    canary_min_episodes: int = 2
    # canary arm may be this much worse (relative, on tuned-over-default
    # runtime) than the control arm and still promote
    canary_tolerance: float = 0.05
    # service ticks a canary may idle without enough samples before it is
    # rolled back (a canary must never become a permanent mixed pool)
    canary_timeout_ticks: int = 256

    # ---- auto-rollback: the post-promotion watch window
    # observed windows after a promotion during which a divergence-monitor
    # re-fire (or a score regression) reverts the swap bitwise
    rollback_windows: int = 4
    # post-promotion summaries may be this much worse (relative) than the
    # tenant's pre-swap rolling baseline before the swap is reverted
    rollback_tolerance: float = 0.10
    # retired-episode scores kept in the tenant's rolling baseline (the
    # control arm for slots=1 pools and the rollback regression check)
    baseline_window: int = 32

    def __post_init__(self):
        if not 0.0 < self.ci_level < 1.0:
            raise ValueError(f"ci_level={self.ci_level} must be in (0, 1)")
        if not 0.0 < self.canary_fraction <= 1.0:
            raise ValueError(f"canary_fraction={self.canary_fraction} "
                             f"must be in (0, 1]")
        if self.canary_min_episodes < 1:
            raise ValueError("canary_min_episodes must be >= 1")
        if self.rollback_windows < 0:
            raise ValueError("rollback_windows must be >= 0")

    @property
    def staged(self) -> bool:
        """Whether any stage beyond the immediate swap is armed."""
        return self.ci_gate or self.canary


@dataclasses.dataclass(frozen=True)
class ServeConfig:
    """Everything `TuningService` needs beyond the agents themselves.

    Field-for-field the old constructor kwargs, plus `swap` (the
    hot-swap trust policy).  `policy`, `clock`, and `topology` keep
    their None-means-default semantics (static policy, `time.
    perf_counter`, flat host topology) so `ServeConfig()` is exactly the
    historical default service.
    """

    slots: int = 4
    horizon_cap: int = 256
    seed: int = 0
    o2: O2ServiceConfig = dataclasses.field(default_factory=O2ServiceConfig)
    policy: SlotPolicy | None = None
    slo: SLOConfig = dataclasses.field(default_factory=SLOConfig)
    clock: Callable[[], float] | None = None
    topology: ServingTopology | None = None
    swap: SwapConfig = dataclasses.field(default_factory=SwapConfig)
    # the fault-tolerance layer (param-health guards, annex watchdog,
    # tenant circuit breakers, fault injection — launch/serving/health.py).
    # Enabled by default: the guards are read-only on healthy paths, so
    # every parity guarantee holds with them on
    health: HealthConfig = dataclasses.field(default_factory=HealthConfig)
    # kernel execution posture (kernels/dispatch.py): threaded into every
    # pool's and tenant's EnvConfig, so the Pallas probe gate and the
    # fused-tick capture follow one config for the whole service.  The
    # default resolves to the bitwise jnp reference on CPU and the
    # compiled kernels on accelerators; `fused_tick` (default on) fuses
    # the capture append into the step program in every mode
    kernel: KernelConfig = dataclasses.field(default_factory=KernelConfig)

    def __post_init__(self):
        if self.slots < 1:
            raise ValueError(f"slots={self.slots} must be >= 1")
        if self.horizon_cap < 1:
            raise ValueError(f"horizon_cap={self.horizon_cap} must be >= 1")


# the legacy TuningService kwargs the adapter accepts, in their
# historical positional order (shared with tests and the deprecation
# message so the two never drift)
LEGACY_KWARGS = ("slots", "horizon_cap", "seed", "o2", "policy", "slo",
                 "clock", "topology", "swap")


def config_from_legacy(**kwargs) -> ServeConfig:
    """Build a `ServeConfig` from the pre-consolidation kwarg form.
    None values fall through to the dataclass defaults, matching the old
    constructor's `x if x is not None else default` handling."""
    unknown = set(kwargs) - set(LEGACY_KWARGS)
    if unknown:
        raise TypeError(f"unknown TuningService kwargs: {sorted(unknown)} "
                        f"(accepted: {list(LEGACY_KWARGS)})")
    return ServeConfig(**{k: v for k, v in kwargs.items() if v is not None})
