"""Typed `stats()` contract for the serving stack.

The nested dicts `TuningService.stats()` returns were hand-assembled in
three modules (`service.py`, `o2_runtime.py`, `slo.py`) with no schema —
nothing pinned the keys dashboards and the CI gates read.  This module
defines every block as a dataclass with an `as_dict()` that produces the
exact historical dict shape (pinned by the golden-keys test in
tests/test_swap_pipeline.py), so the schema finally lives in one place:

    service_steps, episode_steps, completed, queued, pools, devices,
    topology, program_misses, program_hits, programs_resident
    per_pool.<pool-key>   -> PoolStats      (slots/active/peak/resizes)
    scheduler             -> SchedulerStats (policy, resize_events)
    slo                   -> SLOStats       (percentiles + breaches)
    o2                    -> O2Stats        (per-tenant + phase/annex)
    swaps                 -> SwapStats      (the hot-swap state machine)
    health                -> HealthStats    (the fault-tolerance layer)

`swaps` is the one new block this PR adds (the canary/rollback pipeline's
counters); every other block is shape-identical to what PR 4/5 shipped —
existing assertions like ``slo["breaches"] == {...}`` hold unchanged.
The schema is documented in README "Safe hot-swaps".
"""
from __future__ import annotations

import dataclasses


@dataclasses.dataclass
class BreachStats:
    """`stats()["slo"]["breaches"]` — cumulative breach counters."""
    dropped_queued: int = 0
    dropped_running: int = 0
    pre_dropped: int = 0
    truncated: int = 0

    def as_dict(self) -> dict:
        return dataclasses.asdict(self)


@dataclasses.dataclass
class SLOStats:
    """`stats()["slo"]` — latency percentiles + breach accounting."""
    queue_wait_ms: dict          # {"p50": ms, "p95": ms, "p99": ms}
    serve_ms: dict
    breaches: BreachStats
    tracked: int

    def as_dict(self) -> dict:
        return {"queue_wait_ms": dict(self.queue_wait_ms),
                "serve_ms": dict(self.serve_ms),
                "breaches": self.breaches.as_dict(),
                "tracked": self.tracked}


@dataclasses.dataclass
class PoolStats:
    """One `stats()["per_pool"]` entry — occupancy + resize history."""
    slots: int
    active: int
    peak_slots: int
    resizes: dict                # {"grow": n, "shrink": n}

    def as_dict(self) -> dict:
        return {"slots": self.slots, "active": self.active,
                "peak_slots": self.peak_slots,
                "resizes": dict(self.resizes)}


@dataclasses.dataclass
class SchedulerStats:
    """`stats()["scheduler"]` — the admission policy's observability."""
    policy: str
    resize_events: int

    def as_dict(self) -> dict:
        return dataclasses.asdict(self)


@dataclasses.dataclass
class TenantO2Stats:
    """One tenant's entry inside `stats()["o2"]`."""
    windows: int
    diverged: int
    swaps: int
    offline_updates: int
    finetune_skipped: int
    replay_size: int
    mean_swap_ms: float
    tier: str = "hot"            # fleet tier ("hot" off-fleet, always)

    def as_dict(self) -> dict:
        return dataclasses.asdict(self)


@dataclasses.dataclass
class O2Stats:
    """`stats()["o2"]` — per-tenant blocks at the top level (the
    historical flat shape) plus the runtime-wide counters beside them."""
    tenants: dict                # index_type -> TenantO2Stats
    phase_ms: dict               # {"capture": ms, "finetune": ..., ...}
    assessments: int
    inflight_assessments: int
    pending_missing: int
    annex_width: int
    annex_shared: bool
    # fleet-mode counters (rendered unconditionally — zeros/"off" when
    # fleet mode is disabled, so dashboards never branch on presence)
    warm_starts: int = 0         # tenants seeded from a neighbor
    tenants_hot: int = 0
    tenants_warm: int = 0
    tenants_cold: int = 0
    device_bytes: int = 0        # approx O2 device residency, all tenants
    host_bytes: int = 0          # approx spilled/evicted host residency
    fleet: dict = dataclasses.field(default_factory=dict)

    def as_dict(self) -> dict:
        out = {it: t.as_dict() for it, t in self.tenants.items()}
        out["phase_ms"] = dict(self.phase_ms)
        out["assessments"] = self.assessments
        out["inflight_assessments"] = self.inflight_assessments
        out["pending_missing"] = self.pending_missing
        out["annex_width"] = self.annex_width
        out["annex_shared"] = self.annex_shared
        out["warm_starts"] = self.warm_starts
        out["tenants_hot"] = self.tenants_hot
        out["tenants_warm"] = self.tenants_warm
        out["tenants_cold"] = self.tenants_cold
        out["device_bytes"] = self.device_bytes
        out["host_bytes"] = self.host_bytes
        out["fleet"] = dict(self.fleet)
        return out


@dataclasses.dataclass
class TenantSwapStats:
    """One tenant's hot-swap state-machine counters.

    A verdict win becomes a *candidate*; with the canary stage disabled
    it promotes *immediate*ly (today's path), otherwise it is *canaried*
    (or *deferred* while another trial is active).  A canary either
    *promote*s pool-wide or rolls back; a promotion may still roll back
    inside the post-swap watch window.  `ci_rejected` counts per-window
    wins the bootstrap CI gate refused.
    """
    candidates: int = 0
    immediate: int = 0
    canaried: int = 0
    deferred: int = 0
    promoted: int = 0
    ci_rejected: int = 0
    rolled_back_canary: int = 0
    rolled_back_promoted: int = 0
    active_state: str | None = None     # "canary" | "promoted" | None

    def as_dict(self) -> dict:
        d = dataclasses.asdict(self)
        d["rolled_back"] = self.rolled_back_canary + \
            self.rolled_back_promoted
        return d


@dataclasses.dataclass
class SwapStats:
    """`stats()["swaps"]` — the new block: the swap pipeline's verdict
    state machine, totalled and per tenant, plus SLO-breach attribution
    (breaches that landed while a canary/watch trial was live)."""
    per_tenant: dict             # index_type -> TenantSwapStats
    breaches_during_trial: int = 0

    def as_dict(self) -> dict:
        totals = TenantSwapStats()
        for t in self.per_tenant.values():
            for f in ("candidates", "immediate", "canaried", "deferred",
                      "promoted", "ci_rejected", "rolled_back_canary",
                      "rolled_back_promoted"):
                setattr(totals, f, getattr(totals, f) + getattr(t, f))
        out = totals.as_dict()
        del out["active_state"]          # meaningless when totalled
        out["per_tenant"] = {it: t.as_dict()
                             for it, t in self.per_tenant.items()}
        out["breaches_during_trial"] = self.breaches_during_trial
        return out


@dataclasses.dataclass
class HealthStats:
    """`stats()["health"]` — the fault-tolerance layer's counters
    (rendered whenever O2 is enabled; see launch/serving/health.py).

    `state` is the annex's view: "healthy" or "degraded" (demoted, O2
    paused or half-open).  `quarantined` lists tenants whose breaker is
    currently open — their pools serve frozen params while their O2
    loop waits out the cooloff."""
    state: str = "healthy"
    rejected_params: int = 0
    retries: int = 0
    annex_demotions: int = 0
    annex_recoveries: int = 0
    dropped_dispatches: int = 0
    quarantines: int = 0
    quarantine_releases: int = 0
    degraded_ticks: int = 0
    quarantined: list = dataclasses.field(default_factory=list)

    def as_dict(self) -> dict:
        d = dataclasses.asdict(self)
        d["quarantined"] = list(self.quarantined)
        return d


@dataclasses.dataclass
class ServiceStats:
    """The whole `TuningService.stats()` document."""
    service_steps: int
    episode_steps: int
    completed: int
    queued: int
    pools: int
    devices: int
    topology: dict
    program_misses: int
    program_hits: int
    programs_resident: int
    per_pool: dict               # pool-key string -> PoolStats
    scheduler: SchedulerStats
    slo: SLOStats
    o2: O2Stats | None = None
    swaps: SwapStats | None = None
    health: HealthStats | None = None

    def as_dict(self) -> dict:
        out = {
            "service_steps": self.service_steps,
            "episode_steps": self.episode_steps,
            "completed": self.completed,
            "queued": self.queued,
            "pools": self.pools,
            "devices": self.devices,
            "topology": dict(self.topology),
            "program_misses": self.program_misses,
            "program_hits": self.program_hits,
            "programs_resident": self.programs_resident,
            "per_pool": {k: p.as_dict() for k, p in self.per_pool.items()},
            "scheduler": self.scheduler.as_dict(),
            "slo": self.slo.as_dict(),
        }
        if self.o2 is not None:
            out["o2"] = self.o2.as_dict()
        if self.swaps is not None:
            out["swaps"] = self.swaps.as_dict()
        if self.health is not None:
            out["health"] = self.health.as_dict()
        return out
