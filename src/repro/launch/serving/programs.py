"""Process-wide compiled-program cache for the serving stack.

Every jitted program the serving layers dispatch lives here, keyed on
(`topology.DeviceSlice`, frozen configs, shapes) so every
`TuningService` instance — and every pool within one — shares the same
jitted callables and their compiled executables.  A per-service dict on
top of this would recompile per instance, which is exactly the
recompile-on-mixed-streams failure this engine exists to avoid.

Slices hash by their device ids (display names excluded), so two
topologies whose slices cover the same devices — a flat host layout and
a carved pod mesh, say — alternate between the *same* resident
executables (tests/test_topology.py asserts zero re-traces across
equal-shape topologies).

The same cache is what makes **pool resizing** cheap: a pool growing
from B to B' slots re-enters the *same* `_step_program` callable with a
wider carry — jax traces the new shape once, and shrinking back to a
previously-served width re-uses its resident executable, so a
grow→shrink cycle after warmup binds zero new programs
(tests/test_serving_layers.py asserts this).

Buffer donation (the slot carry, capture buffers, learner state — the
largest live trees, all rebound every tick) is gated off the CPU
backend via `repro.core.replay.donate_argnums`: the CPU PJRT donation
hand-off synchronizes with pending readers (~6-70 ms per dispatch,
measured on jax 0.4.37) for no memory win.  The helper probes the
backend lazily at program-build time, so importing this module never
initializes jax before the operator's XLA_FLAGS are set.
tests/test_o2_service.py asserts the donating programs stay
re-trace-free either way.
"""
from __future__ import annotations

from functools import lru_cache

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.core import networks as nets
from repro.core.etmdp import batched_episode_scan, transition_view
from repro.core.parallel import mapped_reset
from repro.core.replay import donate_argnums
from repro.kernels.fused_tick.ops import fused_capture_core
from repro.kernels.fused_tick.ref import fused_capture_ref
from repro.launch.serving.topology import DeviceSlice, _slice_mesh
from repro.runtime.mesh_utils import shard_map_compat


def _pow2_ladder(n: int) -> list[int]:
    out, k = [], 1
    while k <= n:
        out.append(k)
        k *= 2
    return out


def _admit_key_chain(window_key):
    """O2System.tune_window's PRNG discipline for one window key: the
    episode runs on the second split (k_on) and a diverged window's
    assessment on the second split of the remainder (k_off)."""
    remainder, k_on = jax.random.split(window_key)
    k_off = jax.random.split(remainder)[1]
    return k_on, k_off


# one dispatch derives a whole admission wave's episode + assessment keys
# (vmap over the integer threefry core is bitwise the per-key splits)
_batched_admit_keys = jax.jit(jax.vmap(_admit_key_chain))


def _mesh_for(device_ids: tuple):
    """Back-compat shim for raw-id callers (the tune_serve re-export):
    the topology layer's slice mesh is the one source of truth now."""
    return _slice_mesh(tuple(device_ids), "slots")


@lru_cache(maxsize=None)
def _step_program(slice_: DeviceSlice, net_cfg, env_cfg, et_cfg, k: int,
                  per_lane: bool = False, capture: bool = False):
    """K-step slot program: scan over K ticks of the bitwise-stable
    one-tick map body, lanes sharded over the slice.  The carry is
    donated — every caller rebinds it to the program's output, and the
    donation lets XLA write the new carry into the old one's buffers
    instead of allocating a fresh slot-state tree per tick.

    `per_lane=True` is the canary-pool variant: params carry a leading
    slot axis and shard with the lanes, so a pool may serve candidate
    params on a lane fraction while control lanes keep the incumbent —
    a *pure buffer update* relative to this resident program.  The lane
    math is the same mapped body either way (`batched_episode_scan_lanes`
    maps params instead of closing over them), so control lanes stay
    bitwise-equal to the shared-params program.  All variants live in
    this one lru cache: `programs_resident` counts them together, which
    is what lets tests assert a whole canary→promote/rollback cycle
    binds zero new programs after warmup.

    `capture=True` is the fused-tick variant: the program takes the
    pool's `[B, H, wide]` capture buffer and `[B]` pre-tick offsets as
    extra operands and appends the tick's transition view in place
    (`kernels/fused_tick`), so one dispatch covers scan + capture — no
    `[K, B, wide]` intermediate crosses a program boundary per tick.
    The append is pure data movement (bitwise the historical
    `_capture_write` program in every kernel mode), so serving results
    and ring contents are unchanged; the capture-tail kernel mode
    follows `env_cfg.kernel` like the read probes inside the scan."""
    mesh = slice_.mesh()
    ax = slice_.axis

    if per_lane:
        from repro.core.etmdp import batched_episode_scan_lanes

        def scan_core(p, c, n):
            return batched_episode_scan_lanes(p, c, n, k, net_cfg,
                                              env_cfg, et_cfg, False)
        p_spec = P(ax)
    else:
        def scan_core(p, c, n):
            return batched_episode_scan(p, c, n, k, net_cfg, env_cfg,
                                        et_cfg, False)
        p_spec = P()

    if capture:
        kmode = env_cfg.kernel.resolved()

        def core(p, c, n, cap, off):
            c2, out = scan_core(p, c, n)
            cap2 = fused_capture_core(cap, transition_view(out), off,
                                      kmode)
            return c2, out, cap2

        return jax.jit(shard_map_compat(
            core, mesh, in_specs=(p_spec, P(ax), P(ax), P(ax), P(ax)),
            out_specs=(P(ax), P(None, ax), P(ax))),
            donate_argnums=donate_argnums(1, 3))

    return jax.jit(shard_map_compat(
        scan_core, mesh, in_specs=(p_spec, P(ax), P(ax)),
        out_specs=(P(ax), P(None, ax))),
        donate_argnums=donate_argnums(1))


@lru_cache(maxsize=None)
def _reset_program(slice_: DeviceSlice, env_cfg):
    """Batched admission: reset a wave of episodes in one (sharded over
    the slice when the wave divides it) program."""
    mesh = slice_.mesh()
    ax = slice_.axis

    def core(d, r, i, wr):
        return mapped_reset(env_cfg, d, {"reads": r, "inserts": i}, wr)

    return jax.jit(shard_map_compat(
        core, mesh,
        in_specs=(P(ax), P(ax), P(ax), P(ax)),
        out_specs=P(ax)))


@lru_cache(maxsize=None)
def _admit_scatter_program(slice_: DeviceSlice, net_cfg, slots: int):
    """Scatter freshly-reset episodes into their slots (padded entries
    target slot index B and are dropped)."""
    sharded = slice_.sharded()

    def scatter(carry, idx, keys, env_states, obs):
        def upd(buf, x):
            return buf.at[idx].set(x, mode="drop")
        zero_h = nets.zero_hidden(net_cfg, (idx.shape[0],))
        return {
            "key": upd(carry["key"], keys),
            "env": jax.tree.map(upd, carry["env"], env_states),
            "obs": upd(carry["obs"], obs),
            "h_a": tuple(upd(c, z) for c, z in zip(carry["h_a"], zero_h)),
            "h_q": tuple(upd(c, z) for c, z in zip(carry["h_q"], zero_h)),
            "b_t": upd(carry["b_t"],
                       jnp.zeros((idx.shape[0],), jnp.float32)),
        }

    # the carry is rebound to the output on every admission — donate it
    return jax.jit(scatter, out_shardings=sharded,
                   donate_argnums=donate_argnums(0))


@lru_cache(maxsize=None)
def _build_carry_program(slice_: DeviceSlice, net_cfg, slots: int):
    """Initial-wave fast path: construct the whole B-slot carry from a
    full batch of resets (no scatter)."""
    sharded = slice_.sharded()

    def build(keys, env_states, obs):
        return {
            "key": keys,
            "env": env_states,
            "obs": obs,
            "h_a": nets.zero_hidden(net_cfg, (slots,)),
            "h_q": nets.zero_hidden(net_cfg, (slots,)),
            "b_t": jnp.zeros((slots,), jnp.float32),
        }

    return jax.jit(build, out_shardings=sharded)


def _extract_episode_core(cap, slot, src_idx):
    """One retired slot's capture rows, compacted to the episode's padded
    length: the small packed `[Tp, wide]` array the ring ingests (pure
    gather — indices are inputs)."""
    return cap[slot][src_idx]


@lru_cache(maxsize=None)
def _extract_episode_program(slice_: DeviceSlice):
    """Replicated-output extract: every device of the pool's slice holds
    the episode rows, so when the ring home lives inside the slice (the
    flat host layout, and `from_mesh` row 0) its single-device `_place`
    resolves to a local copy instead of a cross-device reshard the next
    gather would wait on.  Pools pinned to rows that exclude the ring
    home still pay one cross-device hop per retired episode — a per-row
    ring home is a ROADMAP follow-up."""
    return jax.jit(_extract_episode_core, out_shardings=slice_.replicated())


# Append one tick's transition view into the `[B, H, wide]` packed
# capture buffer at each slot's episode offset.  The body now lives in
# `kernels/fused_tick/ref.py` (the fused step program's bitwise oracle);
# this standalone program is the unfused fallback when a pool runs with
# `KernelConfig(fused_tick=False)`.  Pure data movement (offsets are
# array inputs): compiles once per (K, shape) pair and never re-traces
# on admissions or swaps.
_capture_write_core = fused_capture_ref


@lru_cache(maxsize=None)
def _capture_write_program():
    # built lazily (donate_argnums probes the backend) so importing this
    # module keeps the no-jax-init contract of the docstring above
    return jax.jit(_capture_write_core, donate_argnums=donate_argnums(0))


def _capture_write(cap, new, offsets):
    return _capture_write_program()(cap, new, offsets)


@lru_cache(maxsize=None)
def _mixed_params_program(slice_: DeviceSlice, slots: int):
    """Build the per-lane params tree of a canary pool: lane b serves
    `cand` where `mask[b]`, the incumbent `base` otherwise.  Pure data
    movement (the mask is an array input), stacked over the lane axis
    and sharded with it — selecting which lanes canary never re-traces,
    and the output feeds `_step_program(per_lane=True)` directly."""
    sharded = slice_.sharded()

    def mix(base, cand, mask):
        def leaf(b, c):
            m = mask.reshape((slots,) + (1,) * b.ndim)
            return jnp.where(
                m, jnp.broadcast_to(c, (slots,) + c.shape),
                jnp.broadcast_to(b, (slots,) + b.shape))
        return jax.tree.map(leaf, base, cand)

    return jax.jit(mix, out_shardings=sharded)


@lru_cache(maxsize=None)
def _fleet_stack_program(k: int):
    """Pack k same-structure tenant trees onto a new leading tenant axis
    — the fleet round's stack step as one resident program per stack
    width (the eager per-leaf `jnp.stack` would dispatch leaves × k
    copy ops per round).  Pure data movement: lane i of the output is
    bitwise tree i, so the stacked fine-tune's serial parity is carried
    entirely by the per-lane math (`core.o2._fleet_finetune_program`).
    Keyed on k alone; XLA traces lazily per tree structure (learner
    states and batch stacks each get one executable per width)."""
    def stack(*trees):
        return jax.tree.map(lambda *xs: jnp.stack(xs), *trees)

    return jax.jit(stack)


@lru_cache(maxsize=None)
def _resize_program(slice_: DeviceSlice):
    """Slot-count resize: gather a pool's device state (the episode carry
    or the capture buffers) through a new→old slot index map, sharded
    over the pool's slice at the new width.  Growth pads fresh slots with
    slot 0's rows (valid, ignored state — the admission scatter
    overwrites them); shrink compacts the active slots to the front.
    Pure gather: indices are array inputs, so resizing never re-traces on
    the request stream — only the first visit to a new width traces its
    shape."""
    sharded = slice_.sharded()

    def gather(tree, idx):
        return jax.tree.map(lambda x: x[idx], tree)

    return jax.jit(gather, out_shardings=sharded)
