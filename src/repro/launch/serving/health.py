"""Serving-side fault tolerance: param-health guards, the annex
watchdog, and per-tenant circuit breakers.

The O2 loop assumes a lot of good behavior — every fine-tune round
converges to finite params, every annex dispatch returns, every
tenant's learner stays sane.  In production none of that holds: one
NaN gradient would hot-swap garbage into serving pools, one hung
dispatch would wedge `flush_o2()` forever, and one poisoned tenant
would starve the shared annex with doomed retries.  This module is the
containment layer between those failures and the frozen serving path:

* **Param-health guards** — every fine-tune result and every swap
  candidate passes a finite/norm check (`HealthGuard.params_healthy`,
  one small jitted reduction over the tree) before it may be published
  to assessments or promoted to pools.  Rejected params are counted and
  the tenant's last-good state is restored, so nothing non-finite can
  *enter* the canary pipeline, let alone serving.

* **Annex watchdog** — learner and pooled-assessment dispatches run
  under a bounded retry loop with seeded exponential backoff; a
  dispatched assessment that never completes is abandoned after
  `dispatch_timeout_s`.  Repeated consecutive failures demote the annex
  into a **degraded mode**: fine-tune and assessment pause, serving
  continues frozen on last-good params, and after `annex_cooloff_s` the
  next dispatch acts as a half-open probe — success recovers the annex
  automatically, failure restarts the cooloff.

* **Per-tenant circuit breakers** — a tenant whose fine-tunes keep
  producing unhealthy params, or whose canaries keep rolling back, is
  quarantined for `quarantine_windows` observed windows: its O2 loop
  (fine-tune, assessments, swap decisions) pauses while its pools keep
  serving the incumbent params, so one poisoned tenant cannot burn the
  shared annex.  Release is automatic once the cooloff elapses.

* **Deterministic fault injection** — `FaultPlan` schedules failures by
  per-site ordinal (the `runtime/fault.py` `FaultSite` idiom: the Nth
  fine-tune round NaNs out, the Nth assessment dispatch raises or
  hangs, the Nth canary trial loses), injectable via
  `HealthConfig(fault=...)` on `ServeConfig`.  The chaos drill
  (`benchmarks/slo_serve.py --scenario chaos`) drives all of the above
  through this plan and gates hard invariants in CI.

Guards observe, they don't perturb: with no faults and healthy params
every check is read-only, so all bitwise-parity guarantees (serial ≡
served, health-on ≡ health-off) hold with the guards enabled — which
is why they default on.

Watchdog and cooloff timing deliberately use the wall clock
(`time.monotonic`), not the service's injectable SLO clock: fake test
clocks advance on *call count*, which would fire spurious timeouts on
perfectly healthy dispatches.
"""
from __future__ import annotations

import dataclasses
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.runtime.fault import FaultSite, InjectedFailure

__all__ = ["FaultPlan", "HealthConfig", "HealthGuard", "InjectedFailure"]


@dataclasses.dataclass(frozen=True)
class FaultPlan:
    """Deterministic fault schedule, by 0-based per-site event ordinal.
    Empty tuples everywhere (the default) injects nothing."""
    # the Nth completed fine-tune round has its params overwritten with
    # NaN before the health gate sees them (a diverged learner)
    nan_finetune_rounds: tuple = ()
    # the Nth learner dispatch raises InjectedFailure (annex fault)
    fail_finetune_dispatches: tuple = ()
    # the Nth pooled-assessment dispatch raises InjectedFailure
    fail_assess_dispatches: tuple = ()
    # the Nth pooled-assessment dispatch succeeds but never reports
    # ready — the watchdog must abandon it
    hang_assess_dispatches: tuple = ()
    # the Nth canary trial is forced to lose (scores ignored)
    lose_canary_trials: tuple = ()


@dataclasses.dataclass(frozen=True)
class HealthConfig:
    """Knobs for the serving health layer (a `ServeConfig` sub-config).

    Defaults are production-shaped: guards on (they are read-only on
    healthy paths), generous watchdog timeout, no flush deadline (the
    historical blocking `flush_o2` contract)."""
    enabled: bool = True
    # reject any param tree whose global l2 norm exceeds this (or is
    # non-finite) — exploding-but-finite learners are caught too
    max_param_norm: float = 1e6
    # abandon a dispatched assessment not ready after this many wall
    # seconds (generous: pending device work, not compile time)
    dispatch_timeout_s: float = 30.0
    # retries per dispatch after the first attempt, with seeded
    # exponential backoff between attempts
    dispatch_retries: int = 2
    retry_backoff_s: float = 0.02
    backoff_seed: int = 0
    # consecutive dispatch failures before the annex is demoted, and the
    # wall-clock cooloff before a half-open probe may try again
    annex_failure_threshold: int = 2
    annex_cooloff_s: float = 1.0
    # consecutive bad events (rejected params, rollbacks) before a
    # tenant's O2 loop is quarantined, and the cooloff in *observed
    # windows* before it is released
    quarantine_threshold: int = 3
    quarantine_windows: int = 8
    # fleet mode: an opening breaker also spills the tenant's replay
    # pages to host (hot -> warm) — it cannot fine-tune during the
    # cooloff, so holding device pages buys nothing.  Serving params are
    # untouched either way
    quarantine_spills: bool = True
    # default deadline for `TuningService.flush_o2` (None -> block until
    # settled, the historical contract)
    flush_deadline_s: float | None = None
    fault: FaultPlan | None = None

    def __post_init__(self):
        if self.max_param_norm <= 0:
            raise ValueError("max_param_norm must be positive")
        if self.dispatch_retries < 0:
            raise ValueError("dispatch_retries must be >= 0")
        if self.annex_failure_threshold < 1:
            raise ValueError("annex_failure_threshold must be >= 1")
        if self.quarantine_threshold < 1:
            raise ValueError("quarantine_threshold must be >= 1")
        if self.quarantine_windows < 1:
            raise ValueError("quarantine_windows must be >= 1")


@jax.jit
def _tree_health(tree):
    """(all-finite, global l2 norm) over every leaf of a param tree —
    one small fused reduction, dispatched wherever the tree lives.
    float32 accumulation on purpose: an exploding tree overflowing the
    sum-of-squares to inf *is* a health failure."""
    leaves = jax.tree.leaves(tree)
    finite = jnp.bool_(True)
    sq = jnp.float32(0.0)
    for leaf in leaves:
        finite &= jnp.all(jnp.isfinite(leaf))
        sq += jnp.sum(jnp.square(leaf.astype(jnp.float32)))
    return finite, jnp.sqrt(sq)


class HealthGuard:
    """Process-wide health state for one `O2Runtime`: fault sites, the
    annex breaker, and every counter `stats()["health"]` renders.
    Per-tenant breaker state lives on `_TenantO2` (it is tenant state);
    this object owns the aggregate counts and the annex's demotion
    clock."""

    SITES = ("nan_round", "finetune_fail", "assess_fail", "assess_hang",
             "canary_loss")

    def __init__(self, cfg: HealthConfig):
        self.cfg = cfg
        plan = cfg.fault if cfg.fault is not None else FaultPlan()
        self.sites: dict[str, FaultSite] = {
            "nan_round": FaultSite(plan.nan_finetune_rounds),
            "finetune_fail": FaultSite(plan.fail_finetune_dispatches),
            "assess_fail": FaultSite(plan.fail_assess_dispatches),
            "assess_hang": FaultSite(plan.hang_assess_dispatches),
            "canary_loss": FaultSite(plan.lose_canary_trials),
        }
        self._backoff_rng = np.random.default_rng(cfg.backoff_seed)
        # counters (the stats()["health"] block)
        self.rejected_params = 0
        self.retries = 0
        self.annex_demotions = 0
        self.annex_recoveries = 0
        self.dropped_dispatches = 0
        self.quarantines = 0
        self.quarantine_releases = 0
        self.degraded_ticks = 0
        # annex breaker state
        self._consecutive_failures = 0
        self._degraded_at: float | None = None

    # ---------------------------------------------------------- queries
    @property
    def enabled(self) -> bool:
        return self.cfg.enabled

    @property
    def degraded(self) -> bool:
        return self._degraded_at is not None

    def o2_paused(self) -> bool:
        """True while the annex is demoted *and* inside its cooloff —
        the window where fine-tune/assessment must not even try.  After
        the cooloff the annex stays nominally degraded but dispatches
        are allowed again as half-open probes."""
        if self._degraded_at is None or not self.enabled:
            return False
        return (time.monotonic() - self._degraded_at) \
            < self.cfg.annex_cooloff_s

    # -------------------------------------------------------- the guard
    def params_healthy(self, tree) -> bool:
        """Finite + bounded-norm check on a param tree.  Read-only: the
        tree is never modified, and on healthy paths this is the guard's
        only device work (one small reduction)."""
        if not self.enabled:
            return True
        finite, norm = _tree_health(tree)
        norm = float(norm)
        return bool(finite) and np.isfinite(norm) \
            and norm <= self.cfg.max_param_norm

    # ------------------------------------------------- the annex breaker
    def note_retry(self):
        self.retries += 1

    def note_annex_failure(self):
        """One exhausted dispatch (all retries failed, or a watchdog
        abandon).  Consecutive failures demote; a failed half-open probe
        restarts the cooloff without recounting the demotion."""
        if not self.enabled:
            return
        self._consecutive_failures += 1
        if self._degraded_at is not None:
            self._degraded_at = time.monotonic()
        elif self._consecutive_failures >= self.cfg.annex_failure_threshold:
            self._degraded_at = time.monotonic()
            self.annex_demotions += 1

    def note_annex_ok(self):
        """One successful dispatch: the failure streak resets, and a
        degraded annex recovers (the half-open probe succeeded)."""
        self._consecutive_failures = 0
        if self._degraded_at is not None:
            self._degraded_at = None
            self.annex_recoveries += 1

    def sleep_backoff(self, attempt: int):
        """Seeded jittered exponential backoff between dispatch retries
        (deterministic given `backoff_seed` — replayed drills sleep the
        same schedule)."""
        base = self.cfg.retry_backoff_s * (2.0 ** attempt)
        time.sleep(base * (0.5 + self._backoff_rng.random()))

    def watchdog_expired(self, dispatched_at: float | None) -> bool:
        if not self.enabled or dispatched_at is None:
            return False
        return (time.monotonic() - dispatched_at) \
            > self.cfg.dispatch_timeout_s

    # --------------------------------------------------- fault injection
    def fire(self, site: str) -> bool:
        """Count one event at `site`; True when the plan schedules a
        fault at this ordinal.  Disabled guards never fire (and never
        count — the plan is part of the guard)."""
        return self.enabled and self.sites[site].check()

    def raise_if_planned(self, site: str):
        if self.fire(site):
            raise InjectedFailure(f"injected fault at {site} "
                                  f"(event {self.sites[site].count - 1})")
