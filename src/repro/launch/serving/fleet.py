"""Fleet mode: the tenant axis as a batched device axis.

Everything below `O2Runtime` scales the *slot* axis — pools, waves,
annex shards.  The tenant axis was still a Python dict walk: one
fine-tune dispatch per tenant per round, one device-resident replay
ring and learner tree per tenant *forever*.  Thousands of tenants per
instance (the ROADMAP's "millions of users") need both fixed:

* **Stacked rounds** — `FleetLearner.round` samples each hot tenant's
  batches from its own replay RNG in serial tenant order, packs the K
  learner states and batch stacks onto a leading tenant axis
  (`programs._fleet_stack_program`), and advances all K with ONE jitted
  program (`core.o2._fleet_finetune_program`) — `lax.map` over the
  tenant axis on CPU (bitwise-equal to K serial `offline_finetune`
  calls, asserted in tests/test_fleet.py), `vmap` on accelerators
  (batched kernels; see `core.o2.fleet_stack_impl` for why the two are
  split).  The stack pads to a power of two with lane 0 repeated, so a
  warmed 1..max_hot ladder never binds a new program as the hot-set
  size changes.

* **Hot/warm/cold tiering** — `_TenantO2` tier state drives where a
  tenant's memory lives: *hot* tenants hold device replay pages and
  ride the stacked round; *warm* tenants keep learner params on device
  but spill their `DeviceSequenceReplay` pages to host buffers; *cold*
  tenants cost zero device bytes (learner trees evicted to host or
  dropped to the pretrained seed, monitor history trimmed, idle pools
  torn down) and re-page on their first divergence observation.

* **BALANCE-style warm start** — a new tenant's first observed window
  is embedded (`embed_window`: normalized key-distribution quantiles +
  read/write mix) and its learner seeds from the nearest existing
  tenant's tuned params (`nearest_tenant`) instead of the pretrained
  default, falling back to the default when the fleet is empty.

`FleetConfig` defaults **off**: `FleetConfig()` on `O2ServiceConfig`
reproduces the per-tenant eager path bitwise, so every existing parity
guarantee is untouched.
"""
from __future__ import annotations

import dataclasses

import numpy as np

from repro.core.o2 import (fleet_finetune, fleet_stack_impl,
                           sample_update_batches)
from repro.core.replay import _pow2_pad
from repro.launch.serving.programs import _fleet_stack_program


@dataclasses.dataclass(frozen=True)
class FleetConfig:
    """Knobs for fleet mode (an `O2ServiceConfig` sub-config)."""
    enabled: bool = False
    # hot-tier capacity: the stacked round's width cap.  Promoting past
    # it demotes the idlest hot tenant to warm (its pages spill)
    max_hot: int = 64
    # O2 ticks a hot tenant may idle (no admissions, no retirements)
    # before its replay pages spill to host (hot -> warm)
    warm_after_ticks: int = 64
    # total idle ticks before a warm tenant evicts to cold: learner
    # trees off device, monitor history trimmed, idle pools dropped
    cold_after_ticks: int = 256
    # seed a brand-new tenant's learner from its nearest neighbor's
    # tuned params (BALANCE-style transfer) instead of the pretrained
    # default; counted in stats()["o2"]["warm_starts"]
    warm_start: bool = True
    # divergence/anchor history entries kept per tenant at cold
    # eviction (the unbounded-monitor-history fix)
    monitor_history: int = 64
    # tenant-axis batching: "auto" (map on CPU for bitwise serial
    # parity, vmap on accelerators), or force "map"/"vmap" —
    # see core.o2.fleet_stack_impl
    stack_impl: str = "auto"

    def __post_init__(self):
        if self.max_hot < 1:
            raise ValueError(f"max_hot={self.max_hot} must be >= 1")
        if self.warm_after_ticks < 1:
            raise ValueError("warm_after_ticks must be >= 1")
        if self.cold_after_ticks <= self.warm_after_ticks:
            raise ValueError("cold_after_ticks must exceed "
                             "warm_after_ticks")
        if self.monitor_history < 1:
            raise ValueError("monitor_history must be >= 1")
        if self.stack_impl not in ("auto", "vmap", "map"):
            raise ValueError(f"stack_impl={self.stack_impl!r} not in "
                             f"('auto', 'vmap', 'map')")


def embed_window(data_keys, wr_ratio: float, quantiles: int = 8):
    """One observed window as a small workload embedding: the key
    distribution's normalized quantile profile (location/scale removed —
    two tenants over shifted copies of the same distribution are
    neighbors) plus the log write/read mix.  The same summary the
    `DivergenceMonitor` watches, so "nearest tenant" means nearest in
    the space divergence is measured in."""
    keys = np.asarray(data_keys, np.float64).ravel()
    q = np.quantile(keys, np.linspace(0.0, 1.0, quantiles))
    span = max(float(q[-1] - q[0]), 1e-9)
    qn = (q - q[0]) / span
    return np.concatenate(
        [qn, [np.log1p(max(float(wr_ratio), 0.0))]]).astype(np.float32)


def nearest_tenant(embedding: np.ndarray, donors: dict) -> str | None:
    """L2-nearest donor among `donors` (name -> embedding), ties broken
    by sorted name so the pick is deterministic across runs."""
    best, best_d = None, np.inf
    for name in sorted(donors):
        d = float(np.sum((donors[name] - embedding) ** 2))
        if d < best_d:
            best, best_d = name, d
    return best


class FleetLearner:
    """Stacked-round orchestration + the fleet counters `stats()["o2"]
    ["fleet"]` renders.  Stateless across rounds beyond the counters:
    the stack is re-formed from the surviving tenants every round, which
    is what lets a quarantined tenant leave it without perturbing the
    other lanes' bits (each lane's state and batches are its own)."""

    def __init__(self, cfg: FleetConfig, annex=None):
        self.cfg = cfg
        self.annex = annex
        self.impl = fleet_stack_impl(cfg.stack_impl)
        self.rounds = 0         # stacked program dispatches
        self.lanes = 0          # tenant lanes actually advanced
        self.padded_lanes = 0   # lanes incl. pow2 padding (occupancy)
        self.peak_stack = 0     # widest stack (pre-padding) seen
        self.promotions = 0     # cold/warm -> hot
        self.demotions = 0      # hot -> warm
        self.evictions = 0      # -> cold

    def round(self, items: list) -> list:
        """One stacked fine-tune round over `items` = [(tenant, n), ...]
        in serial tenant order.  Samples each tenant's batches from its
        own replay RNG *before* any dispatch (the serial-RNG-order
        parity contract), groups lanes by (net config, DDPG config,
        round size) — a homogeneous fleet is one group, one dispatch —
        and assigns each advanced state back to its lane's tenant.
        Returns the (tenant, n) pairs that actually ran (tenants whose
        replay cannot sample yet are skipped, matching the serial
        path's no-op)."""
        groups: dict = {}
        for tenant, n in items:
            batches = sample_update_batches(tenant.replay, n,
                                            tenant.ddpg_cfg.batch_size)
            if batches is None:
                continue
            key = (tenant.net_cfg, tenant.ddpg_cfg, n)
            groups.setdefault(key, []).append((tenant, n, batches))
        ran = []
        for (net_cfg, ddpg_cfg, n), lanes in groups.items():
            k = len(lanes)
            k_pad = _pow2_pad(k)
            outs = fleet_finetune(
                [t.offline for t, _, _ in lanes],
                [b for _, _, b in lanes],
                net_cfg, ddpg_cfg, n, place_on=self.annex,
                impl=self.impl,
                stack_fn=lambda *trees: _fleet_stack_program(
                    len(trees))(*trees))
            self.rounds += 1
            self.lanes += k
            self.padded_lanes += k_pad
            self.peak_stack = max(self.peak_stack, k)
            for (tenant, n_t, _), out in zip(lanes, outs):
                tenant.offline = out
                ran.append((tenant, n_t))
        return ran

    def stats(self) -> dict:
        return {
            "impl": self.impl,
            "rounds": self.rounds,
            "lanes": self.lanes,
            "peak_stack": self.peak_stack,
            # mean useful fraction of the padded stacks dispatched
            "occupancy": round(self.lanes / self.padded_lanes, 4)
            if self.padded_lanes else 0.0,
            "promotions": self.promotions,
            "demotions": self.demotions,
            "evictions": self.evictions,
        }

    @staticmethod
    def empty_stats() -> dict:
        """The `stats()["o2"]["fleet"]` shape when fleet mode is off —
        same keys, so dashboards and the golden-keys test never branch."""
        return {"impl": "off", "rounds": 0, "lanes": 0, "peak_stack": 0,
                "occupancy": 0.0, "promotions": 0, "demotions": 0,
                "evictions": 0}


__all__ = ["FleetConfig", "FleetLearner", "embed_window",
           "nearest_tenant"]
