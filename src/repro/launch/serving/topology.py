"""Device-topology layer of the serving stack: every placement decision
in one object.

Before this layer existed, placement was computed ad hoc three times
over: `service.py` sliced `jax.devices()` into a flat 1-D `("slots",)`
mesh with inline largest-divisor arithmetic, the O2 annex was "first
spare device or device 0", and `programs.py` keyed every cached program
on raw device-id tuples.  `ServingTopology` owns all of it, built once
from the available devices (or an injected fake set):

  * the **pool slices** — the named device subsets slot pools pin to.
    A flat host topology has one (`serve`); a topology carved from a
    real production mesh (`launch/mesh.py`) has one per mesh row, so one
    service instance spans a pod with pools on disjoint rows;
  * the **annex slice** — the learner/assessment executor beside the
    serving pod.  A multi-device slice, not a single device: pooled
    assessments `shard_map` across its width instead of running
    `lax.map`-serial, and the offline learner can scale its round size
    to the slice.  On hosts with no spare device it co-locates with
    serving device 0 (`annex_shared`, surfaced in `stats()["o2"]` and
    warned about at service construction);
  * the **ring home** — the single device the replay ring's pages commit
    to (the serving side: its writers and sampling readers run there).

The unit of placement is a `DeviceSlice`: an ordered device-id tuple
plus a 1-D mesh axis, hashable *by ids* (the display name is excluded),
so it doubles as the process-wide program-cache key in `programs.py` —
two topologies whose slices cover the same devices share every resident
executable, whatever the slices are called (tests/test_topology.py
asserts zero re-traces across equal-shape topologies).

Parity contract: sharding a slice never changes per-lane math (the step
programs are `lax.map` over lanes inside each shard), so the same
request stream produces bitwise-identical summaries on any topology —
1 device, forced host devices, or a carved pod mesh.
"""
from __future__ import annotations

import dataclasses
from functools import lru_cache

import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P


@lru_cache(maxsize=None)
def _slice_mesh(device_ids: tuple, axis: str) -> Mesh:
    """The 1-D mesh over an ordered device-id tuple, built lazily (first
    use, not import) and cached process-wide so every program lowered
    onto the same slice shares one Mesh object."""
    import jax
    by_id = {d.id: d for d in jax.devices()}
    return Mesh(np.array([by_id[i] for i in device_ids]), (axis,))


@dataclasses.dataclass(frozen=True)
class DeviceSlice:
    """An ordered device subset with a named 1-D mesh axis — the unit of
    placement, and (hashed by `device_ids`/`axis` only) the program-cache
    key.  `name` is display-only metadata: two slices over the same
    devices are the *same* slice to the compiled-program cache even if
    one topology calls them "serve" and another "pod0/row0"."""

    device_ids: tuple
    axis: str = "slots"
    name: str = dataclasses.field(default="", compare=False)

    def __post_init__(self):
        if not self.device_ids:
            raise ValueError("a DeviceSlice needs at least one device")

    @property
    def width(self) -> int:
        return len(self.device_ids)

    def mesh(self) -> Mesh:
        return _slice_mesh(self.device_ids, self.axis)

    def sharded(self) -> NamedSharding:
        return NamedSharding(self.mesh(), P(self.axis))

    def replicated(self) -> NamedSharding:
        return NamedSharding(self.mesh(), P())

    def device(self, i: int = 0):
        return self.mesh().devices.flat[i]

    def prefix(self, n: int) -> "DeviceSlice":
        """The leading n-device sub-slice (same axis)."""
        if n == self.width:
            return self
        return DeviceSlice(self.device_ids[:n], self.axis,
                           name=f"{self.name}[:{n}]")

    def narrow(self, batch: int) -> "DeviceSlice":
        """The widest leading sub-slice whose width divides `batch` — the
        slice a narrower-than-full wave lowers onto (a batch that does
        not divide the slice cannot shard over all of it)."""
        n = max(d for d in range(1, self.width + 1) if batch % d == 0)
        return self.prefix(n)


def _largest_divisor_leq(n: int, cap: int) -> int:
    """Largest divisor of `n` that is <= cap (>= 1)."""
    return max(d for d in range(1, max(cap, 1) + 1) if n % d == 0)


def _pow2_floor(n: int) -> int:
    k = 1
    while k * 2 <= n:
        k *= 2
    return k


class ServingTopology:
    """All placement decisions of one service instance, made once.

    `pool_slices` are the slices slot pools pin to (round-robin by pool
    creation order); `annex` is the learner/assessment slice; `ring` the
    single-device home of the replay ring's pages.  Constructors:

      * `ServingTopology.host(slots)` — the flat layout the service
        computed inline before this layer: serving devices = the largest
        divisor of `slots` the host offers, annex = the spare devices
        beyond them (power-of-two width), device 0 when there are none;
      * `ServingTopology.from_mesh(mesh, slots)` — carve a real N-D
        production mesh: each row of the leading axis becomes one named
        pool slice, the last `annex_rows` rows become the annex, so "one
        service instance spans a pod" is a constructor argument.

    Both accept an injected device list / mesh, so topologies are unit-
    testable without touching jax device state (slices only *store* ids;
    meshes build lazily on first program lowering).
    """

    def __init__(self, pool_slices, annex: DeviceSlice,
                 ring: DeviceSlice | None = None, name: str = "custom"):
        if not pool_slices:
            raise ValueError("a topology needs at least one pool slice")
        self.pool_slices = tuple(pool_slices)
        self.name = name
        serving_ids, seen = [], set()
        for sl in self.pool_slices:
            for i in sl.device_ids:
                if i not in seen:
                    seen.add(i)
                    serving_ids.append(i)
        self.serving = DeviceSlice(tuple(serving_ids), name="serve")
        self.annex = annex
        self.ring = ring if ring is not None else self.pool_slices[0].prefix(1)
        # the annex is "shared" when it overlaps the serving devices — the
        # single-host fallback where learner/assessment work queues behind
        # serving fetches instead of overlapping them
        self.annex_shared = bool(seen & set(annex.device_ids))

    # ------------------------------------------------------- constructors
    @classmethod
    def host(cls, slots: int, devices=None,
             annex_width: int | None = None) -> "ServingTopology":
        """The flat host layout: one pool slice over the largest device
        subset whose count divides `slots` (so e.g. slots=4 on a 16-way
        host shards over 4, and slots=2 on a 3-way host over 2), the
        annex over the spare devices beyond it.

        `annex_width` pins the annex slice width (the `--annex-width`
        knob): the requested number of spare devices must exist, except
        width 1 which always resolves (to the shared device-0 fallback
        when nothing is spare).  Default: every spare device, truncated
        to a power of two so pow2-padded assessment waves divide it.
        """
        if devices is None:
            import jax
            devices = jax.devices()
        ids = tuple(d.id for d in devices)
        nserve = _largest_divisor_leq(slots, len(ids))
        serve = DeviceSlice(ids[:nserve], name="serve")
        spare = ids[nserve:]
        if annex_width is None:
            width = _pow2_floor(len(spare)) if spare else 1
        else:
            if annex_width < 1:
                raise ValueError(f"annex_width={annex_width} must be >= 1")
            if annex_width > max(len(spare), 1):
                raise ValueError(
                    f"annex_width={annex_width} exceeds the {len(spare)} "
                    f"spare device(s) beyond the {nserve}-wide serving "
                    f"slice")
            width = annex_width
        annex = (DeviceSlice(spare[:width], name="annex") if spare
                 else DeviceSlice(ids[:1], name="annex"))
        return cls((serve,), annex, ring=serve.prefix(1), name="host")

    @classmethod
    def from_mesh(cls, mesh: Mesh, slots: int,
                  annex_rows: int = 1) -> "ServingTopology":
        """Carve a production mesh (`launch/mesh.py`) into serving rows
        plus an annex: each row of the *leading* mesh axis is one named
        pool slice (its devices flattened row-major), and the last
        `annex_rows` rows merge into the annex slice.  `annex_rows=0`
        keeps every row serving and co-locates the annex on row 0
        (shared).  `slots` must shard over a row."""
        dev = np.asarray(mesh.devices)
        rows = dev.reshape((dev.shape[0], -1))
        n_rows, row_w = rows.shape
        if annex_rows < 0 or annex_rows >= n_rows:
            raise ValueError(
                f"annex_rows={annex_rows} must leave at least one of the "
                f"{n_rows} rows serving")
        if slots % row_w != 0:
            raise ValueError(
                f"slots={slots} does not shard over the {row_w}-wide mesh "
                f"rows (axis {mesh.axis_names[0]!r} slices)")
        axis0 = mesh.axis_names[0]
        serve_rows = n_rows - annex_rows
        pool_slices = tuple(
            DeviceSlice(tuple(int(d.id) for d in rows[r]),
                        name=f"{axis0}{r}")
            for r in range(serve_rows))
        if annex_rows:
            annex_ids = tuple(int(d.id) for r in range(serve_rows, n_rows)
                              for d in rows[r])
            annex = DeviceSlice(annex_ids, name="annex")
        else:
            annex = DeviceSlice(pool_slices[0].device_ids[:1], name="annex")
        return cls(pool_slices, annex, ring=pool_slices[0].prefix(1),
                   name=f"mesh{tuple(int(s) for s in dev.shape)}")

    # ------------------------------------------------------------ queries
    def pool_slice(self, pool_index: int) -> DeviceSlice:
        """The slice the `pool_index`-th created pool pins to (round-robin
        over the carved slices — deterministic, so identical request
        streams land identical placements)."""
        return self.pool_slices[pool_index % len(self.pool_slices)]

    def validate_slots(self, slots: int):
        for sl in self.pool_slices:
            if slots % sl.width != 0:
                raise ValueError(
                    f"slots={slots} does not shard over pool slice "
                    f"{sl.name!r} (width {sl.width})")

    def assess_slice(self, batch: int) -> DeviceSlice:
        """Where a pooled assessment of `batch` lanes runs: the widest
        annex sub-slice the (pow2-padded) batch shards over."""
        return self.annex.narrow(batch)

    def describe(self) -> dict:
        return {
            "name": self.name,
            "devices": len(set(self.serving.device_ids)
                           | set(self.annex.device_ids)),
            "pool_slices": {sl.name: list(sl.device_ids)
                            for sl in self.pool_slices},
            "annex": {"name": self.annex.name,
                      "devices": list(self.annex.device_ids),
                      "width": self.annex.width,
                      "shared": self.annex_shared},
            "ring_device": self.ring.device_ids[0],
        }

    def __repr__(self):
        pools = ",".join(f"{sl.name}:{sl.width}" for sl in self.pool_slices)
        return (f"ServingTopology({self.name}: pools[{pools}] "
                f"annex:{self.annex.width}"
                f"{'(shared)' if self.annex_shared else ''})")
