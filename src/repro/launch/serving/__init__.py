"""Batched tuning-as-a-service: the layered serving stack for the
online tuning stage (multi-tenant `LITune.tune`).

`launch/serve.py` serves LM decode with fixed slots and per-request
completion; this package applies the same shape to tuning requests.
Many concurrent requests — heterogeneous `(data_keys, workload,
wr_ratio, budget_steps)` across both `alex` and `carmi` spaces — fill
slots in per-space pools; one jitted multi-step program advances all
active episodes of a pool at once; a request that exhausts its budget
(or ET-MDP-terminates) frees its slot mid-flight for the next queued
request.

CPU demo:
    PYTHONPATH=src python -m repro.launch.tune_serve --requests 8 --slots 4
Multi-core (slots shard over forced host devices):
    XLA_FLAGS=--xla_force_host_platform_device_count=2 \
        PYTHONPATH=src python -m repro.launch.tune_serve

Layers (one module each, composed by `service.TuningService`):

    topology.py    device placement: pool slices, annex slice, ring home
    scheduler.py   admission queue, request deadlines, slot policies
    pools.py       slot-batched episode execution + pool resize
    o2_runtime.py  continuous tuning (capture / learner / assessments)
    slo.py         queue-wait & serve-time percentiles, breach handling
    programs.py    process-wide compiled-program cache
    service.py     the thin composition root

Key properties:
  * **parity** — every slot computes the *same traced per-step program*
    as the serial `rollout_episode` (`lax.map` over slots, `lax.scan`
    over steps of the whole map body), so per-request rewards/runtimes
    are bitwise identical to a one-at-a-time `LITune.tune` with the same
    PRNG key (tests/test_tune_service.py).
  * **no recompiles on mixed streams** — compiled executables are cached
    by `(index_type, array shapes, batch shape, scan length)`; an alex
    request arriving after a carmi wave reuses the alex program.
  * **host-side budgets** — `budget_steps` is enforced by the serving
    loop, not baked into the program: each tick scans
    K = largest power of two ≤ the smallest remaining budget among active
    slots, so heterogeneous budgets share a small ladder of executables.
  * **slot sharding** — when the host platform exposes multiple devices
    (cores) and they divide the slot count, slots shard across them via
    `shard_map`; sharding never changes per-slot math, so parity holds.
  * **topology as a constructor argument** — a `ServingTopology` owns
    every placement decision: the named device slices slot pools pin to
    (one flat slice on hosts, one per mesh row on a carved production
    mesh), the multi-device O2 annex slice pooled assessments shard
    over, and the replay ring's home device.  The same request stream
    is bitwise identical on any topology (tests/test_topology.py), and
    program caches key on slices, so equal-shape topologies share every
    resident executable.
  * **adaptive slot scheduling** — with an `AdaptiveSlotPolicy` the
    scheduler sizes each pool by demand (active + queued), growing
    immediately on a burst and shrinking with hysteresis when the queue
    drains.  A resize is one cached gather program; re-entering a
    previously-served width re-uses its resident executables, so a
    grow→shrink cycle binds zero new programs
    (tests/test_serving_layers.py).
  * **request-level SLOs** — per-request wall-clock deadlines: a queued
    breach drops before admission, a running breach truncates (best-so-
    far summary, flagged) or drops per `on_breach`.  Queue-wait and
    serve-time p50/p95/p99 surface in `stats()["slo"]`
    (benchmarks/slo_serve.py races static vs adaptive under bursts).
  * **continuous tuning (O2)** — with `O2ServiceConfig(enabled=True)` the
    service stops serving a frozen agent: retired episodes stream their
    transitions into a per-tenant replay, an offline DDPG learner
    fine-tunes between ticks, and a divergence monitor (KS on key
    quantiles + W/R drift, observed at admission) triggers assessments
    that hot-swap pool params when the offline model wins.  The swap is a
    pure buffer update — params are program *inputs*, so the K-ladder
    compiled-program cache never re-traces.  A single-tenant strict-order
    stream makes the same swap decisions as
    `core.o2.O2System.tune_window` at any budget
    (tests/test_o2_service.py).
  * **near-zero O2 serving tax** — the three O2 phases stay off the
    serving loop's critical path: (1) transition capture is
    device-resident; (2) offline fine-tuning is one scanned,
    state-donating program dispatched asynchronously with backpressure;
    (3) divergence-triggered assessments run as pooled episodes through
    the *same* cached K-ladder step programs, verdicts drained when
    ready.  `strict_order` mode keeps the fully synchronous
    serial-equivalent interleaving for parity.
  * **trustworthy hot-swaps** — `SwapConfig` stages the promotion path:
    a bootstrap CI over the pooled assessment windows gates the verdict,
    a winning candidate canaries on a lane fraction of each pool (per-
    lane params are program inputs — a mixed pool is a pure buffer
    update, zero re-traces), and promotions auto-roll-back bitwise when
    the divergence monitor re-fires or scores regress inside the watch
    window.  Both stages default off; `stats()["swaps"]` counts the
    state machine (tests/test_swap_pipeline.py).
  * **one config object** — the serving posture (slots, O2, policy,
    SLOs, topology, swap trust policy, health guards) is a frozen
    `ServeConfig` passed as `TuningService(agents, config=...)`; the
    legacy per-knob kwargs adapt with a `DeprecationWarning`.
  * **graceful degradation** — `health.py` is the fault-tolerance
    layer: finite/norm guards on every fine-tune result and swap
    candidate (last-good params retained), a watchdog with bounded
    seeded-backoff retries around every annex dispatch (repeated
    failure demotes the annex into a degraded mode that serves frozen
    and recovers automatically), per-tenant circuit breakers that
    quarantine a poisoned tenant's O2 loop, and a deterministic fault
    injector (`HealthConfig(fault=FaultPlan(...))`) driving the chaos
    drill (`benchmarks/slo_serve.py --scenario chaos`, gated in CI).
    `stats()["health"]` counts it all (tests/test_health.py).
"""
from repro.launch.serving.config import (ServeConfig, SwapConfig,
                                         config_from_legacy)
from repro.launch.serving.fleet import (FleetConfig, FleetLearner,
                                        embed_window, nearest_tenant)
from repro.launch.serving.health import (FaultPlan, HealthConfig,
                                         HealthGuard)
from repro.launch.serving.o2_runtime import O2Runtime, O2ServiceConfig
from repro.launch.serving.pools import _SlotPool, summarize_episode
from repro.launch.serving.scheduler import (AdaptiveSlotPolicy,
                                            EDFSlotPolicy, Scheduler,
                                            SlotPolicy, StaticSlotPolicy,
                                            TuneRequest)
from repro.launch.serving.service import TuningService
from repro.launch.serving.slo import SLOConfig, SLOTracker
from repro.launch.serving.stats import (HealthStats, O2Stats, PoolStats,
                                        SchedulerStats, ServiceStats,
                                        SLOStats, SwapStats,
                                        TenantSwapStats)
from repro.launch.serving.topology import DeviceSlice, ServingTopology

__all__ = [
    "AdaptiveSlotPolicy",
    "DeviceSlice",
    "EDFSlotPolicy",
    "FaultPlan",
    "FleetConfig",
    "FleetLearner",
    "HealthConfig",
    "HealthGuard",
    "HealthStats",
    "O2Runtime",
    "O2ServiceConfig",
    "O2Stats",
    "PoolStats",
    "Scheduler",
    "SchedulerStats",
    "ServeConfig",
    "ServiceStats",
    "ServingTopology",
    "SLOConfig",
    "SLOStats",
    "SLOTracker",
    "SlotPolicy",
    "StaticSlotPolicy",
    "SwapConfig",
    "SwapStats",
    "TenantSwapStats",
    "config_from_legacy",
    "embed_window",
    "nearest_tenant",
    "summarize_episode",
    "TuneRequest",
    "TuningService",
    "_SlotPool",
]
