"""Admission layer of the serving stack: the request queue, per-request
deadlines, and the pluggable slot-scheduling policy.

This layer owns *which* requests enter *which* pool *when* — and how
many slots each pool should hold — without touching any device state.
The policy seam is `SlotPolicy.desired_slots`, consulted once per
service tick per pool:

  * `StaticSlotPolicy` — fixed per-pool slot counts (PR 1–3 behavior,
    and the parity mode: a static pool never resizes, so strict-order O2
    streams stay tick-for-tick identical to the serial loop);
  * `AdaptiveSlotPolicy` — sizes pools by demand (active episodes +
    queued requests), growing immediately on a burst and shrinking only
    after `shrink_patience` consecutive low-demand ticks (hysteresis, so
    a jittery queue doesn't thrash the pool width).  Candidate widths
    come from the service's size ladder (multiples of the pool slice
    width, so resized pools still shard), and the K-ladder program cache
    makes the reshape itself cheap: re-entering a previously-served
    width binds zero new programs;
  * `EDFSlotPolicy` — earliest-deadline-first *admission ordering* on
    top of either width rule: free slots go to the queued requests with
    the tightest absolute deadlines first (deadline-less requests rank
    last, FIFO among themselves), and requests whose remaining budget
    provably cannot fit their deadline at the current measured tick rate
    are pre-dropped from the queue — they were going to breach anyway,
    so the slot- and queue-time they would have burned goes to requests
    that can still make it.  The tick rate is an EWMA of
    seconds-per-episode-step the scheduler observes from the service
    (`note_tick`), so the estimate tracks the live machine, not a
    config.

Deadline handling (the request-level SLO seam) splits by request state:
a *queued* request past its deadline is dropped before admission — it
never occupies a slot; a *running* request past its deadline is retired
at the end of the breaching tick, either truncated (its best-so-far
summary is returned, flagged) or dropped, per its `on_breach`.  Both
paths free capacity without perturbing the surviving slots' math: slots
are independent lanes of the same mapped program, so retiring one early
never changes another's per-step outputs.
"""
from __future__ import annotations

import dataclasses
from collections import deque
from typing import ClassVar

import jax


@dataclasses.dataclass
class TuneRequest:
    """One tuning-as-a-service request (the unit of multi-tenancy)."""
    rid: int
    data_keys: jax.Array
    workload: dict                 # {"reads": [r], "inserts": [i]}
    wr_ratio: float
    budget_steps: int
    index_type: str = "alex"       # alex | carmi
    key: jax.Array | None = None   # episode/window PRNG key (parity handle)
    noise_scale: float = 0.05
    # ------------------------------------------------------------- SLO
    deadline_s: float | None = None   # wall-clock budget from submission
    on_breach: str = "truncate"       # truncate | drop (running breaches)
    submitted_at: float = 0.0         # service clock at submit()


class SlotPolicy:
    """Pluggable per-pool slot-count + admission-ordering policy.
    `desired_slots` is consulted before each tick's admissions (`ladder`
    is the service's list of shardable pool widths, ascending; the
    returned width must come from it); `admission_order` ranks the queue
    for this tick's free slots (FIFO by default); `hopeless` marks
    queued requests the service should pre-drop because their budget
    cannot fit their deadline at the measured tick rate (never, by
    default)."""

    name: ClassVar[str] = "static"

    def desired_slots(self, *, slots: int, active: int, queued: int,
                      ladder: list[int]) -> int:
        return slots

    def admission_order(self, queue, now: float) -> list:
        return list(queue)              # FIFO

    def hopeless(self, req, now: float,
                 s_per_step: float | None) -> bool:
        return False


class StaticSlotPolicy(SlotPolicy):
    """Fixed pool widths: the PR 1–3 behavior and the parity default."""


@dataclasses.dataclass
class AdaptiveSlotPolicy(SlotPolicy):
    """Demand-driven pool widths: grow to the smallest ladder width that
    covers `active + queued`, shrink (with hysteresis, applied by the
    scheduler) when demand stays below the current width."""

    min_slots: int = 1
    max_slots: int = 16
    # consecutive low-demand ticks before a shrink is applied
    shrink_patience: int = 2

    name: ClassVar[str] = "adaptive"

    def desired_slots(self, *, slots: int, active: int, queued: int,
                      ladder: list[int]) -> int:
        fit = [s for s in ladder
               if self.min_slots <= s <= self.max_slots] or ladder[:1]
        demand = active + queued
        return next((s for s in fit if s >= demand), fit[-1])


def _abs_deadline(req) -> float:
    return (req.submitted_at + req.deadline_s
            if req.deadline_s is not None else float("inf"))


@dataclasses.dataclass
class EDFSlotPolicy(SlotPolicy):
    """Earliest-deadline-first admission: free slots go to the tightest
    absolute deadlines first (sorted stably, so deadline-less requests
    stay FIFO at the back), and queued requests that provably cannot
    finish inside their deadline at the current tick rate are
    pre-dropped (`hopeless`) before they waste a slot.

    `headroom` scales the feasibility estimate: a request is hopeless
    when ``budget_steps * s_per_step * headroom`` exceeds the time left
    to its deadline.  Headroom below 1 forgives estimate noise; above 1
    drops earlier.  Pool widths stay static (compose with the service's
    `slots`); the ordering seam is independent of the sizing seam.
    """

    headroom: float = 1.0

    name: ClassVar[str] = "edf"

    def admission_order(self, queue, now: float) -> list:
        return sorted(queue, key=_abs_deadline)     # stable: FIFO ties

    def hopeless(self, req, now: float,
                 s_per_step: float | None) -> bool:
        if req.deadline_s is None or not s_per_step:
            return False
        time_left = _abs_deadline(req) - now
        return req.budget_steps * s_per_step * self.headroom > time_left


class Scheduler:
    """FIFO admission queue + deadline drops + resize planning.

    Host-only bookkeeping: the scheduler never touches device state.  The
    service asks it, each tick, (1) which queued requests breached their
    deadline while waiting, (2) what width each pool should be, and
    (3) which requests to admit into which pool's free slots.
    """

    def __init__(self, policy: SlotPolicy, strict_order: bool = False):
        self.policy = policy
        self.strict_order = strict_order
        self.queue: deque[TuneRequest] = deque()
        self._shrink_streak: dict[tuple, int] = {}
        self.resize_events = 0
        # EWMA of seconds per episode-step, observed from served ticks
        # (tick wall time / K steps scanned) — the live tick rate the
        # EDF feasibility pre-drop reads
        self.s_per_step: float | None = None

    def submit(self, req: TuneRequest):
        self.queue.append(req)

    def note_tick(self, k_steps: int, dt_s: float,
                  in_trial: bool = False):
        """Fold one served tick (K scanned steps in `dt_s` wall seconds)
        into the tick-rate estimate.  Ticks served while a swap trial was
        live (`in_trial`) are excluded: a mixed-params canary pool runs
        the per-lane program variant, and letting its timing into the
        EWMA would have the EDF feasibility cut (and any resize planning
        reading the rate) react to a transient the rollback may erase."""
        if k_steps <= 0 or dt_s <= 0.0 or in_trial:
            return
        obs = dt_s / k_steps
        self.s_per_step = (obs if self.s_per_step is None
                           else 0.5 * self.s_per_step + 0.5 * obs)

    # ------------------------------------------------------------- SLO
    def drop_breached(self, now: float) -> list[TuneRequest]:
        """Remove (and return) queued requests whose deadline passed
        while they waited — they never occupy a slot."""
        kept, dropped = deque(), []
        for req in self.queue:
            if req.deadline_s is not None and \
                    now - req.submitted_at > req.deadline_s:
                dropped.append(req)
            else:
                kept.append(req)
        self.queue = kept
        return dropped

    def pre_drop_hopeless(self, now: float) -> list[TuneRequest]:
        """Remove (and return) queued requests the policy deems hopeless
        — their remaining budget cannot fit their deadline at the
        measured tick rate (EDF's feasibility cut; the default policy
        never pre-drops)."""
        kept, dropped = deque(), []
        for req in self.queue:
            if self.policy.hopeless(req, now, self.s_per_step):
                dropped.append(req)
            else:
                kept.append(req)
        self.queue = kept
        return dropped

    # ---------------------------------------------------------- resize
    def plan_resize(self, pk: tuple, pool, queued: int,
                    ladder: list[int]) -> int | None:
        """Desired width for `pool` this tick, or None to keep it.
        Growth applies immediately (a burst should not wait out the
        hysteresis); shrink waits for `shrink_patience` consecutive
        low-demand ticks and for the active episodes to fit."""
        desired = self.policy.desired_slots(
            slots=pool.slots, active=pool.n_active, queued=queued,
            ladder=ladder)
        if desired > pool.slots:
            self._shrink_streak[pk] = 0
            self.resize_events += 1
            return desired
        if desired < pool.slots:
            streak = self._shrink_streak.get(pk, 0) + 1
            self._shrink_streak[pk] = streak
            patience = getattr(self.policy, "shrink_patience", 0)
            if streak >= patience and pool.n_active <= desired:
                self._shrink_streak[pk] = 0
                self.resize_events += 1
                return desired
            return None
        self._shrink_streak[pk] = 0
        return None

    # ------------------------------------------------------- admission
    def select(self, pools: dict, pool_for, pool_key,
               any_active: bool,
               now: float = 0.0) -> dict[tuple, list[TuneRequest]]:
        """Pick this tick's admissions in the policy's order (FIFO by
        default, tightest-deadline-first under EDF) per pool group,
        bounded by each pool's free slots.  Requests not admitted keep
        their submission order in the queue.  In strict-order O2 mode a
        single window is admitted at a time, in submission order."""
        if self.strict_order:
            if not self.queue or any_active:
                return {}
            req = self.queue.popleft()
            pool_for(req)           # ensure the pool exists
            return {pool_key(req): [req]}
        per_pool: dict[tuple, list[TuneRequest]] = {}
        admitted: set[int] = set()
        free_left: dict[tuple, int] = {}
        for req in self.policy.admission_order(self.queue, now):
            pool = pool_for(req)
            pk = pool_key(req)
            if pk not in free_left:
                free_left[pk] = len(pool.free_slots())
            if free_left[pk] > 0:
                per_pool.setdefault(pk, []).append(req)
                admitted.add(req.rid)
                free_left[pk] -= 1
        self.queue = deque(r for r in self.queue
                           if r.rid not in admitted)
        return per_pool

    def queued_by_pool(self, pool_key) -> dict[tuple, int]:
        counts: dict[tuple, int] = {}
        for req in self.queue:
            pk = pool_key(req)
            counts[pk] = counts.get(pk, 0) + 1
        return counts
