"""Execution layer of the serving stack: fixed-width slot pools.

A `_SlotPool` is one (index space, array-shape) group's batch of episode
lanes: a slot-batched device carry advanced K steps per tick by the
process-wide step programs (`programs.py`), plus the host-side
bookkeeping of which request occupies which lane.  Each pool is pinned
to one `topology.DeviceSlice` — the flat host slice, or one named row of
a carved production mesh — and every device buffer it owns (carry,
capture, noise) shards over that slice.  The pool knows nothing about
queues, deadlines, or O2 — the topology says where it runs, the
scheduler decides what enters it, the O2 runtime consumes what leaves
it.

Pool *resize* (the adaptive-scheduling seam): `resize()` re-gathers the
device carry (and capture buffers) through a new→old slot index map —
growth appends fresh lanes seeded with slot 0's rows (valid, ignored
state that the next admission scatter overwrites), shrink compacts the
active lanes to the front.  Per-lane math is a `lax.map` over slots, so
moving a lane never changes its per-step outputs: a request's results
are bitwise identical whatever widths its pool passed through while it
ran.  Re-entering a previously-served width re-uses the resident
compiled programs (zero re-traces — tests/test_serving_layers.py).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.etmdp import transition_view
from repro.core.litune import attach_best_params
from repro.core.replay import wide_dim
from repro.index import env as E

from repro.launch.serving.programs import (_capture_write,
                                           _mixed_params_program,
                                           _resize_program)
from repro.launch.serving.scheduler import TuneRequest
from repro.launch.serving.topology import DeviceSlice


def summarize_episode(env_cfg: E.EnvConfig, r0: float, rewards, runtimes,
                      actions, costs, terminated: bool) -> dict:
    """Assemble the per-request summary in the exact `LITune.tune` shape
    (shared decode via `attach_best_params`)."""
    summary = {
        "episode_return": float(np.sum(rewards)),
        "best_runtime_ns": min(r0, float(np.min(runtimes))),
        "r0_ns": r0,
        "violations": float(np.sum(costs)),
        "terminated_early": terminated,
        "runtimes": [float(r) for r in runtimes],
        "actions": [np.asarray(a) for a in actions],
        "steps": len(runtimes),
    }
    summary["best_params"] = attach_best_params(summary, env_cfg)
    return summary


class _SlotPool:
    """B-slot episode pool for one (index space, array-shape) group.

    Device state: a slot-batched episode carry (sharded over the mesh), a
    [B] per-slot noise vector, and — under O2 — per-slot `[B, H, ...]`
    transition capture buffers appended in place by each tick's program
    outputs.  Host state: which request occupies which slot, steps taken,
    and the per-step narrow records streamed back each tick.
    """

    def __init__(self, env_cfg: E.EnvConfig, net_cfg, et_cfg, params,
                 slots: int, slice_: DeviceSlice, capture: bool = False):
        self.env_cfg = env_cfg
        self.net_cfg = net_cfg
        self.et_cfg = et_cfg
        self.slots = slots
        self.slice = slice_             # topology slice the pool pins to
        self.capture = capture          # device-resident transitions (O2)
        self.replicated = slice_.replicated()
        self.sharded = slice_.sharded()
        self.params = jax.device_put(params, self.replicated)
        self.carry = None                       # batched pytree, lazy init
        self.cap = None                         # capture buffers, lazy
        self.noise = np.zeros((slots,), np.float32)
        self._noise_dev = None                  # placed copy, lazy
        self.requests: list[TuneRequest | None] = [None] * slots
        self.steps_taken = np.zeros((slots,), np.int64)
        self.records: list[dict | None] = [None] * slots
        self.r0: list[float] = [0.0] * slots
        self.resizes = {"grow": 0, "shrink": 0}
        self.peak_slots = slots
        # canary state: while a swap trial runs, `canary_lanes` lists the
        # lanes serving the candidate params and `lane_params` holds the
        # per-lane stacked tree `_step_program(per_lane=True)` consumes
        self.canary_lanes: list[int] | None = None
        self.lane_params = None

    @property
    def n_active(self) -> int:
        return sum(r is not None for r in self.requests)

    def free_slots(self):
        return [i for i, r in enumerate(self.requests) if r is None]

    def remaining(self):
        return [r.budget_steps - int(self.steps_taken[i])
                for i, r in enumerate(self.requests) if r is not None]

    def noise_dev(self):
        if self._noise_dev is None:
            self._noise_dev = jax.device_put(jnp.asarray(self.noise),
                                             self.sharded)
        return self._noise_dev

    # ------------------------------------------------------------ resize
    def resize(self, new_slots: int):
        """Grow or shrink the pool to `new_slots` lanes in place, within
        the pool's topology slice.  The device carry and capture buffers
        are re-gathered through a new→old index map; host mirrors follow
        the same map.  Shrink requires the active lanes to fit (the
        scheduler guarantees it).
        """
        old = self.slots
        if new_slots == old:
            return
        if self.canary_lanes is not None:
            # the param mix is lane-indexed; re-mapping lanes mid-trial
            # would shuffle canary and control arms (the scheduler skips
            # canarying pools, so this only fires on a direct caller)
            raise RuntimeError(
                "cannot resize a pool while a canary trial is live")
        if new_slots < old:
            keep = [i for i, r in enumerate(self.requests) if r is not None]
            if len(keep) > new_slots:
                raise ValueError(
                    f"cannot shrink pool to {new_slots} slots with "
                    f"{len(keep)} active episodes")
            idle = [i for i, r in enumerate(self.requests) if r is None]
            idx = (keep + idle)[:new_slots]
            self.resizes["shrink"] += 1
        else:
            idx = list(range(old)) + [0] * (new_slots - old)
            self.resizes["grow"] += 1
        ai = np.asarray(idx, np.int32)
        if self.carry is not None:
            self.carry = _resize_program(self.slice)(self.carry, ai)
        if self.cap is not None:
            self.cap = _resize_program(self.slice)(self.cap, ai)
        self.requests = [self.requests[i] for i in idx]
        self.records = [self.records[i] for i in idx]
        self.r0 = [self.r0[i] for i in idx]
        self.steps_taken = self.steps_taken[ai].copy()
        self.noise = self.noise[ai].copy()
        if new_slots > old:
            # grown lanes are empty, not clones of lane 0 (the gather
            # only seeded their device rows with valid ignored state)
            for j in range(old, new_slots):
                self.requests[j] = None
                self.records[j] = None
                self.r0[j] = 0.0
                self.steps_taken[j] = 0
                self.noise[j] = 0.0
        self._noise_dev = None
        self.slots = new_slots
        self.peak_slots = max(self.peak_slots, new_slots)

    # ------------------------------------------------------------ canary
    def set_canary(self, lanes: list[int], candidate_params):
        """Serve `candidate_params` on `lanes` and keep the incumbent
        `self.params` everywhere else — a mixed-params pool.  Pure buffer
        update: the per-lane stacked tree is built by a cached jitted
        select (`_mixed_params_program`) and consumed by the resident
        `per_lane` step program, so entering (and leaving) a canary never
        re-traces.  `self.params` itself is untouched: a rollback is just
        `clear_canary()`."""
        self.canary_lanes = sorted(int(x) for x in lanes)
        mask = np.zeros((self.slots,), bool)
        mask[self.canary_lanes] = True
        self.lane_params = _mixed_params_program(self.slice, self.slots)(
            self.params, jax.device_put(candidate_params, self.replicated),
            mask)

    def clear_canary(self):
        """Drop the mixed-params state; every lane serves `self.params`
        again (the incumbent — promotion replaces `params` first)."""
        self.canary_lanes = None
        self.lane_params = None

    # ----------------------------------------------------------- capture
    def ensure_cap(self):
        """The pool's `[B, H, wide]` capture buffer, lazily allocated on
        the pool's slice.  Shared by the fused step program (which takes
        it as an operand) and the standalone `_capture_write` fallback."""
        if self.cap is None:
            self.cap = jax.device_put(
                jnp.zeros((self.slots, self.env_cfg.episode_len,
                           wide_dim(self.net_cfg.obs_dim,
                                    self.net_cfg.lstm_hidden)),
                          jnp.float32), self.sharded)
        return self.cap

    def capture_tick(self, out: dict):
        """Append this tick's `[K, B, ...]` transition view into the
        capture buffers (on the serving mesh, next to their producer and
        their extract readers) at each slot's pre-tick episode offset.
        Called after the tick's narrow-field fetch — the serving queue is
        drained then, so the donated in-place append costs its own
        microseconds, not a wait — and before `collect` advances
        `steps_taken`.  The fused-tick path (`KernelConfig.fused_tick`)
        bypasses this: its step program appends in the same dispatch."""
        self.cap = _capture_write(self.ensure_cap(), transition_view(out),
                                  self.steps_taken.astype(np.int32))

    # --------------------------------------------------------- lifecycle
    def mark_admitted(self, slot: int, req: TuneRequest, r0: float):
        self.noise[slot] = req.noise_scale
        self._noise_dev = None
        self.requests[slot] = req
        self.steps_taken[slot] = 0
        self.r0[slot] = r0
        self.records[slot] = {"rewards": [], "runtimes": [], "actions": [],
                              "costs": []}

    def collect(self, slot: int, out_host: dict, step: int,
                early: bool = False) -> bool:
        """Record one step for `slot`; returns whether the episode is done
        (early exit or budget exhausted).  `done` is computed host-side
        against the request budget — the program's own horizon flag tracks
        the pool's horizon_cap, not the per-request episode length."""
        rec = self.records[slot]
        rec["rewards"].append(float(out_host["reward"][step, slot]))
        rec["runtimes"].append(float(out_host["runtime_ns"][step, slot]))
        rec["actions"].append(np.asarray(out_host["action"][step, slot]))
        rec["costs"].append(float(out_host["cost"][step, slot]))
        self.steps_taken[slot] += 1
        return early or \
            self.steps_taken[slot] >= self.requests[slot].budget_steps

    def retire(self, slot: int,
               terminated: bool) -> tuple[TuneRequest, dict, dict | None]:
        """Free the slot; returns the request, its summary, and — under
        capture — the episode's narrow fields (`[T]` host arrays) for ring
        ingestion alongside the slot's device capture rows.  The wide
        fields never left the device: they ride `self.cap`."""
        req, rec = self.requests[slot], self.records[slot]
        summary = summarize_episode(
            self.env_cfg, self.r0[slot], rec["rewards"], rec["runtimes"],
            rec["actions"], rec["costs"], terminated)
        if self.canary_lanes is not None:
            # lane-tagged summaries: the swap trial scores canary lanes
            # against control lanes.  Only tagged while a canary is live,
            # so summaries stay shape-identical on every parity path
            summary["lane"] = slot
            summary["canary"] = slot in self.canary_lanes
        narrow = None
        if self.capture:
            T = len(rec["rewards"])
            done = np.zeros((T,), np.float32)
            done[-1] = 1.0      # retire only happens at the done step
            narrow = {
                "action": np.stack(rec["actions"]).astype(np.float32),
                "reward": np.asarray(rec["rewards"], np.float32),
                "done": done,
                "cost": np.asarray(rec["costs"], np.float32),
            }
        self.requests[slot] = None
        self.records[slot] = None
        return req, summary, narrow
