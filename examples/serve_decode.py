"""Batched serving example: prefill + decode with continuous-batch slots
(deliverable (b), serving flavor).

    PYTHONPATH=src python examples/serve_decode.py
"""
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config
from repro.launch.serve import BatchedServer, Request
from repro.launch.train import scale_config


def main():
    cfg = scale_config(get_config("gemma3_4b"), "10m")
    server = BatchedServer(cfg, batch_slots=4, max_len=96)
    rng = np.random.default_rng(0)
    requests = [Request(i, rng.integers(0, cfg.vocab_size, 64), 24)
                for i in range(4)]
    stats = server.run(requests)
    print(f"arch={cfg.name} (sliding-window + global attention)")
    print(f"prefill: {stats['prefill_s']:.2f}s   "
          f"decode: {stats['decode_tok_per_s']:.1f} tok/s")
    for rid, toks in stats["outputs"].items():
        print(f"  request {rid}: {len(toks)} tokens, head={toks[:8]}")


if __name__ == "__main__":
    main()
