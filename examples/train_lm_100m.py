"""End-to-end LM training driver (deliverable (b)): a ~100M-parameter
llama-family model for a few hundred steps on CPU, with checkpointing and
the deterministic pipeline.  The identical driver lowers on the production
meshes (launch/dryrun.py).

    PYTHONPATH=src python examples/train_lm_100m.py [--steps 200]
"""
import argparse

import numpy as np

from repro.launch.train import Trainer, TrainerConfig


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--arch", default="llama3_8b")
    args = ap.parse_args()

    tc = TrainerConfig(
        arch=args.arch, scale="100m", steps=args.steps,
        global_batch=8, seq_len=256, lr=1e-3, warmup=20,
        ckpt_dir="/tmp/repro_lm100m_ckpt", save_every=100, log_every=10)
    trainer = Trainer(tc)
    print(f"model: {trainer.cfg.name}  "
          f"params={trainer.bundle.n_params()/1e6:.1f}M")
    trainer.run_until(tc.steps)
    first, last = np.mean(trainer.losses[:10]), np.mean(trainer.losses[-10:])
    print(f"\nloss {first:.3f} -> {last:.3f} over {tc.steps} steps")
    assert last < first, "loss must decrease"


if __name__ == "__main__":
    main()
