"""Beyond-paper integration (DESIGN.md §Arch-applicability): the paper's
black-box tuning loop pointed at the *serving system itself*.

The environment is the compile-time roofline model: each action picks
system knobs (attention chunk sizes, KV-cache sharding axis, microbatch),
the step lowers+compiles the serve/train program on a host mesh, and the
reward is the negative dominant roofline term -- the same
state/action/reward contract as index tuning, so the same tuner machinery
(here: the SMBO baseline; §Perf uses the full loop) applies.

NOTE: spawns its own 8-device host platform; run standalone:
    PYTHONPATH=src python examples/systune_serving.py
"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"

import itertools   # noqa: E402
import time        # noqa: E402

import jax         # noqa: E402

from repro.configs import SHAPES, get_config            # noqa: E402
from repro.launch.steps import lower_cell, plan_cell    # noqa: E402
from repro.launch.train import scale_config             # noqa: E402
from repro.runtime import hlo_analysis as ha            # noqa: E402


def evaluate(cfg, shape, mesh, rules):
    plan = plan_cell(cfg, shape, mesh, rules_override=rules)
    compiled = lower_cell(plan).compile()
    analysis = ha.analyze(compiled.as_text(), n_devices=mesh.size)
    terms = ha.roofline(analysis, plan.bundle.model_flops(shape) / mesh.size)
    return terms


def main():
    mesh = jax.make_mesh((2, 4), ("data", "model"))
    cfg = scale_config(get_config("llama3_8b"), "100m")
    import dataclasses
    shape = dataclasses.replace(SHAPES["decode_32k"], global_batch=8,
                                seq_len=4096)

    # knob space: KV-cache sharding axis x logits sharding
    knob_space = {
        "cache_seq": [None, "model"],
        "kv_heads": [None, "model"],
    }
    print(f"tuning serve_step system knobs for {cfg.name} on 2x4 mesh")
    best, best_rules = None, None
    for values in itertools.product(*knob_space.values()):
        rules = dict(zip(knob_space.keys(), values))
        t0 = time.time()
        try:
            terms = evaluate(cfg, shape, mesh, rules)
        except Exception as e:
            print(f"  {rules}: INVALID ({type(e).__name__})")
            continue
        step = terms.step_time_s
        print(f"  {str(rules):48s} step={step*1e6:9.1f}us "
              f"dom={terms.dominant:10s} ({time.time()-t0:.1f}s to evaluate)")
        if best is None or step < best:
            best, best_rules = step, rules
    print(f"\nbest knobs: {best_rules}  ({best*1e6:.1f}us/step roofline)")


if __name__ == "__main__":
    main()
