"""Continuous tuning (O2) inside the batched tuning service: a drifting
window stream served by `TuningService` with `O2ServiceConfig(enabled=True)`.

Each window is one tuning request; the service observes its key/W-R
divergence at admission, streams the retired episode's transitions into
the tenant replay, fine-tunes the offline DDPG learner between ticks, and
hot-swaps pool params (a pure buffer update — no re-trace) whenever a
diverged window's assessment shows the offline model winning.

    PYTHONPATH=src python examples/o2_service.py

The one-call equivalent is ``LITune.stream(windows, via_service=True)``,
which makes the same swap decisions as the serial
`O2System.tune_window` loop (tests/test_o2_service.py asserts parity).
"""
import jax

from repro.core.ddpg import DDPGConfig
from repro.core.litune import LITune, LITuneConfig
from repro.core.maml import MetaConfig
from repro.core.o2 import O2Config
from repro.index.workloads import StreamConfig, stream_windows
from repro.launch.serving import (O2ServiceConfig, ServeConfig,
                                  TuningService)


def main():
    cfg = LITuneConfig(
        index_type="alex", episode_len=6,
        lstm_hidden=32, mlp_hidden=64,
        ddpg=DDPGConfig(batch_size=16, seq_len=4, burn_in=1),
        meta=MetaConfig(meta_batch=2, inner_episodes=1, inner_updates=4),
        o2=O2Config(divergence_threshold=0.10,
                    offline_updates_per_window=8))
    tuner = LITune(cfg, seed=0)
    print("pretraining ...")
    tuner.pretrain(n_outer=2)
    service = TuningService(tuner, config=ServeConfig(
        slots=1,
        o2=O2ServiceConfig(enabled=True, o2=cfg.o2, strict_order=True)))

    stream_cfg = StreamConfig(
        n_windows=8, base_per_window=2048, updates_per_window=2048,
        dist="mix", drift_per_window=0.15, wr_start=1.0, wr_end=3.0)
    print("serving 8 tumbling windows (drift 0.15/window, W/R 1->3) "
          "through the O2-enabled service:")
    rids = [service.submit(data, wl, wr, budget_steps=6, noise_scale=0.02)
            for _, data, wl, wr in
            stream_windows(jax.random.PRNGKey(3), stream_cfg)]
    results = service.run()

    for w, rid in enumerate(rids):
        r = results[rid]
        div = r["divergence"]
        print(f"  window {w:2d}: default {r['r0_ns']:8.1f} ns/op  "
              f"tuned {r['best_runtime_ns']:8.1f}  "
              f"ks={div['ks']:.3f}  "
              f"{'<- model swap' if r['swapped'] else ''}")

    st = service.stats()
    o2 = st["o2"]["alex"]
    print(f"\nO2: windows={o2['windows']}  diverged={o2['diverged']}  "
          f"swaps={o2['swaps']}  offline updates={o2['offline_updates']}  "
          f"replay={o2['replay_size']} transitions")
    print(f"programs: bound={st['program_misses']} "
          f"reused={st['program_hits']} "
          f"resident={st['programs_resident']} — hot-swaps never re-trace "
          f"(params are program inputs, not constants)")


if __name__ == "__main__":
    main()
