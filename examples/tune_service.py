"""Tuning-as-a-service: one service, many tenants (paper Part B/C served
the way the ROADMAP wants it — concurrently).

Two pretrained agents (alex + carmi spaces) sit behind one
`TuningService`.  A wave of heterogeneous requests — different datasets,
write/read ratios, step budgets, and index types — is served with
slot-based continuous batching: short-budget requests retire mid-flight
and their slots are immediately reused by queued requests, while compiled
step programs are cached per (space, shape) so the mixed stream never
re-traces.

    PYTHONPATH=src python examples/tune_service.py
"""
import time

import jax

from repro.core.ddpg import DDPGConfig
from repro.core.litune import LITune, LITuneConfig
from repro.core.maml import MetaConfig
from repro.index.workloads import sample_keys, wr_workload
from repro.launch.serving import ServeConfig, TuningService


def small_cfg(index_type: str) -> LITuneConfig:
    return LITuneConfig(
        index_type=index_type, episode_len=10,
        lstm_hidden=32, mlp_hidden=64,
        ddpg=DDPGConfig(batch_size=16, seq_len=4, burn_in=1),
        meta=MetaConfig(meta_batch=2, inner_episodes=1, inner_updates=4))


def main():
    agents = {}
    for index_type in ("alex", "carmi"):
        print(f"pretraining {index_type} agent ...")
        tuner = LITune(small_cfg(index_type), seed=0)
        tuner.pretrain(n_outer=2)
        agents[index_type] = tuner

    service = TuningService(agents, config=ServeConfig(slots=4))
    key = jax.random.PRNGKey(7)
    tenants = [
        # (index, dataset, wr ratio, budget)
        ("alex", "osm", 1.0, 10),
        ("alex", "books", 1.0 / 3.0, 4),     # read-heavy, short budget
        ("carmi", "fb", 3.0, 8),             # write-heavy
        ("alex", "mix", 1.0, 6),
        ("carmi", "osm", 1.0, 10),
        ("alex", "fb", 3.0, 4),
    ]
    for i, (index_type, dist, wr, budget) in enumerate(tenants):
        k = jax.random.fold_in(key, i)
        data = sample_keys(k, 2048, dist)
        wl, _ = wr_workload(jax.random.fold_in(k, 1), data, wr,
                            total=2048, dist=dist)
        service.submit(data, wl, wr, budget_steps=budget,
                       index_type=index_type)

    print(f"\nserving {len(tenants)} concurrent tuning requests "
          f"on {service.stats()['pools'] or 'fresh'} pools ...")
    t0 = time.time()
    results = service.run()
    dt = time.time() - t0

    for rid, (index_type, dist, wr, budget) in enumerate(tenants):
        r = results[rid]
        speedup = r["r0_ns"] / max(r["best_runtime_ns"], 1e-9)
        print(f"  req {rid} [{index_type:5s} {dist:5s} wr={wr:.2f} "
              f"budget={budget:2d}]: default {r['r0_ns']:8.1f} ns/op -> "
              f"best {r['best_runtime_ns']:8.1f} ({speedup:.2f}x) "
              f"in {r['steps']} steps"
              + ("  [early-terminated]" if r["terminated_early"] else ""))
    st = service.stats()
    print(f"\n{st['completed']} requests in {dt:.1f}s across {st['pools']} "
          f"slot pools; {st['program_misses']} step programs bound "
          f"({st['programs_resident']} resident), {st['program_hits']} "
          f"cache hits; {st['service_steps']} ticks for "
          f"{st['episode_steps']} episode-steps")


if __name__ == "__main__":
    main()
