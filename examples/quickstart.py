"""Quickstart: tune a learned index with LITune in ~2 minutes on CPU.

    PYTHONPATH=src python examples/quickstart.py
"""
import jax

from repro.core.ddpg import DDPGConfig
from repro.core.litune import LITune, LITuneConfig
from repro.core.maml import MetaConfig
from repro.index.workloads import sample_keys, wr_workload


def main():
    # 1. A tuning instance: 8k keys from an OSM-like distribution,
    #    write-heavy workload (W/R = 3).
    key = jax.random.PRNGKey(0)
    data = sample_keys(key, 8192, "osm")
    workload, _ = wr_workload(jax.random.fold_in(key, 1), data,
                              wr_ratio=3.0, total=8192, dist="osm")

    # 2. LITune with a small agent (CPU-friendly); meta-pretrain briefly.
    cfg = LITuneConfig(
        index_type="alex", episode_len=15,
        lstm_hidden=32, mlp_hidden=64,
        ddpg=DDPGConfig(batch_size=16, seq_len=4, burn_in=1),
        meta=MetaConfig(meta_batch=2, inner_episodes=1, inner_updates=4),
    )
    tuner = LITune(cfg, seed=0)
    print("meta-pretraining (small budget) ...")
    tuner.pretrain(n_outer=4, callback=lambda r: print(
        f"  outer {r['iter']}: return {r['mean_return']:+.3f}"))

    # 3. Answer a tuning request.
    res = tuner.tune(data, workload, wr_ratio=3.0, budget_steps=15)
    print(f"\ndefault runtime : {res['r0_ns']:8.1f} ns/op")
    print(f"tuned runtime   : {res['best_runtime_ns']:8.1f} ns/op  "
          f"({res['r0_ns'] / res['best_runtime_ns']:.2f}x)")
    print(f"safety violations during tuning: {res['violations']:.0f}")
    print("recommended parameters (excerpt):")
    for k, v in list(res["best_params"].items())[:6]:
        print(f"  {k:28s} = {v:.4f}")


if __name__ == "__main__":
    main()
