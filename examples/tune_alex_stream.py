"""Continuous online tuning under data shift (paper §5.2.4(b), Fig 9/10):
tumbling windows with drifting key distribution and rising write ratio,
tuned through the O2 system (online recommendations + offline fine-tuning
+ divergence-triggered swaps).

    PYTHONPATH=src python examples/tune_alex_stream.py
"""
import jax

from repro.core.ddpg import DDPGConfig
from repro.core.litune import LITune, LITuneConfig
from repro.core.maml import MetaConfig
from repro.index.workloads import StreamConfig, stream_windows


def main():
    cfg = LITuneConfig(
        index_type="alex", episode_len=10,
        lstm_hidden=32, mlp_hidden=64,
        ddpg=DDPGConfig(batch_size=16, seq_len=4, burn_in=1),
        meta=MetaConfig(meta_batch=2, inner_episodes=1, inner_updates=4),
    )
    tuner = LITune(cfg, seed=0)
    print("pretraining ...")
    tuner.pretrain(n_outer=3)

    stream_cfg = StreamConfig(
        n_windows=8, base_per_window=4096, updates_per_window=4096,
        dist="mix", drift_per_window=0.12, wr_start=1.0, wr_end=3.0)
    print("\nstreaming 8 tumbling windows (drift 0.12/window, W/R 1->3):")
    results = tuner.stream(stream_windows(jax.random.PRNGKey(3), stream_cfg),
                           max_steps_per_window=5)
    for r in results:
        div = r.get("divergence", {})
        print(f"  window {r['window']:2d}: default {r['r0_ns']:8.1f} ns/op  "
              f"tuned {r['best_runtime_ns']:8.1f}  "
              f"ks={div.get('ks', 0.0):.3f}  "
              f"{'<- model swap' if r.get('swapped') else ''}")
    print(f"\nO2 model swaps: {tuner._o2.swaps}")


if __name__ == "__main__":
    main()
