"""Explicit-all_to_all MoE (shard_map EP) vs the GSPMD path and a dense
per-token reference — multi-device, run in a subprocess so the forced
device count stays out of the main test process."""
import json
import subprocess
import sys

import pytest

SCRIPT = r"""
import dataclasses, json
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import jax, jax.numpy as jnp, numpy as np
from repro.configs import get_config, smoke
from repro.models.moe import apply_moe, moe_specs
from repro.models.moe_shard_map import apply_moe_shard_map
from repro.models.module import init_params

out = {}
cfg = dataclasses.replace(smoke(get_config("phi35_moe_42b_a66b")),
                          capacity_factor=8.0, n_experts=8,
                          experts_per_token=2)
p = init_params(moe_specs(cfg), jax.random.PRNGKey(0))
x = (jax.random.normal(jax.random.PRNGKey(1), (4, 16, cfg.d_model))
     * 0.3).astype(jnp.bfloat16)
truth, aux_t = apply_moe(p, x, cfg)  # single-device ground truth

for tag, shape, names in (("dp_tp", (2, 4), ("data", "model")),
                          ("pod", (2, 2, 2), ("pod", "data", "model"))):
    mesh = jax.make_mesh(shape, names)
    with mesh:
        got, aux = jax.jit(
            lambda p, x: apply_moe_shard_map(p, x, cfg, mesh))(p, x)
        grads = jax.grad(lambda xx: apply_moe_shard_map(
            p, xx, cfg, mesh)[0].astype(jnp.float32).sum())(x)
        txt = jax.jit(lambda p, x: apply_moe_shard_map(p, x, cfg, mesh)
                      ).lower(p, x).compile().as_text()
    out[tag] = {
        "err": float(jnp.max(jnp.abs(got.astype(jnp.float32)
                                     - truth.astype(jnp.float32)))),
        "aux_err": abs(float(aux) - float(aux_t)),
        "grad_finite": bool(jnp.all(jnp.isfinite(
            grads.astype(jnp.float32)))),
        "has_all_to_all": "all-to-all" in txt,
    }

# decode-shaped fallback (tokens < tp)
xd = (jax.random.normal(jax.random.PRNGKey(2), (4, 1, cfg.d_model))
      * 0.3).astype(jnp.bfloat16)
td, _ = apply_moe(p, xd, cfg)
mesh = jax.make_mesh((2, 4), ("data", "model"))
with mesh:
    gd, _ = jax.jit(lambda p, x: apply_moe_shard_map(p, x, cfg, mesh))(p, xd)
out["decode"] = {"err": float(jnp.max(jnp.abs(
    gd.astype(jnp.float32) - td.astype(jnp.float32))))}
print("RESULT " + json.dumps(out))
"""


@pytest.fixture(scope="module")
def results():
    proc = subprocess.run(
        [sys.executable, "-c", SCRIPT], capture_output=True, text=True,
        timeout=540, env={"PYTHONPATH": "src", "PATH": "/usr/bin:/bin",
                          "HOME": "/root",
                          # force CPU: a stray libtpu otherwise burns
                          # minutes probing cloud TPU metadata
                          "JAX_PLATFORMS": "cpu"}, cwd="/root/repo")
    assert proc.returncode == 0, proc.stderr[-3000:]
    line = [l for l in proc.stdout.splitlines() if l.startswith("RESULT ")]
    return json.loads(line[0][len("RESULT "):])


@pytest.mark.parametrize("mesh", ["dp_tp", "pod"])
def test_matches_single_device_truth(results, mesh):
    assert results[mesh]["err"] < 0.01
    # aux differs slightly: mean-of-per-slice-stats vs one global mean
    assert results[mesh]["aux_err"] < 1e-3


@pytest.mark.parametrize("mesh", ["dp_tp", "pod"])
def test_gradients_flow_and_a2a_present(results, mesh):
    assert results[mesh]["grad_finite"]
    assert results[mesh]["has_all_to_all"]


def test_decode_shape_fallback(results):
    assert results["decode"]["err"] < 0.01
