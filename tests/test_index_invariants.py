"""Property-based tests (hypothesis) on the learned-index substrate."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from _hypothesis_compat import given, settings, st

from repro.core.spaces import alex_space, carmi_space
from repro.index import alex, carmi
from repro.index import linear_model as lm
from repro.index.workloads import sample_keys, wr_workload

SPACE = alex_space()


def _params(overrides=None):
    p = {k: jnp.float32(v) for k, v in alex.DEFAULTS.items()}
    p.update({k: jnp.float32(v) for k, v in (overrides or {}).items()})
    return p


# ------------------------------------------------------------------ fits
@settings(max_examples=20, deadline=None)
@given(st.integers(10, 400), st.integers(1, 8), st.integers(0, 10_000))
def test_linear_fit_perfect_on_linear_data(n, n_segs, seed):
    """On exactly-linear data the exact fit has ~zero error bound."""
    key = jax.random.PRNGKey(seed)
    keys = jnp.sort(jax.random.uniform(key, (n,)))
    keys = jnp.linspace(0.1, 0.9, n)  # perfectly linear CDF
    seg = jnp.minimum((jnp.arange(n) * n_segs) // n, n_segs - 1).astype(
        jnp.int32)
    slope, icpt, cnt = lm.fit_segments_exact(keys, seg, n_segs)
    err = lm.segment_errors(keys, seg, n_segs, slope, icpt)
    assert float(jnp.max(err)) < 1e-2


@settings(max_examples=15, deadline=None)
@given(st.integers(50, 500), st.integers(2, 16), st.integers(0, 10_000))
def test_fit_error_bound_nonnegative_and_bounded(n, n_segs, seed):
    keys = jnp.sort(jax.random.uniform(jax.random.PRNGKey(seed), (n,)))
    seg = jnp.minimum((jnp.arange(n) * n_segs) // n, n_segs - 1).astype(
        jnp.int32)
    slope, icpt, cnt = lm.fit_segments_exact(keys, seg, n_segs)
    err = lm.segment_errors(keys, seg, n_segs, slope, icpt)
    assert float(jnp.min(err)) >= 0.0
    assert float(jnp.max(err)) <= n  # can't be worse than the segment size


@settings(max_examples=10, deadline=None)
@given(st.integers(0, 10_000))
def test_approx_fit_never_better_than_exact_on_average(seed):
    key = jax.random.PRNGKey(seed)
    keys = sample_keys(key, 1024, "fb")
    seg = jnp.minimum(jnp.arange(1024) * 8 // 1024, 7).astype(jnp.int32)
    s_e, i_e, _ = lm.fit_segments_exact(keys, seg, 8)
    s_a, i_a, _ = lm.fit_segments_approx(keys, seg, 8)
    err_e = lm.segment_errors(keys, seg, 8, s_e, i_e)
    err_a = lm.segment_errors(keys, seg, 8, s_a, i_a)
    assert float(jnp.mean(err_a)) >= float(jnp.mean(err_e)) - 1.0


# ------------------------------------------------------------------ alex
def test_alex_search_exact_on_uniform(rng_key):
    """Near-linear data + exact fits => tiny search distances."""
    keys = jnp.linspace(0.0, 1.0, 2048)
    idx = alex.build(keys, _params())
    _, m = alex.run_reads(idx, keys[100:200])
    assert float(m["avg_search_dist"]) < 2.0


def test_alex_skewed_data_larger_distance(rng_key):
    uni = jnp.linspace(0.0, 1.0, 2048)
    skew = sample_keys(rng_key, 2048, "fb")
    p = _params({"fanout_selection_method": 1})  # equi-width fanout
    d_uni = float(alex.run_reads(alex.build(uni, p), uni[:256])[1]
                  ["avg_search_dist"])
    d_skew = float(alex.run_reads(alex.build(skew, p), skew[:256])[1]
                   ["avg_search_dist"])
    assert d_skew > d_uni


def test_alex_insert_monotonic_counters(small_index_instance):
    data, workload = small_index_instance
    idx = alex.build(data, _params())
    idx2, ns, m = alex.run_inserts(idx, workload["inserts"], _params())
    assert float(ns) > 0
    assert float(jnp.sum(idx2["cnt"])) >= float(jnp.sum(idx["cnt"]))
    assert float(idx2["counters"]["n_retrains"]) >= 0


def test_alex_dangerous_zone_memory():
    """Fig 11: aggressive ood thresholds with equi-width+upward splitting
    blow the memory budget."""
    from repro.index import cost as C
    keys = jnp.linspace(0.0, 1.0, 2048)
    danger = _params({"fanout_selection_method": 1,
                      "splitting_policy_method": 1,
                      "allow_splitting_upwards": 1,
                      "kmax_ood_keys_log2": 14,
                      "ood_tolerance_factor": 48})
    idx = alex.build(keys, danger)
    assert float(alex.memory_bytes(idx, danger)) > C.MEM_BUDGET_BYTES
    safe = _params()
    assert float(alex.memory_bytes(alex.build(keys, safe), safe)) \
        < C.MEM_BUDGET_BYTES


@settings(max_examples=10, deadline=None)
@given(st.integers(0, 10_000))
def test_alex_runtime_positive_any_params(seed):
    rng = np.random.default_rng(seed)
    raw = SPACE.random_raw(rng)
    p = {k: jnp.float32(v) for k, v in raw.items()}
    keys = jnp.linspace(0.0, 1.0, 512)
    idx = alex.build(keys, p)
    ns, m = alex.run_reads(idx, keys[:64])
    assert np.isfinite(float(ns)) and float(ns) > 0


# ------------------------------------------------------------------ carmi
def test_carmi_prefetch_helps_predictable_data():
    keys = jnp.linspace(0.0, 1.0, 2048)
    p0 = {k: jnp.float32(v) for k, v in carmi.DEFAULTS.items()}
    p1 = dict(p0, prefetch_aggr=jnp.float32(1.0))
    ns0, _ = carmi.run_reads(carmi.build(keys, p0), keys[:256], p0)
    ns1, _ = carmi.run_reads(carmi.build(keys, p1), keys[:256], p1)
    assert float(ns1) < float(ns0)


def test_carmi_lambda_spacetime_tradeoff():
    keys = jnp.linspace(0.0, 1.0, 2048)
    p_time = {**{k: jnp.float32(v) for k, v in carmi.DEFAULTS.items()},
              "lambda_spacetime": jnp.float32(0.0)}   # snaps to time-only
    p_space = {**{k: jnp.float32(v) for k, v in carmi.DEFAULTS.items()},
               "lambda_spacetime": jnp.float32(1.0)}
    m_time = float(carmi.memory_bytes(carmi.build(keys, p_time)))
    m_space = float(carmi.memory_bytes(carmi.build(keys, p_space)))
    assert m_time > m_space  # time-mode spends memory (lower density)


# ------------------------------------------------------------------ spaces
@settings(max_examples=30, deadline=None)
@given(st.integers(0, 100_000))
def test_space_encode_decode_roundtrip(seed):
    rng = np.random.default_rng(seed)
    for space in (alex_space(), carmi_space()):
        raw = space.random_raw(rng)
        a = space.encode(raw)
        back = {k: float(v) for k, v in space.decode(jnp.asarray(a)).items()}
        for i, name in enumerate(space.names):
            if space.kinds[i] in ("int", "choice", "bool"):
                assert abs(back[name] - raw[name]) <= 0.5 + 1e-4, name
            else:
                rangei = float(space.highs[i] - space.lows[i])
                assert abs(back[name] - raw[name]) <= 0.02 * rangei + 1e-5


def test_table2_dimensions():
    """Table 2: ALEX 14 dims (5 cont/3 bool/4 int/2 choice); CARMI 13."""
    sa = alex_space()
    assert sa.dim == 14
    from collections import Counter
    ca = Counter(sa.kinds)
    assert ca["cont"] == 5 and ca["bool"] == 3 and ca["int"] == 4 \
        and ca["choice"] == 2
    sc = carmi_space()
    assert sc.dim == 13
    cc = Counter(sc.kinds)
    assert cc["cont"] == 10 and cc["int"] == 2 and cc["hybrid"] == 1
