"""The topology layer (launch/serving/topology.py): slice carving,
placement handles, and the cross-topology parity guarantees.

* unit seams — `DeviceSlice` hashes by device ids (names excluded: two
  topologies naming the same devices differently share program-cache
  entries), `narrow` picks the widest dividing sub-slice, `host` carves
  serving + annex with the largest-divisor rule the service used to
  inline, `from_mesh` carves a production mesh into named row slices
  plus a multi-device annex;
* parity — the same request stream is bitwise identical under forced
  host-device counts 1, 2 and 8 (subprocess probes: the device count
  must be pinned before jax initializes), for both the frozen service
  and an O2 service whose pooled assessments shard over a >=2-device
  annex slice — the sharded verdict inputs equal the 1-device
  `lax.map`-serial path's bit for bit;
* zero re-trace — a `from_mesh` topology whose slices cover the same
  device ids as the flat host layout binds zero new step programs and
  serves bitwise-identical results (probe `--compare-mesh`).
"""
from __future__ import annotations

import json
import os
import pathlib
import subprocess
import sys

import numpy as np
import pytest

from repro.launch.serving.topology import DeviceSlice, ServingTopology

_REPO = pathlib.Path(__file__).resolve().parent.parent
_PROBE = pathlib.Path(__file__).resolve().parent / "_topology_probe.py"


class _FakeDev:
    def __init__(self, i: int):
        self.id = i

    def __repr__(self):
        return f"dev{self.id}"


class _FakeMesh:
    """Just enough of a Mesh for `from_mesh` carving: a device grid and
    axis names (slices only store ids; real meshes build lazily)."""

    def __init__(self, shape, axis_names):
        n = int(np.prod(shape))
        self.devices = np.array([_FakeDev(i) for i in range(n)],
                                dtype=object).reshape(shape)
        self.axis_names = axis_names


# ------------------------------------------------------------------ units
def test_device_slice_hashes_by_ids_not_name():
    a = DeviceSlice((0, 1), name="serve")
    b = DeviceSlice((0, 1), name="pod0/row0")
    c = DeviceSlice((0, 2), name="serve")
    assert a == b and hash(a) == hash(b)    # the program-cache guarantee
    assert a != c
    assert a.width == 2


def test_device_slice_narrow_and_prefix():
    sl = DeviceSlice((0, 1, 2, 3), name="serve")
    assert sl.narrow(8) is sl               # divides: the full slice
    assert sl.narrow(4) is sl
    assert sl.narrow(2).device_ids == (0, 1)
    assert sl.narrow(1).device_ids == (0,)
    assert sl.narrow(6).device_ids == (0, 1, 2)   # widest divisor of 6
    assert sl.prefix(4) is sl
    assert sl.prefix(1).device_ids == (0,)


def test_host_carving_and_annex_rules():
    devs = [_FakeDev(i) for i in range(8)]
    topo = ServingTopology.host(4, devices=devs)
    assert topo.serving.device_ids == (0, 1, 2, 3)   # largest divisor
    assert topo.annex.device_ids == (4, 5, 6, 7)     # pow2 of the spares
    assert not topo.annex_shared
    assert topo.ring.device_ids == (0,)

    # explicit annex width carves exactly that many spares
    topo2 = ServingTopology.host(4, devices=devs, annex_width=2)
    assert topo2.annex.device_ids == (4, 5)
    with pytest.raises(ValueError, match="annex_width"):
        ServingTopology.host(4, devices=devs, annex_width=5)
    with pytest.raises(ValueError, match="annex_width"):
        ServingTopology.host(4, devices=devs, annex_width=0)

    # slots=4 on a 3-device host serves on 2 devices, annex on the spare
    topo3 = ServingTopology.host(4, devices=devs[:3])
    assert topo3.serving.device_ids == (0, 1)
    assert topo3.annex.device_ids == (2,)

    # single device: everything co-locates, and says so
    topo1 = ServingTopology.host(4, devices=devs[:1])
    assert topo1.serving.device_ids == (0,)
    assert topo1.annex.device_ids == (0,)
    assert topo1.annex_shared


def test_from_mesh_carving():
    mesh = _FakeMesh((4, 4), ("data", "model"))
    topo = ServingTopology.from_mesh(mesh, slots=8)
    assert [sl.name for sl in topo.pool_slices] == \
        ["data0", "data1", "data2"]
    assert topo.pool_slices[1].device_ids == (4, 5, 6, 7)
    assert topo.annex.device_ids == (12, 13, 14, 15)  # last row
    assert not topo.annex_shared
    # round-robin pinning of pools to row slices
    assert topo.pool_slice(0).name == "data0"
    assert topo.pool_slice(3).name == "data0"
    assert topo.pool_slice(4).name == "data1"

    # two annex rows merge into one wide annex slice
    topo2 = ServingTopology.from_mesh(mesh, slots=8, annex_rows=2)
    assert len(topo2.pool_slices) == 2
    assert topo2.annex.device_ids == (8, 9, 10, 11, 12, 13, 14, 15)

    # annex_rows=0 serves every row and shares the annex with row 0
    topo0 = ServingTopology.from_mesh(mesh, slots=8, annex_rows=0)
    assert len(topo0.pool_slices) == 4
    assert topo0.annex.device_ids == (0,) and topo0.annex_shared

    # a 3-D mesh flattens its trailing axes into the rows
    topo3 = ServingTopology.from_mesh(_FakeMesh((2, 2, 2), ("pod", "a", "b")),
                                      slots=4)
    assert topo3.pool_slices[0].device_ids == (0, 1, 2, 3)
    assert topo3.annex.device_ids == (4, 5, 6, 7)

    with pytest.raises(ValueError, match="annex_rows"):
        ServingTopology.from_mesh(mesh, slots=8, annex_rows=4)
    with pytest.raises(ValueError, match="shard"):
        ServingTopology.from_mesh(mesh, slots=6)


def test_validate_slots_and_describe():
    devs = [_FakeDev(i) for i in range(4)]
    topo = ServingTopology.host(4, devices=devs)
    topo.validate_slots(4)
    topo.validate_slots(8)
    with pytest.raises(ValueError, match="slots"):
        topo.validate_slots(6)
    d = topo.describe()
    assert d["annex"] == {"name": "annex", "devices": [0],
                          "width": 1, "shared": True}
    assert d["pool_slices"] == {"serve": [0, 1, 2, 3]}
    assert d["ring_device"] == 0
    assert "serve" in repr(topo)


def test_assess_slice_narrows_to_the_wave():
    devs = [_FakeDev(i) for i in range(8)]
    topo = ServingTopology.host(4, devices=devs)   # annex (4,5,6,7)
    assert topo.assess_slice(8).device_ids == (4, 5, 6, 7)
    assert topo.assess_slice(4).device_ids == (4, 5, 6, 7)
    assert topo.assess_slice(2).device_ids == (4, 5)
    assert topo.assess_slice(1).device_ids == (4,)


def test_scale_rounds_to_annex_width():
    """`O2ServiceConfig(scale_rounds_to_annex=True)` multiplies each
    fine-tune round by the annex slice width (the slice bought the
    assessment headroom; the learner may spend it too); the default
    keeps the serial-parity round sizes."""
    import types

    from repro.launch.serving.health import HealthConfig, HealthGuard
    from repro.launch.serving.o2_runtime import O2Runtime, O2ServiceConfig

    devs = [_FakeDev(i) for i in range(8)]
    topo = ServingTopology.host(4, devices=devs)      # annex width 4

    def run(cfg):
        calls = []

        class _Tenant:
            cfg = types.SimpleNamespace(offline_updates_per_window=3)
            quarantined = False      # breaker closed: rounds dispatch

            def finetune(self, n, strict):
                calls.append(n)

        rt = types.SimpleNamespace(cfg=cfg, topology=topo,
                                   tenants={"alex": _Tenant()},
                                   health=HealthGuard(HealthConfig()))
        rt._guarded_finetune = types.MethodType(
            O2Runtime._guarded_finetune, rt)
        rt._round_updates = types.MethodType(
            O2Runtime._round_updates, rt)
        req = types.SimpleNamespace(index_type="alex")
        O2Runtime._finetune_retired(rt, [(req, {})], strict=False)
        return calls

    assert run(O2ServiceConfig(enabled=True)) == [3]
    assert run(O2ServiceConfig(enabled=True,
                               scale_rounds_to_annex=True)) == [12]
    # an explicit per-tick count scales the same way
    assert run(O2ServiceConfig(enabled=True, offline_updates_per_tick=2,
                               scale_rounds_to_annex=True)) == [8]


# ---------------------------------------------------- cross-device parity
_probe_cache: dict[tuple, dict] = {}


def _probe(devices: int, mode: str, *extra: str) -> dict:
    """Run (and memoize) one forced-device-count probe subprocess."""
    key = (devices, mode) + extra
    if key not in _probe_cache:
        env = dict(os.environ)
        env["PYTHONPATH"] = (str(_REPO / "src") + os.pathsep +
                             env.get("PYTHONPATH", ""))
        proc = subprocess.run(
            [sys.executable, str(_PROBE), "--devices", str(devices),
             "--mode", mode, *extra],
            capture_output=True, text=True, env=env, timeout=1200,
            cwd=str(_REPO))
        assert proc.returncode == 0, \
            f"probe failed:\n{proc.stdout}\n{proc.stderr}"
        _probe_cache[key] = json.loads(proc.stdout.splitlines()[-1])
    return _probe_cache[key]


@pytest.mark.parametrize("devices", [2, 8])
def test_frozen_serving_bitwise_across_device_counts(devices):
    """The same request stream, served under forced host-device counts:
    summaries (runtimes, returns, steps) are bitwise identical to the
    1-device run — sharding a slice never changes per-lane math."""
    ref = _probe(1, "frozen")
    got = _probe(devices, "frozen")
    assert got["results"] == ref["results"]
    assert got["topology"]["pool_slices"]["serve"] == \
        list(range(min(devices, 4)))


@pytest.mark.parametrize("devices", [2, 8])
def test_o2_serving_bitwise_across_device_counts(devices):
    """The O2 path too: divergence verdicts, swap annotations, episode
    summaries and — the annex guarantee — every pooled-assessment
    verdict input (`_pooled_best`) matches the 1-device run bit for
    bit.  At 8 devices the assessment waves shard over a >=2-wide annex
    sub-slice, so this is sharded-vs-`lax.map`-serial equality, not a
    no-op."""
    ref = _probe(1, "o2")
    got = _probe(devices, "o2")
    assert got["results"] == ref["results"]
    assert got["o2"]["pooled_bests"] == ref["o2"]["pooled_bests"]
    assert got["o2"]["assessments"] == ref["o2"]["assessments"] > 0
    assert got["o2"]["swaps"] == ref["o2"]["swaps"]

    # the 1-device run is the serial path; the 8-device run must have
    # actually sharded its assessment waves across the annex slice
    assert ref["o2"]["annex_width"] == 1 and ref["o2"]["annex_shared"]
    if devices == 8:
        assert got["o2"]["annex_width"] == 4
        assert not got["o2"]["annex_shared"]
        assert max(got["o2"]["assess_widths"]) >= 2
    assert sorted(set(ref["o2"]["assess_widths"])) == [1]


def test_mesh_topology_equal_slices_zero_retrace():
    """A `from_mesh` carving whose row + annex slices cover the same
    device ids as the flat host layout serves the same stream bitwise
    and binds zero new step programs — slices hash by ids, so
    equal-shape topologies share every resident executable."""
    rep = _probe(8, "o2", "--compare-mesh")
    cmp = rep["mesh_compare"]
    assert cmp["equal"]
    assert cmp["new_resident"] == 0
    assert cmp["binder_misses_delta"] == 0
    assert cmp["topology"]["pool_slices"] == {"data0": [0, 1, 2, 3]}
    assert cmp["topology"]["annex"]["devices"] == [4, 5, 6, 7]


def test_multi_row_mesh_pins_pools_to_distinct_slices():
    """A 4-row carve of the same 8 devices: the stream's three pool
    groups round-robin onto three *different* named row slices (the
    pod-spanning layout) and still serve the host layout's results bit
    for bit — placement is invisible to the math."""
    rep = _probe(8, "o2", "--compare-mesh", "--mesh-rows", "4")
    cmp = rep["mesh_compare"]
    assert cmp["equal"]
    used = cmp["pool_slices_used"]
    assert len(used) == 3                       # three workload shapes
    assert sorted(set(used.values())) == ["data0", "data1", "data2"]
    assert cmp["topology"]["annex"]["devices"] == [6, 7]
