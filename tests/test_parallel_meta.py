"""Mesh-parallel meta-training (core/parallel.py) — the paper's §6
'accelerate offline training via parallelization' future work."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import parallel as par
from repro.core.ddpg import DDPGConfig
from repro.core.networks import NetConfig
from repro.index import env as E
from repro.index.workloads import WorkloadConfig, make_workload, sample_keys


@pytest.fixture(scope="module")
def setup():
    env_cfg = E.EnvConfig(index_type="alex", episode_len=8)
    net_cfg = NetConfig(obs_dim=E.obs_dim(), action_dim=env_cfg.space.dim,
                        lstm_hidden=16, mlp_hidden=32)
    return env_cfg, net_cfg, DDPGConfig(seq_len=4, burn_in=1)


def _instances(b, n=512):
    key = jax.random.PRNGKey(0)
    data, reads, inserts = [], [], []
    for i in range(b):
        kk = jax.random.fold_in(key, i)
        d = sample_keys(kk, n, "mix")
        w = make_workload(jax.random.fold_in(kk, 1), d,
                          WorkloadConfig(n_reads=n // 2, n_inserts=n // 2))
        data.append(d)
        reads.append(w["reads"])
        inserts.append(w["inserts"])
    return (jnp.stack(data), {"reads": jnp.stack(reads),
                              "inserts": jnp.stack(inserts)},
            jnp.ones((b,), jnp.float32))


def test_parallel_rollout_matches_sequential_env(setup):
    """A vmapped rollout step must equal per-env sequential stepping."""
    env_cfg, net_cfg, ddpg_cfg = setup
    from repro.core import ddpg
    agent = ddpg.init_state(jax.random.PRNGKey(1), net_cfg, ddpg_cfg)
    data, workloads, wr = _instances(3)
    env_states, obs = par.batched_reset(env_cfg, data, workloads, wr)

    # sequential reference for env 1
    d1 = data[1]
    w1 = {"reads": workloads["reads"][1], "inserts": workloads["inserts"][1]}
    es_ref, obs_ref = E.reset(env_cfg, d1, w1, 1.0)
    np.testing.assert_allclose(np.asarray(obs[1]), np.asarray(obs_ref),
                               rtol=1e-5)

    action = jnp.zeros((3, env_cfg.space.dim))
    stepped = jax.vmap(lambda s, a: E.step.__wrapped__(env_cfg, s, a))(
        env_states, action)
    _, obs2, r2, _, info2 = stepped
    _, obs_ref2, r_ref, _, info_ref = E.step(env_cfg, es_ref,
                                             jnp.zeros(env_cfg.space.dim))
    np.testing.assert_allclose(np.asarray(obs2[1]), np.asarray(obs_ref2),
                               rtol=1e-4)
    np.testing.assert_allclose(float(info2["runtime_ns"][1]),
                               float(info_ref["runtime_ns"]), rtol=1e-5)


def test_meta_train_parallel_runs_and_updates(setup):
    env_cfg, net_cfg, ddpg_cfg = setup
    state, hist = par.meta_train_parallel(
        jax.random.PRNGKey(0), net_cfg, ddpg_cfg, env_cfg,
        meta_batch=2, n_outer=2, rollout_steps=4, updates_per_outer=1)
    assert len(hist) == 2
    assert all(np.isfinite(h["mean_runtime"]) for h in hist)


def test_traj_to_sequences_shapes(setup):
    env_cfg, net_cfg, ddpg_cfg = setup
    T, B = 8, 3
    traj = {
        "obs": jnp.zeros((T, B, E.obs_dim())),
        "action": jnp.zeros((T, B, env_cfg.space.dim)),
        "reward": jnp.zeros((T, B)), "next_obs": jnp.zeros((T, B,
                                                            E.obs_dim())),
        "done": jnp.zeros((T, B)), "cost": jnp.zeros((T, B)),
        "h_a": jnp.zeros((T, B, 16)), "c_a": jnp.zeros((T, B, 16)),
        "h_q": jnp.zeros((T, B, 16)), "c_q": jnp.zeros((T, B, 16)),
    }
    batch = par.traj_to_sequences(traj, seq_len=4)
    assert batch["obs"].shape == (6, 4, E.obs_dim())   # 2 chunks x 3 envs
    assert batch["h_a"].shape == (6, 16)
