"""Baseline tuner behaviour + the budgeted runner's failure accounting."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.spaces import alex_space, carmi_space
from repro.index import env as E
from repro.tuning.base import run_tuner
from repro.tuning.baselines import GridSearch, SMBO, make_baseline


@pytest.mark.parametrize("method", ["random", "grid", "heuristic", "smbo"])
@pytest.mark.parametrize("index_type", ["alex", "carmi"])
def test_baselines_never_worse_than_default(method, index_type,
                                            small_index_instance):
    data, workload = small_index_instance
    env_cfg = E.EnvConfig(index_type=index_type)
    space = alex_space() if index_type == "alex" else carmi_space()
    res = run_tuner(make_baseline(method, space, seed=0), env_cfg, data,
                    workload, 1.0, budget_evals=12)
    assert res.best_runtime_ns <= res.default_runtime_ns + 1e-6
    assert res.evals == 12
    assert len(res.best_so_far) == 12
    assert np.all(np.diff(res.best_so_far) <= 1e-9)  # monotone best-so-far


def test_grid_search_is_deterministic_lattice():
    space = alex_space()
    g1 = GridSearch(space, seed=0)
    g2 = GridSearch(space, seed=99)  # seed must not matter for the lattice
    for _ in range(5):
        assert g1.propose() == g2.propose()


def test_smbo_concentrates_on_good_region():
    """TPE on a quadratic surrogate: late proposals closer to optimum."""
    space = carmi_space()
    smbo = SMBO(space, seed=0, n_startup=5)
    target = {n: (space.lows[i] + space.highs[i]) / 2
              for i, n in enumerate(space.names)}

    def score(p):
        return sum((p[n] - target[n]) ** 2 /
                   (space.highs[i] - space.lows[i]) ** 2
                   for i, n in enumerate(space.names))

    dists = []
    for i in range(40):
        p = smbo.propose()
        d = score(p)
        smbo.observe(p, d, failed=False)
        dists.append(d)
    assert np.mean(dists[-10:]) < np.mean(dists[:10])


def test_runner_counts_failures(small_index_instance):
    """A tuner that always proposes the dangerous corner must rack up
    failures and never displace the default as 'best'."""
    from repro.index.alex import DEFAULTS
    from repro.tuning.base import Tuner

    class DangerTuner(Tuner):
        name = "danger"

        def propose(self):
            raw = dict(DEFAULTS)
            raw.update(fanout_selection_method=1, splitting_policy_method=1,
                       allow_splitting_upwards=1, kmax_ood_keys_log2=14,
                       ood_tolerance_factor=50)
            return raw

    data, workload = small_index_instance
    env_cfg = E.EnvConfig(index_type="alex")
    res = run_tuner(DangerTuner(alex_space(), 0), env_cfg, data, workload,
                    1.0, budget_evals=5)
    assert res.failures == 5
    assert res.best_runtime_ns == res.default_runtime_ns
