"""The trustworthy hot-swap pipeline (launch/serving/): confidence-gated
canary promotion with auto-rollback, behind the consolidated ServeConfig.

* canary flow — a forced verdict win lands on a lane fraction first; a
  good canary promotes pool-wide, a bad one rolls back with the incumbent
  pool params bitwise untouched;
* auto-rollback — a promoted swap reverts bitwise (online tree, pool
  buffers, divergence-monitor reference + anchors history) when post-swap
  scores regress or the monitor re-fires inside the watch window;
* zero re-traces — a whole canary -> promote/rollback cycle binds no new
  step programs (per-lane params are program inputs on the same resident
  K-ladder cache);
* ServeConfig — the consolidated config object, the legacy-kwarg adapter
  (DeprecationWarning), and the mixing error;
* stats schema — the golden-keys test pinning the exact dict shape
  `stats()` renders (serving/stats.py is the schema);
* seams — `_bootstrap_ci` determinism and the injectable clock routing
  swap timings.

Outcome-deciding knobs are pinned through the module seams
(`_pooled_best`, `_lane_score`) so every path here is deterministic; the
end-to-end drill against real verdicts is benchmarks/slo_serve.py
--scenario poisoned.
"""
import jax
import numpy as np
import pytest

import repro.launch.serving.o2_runtime as o2_runtime
import repro.launch.serving.programs as programs
from repro.core.ddpg import DDPGConfig
from repro.core.litune import LITune, LITuneConfig
from repro.core.o2 import O2Config
from repro.index.workloads import sample_keys, wr_workload
from repro.launch.serving import (O2ServiceConfig, ServeConfig, SwapConfig,
                                  TuningService, config_from_legacy)

# KS effectively off: divergence (and therefore assessments) fire purely
# on W/R shift, which is exact — no finite-sample noise in any verdict
_O2 = O2Config(divergence_threshold=10.0, wr_shift_threshold=0.5,
               offline_updates_per_window=2, assess_every=1)


def _cfg(**kw) -> LITuneConfig:
    return LITuneConfig(index_type="alex", episode_len=4, lstm_hidden=16,
                        mlp_hidden=32,
                        ddpg=DDPGConfig(seq_len=3, burn_in=1, batch_size=8),
                        o2=_O2, **kw)


def _window(key, wr: float, n_keys: int = 256):
    data = sample_keys(key, n_keys, "mix")
    wl, _ = wr_workload(jax.random.fold_in(key, 1), data, wr,
                        total=n_keys, dist="mix")
    return data, wl, wr


def _service(swap: SwapConfig, clock=None) -> TuningService:
    cfg = _cfg()
    return TuningService(LITune(cfg, seed=0), config=ServeConfig(
        slots=4, o2=O2ServiceConfig(enabled=True, o2=cfg.o2),
        clock=clock, swap=swap))


def _serve_wave(service, wrs, fold: int, budget: int = 4):
    """Submit one window per wr, run to empty, settle O2; returns rids."""
    key = jax.random.PRNGKey(3)
    rids = [service.submit(*_window(jax.random.fold_in(key, fold + i), wr),
                           budget_steps=budget)
            for i, wr in enumerate(wrs)]
    service.run()
    service.flush_o2()
    return rids


def _start_trial(service):
    """Window 0 (wr=1) anchors the monitor; window 1 (wr=3) W/R-diverges,
    its forced-win assessment starts the canary trial."""
    rids = _serve_wave(service, [1.0, 3.0], fold=0)
    assert "alex" in service.o2rt.trials
    assert service.o2rt.trials["alex"].state == "canary"
    return rids


def _assert_trees_equal(a, b):
    jax.tree.map(
        lambda x, y: np.testing.assert_array_equal(np.asarray(x),
                                                   np.asarray(y)), a, b)


def _lane_score_stub(scores: dict):
    """A patchable `_lane_score`: arm-dependent values from a mutable
    dict, so a test can pin each canary/watch decision."""
    def score(summary):
        return (scores["canary"] if summary.get("canary")
                else scores["control"])
    return score


# ------------------------------------------------------------- canary flow
def test_canary_win_promotes_pool_wide(monkeypatch):
    monkeypatch.setattr(o2_runtime, "_pooled_best", lambda *a: -1.0)
    monkeypatch.setattr(o2_runtime, "_lane_score",
                        _lane_score_stub({"canary": 0.5, "control": 1.0}))
    service = _service(SwapConfig(canary=True, canary_min_episodes=1))
    rids = _start_trial(service)
    misses0 = service.program_misses
    resident0 = programs._step_program.cache_info().currsize

    # a full wave (wr=1: no new divergences) fills every lane: the canary
    # lane outperforms the controls -> pool-wide promotion
    _serve_wave(service, [1.0] * 4, fold=10)
    tenant = service.tenants["alex"]
    trial = service.o2rt.trials["alex"]
    assert trial.state == "promoted"
    sw = service.stats()["swaps"]
    assert sw["candidates"] == 1 and sw["canaried"] == 1
    assert sw["promoted"] == 1 and sw["rolled_back"] == 0
    assert sw["per_tenant"]["alex"]["active_state"] == "promoted"
    # the trial window's summary carries the stage flags
    assert service.results[rids[1]]["canaried"] is True
    assert service.results[rids[1]]["swapped"] is True
    # every pool of the tenant now serves the promoted candidate, bitwise,
    # with the canary mix dropped
    for pk, pool in service.pools.items():
        assert pool.lane_params is None
        _assert_trees_equal(jax.device_get(pool.params),
                            jax.device_get(tenant.online["params"]))
    _assert_trees_equal(jax.device_get(tenant.online["params"]),
                        jax.device_get(trial.candidate))
    # the whole canary -> promote cycle rode resident executables
    assert service.program_misses == misses0
    assert programs._step_program.cache_info().currsize == resident0


def test_canary_regression_rolls_back_incumbent_untouched(monkeypatch):
    monkeypatch.setattr(o2_runtime, "_pooled_best", lambda *a: -1.0)
    monkeypatch.setattr(o2_runtime, "_lane_score",
                        _lane_score_stub({"canary": 5.0, "control": 1.0}))
    service = _service(SwapConfig(canary=True, canary_min_episodes=1))
    rids = _start_trial(service)
    tenant = service.tenants["alex"]
    incumbent = jax.device_get(tenant.online["params"])

    _serve_wave(service, [1.0] * 4, fold=10)
    sw = service.stats()["swaps"]
    assert sw["rolled_back_canary"] == 1 and sw["rolled_back"] == 1
    assert sw["promoted"] == 0
    assert "alex" not in service.o2rt.trials
    assert service.results[rids[1]]["swap_rolled_back"] == "canary"
    # the canary never touched the incumbent: pool params are bitwise the
    # pre-trial online tree, and the per-lane mix is gone
    for pool in service.pools.values():
        assert pool.lane_params is None and pool.canary_lanes is None
        _assert_trees_equal(jax.device_get(pool.params), incumbent)
    _assert_trees_equal(jax.device_get(tenant.online["params"]), incumbent)


def test_canary_timeout_rolls_back(monkeypatch):
    """A canary that never gathers enough scored episodes must not become
    a permanent mixed pool: it times out into rollback."""
    monkeypatch.setattr(o2_runtime, "_pooled_best", lambda *a: -1.0)
    service = _service(SwapConfig(canary=True, canary_min_episodes=1,
                                  canary_timeout_ticks=2))
    _start_trial(service)
    # serve single-window waves: one active lane (a control) per wave, so
    # the canary lane never retires an episode and the trial idles out
    _serve_wave(service, [1.0], fold=10)
    _serve_wave(service, [1.0], fold=11)
    _serve_wave(service, [1.0], fold=12)
    sw = service.stats()["swaps"]
    assert sw["rolled_back_canary"] == 1
    assert "alex" not in service.o2rt.trials


# ----------------------------------------------------------- auto-rollback
def test_promoted_regression_rolls_back_bitwise(monkeypatch):
    monkeypatch.setattr(o2_runtime, "_pooled_best", lambda *a: -1.0)
    scores = {"canary": 0.5, "control": 1.0}
    monkeypatch.setattr(o2_runtime, "_lane_score", _lane_score_stub(scores))
    service = _service(SwapConfig(canary=True, canary_min_episodes=1,
                                  rollback_windows=10))
    rids = _start_trial(service)
    tenant = service.tenants["alex"]
    pre_swap = jax.device_get(tenant.online["params"])
    _serve_wave(service, [1.0] * 4, fold=10)        # -> promoted
    assert service.o2rt.trials["alex"].state == "promoted"

    # post-promotion episodes regress hard against the pre-swap baseline
    # (watch window held open by rollback_windows=10); wr matches the
    # promoted anchor so the monitor stays quiet — this is the score path
    scores["canary"] = scores["control"] = 10.0
    _serve_wave(service, [3.0] * 4, fold=20)
    sw = service.stats()["swaps"]
    assert sw["promoted"] == 1
    assert sw["rolled_back_promoted"] == 1 and sw["rolled_back"] == 1
    assert "alex" not in service.o2rt.trials
    assert service.results[rids[1]]["swap_rolled_back"] == "regression"
    # bitwise restoration: the online tree and every pool buffer are the
    # pre-swap params again
    _assert_trees_equal(jax.device_get(tenant.online["params"]), pre_swap)
    for pool in service.pools.values():
        _assert_trees_equal(jax.device_get(pool.params), pre_swap)


def test_monitor_refire_rolls_back_and_restores_reference(monkeypatch):
    monkeypatch.setattr(o2_runtime, "_pooled_best", lambda *a: -1.0)
    monkeypatch.setattr(o2_runtime, "_lane_score",
                        _lane_score_stub({"canary": 0.5, "control": 1.0}))
    service = _service(SwapConfig(canary=True, canary_min_episodes=1))
    rids = _start_trial(service)
    tenant = service.tenants["alex"]
    mon = tenant.monitor
    ref_q = mon.ref_quantiles.copy()            # window 0's anchor
    ref_wr = mon.ref_wr
    anchors_before = list(mon.anchors)
    misses0 = service.program_misses
    resident0 = programs._step_program.cache_info().currsize

    _serve_wave(service, [1.0] * 4, fold=10)    # -> promoted
    # promotion re-anchored the monitor on the trial window's data
    assert mon.ref_wr == 3.0
    assert mon.anchors[-1] != anchors_before[-1]

    # the next window W/R-shifts against the *new* anchor: the monitor
    # re-fires inside the watch window -> bitwise revert, reference and
    # anchors history restored (the revert stays visible in the history)
    _serve_wave(service, [1.0], fold=30)
    sw = service.stats()["swaps"]
    assert sw["rolled_back_promoted"] == 1
    assert service.results[rids[1]]["swap_rolled_back"] == "monitor"
    np.testing.assert_array_equal(mon.ref_quantiles, ref_q)
    assert mon.ref_wr == ref_wr
    assert mon.anchors[-1] == anchors_before[-1]
    # the full canary -> promote -> rollback cycle bound zero new step
    # programs (per-lane params ride the same resident K-ladder cache)
    assert service.program_misses == misses0
    assert programs._step_program.cache_info().currsize == resident0


def test_watch_window_survival_closes_trial(monkeypatch):
    monkeypatch.setattr(o2_runtime, "_pooled_best", lambda *a: -1.0)
    monkeypatch.setattr(o2_runtime, "_lane_score",
                        _lane_score_stub({"canary": 0.5, "control": 1.0}))
    service = _service(SwapConfig(canary=True, canary_min_episodes=1,
                                  rollback_windows=2))
    _start_trial(service)
    _serve_wave(service, [1.0] * 4, fold=10)    # -> promoted
    # two quiet windows at the promoted anchor's wr: the watch closes and
    # the swap sticks
    _serve_wave(service, [3.0], fold=20)
    _serve_wave(service, [3.0], fold=21)
    sw = service.stats()["swaps"]
    assert sw["promoted"] == 1 and sw["rolled_back"] == 0
    assert "alex" not in service.o2rt.trials
    assert sw["per_tenant"]["alex"]["active_state"] is None


# ------------------------------------------------------------- ServeConfig
def test_legacy_kwargs_adapt_with_deprecation_warning():
    tuner = LITune(_cfg(), seed=0)
    with pytest.warns(DeprecationWarning, match="config=ServeConfig"):
        service = TuningService(tuner, slots=2, horizon_cap=64)
    assert service.config == ServeConfig(slots=2, horizon_cap=64)
    assert service.slots == 2 and service.horizon_cap == 64


def test_config_and_legacy_kwargs_cannot_mix():
    tuner = LITune(_cfg(), seed=0)
    with pytest.raises(TypeError, match="not both"):
        TuningService(tuner, slots=2, config=ServeConfig())


def test_config_from_legacy_rejects_unknown_kwargs():
    with pytest.raises(TypeError, match="unknown"):
        config_from_legacy(slotz=2)


def test_new_style_construction_emits_no_warning(recwarn):
    TuningService(LITune(_cfg(), seed=0), config=ServeConfig(slots=2))
    assert not [w for w in recwarn.list
                if issubclass(w.category, DeprecationWarning)]


# ------------------------------------------------------------ stats schema
def test_stats_golden_keys(monkeypatch):
    """Pin the exact dict shape `stats()` renders (serving/stats.py is
    the schema; dashboards and the CI gates read these keys)."""
    monkeypatch.setattr(o2_runtime, "_pooled_best", lambda *a: -1.0)
    service = _service(SwapConfig(canary=True, canary_min_episodes=1))
    _start_trial(service)
    st = service.stats()

    assert set(st) == {
        "service_steps", "episode_steps", "completed", "queued", "pools",
        "devices", "topology", "program_misses", "program_hits",
        "programs_resident", "per_pool", "scheduler", "slo", "o2", "swaps",
        "health"}
    assert set(st["scheduler"]) == {"policy", "resize_events"}
    assert set(st["slo"]) == {"queue_wait_ms", "serve_ms", "breaches",
                              "tracked"}
    assert set(st["slo"]["breaches"]) == {"dropped_queued",
                                          "dropped_running", "pre_dropped",
                                          "truncated"}
    for pool_stats in st["per_pool"].values():
        assert set(pool_stats) == {"slots", "active", "peak_slots",
                                   "resizes"}
    assert set(st["o2"]) == {"alex", "phase_ms", "assessments",
                             "inflight_assessments", "pending_missing",
                             "annex_width", "annex_shared", "warm_starts",
                             "tenants_hot", "tenants_warm", "tenants_cold",
                             "device_bytes", "host_bytes", "fleet"}
    assert set(st["o2"]["alex"]) == {
        "windows", "diverged", "swaps", "offline_updates",
        "finetune_skipped", "replay_size", "mean_swap_ms", "tier"}
    # fleet keys render (zeroed, impl "off") even with fleet mode off,
    # so dashboards never branch on key presence
    assert set(st["o2"]["fleet"]) == {
        "impl", "rounds", "lanes", "peak_stack", "occupancy",
        "promotions", "demotions", "evictions"}
    assert st["o2"]["fleet"]["impl"] == "off"
    assert st["o2"]["alex"]["tier"] == "hot"
    counter_keys = {"candidates", "immediate", "canaried", "deferred",
                    "promoted", "ci_rejected", "rolled_back_canary",
                    "rolled_back_promoted", "rolled_back"}
    assert set(st["swaps"]) == counter_keys | {"per_tenant",
                                               "breaches_during_trial"}
    assert set(st["swaps"]["per_tenant"]["alex"]) == \
        counter_keys | {"active_state"}
    assert set(st["health"]) == {
        "state", "rejected_params", "retries", "annex_demotions",
        "annex_recoveries", "dropped_dispatches", "quarantines",
        "quarantine_releases", "degraded_ticks", "quarantined"}

    # a frozen service (no O2) renders the historical document: no o2,
    # no swaps, no health block
    frozen = TuningService(LITune(_cfg(), seed=0),
                           config=ServeConfig(slots=2))
    st2 = frozen.stats()
    assert "o2" not in st2 and "swaps" not in st2 and "health" not in st2


def test_breaches_during_trial_attribution(monkeypatch):
    """Queued-deadline breaches that land while a trial is live surface
    under stats()["swaps"], never inside the pinned slo block."""
    import types
    service = _service(SwapConfig(canary=True))
    # any live trial marks the tenant in-trial; an inert state keeps the
    # trial-advance machinery from deciding it
    service.o2rt.trials["alex"] = types.SimpleNamespace(state="idle")
    service.submit(*_window(jax.random.PRNGKey(0), 1.0), budget_steps=4,
                   deadline_s=-1.0)
    service.step()
    st = service.stats()
    assert st["swaps"]["breaches_during_trial"] == 1
    assert st["slo"]["breaches"]["dropped_queued"] == 1
    assert "breaches_during_trial" not in st["slo"]["breaches"]


# ------------------------------------------------------------------- seams
def test_bootstrap_ci_deterministic_and_sane():
    deltas = [3.0, 5.0, 4.0, 6.0, 2.0, 5.5]
    lo1, hi1 = o2_runtime._bootstrap_ci(deltas, 0.95, 500,
                                        np.random.default_rng(0))
    lo2, hi2 = o2_runtime._bootstrap_ci(deltas, 0.95, 500,
                                        np.random.default_rng(0))
    assert (lo1, hi1) == (lo2, hi2)             # seeded -> replayable
    assert lo1 <= np.mean(deltas) <= hi1
    assert lo1 > 0.0                            # all-positive deltas pass

    # zero-straddling deltas must not exclude zero
    lo, hi = o2_runtime._bootstrap_ci([1.0, -1.0, 2.0, -2.0, 0.5, -0.5],
                                      0.95, 500, np.random.default_rng(0))
    assert lo <= 0.0 <= hi
    # a single sample collapses to a point interval (no spread to resample)
    assert o2_runtime._bootstrap_ci([4.2], 0.95, 100,
                                    np.random.default_rng(0)) == (4.2, 4.2)


def test_ci_gate_rejects_noisy_wins(monkeypatch):
    """With the CI gate armed and the per-window deltas forced to
    straddle zero, a win must be ci_rejected, not promoted."""
    monkeypatch.setattr(o2_runtime, "_pooled_best", lambda *a: -1.0)
    service = _service(SwapConfig(ci_gate=True))
    # the gate bootstraps per-step deltas for a single-window dispatch;
    # force that spread to straddle zero
    monkeypatch.setattr(o2_runtime, "_bootstrap_ci",
                        lambda *a, **k: (-1.0, 1.0))
    _serve_wave(service, [1.0, 3.0], fold=0)
    sw = service.stats()["swaps"]
    assert sw["ci_rejected"] == 1
    assert sw["candidates"] == 0 and sw["promoted"] == 0
    assert "alex" not in service.o2rt.trials


def test_swap_timing_rides_injected_clock(monkeypatch):
    """`hot_swap` measures through the service's injectable clock, not a
    bare time.perf_counter: a fake clock advancing 1s per call makes each
    recorded swap take exactly 1 fake second."""
    monkeypatch.setattr(o2_runtime, "_pooled_best", lambda *a: -1.0)
    ticks = {"t": 0.0}

    def fake_clock():
        ticks["t"] += 1.0
        return ticks["t"]

    service = _service(SwapConfig(), clock=fake_clock)   # immediate path
    _serve_wave(service, [1.0, 3.0], fold=0)
    tenant = service.tenants["alex"]
    assert tenant.swaps >= 1
    assert tenant.swap_times_s == [1.0] * tenant.swaps
    assert service.stats()["o2"]["alex"]["mean_swap_ms"] == 1000.0
