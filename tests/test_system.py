"""End-to-end behaviour tests for the paper's system (LITune)."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.ddpg import DDPGConfig
from repro.core.litune import LITune, LITuneConfig
from repro.core.maml import MetaConfig
from repro.index import env as E
from repro.index.workloads import StreamConfig, sample_keys, stream_windows, wr_workload


def _small_cfg(index_type="alex", **kw):
    return LITuneConfig(
        index_type=index_type, episode_len=8,
        lstm_hidden=16, mlp_hidden=32,
        ddpg=DDPGConfig(batch_size=8, seq_len=4, burn_in=1),
        meta=MetaConfig(meta_batch=1, inner_episodes=1, inner_updates=2),
        **kw)


@pytest.fixture(scope="module")
def pretrained():
    tuner = LITune(_small_cfg(), seed=0)
    tuner.pretrain(n_outer=2)
    return tuner


def test_end_to_end_tuning_beats_or_matches_default(pretrained,
                                                    small_index_instance):
    data, workload = small_index_instance
    res = pretrained.tune(data, workload, 1.0, budget_steps=8)
    # the tuner must never *deploy* something worse than default: best
    # runtime tracked over the episode is <= default by construction
    assert res["best_runtime_ns"] <= res["r0_ns"] * 1.0 + 1e-6
    assert len(res["best_params"]) == 14  # ALEX Table-2 dimensionality


def test_tuning_request_api_carmi(small_index_instance):
    tuner = LITune(_small_cfg("carmi"), seed=1)
    data, workload = small_index_instance
    res = tuner.tune(data, workload, 1.0, budget_steps=5)
    assert len(res["best_params"]) == 13  # CARMI Table-2 dimensionality
    assert np.isfinite(res["best_runtime_ns"])


def test_stream_o2_runs_and_monitors_divergence(pretrained):
    scfg = StreamConfig(n_windows=4, base_per_window=1024,
                        updates_per_window=1024, drift_per_window=0.2)
    res = pretrained.stream(stream_windows(jax.random.PRNGKey(9), scfg),
                            max_steps_per_window=3)
    assert len(res) == 4
    assert all(np.isfinite(r["best_runtime_ns"]) for r in res)
    assert pretrained._o2 is not None
    assert len(pretrained._o2.divergences) >= 2  # monitor active


def test_save_load_roundtrip(pretrained, tmp_path):
    path = str(tmp_path / "agent.pkl")
    pretrained.save(path)
    loaded = LITune.load(path)
    a = jax.tree.leaves(pretrained.state["params"])[0]
    b = jax.tree.leaves(loaded.state["params"])[0]
    np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_safe_variant_bounds_violations():
    """ET-MDP terminates episodes after C violations, so total violations
    during pretraining are bounded vs the unsafe variant (Fig-12 mechanism);
    identical seeds => identical task sequences."""
    def violations(safe: bool) -> float:
        tuner = LITune(_small_cfg(safe_rl=safe), seed=42)
        hist = tuner.pretrain(n_outer=3, seed=42)
        return sum(h["violations"] for h in hist)
    assert violations(True) <= violations(False) + 1e-9


def test_reward_uses_paper_formula(small_index_instance):
    from repro.core import reward as rw
    data, workload = small_index_instance
    cfg = E.EnvConfig(index_type="alex", episode_len=4)
    es, obs = E.reset(cfg, data, workload, 1.0)
    a = jnp.zeros(cfg.space.dim)
    es2, obs2, r, done, info = E.step(cfg, es, a)
    expect = rw.reward(info["runtime_ns"], es["r0"], es["r0"])
    assert float(r) == pytest.approx(float(expect), rel=1e-5)


def test_meta_adaptation_beats_scratch_on_new_task():
    """Example 3.1: the meta-init adapts to an unseen instance better than
    a scratch init given the same small adaptation budget."""
    from repro.core import ddpg
    from repro.core.etmdp import rollout_episode
    from repro.core.maml import TaskSpec, inner_adapt, make_task_env

    cfg = _small_cfg()
    meta = LITune(cfg, seed=7)
    meta.pretrain(n_outer=3, seed=7)
    scratch = LITune(cfg, seed=1234)  # untrained

    task = TaskSpec(dist="fb", wr_ratio=3.0, drift=0.25, seed=999)
    data, workload = make_task_env(task)

    def adapted_quality(tuner):
        st, _ = inner_adapt(jax.random.PRNGKey(5), tuner.state, task,
                            cfg.net_cfg(), cfg.ddpg, cfg.env_cfg(),
                            cfg.et_cfg(), cfg.meta)
        s = rollout_episode(jax.random.PRNGKey(6), st, cfg.net_cfg(),
                            cfg.env_cfg(), cfg.et_cfg(), data, workload,
                            task.wr_ratio, deterministic=True)
        return s["best_runtime_ns"]

    # meta-init should adapt at least as well (tolerance: tiny budgets)
    assert adapted_quality(meta) <= adapted_quality(scratch) * 1.15
