"""Shared fixtures. NOTE: no XLA_FLAGS here by design — smoke tests and
benchmarks must see the real (single) device; only launch/dryrun.py pins
the 512-device host platform."""
import jax
import jax.numpy as jnp
import pytest


@pytest.fixture(scope="session")
def rng_key():
    return jax.random.PRNGKey(0)


@pytest.fixture(scope="session")
def small_index_instance():
    """A (data, workload) tuning instance shared across index/RL tests."""
    from repro.index.workloads import sample_keys, wr_workload
    key = jax.random.PRNGKey(42)
    data = sample_keys(key, 2048, "mix")
    workload, _ = wr_workload(jax.random.fold_in(key, 1), data, 1.0,
                              total=2048, dist="mix")
    return data, workload
