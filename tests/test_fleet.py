"""Fleet mode (launch/serving/fleet.py + core.o2 stacked fine-tuning).

* spill/repage round-trip — a `DeviceSequenceReplay` that spilled its
  pages to host (and kept ingesting while spilled) re-pages to a ring
  bitwise-identical to one that never left the device, including
  page-spanning episodes and ring wraparound;
* stacked-round parity — `fleet_finetune` over K tenants is bitwise
  K serial `offline_finetune` rounds in serial RNG order, at K=1 and
  K=3, and with a tenant evicted (quarantined) mid-round the surviving
  lanes' bits are untouched;
* program-cache flatness — after the pow2 ladder warms, sweeping the
  hot-set size binds zero new stacked programs and never touches the
  serving `_step_program` cache;
* tiering in the service — hot tenants age to warm (pages spill) and
  cold (zero device bytes, learner evicted, monitor history trimmed),
  and a cold tenant re-pages on new traffic; BALANCE-style warm starts
  are counted and the new `stats()["o2"]` fleet keys render.
"""
import types

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import ddpg
from repro.core.ddpg import DDPGConfig
from repro.core.litune import LITune, LITuneConfig
from repro.core.networks import NetConfig
from repro.core.o2 import (O2Config, O2System, _finetune_program,
                           _fleet_finetune_program, copy_state,
                           fleet_finetune, fleet_stack_impl,
                           sample_update_batches)
from repro.core.replay import DeviceSequenceReplay, _pow2_pad
from repro.index.workloads import sample_keys, wr_workload
from repro.launch.serving import (FleetConfig, FleetLearner,
                                  O2ServiceConfig, TuningService)
from repro.launch.serving.programs import (_fleet_stack_program,
                                           _pow2_ladder, _step_program)

OBS, ACT, HID = 9, 4, 16
NET = NetConfig(obs_dim=OBS, action_dim=ACT, lstm_hidden=HID,
                mlp_hidden=32)
DDPG = DDPGConfig(seq_len=3, burn_in=1, batch_size=8)


def _episode(rng, T):
    f32 = lambda *s: rng.standard_normal(s).astype(np.float32)  # noqa: E731
    done = np.concatenate([np.zeros(T - 1), [1.0]]).astype(np.float32)
    return dict(obs=f32(T, OBS), action=f32(T, ACT), reward=f32(T),
                next_obs=f32(T, OBS), done=done,
                cost=(rng.random(T) < 0.3).astype(np.float32),
                actor_hidden=(f32(T, HID), f32(T, HID)),
                critic_hidden=(f32(T, HID), f32(T, HID)))


def _ring(cap, seed=0, spilled=False):
    return DeviceSequenceReplay(cap, OBS, ACT, HID, seq_len=DDPG.seq_len,
                                seed=seed, spilled=spilled)


def _assert_trees_equal(a, b):
    jax.tree.map(
        lambda x, y: np.testing.assert_array_equal(np.asarray(x),
                                                   np.asarray(y)), a, b)


# ------------------------------------------------------- spill / re-page
@pytest.mark.parametrize("cap,lens", [
    (32, [5, 7, 9, 6, 8]),            # single page, ring wraps
    (512, [200, 200, 200]),           # page-spanning episodes + wrap
])
def test_spill_repage_bitwise(cap, lens):
    """A ring that spilled (and kept ingesting while spilled) re-pages to
    bitwise the never-left-device ring: contents, pointers, and the
    sampling RNG stream."""
    ref, sub = _ring(cap), _ring(cap)
    rng_r, rng_s = np.random.default_rng(3), np.random.default_rng(3)
    for i, T in enumerate(lens):
        ref.add_episode(**_episode(rng_r, T))
        if i == 1:
            sub.spill()                       # pages to host mid-stream
            assert sub.device_bytes == 0
        if i == len(lens) - 1:
            sub.repage()                      # back before the last write
            assert not sub.spilled
        sub.add_episode(**_episode(rng_s, T))
    sub.repage()                              # idempotent when on-device
    assert (ref.ptr, ref.size) == (sub.ptr, sub.size)
    for f in ("obs", "action", "reward", "next_obs", "done", "cost",
              "h_a", "c_a", "h_q", "c_q", "step_left"):
        np.testing.assert_array_equal(np.asarray(getattr(sub, f)),
                                      np.asarray(getattr(ref, f)),
                                      err_msg=f)
    b_ref = ref.sample_sequence_batches(2, 4)
    b_sub = sub.sample_sequence_batches(2, 4)
    for k in b_ref:
        np.testing.assert_array_equal(np.asarray(b_sub[k]),
                                      np.asarray(b_ref[k]), err_msg=k)


def test_spilled_construction_and_sampling():
    """A ring constructed spilled (the cold-start path) holds zero device
    bytes, ingests and samples on host pages, and samples bitwise the
    same batches as an on-device twin."""
    cold, hot = _ring(32, spilled=True), _ring(32)
    assert cold.spilled and cold.device_bytes == 0
    assert cold.host_bytes > hot.host_bytes   # pages counted host-side
    rng_c, rng_h = np.random.default_rng(5), np.random.default_rng(5)
    for _ in range(3):
        cold.add_episode(**_episode(rng_c, 7))
        hot.add_episode(**_episode(rng_h, 7))
    b_c = cold.sample_sequence_batches(2, 4)
    b_h = hot.sample_sequence_batches(2, 4)
    for k in b_h:
        np.testing.assert_array_equal(np.asarray(b_c[k]),
                                      np.asarray(b_h[k]), err_msg=k)


# --------------------------------------------------- stacked-round parity
def _tenant(i, cap=128, n_eps=4, ep_len=12):
    """A minimal fleet lane: its own replay RNG, its own learner state."""
    replay = _ring(cap, seed=i)
    rng = np.random.default_rng(40 + i)
    for _ in range(n_eps):
        replay.add_episode(**_episode(rng, ep_len))
    return types.SimpleNamespace(
        net_cfg=NET, ddpg_cfg=DDPG, replay=replay,
        offline=ddpg.init_state(jax.random.PRNGKey(i), NET, DDPG))


def _serial_round(tenant, n_updates):
    """The reference: one serial `offline_finetune`-shaped round drawn
    from this tenant's own replay RNG."""
    batches = sample_update_batches(tenant.replay, n_updates,
                                    tenant.ddpg_cfg.batch_size)
    batches = jax.tree.map(jnp.asarray, batches)
    return _finetune_program(NET, DDPG, n_updates)(
        copy_state(tenant.offline), batches)


@pytest.mark.parametrize("k", [1, 3])
def test_fleet_finetune_matches_serial(k):
    """The fleet correctness anchor: one stacked round over K tenants is
    bitwise K serial rounds — same replay RNG draws lane by lane, same
    learner bits out (the `map` lowering on CPU; K=1 also pins the
    degenerate stack)."""
    serial_ts = [_tenant(i) for i in range(k)]
    fleet_ts = [_tenant(i) for i in range(k)]
    serial = [_serial_round(t, 4) for t in serial_ts]

    learner = FleetLearner(FleetConfig(enabled=True, max_hot=4))
    ran = learner.round([(t, 4) for t in fleet_ts])
    assert [t for t, _ in ran] == fleet_ts
    for t, want in zip(fleet_ts, serial):
        _assert_trees_equal(t.offline, want)
    assert learner.rounds == 1 and learner.lanes == k
    assert learner.peak_stack == k


def test_fleet_round_mid_round_eviction_parity():
    """A tenant leaving the stack (quarantine eviction) cannot perturb
    the survivors: the round over {t0, t2} produces bitwise the same
    states t0 and t2 get from their own serial rounds — each lane's
    state and batches are its own."""
    ts = [_tenant(i) for i in range(3)]
    refs = [_tenant(i) for i in range(3)]
    want = {i: _serial_round(refs[i], 4) for i in (0, 2)}

    learner = FleetLearner(FleetConfig(enabled=True, max_hot=4))
    learner.round([(ts[0], 4), (ts[2], 4)])   # t1 evicted pre-dispatch
    _assert_trees_equal(ts[0].offline, want[0])
    _assert_trees_equal(ts[2].offline, want[2])


def test_fleet_program_cache_flat_across_hot_set_sweep():
    """After the pow2 ladder warms, sweeping the hot-set size 1..4 binds
    zero new stacked programs — and never touches the serving
    `_step_program` cache at all (the bench's hard invariant)."""
    impl = fleet_stack_impl("auto")
    for k_pad in _pow2_ladder(_pow2_pad(4)):
        _fleet_finetune_program(NET, DDPG, 4, k_pad, impl)
        _fleet_stack_program(k_pad)
    finetune_size = _fleet_finetune_program.cache_info().currsize
    stack_size = _fleet_stack_program.cache_info().currsize
    step_size = _step_program.cache_info().currsize

    learner = FleetLearner(FleetConfig(enabled=True, max_hot=4))
    for k in (1, 2, 3, 4, 2, 1):
        learner.round([(t, 4) for t in [_tenant(i) for i in range(k)]])
    assert _fleet_finetune_program.cache_info().currsize == finetune_size
    assert _fleet_stack_program.cache_info().currsize == stack_size
    assert _step_program.cache_info().currsize == step_size
    # occupancy: 13 useful lanes over 14 padded (3 -> pad 4)
    assert learner.lanes == 13 and learner.padded_lanes == 14


# --------------------------------------------------- service-level fleet
_O2 = O2Config(divergence_threshold=0.05, offline_updates_per_window=2)


def _cfg(index_type="alex", **kw) -> LITuneConfig:
    return LITuneConfig(index_type=index_type, episode_len=4,
                        lstm_hidden=16, mlp_hidden=32,
                        ddpg=DDPGConfig(seq_len=3, burn_in=1, batch_size=8),
                        o2=_O2, **kw)


def _windows(n, n_keys=512, seed=7):
    dists = ["uniform", "books", "osm", "fb"]
    wrs = [1.0, 1.0, 3.0, 0.33]
    key = jax.random.PRNGKey(seed)
    out = []
    for i in range(n):
        k = jax.random.fold_in(key, i)
        data = sample_keys(k, n_keys, dists[i % len(dists)])
        wl, _ = wr_workload(jax.random.fold_in(k, 1), data,
                            wrs[i % len(wrs)], total=n_keys, dist="mix")
        out.append((data, wl, wrs[i % len(wrs)]))
    return out


def test_service_fleet_parity_with_tune_window():
    """Fleet mode on, one tenant, strict order: the whole stream — swap
    decisions, offline params, online params — is bitwise the serial
    `O2System.tune_window` loop.  The lazy cold start, the promotion
    re-page, and the K=1 stacked round all collapse to the eager path's
    exact bits."""
    cfg = _cfg()
    budget = 4
    wins = _windows(4)
    wkeys = [jax.random.PRNGKey(50 + i) for i in range(len(wins))]

    serial_tuner = LITune(cfg, seed=0)
    o2sys = O2System(serial_tuner.state, cfg.net_cfg(), cfg.ddpg,
                     cfg.env_cfg(), cfg.et_cfg(), cfg.o2, seed=0)
    serial = [o2sys.tune_window(wkeys[i], d, wl, wr, max_steps=budget)
              for i, (d, wl, wr) in enumerate(wins)]
    assert any(r["divergence"]["diverged"] for r in serial)

    service = TuningService(
        LITune(cfg, seed=0), slots=1,
        o2=O2ServiceConfig(enabled=True, o2=cfg.o2, strict_order=True,
                           fleet=FleetConfig(enabled=True, max_hot=4,
                                             warm_after_ticks=64,
                                             cold_after_ticks=256)))
    rids = [service.submit(d, wl, wr, budget_steps=budget, key=wkeys[i],
                           noise_scale=0.02)
            for i, (d, wl, wr) in enumerate(wins)]
    results = service.run()
    tenant = service.tenants["alex"]

    for i, rid in enumerate(rids):
        got, want = results[rid], serial[i]
        assert got["divergence"] == want["divergence"]
        assert got["swapped"] == want["swapped"]
    assert tenant.swaps == o2sys.swaps
    assert tenant.tier == "hot" and tenant.repages == 1
    _assert_trees_equal(tenant.offline["params"], o2sys.offline["params"])
    _assert_trees_equal(tenant.online["params"], o2sys.online["params"])
    st = service.stats()
    assert st["o2"]["fleet"]["rounds"] > 0
    assert st["o2"]["fleet"]["occupancy"] == 1.0   # K=1 pads to 1


def test_service_fleet_tiering_and_warm_start():
    """Two tenants, tiny aging thresholds: the one that stops sending
    traffic ages hot -> warm -> cold (zero device bytes, learner evicted
    to host, monitor history trimmed), while the active one stays hot;
    the late tenant's first window warm-starts from the established
    neighbor and is counted."""
    cfg_a, cfg_b = _cfg("a"), _cfg("b")
    budget = 4
    fleet = FleetConfig(enabled=True, max_hot=2, warm_after_ticks=2,
                        cold_after_ticks=4, monitor_history=2)
    service = TuningService(
        {"a": LITune(cfg_a, seed=0), "b": LITune(cfg_b, seed=1)}, slots=1,
        o2=O2ServiceConfig(enabled=True, o2=_O2, strict_order=True,
                           fleet=fleet))
    wins = _windows(8)
    wkeys = [jax.random.PRNGKey(90 + i) for i in range(len(wins))]

    # tenant "a" streams three windows (> monitor_history) and goes quiet
    for i in (0, 1, 2):
        d, wl, wr = wins[i]
        service.submit(d, wl, wr, budget_steps=budget, index_type="a",
                       key=wkeys[i], noise_scale=0.02)
    service.run()
    ta = service.tenants["a"]
    assert ta.tier == "hot" and ta.embedding is not None
    assert service.o2rt.warm_starts == 0       # no donors existed for "a"

    # tenant "b" arrives: first window embeds + seeds from "a"; the
    # continued stream keeps "b" hot while "a" ages out
    for i in range(3, len(wins)):
        d, wl, wr = wins[i]
        service.submit(d, wl, wr, budget_steps=budget, index_type="b",
                       key=wkeys[i], noise_scale=0.02)
    service.run()
    tb = service.tenants["b"]
    assert tb.warm_started and service.o2rt.warm_starts == 1
    assert tb.tier == "hot"
    # "a" idled through >= cold_after_ticks service ticks: fully evicted
    assert ta.tier == "cold"
    assert ta.device_bytes() == 0
    assert ta.host_bytes() > 0                 # learner evicted, not lost
    assert len(ta.monitor.divergences) <= fleet.monitor_history
    assert ta.monitor.history_trimmed >= 1

    st = service.stats()
    assert st["o2"]["a"]["tier"] == "cold"
    assert st["o2"]["b"]["tier"] == "hot"
    assert st["o2"]["tenants_hot"] == 1 and st["o2"]["tenants_cold"] == 1
    assert st["o2"]["warm_starts"] == 1
    assert st["o2"]["fleet"]["evictions"] >= 1
    assert st["o2"]["device_bytes"] > 0        # "b" is resident

    # new traffic re-pages the cold tenant: first divergence observation
    # (or retirement) promotes it back to hot with its ring intact
    d, wl, wr = wins[1]
    service.submit(d, wl, wr, budget_steps=budget, index_type="a",
                   key=jax.random.PRNGKey(123), noise_scale=0.02)
    service.run()
    assert ta.tier == "hot" and ta.repages >= 2
    assert ta.device_bytes() > 0
