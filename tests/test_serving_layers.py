"""The layered serving stack (launch/serving/): adaptive slot
scheduling, request-level SLOs, per-pool observability, and the
tune_serve re-export shim.

* adaptive resize — a bursty queue grows its pool and a drained one
  shrinks it, mid-flight episodes ride through the resize bitwise, and a
  repeat grow→shrink cycle binds zero new programs (`programs_resident`
  and the per-service binder both stay flat);
* SLOs — queued breaches drop before admission, running breaches
  truncate (best-so-far prefix summary) or drop per request, surviving
  slots' decisions stay bitwise identical, and `stats()["slo"]` reports
  queue-wait/serve-time percentiles + breach counts;
* shim — `repro.launch.tune_serve` re-exports the same objects the
  `serving` package defines.
"""
import dataclasses

import jax
import numpy as np
import pytest

import repro.launch.serving.programs as programs
from repro.core import etmdp
from repro.core.litune import LITune, LITuneConfig
from repro.index.workloads import sample_keys, wr_workload
from repro.launch.serving import (AdaptiveSlotPolicy, EDFSlotPolicy,
                                  SLOConfig, StaticSlotPolicy,
                                  TuningService)
from repro.launch.serving.scheduler import Scheduler


def _cfg(index_type: str = "alex", **kw) -> LITuneConfig:
    return LITuneConfig(index_type=index_type, episode_len=4,
                        lstm_hidden=16, mlp_hidden=32, **kw)


def _instances(n: int, n_keys: int = 512, seed: int = 5, wr: float = 1.0):
    key = jax.random.PRNGKey(seed)
    out = []
    for i in range(n):
        k = jax.random.fold_in(key, i)
        data = sample_keys(k, n_keys, "mix")
        wl, _ = wr_workload(jax.random.fold_in(k, 1), data, wr,
                            total=n_keys, dist="mix")
        out.append((data, wl))
    return out


def _serial(tuner, cfg, data, wl, wr, budget, key, noise=0.05):
    return etmdp.rollout_episode(
        key, tuner.state, cfg.net_cfg(),
        dataclasses.replace(cfg.env_cfg(), episode_len=budget),
        cfg.et_cfg(), data, wl, wr, noise_scale=noise)


class _FakeClock:
    """Injectable service clock: time advances only when the test says."""

    def __init__(self):
        self.t = 0.0

    def __call__(self) -> float:
        return self.t


# ------------------------------------------------------------------- shim
def test_tune_serve_shim_reexports_same_objects():
    """`repro.launch.tune_serve` keeps working and hands out the *same*
    objects as the layered package — external imports and `python -m
    repro.launch.tune_serve` stay valid.  (Identity, not patchability:
    monkeypatching a shim attribute rebinds only the shim's name — patch
    the owning serving module instead, as tests/test_o2_service.py
    does.)"""
    import repro.launch.serving as serving
    import repro.launch.serving.o2_runtime as o2_runtime
    import repro.launch.serving.service as service_mod
    import repro.launch.tune_serve as shim

    assert shim.TuningService is serving.TuningService
    assert shim.TuningService is service_mod.TuningService
    assert shim.O2ServiceConfig is serving.O2ServiceConfig
    assert shim.TuneRequest is serving.TuneRequest
    assert shim.AdaptiveSlotPolicy is serving.AdaptiveSlotPolicy
    assert shim.SLOConfig is serving.SLOConfig
    assert shim._SlotPool is serving._SlotPool
    assert shim.summarize_episode is serving.summarize_episode
    # shared process-wide caches and seams are the same objects too
    assert shim._step_program is programs._step_program
    assert shim._pooled_best is o2_runtime._pooled_best
    assert callable(shim.main)


# -------------------------------------------------------- adaptive sizing
def test_adaptive_policy_and_scheduler_hysteresis():
    """Policy seam: growth tracks demand immediately, shrink waits out
    the patience window and the active episodes."""
    policy = AdaptiveSlotPolicy(min_slots=1, max_slots=8, shrink_patience=2)
    ladder = [1, 2, 4, 8, 16]
    assert policy.desired_slots(slots=1, active=0, queued=0,
                                ladder=ladder) == 1
    assert policy.desired_slots(slots=1, active=1, queued=2,
                                ladder=ladder) == 4
    assert policy.desired_slots(slots=4, active=0, queued=100,
                                ladder=ladder) == 8      # capped

    sched = Scheduler(policy)

    class _Stub:
        slots, n_active = 4, 1

    pool = _Stub()
    # growth is immediate
    assert sched.plan_resize(("p",), pool, queued=7, ladder=ladder) == 8
    # shrink needs `shrink_patience` consecutive low-demand ticks
    assert sched.plan_resize(("p",), pool, queued=0, ladder=ladder) is None
    assert sched.plan_resize(("p",), pool, queued=0, ladder=ladder) == 1
    # a demand spike resets the streak
    assert sched.plan_resize(("p",), pool, queued=0, ladder=ladder) is None
    assert sched.plan_resize(("p",), pool, queued=5, ladder=ladder) == 8
    assert sched.plan_resize(("p",), pool, queued=0, ladder=ladder) is None


def test_adaptive_resize_bitwise_and_zero_retrace():
    """A pool grows mid-flight under a burst and shrinks when the queue
    drains; the episode that rode through both resizes stays bitwise
    identical to its serial rollout, and a second identical grow→shrink
    cycle binds zero new step programs (`programs_resident` flat, binder
    misses flat — the K-ladder cache makes reshaping free)."""
    cfg = _cfg(safe_rl=False)           # no early exits: deterministic
    tuner = LITune(cfg, seed=0)
    policy = AdaptiveSlotPolicy(min_slots=1, max_slots=4, shrink_patience=1)
    service = TuningService(tuner, slots=1, policy=policy)
    budget = 3                          # K2 + K1: episodes span two ticks

    def one_cycle(seed):
        inst = _instances(5, seed=seed)
        keys = [jax.random.fold_in(jax.random.PRNGKey(900 + seed), i)
                for i in range(5)]
        rid0 = service.submit(*inst[0], 1.0, budget_steps=budget,
                              key=keys[0])
        service.step()                  # solo: pool stays at 1, K2 tick
        pool = next(iter(service.pools.values()))
        assert pool.slots == 1 and pool.steps_taken[0] == 2
        rids = [service.submit(*inst[i], 1.0, budget_steps=budget,
                               key=keys[i]) for i in range(1, 4)]
        service.step()                  # burst: grow 1->4 MID-FLIGHT
        assert pool.slots == 4
        assert rid0 in service.results  # rid0 finished its K1 tick
        results = service.run()         # drain the burst
        rid4 = service.submit(*inst[4], 1.0, budget_steps=budget,
                              key=keys[4])
        results = service.run()         # low demand: shrink 4->1
        assert pool.slots == 1
        assert pool.resizes["grow"] >= 1 and pool.resizes["shrink"] >= 1
        # every episode — including the one that spanned the grow and the
        # one admitted after the shrink — matches its serial rollout
        for rid, key, (data, wl) in zip([rid0] + rids + [rid4], keys, inst):
            want = _serial(tuner, cfg, data, wl, 1.0, budget, key)
            got = results[rid]
            assert got["steps"] == want["steps"]
            assert got["runtimes"] == want["runtimes"]
            assert got["episode_return"] == want["episode_return"]

    one_cycle(seed=21)
    resident0 = programs._step_program.cache_info().currsize
    misses0 = service.program_misses
    pool_slice = next(iter(service.pools.values())).slice
    resize_traces0 = programs._resize_program(pool_slice)._cache_size()
    one_cycle(seed=22)                  # same widths, fresh requests
    assert programs._step_program.cache_info().currsize == resident0
    assert service.program_misses == misses0
    # the resize gathers re-used their traced shapes too
    assert programs._resize_program(pool_slice)._cache_size() == \
        resize_traces0

    st = service.stats()
    pk = next(iter(st["per_pool"]))
    assert st["per_pool"][pk]["resizes"]["grow"] >= 2
    assert st["per_pool"][pk]["resizes"]["shrink"] >= 2
    assert st["scheduler"]["policy"] == "adaptive"
    assert st["scheduler"]["resize_events"] >= 4


# ------------------------------------------------------------------- SLOs
def test_deadline_truncate_preserves_survivors():
    """A running request past its deadline is truncated — its summary is
    the bitwise prefix of the no-deadline run — while the surviving
    slot's decisions stay bitwise identical to a service with no
    deadlines at all (slots are independent lanes)."""
    cfg = _cfg(safe_rl=False)
    tuner = LITune(cfg, seed=0)
    (d0, w0), (d1, w1) = _instances(2)
    k0, k1 = jax.random.PRNGKey(300), jax.random.PRNGKey(301)

    ref = TuningService(tuner, slots=2)
    ra = ref.submit(d0, w0, 1.0, budget_steps=12, key=k0)
    rb = ref.submit(d1, w1, 1.0, budget_steps=16, key=k1)
    ref_results = ref.run()

    clock = _FakeClock()
    service = TuningService(tuner, slots=2, clock=clock)
    ta = service.submit(d0, w0, 1.0, budget_steps=12, key=k0,
                        deadline_s=5.0, on_breach="truncate")
    tb = service.submit(d1, w1, 1.0, budget_steps=16, key=k1)
    service.step()                      # K8 tick: A at 8/12, B at 8/16
    clock.t = 10.0                      # A's deadline (5s) passes
    results = service.run()

    got_a, want_a = results[ta], ref_results[ra]
    assert got_a["slo_breached"] and got_a["truncated"]
    assert got_a["steps"] == 8          # truncated at the breaching tick
    assert got_a["runtimes"] == want_a["runtimes"][:8]   # bitwise prefix
    # the survivor is bitwise untouched by its neighbor's truncation
    got_b, want_b = results[tb], ref_results[rb]
    assert got_b["steps"] == want_b["steps"] == 16
    assert got_b["runtimes"] == want_b["runtimes"]
    assert "slo_breached" not in got_b

    slo = service.stats()["slo"]
    assert slo["breaches"] == {"dropped_queued": 0, "dropped_running": 0,
                               "pre_dropped": 0, "truncated": 1}
    assert slo["tracked"] == 2
    assert slo["serve_ms"]["p99"] >= slo["serve_ms"]["p50"] >= 0.0


def test_deadline_drop_running_and_queued():
    """`on_breach="drop"` abandons a breached running episode (the result
    records only the drop), and a request whose deadline lapses while
    queued is dropped before ever occupying a slot."""
    cfg = _cfg(safe_rl=False)
    tuner = LITune(cfg, seed=0)
    (d0, w0), (d1, w1), (d2, w2) = _instances(3)

    clock = _FakeClock()
    service = TuningService(tuner, slots=1, clock=clock)
    r_run = service.submit(d0, w0, 1.0, budget_steps=12,
                           deadline_s=5.0, on_breach="drop")
    r_q = service.submit(d1, w1, 1.0, budget_steps=4, deadline_s=5.0)
    r_ok = service.submit(d2, w2, 1.0, budget_steps=4)
    service.step()                      # r_run runs its K8 tick
    clock.t = 10.0                      # both deadlines lapse
    results = service.run()

    assert results[r_run] == {"dropped": True, "slo_breached": True,
                              "steps": 8, "terminated_early": False}
    assert results[r_q]["dropped"] and results[r_q]["steps"] == 0
    assert results[r_ok]["steps"] == 4 and "dropped" not in results[r_ok]
    slo = service.stats()["slo"]
    assert slo["breaches"]["dropped_running"] == 1
    assert slo["breaches"]["dropped_queued"] == 1
    assert slo["breaches"]["pre_dropped"] == 0
    assert slo["breaches"]["truncated"] == 0


def test_slo_defaults_and_validation():
    cfg = _cfg(safe_rl=False)
    tuner = LITune(cfg, seed=0)
    clock = _FakeClock()
    service = TuningService(tuner, slots=1, clock=clock,
                            slo=SLOConfig(default_deadline_s=5.0,
                                          on_breach="drop"))
    (d0, w0), = _instances(1)
    rid = service.submit(d0, w0, 1.0, budget_steps=12)
    req = service.queue[0]
    assert req.deadline_s == 5.0 and req.on_breach == "drop"
    with pytest.raises(ValueError, match="on_breach"):
        service.submit(d0, w0, 1.0, budget_steps=4, on_breach="retry")
    service.step()
    clock.t = 6.0
    results = service.run()
    assert results[rid]["dropped"]


# ----------------------------------------------------------- observability
def test_stats_per_pool_breakdowns_and_slo_always_present():
    """stats() exposes per-pool slots/occupancy/resize counters (the
    adaptive scheduler's observability) and the SLO block even on a
    plain static frozen service."""
    agents = {"alex": LITune(_cfg("alex"), seed=0),
              "carmi": LITune(_cfg("carmi"), seed=1)}
    service = TuningService(agents, slots=2)
    inst = _instances(4, n_keys=512)
    for i, (d, w) in enumerate(inst):
        service.submit(d, w, 1.0, budget_steps=2,
                       index_type="alex" if i % 2 == 0 else "carmi")
    results = service.run()
    assert len(results) == 4

    st = service.stats()
    assert st["pools"] == 2             # the historical count, unchanged
    assert len(st["per_pool"]) == 2
    for pk, entry in st["per_pool"].items():
        assert entry["slots"] == 2 and entry["active"] == 0
        assert entry["peak_slots"] == 2
        assert entry["resizes"] == {"grow": 0, "shrink": 0}
    assert st["scheduler"] == {"policy": "static", "resize_events": 0}
    slo = st["slo"]
    assert set(slo) == {"queue_wait_ms", "serve_ms", "breaches", "tracked"}
    assert slo["tracked"] == 4
    assert set(slo["queue_wait_ms"]) == {"p50", "p95", "p99"}
    assert slo["breaches"] == {"dropped_queued": 0, "dropped_running": 0,
                               "pre_dropped": 0, "truncated": 0}


def test_static_policy_never_resizes():
    """The default policy is the PR 1–3 behavior: pool widths are fixed
    whatever the queue does."""
    tuner = LITune(_cfg(safe_rl=False), seed=0)
    service = TuningService(tuner, slots=1, policy=StaticSlotPolicy())
    for d, w in _instances(5):
        service.submit(d, w, 1.0, budget_steps=2)
    service.run()
    pool = next(iter(service.pools.values()))
    assert pool.slots == 1
    assert pool.resizes == {"grow": 0, "shrink": 0}


# -------------------------------------------------------------------- EDF
def test_edf_admission_orders_by_deadline():
    """With one slot and three queued requests, the EDF policy admits
    the tightest absolute deadline first (deadline-less requests rank
    last, FIFO among themselves) — while the default policy would have
    admitted in submission order."""
    cfg = _cfg(safe_rl=False)
    tuner = LITune(cfg, seed=0)
    clock = _FakeClock()
    service = TuningService(tuner, slots=1, policy=EDFSlotPolicy(),
                            clock=clock)
    (d0, w0), (d1, w1), (d2, w2) = _instances(3)
    r_loose = service.submit(d0, w0, 1.0, budget_steps=2, deadline_s=60.0)
    r_none = service.submit(d1, w1, 1.0, budget_steps=2)
    r_tight = service.submit(d2, w2, 1.0, budget_steps=2, deadline_s=5.0)

    admitted = []
    orig = service.slo.on_admit

    def spy(req, now):
        admitted.append(req.rid)
        orig(req, now)

    service.slo.on_admit = spy
    service.run()
    assert admitted == [r_tight, r_loose, r_none]
    assert service.stats()["scheduler"]["policy"] == "edf"


def test_edf_pre_drops_hopeless_requests():
    """A queued request whose budget cannot fit its deadline at the
    measured tick rate is pre-dropped (flagged, counted) before it ever
    occupies a slot; feasible requests are untouched."""
    cfg = _cfg(safe_rl=False)
    tuner = LITune(cfg, seed=0)
    clock = _FakeClock()
    service = TuningService(tuner, slots=1, policy=EDFSlotPolicy(),
                            clock=clock)
    # a measured tick rate of 1 s per episode-step (injected: the fake
    # clock never advances through real ticks)
    service.scheduler.s_per_step = 1.0
    (d0, w0), (d1, w1) = _instances(2)
    r_hopeless = service.submit(d0, w0, 1.0, budget_steps=12,
                                deadline_s=5.0)      # needs ~12 s
    r_fine = service.submit(d1, w1, 1.0, budget_steps=2,
                            deadline_s=60.0)
    results = service.run()

    assert results[r_hopeless] == {
        "dropped": True, "slo_breached": True, "pre_dropped": True,
        "steps": 0, "terminated_early": False}
    assert results[r_fine]["steps"] == 2
    assert "dropped" not in results[r_fine]
    slo = service.stats()["slo"]
    assert slo["breaches"]["pre_dropped"] == 1
    assert slo["breaches"]["dropped_queued"] == 1    # pre-drop is queued


def test_edf_policy_unit():
    """Policy seam: ordering is by absolute deadline with FIFO ties, and
    hopelessness needs a measured rate plus an armed deadline."""
    import dataclasses as dc

    policy = EDFSlotPolicy()

    @dc.dataclass
    class R:
        rid: int
        submitted_at: float
        deadline_s: float | None
        budget_steps: int = 4

    a = R(0, 0.0, 10.0)
    b = R(1, 0.0, 2.0)
    c = R(2, 0.0, None)
    d = R(3, 1.0, None)
    assert [r.rid for r in policy.admission_order([a, b, c, d], 0.0)] == \
        [1, 0, 2, 3]
    # no rate estimate or no deadline -> never hopeless
    assert not policy.hopeless(b, 0.0, None)
    assert not policy.hopeless(c, 0.0, 1.0)
    # budget 4 steps at 1 s/step vs 2 s left -> hopeless
    assert policy.hopeless(b, 0.0, 1.0)
    assert not policy.hopeless(a, 0.0, 1.0)
