"""Batched tuning-as-a-service engine (launch/serving/):

* batched-vs-serial parity — a B-slot `TuningService` produces bitwise
  identical per-request runtimes/rewards to B independent
  `rollout_episode` calls with the same PRNG keys (alex and carmi);
* slot recycling — a short-budget request finishes mid-flight and its
  slot is reused by a queued request;
* compiled-program cache — a mixed alex/carmi stream compiles one
  program per (space, shape) group and reuses them.
"""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import etmdp
from repro.core.litune import LITune, LITuneConfig, attach_best_params
from repro.index.workloads import sample_keys, wr_workload
from repro.launch.serving import TuningService


def _cfg(index_type: str, **kw) -> LITuneConfig:
    return LITuneConfig(index_type=index_type, episode_len=4,
                        lstm_hidden=16, mlp_hidden=32, **kw)


def _instances(n: int, n_keys: int = 512, seed: int = 5, wr: float = 1.0):
    key = jax.random.PRNGKey(seed)
    out = []
    for i in range(n):
        k = jax.random.fold_in(key, i)
        data = sample_keys(k, n_keys, "mix")
        wl, _ = wr_workload(jax.random.fold_in(k, 1), data, wr,
                            total=n_keys, dist="mix")
        out.append((data, wl))
    return out


# ------------------------------------------------------------------ parity
@pytest.mark.parametrize("index_type", ["alex", "carmi"])
def test_batched_parity_with_serial(index_type):
    cfg = _cfg(index_type)
    tuner = LITune(cfg, seed=0)
    slots, budget, wr = 3, 4, 1.0
    inst = _instances(slots)
    keys = [jax.random.PRNGKey(100 + i) for i in range(slots)]

    serial = [
        etmdp.rollout_episode(
            keys[i], tuner.state, cfg.net_cfg(),
            dataclasses.replace(cfg.env_cfg(), episode_len=budget),
            cfg.et_cfg(), data, wl, wr, noise_scale=0.05)
        for i, (data, wl) in enumerate(inst)
    ]

    service = TuningService(tuner, slots=slots)
    rids = [service.submit(data, wl, wr, budget_steps=budget,
                           key=keys[i], noise_scale=0.05)
            for i, (data, wl) in enumerate(inst)]
    results = service.run()

    for i, rid in enumerate(rids):
        got, want = results[rid], serial[i]
        assert got["steps"] == want["steps"]
        assert got["terminated_early"] == want["terminated_early"]
        # bitwise: same floats out of the same traced per-step program
        assert got["runtimes"] == want["runtimes"]
        assert got["episode_return"] == want["episode_return"]
        assert got["violations"] == want["violations"]
        assert got["best_runtime_ns"] == want["best_runtime_ns"]
        assert got["r0_ns"] == want["r0_ns"]
        # bitwise holds for the actions too: the service's lax.map body is
        # the same unbatched program as the serial episode_step
        for a_got, a_want in zip(got["actions"], want["actions"]):
            np.testing.assert_array_equal(a_got, a_want)
        assert got["best_params"] == attach_best_params(
            want, dataclasses.replace(cfg.env_cfg(), episode_len=budget))


def test_tune_many_matches_tune_shape():
    """LITune.tune_many returns summaries in the LITune.tune shape."""
    tuner = LITune(_cfg("alex"), seed=0)
    inst = _instances(2)
    out = tuner.tune_many([(d, w, 1.0) for d, w in inst], slots=2,
                          budget_steps=3)
    assert len(out) == 2
    ref = tuner.tune(*inst[0], 1.0, budget_steps=3)
    for s in out:
        assert set(ref) == set(s)
        assert s["steps"] == 3
        assert set(s["best_params"]) == set(ref["best_params"])


# ------------------------------------------------------------ recycling
def test_slot_recycling():
    """A short-budget request retires mid-flight and its slot is taken by
    the queued request; everything completes."""
    tuner = LITune(_cfg("alex", safe_rl=False), seed=0)  # no early exits
    service = TuningService(tuner, slots=2)
    (d0, w0), (d1, w1), (d2, w2) = _instances(3)
    r_short = service.submit(d0, w0, 1.0, budget_steps=2)
    r_long = service.submit(d1, w1, 1.0, budget_steps=6)
    r_queued = service.submit(d2, w2, 1.0, budget_steps=3)

    # tick 1 scans K=2 (the short request's remaining budget bounds K):
    # the short request completes, the third still waits in the queue
    service.step()
    pool = next(iter(service.pools.values()))
    assert r_short in service.results
    assert len(service.queue) == 1          # only 2 slots, third waited
    freed = [i for i, r in enumerate(pool.requests) if r is None]
    assert len(freed) == 1                  # short request's slot is free
    active = [r.rid for r in pool.requests if r is not None]
    assert active == [r_long]

    # tick 2 admits the queued request into the freed slot, mid-flight
    service.step()
    assert len(service.queue) == 0
    assert pool.requests[freed[0]] is not None \
        and pool.requests[freed[0]].rid == r_queued  # recycled slot

    results = service.run()
    assert sorted(results) == sorted([r_short, r_long, r_queued])
    assert results[r_short]["steps"] == 2
    assert results[r_long]["steps"] == 6
    assert results[r_queued]["steps"] == 3
    assert service.episode_steps == 2 + 6 + 3   # no lost/duplicated work


# ------------------------------------------------------------ program cache
def test_mixed_stream_program_cache():
    """alex and carmi requests interleave; one compile per space, then
    pure reuse."""
    agents = {"alex": LITune(_cfg("alex"), seed=0),
              "carmi": LITune(_cfg("carmi"), seed=1)}
    service = TuningService(agents, slots=2)
    inst = _instances(8, n_keys=512)
    for i, (d, w) in enumerate(inst):
        service.submit(d, w, 1.0, budget_steps=2,
                       index_type="alex" if i % 2 == 0 else "carmi")
    results = service.run()
    assert len(results) == 8
    st = service.stats()
    assert st["program_misses"] == 2        # one step program per space
    assert st["program_hits"] >= 2          # the second wave reuses both
    assert st["queued"] == 0
