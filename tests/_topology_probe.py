"""Subprocess probe for tests/test_topology.py.

Runs a fixed, fully deterministic request stream through a
`TuningService` under a *forced* host-device count (the flag must be set
before jax initializes, which is why this is a subprocess and not a
fixture) and prints a JSON report of everything the parity tests
compare bitwise:

  * per-request summaries (runtimes, returns, steps, divergence/swap
    annotations under O2);
  * the pooled-assessment verdict inputs (`_pooled_best` values) and the
    widths of the annex sub-slices the assessment waves sharded over;
  * compiled-program accounting (per-service binds, process-wide
    resident step programs).

`--mode o2` freezes the learner (`offline_updates_per_tick=0`) and
serves zero-noise episodes, so every decision — divergence verdicts,
assessment bests, swap outcomes — is a pure function of the stream, not
of annex timing; that is what makes the cross-device-count comparison
exact.

`--compare-mesh` additionally re-runs the same stream through a
`ServingTopology.from_mesh` carving of a real 2-row mesh over the same
device ids and reports whether results matched bitwise and how many new
programs the second topology bound (the equal-shape-topologies
zero-re-trace guarantee).
"""
from __future__ import annotations

import argparse
import json
import os
import sys


def _build_requests(n: int, n_keys: int, jax):
    """The drifting window stream the O2 tests use: key distribution
    cycles so the divergence monitor fires."""
    from repro.index.workloads import sample_keys, wr_workload
    dists = ["uniform", "books", "osm", "fb"]
    wrs = [1.0, 1.0, 3.0, 0.33]
    key = jax.random.PRNGKey(7)
    out = []
    for i in range(n):
        k = jax.random.fold_in(key, i)
        data = sample_keys(k, n_keys, dists[i % len(dists)])
        wl, _ = wr_workload(jax.random.fold_in(k, 1), data,
                            wrs[i % len(wrs)], total=n_keys, dist="mix")
        out.append((data, wl, wrs[i % len(wrs)]))
    return out


def _summaries(results: dict) -> dict:
    out = {}
    for rid, r in results.items():
        entry = {"steps": r["steps"], "runtimes": r["runtimes"],
                 "episode_return": r["episode_return"],
                 "best_runtime_ns": r["best_runtime_ns"],
                 "violations": r["violations"]}
        if "divergence" in r:
            entry["divergence"] = r["divergence"]
            entry["swapped"] = r["swapped"]
        out[str(rid)] = entry
    return out


def main():
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--devices", type=int, required=True)
    ap.add_argument("--mode", choices=["frozen", "o2"], default="frozen")
    ap.add_argument("--slots", type=int, default=4)
    ap.add_argument("--requests", type=int, default=5)
    ap.add_argument("--budget", type=int, default=4)
    ap.add_argument("--n-keys", type=int, default=256)
    ap.add_argument("--annex-width", type=int, default=None)
    ap.add_argument("--compare-mesh", action="store_true")
    ap.add_argument("--mesh-rows", type=int, default=2,
                    help="leading-axis rows of the --compare-mesh carve "
                         "(2 keeps the host layout's slice ids; more "
                         "rows pin pools to distinct row slices)")
    args = ap.parse_args()

    # force the host platform device count; the forced count *replaces*
    # any count inherited from the environment (a CI job's 4-device flag
    # must not leak into the 1-device parity run)
    flags = [f for f in os.environ.get("XLA_FLAGS", "").split()
             if "xla_force_host_platform_device_count" not in f]
    flags.append(
        f"--xla_force_host_platform_device_count={args.devices}")
    os.environ["XLA_FLAGS"] = " ".join(flags)
    os.environ.setdefault("JAX_PLATFORMS", "cpu")

    import jax

    import repro.launch.serving.o2_runtime as o2_runtime
    import repro.launch.serving.programs as programs
    from repro.core.ddpg import DDPGConfig
    from repro.core.litune import LITune, LITuneConfig
    from repro.core.o2 import O2Config
    from repro.launch.serving import (O2ServiceConfig, ServingTopology,
                                      TuningService)

    assert len(jax.devices()) == args.devices, jax.devices()

    cfg = LITuneConfig(
        index_type="alex", episode_len=args.budget, lstm_hidden=16,
        mlp_hidden=32, safe_rl=False,
        ddpg=DDPGConfig(seq_len=3, burn_in=1, batch_size=8),
        o2=O2Config(divergence_threshold=0.05, assess_every=1,
                    offline_updates_per_window=2))
    o2_cfg = None
    noise = 0.05
    if args.mode == "o2":
        # frozen learner + zero-noise episodes: every O2 decision is a
        # pure function of the stream (see module docstring)
        o2_cfg = O2ServiceConfig(enabled=True, o2=cfg.o2,
                                 offline_updates_per_tick=0)
        noise = 0.0

    # record every pooled-assessment verdict input and the annex
    # sub-slice widths the waves actually sharded over
    pooled_bests: list[float] = []
    assess_widths: list[int] = []
    real_best = o2_runtime._pooled_best

    def recording_best(r0, runtimes):
        best = real_best(r0, runtimes)
        pooled_bests.append(best)
        return best

    o2_runtime._pooled_best = recording_best
    # O2Runtime's construction-time warm binding also calls
    # assess_slice; only widths resolved *inside a dispatch* count as
    # waves that actually sharded
    in_dispatch: list[bool] = []
    orig_dispatch = o2_runtime.O2Runtime._dispatch_assess
    orig_assess_slice = ServingTopology.assess_slice

    def recording_dispatch(self, pk, pool, tenant, chunk):
        in_dispatch.append(True)
        try:
            return orig_dispatch(self, pk, pool, tenant, chunk)
        finally:
            in_dispatch.pop()

    def recording_assess_slice(self, batch):
        sl = orig_assess_slice(self, batch)
        if in_dispatch:
            assess_widths.append(sl.width)
        return sl

    o2_runtime.O2Runtime._dispatch_assess = recording_dispatch
    ServingTopology.assess_slice = recording_assess_slice

    requests = _build_requests(args.requests, args.n_keys, jax)
    wkeys = [jax.random.PRNGKey(50 + i) for i in range(len(requests))]

    def run_stream(topology):
        service = TuningService(
            LITune(cfg, seed=0), slots=args.slots, o2=o2_cfg,
            topology=topology)
        for i, (d, wl, wr) in enumerate(requests):
            service.submit(d, wl, wr, budget_steps=args.budget,
                           key=wkeys[i], noise_scale=noise)
        results = service.run()
        service.flush_o2()
        return service, _summaries(results)

    topo = ServingTopology.host(args.slots, annex_width=args.annex_width)
    service, summaries = run_stream(topo)

    report = {
        "devices": args.devices,
        "mode": args.mode,
        "topology": topo.describe(),
        "results": summaries,
        "programs": {
            "misses": service.program_misses,
            "resident": programs._step_program.cache_info().currsize,
        },
    }
    if args.mode == "o2":
        st = service.stats()["o2"]
        report["o2"] = {
            "assessments": st["assessments"],
            "annex_width": st["annex_width"],
            "annex_shared": st["annex_shared"],
            "pooled_bests": sorted(pooled_bests),
            "assess_widths": sorted(assess_widths),
            "swaps": st["alex"]["swaps"],
        }

    if args.compare_mesh:
        # the same stream through a carved production-style mesh: with 2
        # rows its slices cover the same device ids as the host layout
        # (the zero-re-trace case); with more rows the stream's pools
        # pin to *distinct* row slices (the pod-spanning case)
        rows = args.mesh_rows
        assert args.devices % rows == 0 and args.devices >= 2 * rows
        mesh = jax.make_mesh((rows, args.devices // rows),
                             ("data", "model"))
        topo2 = ServingTopology.from_mesh(mesh, args.slots)
        resident0 = programs._step_program.cache_info().currsize
        misses0 = service.program_misses
        pooled_bests.clear()
        service2, summaries2 = run_stream(topo2)
        report["mesh_compare"] = {
            "topology": topo2.describe(),
            "equal": summaries2 == summaries,
            "new_resident": (programs._step_program.cache_info().currsize
                             - resident0),
            "binder_misses_delta": service2.program_misses - misses0,
            "pool_slices_used": {
                "/".join(str(x) for x in pk): pool.slice.name
                for pk, pool in service2.pools.items()},
        }

    json.dump(report, sys.stdout)
    print()


if __name__ == "__main__":
    main()
