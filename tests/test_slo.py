"""SLOTracker edge cases: the degenerate streams the percentile math
and breach bookkeeping must survive — zero samples (a fresh tracker, or
one whose every request died queued), a single sample (all percentiles
collapse onto it), and all-breach streams where nothing ever retires
cleanly.  Pure host-side unit tests: fake requests are namespaces, time
is explicit."""
import types

from repro.launch.serving.slo import SLOConfig, SLOTracker, _percentiles_ms


def _req(rid: int, submitted_at: float):
    return types.SimpleNamespace(rid=rid, submitted_at=submitted_at)


def _tracker(window: int = 4096) -> SLOTracker:
    return SLOTracker(clock=lambda: 0.0, window=window)


# ----------------------------------------------------- percentile math
def test_percentiles_empty_samples_are_zero():
    assert _percentiles_ms([]) == {"p50": 0.0, "p95": 0.0, "p99": 0.0}


def test_percentiles_single_sample_collapse():
    got = _percentiles_ms([0.25])
    assert got["p50"] == got["p95"] == got["p99"] == 250.0


def test_percentiles_are_milliseconds_rounded():
    got = _percentiles_ms([0.1, 0.2])
    assert got["p50"] == 150.0
    assert got["p99"] == 199.0


# ------------------------------------------------------- zero requests
def test_zero_sample_tracker_stats():
    trk = _tracker()
    st = trk.stats()
    assert st["tracked"] == 0
    assert st["queue_wait_ms"] == {"p50": 0.0, "p95": 0.0, "p99": 0.0}
    assert st["serve_ms"] == {"p50": 0.0, "p95": 0.0, "p99": 0.0}
    br = st["breaches"]
    assert br["dropped_queued"] == br["dropped_running"] == 0
    assert br["truncated"] == br["pre_dropped"] == 0


def test_retire_unknown_rid_is_harmless():
    trk = _tracker()
    trk.on_retire(999, now=1.0)          # never admitted
    assert len(trk.serve_s) == 0 and trk.tracked == 0


# ------------------------------------------------------- single sample
def test_single_request_lifecycle_percentiles_collapse():
    trk = _tracker()
    trk.on_admit(_req(1, submitted_at=10.0), now=10.5)
    trk.on_retire(1, now=12.5)
    st = trk.stats_block()
    assert trk.tracked == 1
    q, s = st.queue_wait_ms, st.serve_ms
    assert q["p50"] == q["p95"] == q["p99"] == 500.0
    assert s["p50"] == s["p95"] == s["p99"] == 2000.0
    assert trk._admitted_at == {}        # bookkeeping fully drained


# --------------------------------------------------- all-breach streams
def test_all_requests_dropped_queued():
    """Every request dies waiting: serve percentiles stay 0.0 (no serve
    samples), the accrued waits still count against the queue SLO."""
    trk = _tracker()
    n = 8
    for i in range(n):
        trk.on_drop_queued(_req(i, submitted_at=0.0), now=1.0 + i,
                           pre=(i % 2 == 0))
    st = trk.stats_block()
    assert trk.tracked == n
    assert trk.dropped_queued == n
    assert trk.pre_dropped == n // 2
    assert len(trk.serve_s) == 0
    assert st.serve_ms == {"p50": 0.0, "p95": 0.0, "p99": 0.0}
    assert st.queue_wait_ms["p50"] > 0.0


def test_all_requests_breach_running_dropped():
    """Admitted then dropped mid-run: no serve sample is recorded (the
    episode never completed), and the admit map drains."""
    trk = _tracker()
    for i in range(4):
        trk.on_admit(_req(i, submitted_at=0.0), now=0.1)
        trk.on_breach_running(_req(i, submitted_at=0.0), now=5.0,
                              dropped=True)
    assert trk.dropped_running == 4 and trk.truncated == 0
    assert len(trk.serve_s) == 0
    assert trk._admitted_at == {}
    assert trk.stats_block().serve_ms["p50"] == 0.0


def test_all_requests_breach_running_truncated():
    """Truncation retires with the best-so-far: the serve time up to the
    breach IS a sample — truncated requests must not vanish from the
    latency evidence."""
    trk = _tracker()
    for i in range(4):
        trk.on_admit(_req(i, submitted_at=0.0), now=0.0)
        trk.on_breach_running(_req(i, submitted_at=0.0), now=3.0,
                              dropped=False)
    assert trk.truncated == 4 and trk.dropped_running == 0
    st = trk.stats_block()
    assert st.serve_ms["p50"] == st.serve_ms["p99"] == 3000.0


def test_mixed_breaches_and_window_bound():
    """The sample window is bounded; the cumulative counters are not."""
    trk = _tracker(window=4)
    for i in range(10):
        trk.on_admit(_req(i, submitted_at=0.0), now=float(i))
        trk.on_retire(i, now=float(i) + 1.0)
    assert trk.tracked == 10
    assert len(trk.queue_wait_s) == 4 and len(trk.serve_s) == 4
    # window holds the most recent 4 waits (6..9 s)
    assert min(trk.queue_wait_s) == 6.0


def test_slo_config_defaults():
    cfg = SLOConfig()
    assert cfg.default_deadline_s is None
    assert cfg.on_breach == "truncate"
