"""Optimizer + schedules + workload-generator unit/property tests."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from _hypothesis_compat import given, settings, st

from repro.optim.adamw import AdamWConfig, adamw_update, global_norm, init_opt_state
from repro.optim.schedules import warmup_cosine
from repro.index.workloads import sample_keys, wr_workload


def test_adamw_converges_on_quadratic():
    target = jnp.asarray(np.random.default_rng(0).normal(size=16),
                         jnp.float32)
    params = {"w": jnp.zeros(16, jnp.float32)}
    state = init_opt_state(params)
    cfg = AdamWConfig(lr=0.05, weight_decay=0.0)
    for _ in range(300):
        grads = {"w": params["w"] - target}
        params, state, _ = adamw_update(params, grads, state, cfg)
    assert float(jnp.max(jnp.abs(params["w"] - target))) < 0.05


def test_adamw_grad_clipping():
    params = {"w": jnp.zeros(4, jnp.float32)}
    state = init_opt_state(params)
    cfg = AdamWConfig(lr=1.0, grad_clip_norm=1.0, weight_decay=0.0)
    _, _, metrics = adamw_update(params, {"w": jnp.full(4, 100.0)}, state,
                                 cfg)
    assert float(metrics["grad_norm"]) == pytest.approx(200.0)


def test_adamw_weight_decay_applies_to_matrices_only():
    params = {"mat": jnp.ones((4, 4)), "vec": jnp.ones((4,))}
    state = init_opt_state(params)
    cfg = AdamWConfig(lr=0.1, weight_decay=1.0, grad_clip_norm=1e9)
    zero_g = jax.tree.map(jnp.zeros_like, params)
    new_params, _, _ = adamw_update(params, zero_g, state, cfg)
    assert float(jnp.max(new_params["mat"])) < 1.0   # decayed
    assert float(jnp.max(new_params["vec"])) == 1.0  # untouched


def test_warmup_cosine_shape():
    sched = warmup_cosine(1.0, warmup_steps=10, total_steps=100)
    assert float(sched(jnp.int32(0))) == pytest.approx(0.0)
    assert float(sched(jnp.int32(10))) == pytest.approx(1.0, rel=1e-3)
    assert float(sched(jnp.int32(100))) == pytest.approx(0.1, rel=1e-2)


# ------------------------------------------------------------ workloads
@settings(max_examples=10, deadline=None)
@given(st.sampled_from(["uniform", "books", "osm", "fb", "mix"]),
       st.integers(0, 10_000))
def test_sample_keys_sorted_in_unit_interval(dist, seed):
    keys = sample_keys(jax.random.PRNGKey(seed), 512, dist)
    k = np.asarray(keys)
    assert np.all(np.diff(k) >= 0)
    assert k.min() >= 0.0 and k.max() <= 1.0


@settings(max_examples=10, deadline=None)
@given(st.floats(0.1, 10.0), st.integers(0, 1000))
def test_wr_workload_ratio(wr, seed):
    key = jax.random.PRNGKey(seed)
    data = sample_keys(key, 256, "uniform")
    workload, cfg = wr_workload(jax.random.fold_in(key, 1), data, wr,
                                total=1024)
    got = workload["inserts"].shape[0] / max(workload["reads"].shape[0], 1)
    assert got == pytest.approx(wr, rel=0.15)
    assert workload["reads"].shape[0] + workload["inserts"].shape[0] == 1024
