"""The serving fault-tolerance layer (launch/serving/health.py):

* param-health guards — NaN fine-tune rounds are rejected before they
  can publish (last-good params retained, served params stay finite),
  and an unhealthy swap candidate never reaches a pool, win or not;
* annex watchdog — failed dispatches retry with backoff, exhaustion
  demotes the annex into degraded mode, a successful half-open probe
  recovers it; a hung dispatch is abandoned by the drain watchdog and
  `flush_o2` returns a bounded partial-flush report instead of hanging;
* per-tenant circuit breakers — repeated unhealthy rounds quarantine
  the tenant's O2 loop (pools serve frozen), released automatically
  after the window cooloff;
* DivergenceMonitor — non-finite window summaries are skipped and
  counted, never ingested into the reference (the satellite regression);
* guards observe, they don't perturb — a faultless strict-order stream
  is bitwise identical with health enabled vs disabled.

All faults are injected deterministically through
`HealthConfig(fault=FaultPlan(...))` — the `runtime/fault.py` FaultSite
idiom — so every path here is replayable; the end-to-end drill against
the full fault battery is benchmarks/slo_serve.py --scenario chaos.
"""
import time

import jax
import numpy as np
import pytest

import repro.launch.serving.o2_runtime as o2_runtime
from repro.core.ddpg import DDPGConfig
from repro.core.litune import LITune, LITuneConfig
from repro.core.o2 import DivergenceMonitor, O2Config
from repro.index.workloads import sample_keys, wr_workload
from repro.launch.serving import (FaultPlan, HealthConfig, O2ServiceConfig,
                                  ServeConfig, SwapConfig, TuningService)
from repro.runtime.fault import FaultSite, InjectedFailure

# KS effectively off: divergence fires purely on W/R shift (exact), so
# every assessment trigger here is deterministic
_O2 = O2Config(divergence_threshold=10.0, wr_shift_threshold=0.5,
               offline_updates_per_window=2, assess_every=1)


def _cfg(**kw) -> LITuneConfig:
    return LITuneConfig(index_type="alex", episode_len=4, lstm_hidden=16,
                        mlp_hidden=32,
                        ddpg=DDPGConfig(seq_len=3, burn_in=1, batch_size=8),
                        o2=_O2, **kw)


def _window(key, wr: float, n_keys: int = 256):
    data = sample_keys(key, n_keys, "mix")
    wl, _ = wr_workload(jax.random.fold_in(key, 1), data, wr,
                        total=n_keys, dist="mix")
    return data, wl, wr


def _service(health: HealthConfig, swap: SwapConfig | None = None,
             slots: int = 2, strict: bool = False) -> TuningService:
    cfg = _cfg()
    return TuningService(LITune(cfg, seed=0), config=ServeConfig(
        slots=slots, horizon_cap=8,
        o2=O2ServiceConfig(enabled=True, o2=cfg.o2, strict_order=strict),
        swap=swap if swap is not None else SwapConfig(), health=health))


def _serve_wave(service, wrs, fold: int, flush: bool = True):
    key = jax.random.PRNGKey(3)
    rids = [service.submit(*_window(jax.random.fold_in(key, 97 * fold + i),
                                    wr), budget_steps=4)
            for i, wr in enumerate(wrs)]
    service.run()
    if flush:
        service.flush_o2()
    return rids


def _all_finite(tree) -> bool:
    return all(np.all(np.isfinite(np.asarray(leaf)))
               for leaf in jax.tree.leaves(jax.device_get(tree)))


# ---------------------------------------------------- DivergenceMonitor
def test_divergence_monitor_skips_nonfinite_windows():
    """The satellite regression: one NaN window summary must not poison
    the reference/divergence bookkeeping permanently."""
    mon = DivergenceMonitor(O2Config(n_quantiles=16))
    mon.observe(np.linspace(0.0, 1.0, 64), 1.0)        # anchors
    ref = mon.ref_quantiles.copy()
    bad = np.linspace(0.0, 1.0, 64)
    bad[3] = np.nan
    v = mon.observe(bad, 1.0)
    assert v["skipped_nonfinite"] is True and v["diverged"] is False
    assert mon.skipped_nonfinite == 1
    np.testing.assert_array_equal(mon.ref_quantiles, ref)
    v = mon.observe(np.linspace(0.0, 1.0, 64), np.inf)  # bad wr too
    assert v["skipped_nonfinite"] is True
    assert mon.skipped_nonfinite == 2
    # the invariant holds through skips: one divergence entry per window
    assert len(mon.divergences) == mon.windows_seen == 3
    # detection still works afterwards (wr shift fires exactly)
    v = mon.observe(np.linspace(0.0, 1.0, 64), 3.0)
    assert v["diverged"] and mon.diverged_count == 1
    # a non-finite re-anchor is refused: reference and history unchanged
    anchors = list(mon.anchors)
    mon.re_anchor(bad, 1.0)
    np.testing.assert_array_equal(mon.ref_quantiles, ref)
    assert mon.anchors == anchors and mon.skipped_nonfinite == 3


def test_divergence_monitor_nonfinite_first_window_never_anchors():
    mon = DivergenceMonitor(O2Config(n_quantiles=16))
    mon.observe(np.full(64, np.nan), 1.0)
    assert mon.ref_quantiles is None and mon.skipped_nonfinite == 1
    # the first *finite* window becomes the reference instead
    v = mon.observe(np.linspace(0.0, 1.0, 64), 1.0)
    assert v == {"diverged": False, "ks": 0.0, "wr_shift": 0.0}
    assert mon.ref_quantiles is not None
    assert len(mon.divergences) == mon.windows_seen == 2


# ----------------------------------------------------------- FaultSite
def test_fault_site_fires_at_planned_ordinals():
    site = FaultSite(fire_at=(1, 3))
    assert [site.check() for _ in range(5)] == \
        [False, True, False, True, False]
    assert site.count == 5
    assert not any(FaultSite().check() for _ in range(4))


# ------------------------------------------------------ param guards
def test_nan_finetune_rounds_rejected_and_last_good_served():
    """Every fine-tune round NaNs out; the guard must reject each at
    publish, keep serving finite params, and never swap garbage in."""
    service = _service(HealthConfig(
        quarantine_threshold=100,        # keep the breaker out of this test
        fault=FaultPlan(nan_finetune_rounds=tuple(range(64)))))
    for fold in range(4):
        _serve_wave(service, [1.0, 3.0], fold)
    st = service.stats()
    assert st["health"]["rejected_params"] >= 1
    tenant = service.tenants["alex"]
    # everything serve-visible stays finite (offline may transiently
    # hold a not-yet-gated poisoned round in concurrent mode — gating
    # happens at publish)
    assert _all_finite(tenant.ready_params)
    assert _all_finite(tenant._last_good["params"])
    assert _all_finite(tenant.online["params"])
    for pool in service.pools.values():
        assert _all_finite(pool.params)


def test_unhealthy_swap_candidate_never_reaches_pools(monkeypatch):
    """Even a forced assessment win must not swap a non-finite candidate
    (the swap-candidacy guard site), and the rejection strikes the
    tenant's breaker."""
    monkeypatch.setattr(o2_runtime, "_pooled_best", lambda *a: -1.0)
    service = _service(HealthConfig(quarantine_threshold=100))
    _serve_wave(service, [1.0], fold=0)    # anchor window, no divergence
    tenant = service.tenants["alex"]
    # poison the published snapshot the next assessment dispatches with,
    # and pin the publish seam so a healthy fine-tune round in the same
    # wave can't republish over it before the dispatch captures it
    tenant.ready_params = jax.tree.map(
        lambda x: np.full(x.shape, np.nan, x.dtype),
        jax.device_get(tenant.ready_params))
    monkeypatch.setattr(tenant, "publish_ready", lambda: None)
    before = dict(service.stats()["health"])
    _serve_wave(service, [3.0, 3.0], fold=1)   # diverge -> forced win
    st = service.stats()
    assert st["health"]["rejected_params"] > before["rejected_params"]
    assert tenant.swaps == 0
    assert tenant.bad_streak >= 1
    for pool in service.pools.values():
        assert _all_finite(pool.params)


def test_healthy_forced_win_still_swaps(monkeypatch):
    """The guard is observe-only on healthy paths: the same forced win
    with finite params still promotes (nothing rejected)."""
    monkeypatch.setattr(o2_runtime, "_pooled_best", lambda *a: -1.0)
    service = _service(HealthConfig())
    _serve_wave(service, [1.0, 3.0], fold=0)
    st = service.stats()
    assert service.tenants["alex"].swaps >= 1
    assert st["health"]["rejected_params"] == 0


# ------------------------------------------------------ annex watchdog
def test_failed_dispatches_retry_demote_then_recover():
    health = HealthConfig(dispatch_retries=1, retry_backoff_s=1e-3,
                          annex_failure_threshold=1, annex_cooloff_s=0.0,
                          fault=FaultPlan(fail_assess_dispatches=(0, 1)))
    service = _service(health)
    _serve_wave(service, [1.0, 3.0], fold=0)
    st = service.stats()["health"]
    assert st["retries"] >= 1
    assert st["annex_demotions"] == 1
    assert st["dropped_dispatches"] >= 1
    # ordinals exhausted: the next diverged window's dispatch is the
    # half-open probe and succeeds -> automatic recovery
    _serve_wave(service, [3.0, 1.0], fold=1)
    st = service.stats()["health"]
    assert st["annex_recoveries"] == 1
    assert st["state"] == "healthy"


def test_degraded_mode_pauses_o2_but_keeps_serving():
    """While demoted inside the cooloff, ticks do no O2 work (counted as
    degraded) but requests keep completing on frozen params."""
    health = HealthConfig(dispatch_retries=0, annex_failure_threshold=1,
                          annex_cooloff_s=60.0,
                          fault=FaultPlan(fail_assess_dispatches=(0,)))
    service = _service(health)
    _serve_wave(service, [1.0, 3.0], fold=0)   # dispatch fails -> demoted
    st = service.stats()["health"]
    assert st["annex_demotions"] == 1 and st["state"] == "degraded"
    updates_before = service.tenants["alex"].offline_updates
    rids = _serve_wave(service, [3.0, 1.0], fold=1)
    st = service.stats()
    assert all(rid in service.results for rid in rids)   # still serving
    assert st["health"]["degraded_ticks"] >= 1
    # no learner rounds, no new assessments while paused
    assert service.tenants["alex"].offline_updates == updates_before
    assert st["health"]["state"] == "degraded"


def test_hung_dispatch_watchdog_and_bounded_flush():
    health = HealthConfig(dispatch_timeout_s=0.05, flush_deadline_s=5.0,
                          fault=FaultPlan(hang_assess_dispatches=(0,)))
    service = _service(health)
    _serve_wave(service, [1.0, 3.0], fold=0, flush=False)
    t0 = time.monotonic()
    report = service.flush_o2()
    assert time.monotonic() - t0 < 5.0
    assert set(report) == {"deadline_hit", "abandoned_backlog",
                           "abandoned_inflight", "elapsed_s"}
    st = service.stats()["health"]
    assert st["dropped_dispatches"] >= 1


def test_flush_deadline_returns_partial_report():
    """A zero deadline abandons whatever is pending immediately and says
    so — `flush_o2` is bounded even with work in flight."""
    health = HealthConfig(dispatch_timeout_s=60.0,
                          fault=FaultPlan(hang_assess_dispatches=(0,)))
    service = _service(health)
    _serve_wave(service, [1.0, 3.0], fold=0, flush=False)
    if service.o2rt.inflight or service.o2rt.backlog:
        report = service.flush_o2(deadline_s=0.0)
        assert report["deadline_hit"] is True
        assert report["abandoned_inflight"] + \
            report["abandoned_backlog"] >= 1
    assert not service.o2rt.inflight and not service.o2rt.backlog
    # a follow-up flush with nothing pending settles cleanly
    report = service.flush_o2()
    assert report["deadline_hit"] is False
    assert report["abandoned_inflight"] == 0


# ------------------------------------------------- tenant circuit breaker
def test_tenant_quarantine_trips_and_releases():
    health = HealthConfig(quarantine_threshold=1, quarantine_windows=2,
                          fault=FaultPlan(
                              nan_finetune_rounds=tuple(range(16))))
    service = _service(health)
    fold = 0
    while service.stats()["health"]["quarantines"] < 1 and fold < 8:
        _serve_wave(service, [1.0, 3.0], fold)
        fold += 1
    st = service.stats()["health"]
    assert st["quarantines"] == 1
    assert st["quarantined"] == ["alex"]
    # quarantined: no fine-tune rounds, no assessment dispatches
    tenant = service.tenants["alex"]
    updates = tenant.offline_updates
    assessments = service.o2rt.assessments
    _serve_wave(service, [3.0], fold=50)
    assert tenant.offline_updates == updates
    assert service.o2rt.assessments == assessments
    # ... but windows are still observed, and after quarantine_windows
    # more of them the breaker releases with a clean streak
    while service.stats()["health"]["quarantine_releases"] < 1 and \
            fold < 16:
        _serve_wave(service, [1.0, 3.0], 100 + fold)
        fold += 1
    st = service.stats()["health"]
    assert st["quarantine_releases"] == 1
    assert st["quarantined"] == []
    assert tenant.bad_streak == 0


def test_forced_canary_losses_strike_the_breaker(monkeypatch):
    """Repeated canary rollbacks open the breaker too — the 'keeps
    rolling back' arm of the circuit."""
    monkeypatch.setattr(o2_runtime, "_pooled_best", lambda *a: -1.0)
    service = _service(
        HealthConfig(quarantine_threshold=2, quarantine_windows=4,
                     fault=FaultPlan(lose_canary_trials=(0, 1))),
        swap=SwapConfig(canary=True, canary_fraction=0.5,
                        canary_min_episodes=1, canary_timeout_ticks=64),
        slots=4)
    fold = 0
    while service.stats()["health"]["quarantines"] < 1 and fold < 10:
        _serve_wave(service, [1.0, 3.0], fold)
        fold += 1
    st = service.stats()
    assert st["swaps"]["rolled_back_canary"] >= 2
    assert st["health"]["quarantines"] == 1
    # the incumbent pool params were never touched by the lost canaries
    for pool in service.pools.values():
        assert pool.canary_lanes is None
        assert _all_finite(pool.params)


# ------------------------------------------------ guards don't perturb
def test_health_guards_do_not_perturb_faultless_results():
    """Bitwise: a faultless strict-order stream is identical with the
    guards enabled (default) and disabled — they observe, not perturb."""
    def run(enabled: bool):
        cfg = _cfg()
        service = TuningService(LITune(cfg, seed=0), config=ServeConfig(
            slots=1, horizon_cap=8,
            o2=O2ServiceConfig(enabled=True, o2=cfg.o2,
                               strict_order=True),
            health=HealthConfig(enabled=enabled)))
        key = jax.random.PRNGKey(11)
        rids = [service.submit(*_window(jax.random.fold_in(key, i), wr),
                               budget_steps=4)
                for i, wr in enumerate([1.0, 3.0, 3.0, 1.0])]
        results = service.run()
        service.flush_o2()
        return ([results[rid] for rid in rids],
                jax.device_get(service.tenants["alex"].offline["params"]))

    res_on, params_on = run(True)
    res_off, params_off = run(False)
    for a, b in zip(res_on, res_off):
        assert a["swapped"] == b["swapped"]
        assert a.get("divergence") == b.get("divergence")
        np.testing.assert_array_equal(a["best_runtime_ns"],
                                      b["best_runtime_ns"])
    jax.tree.map(lambda x, y: np.testing.assert_array_equal(x, y),
                 params_on, params_off)


def test_health_config_validation_and_defaults():
    with pytest.raises(ValueError):
        HealthConfig(max_param_norm=0.0)
    with pytest.raises(ValueError):
        HealthConfig(dispatch_retries=-1)
    with pytest.raises(ValueError):
        HealthConfig(quarantine_windows=0)
    # default ServeConfig carries the guards enabled with no fault plan
    cfg = ServeConfig()
    assert cfg.health.enabled and cfg.health.fault is None
    assert isinstance(InjectedFailure("x"), RuntimeError)
