"""The fused K-ladder tick (`kernels/fused_tick` + the serving step
program's `capture=True` variant):

* the Pallas capture kernel is bitwise against the jnp oracle (pure data
  movement — property-based across shapes/offsets);
* a fused-tick service stream is bitwise-identical to the unfused
  scan-of-steps + standalone-capture path on the CPU reference path —
  results, stats-visible decisions, and replay-ring contents;
* the fused variant lives in the same resident program cache: a second
  identically-shaped stream binds zero new step programs.
"""
import jax
import jax.numpy as jnp
import numpy as np
from _hypothesis_compat import given, settings, st

import repro.launch.serving.programs as programs
from repro.core.litune import LITune, LITuneConfig
from repro.index.workloads import sample_keys, wr_workload
from repro.kernels.dispatch import KernelConfig
from repro.kernels.fused_tick.ops import fused_capture
from repro.kernels.fused_tick.ref import FIELD_ORDER, fused_capture_ref
from repro.launch.serving.config import ServeConfig
from repro.launch.serving.o2_runtime import O2ServiceConfig
from repro.launch.serving.service import TuningService


# --------------------------------------------------------- kernel parity
@settings(max_examples=10, deadline=None)
@given(st.integers(0, 10_000), st.integers(1, 4), st.integers(1, 6))
def test_fused_capture_interpret_matches_ref(seed, k_steps, b):
    """The Pallas append equals the jnp oracle bitwise: same packing
    order, same rows touched, untouched rows preserved."""
    key = jax.random.PRNGKey(seed)
    h = 16
    dims = {"obs": 3, "next_obs": 3, "h_a": 2, "c_a": 2, "h_q": 2,
            "c_q": 2}
    wide = sum(dims.values())
    ks = jax.random.split(key, len(FIELD_ORDER) + 2)
    new = {f: jax.random.normal(ks[i], (k_steps, b, dims[f]), jnp.float32)
           for i, f in enumerate(FIELD_ORDER)}
    cap = jax.random.normal(ks[-2], (b, h, wide), jnp.float32)
    offsets = jax.random.randint(ks[-1], (b,), 0, h - k_steps + 1)
    got = fused_capture(cap, new, offsets, mode="interpret")
    want = fused_capture(cap, new, offsets, mode="ref")
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))
    # and the oracle really is the historical _capture_write body
    direct = fused_capture_ref(cap, new, offsets.astype(jnp.int32))
    np.testing.assert_array_equal(np.asarray(want), np.asarray(direct))


def test_fused_capture_field_order_matches_replay():
    """The capture feature axis must slice back out in replay order."""
    from repro.core.replay import WIDE_FIELDS
    assert FIELD_ORDER == WIDE_FIELDS


# ------------------------------------------------- service-level parity
def _stream(kernel: KernelConfig, n_req: int = 4):
    cfg = LITuneConfig(index_type="alex", episode_len=8, lstm_hidden=16,
                       mlp_hidden=32)
    svc = TuningService(LITune(cfg, seed=0), config=ServeConfig(
        slots=2, horizon_cap=8, seed=0,
        o2=O2ServiceConfig(enabled=True), kernel=kernel))
    key = jax.random.PRNGKey(1)
    for i in range(n_req):
        k = jax.random.fold_in(key, i)
        data = sample_keys(k, 512, "mix")
        wl, _ = wr_workload(jax.random.fold_in(k, 1), data, 1.0,
                            total=512, dist="mix")
        svc.submit(data, wl, 1.0, budget_steps=8)
    res = svc.run()
    svc.flush_o2()
    return svc, res


def _ring_arrays(replay):
    """Every array leaf hanging off the replay ring, keyed by attr."""
    out = {}
    for name, val in replay.__dict__.items():
        leaves = [x for x in jax.tree.leaves(val) if hasattr(x, "shape")]
        if leaves:
            out[name] = leaves
    return out


def test_fused_tick_bitwise_equals_scan_of_steps():
    """The acceptance anchor: a fused-tick O2 stream (default
    KernelConfig) is bitwise-equal to the unfused scan-of-steps +
    standalone-capture path — per-request results AND the replay ring
    the capture buffers feed."""
    svc_f, res_f = _stream(KernelConfig())               # fused default
    svc_u, res_u = _stream(KernelConfig(fused_tick=False))
    assert set(res_f) == set(res_u)
    for rid in res_f:
        a, b = res_f[rid], res_u[rid]
        assert a["episode_return"] == b["episode_return"]
        assert a["runtimes"] == b["runtimes"]
        assert all(np.array_equal(x, y)
                   for x, y in zip(a["actions"], b["actions"]))
    for it in svc_f.tenants:
        rf = _ring_arrays(svc_f.tenants[it].replay)
        ru = _ring_arrays(svc_u.tenants[it].replay)
        assert set(rf) == set(ru)
        for name in rf:
            for x, y in zip(rf[name], ru[name]):
                np.testing.assert_array_equal(
                    np.asarray(x), np.asarray(y), err_msg=(it, name))


def test_fused_variant_zero_new_binds_after_warmup():
    """The fused program rides the same resident ladder cache: a second
    identically-shaped stream re-uses every executable — zero new step
    programs, zero cache misses."""
    svc, _ = _stream(KernelConfig())
    resident0 = programs._step_program.cache_info().currsize
    misses0 = svc.program_misses
    key = jax.random.PRNGKey(9)
    for i in range(3):
        k = jax.random.fold_in(key, i)
        data = sample_keys(k, 512, "mix")
        wl, _ = wr_workload(jax.random.fold_in(k, 1), data, 1.0,
                            total=512, dist="mix")
        svc.submit(data, wl, 1.0, budget_steps=8)
    svc.run()
    svc.flush_o2()
    assert programs._step_program.cache_info().currsize == resident0
    assert svc.program_misses == misses0
