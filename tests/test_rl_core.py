"""Unit tests for the RL core: reward (paper §4.1), ET-MDP termination,
DDPG update mechanics, replay sequencing, O2 divergence detection."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from _hypothesis_compat import given, settings, st

from repro.core import ddpg, networks as nets, reward as rw
from repro.core.ddpg import DDPGConfig
from repro.core.etmdp import ETMDPConfig, rollout_episode
from repro.core.networks import NetConfig
from repro.core.o2 import ks_distance, _quantiles
from repro.core.replay import SequenceReplay
from repro.index import env as E


# ------------------------------------------------------------------ reward
def test_reward_sign_matches_paper():
    # improvement over both baselines -> positive
    assert float(rw.reward(80.0, 100.0, 90.0)) > 0
    # regression below initial -> negative
    assert float(rw.reward(120.0, 100.0, 90.0)) < 0
    # no change -> zero
    assert abs(float(rw.reward(100.0, 100.0, 100.0))) < 1e-6


@settings(max_examples=50, deadline=None)
@given(st.floats(1.0, 1e6), st.floats(1.0, 1e6), st.floats(1.0, 1e6))
def test_reward_finite_and_sign_follows_delta0(r0, rprev, rt):
    """Paper invariant: reward > 0 iff the runtime improved over the initial
    baseline (Delta_{t->0} > 0); the formula is deliberately NOT monotone in
    rt alone (it also weighs step-over-step progress)."""
    r = float(rw.reward(rt, r0, rprev))
    assert np.isfinite(r)
    if rt < r0 * (1 - 1e-9):
        assert r >= 0.0
    elif rt > r0 * (1 + 1e-9):
        assert r <= 0.0


def test_reward_deltas():
    d0, d1 = rw.deltas(80.0, 100.0, 90.0)
    assert abs(float(d0) - 0.2) < 1e-6
    assert abs(float(d1) - (10.0 / 90.0)) < 1e-6


# ------------------------------------------------------------------ networks
def test_actor_critic_shapes(rng_key):
    cfg = NetConfig(obs_dim=26, action_dim=14, lstm_hidden=16, mlp_hidden=32)
    params = nets.init_actor_critic(rng_key, cfg)
    obs = jnp.ones((5, 26))
    h = nets.zero_hidden(cfg, (5,))
    a, h2 = nets.actor_apply(params["actor"], obs, h, cfg)
    assert a.shape == (5, 14) and float(jnp.max(jnp.abs(a))) <= 1.0
    q, _ = nets.critic_apply(params["critic0"], obs, a, h, cfg)
    assert q.shape == (5,)


def test_lstm_context_changes_output(rng_key):
    """The LSTM hidden state must influence the action (context matters)."""
    cfg = NetConfig(obs_dim=26, action_dim=14, lstm_hidden=16, mlp_hidden=32)
    params = nets.init_actor_critic(rng_key, cfg)
    obs = jnp.ones((26,))
    a0, h = nets.actor_apply(params["actor"], obs,
                             nets.zero_hidden(cfg), cfg)
    a1, _ = nets.actor_apply(params["actor"], obs, h, cfg)
    assert float(jnp.max(jnp.abs(a0 - a1))) > 1e-6


# ------------------------------------------------------------------ replay
def test_replay_sequences_respect_episodes():
    rep = SequenceReplay(128, obs_dim=4, action_dim=2, lstm_hidden=8,
                         seq_len=4, seed=0)
    for ep in range(6):
        for t in range(10):
            done = t == 9
            rep.add(np.full(4, ep), np.zeros(2), 0.0, np.zeros(4), done, 0.0,
                    (np.zeros(8), np.zeros(8)), (np.zeros(8), np.zeros(8)))
    batch = rep.sample_sequences(16)
    assert batch["obs"].shape == (16, 4, 4)
    # within a sampled window, no done except possibly at the last step
    assert np.all(batch["done"][:, :-1] == 0)


# ------------------------------------------------------------------ ddpg
def test_ddpg_update_runs_and_changes_params(rng_key):
    net_cfg = NetConfig(obs_dim=6, action_dim=3, lstm_hidden=8, mlp_hidden=16)
    dcfg = DDPGConfig(seq_len=4, burn_in=1, batch_size=8)
    state = ddpg.init_state(rng_key, net_cfg, dcfg)
    B, L = 8, 4
    batch = {
        "obs": jnp.ones((B, L, 6)), "action": jnp.zeros((B, L, 3)),
        "reward": jnp.ones((B, L)), "next_obs": jnp.ones((B, L, 6)),
        "done": jnp.zeros((B, L)), "cost": jnp.zeros((B, L)),
        "h_a": jnp.zeros((B, 8)), "c_a": jnp.zeros((B, 8)),
        "h_q": jnp.zeros((B, 8)), "c_q": jnp.zeros((B, 8)),
    }
    new_state, metrics = ddpg.update(state, batch, net_cfg, dcfg)
    assert np.isfinite(float(metrics["critic_loss"]))
    before = jax.tree.leaves(state["params"]["actor"])[0]
    after = jax.tree.leaves(new_state["params"]["actor"])[0]
    assert float(jnp.max(jnp.abs(before - after))) > 0


def test_lagrangian_lambda_rises_under_violations(rng_key):
    net_cfg = NetConfig(obs_dim=6, action_dim=3, lstm_hidden=8, mlp_hidden=16)
    dcfg = DDPGConfig(seq_len=4, burn_in=1, use_cost_critic=True,
                      cost_limit=0.5, lambda_lr=0.1)
    state = ddpg.init_state(rng_key, net_cfg, dcfg)
    B, L = 4, 4
    batch = {
        "obs": jnp.ones((B, L, 6)), "action": jnp.zeros((B, L, 3)),
        "reward": jnp.ones((B, L)), "next_obs": jnp.ones((B, L, 6)),
        "done": jnp.zeros((B, L)), "cost": jnp.ones((B, L)),  # violations!
        "h_a": jnp.zeros((B, 8)), "c_a": jnp.zeros((B, 8)),
        "h_q": jnp.zeros((B, 8)), "c_q": jnp.zeros((B, 8)),
    }
    new_state, metrics = ddpg.update(state, batch, net_cfg, dcfg)
    assert float(new_state["lmbda"]) > float(state["lmbda"])


# ------------------------------------------------------------------ etmdp
def test_etmdp_early_termination(rng_key, small_index_instance):
    """Force violations by shrinking budgets -> episode must terminate
    early with the termination reward."""
    data, workload = small_index_instance
    env_cfg = E.EnvConfig(index_type="alex", episode_len=20,
                          mem_budget=1.0, runtime_budget=1.0)  # always violate
    net_cfg = NetConfig(obs_dim=E.obs_dim(), action_dim=env_cfg.space.dim,
                        lstm_hidden=8, mlp_hidden=16)
    agent = ddpg.init_state(rng_key, net_cfg, DDPGConfig())
    et = ETMDPConfig(cost_budget=3.0, termination_reward=-1.0, enabled=True)
    s = rollout_episode(rng_key, agent, net_cfg, env_cfg, et, data, workload,
                        1.0, noise_scale=0.3)
    assert s["terminated_early"]
    assert s["steps"] <= 3  # 2 violations/step -> b_t exceeds C=3 at step 2
    et_off = ETMDPConfig(enabled=False)
    s2 = rollout_episode(rng_key, agent, net_cfg, env_cfg, et_off, data,
                         workload, 1.0, noise_scale=0.3)
    assert not s2["terminated_early"] and s2["steps"] == 20


# ------------------------------------------------------------------ o2
def test_ks_divergence_detects_shift(rng_key):
    from repro.index.workloads import sample_keys
    a = np.asarray(sample_keys(rng_key, 2048, "uniform"))
    b = np.asarray(sample_keys(jax.random.fold_in(rng_key, 1), 2048, "fb"))
    qa, qb = _quantiles(a, 32), _quantiles(b, 32)
    assert ks_distance(qa, qa) < 1e-9
    assert ks_distance(qa, qb) > 0.15
