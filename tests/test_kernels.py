"""Per-kernel correctness sweeps: Pallas (interpret=True, kernel body
executed on CPU) vs the pure-jnp ref oracle, across shapes and dtypes."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from _hypothesis_compat import given, settings, st

from repro.kernels.flash_attention.kernel import flash_attention
from repro.kernels.flash_attention.ref import attention_ref
from repro.kernels.index_probe.kernel import probe_pallas
from repro.kernels.index_probe.ops import (batched_lookup,
                                           predecessor_positions)
from repro.kernels.dispatch import KernelConfig
from repro.kernels.index_probe.ref import probe_ref
from repro.kernels.mamba_scan.kernel import selective_scan
from repro.kernels.mamba_scan.ref import selective_scan_ref

# compiled Pallas rows only run where a compiled backend exists; on CPU
# CI they skip-mark (the interpret rows execute the same kernel body)
requires_accel = pytest.mark.skipif(
    jax.default_backend() not in ("gpu", "tpu"),
    reason="compiled Pallas path needs an accelerator backend")

MODES = ["ref", "interpret",
         pytest.param("compiled", marks=requires_accel)]


# ------------------------------------------------------------ index probe
@pytest.mark.parametrize("n_tiles,tile,qcap", [
    (4, 128, 32), (8, 256, 16), (2, 512, 64), (16, 64, 8)])
def test_probe_matches_ref(n_tiles, tile, qcap, rng_key):
    keys = jnp.sort(jax.random.uniform(rng_key, (n_tiles * tile,))
                    ).reshape(n_tiles, tile)
    k2 = jax.random.fold_in(rng_key, 1)
    queries = jax.random.uniform(k2, (n_tiles, qcap))
    valid = jax.random.uniform(jax.random.fold_in(k2, 3),
                               (n_tiles, qcap)) < 0.8
    got = probe_pallas(keys, queries, valid.astype(jnp.int32))
    want = probe_ref(keys, queries, valid)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


def test_probe_boundary_queries(rng_key):
    keys = jnp.linspace(0.0, 1.0, 256).reshape(1, 256)
    queries = jnp.array([[-1.0, 0.0, 0.5, 1.0, 2.0, keys[0, 7], 0.25, 0.75]])
    valid = jnp.ones((1, 8), bool)
    got = probe_pallas(keys, queries, valid.astype(jnp.int32))
    want = probe_ref(keys, queries, valid)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


@settings(max_examples=10, deadline=None)
@given(st.integers(0, 10_000), st.sampled_from([128, 256]))
def test_batched_lookup_end_to_end(seed, tile):
    """End-to-end op: global ranks equal searchsorted on the full array."""
    key = jax.random.PRNGKey(seed)
    n = 8 * tile
    keys = jnp.sort(jax.random.uniform(key, (n,)))
    queries = jax.random.uniform(jax.random.fold_in(key, 1), (64,))
    ranks, dropped = batched_lookup(keys, queries, tile=tile, qcap=64,
                                    mode="interpret")
    want = jnp.searchsorted(keys, queries, side="right").astype(jnp.int32)
    kept = ~dropped
    np.testing.assert_array_equal(np.asarray(ranks)[np.asarray(kept)],
                                  np.asarray(want)[np.asarray(kept)])
    assert float(jnp.mean(kept)) > 0.9  # capacity ample here


# ------------------------------------------------------------ flash attn
@pytest.mark.parametrize("b,h,s,d,dtype", [
    (2, 2, 256, 64, jnp.float32),
    (1, 4, 128, 128, jnp.float32),
    (2, 1, 512, 32, jnp.bfloat16),
])
@pytest.mark.parametrize("causal,window", [(True, 0), (True, 64), (False, 0)])
def test_flash_matches_ref(b, h, s, d, dtype, causal, window, rng_key):
    ks = jax.random.split(rng_key, 3)
    q = jax.random.normal(ks[0], (b, h, s, d)).astype(dtype)
    k = jax.random.normal(ks[1], (b, h, s, d)).astype(dtype)
    v = jax.random.normal(ks[2], (b, h, s, d)).astype(dtype)
    got = flash_attention(q, k, v, causal=causal, window=window,
                          block_q=128, block_k=128, interpret=True)
    want = attention_ref(q, k, v, causal=causal, window=window)
    atol = 2e-2 if dtype == jnp.bfloat16 else 2e-5
    np.testing.assert_allclose(np.asarray(got, np.float32),
                               np.asarray(want, np.float32), atol=atol)


def test_flash_matches_model_attention(rng_key):
    """The kernel agrees with the model stack's streaming-softmax jnp path."""
    from repro.models.attention import flash_attention_jnp
    b, s, h, d = 2, 256, 4, 64
    ks = jax.random.split(rng_key, 3)
    q = jax.random.normal(ks[0], (b, s, h, d), jnp.float32)
    k = jax.random.normal(ks[1], (b, s, h, d), jnp.float32)
    v = jax.random.normal(ks[2], (b, s, h, d), jnp.float32)
    want = flash_attention_jnp(q, k, v, causal=True)
    got = flash_attention(q.transpose(0, 2, 1, 3), k.transpose(0, 2, 1, 3),
                          v.transpose(0, 2, 1, 3), causal=True,
                          interpret=True).transpose(0, 2, 1, 3)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), atol=2e-5)


# ------------------------------------------------------------ mamba scan
@pytest.mark.parametrize("b,s,di,n,chunk", [
    (2, 128, 64, 16, 32), (1, 256, 256, 16, 256), (2, 64, 512, 8, 64)])
def test_mamba_scan_matches_ref(b, s, di, n, chunk, rng_key):
    ks = jax.random.split(rng_key, 4)
    u = jax.random.normal(ks[0], (b, s, di), jnp.float32)
    dt = jax.nn.softplus(jax.random.normal(ks[1], (b, s, di)) - 1.0)
    b_mat = jax.random.normal(ks[2], (b, s, n), jnp.float32)
    c_mat = jax.random.normal(ks[3], (b, s, n), jnp.float32)
    a = -jnp.exp(jax.random.normal(rng_key, (di, n)) * 0.5)
    got = selective_scan(u, dt, b_mat, c_mat, a, chunk=chunk, di_block=128,
                         interpret=True)
    want = selective_scan_ref(u, dt, b_mat, c_mat, a)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               atol=1e-4, rtol=1e-4)


def test_mamba_scan_matches_model_block(rng_key):
    """The kernel recurrence equals the model's chunked _scan_chunk path."""
    from repro.models.mamba import _scan_chunk
    b, s, di, n = 2, 64, 32, 8
    ks = jax.random.split(rng_key, 4)
    u = jax.random.normal(ks[0], (b, s, di), jnp.float32)
    dt = jax.nn.softplus(jax.random.normal(ks[1], (b, s, di)))
    b_mat = jax.random.normal(ks[2], (b, s, n), jnp.float32)
    c_mat = jax.random.normal(ks[3], (b, s, n), jnp.float32)
    a = -jnp.exp(jax.random.normal(rng_key, (di, n)) * 0.3)
    h0 = jnp.zeros((b, di, n), jnp.float32)
    _, want = _scan_chunk(h0, u, dt, b_mat, c_mat, a)
    got = selective_scan(u, dt, b_mat, c_mat, a, chunk=64, interpret=True)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               atol=1e-4, rtol=1e-4)


# ------------------------------------------------- capacity path (dispatch)
def test_batched_lookup_capacity_overflow_flags_dropped(rng_key):
    """Queries beyond a tile's `qcap` come back flagged `dropped` with
    rank -1 — never a silently-wrong rank."""
    tile = 128
    keys = jnp.sort(jax.random.uniform(rng_key, (8 * tile,)))
    # cram 32 queries into tile 0's key range with qcap=4 -> overflow
    queries = jnp.linspace(float(keys[1]), float(keys[tile - 2]), 32)
    ranks, dropped = batched_lookup(keys, queries, tile=tile, qcap=4,
                                    mode="interpret")
    ranks, dropped = np.asarray(ranks), np.asarray(dropped)
    assert dropped.sum() == 32 - 4          # exactly qcap survive
    assert np.all(ranks[dropped] == -1)
    want = np.asarray(jnp.searchsorted(keys, queries, side="right"))
    np.testing.assert_array_equal(ranks[~dropped], want[~dropped])


def test_batched_lookup_capacity_retry_recovers(rng_key):
    """The caller contract: retrying with a larger `qcap` clears the drops
    and recovers the reference ranks (probe_ref path and searchsorted)."""
    tile = 128
    keys = jnp.sort(jax.random.uniform(rng_key, (8 * tile,)))
    queries = jnp.linspace(float(keys[1]), float(keys[tile - 2]), 32)
    _, dropped = batched_lookup(keys, queries, tile=tile, qcap=4,
                                mode="interpret")
    assert bool(np.asarray(dropped).any())
    # retry the same batch with ample capacity
    ranks2, dropped2 = batched_lookup(keys, queries, tile=tile, qcap=32,
                                      mode="interpret")
    assert not bool(np.asarray(dropped2).any())
    ref_ranks, ref_dropped = batched_lookup(keys, queries, tile=tile,
                                            qcap=32, mode="ref")
    assert not bool(np.asarray(ref_dropped).any())
    np.testing.assert_array_equal(np.asarray(ranks2), np.asarray(ref_ranks))
    want = np.asarray(jnp.searchsorted(keys, queries, side="right"))
    np.testing.assert_array_equal(np.asarray(ranks2), want)


# ------------------------------------- tri-mode dispatch parity (ISSUE 10)
@pytest.mark.parametrize("mode", MODES)
@settings(max_examples=8, deadline=None)
@given(st.integers(0, 10_000), st.sampled_from([64, 128, 256]),
       st.sampled_from(["float32", "float64"]))
def test_batched_lookup_mode_parity(mode, seed, tile, dtype):
    """Every execution mode agrees with searchsorted on duplicate-heavy
    keys and out-of-range queries, across tiles and dtypes."""
    key = jax.random.PRNGKey(seed)
    n = 8 * tile
    # integer-valued keys -> runs of duplicates, some spanning tiles
    keys = jnp.sort(jax.random.randint(key, (n,), 0, n // 4)
                    ).astype(dtype)
    k2 = jax.random.fold_in(key, 1)
    # queries stretched past both ends of the key range
    queries = (jax.random.uniform(k2, (96,), jnp.float32)
               * (n // 4) * 1.5 - (n // 8)).astype(dtype)
    ranks, dropped = batched_lookup(keys, queries, tile=tile,
                                    qcap=queries.shape[0], mode=mode)
    assert not bool(np.asarray(dropped).any())   # qcap=m is drop-free
    want = jnp.searchsorted(keys, queries, side="right").astype(jnp.int32)
    np.testing.assert_array_equal(np.asarray(ranks), np.asarray(want))


@pytest.mark.parametrize("mode", MODES)
@settings(max_examples=8, deadline=None)
@given(st.integers(0, 10_000), st.sampled_from([512, 768, 2048]))
def test_predecessor_positions_mode_parity(mode, seed, n):
    """The env-facing probe equals clip(searchsorted-1) in every mode —
    including n=768 where the auto tile is 256, not the 512 cap."""
    key = jax.random.PRNGKey(seed)
    keys = jnp.sort(jax.random.randint(key, (n,), 0, n // 2)
                    ).astype(jnp.float32)
    q = (jax.random.uniform(jax.random.fold_in(key, 1), (64,))
         * (n // 2) * 1.5 - (n // 8))
    got = predecessor_positions(keys, q, kernel=KernelConfig(mode=mode))
    want = jnp.clip(jnp.searchsorted(keys, q, side="right") - 1, 0, n - 1)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


def test_predecessor_positions_ragged_falls_back(rng_key):
    """Array lengths with no usable pow2 divisor take the searchsorted
    fallback (still exact) instead of asserting inside batched_lookup."""
    keys = jnp.sort(jax.random.uniform(rng_key, (1001,)))   # odd length
    q = jax.random.uniform(jax.random.fold_in(rng_key, 1), (32,))
    got = predecessor_positions(keys, q, kernel=KernelConfig(mode="interpret"))
    want = jnp.clip(jnp.searchsorted(keys, q, side="right") - 1, 0, 1000)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))
    # probe_reads=False forces the reference regardless of mode
    got_off = predecessor_positions(
        jnp.sort(jax.random.uniform(rng_key, (1024,))), q,
        kernel=KernelConfig(mode="interpret", probe_reads=False))
    keys2 = jnp.sort(jax.random.uniform(rng_key, (1024,)))
    want2 = jnp.clip(jnp.searchsorted(keys2, q, side="right") - 1, 0, 1023)
    np.testing.assert_array_equal(np.asarray(got_off), np.asarray(want2))
