"""Fault tolerance / checkpointing / elastic / pipeline / straggler tests."""
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint import ckpt
from repro.checkpoint.manager import CheckpointManager
from repro.data.pipeline import DataPipeline, PipelineConfig, batch_at
from repro.launch.train import Trainer, TrainerConfig
from repro.runtime.fault import FailureInjector, InjectedFailure, run_with_restarts
from repro.runtime.straggler import StragglerDetector, simulate_speculative_execution


# ------------------------------------------------------------ pipeline
def test_pipeline_deterministic_and_restorable():
    cfg = PipelineConfig(vocab_size=128, seq_len=16, global_batch=4, seed=7)
    p1 = DataPipeline(cfg)
    seq1 = [next(p1) for _ in range(5)]
    state = p1.state_dict()
    p2 = DataPipeline.from_state(cfg, state)
    b1, b2 = next(p1), next(p2)
    np.testing.assert_array_equal(np.asarray(b1["tokens"]),
                                  np.asarray(b2["tokens"]))
    # pure-function access matches the iterator
    np.testing.assert_array_equal(np.asarray(seq1[3]["tokens"]),
                                  np.asarray(batch_at(cfg, 3)["tokens"]))


def test_pipeline_shards_partition_global_batch():
    base = PipelineConfig(vocab_size=128, seq_len=8, global_batch=8, seed=1)
    full = batch_at(base, 0)
    assert full["tokens"].shape == (8, 8)
    shard_batches = [
        batch_at(PipelineConfig(vocab_size=128, seq_len=8, global_batch=8,
                                seed=1, n_shards=4, shard_id=i), 0)
        for i in range(4)]
    assert all(b["tokens"].shape == (2, 8) for b in shard_batches)
    # distinct shards produce distinct data (independent streams)
    assert not np.array_equal(np.asarray(shard_batches[0]["tokens"]),
                              np.asarray(shard_batches[1]["tokens"]))


# ------------------------------------------------------------ checkpoint
def test_checkpoint_roundtrip_and_atomicity(tmp_path):
    tree = {"a": jnp.arange(6, dtype=jnp.float32).reshape(2, 3),
            "b": {"c": jnp.ones((4,), jnp.bfloat16)}}
    path = ckpt.save(str(tmp_path), 3, tree, extra={"note": "x"})
    assert path.endswith("step_3") and os.path.isdir(path)
    assert not any(p.endswith(".tmp") for p in os.listdir(tmp_path))
    restored, manifest = ckpt.restore(str(tmp_path), 3, tree)
    np.testing.assert_array_equal(np.asarray(restored["a"]),
                                  np.asarray(tree["a"]))
    assert manifest["extra"]["note"] == "x"
    assert restored["b"]["c"].dtype == jnp.bfloat16


def test_manager_rotation(tmp_path):
    mgr = CheckpointManager(str(tmp_path), save_every=1, keep_last=2)
    tree = {"w": jnp.zeros((2,))}
    for step in range(5):
        mgr.save(step, tree)
    assert ckpt.available_steps(str(tmp_path)) == [3, 4]


# ------------------------------------------------------------ fault tolerance
@pytest.mark.slow
def test_restart_bitwise_identical_trajectory(tmp_path):
    """Kill training mid-run; the restarted run must land on the exact same
    parameters as an uninterrupted run (deterministic pipeline + atomic
    checkpoints)."""
    def tc(d):
        return TrainerConfig(arch="llama3_8b", scale="tiny", steps=30,
                             global_batch=2, seq_len=64,
                             ckpt_dir=str(d), save_every=5, log_every=1000)

    # uninterrupted reference
    ref = Trainer(tc(tmp_path / "ref"))
    ref.run_until(30)

    # interrupted run: dies at step 17, restarts from step 15 checkpoint
    injector = FailureInjector(fail_at_steps=(17,), max_failures=1)
    holder = {"first": True}

    def make_driver():
        inj = injector if holder.pop("first", False) else None
        return Trainer(tc(tmp_path / "faulty"), injector=inj)

    driver, restarts = run_with_restarts(make_driver, 30)
    assert restarts == 1

    ref_leaves = jax.tree.leaves(ref.state["params"])
    got_leaves = jax.tree.leaves(driver.state["params"])
    for a, b in zip(ref_leaves, got_leaves):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


# ------------------------------------------------------------ elastic
def test_elastic_reshard_roundtrip(tmp_path):
    import os as _os
    if len(jax.devices()) < 2:
        from repro.runtime.elastic import restore_on_mesh, reshard_tree
        from repro.runtime import mesh_utils
        # single-device: verify the API works with a 1x1 mesh at least
        mesh = jax.make_mesh((1, 1), ("data", "model"))
        tree = {"w": jnp.arange(8, dtype=jnp.float32).reshape(2, 4)}
        axes = {"w": ("batch", "mlp")}
        out = reshard_tree(tree, axes, mesh)
        np.testing.assert_array_equal(np.asarray(out["w"]),
                                      np.asarray(tree["w"]))
        ckpt.save(str(tmp_path), 0, tree)
        restored, _ = restore_on_mesh(str(tmp_path), 0, tree, axes, mesh)
        np.testing.assert_array_equal(np.asarray(restored["w"]),
                                      np.asarray(tree["w"]))
    else:
        pytest.skip("multi-device elastic covered by dryrun")


# ------------------------------------------------------------ straggler
def test_straggler_detection_and_speculation():
    rng = np.random.default_rng(0)
    times = np.abs(rng.normal(1.0, 0.05, (50, 8)))
    times[:, 3] *= 3.0  # shard 3 is a consistent straggler
    det = StragglerDetector(n_shards=8)
    base, spec = simulate_speculative_execution(times, det)
    assert 3 in det.stragglers()
    assert spec[10:].mean() < base[10:].mean() * 0.6  # big win after warmup


# ------------------------------------------------------------ grad compress
def test_grad_compression_error_feedback_tracks_sgd():
    """Compressed-SGD trajectory must track uncompressed SGD (EF property),
    single-device path (the psum path is covered in test_dryrun_small)."""
    from repro.optim.grad_compress import compress_residual, dequantize
    rng = np.random.default_rng(0)
    w_ref = np.zeros(32)
    w_cmp = np.zeros(32)
    err = np.zeros(32)
    target = rng.normal(size=32)
    lr = 0.1
    for step in range(200):
        g_ref = (w_ref - target)
        w_ref = w_ref - lr * g_ref
        g = (w_cmp - target)
        q, scale, err = compress_residual(jnp.asarray(g), jnp.asarray(err))
        g_hat = np.asarray(dequantize(q, scale))
        err = np.asarray(err)
        w_cmp = w_cmp - lr * g_hat
    assert np.max(np.abs(w_cmp - w_ref)) < 0.05
